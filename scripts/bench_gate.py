#!/usr/bin/env python3
"""Bench-regression gate: diff fresh BENCH_*.json files against committed
baselines and fail on gross wall-clock regressions.

Usage:
    scripts/bench_gate.py [--results DIR] [--baselines DIR]
                          [--tolerance X] [--floor-s S]

Every bench target emits a ``BENCH_<name>.json`` of the shape
``{"title": ..., "rows": [{"label": ..., "<cell>": <num>, ...}, ...]}``
(see rust/src/bench/report.rs). The gate compares each *time-like* cell
(name ending in ``_s``) row-by-row against the baseline file of the same
name under --baselines:

* new > tolerance * old  AND  new - old > floor  ->  REGRESSION (exit 1)
* baseline file / row / cell missing              ->  warning (seed mode)

The tolerance is deliberately generous (default 2x) and the absolute
floor (default 0.05 s) ignores noise on micro timings: this gate exists
to catch "the task path got 3x slower", not 10% jitter on shared CI
runners. Byte/count cells (ship_bytes, ships, ...) are ignored — they are
asserted exactly by the test suite where they matter.

Seeding: run the bench job (or ``cd rust && cargo bench --benches --
--tiny``), then copy the produced BENCH_*.json into bench-baselines/ and
commit (see bench-baselines/README.md).
"""

import argparse
import glob
import json
import os
import sys


def load_rows(path):
    """-> {label: {cell: value}} for one bench report file."""
    with open(path) as f:
        doc = json.load(f)
    rows = {}
    for row in doc.get("rows", []):
        label = row.get("label", "?")
        rows[label] = {k: v for k, v in row.items()
                       if k != "label" and isinstance(v, (int, float))}
    return rows


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--results", default=".",
                    help="directory holding fresh BENCH_*.json (default .)")
    ap.add_argument("--baselines", default="bench-baselines",
                    help="directory holding committed baselines")
    ap.add_argument("--tolerance", type=float, default=2.0,
                    help="fail when new > tolerance * baseline (default 2.0)")
    ap.add_argument("--floor-s", type=float, default=0.05,
                    help="ignore regressions smaller than this many seconds")
    args = ap.parse_args()

    fresh = sorted(glob.glob(os.path.join(args.results, "BENCH_*.json")))
    if not fresh:
        print(f"error: no BENCH_*.json under {args.results} — did the benches run?")
        return 1

    regressions = []
    unseeded = []
    checked = 0
    for path in fresh:
        name = os.path.basename(path)
        base_path = os.path.join(args.baselines, name)
        if not os.path.exists(base_path):
            unseeded.append(name)
            continue
        new_rows = load_rows(path)
        old_rows = load_rows(base_path)
        for label, cells in sorted(new_rows.items()):
            old_cells = old_rows.get(label)
            if old_cells is None:
                print(f"::warning::bench gate: {name} row '{label}' has no "
                      f"baseline row — refresh bench-baselines/{name}")
                continue
            for cell, new in sorted(cells.items()):
                if not cell.endswith("_s"):
                    continue  # only wall-clock-like cells gate
                old = old_cells.get(cell)
                if old is None:
                    print(f"::warning::bench gate: {name} '{label}'.{cell} has "
                          f"no baseline cell — refresh bench-baselines/{name}")
                    continue
                checked += 1
                if new > args.tolerance * old and new - old > args.floor_s:
                    regressions.append(
                        f"{name} '{label}'.{cell}: {old:.4f}s -> {new:.4f}s "
                        f"({new / old:.2f}x, tolerance {args.tolerance:.1f}x)")
                else:
                    print(f"ok: {name} '{label}'.{cell}: "
                          f"{old:.4f}s -> {new:.4f}s ({new / max(old, 1e-12):.2f}x)")

    for name in unseeded:
        # loud but not fatal: the first green run on a fresh machine seeds
        # the baselines (bench-baselines/README.md)
        print(f"::warning::bench gate: no baseline for {name} — "
              f"seed it from this run's artifacts")

    if regressions:
        print(f"\nbench gate: {len(regressions)} wall-clock regression(s):")
        for r in regressions:
            print(f"::error::{r}")
        return 1
    print(f"\nbench gate: {checked} timing cell(s) within {args.tolerance:.1f}x "
          f"of baseline ({len(unseeded)} file(s) unseeded)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
