#!/usr/bin/env bash
# Launch K listen-mode parccm workers on ephemeral loopback ports and
# print a ready-to-paste --workers-at string.
#
# Usage:
#   scripts/launch_local_cluster.sh [K] [PARCCM_BINARY]
#
#   K              number of workers (default 3)
#   PARCCM_BINARY  path to the parccm binary
#                  (default rust/target/release/parccm)
#
# Honors PARCCM_AUTH_TOKEN: when set, every worker requires it and the
# driver must pass the same token (--auth-token or the same env var).
#
# Output (eval-able shell):
#   PARCCM_WORKERS=127.0.0.1:34567,127.0.0.1:34568,...
#   WORKER_PIDS="1234 1235 ..."
#
# Typical use:
#   eval "$(scripts/launch_local_cluster.sh 3)"
#   rust/target/release/parccm fig4 --backend process \
#       --workers-at "$PARCCM_WORKERS" --replicas 2
#   kill $WORKER_PIDS
set -euo pipefail

K="${1:-3}"
BIN="${2:-rust/target/release/parccm}"

if [ ! -x "$BIN" ]; then
    echo "error: parccm binary not found at '$BIN' (build with: cd rust && cargo build --release)" >&2
    exit 1
fi

LOG_DIR="$(mktemp -d "${TMPDIR:-/tmp}/parccm-cluster.XXXXXX")"
ADDRS=()
PIDS=()

for i in $(seq 1 "$K"); do
    out="$LOG_DIR/worker$i.out"
    err="$LOG_DIR/worker$i.err"
    "$BIN" worker --listen 127.0.0.1:0 >"$out" 2>"$err" &
    pid=$!
    # the worker announces its bound address on stdout before accepting
    addr=""
    for _ in $(seq 1 100); do
        addr="$(sed -n 's/^PARCCM_WORKER_LISTENING //p' "$out" | head -n1)"
        [ -n "$addr" ] && break
        if ! kill -0 "$pid" 2>/dev/null; then
            echo "error: worker $i exited before listening; stderr:" >&2
            cat "$err" >&2
            exit 1
        fi
        sleep 0.1
    done
    if [ -z "$addr" ]; then
        echo "error: worker $i never announced its address (see $out)" >&2
        exit 1
    fi
    ADDRS+=("$addr")
    PIDS+=("$pid")
    echo "# worker $i: pid $pid at $addr (logs: $err)" >&2
done

joined="$(IFS=,; echo "${ADDRS[*]}")"
echo "PARCCM_WORKERS=$joined"
echo "WORKER_PIDS=\"${PIDS[*]}\""
