#!/usr/bin/env bash
# Launch K listen-mode parccm workers on ephemeral loopback ports and
# print a ready-to-paste --workers-at string — or restart one of them on
# its recorded port (the rejoin fault schedule).
#
# Usage:
#   scripts/launch_local_cluster.sh [K] [PARCCM_BINARY]
#   scripts/launch_local_cluster.sh restart IDX [PARCCM_BINARY]
#   scripts/launch_local_cluster.sh wedge IDX
#
#   K              number of workers (default 3)
#   IDX            0-based index into PARCCM_WORKERS of the worker to
#                  restart on its recorded host:port (restart mode needs
#                  PARCCM_WORKERS and WORKER_PIDS exported from a
#                  previous launch; pair the driver with
#                  --rejoin-backoff-secs so it redials the address)
#   wedge IDX      SIGSTOP worker IDX (needs WORKER_PIDS): the process
#                  freezes but its sockets stay open, so the driver sees a
#                  healthy connection that never answers — the straggler
#                  shape only --task-deadline-secs / --speculate-factor
#                  can recover from (a kill would be detected as a death
#                  and requeued immediately, which is a different fault).
#                  Un-wedge with `kill -CONT pid`, or just kill the pid.
#   PARCCM_BINARY  path to the parccm binary
#                  (default rust/target/release/parccm)
#
# Honors PARCCM_AUTH_TOKEN: when set, every worker requires it and the
# driver must pass the same token (--auth-token or the same env var).
#
# Output (eval-able shell):
#   launch:  PARCCM_WORKERS=127.0.0.1:34567,...  and  WORKER_PIDS="1234 ..."
#   restart: WORKER_PIDS="1234 ..."  (with the restarted slot's new pid)
#
# Typical use:
#   eval "$(scripts/launch_local_cluster.sh 3)"
#   export PARCCM_WORKERS WORKER_PIDS
#   rust/target/release/parccm fig4 --backend process \
#       --workers-at "$PARCCM_WORKERS" --replicas 2 --rejoin-backoff-secs 1 &
#   kill -9 "${WORKER_PIDS%% *}"                       # fault injection
#   eval "$(scripts/launch_local_cluster.sh restart 0)"  # ...and recovery
#   kill $WORKER_PIDS
set -euo pipefail

# Poll $1 (a worker's stdout file) for the PARCCM_WORKER_LISTENING ready
# line while pid $2 stays alive; echoes the bound address on success.
wait_for_addr() {
    local addr=""
    for _ in $(seq 1 100); do
        addr="$(sed -n 's/^PARCCM_WORKER_LISTENING //p' "$1" | head -n1)"
        if [ -n "$addr" ]; then
            echo "$addr"
            return 0
        fi
        kill -0 "$2" 2>/dev/null || return 1
        sleep 0.1
    done
    return 1
}

if [ "${1:-}" = "wedge" ]; then
    IDX="${2:?usage: launch_local_cluster.sh wedge IDX}"
    : "${WORKER_PIDS:?wedge mode needs WORKER_PIDS exported from a launch}"
    read -r -a PIDS <<<"$WORKER_PIDS"
    PID="${PIDS[$IDX]:?no recorded pid for worker index $IDX}"
    kill -STOP "$PID"
    echo "# worker $IDX: wedged (SIGSTOP) pid $PID — resume with: kill -CONT $PID" >&2
    exit 0
fi

if [ "${1:-}" = "restart" ]; then
    IDX="${2:?usage: launch_local_cluster.sh restart IDX [BIN]}"
    BIN="${3:-rust/target/release/parccm}"
    : "${PARCCM_WORKERS:?restart mode needs PARCCM_WORKERS exported from a launch}"
    : "${WORKER_PIDS:?restart mode needs WORKER_PIDS exported from a launch}"
    IFS=',' read -r -a ADDRS <<<"$PARCCM_WORKERS"
    read -r -a PIDS <<<"$WORKER_PIDS"
    ADDR="${ADDRS[$IDX]:?no recorded address for worker index $IDX}"
    LOG_DIR="$(mktemp -d "${TMPDIR:-/tmp}/parccm-cluster.XXXXXX")"
    out="$LOG_DIR/restart$IDX.out"
    err="$LOG_DIR/restart$IDX.err"
    pid=""
    # the worker binds with SO_REUSEADDR, so a lingering TIME_WAIT from
    # the killed predecessor is fine; retry briefly anyway in case the OS
    # has not finished tearing the old socket down
    for _ in $(seq 1 20); do
        "$BIN" worker --listen "$ADDR" >"$out" 2>"$err" &
        pid=$!
        if addr="$(wait_for_addr "$out" "$pid")"; then
            break
        fi
        pid=""
        sleep 0.25
    done
    if [ -z "$pid" ]; then
        echo "error: could not re-listen on $ADDR; stderr:" >&2
        cat "$err" >&2
        exit 1
    fi
    if [ "$addr" != "$ADDR" ]; then
        echo "error: restarted worker bound $addr, expected $ADDR" >&2
        exit 1
    fi
    PIDS[IDX]="$pid"
    echo "# worker $IDX: restarted, pid $pid at $ADDR (logs: $err)" >&2
    echo "WORKER_PIDS=\"${PIDS[*]}\""
    exit 0
fi

K="${1:-3}"
BIN="${2:-rust/target/release/parccm}"

if [ ! -x "$BIN" ]; then
    echo "error: parccm binary not found at '$BIN' (build with: cd rust && cargo build --release)" >&2
    exit 1
fi

LOG_DIR="$(mktemp -d "${TMPDIR:-/tmp}/parccm-cluster.XXXXXX")"
ADDRS=()
PIDS=()

for i in $(seq 1 "$K"); do
    out="$LOG_DIR/worker$i.out"
    err="$LOG_DIR/worker$i.err"
    "$BIN" worker --listen 127.0.0.1:0 >"$out" 2>"$err" &
    pid=$!
    # the worker announces its bound address on stdout before accepting
    if ! addr="$(wait_for_addr "$out" "$pid")"; then
        echo "error: worker $i never announced its address; stderr:" >&2
        cat "$err" >&2
        exit 1
    fi
    ADDRS+=("$addr")
    PIDS+=("$pid")
    echo "# worker $i: pid $pid at $addr (logs: $err)" >&2
done

joined="$(IFS=,; echo "${ADDRS[*]}")"
echo "PARCCM_WORKERS=$joined"
echo "WORKER_PIDS=\"${PIDS[*]}\""
