"""Shared test data builders."""

import numpy as np

from compile.kernels import EMAX, KMAX


def embed_cloud(rng, n, e):
    """n points with e active lanes, zero-padded to EMAX."""
    pts = np.zeros((n, EMAX), np.float32)
    pts[:, :e] = rng.normal(size=(n, e)).astype(np.float32)
    return pts


def k_mask(e):
    m = np.zeros(KMAX, np.float32)
    m[: e + 1] = 1.0
    return m


def coupled_logistic(n, beta_xy=0.02, beta_yx=0.1, rx=3.8, ry=3.5,
                     x0=0.4, y0=0.2, discard=300):
    """Sugihara-style coupled logistic maps. beta_yx > beta_xy means X
    drives Y more strongly than Y drives X."""
    total = n + discard
    x = np.empty(total)
    y = np.empty(total)
    x[0], y[0] = x0, y0
    for t in range(total - 1):
        x[t + 1] = x[t] * (rx - rx * x[t] - beta_xy * y[t])
        y[t + 1] = y[t] * (ry - ry * y[t] - beta_yx * x[t])
    return x[discard:].astype(np.float32), y[discard:].astype(np.float32)


def lag_embed(series, e, tau):
    """Lagged-coordinate embedding: row t -> [x_t, x_{t-tau}, ...,
    x_{t-(e-1)tau}], zero-padded to EMAX. Returns (vectors, time_indices)."""
    offset = (e - 1) * tau
    n = len(series) - offset
    out = np.zeros((n, EMAX), np.float32)
    for j in range(e):
        out[:, j] = series[offset - j * tau : offset - j * tau + n]
    return out, np.arange(offset, len(series), dtype=np.float32)
