"""AOT lowering: HLO text well-formedness and manifest contract."""

import json
import os
import subprocess
import sys

import pytest

from compile import aot
from compile.kernels import EMAX, KMAX


def test_cross_map_hlo_text_shape():
    text = aot.to_hlo_text(aot.lower_cross_map(256, 256))
    assert text.startswith("HloModule")
    # entry layout encodes the exact input order the Rust manifest relies on
    assert "f32[256,8]" in text
    assert "f32[11]" in text
    assert "(f32[], f32[256]" in text  # (rho, preds) tuple


def test_distance_hlo_text_shape():
    text = aot.to_hlo_text(aot.lower_distances(256, 256))
    assert text.startswith("HloModule")
    assert "f32[256,256]" in text


def test_simplex_hlo_text_shape():
    text = aot.to_hlo_text(aot.lower_simplex(256))
    assert text.startswith("HloModule")
    assert "f32[256,11]" in text


@pytest.fixture(scope="module")
def quick_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(out), "--quick"],
        check=True,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    return out


def test_manifest_contract(quick_artifacts):
    with open(quick_artifacts / "manifest.json") as f:
        manifest = json.load(f)
    assert manifest["emax"] == EMAX
    assert manifest["kmax"] == KMAX
    kinds = {a["kind"] for a in manifest["artifacts"]}
    assert kinds == {"cross_map", "distance", "simplex"}
    for a in manifest["artifacts"]:
        path = quick_artifacts / a["file"]
        assert path.exists(), a
        head = path.read_text()[:64]
        assert head.startswith("HloModule"), a
        assert a["n"] >= 1 and a["p"] >= 1
