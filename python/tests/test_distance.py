"""Pallas distance kernel vs the pure-jnp oracle and a naive O(P*N*E) loop."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import EMAX, distance, ref
from .helpers import embed_cloud


def naive_sq_distances(pred, lib):
    p, n = pred.shape[0], lib.shape[0]
    out = np.zeros((p, n), np.float64)
    for i in range(p):
        for j in range(n):
            out[i, j] = np.sum((pred[i].astype(np.float64) - lib[j].astype(np.float64)) ** 2)
    return out


def test_matches_ref_exact_shapes():
    rng = np.random.default_rng(1)
    pred = embed_cloud(rng, 64, 3)
    lib = embed_cloud(rng, 128, 3)
    got = np.asarray(distance.sq_distances(jnp.asarray(pred), jnp.asarray(lib), 32, 32))
    want = np.asarray(ref.sq_distances(jnp.asarray(pred), jnp.asarray(lib)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_matches_naive_float64():
    rng = np.random.default_rng(2)
    pred = embed_cloud(rng, 16, 5)
    lib = embed_cloud(rng, 24, 5)
    got = np.asarray(distance.sq_distances(jnp.asarray(pred), jnp.asarray(lib), 8, 8))
    want = naive_sq_distances(pred, lib)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_zero_padding_invariance():
    """Extra zero lanes change nothing — the artifact-bucket contract."""
    rng = np.random.default_rng(3)
    pred = embed_cloud(rng, 32, 2)
    lib = embed_cloud(rng, 32, 2)
    d_padded = np.asarray(distance.sq_distances(jnp.asarray(pred), jnp.asarray(lib), 16, 16))
    # recompute with only 2 active lanes via the oracle on truncated copies
    pred8 = np.zeros_like(pred); pred8[:, :2] = pred[:, :2]
    lib8 = np.zeros_like(lib); lib8[:, :2] = lib[:, :2]
    d_ref = np.asarray(ref.sq_distances(jnp.asarray(pred8), jnp.asarray(lib8)))
    # tiling may reassociate the reductions -> tiny float drift
    np.testing.assert_allclose(d_padded, d_ref, rtol=1e-5, atol=1e-5)


def test_self_distance_zero_and_symmetry():
    rng = np.random.default_rng(4)
    pts = embed_cloud(rng, 48, 4)
    d = np.asarray(distance.sq_distances(jnp.asarray(pts), jnp.asarray(pts), 16, 16))
    np.testing.assert_allclose(np.diag(d), np.zeros(48), atol=1e-4)
    np.testing.assert_allclose(d, d.T, rtol=1e-5, atol=1e-5)


def test_block_size_invariance():
    """Result must not depend on the BlockSpec tiling."""
    rng = np.random.default_rng(5)
    pred = embed_cloud(rng, 64, 6)
    lib = embed_cloud(rng, 64, 6)
    a = np.asarray(distance.sq_distances(jnp.asarray(pred), jnp.asarray(lib), 64, 64))
    b = np.asarray(distance.sq_distances(jnp.asarray(pred), jnp.asarray(lib), 16, 32))
    # tiling changes XLA fusion order -> bitwise equality is too strong,
    # but the drift must stay at reassociation scale
    np.testing.assert_allclose(a, b, rtol=1e-5, atol=1e-5)


def test_near_duplicate_points_no_cancellation():
    """Regression test: the matmul expansion ||a||^2+||b||^2-2ab loses the
    tiny distances between near-duplicate points to cancellation (found as
    a 6e-4 rho divergence vs the Rust native backend), which perturbs CCM
    neighbour ORDER. The direct-difference kernel must rank near-twins
    exactly like a float64 reference."""
    rng = np.random.default_rng(11)
    base = embed_cloud(rng, 8, 4) * 10.0  # large magnitude -> cancellation zone
    lib = np.repeat(base, 4, axis=0)  # 32 rows: 4 near-copies of each
    lib += rng.normal(scale=1e-3, size=lib.shape).astype(np.float32)
    pred = lib[:8].copy()
    got = np.asarray(distance.sq_distances(jnp.asarray(pred), jnp.asarray(lib), 8, 8))
    want = naive_sq_distances(pred, lib)
    # relative accuracy of the *small* distances is what matters
    small = want < 1e-3
    assert small.any()
    rel = np.abs(got[small] - want[small]) / np.maximum(want[small], 1e-12)
    assert rel.max() < 1e-2, f"near-duplicate distances corrupted: {rel.max()}"
    # neighbour order must match the float64 reference everywhere
    np.testing.assert_array_equal(np.argsort(got, axis=1, kind="stable"),
                                  np.argsort(want, axis=1, kind="stable"))


@settings(max_examples=25, deadline=None)
@given(
    p=st.sampled_from([8, 16, 32]),
    n=st.sampled_from([8, 16, 32]),
    e=st.integers(min_value=1, max_value=EMAX),
    seed=st.integers(min_value=0, max_value=2**16),
    scale=st.floats(min_value=0.01, max_value=100.0),
)
def test_hypothesis_matches_oracle(p, n, e, seed, scale):
    rng = np.random.default_rng(seed)
    pred = embed_cloud(rng, p, e) * np.float32(scale)
    lib = embed_cloud(rng, n, e) * np.float32(scale)
    got = np.asarray(distance.sq_distances(jnp.asarray(pred), jnp.asarray(lib), 8, 8))
    want = naive_sq_distances(pred, lib)
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4 * scale * scale)
    assert (got >= 0).all()
