"""L2 composed graph: oracle match, padding/masking contracts, and the
end-to-end scientific check that CCM recovers the causal direction on
Sugihara's coupled logistic maps."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import EMAX, KMAX, ref
from .helpers import coupled_logistic, embed_cloud, k_mask, lag_embed


def _args(rng, n_valid, n_bucket, e):
    lib = embed_cloud(rng, n_bucket, e)
    pred = lib + rng.normal(scale=0.01, size=lib.shape).astype(np.float32)
    pred[:, e:] = 0.0
    lv = np.zeros(n_bucket, np.float32); lv[:n_valid] = 1.0
    pv = lv.copy()
    lt = rng.normal(size=n_bucket).astype(np.float32)
    pt = rng.normal(size=n_bucket).astype(np.float32)
    idx = np.arange(n_bucket, dtype=np.float32)
    return [lib, pred, lv, lt, pt, pv, idx, idx, k_mask(e), np.float32(0.0)]


def test_matches_ref_oracle():
    rng = np.random.default_rng(0)
    args = [jnp.asarray(a) for a in _args(rng, 200, 256, 3)]
    r1, p1 = model.cross_map(*args)
    r2, p2 = ref.cross_map(*args)
    np.testing.assert_allclose(float(r1), float(r2), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(p1)[:200], np.asarray(p2)[:200],
                               rtol=1e-4, atol=1e-4)


def test_bucket_padding_invariance():
    """Same valid data in a bigger bucket must give the same rho — the
    contract that lets Rust pad any workload to the nearest artifact."""
    rng = np.random.default_rng(1)
    args_small = _args(rng, 200, 256, 3)
    # embed the same 200 valid rows into a 512 bucket
    args_big = []
    for a in args_small:
        if np.isscalar(a) or a.ndim == 0:
            args_big.append(a)
        elif a.ndim == 2:
            b = np.zeros((512, EMAX), np.float32); b[:256] = a; args_big.append(b)
        elif a.shape[0] == KMAX:
            args_big.append(a)
        else:
            b = np.zeros(512, np.float32); b[:256] = a; args_big.append(b)
    # padded idx rows must not collide with valid ones at theiler 0:
    args_big[6][256:] = np.arange(10_000, 10_256, dtype=np.float32)
    args_big[7][256:] = np.arange(20_000, 20_256, dtype=np.float32)
    r_small, p_small = model.cross_map(*[jnp.asarray(a) for a in args_small])
    r_big, p_big = model.cross_map(*[jnp.asarray(a) for a in args_big])
    np.testing.assert_allclose(float(r_small), float(r_big), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(p_small)[:200], np.asarray(p_big)[:200],
                               rtol=1e-4, atol=1e-4)


def test_theiler_zero_excludes_self():
    """With lib == pred and theiler = 0 the self point (distance 0) must not
    be its own neighbour: prediction != target even for exact overlap."""
    rng = np.random.default_rng(2)
    lib = embed_cloud(rng, 64, 2)
    lv = np.ones(64, np.float32)
    lt = rng.normal(size=64).astype(np.float32)
    idx = np.arange(64, dtype=np.float32)
    args = [lib, lib.copy(), lv, lt, lt.copy(), lv.copy(), idx, idx.copy(),
            k_mask(2), np.float32(0.0)]
    _, preds = model.cross_map(*[jnp.asarray(a) for a in args])
    # if self were included, d1=0 -> prediction == target exactly
    assert not np.allclose(np.asarray(preds), lt, atol=1e-6)


def test_theiler_negative_includes_self():
    """theiler = -1 disables exclusion: self distance 0 dominates and the
    prediction collapses onto the target."""
    rng = np.random.default_rng(3)
    lib = embed_cloud(rng, 64, 2)
    lv = np.ones(64, np.float32)
    lt = rng.normal(size=64).astype(np.float32)
    idx = np.arange(64, dtype=np.float32)
    args = [lib, lib.copy(), lv, lt, lt.copy(), lv.copy(), idx, idx.copy(),
            k_mask(2), np.float32(-1.0)]
    rho, preds = model.cross_map(*[jnp.asarray(a) for a in args])
    np.testing.assert_allclose(np.asarray(preds), lt, atol=1e-2)
    assert float(rho) > 0.99


def test_simplex_tail_matches_composition():
    """distance+topk in the oracle, then the simplex_tail graph, must equal
    the full cross_map graph — the table-mode equivalence the Rust
    coordinator relies on (paper §3.2)."""
    rng = np.random.default_rng(4)
    args = [jnp.asarray(a) for a in _args(rng, 256, 256, 4)]
    lib, pred, lv, lt, pt, pv, li, pi, km, th = args
    d = ref.sq_distances(pred, lib)
    d = ref.mask_distances(d, lv, li, pi, th)
    dv, tv = ref.topk_neighbors(d, lt)
    r_tail, p_tail = model.simplex_tail(dv, tv, pt, pv, km)
    r_full, p_full = model.cross_map(*args)
    np.testing.assert_allclose(float(r_tail), float(r_full), rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(p_tail), np.asarray(p_full),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    e=st.integers(min_value=1, max_value=6),
    seed=st.integers(min_value=0, max_value=2**16),
    n_valid=st.integers(min_value=40, max_value=256),
)
def test_hypothesis_graph_matches_oracle(e, seed, n_valid):
    rng = np.random.default_rng(seed)
    args = [jnp.asarray(a) for a in _args(rng, n_valid, 256, e)]
    r1, _ = model.cross_map(*args)
    r2, _ = ref.cross_map(*args)
    np.testing.assert_allclose(float(r1), float(r2), rtol=1e-3, atol=1e-4)


def _ccm_skill(source, target, e, tau, lib_len, rng):
    """Cross-map skill of predicting `source` from `target`'s manifold,
    using a random library of lib_len embedded points. Pure oracle."""
    vecs, idx = lag_embed(target, e, tau)
    n = len(vecs)
    sel = np.sort(rng.choice(n, size=lib_len, replace=False))
    lib = vecs[sel]
    src_aligned = source[idx.astype(int)]
    lt = src_aligned[sel]
    bucket = 256 if n <= 256 else 512 if n <= 512 else 1024
    def pad2(a):
        b = np.zeros((bucket, EMAX), np.float32); b[: a.shape[0]] = a; return b
    def pad1(a, fill=0.0):
        b = np.full(bucket, fill, np.float32); b[: a.shape[0]] = a; return b
    lv = pad1(np.ones(lib_len, np.float32))
    pv = pad1(np.ones(n, np.float32))
    li = pad1(idx[sel], fill=-1e9)
    pi = pad1(idx, fill=-2e9)
    rho, _ = ref.cross_map(
        jnp.asarray(pad2(lib)), jnp.asarray(pad2(vecs)), jnp.asarray(lv),
        jnp.asarray(pad1(lt)), jnp.asarray(pad1(src_aligned)), jnp.asarray(pv),
        jnp.asarray(li), jnp.asarray(pi), jnp.asarray(k_mask(e)),
        jnp.asarray(np.float32(0.0)),
    )
    return float(rho)


def test_ccm_recovers_causal_direction():
    """Sugihara's headline result on coupled logistic maps: X drives Y
    (beta_yx >> beta_xy), so cross-mapping X from M_Y is skillful and
    improves with library size (convergence)."""
    x, y = coupled_logistic(520, beta_xy=0.0, beta_yx=0.35)
    rng = np.random.default_rng(7)
    e, tau = 2, 1
    # X -> Y causality: predict X from Y's shadow manifold
    rho_small = np.mean([_ccm_skill(x, y, e, tau, 40, rng) for _ in range(5)])
    rho_big = np.mean([_ccm_skill(x, y, e, tau, 400, rng) for _ in range(5)])
    # Y does not drive X: predicting Y from X's manifold stays weak
    rho_rev = np.mean([_ccm_skill(y, x, e, tau, 400, rng) for _ in range(5)])
    assert rho_big > 0.9, f"cross-map skill should be high, got {rho_big}"
    assert rho_big > rho_small + 0.03, "skill must converge (grow with L)"
    assert rho_big > rho_rev + 0.1, (
        f"causal asymmetry lost: X->Y {rho_big} vs Y->X {rho_rev}")
