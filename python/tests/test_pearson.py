"""Pallas Pearson kernel vs numpy corrcoef + degenerate cases."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import pearson, ref


def test_matches_numpy_full_valid():
    rng = np.random.default_rng(0)
    x = rng.normal(size=128).astype(np.float32)
    y = (0.8 * x + 0.2 * rng.normal(size=128)).astype(np.float32)
    v = np.ones(128, np.float32)
    got = float(pearson.pearson(jnp.asarray(x), jnp.asarray(y), jnp.asarray(v)))
    want = np.corrcoef(x, y)[0, 1]
    np.testing.assert_allclose(got, want, rtol=1e-4)


def test_masked_rows_ignored():
    rng = np.random.default_rng(1)
    x = rng.normal(size=64).astype(np.float32)
    y = rng.normal(size=64).astype(np.float32)
    v = np.ones(64, np.float32)
    v[40:] = 0.0
    # poison the masked tail; result must not change
    x2 = x.copy(); x2[40:] = 1e6
    y2 = y.copy(); y2[40:] = -1e6
    a = float(pearson.pearson(jnp.asarray(x), jnp.asarray(y), jnp.asarray(v)))
    b = float(pearson.pearson(jnp.asarray(x2), jnp.asarray(y2), jnp.asarray(v)))
    want = np.corrcoef(x[:40], y[:40])[0, 1]
    np.testing.assert_allclose(a, want, rtol=1e-4)
    np.testing.assert_allclose(b, want, rtol=1e-4)


def test_perfect_correlation():
    x = np.linspace(-1, 1, 32, dtype=np.float32)
    v = np.ones(32, np.float32)
    got = float(pearson.pearson(jnp.asarray(x), jnp.asarray(2 * x + 3), jnp.asarray(v)))
    np.testing.assert_allclose(got, 1.0, atol=1e-5)
    got = float(pearson.pearson(jnp.asarray(x), jnp.asarray(-x), jnp.asarray(v)))
    np.testing.assert_allclose(got, -1.0, atol=1e-5)


def test_degenerate_variance_returns_zero():
    x = np.ones(16, np.float32)
    y = np.arange(16, dtype=np.float32)
    v = np.ones(16, np.float32)
    assert float(pearson.pearson(jnp.asarray(x), jnp.asarray(y), jnp.asarray(v))) == 0.0


@settings(max_examples=25, deadline=None)
@given(
    n=st.sampled_from([8, 32, 100]),
    seed=st.integers(min_value=0, max_value=2**16),
    nvalid=st.integers(min_value=3, max_value=8),
)
def test_hypothesis_matches_ref_and_numpy(n, seed, nvalid):
    rng = np.random.default_rng(seed)
    x = rng.normal(size=n).astype(np.float32)
    y = rng.normal(size=n).astype(np.float32)
    v = np.zeros(n, np.float32)
    keep = rng.choice(n, size=min(nvalid, n), replace=False)
    v[keep] = 1.0
    got = float(pearson.pearson(jnp.asarray(x), jnp.asarray(y), jnp.asarray(v)))
    want_ref = float(ref.pearson(jnp.asarray(x), jnp.asarray(y), jnp.asarray(v)))
    np.testing.assert_allclose(got, want_ref, rtol=1e-4, atol=1e-5)
    sel = v > 0
    if sel.sum() >= 2 and np.std(x[sel]) > 1e-6 and np.std(y[sel]) > 1e-6:
        want = np.corrcoef(x[sel], y[sel])[0, 1]
        np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-4)
