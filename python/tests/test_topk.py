"""Pallas top-k kernel vs oracle and vs numpy argsort semantics."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import BIG, KMAX, ref, topk


def numpy_topk(d, targets):
    """Stable ascending sort -> first KMAX (ties by lowest index)."""
    order = np.argsort(d, axis=1, kind="stable")[:, :KMAX]
    dv = np.take_along_axis(d, order, axis=1)
    tv = targets[order]
    return dv, tv


def test_matches_numpy_sort():
    rng = np.random.default_rng(0)
    d = rng.uniform(size=(32, 64)).astype(np.float32)
    t = rng.normal(size=64).astype(np.float32)
    dv, tv = topk.topk_neighbors(jnp.asarray(d), jnp.asarray(t), 16)
    want_dv, want_tv = numpy_topk(d, t)
    np.testing.assert_allclose(np.asarray(dv), want_dv, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(tv), want_tv, rtol=1e-6)


def test_matches_ref_oracle():
    rng = np.random.default_rng(1)
    d = rng.uniform(size=(16, 48)).astype(np.float32)
    t = rng.normal(size=48).astype(np.float32)
    dv, tv = topk.topk_neighbors(jnp.asarray(d), jnp.asarray(t), 16)
    rdv, rtv = ref.topk_neighbors(jnp.asarray(d), jnp.asarray(t))
    np.testing.assert_allclose(np.asarray(dv), np.asarray(rdv), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(tv), np.asarray(rtv), rtol=1e-6)


def test_ascending_order():
    rng = np.random.default_rng(2)
    d = rng.uniform(size=(8, 32)).astype(np.float32)
    t = rng.normal(size=32).astype(np.float32)
    dv, _ = topk.topk_neighbors(jnp.asarray(d), jnp.asarray(t), 8)
    dv = np.asarray(dv)
    assert (np.diff(dv, axis=1) >= 0).all()


def test_masked_entries_sort_last():
    """Entries masked with +BIG (invalid/self rows) must never displace
    genuine neighbours."""
    rng = np.random.default_rng(3)
    d = rng.uniform(size=(8, 32)).astype(np.float32)
    d[:, 20:] += np.float32(BIG)
    t = rng.normal(size=32).astype(np.float32)
    dv, tv = topk.topk_neighbors(jnp.asarray(d), jnp.asarray(t), 8)
    dv = np.asarray(dv)
    # 20 real entries; first 11 < BIG
    assert (dv[:, :KMAX] < BIG / 2).all()
    want_dv, want_tv = numpy_topk(d, t)
    np.testing.assert_allclose(dv, want_dv, rtol=1e-6)


def test_tie_breaking_lowest_index():
    d = np.full((2, 16), 5.0, np.float32)
    d[0, 7] = 1.0
    t = np.arange(16, dtype=np.float32)
    dv, tv = topk.topk_neighbors(jnp.asarray(d), jnp.asarray(t), 2)
    tv = np.asarray(tv)
    # row 0: nearest is idx 7, then ties resolved 0,1,2,...
    assert tv[0, 0] == 7.0
    np.testing.assert_array_equal(tv[0, 1:6], [0, 1, 2, 3, 4])
    # row 1: all ties -> 0..10
    np.testing.assert_array_equal(tv[1], np.arange(KMAX, dtype=np.float32))


@settings(max_examples=25, deadline=None)
@given(
    p=st.sampled_from([8, 16]),
    n=st.sampled_from([16, 32, 64]),
    seed=st.integers(min_value=0, max_value=2**16),
)
def test_hypothesis_matches_numpy(p, n, seed):
    rng = np.random.default_rng(seed)
    d = rng.uniform(size=(p, n)).astype(np.float32)
    t = rng.normal(size=n).astype(np.float32)
    dv, tv = topk.topk_neighbors(jnp.asarray(d), jnp.asarray(t), p)
    want_dv, want_tv = numpy_topk(d, t)
    np.testing.assert_allclose(np.asarray(dv), want_dv, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(tv), want_tv, rtol=1e-6)
