"""Pallas simplex-projection kernel: oracle match + weighting invariants."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import KMAX, ref, simplex
from .helpers import k_mask


def test_matches_ref():
    rng = np.random.default_rng(0)
    dv = np.sort(rng.uniform(size=(32, KMAX)).astype(np.float32), axis=1)
    tv = rng.normal(size=(32, KMAX)).astype(np.float32)
    km = k_mask(3)
    got = np.asarray(simplex.simplex_predict(jnp.asarray(dv), jnp.asarray(tv), jnp.asarray(km), 16))
    want = np.asarray(ref.simplex_predict(jnp.asarray(dv), jnp.asarray(tv), jnp.asarray(km)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_prediction_is_convex_combination():
    """Weights are positive and normalized -> prediction lies within the
    [min, max] of the unmasked neighbour targets."""
    rng = np.random.default_rng(1)
    dv = np.sort(rng.uniform(0.1, 2.0, size=(64, KMAX)).astype(np.float32), axis=1)
    tv = rng.normal(size=(64, KMAX)).astype(np.float32)
    for e in [1, 3, 6]:
        km = k_mask(e)
        pred = np.asarray(simplex.simplex_predict(jnp.asarray(dv), jnp.asarray(tv), jnp.asarray(km), 64))
        lo = tv[:, : e + 1].min(axis=1)
        hi = tv[:, : e + 1].max(axis=1)
        assert (pred >= lo - 1e-5).all() and (pred <= hi + 1e-5).all()


def test_exact_match_dominates():
    """d_1 == 0 (exact manifold revisit): nearest neighbour carries weight 1
    while others floor at 1e-6, so the prediction ~= its target."""
    dv = np.zeros((4, KMAX), np.float32)
    dv[:, 1:] = np.linspace(1.0, 2.0, KMAX - 1, dtype=np.float32)
    tv = np.full((4, KMAX), 100.0, np.float32)
    tv[:, 0] = 7.0
    km = k_mask(4)
    pred = np.asarray(simplex.simplex_predict(jnp.asarray(dv), jnp.asarray(tv), jnp.asarray(km), 4))
    np.testing.assert_allclose(pred, np.full(4, 7.0), atol=1e-2)


def test_equidistant_neighbours_average():
    dv = np.ones((2, KMAX), np.float32)
    tv = np.stack([np.arange(KMAX, dtype=np.float32)] * 2)
    km = k_mask(3)  # first 4 neighbours: targets 0,1,2,3
    pred = np.asarray(simplex.simplex_predict(jnp.asarray(dv), jnp.asarray(tv), jnp.asarray(km), 2))
    np.testing.assert_allclose(pred, np.full(2, 1.5), rtol=1e-6)


@settings(max_examples=25, deadline=None)
@given(
    e=st.integers(min_value=1, max_value=KMAX - 1),
    seed=st.integers(min_value=0, max_value=2**16),
    scale=st.floats(min_value=0.01, max_value=10.0),
)
def test_hypothesis_matches_ref(e, seed, scale):
    rng = np.random.default_rng(seed)
    dv = np.sort((rng.uniform(size=(16, KMAX)) * scale).astype(np.float32), axis=1)
    tv = rng.normal(size=(16, KMAX)).astype(np.float32)
    km = k_mask(e)
    got = np.asarray(simplex.simplex_predict(jnp.asarray(dv), jnp.asarray(tv), jnp.asarray(km), 16))
    want = np.asarray(ref.simplex_predict(jnp.asarray(dv), jnp.asarray(tv), jnp.asarray(km)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)
