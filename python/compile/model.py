"""L2: the CCM compute graphs, built from the L1 Pallas kernels.

Three graph families are AOT-lowered (see aot.py):

* ``cross_map_fn(n, p)``   — the full per-subsample cross-map: distances ->
  masking -> top-KMAX -> simplex -> Pearson. Used by the brute-force CCM
  transform pipeline (paper §3.1). One call per (subsample, L, E, tau).
* ``distance_fn(p, n)``    — raw pairwise squared distances, used by the
  distance-indexing-table pipeline (paper §3.2) to build the broadcast
  table over the *whole* embedded series once per (E, tau).
* ``simplex_fn(p)``        — the table-mode tail: neighbours were already
  found by table lookup in Rust; this evaluates simplex weights + Pearson
  on the gathered [P, KMAX] neighbour panels.

Shape policy (DESIGN.md §Artifact shape policy): embedding dim is padded
to EMAX with zeros, point counts to the bucket size with ``*_valid`` masks,
neighbour count is fixed at KMAX and restricted by ``k_mask``.
"""

import jax.numpy as jnp

from .kernels import BIG, KMAX
from .kernels import distance as kdistance
from .kernels import pearson as kpearson
from .kernels import simplex as ksimplex
from .kernels import topk as ktopk


def mask_distances(d, lib_valid, lib_idx, pred_idx, theiler):
    """Validity + Theiler-window masking (cheap elementwise, fused by XLA)."""
    d = d + BIG * (1.0 - lib_valid)[None, :]
    close = (jnp.abs(pred_idx[:, None] - lib_idx[None, :]) <= theiler).astype(d.dtype)
    return d + BIG * close


def cross_map(lib, pred, lib_valid, lib_targets, pred_targets, pred_valid,
              lib_idx, pred_idx, k_mask, theiler):
    """Full cross-map skill for one subsample. Returns (rho, preds [P])."""
    d = kdistance.sq_distances(pred, lib)
    d = mask_distances(d, lib_valid, lib_idx, pred_idx, theiler)
    dvals, tvals = ktopk.topk_neighbors(d, lib_targets)
    preds = ksimplex.simplex_predict(dvals, tvals, k_mask)
    rho = kpearson.pearson(preds, pred_targets, pred_valid)
    return rho, preds


def simplex_tail(dvals, tvals, pred_targets, pred_valid, k_mask):
    """Table-mode tail: simplex + Pearson over pre-gathered neighbours."""
    preds = ksimplex.simplex_predict(dvals, tvals, k_mask)
    rho = kpearson.pearson(preds, pred_targets, pred_valid)
    return rho, preds


def distances(pred, lib):
    """Raw squared-distance matrix (table construction)."""
    return kdistance.sq_distances(pred, lib)
