"""AOT: lower the L2 graphs to HLO *text* + write artifacts/manifest.json.

HLO text (NOT ``lowered.compile()`` / serialized HloModuleProto) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which xla_extension 0.5.1 (the version the published ``xla`` crate binds)
rejects; the HLO text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage:  cd python && python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import BIG, EMAX, KMAX

# Point-count buckets. Rust pads any (E, tau, L) workload up to the nearest
# bucket; masks keep padding out of the numerics. The set is small to bound
# PJRT compile time at coordinator startup.
#
# Cross-map buckets are RECTANGULAR (n = library rows, p = prediction rows):
# CCM libraries (L) are typically much smaller than the prediction set (the
# whole manifold), and a square bucket would pad the library to the manifold
# size — 8x wasted distance work at the paper's L=500/n=4000 cell. See
# EXPERIMENTS.md §Perf.
CCM_BUCKETS = [
    (256, 256),
    (512, 512),
    (256, 1024), (512, 1024), (1024, 1024),
    (512, 2048), (1024, 2048), (2048, 2048),
    (512, 4096), (1024, 4096), (2048, 4096), (4096, 4096),
]
DIST_BUCKETS = [256, 512, 1024, 2048, 4096]
SIMPLEX_BUCKETS = [256, 512, 1024, 2048, 4096]


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True: the Rust
    side unwraps with ``to_tuple``)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(*shape):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.float32)


def lower_cross_map(n, p):
    """cross_map graph for a library bucket of n points, p prediction points.

    Input order (the Rust manifest relies on this exact order):
      0 lib[n,EMAX] 1 pred[p,EMAX] 2 lib_valid[n] 3 lib_targets[n]
      4 pred_targets[p] 5 pred_valid[p] 6 lib_idx[n] 7 pred_idx[p]
      8 k_mask[KMAX] 9 theiler[]            ->  (rho[], preds[p])
    """
    return jax.jit(model.cross_map).lower(
        _spec(n, EMAX), _spec(p, EMAX), _spec(n), _spec(n),
        _spec(p), _spec(p), _spec(n), _spec(p), _spec(KMAX), _spec(),
    )


def lower_simplex(p):
    """simplex_tail graph. Input order:
      0 dvals[p,KMAX] 1 tvals[p,KMAX] 2 pred_targets[p] 3 pred_valid[p]
      4 k_mask[KMAX]                         ->  (rho[], preds[p])
    """
    return jax.jit(model.simplex_tail).lower(
        _spec(p, KMAX), _spec(p, KMAX), _spec(p), _spec(p), _spec(KMAX),
    )


def lower_distances(p, n):
    """distance graph. Inputs: 0 pred[p,EMAX] 1 lib[n,EMAX] -> (d[p,n],)."""
    return jax.jit(model.distances).lower(_spec(p, EMAX), _spec(n, EMAX))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--quick", action="store_true",
                    help="only the 256 bucket (fast CI of the AOT path)")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    ccm_buckets = [(256, 256)] if args.quick else CCM_BUCKETS
    dist_buckets = [256] if args.quick else DIST_BUCKETS
    simplex_buckets = [256] if args.quick else SIMPLEX_BUCKETS

    artifacts = []

    def emit(name, lowered, kind, **meta):
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        artifacts.append({"name": name, "kind": kind, "file": fname, **meta})
        print(f"  wrote {fname}  ({len(text)} chars)")

    for (n, p) in ccm_buckets:
        emit(f"ccm_n{n}_p{p}", lower_cross_map(n, p), "cross_map", n=n, p=p)
    for n in dist_buckets:
        emit(f"dist_n{n}", lower_distances(n, n), "distance", n=n, p=n)
    for p in simplex_buckets:
        emit(f"simplex_n{p}", lower_simplex(p), "simplex", n=p, p=p)

    manifest = {
        "emax": EMAX,
        "kmax": KMAX,
        "big": BIG,
        "artifacts": artifacts,
    }
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"manifest: {len(artifacts)} artifacts -> {args.out}/manifest.json")


if __name__ == "__main__":
    main()
