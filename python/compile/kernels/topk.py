"""Pallas kernel: top-k nearest neighbours by k-pass min extraction.

A GPU implementation would sort each row (the paper notes "computes the
distances ..., sorts them and finally takes the top E+1"). Sorting is a
poor fit for the TPU vector unit; since k = E+1 <= KMAX = 11, a k-pass
running-min extraction is O(k*N) pure vector work with no data-dependent
control flow: per pass, argmin the row, record (distance, gathered target)
via a one-hot contraction, then knock the winner out with +BIG.

The kernel emits both the neighbour distances and the library *target
values* gathered at the neighbour positions, so the downstream simplex
stage never needs a gather.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import BIG, KMAX


def _topk_kernel(d_ref, t_ref, dv_ref, tv_ref):
    d = d_ref[...]                        # [bp, N]
    t = t_ref[...]                        # [1, N]
    n = d.shape[1]
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, n), 1)
    for k in range(KMAX):                 # static unroll, KMAX passes
        am = jnp.argmin(d, axis=1)        # ties -> lowest index
        onehot = (iota == am[:, None]).astype(d.dtype)
        dv_ref[:, k] = jnp.min(d, axis=1)
        tv_ref[:, k] = jnp.sum(onehot * t, axis=1)
        d = d + onehot * BIG


def topk_neighbors(d, lib_targets, block_p=128):
    """[P, N] distances + [N] targets -> (dvals [P, KMAX], tvals [P, KMAX]).

    Rows of the output are in ascending distance order. Masked entries
    (+BIG and above) sort last; the caller's k_mask keeps them out of the
    simplex weights.
    """
    p, n = d.shape
    bp = min(block_p, p)
    assert p % bp == 0
    t2 = lib_targets.reshape(1, n)
    return pl.pallas_call(
        _topk_kernel,
        grid=(p // bp,),
        in_specs=[
            pl.BlockSpec((bp, n), lambda i: (i, 0)),
            pl.BlockSpec((1, n), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bp, KMAX), lambda i: (i, 0)),
            pl.BlockSpec((bp, KMAX), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((p, KMAX), jnp.float32),
            jax.ShapeDtypeStruct((p, KMAX), jnp.float32),
        ],
        interpret=True,
    )(d, t2)
