"""Pure-jnp oracle for every Pallas kernel and for the composed cross-map.

This module is the correctness contract: pytest checks each Pallas kernel
against the function of the same name here, and the Rust native backend is
cross-checked against the AOT artifacts produced from the Pallas path.
No pallas imports here — plain jax.numpy only.
"""

import jax.numpy as jnp

from . import BIG, KMAX


def sq_distances(pred, lib):
    """Squared euclidean distances, [P, E] x [N, E] -> [P, N].

    Direct difference form (sum over lanes of (a-b)^2), matching the Pallas
    kernel and the Rust native backend exactly — see distance.py for why
    the matmul expansion is *not* used (cancellation perturbs neighbour
    order for near pairs).
    """
    diff = pred[:, None, :] - lib[None, :, :]
    return jnp.sum(diff * diff, axis=-1)


def mask_distances(d, lib_valid, lib_idx, pred_idx, theiler):
    """Apply validity + Theiler-window exclusion masks to a distance matrix.

    * rows of ``lib`` with ``lib_valid == 0`` (bucket padding) are pushed to
      +BIG so they are never selected as neighbours;
    * library points within ``theiler`` time steps of the prediction point
      are excluded — ``theiler == 0`` excludes exactly the self-match, the
      standard CCM leave-one-out.
    """
    d = d + BIG * (1.0 - lib_valid)[None, :]
    close = (jnp.abs(pred_idx[:, None] - lib_idx[None, :]) <= theiler).astype(d.dtype)
    return d + BIG * close


def topk_neighbors(d, lib_targets, k=KMAX):
    """k smallest entries per row of ``d`` plus the library targets gathered
    at those positions. Returns (dvals [P,k], tvals [P,k]) in ascending
    distance order. Ties broken by lowest index (matches the kernel's
    argmin semantics)."""
    dvals = []
    tvals = []
    work = d
    n = d.shape[1]
    iota = jnp.arange(n)
    for _ in range(k):
        am = jnp.argmin(work, axis=1)
        m = jnp.take_along_axis(work, am[:, None], axis=1)[:, 0]
        dvals.append(m)
        tvals.append(lib_targets[am])
        onehot = (iota[None, :] == am[:, None]).astype(work.dtype)
        work = work + onehot * BIG
    return jnp.stack(dvals, axis=1), jnp.stack(tvals, axis=1)


def simplex_predict(dvals, tvals, k_mask):
    """Simplex-projection prediction from k nearest neighbours.

    Weights follow Sugihara simplex / rEDM: w_j = exp(-d_j / d_1) over
    *euclidean* (not squared) distances, floored at 1e-6, restricted to the
    first E+1 neighbours by ``k_mask``.
    """
    d = jnp.sqrt(jnp.maximum(dvals, 0.0))
    d1 = jnp.maximum(d[:, 0:1], 1e-30)
    w = jnp.exp(-d / d1)
    w = jnp.maximum(w, 1e-6) * k_mask[None, :]
    return jnp.sum(w * tvals, axis=1) / jnp.sum(w, axis=1)


def pearson(x, y, valid):
    """Masked Pearson correlation between x and y over rows where
    ``valid == 1``. Returns a scalar; 0 when degenerate (zero variance)."""
    n = jnp.maximum(jnp.sum(valid), 1.0)
    mx = jnp.sum(x * valid) / n
    my = jnp.sum(y * valid) / n
    dx = (x - mx) * valid
    dy = (y - my) * valid
    cov = jnp.sum(dx * dy)
    vx = jnp.sum(dx * dx)
    vy = jnp.sum(dy * dy)
    denom = jnp.sqrt(vx * vy)
    return jnp.where(denom > 0.0, cov / denom, 0.0)


def cross_map(lib, pred, lib_valid, lib_targets, pred_targets, pred_valid,
              lib_idx, pred_idx, k_mask, theiler):
    """Composed reference cross-map skill: the oracle for the full L2 graph.

    Returns (rho, preds): Pearson skill of predicting ``pred_targets`` from
    the library manifold, and the per-point simplex predictions.
    """
    d = sq_distances(pred, lib)
    d = mask_distances(d, lib_valid, lib_idx, pred_idx, theiler)
    dvals, tvals = topk_neighbors(d, lib_targets)
    preds = simplex_predict(dvals, tvals, k_mask)
    rho = pearson(preds, pred_targets, pred_valid)
    return rho, preds
