"""Pallas kernel: masked Pearson correlation (prediction skill).

Single-block reduction: the whole [1, P] vectors live in VMEM (P <= 4096
-> 16 KiB each). Computes the five masked moments and the correlation in
one pass; degenerate (zero-variance) inputs return 0 like rEDM.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pearson_kernel(x_ref, y_ref, v_ref, o_ref):
    x = x_ref[...]                        # [1, P]
    y = y_ref[...]
    v = v_ref[...]
    n = jnp.maximum(jnp.sum(v), 1.0)
    mx = jnp.sum(x * v) / n
    my = jnp.sum(y * v) / n
    dx = (x - mx) * v
    dy = (y - my) * v
    cov = jnp.sum(dx * dy)
    vx = jnp.sum(dx * dx)
    vy = jnp.sum(dy * dy)
    denom = jnp.sqrt(vx * vy)
    o_ref[0, 0] = jnp.where(denom > 0.0, cov / denom, 0.0)


def pearson(x, y, valid):
    """Masked Pearson correlation of two [P] vectors -> scalar."""
    p = x.shape[0]
    out = pl.pallas_call(
        _pearson_kernel,
        in_specs=[
            pl.BlockSpec((1, p), lambda: (0, 0)),
            pl.BlockSpec((1, p), lambda: (0, 0)),
            pl.BlockSpec((1, p), lambda: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=True,
    )(x.reshape(1, p), y.reshape(1, p), valid.reshape(1, p))
    return out[0, 0]
