"""Pallas kernel: simplex-projection weights and prediction.

Implements the Sugihara/rEDM weighting: w_j = exp(-d_j / d_1) over
euclidean distances (inputs are *squared* distances, sqrt happens here),
floored at 1e-6 and restricted to the first E+1 neighbours by ``k_mask``.
Purely elementwise + tiny row reductions — one VMEM-resident block per
grid step.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import KMAX


def _simplex_kernel(dv_ref, tv_ref, km_ref, o_ref):
    d = jnp.sqrt(jnp.maximum(dv_ref[...], 0.0))   # [bp, KMAX]
    d1 = jnp.maximum(d[:, 0:1], 1e-30)
    w = jnp.exp(-d / d1)
    w = jnp.maximum(w, 1e-6) * km_ref[...]        # [1, KMAX] mask broadcast
    num = jnp.sum(w * tv_ref[...], axis=1)
    den = jnp.sum(w, axis=1)
    o_ref[...] = (num / den)[:, None]


def simplex_predict(dvals, tvals, k_mask, block_p=256):
    """(dvals, tvals) [P, KMAX] + k_mask [KMAX] -> predictions [P]."""
    p, k = dvals.shape
    assert k == KMAX
    bp = min(block_p, p)
    assert p % bp == 0
    km2 = k_mask.reshape(1, KMAX)
    out = pl.pallas_call(
        _simplex_kernel,
        grid=(p // bp,),
        in_specs=[
            pl.BlockSpec((bp, KMAX), lambda i: (i, 0)),
            pl.BlockSpec((bp, KMAX), lambda i: (i, 0)),
            pl.BlockSpec((1, KMAX), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bp, 1), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((p, 1), jnp.float32),
        interpret=True,
    )(dvals, tvals, km2)
    return out[:, 0]
