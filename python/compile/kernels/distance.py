"""Pallas kernel: blocked pairwise squared-distance matrix.

The paper's hot spot is "compute the distances from every prediction point
to all lagged-coordinate vectors". BlockSpec expresses the HBM->VMEM
schedule: each grid step owns a (bp x bn) output tile and streams the two
operand slabs.

Form choice (numerics over MXU): the classic accelerator trick is the
matmul expansion ||a-b||^2 = ||a||^2 + ||b||^2 - 2 a.b, which maps on the
MXU systolic array — but it catastrophically cancels for *near* neighbours
(exactly the ones CCM ranks), perturbing neighbour order versus an exact
evaluation. At CCM's EMAX = 8 the direct form sum_l (a_l - b_l)^2 costs the
same 2*P*N*EMAX FLOPs as the contraction, runs on the VPU with an
unrolled 8-lane accumulation, and keeps neighbour ordering bit-stable with
the Rust native backend. DESIGN.md §Hardware-Adaptation discusses the
trade-off (for EMAX >> 8 one would tile the expansion with f32 compensated
accumulation instead).

VMEM budget per block (f32): bp*EMAX + bn*EMAX + bp*bn floats;
at bp = bn = 128, EMAX = 8 that is ~70 KiB — far under the ~16 MiB VMEM of
a TPU core, leaving room for double buffering (see DESIGN.md §Perf).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import EMAX


def _dist_kernel(x_ref, y_ref, o_ref):
    x = x_ref[...]                       # [bp, EMAX]
    y = y_ref[...]                       # [bn, EMAX]
    bp, bn = x.shape[0], y.shape[0]
    acc = jnp.zeros((bp, bn), jnp.float32)
    for l in range(EMAX):                # static unroll, 8 lanes
        diff = x[:, l][:, None] - y[:, l][None, :]
        acc = acc + diff * diff
    o_ref[...] = acc


def sq_distances(pred, lib, block_p=128, block_n=128):
    """[P, EMAX] x [N, EMAX] -> squared distances [P, N].

    P and N must be multiples of the block sizes (the AOT buckets are);
    callers with smaller test shapes pass smaller blocks.
    """
    p, e = pred.shape
    n, e2 = lib.shape
    assert e == EMAX and e2 == EMAX, f"embedding dim must be padded to {EMAX}"
    bp = min(block_p, p)
    bn = min(block_n, n)
    assert p % bp == 0 and n % bn == 0, (p, n, bp, bn)
    return pl.pallas_call(
        _dist_kernel,
        grid=(p // bp, n // bn),
        in_specs=[
            pl.BlockSpec((bp, EMAX), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, EMAX), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bp, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((p, n), jnp.float32),
        interpret=True,
    )(pred, lib)
