"""L1 Pallas kernels for the CCM hot path.

All kernels are written TPU-shaped (MXU-friendly matmul distance expansion,
VMEM-sized blocks expressed via BlockSpec) but lowered with interpret=True so
the resulting HLO runs on any PJRT backend, including the Rust CPU client.

Conventions shared by every kernel and by the Rust runtime:

* ``EMAX = 8``   — embedding vectors are zero-padded to 8 lanes. Padding both
  operands with zeros leaves squared distances exactly unchanged.
* ``KMAX = 11``  — top-k always extracts 11 neighbours (E+1 <= 11 for
  E <= 10); the simplex stage applies a ``k_mask`` so one artifact serves
  every embedding dimension.
* ``BIG = 1e30`` — additive mask for invalid / excluded library rows.
"""

EMAX = 8
KMAX = 11
BIG = 1e30
