"""Build-time compile package: L2 jax graphs + L1 pallas kernels + AOT.

Nothing in here runs at serving/coordination time — ``make artifacts``
lowers the graphs to HLO text once, and the Rust binary is self-contained
afterwards.
"""
