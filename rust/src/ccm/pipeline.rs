//! The paper's two parallel pipelines, expressed on the engine.
//!
//! * [`ccm_transform_rdd`] — §3.1: transform an RDD of library subsamples
//!   into an RDD of prediction skills (brute-force k-NN inside each task).
//! * [`table_pipeline`] / [`table_transform_rdd`] — §3.2: build the
//!   distance indexing table in parallel over manifold-row chunks,
//!   broadcast it, then run the CCM transform as cheap table lookups.
//!
//! Both return *lazy* RDDs; the driver chooses blocking (`collect`) or
//! asynchronous (`collect_async`) submission — §3.3.
//!
//! # Zero-copy task data path
//!
//! The [`CcmProblem`] (manifold + aligned targets + time column) is
//! broadcast once and shared behind an `Arc`; a task's
//! [`CrossMapInput`] is a borrowed view of it plus the sample's library
//! row indices — task assembly copies nothing O(n). Each partition
//! closure owns one [`TaskArena`] reused across its samples, so the only
//! per-sample work besides the kernels is the inherent O(L) library
//! gather (brute-force mode) or the O(n/64) mask refill (table mode).

use std::sync::Arc;

use crate::ccm::backend::{ComputeBackend, CrossMapInput, TaskArena};
use crate::ccm::embedding::Embedding;
use crate::ccm::result::SkillRow;
use crate::ccm::subsample::LibrarySample;
use crate::ccm::table::DistanceTable;
use crate::engine::{Broadcast, Context, Rdd};

/// The cross-mapping problem shared by every task: the effect-series
/// shadow manifold and the cause-series targets aligned to it. Broadcast
/// once per `(E, tau)`; tasks borrow it — they never copy it.
pub struct CcmProblem {
    pub emb: Embedding,
    /// Cause value at each manifold row's time.
    pub targets: Vec<f32>,
    /// Original-series time of each manifold row, as f32 (precomputed once
    /// so task views can borrow it instead of re-deriving O(n) per task).
    pub times: Vec<f32>,
    /// Theiler exclusion radius (0 = self only).
    pub theiler: f32,
}

impl CcmProblem {
    pub fn new(effect: &[f32], cause: &[f32], e: usize, tau: usize, theiler: f32) -> CcmProblem {
        let emb = Embedding::new(effect, e, tau);
        let targets = emb.align_targets(cause);
        let times = (0..emb.n).map(|i| emb.time_of(i) as f32).collect();
        CcmProblem { emb, targets, times, theiler }
    }

    pub fn size_bytes(&self) -> usize {
        self.emb.size_bytes() + self.targets.len() * 4 + self.times.len() * 4
    }

    /// Assemble the zero-copy [`CrossMapInput`] view for one library
    /// sample: three borrowed slices + the sample's row indices. O(1) —
    /// no O(n) prediction-side copies, no O(L) library materialization.
    pub fn input_for<'a>(&'a self, sample: &'a LibrarySample) -> CrossMapInput<'a> {
        CrossMapInput {
            vecs: &self.emb.vecs,
            targets: &self.targets,
            times: &self.times,
            lib_rows: &sample.rows,
            e: sample.params.e,
            theiler: self.theiler,
        }
    }
}

/// How the distance indexing table is stored and broadcast.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TableMode {
    /// All `n - 1` sorted neighbours per row (the paper's layout).
    Full,
    /// Top-`prefix` neighbours per row — `O(n * P)` broadcast bytes with
    /// an exact counted fallback for queries that exhaust the prefix (see
    /// [`crate::ccm::table`] module docs). Size `prefix` with
    /// [`DistanceTable::auto_prefix`].
    Truncated { prefix: usize },
}

/// §3.1 — the CCM transform pipeline: subsamples -> prediction skills via
/// brute-force k-NN + simplex inside each task.
pub fn ccm_transform_rdd(
    _ctx: &Context,
    samples: Rdd<LibrarySample>,
    problem: &Broadcast<CcmProblem>,
    backend: Arc<dyn ComputeBackend>,
) -> Rdd<SkillRow> {
    let problem = problem.clone();
    samples
        .uses_broadcast(&problem)
        .map_partitions(move |_p, samples| {
            let prob = problem.value();
            let mut arena = TaskArena::new();
            samples
                .into_iter()
                .map(|s| {
                    let rho = backend.cross_map_into(&prob.input_for(&s), &mut arena);
                    SkillRow { params: s.params, sample_id: s.sample_id, rho }
                })
                .collect()
        })
}

/// §3.2 (construction) — build the distance indexing table in parallel:
/// one task per chunk of manifold rows, each computing its rows' sorted
/// neighbour lists (truncated at source in [`TableMode::Truncated`], which
/// also shrinks the collect); the driver assembles and broadcasts.
///
/// Blocking (the table is a hard dependency of its transform jobs); the
/// asynchronous driver overlaps *different* (E, tau) tables instead.
pub fn table_pipeline_mode(
    ctx: &Context,
    problem: &Broadcast<CcmProblem>,
    partitions: usize,
    mode: TableMode,
) -> Broadcast<DistanceTable> {
    let n = problem.value().emb.n;
    let row_len = match mode {
        TableMode::Full => n.saturating_sub(1),
        TableMode::Truncated { prefix } => prefix.min(n.saturating_sub(1)),
    };
    let rows_rdd = ctx.parallelize_with((0..n).collect::<Vec<usize>>(), partitions);
    let prob = problem.clone();
    let sorted = rows_rdd.uses_broadcast(&prob).map_partitions(move |_p, rows| {
        let emb = &prob.value().emb;
        rows.into_iter()
            .map(|i| (i, DistanceTable::sorted_row_prefix(emb, i, row_len)))
            .collect()
    });
    let mut rows: Vec<(usize, Vec<u32>)> = ctx.collect(&sorted);
    rows.sort_by_key(|(i, _)| *i);
    let table = DistanceTable::assemble_with(
        &problem.value().emb,
        rows.into_iter().map(|(_, r)| r).collect(),
        row_len,
    );
    let size = table.size_bytes();
    ctx.broadcast(table, size)
}

/// [`table_pipeline_mode`] with the paper's full layout.
pub fn table_pipeline(
    ctx: &Context,
    problem: &Broadcast<CcmProblem>,
    partitions: usize,
) -> Broadcast<DistanceTable> {
    table_pipeline_mode(ctx, problem, partitions, TableMode::Full)
}

/// §3.2 (use) — the CCM transform pipeline with the broadcast table:
/// k-NN becomes a filtered walk of the precomputed sorted lists, then the
/// simplex/Pearson tail runs on the backend. Mask, panels, and prediction
/// buffers all live in the partition's [`TaskArena`].
pub fn table_transform_rdd(
    _ctx: &Context,
    samples: Rdd<LibrarySample>,
    problem: &Broadcast<CcmProblem>,
    table: &Broadcast<DistanceTable>,
    backend: Arc<dyn ComputeBackend>,
) -> Rdd<SkillRow> {
    let problem = problem.clone();
    let table = table.clone();
    samples
        .uses_broadcast(&problem)
        .uses_broadcast(&table)
        .map_partitions(move |_p, samples| {
            let prob = problem.value();
            let tab = table.value();
            let mut arena = TaskArena::new();
            samples
                .into_iter()
                .map(|s| {
                    arena.mask.set_from(tab.n, &s.rows);
                    tab.query_all_into(
                        &s.rows,
                        &arena.mask,
                        &prob.targets,
                        prob.theiler,
                        &mut arena.dvals,
                        &mut arena.tvals,
                    );
                    let rho = backend.simplex_tail_into(
                        &arena.dvals,
                        &arena.tvals,
                        &prob.targets,
                        s.params.e,
                        &mut arena.preds,
                    );
                    SkillRow { params: s.params, sample_id: s.sample_id, rho }
                })
                .collect()
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ccm::params::CcmParams;
    use crate::ccm::subsample::draw_samples;
    use crate::engine::{Deploy, EngineConfig};
    use crate::native::NativeBackend;
    use crate::timeseries::generators::{coupled_logistic, CoupledLogisticParams};
    use crate::util::rng::Rng;
    use crate::KMAX;

    fn setup() -> (Context, Broadcast<CcmProblem>, Vec<LibrarySample>) {
        let ctx = Context::new(
            EngineConfig::new(Deploy::Local { cores: 2 }).with_default_parallelism(4),
        );
        let (x, y) = coupled_logistic(400, CoupledLogisticParams::default());
        let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
        let size = problem.size_bytes();
        let b = ctx.broadcast(problem, size);
        let samples = draw_samples(&Rng::new(9), CcmParams::new(2, 1, 150), 399, 12);
        (ctx, b, samples)
    }

    #[test]
    fn input_for_is_a_borrowed_view() {
        let (_ctx, problem, samples) = setup();
        let prob = problem.value();
        let input = prob.input_for(&samples[0]);
        // the view aliases the problem's storage — no copies
        assert!(std::ptr::eq(input.vecs, prob.emb.vecs.as_slice()));
        assert!(std::ptr::eq(input.targets, prob.targets.as_slice()));
        assert!(std::ptr::eq(input.times, prob.times.as_slice()));
        assert!(std::ptr::eq(input.lib_rows, samples[0].rows.as_slice()));
        input.validate();
    }

    #[test]
    fn transform_pipeline_produces_skill_rows() {
        let (ctx, problem, samples) = setup();
        let rdd = ctx.parallelize_with(samples, 4);
        let skills = ctx.collect(&ccm_transform_rdd(&ctx, rdd, &problem, Arc::new(NativeBackend)));
        assert_eq!(skills.len(), 12);
        // coupled system: every realization should show solid skill
        assert!(skills.iter().all(|s| s.rho > 0.5), "{:?}", skills.iter().map(|s| s.rho).collect::<Vec<_>>());
        // sample ids all present
        let mut ids: Vec<usize> = skills.iter().map(|s| s.sample_id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..12).collect::<Vec<_>>());
    }

    #[test]
    fn table_mode_equals_bruteforce_mode() {
        // §3.2 is an optimization, not an approximation: identical rho —
        // in full AND truncated table layouts.
        let (ctx, problem, samples) = setup();
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend);
        let rdd = ctx.parallelize_with(samples.clone(), 4);
        let brute = ctx.collect(&ccm_transform_rdd(&ctx, rdd, &problem, Arc::clone(&backend)));

        let n = problem.value().emb.n;
        let modes = [
            TableMode::Full,
            TableMode::Truncated { prefix: DistanceTable::auto_prefix(n, 150) },
            TableMode::Truncated { prefix: KMAX }, // pathologically short: fallback-heavy
        ];
        for mode in modes {
            let table = table_pipeline_mode(&ctx, &problem, 4, mode);
            let rdd2 = ctx.parallelize_with(samples.clone(), 4);
            let tabled = ctx.collect(&table_transform_rdd(
                &ctx,
                rdd2,
                &problem,
                &table,
                Arc::clone(&backend),
            ));

            assert_eq!(brute.len(), tabled.len());
            for (a, b) in brute.iter().zip(&tabled) {
                assert_eq!(a.sample_id, b.sample_id, "{mode:?}");
                assert!(
                    (a.rho - b.rho).abs() < 1e-5,
                    "{mode:?} sample {}: brute {} vs table {}",
                    a.sample_id,
                    a.rho,
                    b.rho
                );
            }
        }
    }

    #[test]
    fn truncated_table_broadcast_is_smaller() {
        let (ctx, problem, _samples) = setup();
        let n = problem.value().emb.n;
        let full = table_pipeline_mode(&ctx, &problem, 4, TableMode::Full);
        let prefix = DistanceTable::auto_prefix(n, 150);
        let trunc =
            table_pipeline_mode(&ctx, &problem, 4, TableMode::Truncated { prefix });
        assert!(prefix < n - 1);
        assert_eq!(trunc.value().row_len(), prefix);
        assert!(
            trunc.size_bytes() < full.size_bytes(),
            "truncated broadcast {} must undercut full {}",
            trunc.size_bytes(),
            full.size_bytes()
        );
        // the DES charges what the broadcast declares: O(n*P) + manifold
        assert_eq!(trunc.size_bytes(), n * prefix * 4 + n * crate::EMAX * 4);
    }

    #[test]
    fn broadcast_deps_recorded_for_des() {
        let (ctx, problem, samples) = setup();
        let table = table_pipeline(&ctx, &problem, 4);
        let rdd = ctx.parallelize_with(samples, 4);
        let out = table_transform_rdd(&ctx, rdd, &problem, &table, Arc::new(NativeBackend));
        let _ = ctx.collect(&out);
        let jobs = ctx.events().jobs();
        let last = jobs.last().unwrap();
        assert_eq!(last.broadcast_deps.len(), 2, "problem + table deps expected");
        assert!(last.broadcast_deps.iter().any(|(id, _)| *id == table.id()));
    }
}
