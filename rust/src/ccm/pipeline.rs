//! The paper's two parallel pipelines, expressed on the engine.
//!
//! * [`ccm_transform_rdd`] — §3.1: transform an RDD of library subsamples
//!   into an RDD of prediction skills (brute-force k-NN inside each task).
//! * [`table_pipeline`] / [`table_transform_rdd`] — §3.2: build the
//!   distance indexing table in parallel over manifold-row chunks,
//!   broadcast it, then run the CCM transform as cheap table lookups.
//!
//! Both return *lazy* RDDs; the driver chooses blocking (`collect`) or
//! asynchronous (`collect_async`) submission — §3.3.
//!
//! # Zero-copy task data path
//!
//! The [`CcmProblem`] (manifold + aligned targets + time column) is
//! broadcast once and shared behind an `Arc`; a task's
//! [`CrossMapInput`] is a borrowed view of it plus the sample's library
//! row indices — task assembly copies nothing O(n). Each partition
//! closure owns one [`TaskArena`] reused across its samples, so the only
//! per-sample work besides the kernels is the inherent O(L) library
//! gather (brute-force mode) or the O(n/64) mask refill (table mode).

//! # Sharded table pipeline
//!
//! [`sharded_table_pipeline_mode`] builds the same parallel per-row
//! sorted lists but assembles them into per-node [`TableShard`]s, each
//! registered as its **own** broadcast — the DES then prices shard ships
//! individually instead of charging every node the whole table. The
//! transform becomes one job per shard ([`sharded_transform_rdds`]): a
//! task computes the simplex predictions for its shard's query rows only
//! (`ComputeBackend::shard_chunk_into` — in-process by default, or across
//! a process boundary via `ccm::cluster::ClusterBackend`), and the driver
//! concatenates chunks in row order and applies Pearson
//! ([`combine_shard_chunks`]) — arithmetic identical to the unsharded
//! tail, so skills are bit-identical.

use std::collections::HashMap;
use std::sync::Arc;

use crate::ccm::backend::{ComputeBackend, CrossMapInput, TaskArena};
use crate::ccm::embedding::Embedding;
use crate::ccm::params::CcmParams;
use crate::ccm::result::SkillRow;
use crate::ccm::simplex::pearson_f32;
use crate::ccm::subsample::LibrarySample;
use crate::ccm::table::{shard_bounds, DistanceTable, ShardedTable, TableShard};
use crate::engine::{Broadcast, Context, Rdd};

/// The cross-mapping problem shared by every task: the effect-series
/// shadow manifold and the cause-series targets aligned to it. Broadcast
/// once per `(E, tau)`; tasks borrow it — they never copy it.
pub struct CcmProblem {
    pub emb: Embedding,
    /// Cause value at each manifold row's time.
    pub targets: Vec<f32>,
    /// Original-series time of each manifold row, as f32 (precomputed once
    /// so task views can borrow it instead of re-deriving O(n) per task).
    pub times: Vec<f32>,
    /// Theiler exclusion radius (0 = self only).
    pub theiler: f32,
}

impl CcmProblem {
    pub fn new(effect: &[f32], cause: &[f32], e: usize, tau: usize, theiler: f32) -> CcmProblem {
        let emb = Embedding::new(effect, e, tau);
        let targets = emb.align_targets(cause);
        let times = (0..emb.n).map(|i| emb.time_of(i) as f32).collect();
        CcmProblem { emb, targets, times, theiler }
    }

    pub fn size_bytes(&self) -> usize {
        self.emb.size_bytes() + self.targets.len() * 4 + self.times.len() * 4
    }

    /// Assemble the zero-copy [`CrossMapInput`] view for one library
    /// sample: three borrowed slices + the sample's row indices. O(1) —
    /// no O(n) prediction-side copies, no O(L) library materialization.
    pub fn input_for<'a>(&'a self, sample: &'a LibrarySample) -> CrossMapInput<'a> {
        CrossMapInput {
            vecs: &self.emb.vecs,
            targets: &self.targets,
            times: &self.times,
            lib_rows: &sample.rows,
            e: sample.params.e,
            theiler: self.theiler,
        }
    }
}

/// How the distance indexing table is stored and broadcast.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TableMode {
    /// All `n - 1` sorted neighbours per row (the paper's layout).
    Full,
    /// Top-`prefix` neighbours per row — `O(n * P)` broadcast bytes with
    /// an exact counted fallback for queries that exhaust the prefix (see
    /// [`crate::ccm::table`] module docs). Size `prefix` with
    /// [`DistanceTable::auto_prefix`].
    Truncated { prefix: usize },
}

/// §3.1 — the CCM transform pipeline: subsamples -> prediction skills via
/// brute-force k-NN + simplex inside each task.
pub fn ccm_transform_rdd(
    _ctx: &Context,
    samples: Rdd<LibrarySample>,
    problem: &Broadcast<CcmProblem>,
    backend: Arc<dyn ComputeBackend>,
) -> Rdd<SkillRow> {
    let problem = problem.clone();
    samples
        .uses_broadcast(&problem)
        .map_partitions(move |_p, samples| {
            let prob = problem.value();
            let mut arena = TaskArena::new();
            samples
                .into_iter()
                .map(|s| {
                    let rho = backend.cross_map_into(&prob.input_for(&s), &mut arena);
                    SkillRow { params: s.params, sample_id: s.sample_id, rho }
                })
                .collect()
        })
}

/// §3.2 (construction) — build the distance indexing table in parallel:
/// one task per chunk of manifold rows, each computing its rows' sorted
/// neighbour lists (truncated at source in [`TableMode::Truncated`], which
/// also shrinks the collect); the driver assembles and broadcasts.
///
/// Blocking (the table is a hard dependency of its transform jobs); the
/// asynchronous driver overlaps *different* (E, tau) tables instead.
pub fn table_pipeline_mode(
    ctx: &Context,
    problem: &Broadcast<CcmProblem>,
    partitions: usize,
    mode: TableMode,
) -> Broadcast<DistanceTable> {
    let n = problem.value().emb.n;
    let row_len = match mode {
        TableMode::Full => n.saturating_sub(1),
        TableMode::Truncated { prefix } => prefix.min(n.saturating_sub(1)),
    };
    let rows_rdd = ctx.parallelize_with((0..n).collect::<Vec<usize>>(), partitions);
    let prob = problem.clone();
    let sorted = rows_rdd.uses_broadcast(&prob).map_partitions(move |_p, rows| {
        let emb = &prob.value().emb;
        rows.into_iter()
            .map(|i| (i, DistanceTable::sorted_row_prefix(emb, i, row_len)))
            .collect()
    });
    let mut rows: Vec<(usize, Vec<u32>)> = ctx.collect(&sorted);
    rows.sort_by_key(|(i, _)| *i);
    let table = DistanceTable::assemble_with(
        &problem.value().emb,
        rows.into_iter().map(|(_, r)| r).collect(),
        row_len,
    );
    let size = table.size_bytes();
    ctx.broadcast(table, size)
}

/// [`table_pipeline_mode`] with the paper's full layout.
pub fn table_pipeline(
    ctx: &Context,
    problem: &Broadcast<CcmProblem>,
    partitions: usize,
) -> Broadcast<DistanceTable> {
    table_pipeline_mode(ctx, problem, partitions, TableMode::Full)
}

/// The distance table as per-shard broadcasts: shard `s` is its own
/// [`Broadcast<TableShard>`] sized at its own bytes, so the DES (and a
/// real cluster) ships a node only the shards its tasks query.
pub struct ShardedTableBroadcast {
    shards: Vec<Broadcast<TableShard>>,
    pub n: usize,
    pub row_len: usize,
}

impl ShardedTableBroadcast {
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shards(&self) -> &[Broadcast<TableShard>] {
        &self.shards
    }

    /// Sum of per-shard broadcast bytes.
    pub fn total_size_bytes(&self) -> usize {
        self.shards.iter().map(|b| b.size_bytes()).sum()
    }

    /// A query facade over the same `Arc<TableShard>`s the broadcasts hold
    /// (no duplication) — the driver-side view for tests and local use.
    pub fn facade(&self) -> ShardedTable {
        ShardedTable::from_shards(self.shards.iter().map(Broadcast::share).collect())
    }
}

/// §3.2 construction, sharded: the same parallel per-row build, assembled
/// into `num_shards` contiguous row-range shards, each broadcast
/// separately. Blocking, like [`table_pipeline_mode`].
pub fn sharded_table_pipeline_mode(
    ctx: &Context,
    problem: &Broadcast<CcmProblem>,
    partitions: usize,
    mode: TableMode,
    num_shards: usize,
) -> ShardedTableBroadcast {
    let n = problem.value().emb.n;
    let row_len = match mode {
        TableMode::Full => n.saturating_sub(1),
        TableMode::Truncated { prefix } => prefix.min(n.saturating_sub(1)),
    };
    let rows_rdd = ctx.parallelize_with((0..n).collect::<Vec<usize>>(), partitions);
    let prob = problem.clone();
    let sorted = rows_rdd.uses_broadcast(&prob).map_partitions(move |_p, rows| {
        let emb = &prob.value().emb;
        rows.into_iter()
            .map(|i| (i, DistanceTable::sorted_row_prefix(emb, i, row_len)))
            .collect()
    });
    let mut rows: Vec<(usize, Vec<u32>)> = ctx.collect(&sorted);
    rows.sort_by_key(|(i, _)| *i);
    let mut rows: Vec<Vec<u32>> = rows.into_iter().map(|(_, r)| r).collect();
    let emb = &problem.value().emb;
    let mut shards = Vec::new();
    for (sid, (lo, hi)) in shard_bounds(n, num_shards).into_iter().enumerate().rev() {
        let shard = TableShard::assemble_with(emb, sid, lo, rows.split_off(lo), row_len);
        debug_assert_eq!(shard.row_hi, hi);
        let size = shard.size_bytes();
        shards.push(ctx.broadcast(shard, size));
    }
    shards.reverse();
    ShardedTableBroadcast { shards, n, row_len }
}

/// One sample's simplex predictions for one shard's query rows — the unit
/// the sharded transform jobs emit (a few KB: `row_hi - row_lo` floats).
#[derive(Clone, Debug)]
pub struct PredChunk {
    pub params: CcmParams,
    pub sample_id: usize,
    pub shard_id: usize,
    pub row_lo: usize,
    pub preds: Vec<f32>,
}

/// §3.2 use, sharded: ONE JOB PER SHARD over the same samples RDD. Each
/// job's lineage depends only on the problem and *its* shard broadcast,
/// so ship costs are attributed per shard; each task emits prediction
/// chunks for its shard's query rows via `ComputeBackend::shard_chunk_into`.
/// The caller harvests all jobs and feeds [`combine_shard_chunks`].
pub fn sharded_transform_rdds(
    _ctx: &Context,
    samples: &Rdd<LibrarySample>,
    problem: &Broadcast<CcmProblem>,
    table: &ShardedTableBroadcast,
    backend: Arc<dyn ComputeBackend>,
) -> Vec<Rdd<PredChunk>> {
    // the samples RDD is evaluated once per shard job; cache so the draws
    // happen once (they are cheap but this keeps task logs clean)
    let samples = samples.cache();
    table
        .shards()
        .iter()
        .map(|shard_b| {
            let problem = problem.clone();
            let shard_b2 = shard_b.clone();
            let backend = Arc::clone(&backend);
            samples
                .uses_broadcast(&problem)
                .uses_broadcast(shard_b)
                .named(format!("table_shard_{}.transform", shard_b.value().shard_id))
                .map_partitions(move |_p, samples| {
                    let prob = problem.value();
                    let shard = shard_b2.value();
                    let mut arena = TaskArena::new();
                    samples
                        .into_iter()
                        .map(|s| {
                            let mut preds = Vec::new();
                            backend.shard_chunk_into(
                                shard,
                                &prob.targets,
                                prob.theiler,
                                &s.rows,
                                s.params.e,
                                &mut arena,
                                &mut preds,
                            );
                            PredChunk {
                                params: s.params,
                                sample_id: s.sample_id,
                                shard_id: shard.shard_id,
                                row_lo: shard.row_lo,
                                preds,
                            }
                        })
                        .collect()
                })
        })
        .collect()
}

/// Driver-side combine: group chunks per (params, sample), concatenate in
/// row order, Pearson against the problem's targets. The concatenated
/// vector is element-for-element the unsharded pipeline's prediction
/// vector, and `pearson_f32` runs the same summation order — bit-identical
/// skills. Output is sorted by (E, tau, L, sample).
pub fn combine_shard_chunks(chunks: Vec<PredChunk>, problem: &CcmProblem) -> Vec<SkillRow> {
    let n = problem.targets.len();
    let mut groups: HashMap<(usize, usize, usize, usize), Vec<PredChunk>> = HashMap::new();
    for c in chunks {
        let key = (c.params.e, c.params.tau, c.params.l, c.sample_id);
        groups.entry(key).or_default().push(c);
    }
    let mut out: Vec<SkillRow> = groups
        .into_values()
        .map(|mut chunks| {
            chunks.sort_by_key(|c| c.row_lo);
            let params = chunks[0].params;
            let sample_id = chunks[0].sample_id;
            let mut preds = Vec::with_capacity(n);
            for c in &chunks {
                assert_eq!(c.row_lo, preds.len(), "missing or overlapping shard chunk");
                preds.extend_from_slice(&c.preds);
            }
            assert_eq!(preds.len(), n, "shard chunks do not cover the manifold");
            SkillRow { params, sample_id, rho: pearson_f32(&preds, &problem.targets) }
        })
        .collect();
    out.sort_by_key(|r| (r.params.e, r.params.tau, r.params.l, r.sample_id));
    out
}

/// §3.2 (use) — the CCM transform pipeline with the broadcast table:
/// k-NN becomes a filtered walk of the precomputed sorted lists, then the
/// simplex/Pearson tail runs on the backend. Mask, panels, and prediction
/// buffers all live in the partition's [`TaskArena`].
pub fn table_transform_rdd(
    _ctx: &Context,
    samples: Rdd<LibrarySample>,
    problem: &Broadcast<CcmProblem>,
    table: &Broadcast<DistanceTable>,
    backend: Arc<dyn ComputeBackend>,
) -> Rdd<SkillRow> {
    let problem = problem.clone();
    let table = table.clone();
    samples
        .uses_broadcast(&problem)
        .uses_broadcast(&table)
        .map_partitions(move |_p, samples| {
            let prob = problem.value();
            let tab = table.value();
            let mut arena = TaskArena::new();
            samples
                .into_iter()
                .map(|s| {
                    arena.mask.set_from(tab.n, &s.rows);
                    tab.query_all_into(
                        &s.rows,
                        &arena.mask,
                        &prob.targets,
                        prob.theiler,
                        &mut arena.dvals,
                        &mut arena.tvals,
                    );
                    let rho = backend.simplex_tail_into(
                        &arena.dvals,
                        &arena.tvals,
                        &prob.targets,
                        s.params.e,
                        &mut arena.preds,
                    );
                    SkillRow { params: s.params, sample_id: s.sample_id, rho }
                })
                .collect()
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ccm::params::CcmParams;
    use crate::ccm::subsample::draw_samples;
    use crate::engine::{Deploy, EngineConfig};
    use crate::native::NativeBackend;
    use crate::timeseries::generators::{coupled_logistic, CoupledLogisticParams};
    use crate::util::rng::Rng;
    use crate::KMAX;

    fn setup() -> (Context, Broadcast<CcmProblem>, Vec<LibrarySample>) {
        let ctx = Context::new(
            EngineConfig::new(Deploy::Local { cores: 2 }).with_default_parallelism(4),
        );
        let (x, y) = coupled_logistic(400, CoupledLogisticParams::default());
        let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
        let size = problem.size_bytes();
        let b = ctx.broadcast(problem, size);
        let samples = draw_samples(&Rng::new(9), CcmParams::new(2, 1, 150), 399, 12);
        (ctx, b, samples)
    }

    #[test]
    fn input_for_is_a_borrowed_view() {
        let (_ctx, problem, samples) = setup();
        let prob = problem.value();
        let input = prob.input_for(&samples[0]);
        // the view aliases the problem's storage — no copies
        assert!(std::ptr::eq(input.vecs, prob.emb.vecs.as_slice()));
        assert!(std::ptr::eq(input.targets, prob.targets.as_slice()));
        assert!(std::ptr::eq(input.times, prob.times.as_slice()));
        assert!(std::ptr::eq(input.lib_rows, samples[0].rows.as_slice()));
        input.validate();
    }

    #[test]
    fn transform_pipeline_produces_skill_rows() {
        let (ctx, problem, samples) = setup();
        let rdd = ctx.parallelize_with(samples, 4);
        let skills = ctx.collect(&ccm_transform_rdd(&ctx, rdd, &problem, Arc::new(NativeBackend)));
        assert_eq!(skills.len(), 12);
        // coupled system: every realization should show solid skill
        assert!(skills.iter().all(|s| s.rho > 0.5), "{:?}", skills.iter().map(|s| s.rho).collect::<Vec<_>>());
        // sample ids all present
        let mut ids: Vec<usize> = skills.iter().map(|s| s.sample_id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..12).collect::<Vec<_>>());
    }

    #[test]
    fn table_mode_equals_bruteforce_mode() {
        // §3.2 is an optimization, not an approximation: identical rho —
        // in full AND truncated table layouts.
        let (ctx, problem, samples) = setup();
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend);
        let rdd = ctx.parallelize_with(samples.clone(), 4);
        let brute = ctx.collect(&ccm_transform_rdd(&ctx, rdd, &problem, Arc::clone(&backend)));

        let n = problem.value().emb.n;
        let modes = [
            TableMode::Full,
            TableMode::Truncated { prefix: DistanceTable::auto_prefix(n, 150) },
            TableMode::Truncated { prefix: KMAX }, // pathologically short: fallback-heavy
        ];
        for mode in modes {
            let table = table_pipeline_mode(&ctx, &problem, 4, mode);
            let rdd2 = ctx.parallelize_with(samples.clone(), 4);
            let tabled = ctx.collect(&table_transform_rdd(
                &ctx,
                rdd2,
                &problem,
                &table,
                Arc::clone(&backend),
            ));

            assert_eq!(brute.len(), tabled.len());
            for (a, b) in brute.iter().zip(&tabled) {
                assert_eq!(a.sample_id, b.sample_id, "{mode:?}");
                assert!(
                    (a.rho - b.rho).abs() < 1e-5,
                    "{mode:?} sample {}: brute {} vs table {}",
                    a.sample_id,
                    a.rho,
                    b.rho
                );
            }
        }
    }

    #[test]
    fn truncated_table_broadcast_is_smaller() {
        let (ctx, problem, _samples) = setup();
        let n = problem.value().emb.n;
        let full = table_pipeline_mode(&ctx, &problem, 4, TableMode::Full);
        let prefix = DistanceTable::auto_prefix(n, 150);
        let trunc =
            table_pipeline_mode(&ctx, &problem, 4, TableMode::Truncated { prefix });
        assert!(prefix < n - 1);
        assert_eq!(trunc.value().row_len(), prefix);
        assert!(
            trunc.size_bytes() < full.size_bytes(),
            "truncated broadcast {} must undercut full {}",
            trunc.size_bytes(),
            full.size_bytes()
        );
        // the DES charges what the broadcast declares: O(n*P) + manifold
        assert_eq!(trunc.size_bytes(), n * prefix * 4 + n * crate::EMAX * 4);
    }

    #[test]
    fn sharded_table_mode_bit_identical_to_unsharded() {
        let (ctx, problem, samples) = setup();
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend);
        let n = problem.value().emb.n;
        let mode = TableMode::Truncated { prefix: DistanceTable::auto_prefix(n, 150) };

        // unsharded reference skills
        let table = table_pipeline_mode(&ctx, &problem, 4, mode);
        let rdd = ctx.parallelize_with(samples.clone(), 4);
        let mut want =
            ctx.collect(&table_transform_rdd(&ctx, rdd, &problem, &table, Arc::clone(&backend)));
        want.sort_by_key(|r| (r.params.e, r.params.tau, r.params.l, r.sample_id));

        for shards in [1usize, 3, 7] {
            let sharded = sharded_table_pipeline_mode(&ctx, &problem, 4, mode, shards);
            assert_eq!(sharded.num_shards(), shards);
            assert_eq!(sharded.row_len, table.value().row_len());
            let rdd = ctx.parallelize_with(samples.clone(), 4);
            let mut chunks = Vec::new();
            for chunk_rdd in
                sharded_transform_rdds(&ctx, &rdd, &problem, &sharded, Arc::clone(&backend))
            {
                chunks.extend(ctx.collect(&chunk_rdd));
            }
            let got = combine_shard_chunks(chunks, problem.value());
            assert_eq!(got.len(), want.len(), "{shards} shards");
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.sample_id, b.sample_id);
                assert_eq!(a.rho, b.rho, "{shards} shards: rho must be bit-identical");
            }
        }
    }

    #[test]
    fn sharded_jobs_depend_on_their_own_shard_only() {
        let (ctx, problem, samples) = setup();
        let sharded =
            sharded_table_pipeline_mode(&ctx, &problem, 4, TableMode::Full, 3);
        let rdd = ctx.parallelize_with(samples, 4);
        let chunk_rdds =
            sharded_transform_rdds(&ctx, &rdd, &problem, &sharded, Arc::new(NativeBackend));
        for r in &chunk_rdds {
            let _ = ctx.collect(r);
        }
        let jobs = ctx.events().jobs();
        let shard_jobs: Vec<_> =
            jobs.iter().filter(|j| j.name.contains(".transform")).collect();
        assert_eq!(shard_jobs.len(), 3);
        for (s, job) in shard_jobs.iter().enumerate() {
            let b = &sharded.shards()[s];
            assert_eq!(job.name, format!("table_shard_{s}.transform"));
            assert_eq!(job.broadcast_deps.len(), 2, "problem + own shard only");
            assert!(job.broadcast_deps.contains(&(b.id(), b.size_bytes())));
            // no dependency on any *other* shard broadcast
            for (o, other) in sharded.shards().iter().enumerate() {
                if o != s {
                    assert!(job.broadcast_deps.iter().all(|(id, _)| *id != other.id()));
                }
            }
        }
        // per-shard sizes partition the index: they sum to facade total
        let total: usize = sharded.shards().iter().map(|b| b.size_bytes()).sum();
        assert_eq!(total, sharded.total_size_bytes());
        assert_eq!(total, sharded.facade().size_bytes());
    }

    #[test]
    fn facade_shares_broadcast_shards() {
        let (ctx, problem, _samples) = setup();
        let sharded = sharded_table_pipeline_mode(&ctx, &problem, 4, TableMode::Full, 2);
        let facade = sharded.facade();
        for (b, s) in sharded.shards().iter().zip(facade.shards()) {
            assert!(std::ptr::eq(b.value(), s.as_ref()), "facade must alias broadcasts");
        }
    }

    #[test]
    fn combine_rejects_missing_chunk() {
        let (_ctx, problem, samples) = setup();
        let prob = problem.value();
        let table = DistanceTable::build(&prob.emb);
        let sharded = table.shard(2);
        let backend = NativeBackend;
        let mut arena = TaskArena::new();
        let s = &samples[0];
        let shard = &sharded.shards()[1]; // only the second shard's chunk
        let mut preds = Vec::new();
        backend.shard_chunk_into(
            shard,
            &prob.targets,
            prob.theiler,
            &s.rows,
            s.params.e,
            &mut arena,
            &mut preds,
        );
        let chunk = PredChunk {
            params: s.params,
            sample_id: s.sample_id,
            shard_id: shard.shard_id,
            row_lo: shard.row_lo,
            preds,
        };
        let got = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            combine_shard_chunks(vec![chunk], prob)
        }));
        assert!(got.is_err(), "a missing shard chunk must not silently pass");
    }

    #[test]
    fn broadcast_deps_recorded_for_des() {
        let (ctx, problem, samples) = setup();
        let table = table_pipeline(&ctx, &problem, 4);
        let rdd = ctx.parallelize_with(samples, 4);
        let out = table_transform_rdd(&ctx, rdd, &problem, &table, Arc::new(NativeBackend));
        let _ = ctx.collect(&out);
        let jobs = ctx.events().jobs();
        let last = jobs.last().unwrap();
        assert_eq!(last.broadcast_deps.len(), 2, "problem + table deps expected");
        assert!(last.broadcast_deps.iter().any(|(id, _)| *id == table.id()));
    }
}
