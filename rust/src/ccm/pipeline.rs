//! The paper's two parallel pipelines, expressed on the engine.
//!
//! * [`ccm_transform_rdd`] — §3.1: transform an RDD of library subsamples
//!   into an RDD of prediction skills (brute-force k-NN inside each task).
//! * [`table_pipeline`] / [`table_transform_rdd`] — §3.2: build the
//!   distance indexing table in parallel over manifold-row chunks,
//!   broadcast it, then run the CCM transform as cheap table lookups.
//!
//! Both return *lazy* RDDs; the driver chooses blocking (`collect`) or
//! asynchronous (`collect_async`) submission — §3.3.
//!
//! # Zero-copy task data path
//!
//! The [`CcmProblem`] (manifold + aligned targets + time column) is
//! broadcast once and shared behind an `Arc`; a task's
//! [`CrossMapInput`] is a borrowed view of it plus the sample's library
//! row indices — task assembly copies nothing O(n). Each partition
//! closure owns one [`TaskArena`] reused across its samples, so the only
//! per-sample work besides the kernels is the inherent O(L) library
//! gather (brute-force mode) or the O(n/64) mask refill (table mode).

//! # Sharded table pipeline
//!
//! [`sharded_table_pipeline_mode`] builds the same parallel per-row
//! sorted lists but assembles them into per-node [`TableShard`]s, each
//! registered as its **own** broadcast — the DES then prices shard ships
//! individually instead of charging every node the whole table. The
//! transform becomes one job per shard ([`sharded_transform_rdds`]): a
//! task computes the simplex predictions for its shard's query rows only
//! (`ComputeBackend::shard_chunk_into` — in-process by default, or across
//! a process boundary via `ccm::cluster::ClusterBackend`), and the driver
//! concatenates chunks in row order and applies Pearson
//! ([`combine_shard_chunks`]) — arithmetic identical to the unsharded
//! tail, so skills are bit-identical.
//!
//! # Worker-side reduce (shuffle stage)
//!
//! [`sharded_agg_rdds`] is the map-side-combine variant of the sharded
//! transform: each task folds its shard's predictions straight into a
//! [`PearsonSums`] partial (n, Σx, Σy, Σxy, Σx², Σy²) and ships ~48 bytes
//! back instead of a prediction chunk. The driver groups partials per
//! (E, tau, L, sample) key ([`combine_shard_sums`]), merges them in
//! shard-index order (`ComputeBackend::merge_sums` — on a worker for the
//! cluster backend), and evaluates rho from the merged sums
//! ([`pearson_from_sums`]). Per-chunk accumulation and the merge are both
//! compensated (Kahan) with the compensation internal to each call, so a
//! partial computed in-process and one computed across the wire are
//! bit-identical, and rho agrees with the driver-concat path to within
//! 1 ULP (asserted by tests and the `--reduce` A/B in CI).

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::ccm::backend::{ComputeBackend, CrossMapInput, TaskArena};
use crate::ccm::embedding::Embedding;
use crate::ccm::params::CcmParams;
use crate::ccm::result::SkillRow;
use crate::ccm::simplex::pearson_f32;
use crate::ccm::subsample::LibrarySample;
use crate::ccm::table::{shard_bounds, DistanceTable, ShardedTable, TableShard};
use crate::engine::{Broadcast, Context, Rdd};

/// The cross-mapping problem shared by every task: the effect-series
/// shadow manifold and the cause-series targets aligned to it. Broadcast
/// once per `(E, tau)`; tasks borrow it — they never copy it.
pub struct CcmProblem {
    pub emb: Embedding,
    /// Cause value at each manifold row's time.
    pub targets: Vec<f32>,
    /// Original-series time of each manifold row, as f32 (precomputed once
    /// so task views can borrow it instead of re-deriving O(n) per task).
    pub times: Vec<f32>,
    /// Theiler exclusion radius (0 = self only).
    pub theiler: f32,
}

impl CcmProblem {
    pub fn new(effect: &[f32], cause: &[f32], e: usize, tau: usize, theiler: f32) -> CcmProblem {
        let emb = Embedding::new(effect, e, tau);
        let targets = emb.align_targets(cause);
        let times = (0..emb.n).map(|i| emb.time_of(i) as f32).collect();
        CcmProblem { emb, targets, times, theiler }
    }

    pub fn size_bytes(&self) -> usize {
        self.emb.size_bytes() + self.targets.len() * 4 + self.times.len() * 4
    }

    /// Assemble the zero-copy [`CrossMapInput`] view for one library
    /// sample: three borrowed slices + the sample's row indices. O(1) —
    /// no O(n) prediction-side copies, no O(L) library materialization.
    pub fn input_for<'a>(&'a self, sample: &'a LibrarySample) -> CrossMapInput<'a> {
        CrossMapInput {
            vecs: &self.emb.vecs,
            targets: &self.targets,
            times: &self.times,
            lib_rows: &sample.rows,
            e: sample.params.e,
            theiler: self.theiler,
        }
    }
}

/// How the distance indexing table is stored and broadcast.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TableMode {
    /// All `n - 1` sorted neighbours per row (the paper's layout).
    Full,
    /// Top-`prefix` neighbours per row — `O(n * P)` broadcast bytes with
    /// an exact counted fallback for queries that exhaust the prefix (see
    /// [`crate::ccm::table`] module docs). Size `prefix` with
    /// [`DistanceTable::auto_prefix`].
    Truncated { prefix: usize },
}

/// §3.1 — the CCM transform pipeline: subsamples -> prediction skills via
/// brute-force k-NN + simplex inside each task.
pub fn ccm_transform_rdd(
    _ctx: &Context,
    samples: Rdd<LibrarySample>,
    problem: &Broadcast<CcmProblem>,
    backend: Arc<dyn ComputeBackend>,
) -> Rdd<SkillRow> {
    let problem = problem.clone();
    samples
        .uses_broadcast(&problem)
        .map_partitions(move |_p, samples| {
            let prob = problem.value();
            let mut arena = TaskArena::new();
            samples
                .into_iter()
                .map(|s| {
                    let rho = backend.cross_map_into(&prob.input_for(&s), &mut arena);
                    SkillRow { params: s.params, sample_id: s.sample_id, rho }
                })
                .collect()
        })
}

/// §3.2 (construction) — build the distance indexing table in parallel:
/// one task per chunk of manifold rows, each computing its rows' sorted
/// neighbour lists (truncated at source in [`TableMode::Truncated`], which
/// also shrinks the collect); the driver assembles and broadcasts.
///
/// Blocking (the table is a hard dependency of its transform jobs); the
/// asynchronous driver overlaps *different* (E, tau) tables instead.
pub fn table_pipeline_mode(
    ctx: &Context,
    problem: &Broadcast<CcmProblem>,
    partitions: usize,
    mode: TableMode,
) -> Broadcast<DistanceTable> {
    let n = problem.value().emb.n;
    let row_len = match mode {
        TableMode::Full => n.saturating_sub(1),
        TableMode::Truncated { prefix } => prefix.min(n.saturating_sub(1)),
    };
    let rows_rdd = ctx.parallelize_with((0..n).collect::<Vec<usize>>(), partitions);
    let prob = problem.clone();
    let sorted = rows_rdd.uses_broadcast(&prob).map_partitions(move |_p, rows| {
        let emb = &prob.value().emb;
        rows.into_iter()
            .map(|i| (i, DistanceTable::sorted_row_prefix(emb, i, row_len)))
            .collect()
    });
    let mut rows: Vec<(usize, Vec<u32>)> = ctx.collect(&sorted);
    rows.sort_by_key(|(i, _)| *i);
    let table = DistanceTable::assemble_with(
        &problem.value().emb,
        rows.into_iter().map(|(_, r)| r).collect(),
        row_len,
    );
    let size = table.size_bytes();
    ctx.broadcast(table, size)
}

/// [`table_pipeline_mode`] with the paper's full layout.
pub fn table_pipeline(
    ctx: &Context,
    problem: &Broadcast<CcmProblem>,
    partitions: usize,
) -> Broadcast<DistanceTable> {
    table_pipeline_mode(ctx, problem, partitions, TableMode::Full)
}

/// The distance table as per-shard broadcasts: shard `s` is its own
/// [`Broadcast<TableShard>`] sized at its own bytes, so the DES (and a
/// real cluster) ships a node only the shards its tasks query.
pub struct ShardedTableBroadcast {
    shards: Vec<Broadcast<TableShard>>,
    pub n: usize,
    pub row_len: usize,
}

impl ShardedTableBroadcast {
    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shards(&self) -> &[Broadcast<TableShard>] {
        &self.shards
    }

    /// Sum of per-shard broadcast bytes.
    pub fn total_size_bytes(&self) -> usize {
        self.shards.iter().map(|b| b.size_bytes()).sum()
    }

    /// A query facade over the same `Arc<TableShard>`s the broadcasts hold
    /// (no duplication) — the driver-side view for tests and local use.
    pub fn facade(&self) -> ShardedTable {
        ShardedTable::from_shards(self.shards.iter().map(Broadcast::share).collect())
    }
}

/// §3.2 construction, sharded: the same parallel per-row build, assembled
/// into `num_shards` contiguous row-range shards, each broadcast
/// separately. Blocking, like [`table_pipeline_mode`].
pub fn sharded_table_pipeline_mode(
    ctx: &Context,
    problem: &Broadcast<CcmProblem>,
    partitions: usize,
    mode: TableMode,
    num_shards: usize,
) -> ShardedTableBroadcast {
    let n = problem.value().emb.n;
    let row_len = match mode {
        TableMode::Full => n.saturating_sub(1),
        TableMode::Truncated { prefix } => prefix.min(n.saturating_sub(1)),
    };
    let rows_rdd = ctx.parallelize_with((0..n).collect::<Vec<usize>>(), partitions);
    let prob = problem.clone();
    let sorted = rows_rdd.uses_broadcast(&prob).map_partitions(move |_p, rows| {
        let emb = &prob.value().emb;
        rows.into_iter()
            .map(|i| (i, DistanceTable::sorted_row_prefix(emb, i, row_len)))
            .collect()
    });
    let mut rows: Vec<(usize, Vec<u32>)> = ctx.collect(&sorted);
    rows.sort_by_key(|(i, _)| *i);
    let mut rows: Vec<Vec<u32>> = rows.into_iter().map(|(_, r)| r).collect();
    let emb = &problem.value().emb;
    let mut shards = Vec::new();
    for (sid, (lo, hi)) in shard_bounds(n, num_shards).into_iter().enumerate().rev() {
        let shard = TableShard::assemble_with(emb, sid, lo, rows.split_off(lo), row_len);
        debug_assert_eq!(shard.row_hi, hi);
        let size = shard.size_bytes();
        shards.push(ctx.broadcast(shard, size));
    }
    shards.reverse();
    ShardedTableBroadcast { shards, n, row_len }
}

/// One sample's simplex predictions for one shard's query rows — the unit
/// the sharded transform jobs emit (a few KB: `row_hi - row_lo` floats).
#[derive(Clone, Debug)]
pub struct PredChunk {
    pub params: CcmParams,
    pub sample_id: usize,
    pub shard_id: usize,
    pub row_lo: usize,
    pub preds: Vec<f32>,
}

/// §3.2 use, sharded: ONE JOB PER SHARD over the same samples RDD. Each
/// job's lineage depends only on the problem and *its* shard broadcast,
/// so ship costs are attributed per shard; each task emits prediction
/// chunks for its shard's query rows via `ComputeBackend::shard_chunk_into`.
/// The caller harvests all jobs and feeds [`combine_shard_chunks`].
pub fn sharded_transform_rdds(
    _ctx: &Context,
    samples: &Rdd<LibrarySample>,
    problem: &Broadcast<CcmProblem>,
    table: &ShardedTableBroadcast,
    backend: Arc<dyn ComputeBackend>,
) -> Vec<Rdd<PredChunk>> {
    // the samples RDD is evaluated once per shard job; cache so the draws
    // happen once (they are cheap but this keeps task logs clean)
    let samples = samples.cache();
    table
        .shards()
        .iter()
        .map(|shard_b| {
            let problem = problem.clone();
            let shard_b2 = shard_b.clone();
            let backend = Arc::clone(&backend);
            samples
                .uses_broadcast(&problem)
                .uses_broadcast(shard_b)
                .named(format!("table_shard_{}.transform", shard_b.value().shard_id))
                .map_partitions(move |_p, samples| {
                    let prob = problem.value();
                    let shard = shard_b2.value();
                    let mut arena = TaskArena::new();
                    samples
                        .into_iter()
                        .map(|s| {
                            let mut preds = Vec::new();
                            backend.shard_chunk_into(
                                shard,
                                &prob.targets,
                                prob.theiler,
                                &s.rows,
                                s.params.e,
                                &mut arena,
                                &mut preds,
                            );
                            PredChunk {
                                params: s.params,
                                sample_id: s.sample_id,
                                shard_id: shard.shard_id,
                                row_lo: shard.row_lo,
                                preds,
                            }
                        })
                        .collect()
                })
        })
        .collect()
}

/// Driver-side combine: group chunks per (params, sample), concatenate in
/// row order, Pearson against the problem's targets. The concatenated
/// vector is element-for-element the unsharded pipeline's prediction
/// vector, and `pearson_f32` runs the same summation order — bit-identical
/// skills. Groups are visited in sorted key order (`BTreeMap`), so every
/// step of the combine — not just the sorted output — is independent of
/// hasher seed. Output is sorted by (E, tau, L, sample).
pub fn combine_shard_chunks(chunks: Vec<PredChunk>, problem: &CcmProblem) -> Vec<SkillRow> {
    let n = problem.targets.len();
    let mut groups: BTreeMap<(usize, usize, usize, usize), Vec<PredChunk>> = BTreeMap::new();
    for c in chunks {
        let key = (c.params.e, c.params.tau, c.params.l, c.sample_id);
        groups.entry(key).or_default().push(c);
    }
    groups
        .into_values()
        .map(|mut chunks| {
            chunks.sort_by_key(|c| c.row_lo);
            let params = chunks[0].params;
            let sample_id = chunks[0].sample_id;
            let mut preds = Vec::with_capacity(n);
            for c in &chunks {
                assert_eq!(c.row_lo, preds.len(), "missing or overlapping shard chunk");
                preds.extend_from_slice(&c.preds);
            }
            assert_eq!(preds.len(), n, "shard chunks do not cover the manifold");
            SkillRow { params, sample_id, rho: pearson_f32(&preds, &problem.targets) }
        })
        .collect()
}

/// Streaming partial Pearson sums over a row range: the five raw moments
/// plus the count. This is the shuffle-stage value type — a worker folds
/// its shard's predictions (x) and the aligned targets (y) into one of
/// these and ships ~48 bytes instead of the prediction chunk.
///
/// Accumulation ([`PearsonSums::from_slices`]) and the merge
/// ([`PearsonSums::merge_all`]) are compensated (Kahan) *internally*: the
/// compensation terms never leave the call, only the plain `f64` sums do.
/// A partial is therefore a pure function of its chunk's data, and a merge
/// a pure function of the ordered partials — in-process and across-the-wire
/// reduces are bit-identical (the JSON writer round-trips f64 exactly).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct PearsonSums {
    /// Number of (x, y) pairs folded in.
    pub n: u64,
    /// Σx.
    pub sx: f64,
    /// Σy.
    pub sy: f64,
    /// Σxy.
    pub sxy: f64,
    /// Σx².
    pub sxx: f64,
    /// Σy².
    pub syy: f64,
}

/// Compensated (Kahan) f64 accumulator — private to [`PearsonSums`]; the
/// compensation term never crosses an API boundary.
#[derive(Clone, Copy, Debug, Default)]
struct Kahan {
    sum: f64,
    c: f64,
}

impl Kahan {
    fn add(&mut self, v: f64) {
        let y = v - self.c;
        let t = self.sum + y;
        self.c = (t - self.sum) - y;
        self.sum = t;
    }
}

impl PearsonSums {
    /// Fold one chunk's aligned (predictions, targets) pairs into partial
    /// sums, compensated. One call per shard chunk — the summation order
    /// within a chunk is fixed by row order, so the result is deterministic
    /// for a given chunk regardless of where it runs.
    pub fn from_slices(xs: &[f32], ys: &[f32]) -> PearsonSums {
        assert_eq!(xs.len(), ys.len(), "predictions and targets must align");
        let mut sx = Kahan::default();
        let mut sy = Kahan::default();
        let mut sxy = Kahan::default();
        let mut sxx = Kahan::default();
        let mut syy = Kahan::default();
        for (&xf, &yf) in xs.iter().zip(ys) {
            let (x, y) = (xf as f64, yf as f64);
            sx.add(x);
            sy.add(y);
            sxy.add(x * y);
            sxx.add(x * x);
            syy.add(y * y);
        }
        PearsonSums {
            n: xs.len() as u64,
            sx: sx.sum,
            sy: sy.sum,
            sxy: sxy.sum,
            sxx: sxx.sum,
            syy: syy.sum,
        }
    }

    /// Merge partials column-wise in slice order (callers pass them sorted
    /// by shard index), compensated. Deterministic for a given ordered
    /// slice, so the driver-local default and a worker-side merge of the
    /// same partials produce bit-identical sums.
    pub fn merge_all(parts: &[PearsonSums]) -> PearsonSums {
        let mut n = 0u64;
        let mut sx = Kahan::default();
        let mut sy = Kahan::default();
        let mut sxy = Kahan::default();
        let mut sxx = Kahan::default();
        let mut syy = Kahan::default();
        for p in parts {
            n += p.n;
            sx.add(p.sx);
            sy.add(p.sy);
            sxy.add(p.sxy);
            sxx.add(p.sxx);
            syy.add(p.syy);
        }
        PearsonSums { n, sx: sx.sum, sy: sy.sum, sxy: sxy.sum, sxx: sxx.sum, syy: syy.sum }
    }
}

/// Pearson correlation from merged raw-moment sums, mirroring
/// [`pearson_f32`]'s guards: empty input and zero variance both yield 0.
///
/// `cov = Σxy − n·x̄·ȳ`, `vx = Σx² − n·x̄²`, `vy = Σy² − n·ȳ²`,
/// `rho = cov / sqrt(vx · vy)`. The two-pass mean-centered driver path and
/// this raw-moment form agree to well under one f32 ULP on bounded CCM
/// data (asserted by the property suite).
pub fn pearson_from_sums(s: &PearsonSums) -> f32 {
    if s.n == 0 {
        return 0.0;
    }
    let n = s.n as f64;
    let mx = s.sx / n;
    let my = s.sy / n;
    let cov = s.sxy - n * mx * my;
    let vx = s.sxx - n * mx * mx;
    let vy = s.syy - n * my * my;
    let denom = (vx * vy).sqrt();
    if denom > 0.0 {
        (cov / denom) as f32
    } else {
        0.0
    }
}

/// Distance between two f32 values in units-in-the-last-place, treating
/// the floats as points on the monotonic integer line (negative zero and
/// positive zero are 0 apart). `0` means bit-identical-or-signed-zero;
/// the worker-reduce acceptance bound is `<= 1`.
pub fn f32_ulp_distance(a: f32, b: f32) -> u64 {
    fn ordered(x: f32) -> i64 {
        let i = x.to_bits() as i32 as i64;
        if i < 0 {
            -0x8000_0000 - i
        } else {
            i
        }
    }
    (ordered(a) - ordered(b)).unsigned_abs()
}

/// One sample's partial Pearson sums for one shard's query rows — the unit
/// the shuffle-stage aggregation jobs emit (~48 bytes vs. a few KB for a
/// [`PredChunk`]).
#[derive(Clone, Copy, Debug)]
pub struct SumsChunk {
    pub params: CcmParams,
    pub sample_id: usize,
    pub shard_id: usize,
    pub sums: PearsonSums,
}

/// §3.2 use, sharded, with map-side combine: one job per shard over the
/// same samples RDD, but each task reduces its shard's predictions to a
/// [`PearsonSums`] partial via `ComputeBackend::agg_chunk_into` — in-process
/// by default, or on a remote worker as a wire-v5 `agg_chunk` task (the
/// raw predictions then never leave the worker). The caller harvests all
/// jobs and feeds [`combine_shard_sums`].
pub fn sharded_agg_rdds(
    _ctx: &Context,
    samples: &Rdd<LibrarySample>,
    problem: &Broadcast<CcmProblem>,
    table: &ShardedTableBroadcast,
    backend: Arc<dyn ComputeBackend>,
) -> Vec<Rdd<SumsChunk>> {
    let samples = samples.cache();
    table
        .shards()
        .iter()
        .map(|shard_b| {
            let problem = problem.clone();
            let shard_b2 = shard_b.clone();
            let backend = Arc::clone(&backend);
            samples
                .uses_broadcast(&problem)
                .uses_broadcast(shard_b)
                .named(format!("table_shard_{}.agg", shard_b.value().shard_id))
                .map_partitions(move |_p, samples| {
                    let prob = problem.value();
                    let shard = shard_b2.value();
                    let mut arena = TaskArena::new();
                    samples
                        .into_iter()
                        .map(|s| SumsChunk {
                            params: s.params,
                            sample_id: s.sample_id,
                            shard_id: shard.shard_id,
                            sums: backend.agg_chunk_into(
                                shard,
                                &prob.targets,
                                prob.theiler,
                                &s.rows,
                                s.params.e,
                                &mut arena,
                            ),
                        })
                        .collect()
                })
        })
        .collect()
}

/// Driver-side combine for the worker-reduce path: group partials per
/// (params, sample) key in sorted key order, merge each group's sums in
/// shard-index order (`ComputeBackend::merge_sums` — the cluster backend
/// ships this to a v5 worker, the default merges in-process; both are
/// bit-identical), and evaluate rho from the merged sums. Coverage is
/// checked: duplicate shard partials and missing rows both panic, so a
/// requeued task can never be double-counted silently. Output is sorted by
/// (E, tau, L, sample), like [`combine_shard_chunks`].
pub fn combine_shard_sums(
    chunks: Vec<SumsChunk>,
    problem: &CcmProblem,
    backend: &dyn ComputeBackend,
) -> Vec<SkillRow> {
    let n = problem.targets.len() as u64;
    let mut groups: BTreeMap<(usize, usize, usize, usize), Vec<SumsChunk>> = BTreeMap::new();
    for c in chunks {
        let key = (c.params.e, c.params.tau, c.params.l, c.sample_id);
        groups.entry(key).or_default().push(c);
    }
    groups
        .into_values()
        .map(|mut chunks| {
            chunks.sort_by_key(|c| c.shard_id);
            for w in chunks.windows(2) {
                assert_ne!(
                    w[0].shard_id, w[1].shard_id,
                    "duplicate shard partial — a requeued agg task was double-counted"
                );
            }
            let params = chunks[0].params;
            let sample_id = chunks[0].sample_id;
            let partials: Vec<PearsonSums> = chunks.iter().map(|c| c.sums).collect();
            let merged = backend.merge_sums(&partials);
            assert_eq!(merged.n, n, "shard partial sums do not cover the manifold");
            SkillRow { params, sample_id, rho: pearson_from_sums(&merged) }
        })
        .collect()
}

/// §3.2 (use) — the CCM transform pipeline with the broadcast table:
/// k-NN becomes a filtered walk of the precomputed sorted lists, then the
/// simplex/Pearson tail runs on the backend. Mask, panels, and prediction
/// buffers all live in the partition's [`TaskArena`].
pub fn table_transform_rdd(
    _ctx: &Context,
    samples: Rdd<LibrarySample>,
    problem: &Broadcast<CcmProblem>,
    table: &Broadcast<DistanceTable>,
    backend: Arc<dyn ComputeBackend>,
) -> Rdd<SkillRow> {
    let problem = problem.clone();
    let table = table.clone();
    samples
        .uses_broadcast(&problem)
        .uses_broadcast(&table)
        .map_partitions(move |_p, samples| {
            let prob = problem.value();
            let tab = table.value();
            let mut arena = TaskArena::new();
            samples
                .into_iter()
                .map(|s| {
                    arena.mask.set_from(tab.n, &s.rows);
                    tab.query_all_into(
                        &s.rows,
                        &arena.mask,
                        &prob.targets,
                        prob.theiler,
                        &mut arena.dvals,
                        &mut arena.tvals,
                    );
                    let rho = backend.simplex_tail_into(
                        &arena.dvals,
                        &arena.tvals,
                        &prob.targets,
                        s.params.e,
                        &mut arena.preds,
                    );
                    SkillRow { params: s.params, sample_id: s.sample_id, rho }
                })
                .collect()
        })
}

/// Minimum observed realizations before a [`BoundedRho`] interval may
/// decide a cell — below this the normal approximation for the mean of
/// per-subsample rho is not trustworthy, whatever the variance estimate
/// says (and n=1 has no variance estimate at all).
pub const MIN_PARTIAL_OBS: u64 = 8;

/// The `--partial eps,conf` knob: stop dispatching a grid cell's remaining
/// subsample tasks once the confidence interval around its mean rho is
/// within `eps` half-width at confidence `conf`.
///
/// This is the CCM-shaped port of Spark's partial-result machinery
/// (`ApproximateEvaluator` / `PartialResult` / `BoundedDouble`): the
/// driver evaluates results as they arrive and trades a bounded error for
/// skipped tasks. With the knob unset, the driver never consults an
/// evaluator and results are bit-identical to the full run.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct PartialSpec {
    /// Maximum acceptable confidence-interval half-width on mean rho.
    pub eps: f64,
    /// Two-sided confidence level in (0, 1), e.g. 0.95.
    pub conf: f64,
}

impl PartialSpec {
    /// Parse the CLI grammar `eps,conf` (e.g. `0.05,0.95`). Both numbers
    /// must be finite, `eps > 0`, and `conf` strictly inside (0, 1).
    pub fn parse(text: &str) -> Option<PartialSpec> {
        let (eps_s, conf_s) = text.split_once(',')?;
        let eps: f64 = eps_s.trim().parse().ok()?;
        let conf: f64 = conf_s.trim().parse().ok()?;
        if !eps.is_finite() || eps <= 0.0 || !conf.is_finite() || conf <= 0.0 || conf >= 1.0 {
            return None;
        }
        Some(PartialSpec { eps, conf })
    }

    /// Two-sided critical value: the standard-normal quantile at
    /// `(1 + conf) / 2` (e.g. conf 0.95 -> z ~ 1.96).
    pub fn z(&self) -> f64 {
        normal_quantile((1.0 + self.conf) / 2.0)
    }
}

/// Inverse standard-normal CDF (the quantile function), via Acklam's
/// rational approximation — relative error below 1.15e-9 over (0, 1),
/// far tighter than anything the rho-variance estimate feeding it can
/// resolve. Hand-rolled because the build is dependency-free.
pub fn normal_quantile(p: f64) -> f64 {
    assert!(p > 0.0 && p < 1.0, "quantile needs p in (0,1), got {p}");
    const A: [f64; 6] = [
        -3.969683028665376e+01,
        2.209460984245205e+02,
        -2.759285104469687e+02,
        1.383577518672690e+02,
        -3.066479806614716e+01,
        2.506628277459239e+00,
    ];
    const B: [f64; 5] = [
        -5.447609879822406e+01,
        1.615858368580409e+02,
        -1.556989798598866e+02,
        6.680131188771972e+01,
        -1.328068155288572e+01,
    ];
    const C: [f64; 6] = [
        -7.784894002430293e-03,
        -3.223964580411365e-01,
        -2.400758277161838e+00,
        -2.549732539343734e+00,
        4.374664141464968e+00,
        2.938163982698783e+00,
    ];
    const D: [f64; 4] = [
        7.784695709041462e-03,
        3.224671290700398e-01,
        2.445134137142996e+00,
        3.754408661907416e+00,
    ];
    const P_LOW: f64 = 0.02425;
    if p < P_LOW {
        // lower tail
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        // central region
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        // upper tail, by symmetry
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -((((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0))
    }
}

/// Streaming evaluator for one grid cell's mean rho — the `BoundedDouble`
/// of this engine's partial-result machinery. Per-subsample rho values are
/// folded in as their tasks are harvested (Kahan-compensated count / sum /
/// sum-of-squares, same discipline as [`PearsonSums`]); the driver asks
/// [`BoundedRho::decided`] between waves whether the confidence interval
/// has tightened inside the [`PartialSpec`]'s eps.
///
/// Accumulation order is the driver's harvest order, which the partial
/// driver fixes (sample-id order within each wave) — so identical seeds
/// produce identical intervals and identical stop decisions.
#[derive(Clone, Copy, Debug, Default)]
pub struct BoundedRho {
    n: u64,
    sum: Kahan,
    sumsq: Kahan,
}

impl BoundedRho {
    pub fn new() -> BoundedRho {
        BoundedRho::default()
    }

    /// Fold in one realization's skill.
    pub fn observe(&mut self, rho: f32) {
        let x = rho as f64;
        self.n += 1;
        self.sum.add(x);
        self.sumsq.add(x * x);
    }

    /// Realizations observed so far.
    pub fn n(&self) -> u64 {
        self.n
    }

    /// Running mean rho (0 with no observations).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            0.0
        } else {
            self.sum.sum / self.n as f64
        }
    }

    /// Standard error of the mean, from the sample (n-1) variance.
    /// 0 until two observations exist.
    pub fn stderr(&self) -> f64 {
        if self.n < 2 {
            return 0.0;
        }
        let n = self.n as f64;
        let var = (self.sumsq.sum - self.sum.sum * self.sum.sum / n) / (n - 1.0);
        // compensated or not, cancellation can leave a tiny negative
        (var.max(0.0) / n).sqrt()
    }

    /// Confidence-interval half-width at critical value `z`.
    pub fn radius(&self, z: f64) -> f64 {
        z * self.stderr()
    }

    /// Whether the interval is tight enough to stop the cell: at least
    /// [`MIN_PARTIAL_OBS`] realizations observed and the half-width at the
    /// spec's confidence level is within its eps.
    pub fn decided(&self, spec: &PartialSpec) -> bool {
        self.n >= MIN_PARTIAL_OBS && self.radius(spec.z()) <= spec.eps
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ccm::params::CcmParams;
    use crate::ccm::subsample::draw_samples;
    use crate::engine::{Deploy, EngineConfig};
    use crate::native::NativeBackend;
    use crate::timeseries::generators::{coupled_logistic, CoupledLogisticParams};
    use crate::util::rng::Rng;
    use crate::KMAX;

    fn setup() -> (Context, Broadcast<CcmProblem>, Vec<LibrarySample>) {
        let ctx = Context::new(
            EngineConfig::new(Deploy::Local { cores: 2 }).with_default_parallelism(4),
        );
        let (x, y) = coupled_logistic(400, CoupledLogisticParams::default());
        let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
        let size = problem.size_bytes();
        let b = ctx.broadcast(problem, size);
        let samples = draw_samples(&Rng::new(9), CcmParams::new(2, 1, 150), 399, 12);
        (ctx, b, samples)
    }

    #[test]
    fn input_for_is_a_borrowed_view() {
        let (_ctx, problem, samples) = setup();
        let prob = problem.value();
        let input = prob.input_for(&samples[0]);
        // the view aliases the problem's storage — no copies
        assert!(std::ptr::eq(input.vecs, prob.emb.vecs.as_slice()));
        assert!(std::ptr::eq(input.targets, prob.targets.as_slice()));
        assert!(std::ptr::eq(input.times, prob.times.as_slice()));
        assert!(std::ptr::eq(input.lib_rows, samples[0].rows.as_slice()));
        input.validate();
    }

    #[test]
    fn transform_pipeline_produces_skill_rows() {
        let (ctx, problem, samples) = setup();
        let rdd = ctx.parallelize_with(samples, 4);
        let skills = ctx.collect(&ccm_transform_rdd(&ctx, rdd, &problem, Arc::new(NativeBackend)));
        assert_eq!(skills.len(), 12);
        // coupled system: every realization should show solid skill
        assert!(skills.iter().all(|s| s.rho > 0.5), "{:?}", skills.iter().map(|s| s.rho).collect::<Vec<_>>());
        // sample ids all present
        let mut ids: Vec<usize> = skills.iter().map(|s| s.sample_id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..12).collect::<Vec<_>>());
    }

    #[test]
    fn table_mode_equals_bruteforce_mode() {
        // §3.2 is an optimization, not an approximation: identical rho —
        // in full AND truncated table layouts.
        let (ctx, problem, samples) = setup();
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend);
        let rdd = ctx.parallelize_with(samples.clone(), 4);
        let brute = ctx.collect(&ccm_transform_rdd(&ctx, rdd, &problem, Arc::clone(&backend)));

        let n = problem.value().emb.n;
        let modes = [
            TableMode::Full,
            TableMode::Truncated { prefix: DistanceTable::auto_prefix(n, 150) },
            TableMode::Truncated { prefix: KMAX }, // pathologically short: fallback-heavy
        ];
        for mode in modes {
            let table = table_pipeline_mode(&ctx, &problem, 4, mode);
            let rdd2 = ctx.parallelize_with(samples.clone(), 4);
            let tabled = ctx.collect(&table_transform_rdd(
                &ctx,
                rdd2,
                &problem,
                &table,
                Arc::clone(&backend),
            ));

            assert_eq!(brute.len(), tabled.len());
            for (a, b) in brute.iter().zip(&tabled) {
                assert_eq!(a.sample_id, b.sample_id, "{mode:?}");
                assert!(
                    (a.rho - b.rho).abs() < 1e-5,
                    "{mode:?} sample {}: brute {} vs table {}",
                    a.sample_id,
                    a.rho,
                    b.rho
                );
            }
        }
    }

    #[test]
    fn truncated_table_broadcast_is_smaller() {
        let (ctx, problem, _samples) = setup();
        let n = problem.value().emb.n;
        let full = table_pipeline_mode(&ctx, &problem, 4, TableMode::Full);
        let prefix = DistanceTable::auto_prefix(n, 150);
        let trunc =
            table_pipeline_mode(&ctx, &problem, 4, TableMode::Truncated { prefix });
        assert!(prefix < n - 1);
        assert_eq!(trunc.value().row_len(), prefix);
        assert!(
            trunc.size_bytes() < full.size_bytes(),
            "truncated broadcast {} must undercut full {}",
            trunc.size_bytes(),
            full.size_bytes()
        );
        // the DES charges what the broadcast declares: O(n*P) + manifold
        assert_eq!(trunc.size_bytes(), n * prefix * 4 + n * crate::EMAX * 4);
    }

    #[test]
    fn sharded_table_mode_bit_identical_to_unsharded() {
        let (ctx, problem, samples) = setup();
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend);
        let n = problem.value().emb.n;
        let mode = TableMode::Truncated { prefix: DistanceTable::auto_prefix(n, 150) };

        // unsharded reference skills
        let table = table_pipeline_mode(&ctx, &problem, 4, mode);
        let rdd = ctx.parallelize_with(samples.clone(), 4);
        let mut want =
            ctx.collect(&table_transform_rdd(&ctx, rdd, &problem, &table, Arc::clone(&backend)));
        want.sort_by_key(|r| (r.params.e, r.params.tau, r.params.l, r.sample_id));

        for shards in [1usize, 3, 7] {
            let sharded = sharded_table_pipeline_mode(&ctx, &problem, 4, mode, shards);
            assert_eq!(sharded.num_shards(), shards);
            assert_eq!(sharded.row_len, table.value().row_len());
            let rdd = ctx.parallelize_with(samples.clone(), 4);
            let mut chunks = Vec::new();
            for chunk_rdd in
                sharded_transform_rdds(&ctx, &rdd, &problem, &sharded, Arc::clone(&backend))
            {
                chunks.extend(ctx.collect(&chunk_rdd));
            }
            let got = combine_shard_chunks(chunks, problem.value());
            assert_eq!(got.len(), want.len(), "{shards} shards");
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.sample_id, b.sample_id);
                assert_eq!(a.rho, b.rho, "{shards} shards: rho must be bit-identical");
            }
        }
    }

    #[test]
    fn sharded_jobs_depend_on_their_own_shard_only() {
        let (ctx, problem, samples) = setup();
        let sharded =
            sharded_table_pipeline_mode(&ctx, &problem, 4, TableMode::Full, 3);
        let rdd = ctx.parallelize_with(samples, 4);
        let chunk_rdds =
            sharded_transform_rdds(&ctx, &rdd, &problem, &sharded, Arc::new(NativeBackend));
        for r in &chunk_rdds {
            let _ = ctx.collect(r);
        }
        let jobs = ctx.events().jobs();
        let shard_jobs: Vec<_> =
            jobs.iter().filter(|j| j.name.contains(".transform")).collect();
        assert_eq!(shard_jobs.len(), 3);
        for (s, job) in shard_jobs.iter().enumerate() {
            let b = &sharded.shards()[s];
            assert_eq!(job.name, format!("table_shard_{s}.transform"));
            assert_eq!(job.broadcast_deps.len(), 2, "problem + own shard only");
            assert!(job.broadcast_deps.contains(&(b.id(), b.size_bytes())));
            // no dependency on any *other* shard broadcast
            for (o, other) in sharded.shards().iter().enumerate() {
                if o != s {
                    assert!(job.broadcast_deps.iter().all(|(id, _)| *id != other.id()));
                }
            }
        }
        // per-shard sizes partition the index: they sum to facade total
        let total: usize = sharded.shards().iter().map(|b| b.size_bytes()).sum();
        assert_eq!(total, sharded.total_size_bytes());
        assert_eq!(total, sharded.facade().size_bytes());
    }

    #[test]
    fn facade_shares_broadcast_shards() {
        let (ctx, problem, _samples) = setup();
        let sharded = sharded_table_pipeline_mode(&ctx, &problem, 4, TableMode::Full, 2);
        let facade = sharded.facade();
        for (b, s) in sharded.shards().iter().zip(facade.shards()) {
            assert!(std::ptr::eq(b.value(), s.as_ref()), "facade must alias broadcasts");
        }
    }

    #[test]
    fn combine_rejects_missing_chunk() {
        let (_ctx, problem, samples) = setup();
        let prob = problem.value();
        let table = DistanceTable::build(&prob.emb);
        let sharded = table.shard(2);
        let backend = NativeBackend;
        let mut arena = TaskArena::new();
        let s = &samples[0];
        let shard = &sharded.shards()[1]; // only the second shard's chunk
        let mut preds = Vec::new();
        backend.shard_chunk_into(
            shard,
            &prob.targets,
            prob.theiler,
            &s.rows,
            s.params.e,
            &mut arena,
            &mut preds,
        );
        let chunk = PredChunk {
            params: s.params,
            sample_id: s.sample_id,
            shard_id: shard.shard_id,
            row_lo: shard.row_lo,
            preds,
        };
        let got = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            combine_shard_chunks(vec![chunk], prob)
        }));
        assert!(got.is_err(), "a missing shard chunk must not silently pass");
    }

    #[test]
    fn ulp_distance_basics() {
        assert_eq!(f32_ulp_distance(1.0, 1.0), 0);
        assert_eq!(f32_ulp_distance(0.0, -0.0), 0);
        assert_eq!(f32_ulp_distance(1.0, f32::from_bits(1.0f32.to_bits() + 1)), 1);
        assert_eq!(
            f32_ulp_distance(-1.0, f32::from_bits((-1.0f32).to_bits() + 1)),
            1
        );
        // straddling zero: one step each side of the signed-zero pair
        assert_eq!(f32_ulp_distance(f32::from_bits(1), -f32::from_bits(1)), 2);
        assert!(f32_ulp_distance(1.0, 1.0 + 1e-4) > 1);
    }

    #[test]
    fn pearson_from_sums_matches_pearson_f32_within_1_ulp() {
        let (_ctx, problem, samples) = setup();
        let prob = problem.value();
        let backend = NativeBackend;
        let mut arena = TaskArena::new();
        for s in &samples {
            let rho_concat = backend.cross_map_into(&prob.input_for(s), &mut arena);
            let sums = PearsonSums::from_slices(&arena.preds, &prob.targets);
            let rho_sums = pearson_from_sums(&sums);
            assert!(
                f32_ulp_distance(rho_concat, rho_sums) <= 1,
                "sample {}: concat {} vs sums {}",
                s.sample_id,
                rho_concat,
                rho_sums
            );
        }
    }

    #[test]
    fn merge_is_deterministic_and_split_invariance_holds_to_1_ulp() {
        let (_ctx, problem, samples) = setup();
        let prob = problem.value();
        let backend = NativeBackend;
        let mut arena = TaskArena::new();
        let s = &samples[0];
        backend.cross_map_into(&prob.input_for(s), &mut arena);
        let preds = arena.preds.clone();
        let whole = PearsonSums::from_slices(&preds, &prob.targets);
        for parts in [2usize, 3, 7] {
            let bounds = shard_bounds(preds.len(), parts);
            let partials: Vec<PearsonSums> = bounds
                .iter()
                .map(|&(lo, hi)| {
                    PearsonSums::from_slices(&preds[lo..hi], &prob.targets[lo..hi])
                })
                .collect();
            let merged = PearsonSums::merge_all(&partials);
            // merging the same ordered partials twice is bit-identical
            assert_eq!(merged, PearsonSums::merge_all(&partials));
            assert_eq!(merged.n, whole.n);
            // a different split changes the grouping of the compensated
            // sums, so only rho-level agreement is guaranteed
            assert!(
                f32_ulp_distance(pearson_from_sums(&merged), pearson_from_sums(&whole)) <= 1,
                "{parts} parts"
            );
        }
    }

    #[test]
    fn sharded_agg_mode_matches_driver_concat_within_1_ulp() {
        let (ctx, problem, samples) = setup();
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend);
        let n = problem.value().emb.n;
        let mode = TableMode::Truncated { prefix: DistanceTable::auto_prefix(n, 150) };

        for shards in [1usize, 3, 7] {
            let sharded = sharded_table_pipeline_mode(&ctx, &problem, 4, mode, shards);

            let rdd = ctx.parallelize_with(samples.clone(), 4);
            let mut chunks = Vec::new();
            for chunk_rdd in
                sharded_transform_rdds(&ctx, &rdd, &problem, &sharded, Arc::clone(&backend))
            {
                chunks.extend(ctx.collect(&chunk_rdd));
            }
            let want = combine_shard_chunks(chunks, problem.value());

            let rdd = ctx.parallelize_with(samples.clone(), 4);
            let mut sums = Vec::new();
            for sums_rdd in
                sharded_agg_rdds(&ctx, &rdd, &problem, &sharded, Arc::clone(&backend))
            {
                sums.extend(ctx.collect(&sums_rdd));
            }
            let got = combine_shard_sums(sums, problem.value(), backend.as_ref());

            assert_eq!(got.len(), want.len(), "{shards} shards");
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.sample_id, b.sample_id);
                assert_eq!(a.params, b.params);
                assert!(
                    f32_ulp_distance(a.rho, b.rho) <= 1,
                    "{shards} shards sample {}: concat {} vs worker-reduce {}",
                    a.sample_id,
                    a.rho,
                    b.rho
                );
            }
        }
    }

    #[test]
    fn agg_jobs_depend_on_their_own_shard_only() {
        let (ctx, problem, samples) = setup();
        let sharded = sharded_table_pipeline_mode(&ctx, &problem, 4, TableMode::Full, 3);
        let rdd = ctx.parallelize_with(samples, 4);
        let sums_rdds = sharded_agg_rdds(&ctx, &rdd, &problem, &sharded, Arc::new(NativeBackend));
        for r in &sums_rdds {
            let _ = ctx.collect(r);
        }
        let jobs = ctx.events().jobs();
        let agg_jobs: Vec<_> = jobs.iter().filter(|j| j.name.contains(".agg")).collect();
        assert_eq!(agg_jobs.len(), 3);
        for (s, job) in agg_jobs.iter().enumerate() {
            let b = &sharded.shards()[s];
            assert_eq!(job.name, format!("table_shard_{s}.agg"));
            assert_eq!(job.broadcast_deps.len(), 2, "problem + own shard only");
            assert!(job.broadcast_deps.contains(&(b.id(), b.size_bytes())));
        }
    }

    #[test]
    fn combine_sums_rejects_duplicate_and_missing_partials() {
        let (_ctx, problem, samples) = setup();
        let prob = problem.value();
        let table = DistanceTable::build(&prob.emb);
        let sharded = table.shard(2);
        let backend = NativeBackend;
        let mut arena = TaskArena::new();
        let s = &samples[0];
        let chunk_for = |shard_idx: usize, arena: &mut TaskArena| {
            let shard = &sharded.shards()[shard_idx];
            SumsChunk {
                params: s.params,
                sample_id: s.sample_id,
                shard_id: shard.shard_id,
                sums: backend.agg_chunk_into(
                    shard,
                    &prob.targets,
                    prob.theiler,
                    &s.rows,
                    s.params.e,
                    arena,
                ),
            }
        };
        let c0 = chunk_for(0, &mut arena);
        let c1 = chunk_for(1, &mut arena);

        // complete coverage combines fine
        let ok = combine_shard_sums(vec![c1, c0], prob, &backend);
        assert_eq!(ok.len(), 1);

        // a missing partial must not silently pass
        let missing = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            combine_shard_sums(vec![c0], prob, &backend)
        }));
        assert!(missing.is_err(), "missing shard partial must panic");

        // a double-counted (requeued twice) partial must not silently pass
        let dup = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            combine_shard_sums(vec![c0, c1, c1], prob, &backend)
        }));
        assert!(dup.is_err(), "duplicate shard partial must panic");
    }

    #[test]
    fn partial_spec_parses_the_cli_grammar_and_rejects_garbage() {
        assert_eq!(
            PartialSpec::parse("0.05,0.95"),
            Some(PartialSpec { eps: 0.05, conf: 0.95 })
        );
        assert_eq!(
            PartialSpec::parse(" 0.1 , 0.9 "),
            Some(PartialSpec { eps: 0.1, conf: 0.9 })
        );
        for bad in ["", "0.05", "0.05;0.95", "0,0.95", "-1,0.95", "0.05,0", "0.05,1", "0.05,1.5", "x,y", "0.05,0.95,3"] {
            assert!(PartialSpec::parse(bad).is_none(), "{bad:?} must not parse");
        }
    }

    #[test]
    fn normal_quantile_matches_known_critical_values() {
        // the textbook two-sided critical values
        assert!((normal_quantile(0.975) - 1.959964).abs() < 1e-5);
        assert!((normal_quantile(0.95) - 1.644854).abs() < 1e-5);
        assert!((normal_quantile(0.995) - 2.575829).abs() < 1e-5);
        assert_eq!(normal_quantile(0.5), 0.0);
        // symmetry, including through the tail branches
        for p in [0.001, 0.01, 0.3, 0.7, 0.99, 0.999] {
            assert!(
                (normal_quantile(p) + normal_quantile(1.0 - p)).abs() < 1e-9,
                "asymmetric at {p}"
            );
        }
        // monotone across the branch joins
        let mut last = f64::NEG_INFINITY;
        for i in 1..1000 {
            let q = normal_quantile(i as f64 / 1000.0);
            assert!(q > last);
            last = q;
        }
    }

    #[test]
    fn bounded_rho_tightens_and_decides() {
        let spec = PartialSpec { eps: 0.05, conf: 0.95 };
        let mut ev = BoundedRho::new();
        assert_eq!(ev.mean(), 0.0);
        assert_eq!(ev.stderr(), 0.0);
        assert!(!ev.decided(&spec), "empty evaluator must not decide");
        ev.observe(0.8);
        assert!((ev.mean() - 0.8f32 as f64).abs() < 1e-12);
        assert!(!ev.decided(&spec), "one observation has no variance estimate");
        // identical low-variance observations: decided once past the floor
        for i in 1..MIN_PARTIAL_OBS {
            ev.observe(if i % 2 == 0 { 0.80 } else { 0.81 });
            if i + 1 < MIN_PARTIAL_OBS {
                assert!(!ev.decided(&spec), "below MIN_PARTIAL_OBS at n={}", i + 1);
            }
        }
        assert_eq!(ev.n(), MIN_PARTIAL_OBS);
        assert!(ev.decided(&spec), "tight cluster of rho must decide at the floor");
        assert!(ev.radius(spec.z()) <= spec.eps);

        // wildly scattered observations must NOT decide at the floor
        let mut noisy = BoundedRho::new();
        for i in 0..MIN_PARTIAL_OBS {
            noisy.observe(if i % 2 == 0 { 0.1 } else { 0.9 });
        }
        assert!(!noisy.decided(&spec), "scattered rho must keep the cell running");
        assert!(noisy.radius(spec.z()) > spec.eps);
    }

    #[test]
    fn bounded_rho_mean_tracks_plain_mean() {
        let rhos: Vec<f32> = (0..40).map(|i| 0.5 + 0.01 * (i % 7) as f32).collect();
        let mut ev = BoundedRho::new();
        for &r in &rhos {
            ev.observe(r);
        }
        let plain: f64 = rhos.iter().map(|&r| r as f64).sum::<f64>() / rhos.len() as f64;
        assert!((ev.mean() - plain).abs() < 1e-12);
        // stderr agrees with the direct (n-1) formula
        let var: f64 = rhos
            .iter()
            .map(|&r| {
                let d = r as f64 - plain;
                d * d
            })
            .sum::<f64>()
            / (rhos.len() as f64 - 1.0);
        let want = (var / rhos.len() as f64).sqrt();
        assert!((ev.stderr() - want).abs() < 1e-12, "{} vs {}", ev.stderr(), want);
    }

    #[test]
    fn broadcast_deps_recorded_for_des() {
        let (ctx, problem, samples) = setup();
        let table = table_pipeline(&ctx, &problem, 4);
        let rdd = ctx.parallelize_with(samples, 4);
        let out = table_transform_rdd(&ctx, rdd, &problem, &table, Arc::new(NativeBackend));
        let _ = ctx.collect(&out);
        let jobs = ctx.events().jobs();
        let last = jobs.last().unwrap();
        assert_eq!(last.broadcast_deps.len(), 2, "problem + table deps expected");
        assert!(last.broadcast_deps.iter().any(|(id, _)| *id == table.id()));
    }
}
