//! The paper's two parallel pipelines, expressed on the engine.
//!
//! * [`ccm_transform_rdd`] — §3.1: transform an RDD of library subsamples
//!   into an RDD of prediction skills (brute-force k-NN inside each task).
//! * [`table_pipeline`] / [`table_transform_rdd`] — §3.2: build the
//!   distance indexing table in parallel over manifold-row chunks,
//!   broadcast it, then run the CCM transform as cheap table lookups.
//!
//! Both return *lazy* RDDs; the driver chooses blocking (`collect`) or
//! asynchronous (`collect_async`) submission — §3.3.

use std::sync::Arc;

use crate::ccm::backend::{ComputeBackend, CrossMapInput};
use crate::ccm::embedding::Embedding;
use crate::ccm::result::SkillRow;
use crate::ccm::subsample::LibrarySample;
use crate::ccm::table::{library_mask, DistanceTable};
use crate::engine::{Broadcast, Context, Rdd};
use crate::EMAX;

/// The cross-mapping problem shared by every task: the effect-series
/// shadow manifold and the cause-series targets aligned to it.
pub struct CcmProblem {
    pub emb: Embedding,
    /// Cause value at each manifold row's time.
    pub targets: Vec<f32>,
    /// Theiler exclusion radius (0 = self only).
    pub theiler: f32,
}

impl CcmProblem {
    pub fn new(effect: &[f32], cause: &[f32], e: usize, tau: usize, theiler: f32) -> CcmProblem {
        let emb = Embedding::new(effect, e, tau);
        let targets = emb.align_targets(cause);
        CcmProblem { emb, targets, theiler }
    }

    pub fn size_bytes(&self) -> usize {
        self.emb.size_bytes() + self.targets.len() * 4
    }

    /// Assemble the brute-force [`CrossMapInput`] for one library sample.
    pub fn input_for(&self, sample: &LibrarySample) -> CrossMapInput {
        let l = sample.rows.len();
        let mut lib_vecs = Vec::with_capacity(l * EMAX);
        let mut lib_targets = Vec::with_capacity(l);
        let mut lib_times = Vec::with_capacity(l);
        for &row in &sample.rows {
            lib_vecs.extend_from_slice(self.emb.point(row));
            lib_targets.push(self.targets[row]);
            lib_times.push(self.emb.time_of(row) as f32);
        }
        CrossMapInput {
            lib_vecs,
            lib_targets,
            lib_times,
            pred_vecs: self.emb.vecs.clone(),
            pred_targets: self.targets.clone(),
            pred_times: (0..self.emb.n).map(|i| self.emb.time_of(i) as f32).collect(),
            e: sample.params.e,
            theiler: self.theiler,
        }
    }
}

/// §3.1 — the CCM transform pipeline: subsamples -> prediction skills via
/// brute-force k-NN + simplex inside each task.
pub fn ccm_transform_rdd(
    _ctx: &Context,
    samples: Rdd<LibrarySample>,
    problem: &Broadcast<CcmProblem>,
    backend: Arc<dyn ComputeBackend>,
) -> Rdd<SkillRow> {
    let problem = problem.clone();
    samples
        .uses_broadcast(&problem)
        .map_partitions(move |_p, samples| {
            let prob = problem.value();
            samples
                .into_iter()
                .map(|s| {
                    let input = prob.input_for(&s);
                    let out = backend.cross_map(&input);
                    SkillRow { params: s.params, sample_id: s.sample_id, rho: out.rho }
                })
                .collect()
        })
}

/// §3.2 (construction) — build the distance indexing table in parallel:
/// one task per chunk of manifold rows, each computing its rows' sorted
/// neighbour lists; the driver assembles and broadcasts.
///
/// Blocking (the table is a hard dependency of its transform jobs); the
/// asynchronous driver overlaps *different* (E, tau) tables instead.
pub fn table_pipeline(
    ctx: &Context,
    problem: &Broadcast<CcmProblem>,
    partitions: usize,
) -> Broadcast<DistanceTable> {
    let n = problem.value().emb.n;
    let rows_rdd = ctx.parallelize_with((0..n).collect::<Vec<usize>>(), partitions);
    let prob = problem.clone();
    let sorted = rows_rdd.uses_broadcast(&prob).map_partitions(move |_p, rows| {
        let emb = &prob.value().emb;
        rows.into_iter()
            .map(|i| (i, DistanceTable::sorted_row(emb, i)))
            .collect()
    });
    let mut rows: Vec<(usize, Vec<u32>)> = ctx.collect(&sorted);
    rows.sort_by_key(|(i, _)| *i);
    let table = DistanceTable::assemble(
        &problem.value().emb,
        rows.into_iter().map(|(_, r)| r).collect(),
    );
    let size = table.size_bytes();
    ctx.broadcast(table, size)
}

/// §3.2 (use) — the CCM transform pipeline with the broadcast table:
/// k-NN becomes a filtered walk of the precomputed sorted lists, then the
/// simplex/Pearson tail runs on the backend.
pub fn table_transform_rdd(
    _ctx: &Context,
    samples: Rdd<LibrarySample>,
    problem: &Broadcast<CcmProblem>,
    table: &Broadcast<DistanceTable>,
    backend: Arc<dyn ComputeBackend>,
) -> Rdd<SkillRow> {
    let problem = problem.clone();
    let table = table.clone();
    samples
        .uses_broadcast(&problem)
        .uses_broadcast(&table)
        .map_partitions(move |_p, samples| {
            let prob = problem.value();
            let tab = table.value();
            samples
                .into_iter()
                .map(|s| {
                    let (mask, target_of) = library_mask(tab.n, &s.rows, &prob.targets);
                    let panels = tab.query_all(&mask, &target_of, prob.theiler);
                    let out = backend.simplex_tail(&panels, &prob.targets, s.params.e);
                    SkillRow { params: s.params, sample_id: s.sample_id, rho: out.rho }
                })
                .collect()
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ccm::params::CcmParams;
    use crate::ccm::subsample::draw_samples;
    use crate::engine::{Deploy, EngineConfig};
    use crate::native::NativeBackend;
    use crate::timeseries::generators::{coupled_logistic, CoupledLogisticParams};
    use crate::util::rng::Rng;

    fn setup() -> (Context, Broadcast<CcmProblem>, Vec<LibrarySample>) {
        let ctx = Context::new(
            EngineConfig::new(Deploy::Local { cores: 2 }).with_default_parallelism(4),
        );
        let (x, y) = coupled_logistic(400, CoupledLogisticParams::default());
        let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
        let size = problem.size_bytes();
        let b = ctx.broadcast(problem, size);
        let samples = draw_samples(&Rng::new(9), CcmParams::new(2, 1, 150), 399, 12);
        (ctx, b, samples)
    }

    #[test]
    fn transform_pipeline_produces_skill_rows() {
        let (ctx, problem, samples) = setup();
        let rdd = ctx.parallelize_with(samples, 4);
        let skills = ctx.collect(&ccm_transform_rdd(&ctx, rdd, &problem, Arc::new(NativeBackend)));
        assert_eq!(skills.len(), 12);
        // coupled system: every realization should show solid skill
        assert!(skills.iter().all(|s| s.rho > 0.5), "{:?}", skills.iter().map(|s| s.rho).collect::<Vec<_>>());
        // sample ids all present
        let mut ids: Vec<usize> = skills.iter().map(|s| s.sample_id).collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..12).collect::<Vec<_>>());
    }

    #[test]
    fn table_mode_equals_bruteforce_mode() {
        // §3.2 is an optimization, not an approximation: identical rho.
        let (ctx, problem, samples) = setup();
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend);
        let rdd = ctx.parallelize_with(samples.clone(), 4);
        let brute = ctx.collect(&ccm_transform_rdd(&ctx, rdd, &problem, Arc::clone(&backend)));

        let table = table_pipeline(&ctx, &problem, 4);
        let rdd2 = ctx.parallelize_with(samples, 4);
        let tabled =
            ctx.collect(&table_transform_rdd(&ctx, rdd2, &problem, &table, backend));

        assert_eq!(brute.len(), tabled.len());
        for (a, b) in brute.iter().zip(&tabled) {
            assert_eq!(a.sample_id, b.sample_id);
            assert!(
                (a.rho - b.rho).abs() < 1e-5,
                "sample {}: brute {} vs table {}",
                a.sample_id,
                a.rho,
                b.rho
            );
        }
    }

    #[test]
    fn broadcast_deps_recorded_for_des() {
        let (ctx, problem, samples) = setup();
        let table = table_pipeline(&ctx, &problem, 4);
        let rdd = ctx.parallelize_with(samples, 4);
        let out = table_transform_rdd(&ctx, rdd, &problem, &table, Arc::new(NativeBackend));
        let _ = ctx.collect(&out);
        let jobs = ctx.events().jobs();
        let last = jobs.last().unwrap();
        assert_eq!(last.broadcast_deps.len(), 2, "problem + table deps expected");
        assert!(last.broadcast_deps.iter().any(|(id, _)| *id == table.id()));
    }
}
