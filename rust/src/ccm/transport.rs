//! Worker transports: how framed line-JSON messages move between the
//! driver and a worker process, independent of *what* the messages say.
//!
//! The wire format itself (message kinds, broadcasts, tasks) lives in
//! [`crate::ccm::cluster`]; this module owns the byte layer under it:
//!
//! * [`Transport`] — framed send/recv of one JSON object per line, with
//!   death detection folded into `std::io` errors (EOF / broken pipe /
//!   connection reset all surface as `Err` or `Ok(None)` and mean "the
//!   peer is gone").
//! * [`PipeTransport`] — the original fork + stdio transport: the worker
//!   is a child of the driver and speaks on its stdin/stdout.
//! * [`TcpTransport`] — a TCP transport: for spawned workers the driver
//!   binds an ephemeral listener and the child dials back
//!   (`parccm worker --connect 127.0.0.1:PORT`); for pre-started remote
//!   workers the driver dials out to `parccm worker --listen HOST:PORT`
//!   ([`connect_remote`]). The same versioned wire protocol rides on the
//!   socket, so pipe and TCP results are bit-identical (asserted in
//!   `tests/integration_cluster.rs` / `tests/integration_remote.rs`).
//! * Connection lifecycle — [`connect_worker`] spawns + handshakes a
//!   worker over either transport, [`connect_remote`] dials a pre-started
//!   listener; [`negotiate_hello`] is the pure version-negotiation step
//!   and [`verify_worker_auth`] the pure auth step, both unit-testable
//!   with doctored handshakes.
//!
//! # Version negotiation
//!
//! The worker's first message is a `hello` advertising the highest wire
//! version it speaks. The driver accepts any version in
//! [`MIN_WIRE_VERSION`]..=[`WIRE_VERSION`] and runs the connection at the
//! *minimum* of the two sides (a v1 worker simply never receives v2-only
//! messages such as `evict`). Anything outside the range is a clean,
//! immediate error naming both sides' versions — never a hang and never a
//! silent requeue loop (the regression tests doctor the advertised
//! version via `PARCCM_TEST_HELLO_V`, a child-env test seam).
//!
//! # Authenticated handshake (v3)
//!
//! With a shared secret configured (`--auth-token` / `PARCCM_AUTH_TOKEN`),
//! the worker's hello carries an `auth` field and the driver answers a
//! matching `hello_ack` — each side proves knowledge of the token to the
//! other before any broadcast or task moves. A mismatch is a clean named
//! error on *both* ends: the driver refuses the connection and sends the
//! worker a `reject` naming the failure before hanging up. The token is
//! compared in plain text on the wire: it is accident protection (a
//! driver pointed at the wrong cluster, a stray scanner hitting a listen
//! port), not cryptography — run real deployments on a trusted network.
//!
//! # Checksummed frames (v4)
//!
//! On connections negotiated at v4+, every post-handshake frame carries a
//! trailing `#` + 16-lowercase-hex FNV-1a checksum of the payload bytes
//! ([`append_checksum`] / [`verify_frame`], applied by wrapping the raw
//! transport in a [`ChecksumTransport`] once the hello/`hello_ack`
//! exchange settles the version). A frame whose suffix is missing,
//! malformed, or disagrees with the payload is *never* parsed as a
//! message: the receiver surfaces `InvalidData`, counts it (the driver's
//! `corrupt_frames_detected` counter), and kills the connection, which
//! flows into the existing death → requeue/repair/rejoin machinery. The
//! handshake itself is un-checksummed on every version (the first frame
//! arrives before the version is known), and v≤3 peers never see or are
//! asked for checksums.
//!
//! # Binary frames (v6)
//!
//! On connections negotiated at v6+ every post-handshake message rides a
//! *length-prefixed binary frame* instead of a JSON line:
//!
//! ```text
//! [len: u32 LE] [tag: u8] [payload: len-1 bytes]
//! ```
//!
//! The tag and payload encodings live in [`crate::ccm::binwire`]; this
//! module owns only the byte layer — [`Transport::send_frame`] /
//! [`Transport::recv_frame`] frame and de-frame bodies, and
//! [`ChecksumTransport`] protects each body with a trailing 8-byte LE
//! FNV-1a checksum ([`append_frame_checksum`] / [`verify_binary_frame`],
//! the binary analogue of the v4 text suffix; the *length prefix* is not
//! covered, so a corrupted prefix surfaces as either an over-limit length
//! or a mis-framed body whose checksum cannot verify — both `InvalidData`,
//! both counted). [`TcpTransport::recv_frame`] accumulates into the same
//! persistent partial buffer as `recv_line`, so a recv-deadline timeout
//! mid-frame resumes cleanly and bytes buffered while the line-mode
//! handshake ran stay visible. The handshake itself is always line JSON
//! (the version is unknown until it completes); a v≤5 peer keeps the
//! byte-identical JSON wire for the life of the connection.

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::path::Path;
use std::process::{Child, ChildStdin, ChildStdout, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Highest protocol version this build speaks; bumped on any incompatible
/// message change. v2 added the `evict` message and the capability-carrying
/// hello (`transport`, `caps` fields); v3 added the authenticated
/// handshake (`auth` in hello, `hello_ack`, `reject`) and the keepalive
/// `ping`/`pong` pair; v4 added the per-frame FNV-1a checksum suffix; v5
/// added the worker-side-reduce task kinds `agg_chunk` and `merge_sums`
/// (partial Pearson sums instead of raw predictions); v6 moved every
/// post-handshake message onto length-prefixed binary frames (raw LE
/// arrays for payloads, JSON-in-envelope for control); v7 added the
/// client-role hello (`role` field) and the serve-mode control messages
/// `submit`/`status`/`fetch`/`cancel` — plain JSON envelopes, so v6
/// binary framing carries them unchanged.
pub const WIRE_VERSION: u64 = 7;

/// Oldest protocol version the driver still accepts. Older workers are
/// served without newer-version traffic (no `evict`/`hello_ack`/`ping`).
pub const MIN_WIRE_VERSION: u64 = 1;

/// First wire version that understands `evict`.
pub const EVICT_WIRE_VERSION: u64 = 2;

/// First wire version that understands `hello_ack`, `reject`, and the
/// keepalive `ping`/`pong` pair.
pub const KEEPALIVE_WIRE_VERSION: u64 = 3;

/// First wire version whose post-handshake frames carry the trailing
/// FNV-1a checksum suffix. Connections negotiated below this run exactly
/// the v3 byte streams (pinned by the doctored-handshake test).
pub const CHECKSUM_WIRE_VERSION: u64 = 4;

/// First wire version that understands the worker-side-reduce task kinds
/// `agg_chunk` (fold a shard chunk into partial Pearson sums) and
/// `merge_sums` (merge ordered partials). Peers below this never receive
/// either op — the driver silently keeps their results on the
/// driver-concat path, which is bit-for-bit the v4 behaviour.
pub const AGG_WIRE_VERSION: u64 = 5;

/// First wire version whose post-handshake traffic is length-prefixed
/// binary frames (see the module docs and [`crate::ccm::binwire`]).
/// Connections negotiated below this run the line-JSON wire byte for
/// byte as before — one legacy peer pins only its own connection, never
/// the pool.
pub const BINARY_WIRE_VERSION: u64 = 6;

/// First wire version whose hello may carry a `role` field and whose
/// connections may speak the serve-mode control messages (`submit` /
/// `status` / `fetch` / `cancel`). Workers never see these: the role is
/// declared at handshake time and a `parccm serve` daemon routes
/// `role:"client"` connections to the job tracker instead of the pool.
pub const SERVE_WIRE_VERSION: u64 = 7;

/// Per-write deadline on every TCP connection. A *frozen* peer (SIGSTOP,
/// livelocked host) keeps its sockets open while its kernel buffers fill;
/// without a send deadline a large broadcast ship to it would block the
/// sender forever — a wedge the recv-side lease polling can never see.
/// With it, the stalled write errors out and the normal death → requeue
/// machinery takes over. Generous on purpose: a healthy peer drains even
/// multi-megabyte ships in well under a second.
pub const TCP_WRITE_DEADLINE: Duration = Duration::from_secs(30);

/// How long the driver waits for a spawned TCP worker to dial back before
/// declaring the spawn failed (keeps a broken worker from hanging CI).
pub const TCP_ACCEPT_TIMEOUT: Duration = Duration::from_secs(10);

/// How long [`connect_remote`] waits for a listening remote worker to
/// accept before declaring it unreachable.
pub const REMOTE_CONNECT_TIMEOUT: Duration = Duration::from_secs(10);

/// The (shorter) deadline rejoin redials use on the same path: a redial
/// is speculative by construction — the address is *known* dead until
/// proven otherwise — so a half-open peer must stall only its own probe,
/// never the maintenance thread's whole round.
pub const REJOIN_CONNECT_TIMEOUT: Duration = Duration::from_secs(2);

/// Environment variable both sides read the shared auth token from when
/// no explicit `--auth-token` is given. The driver also exports it to the
/// workers it forks, so local pools authenticate transparently.
pub const AUTH_TOKEN_ENV: &str = "PARCCM_AUTH_TOKEN";

/// Resolve the shared auth token: explicit value, else [`AUTH_TOKEN_ENV`].
pub fn resolve_auth_token(explicit: Option<&str>) -> Option<String> {
    match explicit {
        Some(t) if !t.is_empty() => Some(t.to_string()),
        _ => std::env::var(AUTH_TOKEN_ENV).ok().filter(|t| !t.is_empty()),
    }
}

/// Which byte layer a worker connection uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum TransportKind {
    /// Forked child, line-JSON on its stdin/stdout (the PR 2 transport).
    #[default]
    Pipe,
    /// Forked child dialing back over TCP loopback; same wire protocol.
    Tcp,
}

impl TransportKind {
    /// Stable name used in hello messages, CLI flags, and logs.
    pub fn name(self) -> &'static str {
        match self {
            TransportKind::Pipe => "pipe",
            TransportKind::Tcp => "tcp",
        }
    }

    /// Parse a `--transport` value.
    pub fn parse(s: &str) -> Option<TransportKind> {
        match s {
            "pipe" => Some(TransportKind::Pipe),
            "tcp" => Some(TransportKind::Tcp),
            _ => None,
        }
    }
}

/// One framed line-JSON connection to a worker. Implementations must fold
/// peer death into the return values: a broken connection is an `Err` on
/// send, and `Ok(None)` (clean EOF) or `Err` on receive — the scheduler
/// treats all three as "worker gone, requeue its task".
pub trait Transport: Send {
    /// Ship one pre-serialized JSON object (no trailing newline) and flush.
    fn send_line(&mut self, line: &str) -> std::io::Result<()>;

    /// Receive the next line; `Ok(None)` means the peer closed cleanly.
    fn recv_line(&mut self) -> std::io::Result<Option<String>>;

    /// Which byte layer this is (for logs and hello messages).
    fn kind(&self) -> TransportKind;

    /// Bound how long the next `recv_line` may block (`None` = forever).
    /// Returns `Ok(false)` when the byte layer cannot enforce deadlines
    /// (pipes) — callers must then skip deadline-dependent traffic such as
    /// keepalive pings rather than risk blocking the scheduler.
    fn set_recv_deadline(&mut self, _timeout: Option<Duration>) -> std::io::Result<bool> {
        Ok(false)
    }

    /// Ship one v6 binary frame body (tag + payload, *without* the length
    /// prefix — the transport adds it) and flush. The default refuses:
    /// only byte layers that implement framing may carry v6 connections.
    fn send_frame(&mut self, _frame: &[u8]) -> std::io::Result<()> {
        Err(std::io::Error::new(
            std::io::ErrorKind::Unsupported,
            "transport cannot send binary frames",
        ))
    }

    /// Receive the next v6 frame body; `Ok(None)` means the peer closed
    /// cleanly on a frame boundary. Honors the same recv deadline as
    /// `recv_line` where the byte layer supports one.
    fn recv_frame(&mut self) -> std::io::Result<Option<Vec<u8>>> {
        Err(std::io::Error::new(
            std::io::ErrorKind::Unsupported,
            "transport cannot receive binary frames",
        ))
    }
}

/// Upper bound a v6 length prefix may claim. A corrupted prefix is not
/// checksum-protected (the body is), so without a cap it could demand an
/// absurd allocation before the body checksum ever gets a chance to
/// object; anything over the cap is surfaced (and counted) as corruption.
pub const MAX_BINARY_FRAME: usize = 1 << 31;

/// Write one length-prefixed frame: `u32 LE` body length, then the body.
pub(crate) fn write_frame<W: Write>(w: &mut W, frame: &[u8]) -> std::io::Result<()> {
    debug_assert!(!frame.is_empty(), "v6 frames always carry at least a tag byte");
    w.write_all(&(frame.len() as u32).to_le_bytes())?;
    w.write_all(frame)?;
    w.flush()
}

/// Read one length-prefixed frame from a blocking buffered reader (pipe /
/// stdio byte layers — no deadline, so no resumability needed). A clean
/// EOF *before* the first length byte is `Ok(None)`; EOF anywhere inside
/// a frame is `UnexpectedEof` (the peer died mid-send).
pub(crate) fn read_frame<R: BufRead>(r: &mut R) -> std::io::Result<Option<Vec<u8>>> {
    use std::io::Read;
    let mut len_buf = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        let n = r.read(&mut len_buf[got..])?;
        if n == 0 {
            if got == 0 {
                return Ok(None);
            }
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "connection closed inside a frame length prefix",
            ));
        }
        got += n;
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    check_frame_len(len)?;
    let mut body = vec![0u8; len];
    r.read_exact(&mut body).map_err(|e| {
        if e.kind() == std::io::ErrorKind::UnexpectedEof {
            std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "connection closed inside a frame body",
            )
        } else {
            e
        }
    })?;
    Ok(Some(body))
}

fn check_frame_len(len: usize) -> std::io::Result<()> {
    if len == 0 || len > MAX_BINARY_FRAME {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("implausible frame length {len} (corrupt length prefix?)"),
        ));
    }
    Ok(())
}

/// Receive the next non-empty line as parsed JSON; EOF and parse failures
/// become `std::io` errors so callers have a single failure channel.
pub fn recv_json(t: &mut dyn Transport) -> std::io::Result<Json> {
    recv_json_counted(t).map(|(msg, _)| msg)
}

/// [`recv_json`] plus the received line's byte count (the payload as the
/// transport surfaced it — checksum suffix already stripped on v4+
/// connections — plus one for the newline). The driver's result-ingress
/// accounting reads the count for accepted `result` frames.
pub fn recv_json_counted(t: &mut dyn Transport) -> std::io::Result<(Json, u64)> {
    loop {
        match t.recv_line()? {
            None => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "worker closed its connection",
                ))
            }
            Some(line) if line.trim().is_empty() => continue,
            Some(line) => {
                let bytes = line.trim_end_matches(['\r', '\n']).len() as u64 + 1;
                return Json::parse(&line)
                    .map(|msg| (msg, bytes))
                    .map_err(|e| {
                        std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
                    });
            }
        }
    }
}

fn read_line_opt<R: BufRead>(r: &mut R) -> std::io::Result<Option<String>> {
    let mut line = String::new();
    if r.read_line(&mut line)? == 0 {
        Ok(None)
    } else {
        Ok(Some(line))
    }
}

/// Length of the v4 frame suffix: `#` plus 16 lowercase hex digits.
pub const FRAME_CHECKSUM_LEN: usize = 17;

/// Byte-wise FNV-1a over the frame payload. The per-byte step
/// `h → (h ^ b) * prime` multiplies by an odd (hence invertible mod 2^64)
/// prime, so two payloads differing in a single byte at the same position
/// can never collide — the property test in `tests/prop_wire_checksum.rs`
/// leans on exactly this.
pub fn frame_checksum(payload: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for &b in payload {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Frame a payload for a v4 connection: payload + `#` + 16-hex checksum.
pub fn append_checksum(line: &str) -> String {
    format!("{line}#{:016x}", frame_checksum(line.as_bytes()))
}

/// Validate a v4 frame and return its payload. The suffix is parsed
/// strictly — exactly [`FRAME_CHECKSUM_LEN`] trailing bytes, a literal
/// `#`, then 16 *lowercase* hex digits (no signs, no uppercase, no
/// shorter forms a lenient integer parse would accept) — so a flipped
/// byte anywhere in the frame can never still read as a valid message.
pub fn verify_frame(frame: &str) -> Result<&str, String> {
    let frame = frame.trim_end_matches(['\r', '\n']);
    let bytes = frame.as_bytes();
    if bytes.len() < FRAME_CHECKSUM_LEN + 1 {
        return Err(format!("frame too short for a checksum suffix ({} bytes)", bytes.len()));
    }
    let split = bytes.len() - FRAME_CHECKSUM_LEN;
    if bytes[split] != b'#' {
        return Err("frame carries no checksum suffix".into());
    }
    let mut want: u64 = 0;
    for &c in &bytes[split + 1..] {
        let nibble = match c {
            b'0'..=b'9' => c - b'0',
            b'a'..=b'f' => c - b'a' + 10,
            _ => return Err("checksum suffix is not 16 lowercase hex digits".into()),
        };
        want = (want << 4) | u64::from(nibble);
    }
    // `split` indexes the ascii '#', so it is a valid char boundary even
    // if corruption put multi-byte sequences elsewhere in the frame
    let body = &frame[..split];
    let got = frame_checksum(body.as_bytes());
    if got != want {
        return Err(format!("checksum mismatch: frame says {want:016x}, payload hashes to {got:016x}"));
    }
    Ok(body)
}

/// Length of the v6 binary frame trailer: the raw 8-byte LE FNV-1a hash
/// (binary frames need no `#` sentinel — the length prefix already says
/// where the body ends).
pub const FRAME_BIN_CHECKSUM_LEN: usize = 8;

/// Frame a v6 body for the wire: body + 8-byte LE FNV-1a over the body.
pub fn append_frame_checksum(body: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(body.len() + FRAME_BIN_CHECKSUM_LEN);
    out.extend_from_slice(body);
    out.extend_from_slice(&frame_checksum(body).to_le_bytes());
    out
}

/// Validate a checksummed v6 frame and return its body. Same single-byte
/// detection guarantee as the text-mode [`verify_frame`]: the trailer
/// must match the body byte for byte, and a frame too short to even carry
/// a trailer (a truncated or mis-framed read) is corruption, not a parse.
pub fn verify_binary_frame(frame: &[u8]) -> Result<&[u8], String> {
    if frame.len() < FRAME_BIN_CHECKSUM_LEN + 1 {
        return Err(format!("frame too short for a checksum trailer ({} bytes)", frame.len()));
    }
    let split = frame.len() - FRAME_BIN_CHECKSUM_LEN;
    let mut trailer = [0u8; FRAME_BIN_CHECKSUM_LEN];
    trailer.copy_from_slice(&frame[split..]);
    let want = u64::from_le_bytes(trailer);
    let got = frame_checksum(&frame[..split]);
    if got != want {
        return Err(format!(
            "checksum mismatch: frame says {want:016x}, payload hashes to {got:016x}"
        ));
    }
    Ok(&frame[..split])
}

/// v4 framing layer: checksums every outbound line and verifies every
/// inbound one, surfacing corruption as `InvalidData` (optionally tallied
/// into the driver's `corrupt_frames_detected` counter). Wrapped
/// *outermost* — around any chaos-injection layer — so injected
/// corruption is seen by the peer's verify, not silently re-checksummed.
pub struct ChecksumTransport {
    inner: Box<dyn Transport>,
    tally: Option<Arc<AtomicU64>>,
}

impl ChecksumTransport {
    /// Wrap `inner`; `tally` (when given) counts detected corrupt frames.
    pub fn new(inner: Box<dyn Transport>, tally: Option<Arc<AtomicU64>>) -> ChecksumTransport {
        ChecksumTransport { inner, tally }
    }

    fn count_corrupt(&self) {
        if let Some(t) = &self.tally {
            t.fetch_add(1, Ordering::Relaxed);
        }
    }
}

impl Transport for ChecksumTransport {
    fn send_line(&mut self, line: &str) -> std::io::Result<()> {
        self.inner.send_line(&append_checksum(line))
    }

    fn recv_line(&mut self) -> std::io::Result<Option<String>> {
        match self.inner.recv_line() {
            Ok(None) => Ok(None),
            Ok(Some(frame)) => {
                if frame.trim().is_empty() {
                    return Ok(Some(frame)); // blank keepalive lines carry nothing to protect
                }
                match verify_frame(&frame) {
                    Ok(body) => Ok(Some(body.to_string())),
                    Err(why) => {
                        self.count_corrupt();
                        Err(std::io::Error::new(
                            std::io::ErrorKind::InvalidData,
                            format!("corrupt frame: {why}"),
                        ))
                    }
                }
            }
            Err(e) => {
                // invalid UTF-8 on the wire is corruption too (the byte
                // layer refuses to even hand the frame up)
                if e.kind() == std::io::ErrorKind::InvalidData {
                    self.count_corrupt();
                }
                Err(e)
            }
        }
    }

    fn kind(&self) -> TransportKind {
        self.inner.kind()
    }

    fn set_recv_deadline(&mut self, timeout: Option<Duration>) -> std::io::Result<bool> {
        self.inner.set_recv_deadline(timeout)
    }

    fn send_frame(&mut self, frame: &[u8]) -> std::io::Result<()> {
        self.inner.send_frame(&append_frame_checksum(frame))
    }

    fn recv_frame(&mut self) -> std::io::Result<Option<Vec<u8>>> {
        match self.inner.recv_frame() {
            Ok(None) => Ok(None),
            Ok(Some(mut frame)) => match verify_binary_frame(&frame) {
                Ok(body) => {
                    let keep = body.len();
                    frame.truncate(keep);
                    Ok(Some(frame))
                }
                Err(why) => {
                    self.count_corrupt();
                    Err(std::io::Error::new(
                        std::io::ErrorKind::InvalidData,
                        format!("corrupt frame: {why}"),
                    ))
                }
            },
            Err(e) => {
                // an implausible length prefix is corruption the byte
                // layer refuses to even hand up — count it the same way
                if e.kind() == std::io::ErrorKind::InvalidData {
                    self.count_corrupt();
                }
                Err(e)
            }
        }
    }
}

/// Fork + stdio transport (driver side): the worker's stdin/stdout pipes.
pub struct PipeTransport {
    stdin: ChildStdin,
    stdout: BufReader<ChildStdout>,
}

impl Transport for PipeTransport {
    fn send_line(&mut self, line: &str) -> std::io::Result<()> {
        self.stdin.write_all(line.as_bytes())?;
        self.stdin.write_all(b"\n")?;
        self.stdin.flush()
    }

    fn recv_line(&mut self) -> std::io::Result<Option<String>> {
        read_line_opt(&mut self.stdout)
    }

    fn kind(&self) -> TransportKind {
        TransportKind::Pipe
    }

    fn send_frame(&mut self, frame: &[u8]) -> std::io::Result<()> {
        write_frame(&mut self.stdin, frame)
    }

    fn recv_frame(&mut self) -> std::io::Result<Option<Vec<u8>>> {
        read_frame(&mut self.stdout)
    }
}

/// TCP transport (either side): a connected stream plus a buffered reader
/// over its clone. `TCP_NODELAY` is set — the protocol is small
/// request/response lines, exactly the shape Nagle's algorithm penalizes.
///
/// `recv_line` accumulates into a persistent partial-line buffer rather
/// than using `BufRead::read_line`: a recv-deadline timeout that lands
/// mid-frame must *keep* the bytes already read so the next call resumes
/// the same line — `read_line` drops them on `Err`, which would shear a
/// frame in half and (on v4 connections) read as phantom corruption.
/// `recv_frame` shares the same partial buffer with the same invariant
/// for v6 binary frames, and because `recv_line` only ever consumes up to
/// its newline, frame bytes the peer pipelined behind the line-mode
/// handshake stay queued for the first `recv_frame`.
pub struct TcpTransport {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
    partial: Vec<u8>,
}

impl TcpTransport {
    /// Wrap an already-connected stream (used by both driver and worker).
    pub fn from_stream(stream: TcpStream) -> std::io::Result<TcpTransport> {
        stream.set_nodelay(true)?;
        stream.set_write_timeout(Some(TCP_WRITE_DEADLINE))?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(TcpTransport { writer: stream, reader, partial: Vec::new() })
    }

    fn take_line(&mut self, end: usize) -> std::io::Result<Option<String>> {
        let rest = self.partial.split_off(end);
        let line = std::mem::replace(&mut self.partial, rest);
        String::from_utf8(line).map(Some).map_err(|e| {
            std::io::Error::new(std::io::ErrorKind::InvalidData, format!("non-UTF-8 frame: {e}"))
        })
    }
}

impl Transport for TcpTransport {
    fn send_line(&mut self, line: &str) -> std::io::Result<()> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()
    }

    fn recv_line(&mut self) -> std::io::Result<Option<String>> {
        loop {
            if let Some(pos) = self.partial.iter().position(|&b| b == b'\n') {
                return self.take_line(pos + 1);
            }
            let taken = {
                let buf = self.reader.fill_buf()?; // timeout Err leaves `partial` intact
                let take = match buf.iter().position(|&b| b == b'\n') {
                    Some(p) => p + 1,
                    None => buf.len(),
                };
                self.partial.extend_from_slice(&buf[..take]);
                take
            };
            self.reader.consume(taken);
            if taken == 0 {
                // EOF: a trailing unterminated line still surfaces
                if self.partial.is_empty() {
                    return Ok(None);
                }
                let end = self.partial.len();
                return self.take_line(end);
            }
        }
    }

    fn kind(&self) -> TransportKind {
        TransportKind::Tcp
    }

    fn set_recv_deadline(&mut self, timeout: Option<Duration>) -> std::io::Result<bool> {
        self.reader.get_ref().set_read_timeout(timeout)?;
        Ok(true)
    }

    fn send_frame(&mut self, frame: &[u8]) -> std::io::Result<()> {
        write_frame(&mut self.writer, frame)
    }

    fn recv_frame(&mut self) -> std::io::Result<Option<Vec<u8>>> {
        loop {
            if self.partial.len() >= 4 {
                let mut len_buf = [0u8; 4];
                len_buf.copy_from_slice(&self.partial[..4]);
                let len = u32::from_le_bytes(len_buf) as usize;
                check_frame_len(len)?;
                if self.partial.len() >= 4 + len {
                    let rest = self.partial.split_off(4 + len);
                    let mut frame = std::mem::replace(&mut self.partial, rest);
                    frame.drain(..4);
                    return Ok(Some(frame));
                }
            }
            let taken = {
                let buf = self.reader.fill_buf()?; // timeout Err leaves `partial` intact
                self.partial.extend_from_slice(buf);
                buf.len()
            };
            self.reader.consume(taken);
            if taken == 0 {
                if self.partial.is_empty() {
                    return Ok(None);
                }
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "connection closed inside a frame",
                ));
            }
        }
    }
}

/// A connected worker plus its transport — what the cluster scheduler
/// leases tasks onto. Spawned workers carry their child-process handle;
/// remote workers (pre-started, reached via [`connect_remote`]) have no
/// child to kill or respawn — their death permanently shrinks the pool.
pub struct WorkerLink {
    /// Child process handle (kill/wait on discard and shutdown); `None`
    /// for remote workers, whose lifecycle the driver does not own.
    pub child: Option<Child>,
    /// The framed connection to it.
    pub transport: Box<dyn Transport>,
    /// OS pid as the worker reports it (observability and kill-recovery
    /// tests; for remote workers this is a pid on the *remote* machine).
    pub pid: u32,
    /// Address dialed for remote workers (diagnostics).
    pub addr: Option<String>,
}

/// The worker's negotiated identity after a successful hello.
#[derive(Clone, Debug)]
pub struct Hello {
    /// Version the connection runs at: `min(worker's, ours)`.
    pub version: u64,
    /// Worker-reported pid (equals the child pid for spawned workers).
    pub pid: u64,
    /// Transport the worker believes it is serving on (v2 hellos).
    pub transport: Option<String>,
    /// Capability strings (v2 hellos; e.g. `"evict"`).
    pub caps: Vec<String>,
    /// Shared-secret token the worker presented (v3 hellos; present iff
    /// the worker was configured with one — presenting a token also means
    /// the worker *requires* the driver to echo it in `hello_ack`).
    pub auth: Option<String>,
    /// Declared peer role (v7 hellos): `"client"` for serve-mode job
    /// clients, absent/anything else for workers. A daemon uses this to
    /// route the connection; the batch driver ignores it.
    pub role: Option<String>,
}

/// Validate a worker hello and negotiate the connection version.
///
/// This is the dedicated version-mismatch failure path: a worker speaking
/// a version outside [`MIN_WIRE_VERSION`]..=[`WIRE_VERSION`] produces an
/// error naming **both** versions, so the operator sees exactly which side
/// is stale instead of a hang or a silent requeue loop.
pub fn negotiate_hello(msg: &Json) -> Result<Hello, String> {
    if msg.get("type").and_then(Json::as_str) != Some("hello") {
        return Err(format!("expected hello handshake, got {msg}"));
    }
    let pid = msg.get("pid").and_then(Json::as_f64).unwrap_or(0.0) as u64;
    let Some(v) = msg.get("v").and_then(Json::as_f64) else {
        return Err(format!("hello from worker pid {pid} carries no wire version: {msg}"));
    };
    let v = v as u64;
    if !(MIN_WIRE_VERSION..=WIRE_VERSION).contains(&v) {
        return Err(format!(
            "wire version mismatch: driver speaks v{MIN_WIRE_VERSION}..v{WIRE_VERSION}, \
             worker pid {pid} speaks v{v} — refusing the connection"
        ));
    }
    let caps = match msg.get("caps") {
        Some(Json::Arr(items)) => items
            .iter()
            .filter_map(|c| c.as_str().map(str::to_string))
            .collect(),
        _ => Vec::new(),
    };
    Ok(Hello {
        version: v.min(WIRE_VERSION),
        pid,
        transport: msg.get("transport").and_then(Json::as_str).map(str::to_string),
        caps,
        auth: msg.get("auth").and_then(Json::as_str).map(str::to_string),
        role: msg.get("role").and_then(Json::as_str).map(str::to_string),
    })
}

/// Validate the worker's presented auth token against the driver's. Pure,
/// so the mismatch wording is unit-testable; the token itself never
/// appears in the error.
pub fn verify_worker_auth(hello: &Hello, driver_token: Option<&str>) -> Result<(), String> {
    match (driver_token, hello.auth.as_deref()) {
        (None, None) => Ok(()),
        (Some(want), Some(got)) if want == got => Ok(()),
        (Some(_), Some(_)) => Err(format!(
            "auth token mismatch: worker pid {} presented a token the driver does not \
             accept — set the same --auth-token / {AUTH_TOKEN_ENV} on both ends",
            hello.pid
        )),
        (Some(_), None) => Err(format!(
            "auth token mismatch: the driver requires a token but worker pid {} presented \
             none — start the worker with --auth-token / {AUTH_TOKEN_ENV}",
            hello.pid
        )),
        (None, Some(_)) => Err(format!(
            "auth token mismatch: worker pid {} requires a token but the driver has none \
             — pass --auth-token / {AUTH_TOKEN_ENV} to the driver",
            hello.pid
        )),
    }
}

/// The driver's half of the v3 handshake: `hello_ack` echoing the shared
/// token (when configured) so the worker can authenticate the driver too.
pub fn hello_ack_payload(auth: Option<&str>) -> String {
    let mut fields = vec![
        ("v", Json::Num(WIRE_VERSION as f64)),
        ("type", Json::Str("hello_ack".into())),
    ];
    if let Some(token) = auth {
        fields.push(("auth", Json::Str(token.to_string())));
    }
    Json::obj(fields).to_string()
}

/// A clean refusal the driver sends before hanging up, so the worker end
/// logs a named error instead of a bare EOF.
pub fn reject_payload(msg: &str) -> String {
    Json::obj(vec![
        ("v", Json::Num(WIRE_VERSION as f64)),
        ("type", Json::Str("reject".into())),
        ("msg", Json::Str(msg.to_string())),
    ])
    .to_string()
}

/// Keepalive probe; the worker answers `{"type":"pong","nonce":N}`.
pub fn ping_payload(nonce: u64) -> String {
    Json::obj(vec![
        ("v", Json::Num(WIRE_VERSION as f64)),
        ("type", Json::Str("ping".into())),
        ("nonce", Json::Num(nonce as f64)),
    ])
    .to_string()
}

/// Complete the driver side of the handshake after version negotiation:
/// authenticate the worker and, on v3+ connections, send the `hello_ack`
/// (a rejected worker is sent a `reject` naming the failure first, so the
/// mismatch is a clean error on both ends).
pub fn finish_handshake(
    transport: &mut dyn Transport,
    hello: &Hello,
    driver_token: Option<&str>,
) -> std::io::Result<()> {
    if hello.version < KEEPALIVE_WIRE_VERSION {
        // legacy workers predate the auth handshake entirely
        if driver_token.is_some() {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                format!(
                    "auth token required but worker pid {} speaks wire v{} \
                     (auth needs v{KEEPALIVE_WIRE_VERSION}+)",
                    hello.pid, hello.version
                ),
            ));
        }
        return Ok(());
    }
    match verify_worker_auth(hello, driver_token) {
        Ok(()) => transport.send_line(&hello_ack_payload(driver_token)),
        Err(msg) => {
            let _ = transport.send_line(&reject_payload(&msg));
            Err(std::io::Error::new(std::io::ErrorKind::PermissionDenied, msg))
        }
    }
}

/// Spawn a worker over `kind` and complete the hello handshake, returning
/// the connected link and the negotiated [`Hello`]. `extra_env` is set on
/// the child only (used by tests to doctor the advertised version); a
/// configured `auth` token is exported to the child so it can present it.
pub fn connect_worker(
    cmd: &Path,
    kind: TransportKind,
    extra_env: &[(String, String)],
    auth: Option<&str>,
) -> std::io::Result<(WorkerLink, Hello)> {
    let mut link = match kind {
        TransportKind::Pipe => spawn_pipe(cmd, extra_env, auth)?,
        TransportKind::Tcp => spawn_tcp(cmd, extra_env, auth)?,
    };
    let handshake = recv_json(link.transport.as_mut())
        .and_then(|msg| {
            negotiate_hello(&msg)
                .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))
        })
        .and_then(|h| finish_handshake(link.transport.as_mut(), &h, auth).map(|()| h));
    match handshake {
        Ok(h) => Ok((link, h)),
        Err(e) => {
            if let Some(child) = link.child.as_mut() {
                let _ = child.kill();
                let _ = child.wait();
            }
            Err(e)
        }
    }
}

/// Dial a pre-started `parccm worker --listen ADDR` and complete the
/// authenticated handshake — the outbound-connect construction behind
/// `--workers-at`. No child process is owned: the returned link's death
/// cannot be repaired by respawning.
pub fn connect_remote(addr: &str, auth: Option<&str>) -> std::io::Result<(WorkerLink, Hello)> {
    connect_remote_deadline(addr, auth, REMOTE_CONNECT_TIMEOUT)
}

/// [`connect_remote`] with an explicit deadline covering both the TCP
/// connect *and* the handshake reads. The handshake deadline matters for
/// rejoin redials ([`REJOIN_CONNECT_TIMEOUT`]): a dial can land in the
/// listen backlog of a worker that will never accept it (e.g. one
/// already serving an abandoned connection), where the connect succeeds
/// but no hello ever arrives — without a read deadline that would wedge
/// the caller forever.
pub fn connect_remote_deadline(
    addr: &str,
    auth: Option<&str>,
    deadline: Duration,
) -> std::io::Result<(WorkerLink, Hello)> {
    let resolved = addr
        .to_socket_addrs()
        .map_err(|e| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                format!("cannot resolve remote worker address '{addr}': {e}"),
            )
        })?
        .next()
        .ok_or_else(|| {
            std::io::Error::new(
                std::io::ErrorKind::InvalidInput,
                format!("remote worker address '{addr}' resolved to nothing"),
            )
        })?;
    let stream = TcpStream::connect_timeout(&resolved, deadline).map_err(|e| {
        std::io::Error::new(
            e.kind(),
            format!(
                "cannot reach remote worker at {addr}: {e} — is `parccm worker \
                 --listen {addr}` running?"
            ),
        )
    })?;
    let mut transport: Box<dyn Transport> = Box::new(TcpTransport::from_stream(stream)?);
    transport.set_recv_deadline(Some(deadline))?;
    let hello = recv_json(transport.as_mut()).and_then(|msg| {
        negotiate_hello(&msg).map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))
    })?;
    finish_handshake(transport.as_mut(), &hello, auth)?;
    transport.set_recv_deadline(None)?;
    let pid = hello.pid as u32;
    Ok((
        WorkerLink { child: None, transport, pid, addr: Some(addr.to_string()) },
        hello,
    ))
}

fn spawn_pipe(
    cmd: &Path,
    extra_env: &[(String, String)],
    auth: Option<&str>,
) -> std::io::Result<WorkerLink> {
    let mut command = Command::new(cmd);
    command.arg("worker").stdin(Stdio::piped()).stdout(Stdio::piped());
    if let Some(token) = auth {
        command.env(AUTH_TOKEN_ENV, token);
    }
    for (k, v) in extra_env {
        command.env(k, v);
    }
    let mut child = command.spawn()?;
    let stdin = child.stdin.take().expect("piped stdin");
    let stdout = BufReader::new(child.stdout.take().expect("piped stdout"));
    let pid = child.id();
    Ok(WorkerLink {
        child: Some(child),
        transport: Box::new(PipeTransport { stdin, stdout }),
        pid,
        addr: None,
    })
}

fn spawn_tcp(
    cmd: &Path,
    extra_env: &[(String, String)],
    auth: Option<&str>,
) -> std::io::Result<WorkerLink> {
    // one ephemeral listener per worker: unambiguous child <-> connection
    // mapping without trusting accept order
    let listener = TcpListener::bind(("127.0.0.1", 0))?;
    let addr = listener.local_addr()?;
    let mut command = Command::new(cmd);
    command
        .arg("worker")
        .arg("--connect")
        .arg(addr.to_string())
        .stdin(Stdio::null())
        .stdout(Stdio::null());
    if let Some(token) = auth {
        command.env(AUTH_TOKEN_ENV, token);
    }
    for (k, v) in extra_env {
        command.env(k, v);
    }
    let mut child = command.spawn()?;
    // non-blocking accept with a deadline: a worker that crashes before
    // dialing back (or never dials) must fail the spawn, not hang it
    listener.set_nonblocking(true)?;
    let deadline = Instant::now() + TCP_ACCEPT_TIMEOUT;
    let stream = loop {
        match listener.accept() {
            Ok((stream, _peer)) => break stream,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if let Some(status) = child.try_wait()? {
                    return Err(std::io::Error::other(format!(
                        "tcp worker exited before connecting ({status})"
                    )));
                }
                if Instant::now() >= deadline {
                    let _ = child.kill();
                    let _ = child.wait();
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::TimedOut,
                        format!("tcp worker did not connect within {TCP_ACCEPT_TIMEOUT:?}"),
                    ));
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                let _ = child.kill();
                let _ = child.wait();
                return Err(e);
            }
        }
    };
    // the accepted stream must be blocking regardless of what it inherited
    stream.set_nonblocking(false)?;
    let pid = child.id();
    Ok(WorkerLink {
        child: Some(child),
        transport: Box::new(TcpTransport::from_stream(stream)?),
        pid,
        addr: None,
    })
}

/// Bind a TCP listener with `SO_REUSEADDR` set *before* the bind.
///
/// The rejoin path depends on "same address, new process": a restarted
/// `parccm worker --listen HOST:PORT` must be able to re-bind the port
/// its predecessor just died on, even while the predecessor's connection
/// lingers in `TIME_WAIT` (a SIGKILLed worker's kernel-orphaned socket
/// commonly does, for up to a minute). `std::net::TcpListener::bind`
/// cannot set the option pre-bind, so on Linux this drops down to the
/// libc socket calls (std already links libc; the crate stays
/// dependency-free). Any setup failure falls back to the std path —
/// worst case is the old fast-restart `EADDRINUSE` behavior; a genuine
/// bind/listen failure (port held by a live listener) still surfaces as
/// an error.
#[cfg(target_os = "linux")]
pub fn bind_reuseaddr(addr: &str) -> std::io::Result<TcpListener> {
    use std::net::SocketAddr;
    use std::os::unix::io::FromRawFd;

    #[allow(non_camel_case_types)]
    type c_int = i32;
    extern "C" {
        fn socket(domain: c_int, ty: c_int, protocol: c_int) -> c_int;
        fn setsockopt(
            fd: c_int,
            level: c_int,
            name: c_int,
            value: *const c_int,
            len: u32,
        ) -> c_int;
        fn bind(fd: c_int, addr: *const SockaddrIn, len: u32) -> c_int;
        fn listen(fd: c_int, backlog: c_int) -> c_int;
        fn close(fd: c_int) -> c_int;
    }
    const AF_INET: c_int = 2;
    const SOCK_STREAM: c_int = 1;
    const SOCK_CLOEXEC: c_int = 0o2000000;
    const SOL_SOCKET: c_int = 1;
    const SO_REUSEADDR: c_int = 2;
    /// `struct sockaddr_in` (all fields in network byte order).
    #[repr(C)]
    struct SockaddrIn {
        family: u16,
        port: u16,
        addr: u32,
        zero: [u8; 8],
    }

    let mut v4 = None;
    if let Ok(resolved) = addr.to_socket_addrs() {
        for a in resolved {
            if let SocketAddr::V4(found) = a {
                v4 = Some(found);
                break;
            }
        }
    }
    let Some(v4) = v4 else {
        return TcpListener::bind(addr); // unresolvable / IPv6-only: std path
    };
    unsafe {
        let fd = socket(AF_INET, SOCK_STREAM | SOCK_CLOEXEC, 0);
        if fd < 0 {
            return TcpListener::bind(addr);
        }
        let one: c_int = 1;
        let len = std::mem::size_of::<c_int>() as u32;
        if setsockopt(fd, SOL_SOCKET, SO_REUSEADDR, &one, len) != 0 {
            close(fd);
            return TcpListener::bind(addr);
        }
        let sa = SockaddrIn {
            family: AF_INET as u16,
            port: v4.port().to_be(),
            addr: u32::from_ne_bytes(v4.ip().octets()),
            zero: [0; 8],
        };
        let sa_len = std::mem::size_of::<SockaddrIn>() as u32;
        if bind(fd, &sa, sa_len) != 0 || listen(fd, 128) != 0 {
            let err = std::io::Error::last_os_error();
            close(fd);
            return Err(err);
        }
        Ok(TcpListener::from_raw_fd(fd))
    }
}

/// Non-Linux fallback: the plain std bind (no pre-bind socket options).
#[cfg(not(target_os = "linux"))]
pub fn bind_reuseaddr(addr: &str) -> std::io::Result<TcpListener> {
    TcpListener::bind(addr)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hello(v: f64) -> Json {
        Json::obj(vec![
            ("type", Json::Str("hello".into())),
            ("v", Json::Num(v)),
            ("pid", Json::Num(4242.0)),
        ])
    }

    #[test]
    fn negotiates_current_and_legacy_versions() {
        let h = negotiate_hello(&hello(WIRE_VERSION as f64)).unwrap();
        assert_eq!(h.version, WIRE_VERSION);
        assert_eq!(h.pid, 4242);
        let h1 = negotiate_hello(&hello(MIN_WIRE_VERSION as f64)).unwrap();
        assert_eq!(h1.version, MIN_WIRE_VERSION, "legacy workers run at their own version");
    }

    #[test]
    fn mismatch_error_names_both_versions() {
        let err = negotiate_hello(&hello(99.0)).unwrap_err();
        assert!(err.contains("v99"), "{err}");
        assert!(err.contains(&format!("v{WIRE_VERSION}")), "{err}");
        assert!(err.contains(&format!("v{MIN_WIRE_VERSION}")), "{err}");
        assert!(err.contains("4242"), "must name the offending worker: {err}");
        let too_old = negotiate_hello(&hello(0.0)).unwrap_err();
        assert!(too_old.contains("v0"), "{too_old}");
    }

    #[test]
    fn missing_or_malformed_hello_is_a_clean_error() {
        let no_v = Json::obj(vec![
            ("type", Json::Str("hello".into())),
            ("pid", Json::Num(7.0)),
        ]);
        assert!(negotiate_hello(&no_v).unwrap_err().contains("no wire version"));
        let not_hello = Json::obj(vec![("type", Json::Str("result".into()))]);
        assert!(negotiate_hello(&not_hello).unwrap_err().contains("expected hello"));
    }

    #[test]
    fn hello_caps_and_transport_parse() {
        let msg = Json::obj(vec![
            ("type", Json::Str("hello".into())),
            ("v", Json::Num(2.0)),
            ("pid", Json::Num(1.0)),
            ("transport", Json::Str("tcp".into())),
            ("caps", Json::Arr(vec![Json::Str("evict".into())])),
        ]);
        let h = negotiate_hello(&msg).unwrap();
        assert_eq!(h.transport.as_deref(), Some("tcp"));
        assert_eq!(h.caps, vec!["evict".to_string()]);
        assert_eq!(h.role, None, "worker hellos carry no role");
    }

    #[test]
    fn hello_parses_client_role() {
        // the v7 serve-mode handshake: a job client declares itself via
        // `role` and negotiates versions exactly like a worker would
        let msg = Json::obj(vec![
            ("type", Json::Str("hello".into())),
            ("v", Json::Num(SERVE_WIRE_VERSION as f64)),
            ("pid", Json::Num(99.0)),
            ("role", Json::Str("client".into())),
        ]);
        let h = negotiate_hello(&msg).unwrap();
        assert_eq!(h.role.as_deref(), Some("client"));
        assert_eq!(h.version, SERVE_WIRE_VERSION.min(WIRE_VERSION));
        // a v6 hello without the field still parses, role simply absent
        let h6 = negotiate_hello(&hello(6.0)).unwrap();
        assert_eq!(h6.role, None);
        assert_eq!(h6.version, 6);
    }

    #[test]
    fn transport_kind_round_trips() {
        for k in [TransportKind::Pipe, TransportKind::Tcp] {
            assert_eq!(TransportKind::parse(k.name()), Some(k));
        }
        assert_eq!(TransportKind::parse("carrier-pigeon"), None);
    }

    #[test]
    fn tcp_transport_round_trips_lines() {
        // loopback socket pair exercising the framed send/recv path
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut t = TcpTransport::from_stream(TcpStream::connect(addr).unwrap()).unwrap();
            t.send_line(r#"{"type":"ping"}"#).unwrap();
            recv_json(&mut t).unwrap()
        });
        let (stream, _) = listener.accept().unwrap();
        let mut server = TcpTransport::from_stream(stream).unwrap();
        let msg = recv_json(&mut server).unwrap();
        assert_eq!(msg.get("type").and_then(Json::as_str), Some("ping"));
        server.send_line(r#"{"type":"pong"}"#).unwrap();
        let reply = client.join().unwrap();
        assert_eq!(reply.get("type").and_then(Json::as_str), Some("pong"));
        assert_eq!(server.kind(), TransportKind::Tcp);
    }

    fn hello_with_auth(auth: Option<&str>) -> Hello {
        Hello {
            version: WIRE_VERSION,
            pid: 4242,
            transport: None,
            caps: Vec::new(),
            auth: auth.map(str::to_string),
            role: None,
        }
    }

    #[test]
    fn auth_verification_matrix() {
        // both unset and exact match pass
        assert!(verify_worker_auth(&hello_with_auth(None), None).is_ok());
        assert!(verify_worker_auth(&hello_with_auth(Some("s3")), Some("s3")).is_ok());
        // every mismatch is a clean error naming the worker, never the token
        for (worker, driver) in [
            (Some("sesame"), Some("wrong")),
            (None, Some("sesame")),
            (Some("sesame"), None),
        ] {
            let err = verify_worker_auth(&hello_with_auth(worker), driver).unwrap_err();
            assert!(err.contains("auth token mismatch"), "{err}");
            assert!(err.contains("4242"), "must name the worker: {err}");
            assert!(!err.contains("sesame") && !err.contains("wrong"), "no token leak: {err}");
        }
    }

    #[test]
    fn hello_parses_auth_field() {
        let msg = Json::obj(vec![
            ("type", Json::Str("hello".into())),
            ("v", Json::Num(3.0)),
            ("pid", Json::Num(1.0)),
            ("auth", Json::Str("sesame".into())),
        ]);
        assert_eq!(negotiate_hello(&msg).unwrap().auth.as_deref(), Some("sesame"));
        assert_eq!(negotiate_hello(&hello(3.0)).unwrap().auth, None);
    }

    #[test]
    fn handshake_payloads_round_trip() {
        let ack = Json::parse(&hello_ack_payload(Some("tok"))).unwrap();
        assert_eq!(ack.get("type").and_then(Json::as_str), Some("hello_ack"));
        assert_eq!(ack.get("auth").and_then(Json::as_str), Some("tok"));
        let bare = Json::parse(&hello_ack_payload(None)).unwrap();
        assert!(bare.get("auth").is_none());
        let rej = Json::parse(&reject_payload("nope")).unwrap();
        assert_eq!(rej.get("type").and_then(Json::as_str), Some("reject"));
        assert_eq!(rej.get("msg").and_then(Json::as_str), Some("nope"));
        let ping = Json::parse(&ping_payload(7)).unwrap();
        assert_eq!(ping.get("type").and_then(Json::as_str), Some("ping"));
        assert_eq!(ping.get("nonce").and_then(Json::as_f64), Some(7.0));
    }

    #[test]
    fn legacy_worker_cannot_satisfy_auth_requirement() {
        // a v1/v2 worker predates the handshake: with a driver token set,
        // finish_handshake must refuse instead of silently skipping auth
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut t = TcpTransport::from_stream(TcpStream::connect(addr).unwrap()).unwrap();
            let legacy = Hello {
                version: 1,
                pid: 1,
                transport: None,
                caps: Vec::new(),
                auth: None,
                role: None,
            };
            let err = finish_handshake(&mut t, &legacy, Some("tok")).unwrap_err();
            assert!(err.to_string().contains("auth token required"), "{err}");
            // and without a token the legacy path is a silent no-op
            finish_handshake(&mut t, &legacy, None).unwrap();
        });
        let (_stream, _) = listener.accept().unwrap();
        client.join().unwrap();
    }

    #[test]
    fn tcp_recv_deadline_is_enforced() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let silent = std::thread::spawn(move || {
            let stream = TcpStream::connect(addr).unwrap();
            std::thread::sleep(Duration::from_millis(400));
            drop(stream);
        });
        let (stream, _) = listener.accept().unwrap();
        let mut server = TcpTransport::from_stream(stream).unwrap();
        assert!(server.set_recv_deadline(Some(Duration::from_millis(50))).unwrap());
        let err = server.recv_line().unwrap_err();
        assert!(
            matches!(err.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut),
            "a silent peer must surface as a timeout, got {err:?}"
        );
        silent.join().unwrap();
    }

    #[test]
    fn reuseaddr_bind_survives_a_previous_listeners_time_wait() {
        // the rejoin shape: a listener dies with an open connection, a new
        // process re-listens on the SAME port moments later. The old
        // server-side socket closes first, so it lingers in TIME_WAIT —
        // bind_reuseaddr must succeed anyway.
        let listener = bind_reuseaddr("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let client = TcpStream::connect(addr).unwrap();
        let (server_side, _) = listener.accept().unwrap();
        drop(server_side); // server closes first → its side heads to TIME_WAIT
        std::thread::sleep(Duration::from_millis(10));
        drop(client);
        drop(listener);
        std::thread::sleep(Duration::from_millis(20));
        let again = bind_reuseaddr(&addr.to_string()).expect("re-bind on the same port");
        assert_eq!(again.local_addr().unwrap().port(), addr.port());
    }

    #[test]
    fn checksum_frames_round_trip_and_reject_tampering() {
        for payload in [r#"{"type":"task","id":7}"#, "", "π ≠ 3", r#"{"nested":{"a":[1,2]}}"#] {
            let frame = append_checksum(payload);
            assert_eq!(verify_frame(&frame).unwrap(), payload, "round trip");
            assert_eq!(verify_frame(&format!("{frame}\n")).unwrap(), payload, "newline trimmed");
        }
        // no suffix at all
        assert!(verify_frame(r#"{"type":"task"}"#).is_err());
        // suffix present but the body changed
        let frame = append_checksum(r#"{"type":"task","id":7}"#);
        let tampered = frame.replacen('7', "8", 1);
        assert!(verify_frame(&tampered).is_err());
    }

    #[test]
    fn checksum_suffix_parse_is_strict() {
        // a lenient integer parse would accept "+abc..." or uppercase hex
        // and could equate them with the honest value — the strict parser
        // must refuse anything but exactly 16 lowercase hex digits
        let frame = append_checksum("payload");
        let n = frame.len();
        let mut plus = frame.clone();
        plus.replace_range(n - 16..n - 15, "+");
        assert!(verify_frame(&plus).is_err(), "sign characters are not hex");
        let upper = format!("{}{}", &frame[..n - 16], frame[n - 16..].to_uppercase());
        if upper != frame {
            assert!(verify_frame(&upper).is_err(), "uppercase hex is refused");
        }
    }

    #[test]
    fn checksum_transport_round_trips_and_counts_corruption() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let raw = TcpTransport::from_stream(TcpStream::connect(addr).unwrap()).unwrap();
            let mut t = ChecksumTransport::new(Box::new(raw), None);
            t.send_line(r#"{"type":"ping"}"#).unwrap();
            // a clean checksummed reply parses...
            let ok = recv_json(&mut t).unwrap();
            assert_eq!(ok.get("type").and_then(Json::as_str), Some("pong"));
        });
        let (stream, _) = listener.accept().unwrap();
        let tally = Arc::new(AtomicU64::new(0));
        let raw = TcpTransport::from_stream(stream).unwrap();
        let mut server = ChecksumTransport::new(Box::new(raw), Some(tally.clone()));
        let msg = recv_json(&mut server).unwrap();
        assert_eq!(msg.get("type").and_then(Json::as_str), Some("ping"));
        server.send_line(r#"{"type":"pong"}"#).unwrap();
        client.join().unwrap();
        assert_eq!(tally.load(Ordering::Relaxed), 0, "clean traffic counts nothing");

        // ...while a bare (un-checksummed) frame is corruption, tallied
        let listener2 = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr2 = listener2.local_addr().unwrap();
        let bare = std::thread::spawn(move || {
            let mut raw = TcpTransport::from_stream(TcpStream::connect(addr2).unwrap()).unwrap();
            raw.send_line(r#"{"type":"ping"}"#).unwrap();
        });
        let (stream2, _) = listener2.accept().unwrap();
        let tally2 = Arc::new(AtomicU64::new(0));
        let raw2 = TcpTransport::from_stream(stream2).unwrap();
        let mut server2 = ChecksumTransport::new(Box::new(raw2), Some(tally2.clone()));
        let err = server2.recv_line().unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{err}");
        assert_eq!(tally2.load(Ordering::Relaxed), 1, "corrupt frame tallied");
        bare.join().unwrap();
    }

    #[test]
    fn tcp_recv_keeps_partial_line_across_timeouts() {
        // a deadline that fires mid-frame must not shear the frame: the
        // next recv_line picks the same line back up and completes it
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let sender = std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            stream.write_all(b"{\"type\":\"res").unwrap();
            stream.flush().unwrap();
            std::thread::sleep(Duration::from_millis(250));
            stream.write_all(b"ult\",\"id\":7}\n").unwrap();
            stream.flush().unwrap();
        });
        let (stream, _) = listener.accept().unwrap();
        let mut server = TcpTransport::from_stream(stream).unwrap();
        server.set_recv_deadline(Some(Duration::from_millis(60))).unwrap();
        let err = server.recv_line().unwrap_err();
        assert!(
            matches!(err.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut),
            "mid-frame deadline surfaces as a timeout: {err:?}"
        );
        server.set_recv_deadline(None).unwrap();
        let line = server.recv_line().unwrap().unwrap();
        assert_eq!(line.trim_end(), r#"{"type":"result","id":7}"#, "frame reassembled");
        sender.join().unwrap();
    }

    #[test]
    fn tcp_recv_reports_clean_eof() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            // connect and immediately hang up
            drop(TcpStream::connect(addr).unwrap());
        });
        let (stream, _) = listener.accept().unwrap();
        let mut server = TcpTransport::from_stream(stream).unwrap();
        t.join().unwrap();
        assert!(matches!(server.recv_line(), Ok(None)), "EOF must be Ok(None)");
        let err = recv_json(&mut server).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);
    }

    #[test]
    fn binary_frames_round_trip_after_a_line_handshake() {
        // the v6 connection shape: one line-JSON hello exchange, then
        // binary frames — including frames the peer pipelined behind its
        // final handshake line, which must stay visible to recv_frame
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut t = TcpTransport::from_stream(TcpStream::connect(addr).unwrap()).unwrap();
            t.send_line(r#"{"type":"hello"}"#).unwrap();
            let frame = t.recv_frame().unwrap().unwrap();
            assert_eq!(frame, vec![0x01, 0xff, 0x00, 0x80]);
            t.send_frame(&[0x10, 1, 2, 3]).unwrap();
            assert!(matches!(t.recv_frame(), Ok(None)), "clean EOF on a frame boundary");
        });
        let (stream, _) = listener.accept().unwrap();
        let mut server = TcpTransport::from_stream(stream).unwrap();
        let line = server.recv_line().unwrap().unwrap();
        assert_eq!(line.trim_end(), r#"{"type":"hello"}"#);
        // pipeline two sends back to back: the line then the frame
        server.send_frame(&[0x01, 0xff, 0x00, 0x80]).unwrap();
        let reply = server.recv_frame().unwrap().unwrap();
        assert_eq!(reply, vec![0x10, 1, 2, 3]);
        drop(server);
        client.join().unwrap();
    }

    #[test]
    fn binary_checksum_round_trips_and_detects_every_corruption_shape() {
        let body: Vec<u8> = vec![0x03, 0, 0, 0x80, 0x7f, 0xc0, 0xff];
        let framed = append_frame_checksum(&body);
        assert_eq!(framed.len(), body.len() + FRAME_BIN_CHECKSUM_LEN);
        assert_eq!(verify_binary_frame(&framed).unwrap(), &body[..]);
        // every single-byte flip (body or trailer) must be detected
        for i in 0..framed.len() {
            for bit in 0..8u8 {
                let mut bad = framed.clone();
                bad[i] ^= 1 << bit;
                assert!(verify_binary_frame(&bad).is_err(), "flip at byte {i} bit {bit}");
            }
        }
        // a frame too short to carry a trailer is corruption, not a parse
        assert!(verify_binary_frame(&framed[..FRAME_BIN_CHECKSUM_LEN]).is_err());
        assert!(verify_binary_frame(&[]).is_err());
    }

    #[test]
    fn binary_checksum_transport_round_trips_and_counts_corruption() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let raw = TcpTransport::from_stream(TcpStream::connect(addr).unwrap()).unwrap();
            let mut t = ChecksumTransport::new(Box::new(raw), None);
            t.send_frame(&[0x01, 42, 0, 1]).unwrap();
            let reply = t.recv_frame().unwrap().unwrap();
            assert_eq!(reply, vec![0x10, 7]);
            // now send a frame whose trailer lies about the body
            let mut bad = append_frame_checksum(&[0x01, 42, 0, 1]);
            let n = bad.len();
            bad[n - 1] ^= 0x40;
            t.inner.send_frame(&bad).unwrap();
        });
        let (stream, _) = listener.accept().unwrap();
        let tally = Arc::new(AtomicU64::new(0));
        let raw = TcpTransport::from_stream(stream).unwrap();
        let mut server = ChecksumTransport::new(Box::new(raw), Some(tally.clone()));
        let frame = server.recv_frame().unwrap().unwrap();
        assert_eq!(frame, vec![0x01, 42, 0, 1], "trailer stripped before hand-up");
        server.send_frame(&[0x10, 7]).unwrap();
        assert_eq!(tally.load(Ordering::Relaxed), 0, "clean traffic counts nothing");
        let err = server.recv_frame().unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{err}");
        assert_eq!(tally.load(Ordering::Relaxed), 1, "corrupt binary frame tallied");
        client.join().unwrap();
    }

    #[test]
    fn implausible_length_prefix_is_counted_corruption() {
        // a flipped high bit in the (unchecksummed) length prefix must
        // surface as counted InvalidData, never a giant allocation
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            stream.write_all(&u32::MAX.to_le_bytes()).unwrap();
            stream.flush().unwrap();
            std::thread::sleep(Duration::from_millis(100));
        });
        let (stream, _) = listener.accept().unwrap();
        let tally = Arc::new(AtomicU64::new(0));
        let raw = TcpTransport::from_stream(stream).unwrap();
        let mut server = ChecksumTransport::new(Box::new(raw), Some(tally.clone()));
        let err = server.recv_frame().unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{err}");
        assert_eq!(tally.load(Ordering::Relaxed), 1);
        client.join().unwrap();
    }

    #[test]
    fn tcp_recv_keeps_partial_frame_across_timeouts() {
        // the binary analogue of the partial-line invariant: a deadline
        // mid-frame keeps the prefix and body bytes already read
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let sender = std::thread::spawn(move || {
            let mut stream = TcpStream::connect(addr).unwrap();
            let body = [0x02u8, 9, 8, 7, 6, 5];
            stream.write_all(&(body.len() as u32).to_le_bytes()).unwrap();
            stream.write_all(&body[..2]).unwrap();
            stream.flush().unwrap();
            std::thread::sleep(Duration::from_millis(250));
            stream.write_all(&body[2..]).unwrap();
            stream.flush().unwrap();
        });
        let (stream, _) = listener.accept().unwrap();
        let mut server = TcpTransport::from_stream(stream).unwrap();
        server.set_recv_deadline(Some(Duration::from_millis(60))).unwrap();
        let err = server.recv_frame().unwrap_err();
        assert!(
            matches!(err.kind(), std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut),
            "mid-frame deadline surfaces as a timeout: {err:?}"
        );
        server.set_recv_deadline(None).unwrap();
        let frame = server.recv_frame().unwrap().unwrap();
        assert_eq!(frame, vec![0x02, 9, 8, 7, 6, 5], "frame reassembled");
        sender.join().unwrap();
    }
}
