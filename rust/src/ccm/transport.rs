//! Worker transports: how framed line-JSON messages move between the
//! driver and a worker process, independent of *what* the messages say.
//!
//! The wire format itself (message kinds, broadcasts, tasks) lives in
//! [`crate::ccm::cluster`]; this module owns the byte layer under it:
//!
//! * [`Transport`] — framed send/recv of one JSON object per line, with
//!   death detection folded into `std::io` errors (EOF / broken pipe /
//!   connection reset all surface as `Err` or `Ok(None)` and mean "the
//!   peer is gone").
//! * [`PipeTransport`] — the original fork + stdio transport: the worker
//!   is a child of the driver and speaks on its stdin/stdout.
//! * [`TcpTransport`] — a TCP-loopback transport: the driver binds an
//!   ephemeral listener, spawns `parccm worker --connect 127.0.0.1:PORT`,
//!   and accepts exactly one connection per worker. The same versioned
//!   wire protocol rides on the socket, so pipe and TCP results are
//!   bit-identical (asserted in `tests/integration_cluster.rs`).
//! * Connection lifecycle — [`connect_worker`] spawns + handshakes a
//!   worker over either transport; [`negotiate_hello`] is the pure
//!   version-negotiation step, unit-testable with doctored handshakes.
//!
//! # Version negotiation
//!
//! The worker's first message is a `hello` advertising the highest wire
//! version it speaks. The driver accepts any version in
//! [`MIN_WIRE_VERSION`]..=[`WIRE_VERSION`] and runs the connection at the
//! *minimum* of the two sides (a v1 worker simply never receives v2-only
//! messages such as `evict`). Anything outside the range is a clean,
//! immediate error naming both sides' versions — never a hang and never a
//! silent requeue loop (the regression tests doctor the advertised
//! version via `PARCCM_TEST_HELLO_V`, a child-env test seam).

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::Path;
use std::process::{Child, ChildStdin, ChildStdout, Command, Stdio};
use std::time::{Duration, Instant};

use crate::util::json::Json;

/// Highest protocol version this build speaks; bumped on any incompatible
/// message change. v2 added the `evict` message and the capability-carrying
/// hello (`transport`, `caps` fields).
pub const WIRE_VERSION: u64 = 2;

/// Oldest protocol version the driver still accepts. v1 workers are served
/// without v2-only traffic (no `evict` is ever sent to them).
pub const MIN_WIRE_VERSION: u64 = 1;

/// How long the driver waits for a spawned TCP worker to dial back before
/// declaring the spawn failed (keeps a broken worker from hanging CI).
pub const TCP_ACCEPT_TIMEOUT: Duration = Duration::from_secs(10);

/// Which byte layer a worker connection uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum TransportKind {
    /// Forked child, line-JSON on its stdin/stdout (the PR 2 transport).
    #[default]
    Pipe,
    /// Forked child dialing back over TCP loopback; same wire protocol.
    Tcp,
}

impl TransportKind {
    /// Stable name used in hello messages, CLI flags, and logs.
    pub fn name(self) -> &'static str {
        match self {
            TransportKind::Pipe => "pipe",
            TransportKind::Tcp => "tcp",
        }
    }

    /// Parse a `--transport` value.
    pub fn parse(s: &str) -> Option<TransportKind> {
        match s {
            "pipe" => Some(TransportKind::Pipe),
            "tcp" => Some(TransportKind::Tcp),
            _ => None,
        }
    }
}

/// One framed line-JSON connection to a worker. Implementations must fold
/// peer death into the return values: a broken connection is an `Err` on
/// send, and `Ok(None)` (clean EOF) or `Err` on receive — the scheduler
/// treats all three as "worker gone, requeue its task".
pub trait Transport: Send {
    /// Ship one pre-serialized JSON object (no trailing newline) and flush.
    fn send_line(&mut self, line: &str) -> std::io::Result<()>;

    /// Receive the next line; `Ok(None)` means the peer closed cleanly.
    fn recv_line(&mut self) -> std::io::Result<Option<String>>;

    /// Which byte layer this is (for logs and hello messages).
    fn kind(&self) -> TransportKind;
}

/// Receive the next non-empty line as parsed JSON; EOF and parse failures
/// become `std::io` errors so callers have a single failure channel.
pub fn recv_json(t: &mut dyn Transport) -> std::io::Result<Json> {
    loop {
        match t.recv_line()? {
            None => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "worker closed its connection",
                ))
            }
            Some(line) if line.trim().is_empty() => continue,
            Some(line) => {
                return Json::parse(&line).map_err(|e| {
                    std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
                })
            }
        }
    }
}

fn read_line_opt<R: BufRead>(r: &mut R) -> std::io::Result<Option<String>> {
    let mut line = String::new();
    if r.read_line(&mut line)? == 0 {
        Ok(None)
    } else {
        Ok(Some(line))
    }
}

/// Fork + stdio transport (driver side): the worker's stdin/stdout pipes.
pub struct PipeTransport {
    stdin: ChildStdin,
    stdout: BufReader<ChildStdout>,
}

impl Transport for PipeTransport {
    fn send_line(&mut self, line: &str) -> std::io::Result<()> {
        self.stdin.write_all(line.as_bytes())?;
        self.stdin.write_all(b"\n")?;
        self.stdin.flush()
    }

    fn recv_line(&mut self) -> std::io::Result<Option<String>> {
        read_line_opt(&mut self.stdout)
    }

    fn kind(&self) -> TransportKind {
        TransportKind::Pipe
    }
}

/// TCP transport (either side): a connected stream plus a buffered reader
/// over its clone. `TCP_NODELAY` is set — the protocol is small
/// request/response lines, exactly the shape Nagle's algorithm penalizes.
pub struct TcpTransport {
    writer: TcpStream,
    reader: BufReader<TcpStream>,
}

impl TcpTransport {
    /// Wrap an already-connected stream (used by both driver and worker).
    pub fn from_stream(stream: TcpStream) -> std::io::Result<TcpTransport> {
        stream.set_nodelay(true)?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(TcpTransport { writer: stream, reader })
    }
}

impl Transport for TcpTransport {
    fn send_line(&mut self, line: &str) -> std::io::Result<()> {
        self.writer.write_all(line.as_bytes())?;
        self.writer.write_all(b"\n")?;
        self.writer.flush()
    }

    fn recv_line(&mut self) -> std::io::Result<Option<String>> {
        read_line_opt(&mut self.reader)
    }

    fn kind(&self) -> TransportKind {
        TransportKind::Tcp
    }
}

/// A spawned worker process plus its connected transport — what the
/// cluster scheduler leases tasks onto.
pub struct WorkerLink {
    /// Child process handle (kill/wait on discard and shutdown).
    pub child: Child,
    /// The framed connection to it.
    pub transport: Box<dyn Transport>,
    /// OS pid (observability and kill-recovery tests).
    pub pid: u32,
}

/// The worker's negotiated identity after a successful hello.
#[derive(Clone, Debug)]
pub struct Hello {
    /// Version the connection runs at: `min(worker's, ours)`.
    pub version: u64,
    /// Worker-reported pid (equals the child pid for spawned workers).
    pub pid: u64,
    /// Transport the worker believes it is serving on (v2 hellos).
    pub transport: Option<String>,
    /// Capability strings (v2 hellos; e.g. `"evict"`).
    pub caps: Vec<String>,
}

/// Validate a worker hello and negotiate the connection version.
///
/// This is the dedicated version-mismatch failure path: a worker speaking
/// a version outside [`MIN_WIRE_VERSION`]..=[`WIRE_VERSION`] produces an
/// error naming **both** versions, so the operator sees exactly which side
/// is stale instead of a hang or a silent requeue loop.
pub fn negotiate_hello(msg: &Json) -> Result<Hello, String> {
    if msg.get("type").and_then(Json::as_str) != Some("hello") {
        return Err(format!("expected hello handshake, got {msg}"));
    }
    let pid = msg.get("pid").and_then(Json::as_f64).unwrap_or(0.0) as u64;
    let Some(v) = msg.get("v").and_then(Json::as_f64) else {
        return Err(format!("hello from worker pid {pid} carries no wire version: {msg}"));
    };
    let v = v as u64;
    if !(MIN_WIRE_VERSION..=WIRE_VERSION).contains(&v) {
        return Err(format!(
            "wire version mismatch: driver speaks v{MIN_WIRE_VERSION}..v{WIRE_VERSION}, \
             worker pid {pid} speaks v{v} — refusing the connection"
        ));
    }
    let caps = match msg.get("caps") {
        Some(Json::Arr(items)) => items
            .iter()
            .filter_map(|c| c.as_str().map(str::to_string))
            .collect(),
        _ => Vec::new(),
    };
    Ok(Hello {
        version: v.min(WIRE_VERSION),
        pid,
        transport: msg.get("transport").and_then(Json::as_str).map(str::to_string),
        caps,
    })
}

/// Spawn a worker over `kind` and complete the hello handshake, returning
/// the connected link and the negotiated [`Hello`]. `extra_env` is set on
/// the child only (used by tests to doctor the advertised version).
pub fn connect_worker(
    cmd: &Path,
    kind: TransportKind,
    extra_env: &[(String, String)],
) -> std::io::Result<(WorkerLink, Hello)> {
    let mut link = match kind {
        TransportKind::Pipe => spawn_pipe(cmd, extra_env)?,
        TransportKind::Tcp => spawn_tcp(cmd, extra_env)?,
    };
    let hello = recv_json(link.transport.as_mut())?;
    match negotiate_hello(&hello) {
        Ok(h) => Ok((link, h)),
        Err(e) => {
            let _ = link.child.kill();
            let _ = link.child.wait();
            Err(std::io::Error::new(std::io::ErrorKind::InvalidData, e))
        }
    }
}

fn spawn_pipe(cmd: &Path, extra_env: &[(String, String)]) -> std::io::Result<WorkerLink> {
    let mut command = Command::new(cmd);
    command.arg("worker").stdin(Stdio::piped()).stdout(Stdio::piped());
    for (k, v) in extra_env {
        command.env(k, v);
    }
    let mut child = command.spawn()?;
    let stdin = child.stdin.take().expect("piped stdin");
    let stdout = BufReader::new(child.stdout.take().expect("piped stdout"));
    let pid = child.id();
    Ok(WorkerLink { child, transport: Box::new(PipeTransport { stdin, stdout }), pid })
}

fn spawn_tcp(cmd: &Path, extra_env: &[(String, String)]) -> std::io::Result<WorkerLink> {
    // one ephemeral listener per worker: unambiguous child <-> connection
    // mapping without trusting accept order
    let listener = TcpListener::bind(("127.0.0.1", 0))?;
    let addr = listener.local_addr()?;
    let mut command = Command::new(cmd);
    command
        .arg("worker")
        .arg("--connect")
        .arg(addr.to_string())
        .stdin(Stdio::null())
        .stdout(Stdio::null());
    for (k, v) in extra_env {
        command.env(k, v);
    }
    let mut child = command.spawn()?;
    // non-blocking accept with a deadline: a worker that crashes before
    // dialing back (or never dials) must fail the spawn, not hang it
    listener.set_nonblocking(true)?;
    let deadline = Instant::now() + TCP_ACCEPT_TIMEOUT;
    let stream = loop {
        match listener.accept() {
            Ok((stream, _peer)) => break stream,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if let Some(status) = child.try_wait()? {
                    return Err(std::io::Error::other(format!(
                        "tcp worker exited before connecting ({status})"
                    )));
                }
                if Instant::now() >= deadline {
                    let _ = child.kill();
                    let _ = child.wait();
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::TimedOut,
                        format!("tcp worker did not connect within {TCP_ACCEPT_TIMEOUT:?}"),
                    ));
                }
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(e) => {
                let _ = child.kill();
                let _ = child.wait();
                return Err(e);
            }
        }
    };
    // the accepted stream must be blocking regardless of what it inherited
    stream.set_nonblocking(false)?;
    let pid = child.id();
    Ok(WorkerLink { child, transport: Box::new(TcpTransport::from_stream(stream)?), pid })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hello(v: f64) -> Json {
        Json::obj(vec![
            ("type", Json::Str("hello".into())),
            ("v", Json::Num(v)),
            ("pid", Json::Num(4242.0)),
        ])
    }

    #[test]
    fn negotiates_current_and_legacy_versions() {
        let h = negotiate_hello(&hello(WIRE_VERSION as f64)).unwrap();
        assert_eq!(h.version, WIRE_VERSION);
        assert_eq!(h.pid, 4242);
        let h1 = negotiate_hello(&hello(MIN_WIRE_VERSION as f64)).unwrap();
        assert_eq!(h1.version, MIN_WIRE_VERSION, "legacy workers run at their own version");
    }

    #[test]
    fn mismatch_error_names_both_versions() {
        let err = negotiate_hello(&hello(99.0)).unwrap_err();
        assert!(err.contains("v99"), "{err}");
        assert!(err.contains(&format!("v{WIRE_VERSION}")), "{err}");
        assert!(err.contains(&format!("v{MIN_WIRE_VERSION}")), "{err}");
        assert!(err.contains("4242"), "must name the offending worker: {err}");
        let too_old = negotiate_hello(&hello(0.0)).unwrap_err();
        assert!(too_old.contains("v0"), "{too_old}");
    }

    #[test]
    fn missing_or_malformed_hello_is_a_clean_error() {
        let no_v = Json::obj(vec![
            ("type", Json::Str("hello".into())),
            ("pid", Json::Num(7.0)),
        ]);
        assert!(negotiate_hello(&no_v).unwrap_err().contains("no wire version"));
        let not_hello = Json::obj(vec![("type", Json::Str("result".into()))]);
        assert!(negotiate_hello(&not_hello).unwrap_err().contains("expected hello"));
    }

    #[test]
    fn hello_caps_and_transport_parse() {
        let msg = Json::obj(vec![
            ("type", Json::Str("hello".into())),
            ("v", Json::Num(2.0)),
            ("pid", Json::Num(1.0)),
            ("transport", Json::Str("tcp".into())),
            ("caps", Json::Arr(vec![Json::Str("evict".into())])),
        ]);
        let h = negotiate_hello(&msg).unwrap();
        assert_eq!(h.transport.as_deref(), Some("tcp"));
        assert_eq!(h.caps, vec!["evict".to_string()]);
    }

    #[test]
    fn transport_kind_round_trips() {
        for k in [TransportKind::Pipe, TransportKind::Tcp] {
            assert_eq!(TransportKind::parse(k.name()), Some(k));
        }
        assert_eq!(TransportKind::parse("carrier-pigeon"), None);
    }

    #[test]
    fn tcp_transport_round_trips_lines() {
        // loopback socket pair exercising the framed send/recv path
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || {
            let mut t = TcpTransport::from_stream(TcpStream::connect(addr).unwrap()).unwrap();
            t.send_line(r#"{"type":"ping"}"#).unwrap();
            recv_json(&mut t).unwrap()
        });
        let (stream, _) = listener.accept().unwrap();
        let mut server = TcpTransport::from_stream(stream).unwrap();
        let msg = recv_json(&mut server).unwrap();
        assert_eq!(msg.get("type").and_then(Json::as_str), Some("ping"));
        server.send_line(r#"{"type":"pong"}"#).unwrap();
        let reply = client.join().unwrap();
        assert_eq!(reply.get("type").and_then(Json::as_str), Some("pong"));
        assert_eq!(server.kind(), TransportKind::Tcp);
    }

    #[test]
    fn tcp_recv_reports_clean_eof() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let t = std::thread::spawn(move || {
            // connect and immediately hang up
            drop(TcpStream::connect(addr).unwrap());
        });
        let (stream, _) = listener.accept().unwrap();
        let mut server = TcpTransport::from_stream(stream).unwrap();
        t.join().unwrap();
        assert!(matches!(server.recv_line(), Ok(None)), "EOF must be Ok(None)");
        let err = recv_json(&mut server).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof);
    }
}
