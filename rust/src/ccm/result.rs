//! Result types: per-realization skills and per-combination summaries.

use std::collections::BTreeMap;

use crate::ccm::params::CcmParams;
use crate::util::json::Json;
use crate::util::stats;

/// Cross-map skill of one realization (one library subsample).
#[derive(Clone, Copy, Debug)]
pub struct SkillRow {
    pub params: CcmParams,
    pub sample_id: usize,
    pub rho: f32,
}

/// Aggregated skill for one `(E, tau, L)` combination.
#[derive(Clone, Debug)]
pub struct SkillSummary {
    pub params: CcmParams,
    pub n: usize,
    pub mean_rho: f64,
    pub std_rho: f64,
    pub q05: f64,
    pub q95: f64,
}

/// Group skill rows by combination and summarize (sorted by (E, tau, L)).
pub fn summarize(rows: &[SkillRow]) -> Vec<SkillSummary> {
    let mut groups: BTreeMap<(usize, usize, usize), Vec<f64>> = BTreeMap::new();
    for row in rows {
        groups
            .entry((row.params.e, row.params.tau, row.params.l))
            .or_default()
            .push(row.rho as f64);
    }
    groups
        .into_iter()
        .map(|((e, tau, l), rhos)| SkillSummary {
            params: CcmParams::new(e, tau, l),
            n: rhos.len(),
            mean_rho: stats::mean(&rhos),
            std_rho: stats::stddev(&rhos),
            q05: stats::percentile(&rhos, 5.0),
            q95: stats::percentile(&rhos, 95.0),
        })
        .collect()
}

impl SkillSummary {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("e", Json::Num(self.params.e as f64)),
            ("tau", Json::Num(self.params.tau as f64)),
            ("l", Json::Num(self.params.l as f64)),
            ("n", Json::Num(self.n as f64)),
            ("mean_rho", Json::Num(self.mean_rho)),
            ("std_rho", Json::Num(self.std_rho)),
            ("q05", Json::Num(self.q05)),
            ("q95", Json::Num(self.q95)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn row(e: usize, l: usize, sample_id: usize, rho: f32) -> SkillRow {
        SkillRow { params: CcmParams::new(e, 1, l), sample_id, rho }
    }

    #[test]
    fn groups_and_summarizes() {
        let rows = vec![
            row(2, 50, 0, 0.5),
            row(2, 50, 1, 0.7),
            row(2, 100, 0, 0.9),
            row(1, 50, 0, 0.1),
        ];
        let s = summarize(&rows);
        assert_eq!(s.len(), 3);
        // sorted by (e, tau, l)
        assert_eq!(s[0].params, CcmParams::new(1, 1, 50));
        assert_eq!(s[1].params, CcmParams::new(2, 1, 50));
        assert_eq!(s[1].n, 2);
        assert!((s[1].mean_rho - 0.6).abs() < 1e-6);
        assert_eq!(s[2].params, CcmParams::new(2, 1, 100));
    }

    #[test]
    fn json_has_all_fields() {
        let s = summarize(&[row(2, 50, 0, 0.5)]);
        let j = s[0].to_json();
        for key in ["e", "tau", "l", "n", "mean_rho", "std_rho", "q05", "q95"] {
            assert!(j.get(key).is_some(), "missing {key}");
        }
    }
}
