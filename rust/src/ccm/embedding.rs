//! Lagged-coordinate embedding (Takens reconstruction).
//!
//! Row `i` of the embedding is the vector
//! `[y[t], y[t-tau], ..., y[t-(E-1)tau]]` with `t = (E-1)*tau + i`, i.e.
//! every time index that has a full history. Vectors are stored flat,
//! zero-padded to [`crate::EMAX`] lanes — the backend/artifact contract
//! (zero padding is distance-invariant).

use crate::EMAX;

/// A shadow manifold: `n` points of an `e`-dimensional reconstruction,
/// stored row-major with EMAX-lane padding.
#[derive(Clone, Debug)]
pub struct Embedding {
    /// Flat `[n, EMAX]` row-major vectors.
    pub vecs: Vec<f32>,
    /// Number of manifold points.
    pub n: usize,
    /// Active embedding dimension (<= EMAX).
    pub e: usize,
    /// Embedding delay.
    pub tau: usize,
    /// Time index of row 0 in the original series (= `(e-1)*tau`).
    pub t0: usize,
}

impl Embedding {
    /// Embed `series` with dimension `e` and delay `tau`.
    ///
    /// Panics if the series is too short to produce at least one vector.
    pub fn new(series: &[f32], e: usize, tau: usize) -> Embedding {
        assert!((1..=EMAX).contains(&e), "E must be in 1..={EMAX}, got {e}");
        assert!(tau >= 1, "tau must be >= 1");
        let offset = (e - 1) * tau;
        assert!(
            series.len() > offset,
            "series of length {} cannot be embedded with E={e}, tau={tau}",
            series.len()
        );
        let n = series.len() - offset;
        let mut vecs = vec![0.0f32; n * EMAX];
        for i in 0..n {
            let t = offset + i;
            for j in 0..e {
                vecs[i * EMAX + j] = series[t - j * tau];
            }
        }
        Embedding { vecs, n, e, tau, t0: offset }
    }

    /// The manifold point at row `i` (EMAX lanes, zero-padded).
    pub fn point(&self, i: usize) -> &[f32] {
        &self.vecs[i * EMAX..(i + 1) * EMAX]
    }

    /// Original-series time index of row `i`.
    pub fn time_of(&self, i: usize) -> usize {
        self.t0 + i
    }

    /// Align a co-observed series to the manifold rows: `out[i]` is the
    /// value of `other` at the time of manifold point `i`. This is the
    /// "target" vector cross-mapping predicts.
    pub fn align_targets(&self, other: &[f32]) -> Vec<f32> {
        assert!(
            other.len() >= self.t0 + self.n,
            "target series too short: {} < {}",
            other.len(),
            self.t0 + self.n
        );
        (0..self.n).map(|i| other[self.time_of(i)]).collect()
    }

    /// Approximate in-memory size (for broadcast accounting).
    pub fn size_bytes(&self) -> usize {
        self.vecs.len() * std::mem::size_of::<f32>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn embeds_with_correct_lags() {
        let series: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let emb = Embedding::new(&series, 3, 2);
        // offset = 4; first vector at t=4: [4, 2, 0]
        assert_eq!(emb.n, 6);
        assert_eq!(emb.t0, 4);
        assert_eq!(&emb.point(0)[..3], &[4.0, 2.0, 0.0]);
        assert_eq!(&emb.point(5)[..3], &[9.0, 7.0, 5.0]);
        // padding lanes zero
        assert!(emb.point(0)[3..].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn e1_is_identity() {
        let series: Vec<f32> = vec![5.0, 6.0, 7.0];
        let emb = Embedding::new(&series, 1, 3);
        assert_eq!(emb.n, 3);
        assert_eq!(emb.t0, 0);
        assert_eq!(&emb.point(1)[..1], &[6.0]);
    }

    #[test]
    fn align_targets_matches_times() {
        let y: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let x: Vec<f32> = (0..10).map(|i| (i * 10) as f32).collect();
        let emb = Embedding::new(&y, 2, 3);
        let t = emb.align_targets(&x);
        assert_eq!(t.len(), emb.n);
        assert_eq!(t[0], 30.0); // t0 = 3
        assert_eq!(t[6], 90.0);
    }

    #[test]
    #[should_panic(expected = "cannot be embedded")]
    fn rejects_short_series() {
        Embedding::new(&[1.0, 2.0], 3, 2);
    }

    #[test]
    fn time_roundtrip() {
        let series: Vec<f32> = (0..50).map(|i| (i as f32).sin()).collect();
        let emb = Embedding::new(&series, 4, 2);
        for i in 0..emb.n {
            let t = emb.time_of(i);
            assert_eq!(emb.point(i)[0], series[t]);
            assert_eq!(emb.point(i)[3], series[t - 6]);
        }
    }
}
