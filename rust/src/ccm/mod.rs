//! Convergent Cross Mapping: the algorithm, its data structures, and the
//! paper's two parallel pipelines built on the [`crate::engine`].
//!
//! The flow mirrors Sugihara et al. (2012) / rEDM semantics:
//!
//! 1. [`embedding`] — lagged-coordinate reconstruction of the shadow
//!    manifold `M_Y` from the candidate *effect* series Y.
//! 2. [`subsample`] — draw `r` random libraries of size `L` from `M_Y`.
//! 3. k-NN + [`backend`] simplex projection — predict the *cause* series X
//!    at every manifold point from each library's E+1 nearest neighbours
//!    (self-matches excluded).
//! 4. Pearson skill + [`convergence`] — `rho(L)` increasing and
//!    plateauing with library size is the CCM causality signature.
//!
//! The paper's contributions map to:
//! * [`pipeline::ccm_transform_pipeline`] — §3.1, the per-subsample
//!   cross-map as an RDD transform chain;
//! * [`table::DistanceTable`] + [`pipeline::table_pipeline`] — §3.2, the
//!   broadcast distance indexing table that replaces per-subsample
//!   brute-force k-NN with filtered lookups;
//! * [`driver`] — §4/Table 1, the five implementation levels A1–A5
//!   (sync/async x with/without the table, plus the engine-free A1).
//!
//! Beyond the paper, [`table::ShardedTable`] splits the distance index
//! into per-node row-range shards and [`cluster::ClusterBackend`] ships
//! index-only tasks to worker processes over a versioned wire protocol —
//! v6 length-prefixed [`binwire`] frames for bulk payloads, negotiated
//! per connection with a byte-identical JSON line fallback for v<=5
//! peers — riding a pluggable [`transport`] (pipe/fork or TCP loopback),
//! with shard replication and zero-re-ship task requeue — the genuinely
//! distributed deployment of the same pipelines. The old
//! [`process::ProcessBackend`] name remains as a compatibility shim.
//! [`serve`] turns that one-shot cluster into a long-running service:
//! a `parccm serve` daemon owns the warm pool for its lifetime and
//! admits many concurrent jobs over the v7 wire, each isolated by a
//! [`cluster::JobBackend`] tag, scheduled fairly round-robin, and
//! priced per tenant by [`cluster::JobTally`].

pub mod backend;
pub mod binwire;
pub mod chaos;
pub mod cluster;
pub mod convergence;
pub mod driver;
pub mod embedding;
pub mod forecast;
pub mod knn;
pub mod lagmap;
pub mod lifecycle;
pub mod params;
pub mod pipeline;
pub mod process;
pub mod result;
pub mod select;
pub mod serve;
pub mod simplex;
pub mod subsample;
pub mod surrogate;
pub mod table;
pub mod transport;

pub use backend::{ComputeBackend, CrossMapInput, CrossMapOutput, TaskArena};
pub use cluster::{ClusterBackend, ClusterOptions, JobBackend, JobTally, OnExhausted, TaskExhausted};
pub use driver::{Case, CaseReport, JobSpec, TablePolicy};
pub use lifecycle::WorkerSource;
pub use embedding::Embedding;
pub use params::{CcmParams, Scenario};
pub use pipeline::TableMode;
pub use process::ProcessBackend;
pub use result::{SkillRow, SkillSummary};
pub use serve::{JobClient, JobId, JobPool, JobState, JobTracker, ServeDaemon, ServeOptions};
pub use table::{DistanceTable, LibraryMask, ShardedTable, TableShard};
pub use transport::TransportKind;
