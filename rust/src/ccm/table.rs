//! The distance indexing table (paper §3.2) — the headline optimization.
//!
//! Brute-force CCM recomputes, **per subsample**, the distances from every
//! prediction point to the L library points and re-selects the top E+1 —
//! `O(r * n * L)` distance work plus selection. The paper instead builds,
//! once per `(E, tau)`, a table over the *whole* embedded series: for each
//! manifold point, all other points sorted by distance. The table is
//! broadcast to every worker; each subsample's k-NN then degenerates to
//! walking the precomputed sorted list and keeping the first E+1 entries
//! that are members of the sampled library — no distance computation, no
//! sorting, expected `O(n/L * k)` walk per query.
//!
//! # Truncated mode
//!
//! A query only ever *walks* an expected `O(n/L * KMAX)` prefix of each
//! sorted row, yet the full table broadcasts all `n * (n-1)` entries
//! (~64 MB at n = 4000). Truncated mode stores only the top-P prefix per
//! row — P sized from the smallest library density via
//! [`DistanceTable::auto_prefix`] — cutting the broadcast to `O(n * P)`
//! bytes. Correctness is preserved *exactly*: while walking, the query
//! counts the library members it has seen; if the prefix is exhausted
//! before KMAX neighbours are found **and** unseen members remain, it
//! falls back to a brute-force scan of the library rows for that one
//! query. The fallback reproduces the walk's semantics bit-for-bit
//! (identical distance arithmetic, ties to the lower manifold row), so
//! truncated-table results are bit-identical to full-table and
//! brute-force k-NN; [`DistanceTable::fallback_queries`] counts how often
//! the prefix ran dry.
//!
//! Memory: `n * row_len` u32 indices. Neighbour *distances* are recomputed
//! on the fly for accepted entries only (k per query), saving 8x memory
//! over storing them.
//!
//! # Sharded mode
//!
//! Rows are independent, so the table splits mechanically into contiguous
//! row-range shards ([`TableShard`]): shard `s` of `S` stores the sorted
//! prefixes for query rows `[s*n/S, (s+1)*n/S)` plus the `O(n * EMAX)`
//! manifold copy every shard needs for distance recomputation and the
//! sparse-library fallback. No shard holds another shard's index — the
//! `O(n * row_len)` bulk of the broadcast is partitioned, which is what
//! lets a multi-node deployment ship each node only the shards it queries
//! (the DES prices per-shard broadcasts individually). [`ShardedTable`]
//! is the facade that routes a query row to its owning shard; shard
//! queries run the *same* walk/fallback code as the unsharded table, so
//! results are bit-identical by construction (property-tested in
//! `tests/prop_invariants.rs`).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, OnceLock};

use crate::ccm::backend::NeighborPanels;
use crate::ccm::embedding::Embedding;
use crate::{BIG, EMAX, KMAX};

/// Library membership as a packed u64 bitset over manifold rows, refilled
/// per sample from a [`crate::ccm::backend::TaskArena`] without
/// reallocating. Replaces the old one-byte-per-row mask: 8x smaller, and
/// clearing between samples is an `O(n/64)` word fill.
#[derive(Default)]
pub struct LibraryMask {
    words: Vec<u64>,
    n: usize,
    members: usize,
}

impl LibraryMask {
    pub fn new() -> LibraryMask {
        LibraryMask::default()
    }

    /// Reset to an `n`-row manifold with the given member rows set.
    pub fn set_from(&mut self, n: usize, rows: &[usize]) {
        let n_words = n.div_ceil(64);
        self.words.clear();
        self.words.resize(n_words, 0);
        self.n = n;
        for &r in rows {
            debug_assert!(r < n);
            self.words[r >> 6] |= 1u64 << (r & 63);
        }
        self.members = rows.len();
    }

    #[inline]
    pub fn contains(&self, row: usize) -> bool {
        (self.words[row >> 6] >> (row & 63)) & 1 == 1
    }

    /// Number of member rows.
    pub fn members(&self) -> usize {
        self.members
    }

    /// Manifold size this mask covers.
    pub fn n(&self) -> usize {
        self.n
    }
}

/// Sorted-neighbour index over a full shadow manifold (full or truncated
/// prefix per row — see the module docs).
pub struct DistanceTable {
    /// Flat `[n, row_len]`: row i lists other manifold rows ascending by
    /// distance to i (ties by index); the first `row_len` of them.
    neighbors: Vec<u32>,
    /// Entries stored per row: `n - 1` (full) or the truncation prefix P.
    row_len: usize,
    /// Number of manifold points.
    pub n: usize,
    /// The manifold the table indexes (owned copy of the flat vectors —
    /// needed to recompute accepted-neighbour distances and to serve the
    /// sparse-library brute-force fallback).
    vecs: Vec<f32>,
    /// Time index of row 0 (Theiler windows work on original time).
    pub t0: usize,
    /// Queries that exhausted a truncated prefix and fell back to the
    /// brute-force scan (observability; relaxed counter).
    fallbacks: AtomicU64,
}

impl DistanceTable {
    /// Build the full table serially. The parallel build used by the
    /// pipelines is [`DistanceTable::sorted_row`] + [`DistanceTable::assemble`].
    pub fn build(emb: &Embedding) -> DistanceTable {
        let rows: Vec<Vec<u32>> = (0..emb.n).map(|i| Self::sorted_row(emb, i)).collect();
        Self::assemble(emb, rows)
    }

    /// Build a truncated table serially, keeping the top-`prefix` entries
    /// per row.
    pub fn build_truncated(emb: &Embedding, prefix: usize) -> DistanceTable {
        let row_len = prefix.min(emb.n.saturating_sub(1));
        let rows: Vec<Vec<u32>> =
            (0..emb.n).map(|i| Self::sorted_row_prefix(emb, i, row_len)).collect();
        Self::assemble_with(emb, rows, row_len)
    }

    /// Prefix length for truncated mode: the expected walk length to find
    /// KMAX members at the sparsest library density `min_l / n`, with 4x
    /// headroom so the exact brute-force fallback stays rare. Clamped to
    /// the full row length.
    pub fn auto_prefix(n: usize, min_l: usize) -> usize {
        let full = n.saturating_sub(1);
        let min_l = min_l.max(1);
        let expected = KMAX * n.div_ceil(min_l);
        (expected * 4).max(KMAX).min(full)
    }

    /// Compute the sorted neighbour list of manifold row `i` — the unit of
    /// parallel table construction (each engine task handles a chunk of
    /// rows).
    ///
    /// §Perf: squared distances are non-negative, so their IEEE-754 bit
    /// patterns are order-monotone; packing `(dist_bits << 32) | index`
    /// into a u64 replaces the branchy `partial_cmp` comparator sort with
    /// a plain integer sort (ties fall through to the index — exactly the
    /// lowest-index tie-break the kernels use). ~2.3x faster build.
    pub fn sorted_row(emb: &Embedding, i: usize) -> Vec<u32> {
        let n = emb.n;
        let a = emb.point(i);
        let mut keys: Vec<u64> = Vec::with_capacity(n - 1);
        for j in 0..n {
            if j == i {
                continue;
            }
            let b = emb.point(j);
            let mut d = 0.0f32;
            for l in 0..EMAX {
                let diff = a[l] - b[l];
                d += diff * diff;
            }
            keys.push(((d.to_bits() as u64) << 32) | j as u64);
        }
        keys.sort_unstable();
        keys.into_iter().map(|k| k as u32).collect()
    }

    /// [`DistanceTable::sorted_row`] truncated to its top-`prefix` entries
    /// — the unit of parallel *truncated* construction. Truncating inside
    /// the task also shrinks what the driver collects.
    pub fn sorted_row_prefix(emb: &Embedding, i: usize, prefix: usize) -> Vec<u32> {
        let mut row = Self::sorted_row(emb, i);
        row.truncate(prefix);
        row
    }

    /// Assemble per-row *full* sorted lists (in row order) into a table.
    pub fn assemble(emb: &Embedding, rows: Vec<Vec<u32>>) -> DistanceTable {
        let row_len = emb.n.saturating_sub(1);
        Self::assemble_with(emb, rows, row_len)
    }

    /// Assemble per-row sorted lists of uniform length `row_len` (the
    /// truncation prefix, or `n - 1` for a full table).
    pub fn assemble_with(emb: &Embedding, rows: Vec<Vec<u32>>, row_len: usize) -> DistanceTable {
        let n = emb.n;
        assert_eq!(rows.len(), n);
        let mut neighbors = Vec::with_capacity(n * row_len);
        for r in &rows {
            assert_eq!(r.len(), row_len);
            neighbors.extend_from_slice(r);
        }
        DistanceTable {
            neighbors,
            row_len,
            n,
            vecs: emb.vecs.clone(),
            t0: emb.t0,
            fallbacks: AtomicU64::new(0),
        }
    }

    /// Entries stored per row (`n - 1` when full).
    pub fn row_len(&self) -> usize {
        self.row_len
    }

    /// True when rows store a truncated prefix.
    pub fn is_truncated(&self) -> bool {
        self.row_len < self.n.saturating_sub(1)
    }

    /// Times a truncated query ran out of prefix and used the brute-force
    /// fallback (0 for full tables).
    pub fn fallback_queries(&self) -> u64 {
        self.fallbacks.load(Ordering::Relaxed)
    }

    /// Serialized size for broadcast cost accounting: `O(n * row_len)`
    /// indices plus the `O(n * EMAX)` manifold copy.
    pub fn size_bytes(&self) -> usize {
        self.neighbors.len() * 4 + self.vecs.len() * 4
    }

    /// Squared distance between manifold rows (recomputed, EMAX-padded).
    #[inline]
    fn sq_dist(&self, i: usize, j: usize) -> f32 {
        sq_dist_flat(&self.vecs, i, j)
    }

    /// k-NN of manifold row `qi` restricted to library members, by walking
    /// the precomputed list. `mask` marks member rows (packed);
    /// `targets[j]` is the target value of manifold row j (the problem's
    /// aligned target column — only member slots are read). `lib_rows`
    /// backs the truncated-prefix fallback. Matches brute-force semantics:
    /// Theiler exclusion on original time, KMAX slots padded with BIG/0.
    #[allow(clippy::too_many_arguments)]
    pub fn query_into(
        &self,
        qi: usize,
        lib_rows: &[usize],
        mask: &LibraryMask,
        targets: &[f32],
        theiler: f32,
        out_d: &mut [f32],
        out_t: &mut [f32],
    ) {
        debug_assert_eq!(mask.n(), self.n);
        let row = &self.neighbors[qi * self.row_len..(qi + 1) * self.row_len];
        walk_row_into(
            row,
            qi,
            &self.vecs,
            self.t0,
            lib_rows,
            mask,
            targets,
            theiler,
            &self.fallbacks,
            out_d,
            out_t,
        );
    }

    /// Batch query into reused flat `[n, KMAX]` buffers (the standard CCM
    /// prediction set is the whole manifold). Buffers are resized in place
    /// — with a [`crate::ccm::backend::TaskArena`] nothing allocates after
    /// the first sample.
    pub fn query_all_into(
        &self,
        lib_rows: &[usize],
        mask: &LibraryMask,
        targets: &[f32],
        theiler: f32,
        dvals: &mut Vec<f32>,
        tvals: &mut Vec<f32>,
    ) {
        // size-only resize: query_into overwrites all KMAX slots per row,
        // so a correctly-shaped arena buffer needs no per-sample memset
        if dvals.len() != self.n * KMAX {
            dvals.resize(self.n * KMAX, 0.0);
        }
        if tvals.len() != self.n * KMAX {
            tvals.resize(self.n * KMAX, 0.0);
        }
        for qi in 0..self.n {
            self.query_into(
                qi,
                lib_rows,
                mask,
                targets,
                theiler,
                &mut dvals[qi * KMAX..(qi + 1) * KMAX],
                &mut tvals[qi * KMAX..(qi + 1) * KMAX],
            );
        }
    }

    /// Allocating batch query (tests and one-off analysis).
    pub fn query_all(
        &self,
        lib_rows: &[usize],
        mask: &LibraryMask,
        targets: &[f32],
        theiler: f32,
    ) -> NeighborPanels {
        let mut dvals = Vec::new();
        let mut tvals = Vec::new();
        self.query_all_into(lib_rows, mask, targets, theiler, &mut dvals, &mut tvals);
        NeighborPanels { dvals, tvals, n_pred: self.n }
    }

    /// Split into `num_shards` contiguous row-range shards (clamped to at
    /// least one row per shard). Each shard copies its slice of the
    /// neighbour index plus the shared manifold; together the shards
    /// reproduce this table's queries bit-for-bit.
    pub fn shard(&self, num_shards: usize) -> ShardedTable {
        let bounds = shard_bounds(self.n, num_shards);
        let shards = bounds
            .into_iter()
            .enumerate()
            .map(|(sid, (lo, hi))| {
                Arc::new(TableShard {
                    shard_id: sid,
                    row_lo: lo,
                    row_hi: hi,
                    neighbors: self.neighbors[lo * self.row_len..hi * self.row_len].to_vec(),
                    row_len: self.row_len,
                    n: self.n,
                    vecs: self.vecs.clone(),
                    t0: self.t0,
                    fallbacks: AtomicU64::new(0),
                    wire_key: OnceLock::new(),
                })
            })
            .collect();
        ShardedTable { shards, n: self.n, row_len: self.row_len }
    }
}

/// Squared EMAX-padded distance between rows `i` and `j` of a flat
/// `[n, EMAX]` manifold — the one distance kernel every query path shares.
#[inline]
fn sq_dist_flat(vecs: &[f32], i: usize, j: usize) -> f32 {
    let a = &vecs[i * EMAX..(i + 1) * EMAX];
    let b = &vecs[j * EMAX..(j + 1) * EMAX];
    let mut d = 0.0f32;
    for l in 0..EMAX {
        let diff = a[l] - b[l];
        d += diff * diff;
    }
    d
}

/// The sorted-prefix walk shared by [`DistanceTable`] and [`TableShard`]
/// (one implementation → shard queries are bit-identical by construction).
/// `row` is query row `qi`'s stored neighbour prefix (global manifold
/// indices ascending by distance); see [`DistanceTable::query_into`] for
/// the contract.
#[allow(clippy::too_many_arguments)]
fn walk_row_into(
    row: &[u32],
    qi: usize,
    vecs: &[f32],
    t0: usize,
    lib_rows: &[usize],
    mask: &LibraryMask,
    targets: &[f32],
    theiler: f32,
    fallbacks: &AtomicU64,
    out_d: &mut [f32],
    out_t: &mut [f32],
) {
    debug_assert!(out_d.len() >= KMAX && out_t.len() >= KMAX);
    out_d[..KMAX].fill(BIG);
    out_t[..KMAX].fill(0.0);
    let qt = (t0 + qi) as f32;
    // The row never lists qi itself, so a member query point can see
    // at most members-1 rows: count against the reachable total.
    let reachable = mask.members() - usize::from(mask.contains(qi));
    let mut found = 0usize;
    let mut seen = 0usize;
    for &j in row {
        let j = j as usize;
        if !mask.contains(j) {
            continue;
        }
        seen += 1;
        if theiler >= 0.0 && ((t0 + j) as f32 - qt).abs() <= theiler {
            continue;
        }
        out_d[found] = sq_dist_flat(vecs, qi, j);
        out_t[found] = targets[j];
        found += 1;
        if found == KMAX {
            return;
        }
    }
    if seen == reachable {
        // every member lay inside the stored prefix: the padded result
        // is exactly what the full walk would produce.
        return;
    }
    // Truncated prefix exhausted with members unseen: exact counted
    // fallback — brute-force k-NN over the library rows for this query.
    fallbacks.fetch_add(1, Ordering::Relaxed);
    brute_scan_into(vecs, t0, qi, lib_rows, targets, theiler, out_d, out_t);
}

/// Exact brute-force k-NN over `lib_rows` for query row `qi`, reproducing
/// the sorted-walk semantics: self excluded, Theiler on original time,
/// ties to the lower manifold row (lib_rows ascending + strict-less
/// insertion).
#[allow(clippy::too_many_arguments)]
fn brute_scan_into(
    vecs: &[f32],
    t0: usize,
    qi: usize,
    lib_rows: &[usize],
    targets: &[f32],
    theiler: f32,
    out_d: &mut [f32],
    out_t: &mut [f32],
) {
    out_d[..KMAX].fill(BIG);
    out_t[..KMAX].fill(0.0);
    let qt = (t0 + qi) as f32;
    let mut worst = BIG;
    for &j in lib_rows {
        if j == qi {
            continue; // the sorted row never lists the point itself
        }
        if theiler >= 0.0 && ((t0 + j) as f32 - qt).abs() <= theiler {
            continue;
        }
        let d = sq_dist_flat(vecs, qi, j);
        if d >= worst {
            continue;
        }
        let mut pos = KMAX - 1;
        while pos > 0 && d < out_d[pos - 1] {
            out_d[pos] = out_d[pos - 1];
            out_t[pos] = out_t[pos - 1];
            pos -= 1;
        }
        out_d[pos] = d;
        out_t[pos] = targets[j];
        worst = out_d[KMAX - 1];
    }
}

/// Contiguous `[lo, hi)` row ranges distributing `n` rows over
/// `num_shards` shards as evenly as possible (Spark-style range split;
/// clamped so no shard is empty).
pub fn shard_bounds(n: usize, num_shards: usize) -> Vec<(usize, usize)> {
    let s = num_shards.clamp(1, n.max(1));
    (0..s).map(|i| (i * n / s, (i + 1) * n / s)).collect()
}

/// One contiguous row-range slice of a distance table: the sorted
/// neighbour prefixes for query rows `[row_lo, row_hi)` plus the shared
/// `O(n * EMAX)` manifold copy (distance recomputation + the brute-force
/// fallback need every candidate's coordinates, not just this range's).
///
/// This is the unit that ships to a worker node/process: `size_bytes()`
/// is what the DES charges for its broadcast, and `wire_id()` is the
/// content-addressed identity the process wire protocol deduplicates on.
pub struct TableShard {
    pub shard_id: usize,
    /// First query row this shard owns.
    pub row_lo: usize,
    /// One past the last query row this shard owns.
    pub row_hi: usize,
    /// Flat `[row_hi - row_lo, row_len]` sorted prefixes (global indices).
    neighbors: Vec<u32>,
    /// Entries stored per row.
    row_len: usize,
    /// Full manifold size (mask and fallback operate globally).
    pub n: usize,
    /// Full EMAX-padded manifold copy.
    vecs: Vec<f32>,
    /// Time index of manifold row 0.
    pub t0: usize,
    fallbacks: AtomicU64,
    wire_key: OnceLock<u64>,
}

impl TableShard {
    /// Assemble a shard from per-row sorted prefixes (uniform `row_len`),
    /// rows `row_lo..row_lo + rows.len()` — the parallel-build path used
    /// by the sharded table pipeline.
    pub fn assemble_with(
        emb: &Embedding,
        shard_id: usize,
        row_lo: usize,
        rows: Vec<Vec<u32>>,
        row_len: usize,
    ) -> TableShard {
        let mut neighbors = Vec::with_capacity(rows.len() * row_len);
        for r in &rows {
            assert_eq!(r.len(), row_len);
            neighbors.extend_from_slice(r);
        }
        TableShard {
            shard_id,
            row_lo,
            row_hi: row_lo + rows.len(),
            neighbors,
            row_len,
            n: emb.n,
            vecs: emb.vecs.clone(),
            t0: emb.t0,
            fallbacks: AtomicU64::new(0),
            wire_key: OnceLock::new(),
        }
    }

    /// Rebuild a shard from raw wire parts (worker side of the process
    /// protocol).
    #[allow(clippy::too_many_arguments)]
    pub fn from_parts(
        shard_id: usize,
        row_lo: usize,
        row_hi: usize,
        row_len: usize,
        n: usize,
        t0: usize,
        neighbors: Vec<u32>,
        vecs: Vec<f32>,
    ) -> TableShard {
        assert_eq!(neighbors.len(), (row_hi - row_lo) * row_len);
        assert_eq!(vecs.len(), n * EMAX);
        TableShard {
            shard_id,
            row_lo,
            row_hi,
            neighbors,
            row_len,
            n,
            vecs,
            t0,
            fallbacks: AtomicU64::new(0),
            wire_key: OnceLock::new(),
        }
    }

    /// Number of query rows this shard owns.
    pub fn num_rows(&self) -> usize {
        self.row_hi - self.row_lo
    }

    /// Entries stored per row.
    pub fn row_len(&self) -> usize {
        self.row_len
    }

    /// True when `row` is one of this shard's query rows.
    pub fn contains_row(&self, row: usize) -> bool {
        (self.row_lo..self.row_hi).contains(&row)
    }

    /// Raw sorted-prefix slice and manifold (wire serialization).
    pub fn raw_parts(&self) -> (&[u32], &[f32]) {
        (&self.neighbors, &self.vecs)
    }

    /// Queries that exhausted a truncated prefix on this shard.
    pub fn fallback_queries(&self) -> u64 {
        self.fallbacks.load(Ordering::Relaxed)
    }

    /// Broadcast bytes: this shard's index slice + the manifold copy.
    pub fn size_bytes(&self) -> usize {
        self.neighbors.len() * 4 + self.vecs.len() * 4
    }

    /// Content hash identifying this shard on the wire (computed once).
    pub fn wire_id(&self) -> u64 {
        *self.wire_key.get_or_init(|| {
            let mut h = FNV_OFFSET;
            for x in [self.n, self.shard_id, self.row_lo, self.row_hi, self.row_len, self.t0] {
                h = fnv1a64_word(h, x as u64);
            }
            for &v in &self.neighbors {
                h = fnv1a64_word(h, v as u64);
            }
            for &v in &self.vecs {
                h = fnv1a64_word(h, v.to_bits() as u64);
            }
            h
        })
    }

    /// [`DistanceTable::query_into`] for a row this shard owns (panics
    /// otherwise) — same walk, same fallback, bit-identical output.
    #[allow(clippy::too_many_arguments)]
    pub fn query_into(
        &self,
        qi: usize,
        lib_rows: &[usize],
        mask: &LibraryMask,
        targets: &[f32],
        theiler: f32,
        out_d: &mut [f32],
        out_t: &mut [f32],
    ) {
        assert!(
            self.contains_row(qi),
            "row {qi} outside shard {} range {}..{}",
            self.shard_id,
            self.row_lo,
            self.row_hi
        );
        debug_assert_eq!(mask.n(), self.n);
        let local = qi - self.row_lo;
        let row = &self.neighbors[local * self.row_len..(local + 1) * self.row_len];
        walk_row_into(
            row,
            qi,
            &self.vecs,
            self.t0,
            lib_rows,
            mask,
            targets,
            theiler,
            &self.fallbacks,
            out_d,
            out_t,
        );
    }

    /// Batch query over **this shard's rows only**, into reused flat
    /// `[num_rows, KMAX]` buffers (the per-shard task body).
    pub fn query_rows_into(
        &self,
        lib_rows: &[usize],
        mask: &LibraryMask,
        targets: &[f32],
        theiler: f32,
        dvals: &mut Vec<f32>,
        tvals: &mut Vec<f32>,
    ) {
        let rows = self.num_rows();
        if dvals.len() != rows * KMAX {
            dvals.resize(rows * KMAX, 0.0);
        }
        if tvals.len() != rows * KMAX {
            tvals.resize(rows * KMAX, 0.0);
        }
        for (i, qi) in (self.row_lo..self.row_hi).enumerate() {
            self.query_into(
                qi,
                lib_rows,
                mask,
                targets,
                theiler,
                &mut dvals[i * KMAX..(i + 1) * KMAX],
                &mut tvals[i * KMAX..(i + 1) * KMAX],
            );
        }
    }
}

/// FNV-1a offset basis — the shared starting state for every content
/// hash in the crate (shard wire ids here, broadcast ids in
/// `ccm::process`). One definition: if the hash scheme ever changes, the
/// shard identity and the wire dedup keys move together.
pub(crate) const FNV_OFFSET: u64 = 0xcbf29ce484222325;

/// FNV-1a-style 64-bit word mix for content addressing.
#[inline]
pub(crate) fn fnv1a64_word(mut h: u64, w: u64) -> u64 {
    for b in w.to_le_bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Facade over contiguous [`TableShard`]s covering rows `0..n`: resolves
/// every query row to its owning shard and otherwise mirrors
/// [`DistanceTable`]'s query API bit-for-bit. Shards are `Arc`-shared so
/// the same objects can simultaneously back broadcasts and this facade.
pub struct ShardedTable {
    shards: Vec<Arc<TableShard>>,
    pub n: usize,
    row_len: usize,
}

impl ShardedTable {
    /// Build from shards (must be contiguous from row 0 and cover `0..n`
    /// with a uniform `row_len`).
    pub fn from_shards(shards: Vec<Arc<TableShard>>) -> ShardedTable {
        assert!(!shards.is_empty(), "need at least one shard");
        let row_len = shards[0].row_len;
        let n = shards[0].n;
        let mut next = 0usize;
        for (i, s) in shards.iter().enumerate() {
            assert_eq!(s.row_lo, next, "shard {i} not contiguous");
            assert!(s.row_hi >= s.row_lo);
            assert_eq!(s.row_len, row_len, "shard {i} row_len mismatch");
            assert_eq!(s.n, n, "shard {i} manifold size mismatch");
            next = s.row_hi;
        }
        assert_eq!(next, n, "shards do not cover the manifold");
        ShardedTable { shards, n, row_len }
    }

    pub fn num_shards(&self) -> usize {
        self.shards.len()
    }

    pub fn shards(&self) -> &[Arc<TableShard>] {
        &self.shards
    }

    pub fn row_len(&self) -> usize {
        self.row_len
    }

    /// True when rows store a truncated prefix.
    pub fn is_truncated(&self) -> bool {
        self.row_len < self.n.saturating_sub(1)
    }

    /// The shard owning query row `row`.
    pub fn shard_of(&self, row: usize) -> &Arc<TableShard> {
        debug_assert!(row < self.n);
        // ranges are sorted by row_lo: last shard with row_lo <= row
        let idx = self.shards.partition_point(|s| s.row_lo <= row) - 1;
        &self.shards[idx]
    }

    /// Sum of per-shard broadcast bytes (>= the unsharded table's bytes by
    /// one manifold copy per extra shard — the price of independence).
    pub fn size_bytes(&self) -> usize {
        self.shards.iter().map(|s| s.size_bytes()).sum()
    }

    /// Fallback count summed over shards.
    pub fn fallback_queries(&self) -> u64 {
        self.shards.iter().map(|s| s.fallback_queries()).sum()
    }

    /// [`DistanceTable::query_into`], routed to the owning shard.
    #[allow(clippy::too_many_arguments)]
    pub fn query_into(
        &self,
        qi: usize,
        lib_rows: &[usize],
        mask: &LibraryMask,
        targets: &[f32],
        theiler: f32,
        out_d: &mut [f32],
        out_t: &mut [f32],
    ) {
        self.shard_of(qi).query_into(qi, lib_rows, mask, targets, theiler, out_d, out_t);
    }

    /// [`DistanceTable::query_all_into`] over the shard set: walks shards
    /// in row order, producing the identical flat `[n, KMAX]` layout.
    pub fn query_all_into(
        &self,
        lib_rows: &[usize],
        mask: &LibraryMask,
        targets: &[f32],
        theiler: f32,
        dvals: &mut Vec<f32>,
        tvals: &mut Vec<f32>,
    ) {
        if dvals.len() != self.n * KMAX {
            dvals.resize(self.n * KMAX, 0.0);
        }
        if tvals.len() != self.n * KMAX {
            tvals.resize(self.n * KMAX, 0.0);
        }
        for shard in &self.shards {
            for qi in shard.row_lo..shard.row_hi {
                shard.query_into(
                    qi,
                    lib_rows,
                    mask,
                    targets,
                    theiler,
                    &mut dvals[qi * KMAX..(qi + 1) * KMAX],
                    &mut tvals[qi * KMAX..(qi + 1) * KMAX],
                );
            }
        }
    }

    /// Allocating batch query (tests and one-off analysis).
    pub fn query_all(
        &self,
        lib_rows: &[usize],
        mask: &LibraryMask,
        targets: &[f32],
        theiler: f32,
    ) -> NeighborPanels {
        let mut dvals = Vec::new();
        let mut tvals = Vec::new();
        self.query_all_into(lib_rows, mask, targets, theiler, &mut dvals, &mut tvals);
        NeighborPanels { dvals, tvals, n_pred: self.n }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ccm::knn::knn_batch;
    use crate::timeseries::generators::{coupled_logistic, CoupledLogisticParams};
    use crate::util::rng::Rng;

    fn embedding() -> (Embedding, Vec<f32>) {
        let (x, y) = coupled_logistic(300, CoupledLogisticParams::default());
        let emb = Embedding::new(&y, 3, 2);
        let targets = emb.align_targets(&x);
        (emb, targets)
    }

    fn mask_of(n: usize, rows: &[usize]) -> LibraryMask {
        let mut m = LibraryMask::new();
        m.set_from(n, rows);
        m
    }

    #[test]
    fn mask_packs_and_counts() {
        let m = mask_of(130, &[0, 63, 64, 129]);
        assert!(m.contains(0) && m.contains(63) && m.contains(64) && m.contains(129));
        assert!(!m.contains(1) && !m.contains(65) && !m.contains(128));
        assert_eq!(m.members(), 4);
        assert_eq!(m.n(), 130);
    }

    #[test]
    fn rows_sorted_ascending() {
        let (emb, _) = embedding();
        let table = DistanceTable::build(&emb);
        for i in [0usize, 7, emb.n - 1] {
            let row = &table.neighbors[i * (emb.n - 1)..(i + 1) * (emb.n - 1)];
            assert_eq!(row.len(), emb.n - 1);
            let dists: Vec<f32> = row.iter().map(|&j| table.sq_dist(i, j as usize)).collect();
            assert!(dists.windows(2).all(|w| w[0] <= w[1]), "row {i} not sorted");
            // no self, no duplicates
            assert!(!row.contains(&(i as u32)));
            let mut uniq = row.to_vec();
            uniq.sort_unstable();
            uniq.dedup();
            assert_eq!(uniq.len(), emb.n - 1);
        }
    }

    #[test]
    fn table_query_matches_bruteforce_knn() {
        // THE critical equivalence: paper §3.2 is an optimization, not an
        // approximation. Table-mode k-NN must equal brute force exactly.
        let (emb, targets) = embedding();
        let table = DistanceTable::build(&emb);
        let mut rng = Rng::new(5);
        let rows = rng.sample_indices(emb.n, 120);
        let mask = mask_of(emb.n, &rows);
        let panels = table.query_all(&rows, &mask, &targets, 0.0);

        // brute force over the same library
        let mut lib_vecs = Vec::new();
        let mut lib_targets = Vec::new();
        let mut lib_times = Vec::new();
        for &r in &rows {
            lib_vecs.extend_from_slice(emb.point(r));
            lib_targets.push(targets[r]);
            lib_times.push(emb.time_of(r) as f32);
        }
        let pred_times: Vec<f32> = (0..emb.n).map(|i| emb.time_of(i) as f32).collect();
        let (bd, bt) = knn_batch(&emb.vecs, &pred_times, &lib_vecs, &lib_targets, &lib_times, 0.0);

        for i in 0..emb.n * KMAX {
            assert!(
                (panels.dvals[i] - bd[i]).abs() < 1e-5,
                "dval mismatch at {i}: {} vs {}",
                panels.dvals[i],
                bd[i]
            );
            assert_eq!(panels.tvals[i], bt[i], "tval mismatch at {i}");
        }
    }

    #[test]
    fn truncated_table_bit_identical_to_full() {
        let (emb, targets) = embedding();
        let full = DistanceTable::build(&emb);
        let mut rng = Rng::new(9);
        for (l, prefix) in [(120usize, 64usize), (40, 32), (12, KMAX), (emb.n, KMAX)] {
            let rows = rng.sample_indices(emb.n, l.min(emb.n));
            let mask = mask_of(emb.n, &rows);
            let trunc = DistanceTable::build_truncated(&emb, prefix);
            assert!(trunc.is_truncated());
            let a = full.query_all(&rows, &mask, &targets, 0.0);
            let b = trunc.query_all(&rows, &mask, &targets, 0.0);
            assert_eq!(a.dvals, b.dvals, "l={l} prefix={prefix}");
            assert_eq!(a.tvals, b.tvals, "l={l} prefix={prefix}");
        }
    }

    #[test]
    fn sparse_library_takes_counted_fallback_and_stays_exact() {
        let (emb, targets) = embedding();
        let full = DistanceTable::build(&emb);
        // library so sparse that a KMAX-deep prefix can't see all members
        let rows = vec![3usize, 40, 80, 150, 200];
        let mask = mask_of(emb.n, &rows);
        let trunc = DistanceTable::build_truncated(&emb, KMAX);
        let a = full.query_all(&rows, &mask, &targets, 0.0);
        let b = trunc.query_all(&rows, &mask, &targets, 0.0);
        assert_eq!(a.dvals, b.dvals);
        assert_eq!(a.tvals, b.tvals);
        assert!(
            trunc.fallback_queries() > 0,
            "a 5-member library must exhaust a KMAX-deep prefix somewhere"
        );
        assert_eq!(full.fallback_queries(), 0, "full tables never fall back");
    }

    #[test]
    fn theiler_respected_in_table_query() {
        let (emb, targets) = embedding();
        let table = DistanceTable::build(&emb);
        let all_rows: Vec<usize> = (0..emb.n).collect();
        let mask = mask_of(emb.n, &all_rows);
        let mut d = [0.0; KMAX];
        let mut t = [0.0; KMAX];
        // theiler = 5: all neighbours at least 6 steps away in time
        table.query_into(50, &all_rows, &mask, &targets, 5.0, &mut d, &mut t);
        // verify by brute force over allowed rows
        let best = (0..emb.n)
            .filter(|&j| (j as i64 - 50).abs() > 5)
            .map(|j| table.sq_dist(50, j))
            .fold(f32::INFINITY, f32::min);
        assert!((d[0] - best).abs() < 1e-6);
    }

    #[test]
    fn sparse_library_pads_with_big() {
        let (emb, targets) = embedding();
        let table = DistanceTable::build(&emb);
        let rows = vec![3usize, 40, 80]; // only 3 members
        let mask = mask_of(emb.n, &rows);
        let mut d = [0.0; KMAX];
        let mut t = [0.0; KMAX];
        table.query_into(10, &rows, &mask, &targets, 0.0, &mut d, &mut t);
        assert!(d[0] < BIG && d[1] < BIG && d[2] < BIG);
        assert_eq!(d[3], BIG);
        assert_eq!(t[3], 0.0);
    }

    #[test]
    fn size_accounting() {
        let (emb, _) = embedding();
        let table = DistanceTable::build(&emb);
        assert_eq!(table.size_bytes(), emb.n * (emb.n - 1) * 4 + emb.n * EMAX * 4);
        // truncated: O(n * P) indices instead of O(n^2)
        let trunc = DistanceTable::build_truncated(&emb, 40);
        assert_eq!(trunc.size_bytes(), emb.n * 40 * 4 + emb.n * EMAX * 4);
        assert_eq!(trunc.row_len(), 40);
    }

    #[test]
    fn shard_bounds_cover_and_clamp() {
        assert_eq!(shard_bounds(10, 1), vec![(0, 10)]);
        assert_eq!(shard_bounds(10, 3), vec![(0, 3), (3, 6), (6, 10)]);
        // more shards than rows: clamped to one row per shard
        assert_eq!(shard_bounds(2, 5).len(), 2);
        for s in 1..=7 {
            let b = shard_bounds(97, s);
            assert_eq!(b[0].0, 0);
            assert_eq!(b.last().unwrap().1, 97);
            assert!(b.windows(2).all(|w| w[0].1 == w[1].0), "contiguous");
            assert!(b.iter().all(|&(lo, hi)| hi > lo), "non-empty");
        }
    }

    #[test]
    fn sharded_queries_bit_identical_incl_edges() {
        let (emb, targets) = embedding();
        let table = DistanceTable::build(&emb);
        let sharded = table.shard(4);
        assert_eq!(sharded.num_shards(), 4);
        let mut rng = Rng::new(11);
        let rows = rng.sample_indices(emb.n, 90);
        let mask = mask_of(emb.n, &rows);
        // every shard-boundary row (first and last of each range) plus a
        // full batch sweep must match the unsharded table exactly
        let mut d0 = [0.0; KMAX];
        let mut t0v = [0.0; KMAX];
        let mut d1 = [0.0; KMAX];
        let mut t1v = [0.0; KMAX];
        for shard in sharded.shards() {
            for qi in [shard.row_lo, shard.row_hi - 1] {
                assert!(shard.contains_row(qi));
                assert!(std::ptr::eq(sharded.shard_of(qi).as_ref(), shard.as_ref()));
                table.query_into(qi, &rows, &mask, &targets, 0.0, &mut d0, &mut t0v);
                sharded.query_into(qi, &rows, &mask, &targets, 0.0, &mut d1, &mut t1v);
                assert_eq!(d0, d1, "edge row {qi}");
                assert_eq!(t0v, t1v, "edge row {qi}");
            }
        }
        let a = table.query_all(&rows, &mask, &targets, 0.0);
        let b = sharded.query_all(&rows, &mask, &targets, 0.0);
        assert_eq!(a.dvals, b.dvals);
        assert_eq!(a.tvals, b.tvals);
    }

    #[test]
    fn single_shard_degenerate_equals_table() {
        let (emb, targets) = embedding();
        let table = DistanceTable::build_truncated(&emb, 48);
        let sharded = table.shard(1);
        assert_eq!(sharded.num_shards(), 1);
        assert_eq!(sharded.row_len(), table.row_len());
        assert!(sharded.is_truncated());
        let mut rng = Rng::new(13);
        let rows = rng.sample_indices(emb.n, 60);
        let mask = mask_of(emb.n, &rows);
        let a = table.query_all(&rows, &mask, &targets, 0.0);
        let b = sharded.query_all(&rows, &mask, &targets, 0.0);
        assert_eq!(a.dvals, b.dvals);
        assert_eq!(a.tvals, b.tvals);
    }

    #[test]
    fn shard_with_no_local_library_forces_fallback_and_stays_exact() {
        // library entirely outside one shard's row range, prefix so short
        // the shard's queries exhaust it: the shard must take the counted
        // brute-force fallback and still agree with the full table.
        let (emb, targets) = embedding();
        let full = DistanceTable::build(&emb);
        let trunc = DistanceTable::build_truncated(&emb, KMAX);
        let sharded = trunc.shard(3);
        let first = Arc::clone(&sharded.shards()[0]);
        // members only from the LAST shard's range, far from shard 0
        let lo = sharded.shards()[2].row_lo;
        let rows: Vec<usize> = (lo..emb.n).step_by(17).collect();
        assert!(rows.len() >= 4, "need a non-trivial sparse library");
        let mask = mask_of(emb.n, &rows);
        let a = full.query_all(&rows, &mask, &targets, 0.0);
        let b = sharded.query_all(&rows, &mask, &targets, 0.0);
        assert_eq!(a.dvals, b.dvals);
        assert_eq!(a.tvals, b.tvals);
        assert!(
            first.fallback_queries() > 0,
            "shard 0 has no nearby members in a KMAX prefix: must fall back"
        );
    }

    #[test]
    fn shard_accounting_and_wire_identity() {
        let (emb, _) = embedding();
        let table = DistanceTable::build_truncated(&emb, 32);
        let sharded = table.shard(4);
        // sum of shard bytes = index bytes + one manifold copy per shard
        let idx_bytes = emb.n * 32 * 4;
        assert_eq!(sharded.size_bytes(), idx_bytes + 4 * emb.n * EMAX * 4);
        // wire ids: stable per shard, distinct across shards
        for s in sharded.shards() {
            assert_eq!(s.wire_id(), s.wire_id());
        }
        let mut ids: Vec<u64> = sharded.shards().iter().map(|s| s.wire_id()).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 4, "shard wire ids must be distinct");
        // from_parts round-trips a shard into an identical wire identity
        let s0 = &sharded.shards()[0];
        let (nbrs, vecs) = s0.raw_parts();
        let rebuilt = TableShard::from_parts(
            s0.shard_id,
            s0.row_lo,
            s0.row_hi,
            s0.row_len(),
            s0.n,
            s0.t0,
            nbrs.to_vec(),
            vecs.to_vec(),
        );
        assert_eq!(rebuilt.wire_id(), s0.wire_id());
    }

    #[test]
    fn auto_prefix_scales_with_density() {
        // dense library: short prefix; sparse library: longer; always
        // clamped to the full row.
        let dense = DistanceTable::auto_prefix(1000, 500);
        let sparse = DistanceTable::auto_prefix(1000, 50);
        assert!(dense < sparse);
        assert!(sparse <= 999);
        assert!(dense >= KMAX);
        assert_eq!(DistanceTable::auto_prefix(10, 1), 9);
    }
}
