//! The distance indexing table (paper §3.2) — the headline optimization.
//!
//! Brute-force CCM recomputes, **per subsample**, the distances from every
//! prediction point to the L library points and re-selects the top E+1 —
//! `O(r * n * L)` distance work plus selection. The paper instead builds,
//! once per `(E, tau)`, a table over the *whole* embedded series: for each
//! manifold point, all other points sorted by distance. The table is
//! broadcast to every worker; each subsample's k-NN then degenerates to
//! walking the precomputed sorted list and keeping the first E+1 entries
//! that are members of the sampled library — no distance computation, no
//! sorting, expected `O(n/L * k)` walk per query.
//!
//! # Truncated mode
//!
//! A query only ever *walks* an expected `O(n/L * KMAX)` prefix of each
//! sorted row, yet the full table broadcasts all `n * (n-1)` entries
//! (~64 MB at n = 4000). Truncated mode stores only the top-P prefix per
//! row — P sized from the smallest library density via
//! [`DistanceTable::auto_prefix`] — cutting the broadcast to `O(n * P)`
//! bytes. Correctness is preserved *exactly*: while walking, the query
//! counts the library members it has seen; if the prefix is exhausted
//! before KMAX neighbours are found **and** unseen members remain, it
//! falls back to a brute-force scan of the library rows for that one
//! query. The fallback reproduces the walk's semantics bit-for-bit
//! (identical distance arithmetic, ties to the lower manifold row), so
//! truncated-table results are bit-identical to full-table and
//! brute-force k-NN; [`DistanceTable::fallback_queries`] counts how often
//! the prefix ran dry.
//!
//! Memory: `n * row_len` u32 indices. Neighbour *distances* are recomputed
//! on the fly for accepted entries only (k per query), saving 8x memory
//! over storing them.

use std::sync::atomic::{AtomicU64, Ordering};

use crate::ccm::backend::NeighborPanels;
use crate::ccm::embedding::Embedding;
use crate::{BIG, EMAX, KMAX};

/// Library membership as a packed u64 bitset over manifold rows, refilled
/// per sample from a [`crate::ccm::backend::TaskArena`] without
/// reallocating. Replaces the old one-byte-per-row mask: 8x smaller, and
/// clearing between samples is an `O(n/64)` word fill.
#[derive(Default)]
pub struct LibraryMask {
    words: Vec<u64>,
    n: usize,
    members: usize,
}

impl LibraryMask {
    pub fn new() -> LibraryMask {
        LibraryMask::default()
    }

    /// Reset to an `n`-row manifold with the given member rows set.
    pub fn set_from(&mut self, n: usize, rows: &[usize]) {
        let n_words = n.div_ceil(64);
        self.words.clear();
        self.words.resize(n_words, 0);
        self.n = n;
        for &r in rows {
            debug_assert!(r < n);
            self.words[r >> 6] |= 1u64 << (r & 63);
        }
        self.members = rows.len();
    }

    #[inline]
    pub fn contains(&self, row: usize) -> bool {
        (self.words[row >> 6] >> (row & 63)) & 1 == 1
    }

    /// Number of member rows.
    pub fn members(&self) -> usize {
        self.members
    }

    /// Manifold size this mask covers.
    pub fn n(&self) -> usize {
        self.n
    }
}

/// Sorted-neighbour index over a full shadow manifold (full or truncated
/// prefix per row — see the module docs).
pub struct DistanceTable {
    /// Flat `[n, row_len]`: row i lists other manifold rows ascending by
    /// distance to i (ties by index); the first `row_len` of them.
    neighbors: Vec<u32>,
    /// Entries stored per row: `n - 1` (full) or the truncation prefix P.
    row_len: usize,
    /// Number of manifold points.
    pub n: usize,
    /// The manifold the table indexes (owned copy of the flat vectors —
    /// needed to recompute accepted-neighbour distances and to serve the
    /// sparse-library brute-force fallback).
    vecs: Vec<f32>,
    /// Time index of row 0 (Theiler windows work on original time).
    pub t0: usize,
    /// Queries that exhausted a truncated prefix and fell back to the
    /// brute-force scan (observability; relaxed counter).
    fallbacks: AtomicU64,
}

impl DistanceTable {
    /// Build the full table serially. The parallel build used by the
    /// pipelines is [`DistanceTable::sorted_row`] + [`DistanceTable::assemble`].
    pub fn build(emb: &Embedding) -> DistanceTable {
        let rows: Vec<Vec<u32>> = (0..emb.n).map(|i| Self::sorted_row(emb, i)).collect();
        Self::assemble(emb, rows)
    }

    /// Build a truncated table serially, keeping the top-`prefix` entries
    /// per row.
    pub fn build_truncated(emb: &Embedding, prefix: usize) -> DistanceTable {
        let row_len = prefix.min(emb.n.saturating_sub(1));
        let rows: Vec<Vec<u32>> =
            (0..emb.n).map(|i| Self::sorted_row_prefix(emb, i, row_len)).collect();
        Self::assemble_with(emb, rows, row_len)
    }

    /// Prefix length for truncated mode: the expected walk length to find
    /// KMAX members at the sparsest library density `min_l / n`, with 4x
    /// headroom so the exact brute-force fallback stays rare. Clamped to
    /// the full row length.
    pub fn auto_prefix(n: usize, min_l: usize) -> usize {
        let full = n.saturating_sub(1);
        let min_l = min_l.max(1);
        let expected = KMAX * n.div_ceil(min_l);
        (expected * 4).max(KMAX).min(full)
    }

    /// Compute the sorted neighbour list of manifold row `i` — the unit of
    /// parallel table construction (each engine task handles a chunk of
    /// rows).
    ///
    /// §Perf: squared distances are non-negative, so their IEEE-754 bit
    /// patterns are order-monotone; packing `(dist_bits << 32) | index`
    /// into a u64 replaces the branchy `partial_cmp` comparator sort with
    /// a plain integer sort (ties fall through to the index — exactly the
    /// lowest-index tie-break the kernels use). ~2.3x faster build.
    pub fn sorted_row(emb: &Embedding, i: usize) -> Vec<u32> {
        let n = emb.n;
        let a = emb.point(i);
        let mut keys: Vec<u64> = Vec::with_capacity(n - 1);
        for j in 0..n {
            if j == i {
                continue;
            }
            let b = emb.point(j);
            let mut d = 0.0f32;
            for l in 0..EMAX {
                let diff = a[l] - b[l];
                d += diff * diff;
            }
            keys.push(((d.to_bits() as u64) << 32) | j as u64);
        }
        keys.sort_unstable();
        keys.into_iter().map(|k| k as u32).collect()
    }

    /// [`DistanceTable::sorted_row`] truncated to its top-`prefix` entries
    /// — the unit of parallel *truncated* construction. Truncating inside
    /// the task also shrinks what the driver collects.
    pub fn sorted_row_prefix(emb: &Embedding, i: usize, prefix: usize) -> Vec<u32> {
        let mut row = Self::sorted_row(emb, i);
        row.truncate(prefix);
        row
    }

    /// Assemble per-row *full* sorted lists (in row order) into a table.
    pub fn assemble(emb: &Embedding, rows: Vec<Vec<u32>>) -> DistanceTable {
        let row_len = emb.n.saturating_sub(1);
        Self::assemble_with(emb, rows, row_len)
    }

    /// Assemble per-row sorted lists of uniform length `row_len` (the
    /// truncation prefix, or `n - 1` for a full table).
    pub fn assemble_with(emb: &Embedding, rows: Vec<Vec<u32>>, row_len: usize) -> DistanceTable {
        let n = emb.n;
        assert_eq!(rows.len(), n);
        let mut neighbors = Vec::with_capacity(n * row_len);
        for r in &rows {
            assert_eq!(r.len(), row_len);
            neighbors.extend_from_slice(r);
        }
        DistanceTable {
            neighbors,
            row_len,
            n,
            vecs: emb.vecs.clone(),
            t0: emb.t0,
            fallbacks: AtomicU64::new(0),
        }
    }

    /// Entries stored per row (`n - 1` when full).
    pub fn row_len(&self) -> usize {
        self.row_len
    }

    /// True when rows store a truncated prefix.
    pub fn is_truncated(&self) -> bool {
        self.row_len < self.n.saturating_sub(1)
    }

    /// Times a truncated query ran out of prefix and used the brute-force
    /// fallback (0 for full tables).
    pub fn fallback_queries(&self) -> u64 {
        self.fallbacks.load(Ordering::Relaxed)
    }

    /// Serialized size for broadcast cost accounting: `O(n * row_len)`
    /// indices plus the `O(n * EMAX)` manifold copy.
    pub fn size_bytes(&self) -> usize {
        self.neighbors.len() * 4 + self.vecs.len() * 4
    }

    /// Squared distance between manifold rows (recomputed, EMAX-padded).
    #[inline]
    fn sq_dist(&self, i: usize, j: usize) -> f32 {
        let a = &self.vecs[i * EMAX..(i + 1) * EMAX];
        let b = &self.vecs[j * EMAX..(j + 1) * EMAX];
        let mut d = 0.0f32;
        for l in 0..EMAX {
            let diff = a[l] - b[l];
            d += diff * diff;
        }
        d
    }

    /// k-NN of manifold row `qi` restricted to library members, by walking
    /// the precomputed list. `mask` marks member rows (packed);
    /// `targets[j]` is the target value of manifold row j (the problem's
    /// aligned target column — only member slots are read). `lib_rows`
    /// backs the truncated-prefix fallback. Matches brute-force semantics:
    /// Theiler exclusion on original time, KMAX slots padded with BIG/0.
    pub fn query_into(
        &self,
        qi: usize,
        lib_rows: &[usize],
        mask: &LibraryMask,
        targets: &[f32],
        theiler: f32,
        out_d: &mut [f32],
        out_t: &mut [f32],
    ) {
        debug_assert!(out_d.len() >= KMAX && out_t.len() >= KMAX);
        debug_assert_eq!(mask.n(), self.n);
        out_d[..KMAX].fill(BIG);
        out_t[..KMAX].fill(0.0);
        let row = &self.neighbors[qi * self.row_len..(qi + 1) * self.row_len];
        let qt = (self.t0 + qi) as f32;
        // The row never lists qi itself, so a member query point can see
        // at most members-1 rows: count against the reachable total.
        let reachable = mask.members() - usize::from(mask.contains(qi));
        let mut found = 0usize;
        let mut seen = 0usize;
        for &j in row {
            let j = j as usize;
            if !mask.contains(j) {
                continue;
            }
            seen += 1;
            if theiler >= 0.0 && ((self.t0 + j) as f32 - qt).abs() <= theiler {
                continue;
            }
            out_d[found] = self.sq_dist(qi, j);
            out_t[found] = targets[j];
            found += 1;
            if found == KMAX {
                return;
            }
        }
        if seen == reachable {
            // every member lay inside the stored prefix: the padded result
            // is exactly what the full walk would produce.
            return;
        }
        // Truncated prefix exhausted with members unseen: exact counted
        // fallback — brute-force k-NN over the library rows for this query.
        self.fallbacks.fetch_add(1, Ordering::Relaxed);
        self.brute_query_into(qi, lib_rows, targets, theiler, out_d, out_t);
    }

    /// Exact brute-force k-NN over `lib_rows` for query row `qi`,
    /// reproducing the sorted-walk semantics: self excluded, Theiler on
    /// original time, ties to the lower manifold row (lib_rows ascending +
    /// strict-less insertion).
    fn brute_query_into(
        &self,
        qi: usize,
        lib_rows: &[usize],
        targets: &[f32],
        theiler: f32,
        out_d: &mut [f32],
        out_t: &mut [f32],
    ) {
        out_d[..KMAX].fill(BIG);
        out_t[..KMAX].fill(0.0);
        let qt = (self.t0 + qi) as f32;
        let mut worst = BIG;
        for &j in lib_rows {
            if j == qi {
                continue; // the sorted row never lists the point itself
            }
            if theiler >= 0.0 && ((self.t0 + j) as f32 - qt).abs() <= theiler {
                continue;
            }
            let d = self.sq_dist(qi, j);
            if d >= worst {
                continue;
            }
            let mut pos = KMAX - 1;
            while pos > 0 && d < out_d[pos - 1] {
                out_d[pos] = out_d[pos - 1];
                out_t[pos] = out_t[pos - 1];
                pos -= 1;
            }
            out_d[pos] = d;
            out_t[pos] = targets[j];
            worst = out_d[KMAX - 1];
        }
    }

    /// Batch query into reused flat `[n, KMAX]` buffers (the standard CCM
    /// prediction set is the whole manifold). Buffers are resized in place
    /// — with a [`crate::ccm::backend::TaskArena`] nothing allocates after
    /// the first sample.
    pub fn query_all_into(
        &self,
        lib_rows: &[usize],
        mask: &LibraryMask,
        targets: &[f32],
        theiler: f32,
        dvals: &mut Vec<f32>,
        tvals: &mut Vec<f32>,
    ) {
        // size-only resize: query_into overwrites all KMAX slots per row,
        // so a correctly-shaped arena buffer needs no per-sample memset
        if dvals.len() != self.n * KMAX {
            dvals.resize(self.n * KMAX, 0.0);
        }
        if tvals.len() != self.n * KMAX {
            tvals.resize(self.n * KMAX, 0.0);
        }
        for qi in 0..self.n {
            self.query_into(
                qi,
                lib_rows,
                mask,
                targets,
                theiler,
                &mut dvals[qi * KMAX..(qi + 1) * KMAX],
                &mut tvals[qi * KMAX..(qi + 1) * KMAX],
            );
        }
    }

    /// Allocating batch query (tests and one-off analysis).
    pub fn query_all(
        &self,
        lib_rows: &[usize],
        mask: &LibraryMask,
        targets: &[f32],
        theiler: f32,
    ) -> NeighborPanels {
        let mut dvals = Vec::new();
        let mut tvals = Vec::new();
        self.query_all_into(lib_rows, mask, targets, theiler, &mut dvals, &mut tvals);
        NeighborPanels { dvals, tvals, n_pred: self.n }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ccm::knn::knn_batch;
    use crate::timeseries::generators::{coupled_logistic, CoupledLogisticParams};
    use crate::util::rng::Rng;

    fn embedding() -> (Embedding, Vec<f32>) {
        let (x, y) = coupled_logistic(300, CoupledLogisticParams::default());
        let emb = Embedding::new(&y, 3, 2);
        let targets = emb.align_targets(&x);
        (emb, targets)
    }

    fn mask_of(n: usize, rows: &[usize]) -> LibraryMask {
        let mut m = LibraryMask::new();
        m.set_from(n, rows);
        m
    }

    #[test]
    fn mask_packs_and_counts() {
        let m = mask_of(130, &[0, 63, 64, 129]);
        assert!(m.contains(0) && m.contains(63) && m.contains(64) && m.contains(129));
        assert!(!m.contains(1) && !m.contains(65) && !m.contains(128));
        assert_eq!(m.members(), 4);
        assert_eq!(m.n(), 130);
    }

    #[test]
    fn rows_sorted_ascending() {
        let (emb, _) = embedding();
        let table = DistanceTable::build(&emb);
        for i in [0usize, 7, emb.n - 1] {
            let row = &table.neighbors[i * (emb.n - 1)..(i + 1) * (emb.n - 1)];
            assert_eq!(row.len(), emb.n - 1);
            let dists: Vec<f32> = row.iter().map(|&j| table.sq_dist(i, j as usize)).collect();
            assert!(dists.windows(2).all(|w| w[0] <= w[1]), "row {i} not sorted");
            // no self, no duplicates
            assert!(!row.contains(&(i as u32)));
            let mut uniq = row.to_vec();
            uniq.sort_unstable();
            uniq.dedup();
            assert_eq!(uniq.len(), emb.n - 1);
        }
    }

    #[test]
    fn table_query_matches_bruteforce_knn() {
        // THE critical equivalence: paper §3.2 is an optimization, not an
        // approximation. Table-mode k-NN must equal brute force exactly.
        let (emb, targets) = embedding();
        let table = DistanceTable::build(&emb);
        let mut rng = Rng::new(5);
        let rows = rng.sample_indices(emb.n, 120);
        let mask = mask_of(emb.n, &rows);
        let panels = table.query_all(&rows, &mask, &targets, 0.0);

        // brute force over the same library
        let mut lib_vecs = Vec::new();
        let mut lib_targets = Vec::new();
        let mut lib_times = Vec::new();
        for &r in &rows {
            lib_vecs.extend_from_slice(emb.point(r));
            lib_targets.push(targets[r]);
            lib_times.push(emb.time_of(r) as f32);
        }
        let pred_times: Vec<f32> = (0..emb.n).map(|i| emb.time_of(i) as f32).collect();
        let (bd, bt) = knn_batch(&emb.vecs, &pred_times, &lib_vecs, &lib_targets, &lib_times, 0.0);

        for i in 0..emb.n * KMAX {
            assert!(
                (panels.dvals[i] - bd[i]).abs() < 1e-5,
                "dval mismatch at {i}: {} vs {}",
                panels.dvals[i],
                bd[i]
            );
            assert_eq!(panels.tvals[i], bt[i], "tval mismatch at {i}");
        }
    }

    #[test]
    fn truncated_table_bit_identical_to_full() {
        let (emb, targets) = embedding();
        let full = DistanceTable::build(&emb);
        let mut rng = Rng::new(9);
        for (l, prefix) in [(120usize, 64usize), (40, 32), (12, KMAX), (emb.n, KMAX)] {
            let rows = rng.sample_indices(emb.n, l.min(emb.n));
            let mask = mask_of(emb.n, &rows);
            let trunc = DistanceTable::build_truncated(&emb, prefix);
            assert!(trunc.is_truncated());
            let a = full.query_all(&rows, &mask, &targets, 0.0);
            let b = trunc.query_all(&rows, &mask, &targets, 0.0);
            assert_eq!(a.dvals, b.dvals, "l={l} prefix={prefix}");
            assert_eq!(a.tvals, b.tvals, "l={l} prefix={prefix}");
        }
    }

    #[test]
    fn sparse_library_takes_counted_fallback_and_stays_exact() {
        let (emb, targets) = embedding();
        let full = DistanceTable::build(&emb);
        // library so sparse that a KMAX-deep prefix can't see all members
        let rows = vec![3usize, 40, 80, 150, 200];
        let mask = mask_of(emb.n, &rows);
        let trunc = DistanceTable::build_truncated(&emb, KMAX);
        let a = full.query_all(&rows, &mask, &targets, 0.0);
        let b = trunc.query_all(&rows, &mask, &targets, 0.0);
        assert_eq!(a.dvals, b.dvals);
        assert_eq!(a.tvals, b.tvals);
        assert!(
            trunc.fallback_queries() > 0,
            "a 5-member library must exhaust a KMAX-deep prefix somewhere"
        );
        assert_eq!(full.fallback_queries(), 0, "full tables never fall back");
    }

    #[test]
    fn theiler_respected_in_table_query() {
        let (emb, targets) = embedding();
        let table = DistanceTable::build(&emb);
        let all_rows: Vec<usize> = (0..emb.n).collect();
        let mask = mask_of(emb.n, &all_rows);
        let mut d = [0.0; KMAX];
        let mut t = [0.0; KMAX];
        // theiler = 5: all neighbours at least 6 steps away in time
        table.query_into(50, &all_rows, &mask, &targets, 5.0, &mut d, &mut t);
        // verify by brute force over allowed rows
        let best = (0..emb.n)
            .filter(|&j| (j as i64 - 50).abs() > 5)
            .map(|j| table.sq_dist(50, j))
            .fold(f32::INFINITY, f32::min);
        assert!((d[0] - best).abs() < 1e-6);
    }

    #[test]
    fn sparse_library_pads_with_big() {
        let (emb, targets) = embedding();
        let table = DistanceTable::build(&emb);
        let rows = vec![3usize, 40, 80]; // only 3 members
        let mask = mask_of(emb.n, &rows);
        let mut d = [0.0; KMAX];
        let mut t = [0.0; KMAX];
        table.query_into(10, &rows, &mask, &targets, 0.0, &mut d, &mut t);
        assert!(d[0] < BIG && d[1] < BIG && d[2] < BIG);
        assert_eq!(d[3], BIG);
        assert_eq!(t[3], 0.0);
    }

    #[test]
    fn size_accounting() {
        let (emb, _) = embedding();
        let table = DistanceTable::build(&emb);
        assert_eq!(table.size_bytes(), emb.n * (emb.n - 1) * 4 + emb.n * EMAX * 4);
        // truncated: O(n * P) indices instead of O(n^2)
        let trunc = DistanceTable::build_truncated(&emb, 40);
        assert_eq!(trunc.size_bytes(), emb.n * 40 * 4 + emb.n * EMAX * 4);
        assert_eq!(trunc.row_len(), 40);
    }

    #[test]
    fn auto_prefix_scales_with_density() {
        // dense library: short prefix; sparse library: longer; always
        // clamped to the full row.
        let dense = DistanceTable::auto_prefix(1000, 500);
        let sparse = DistanceTable::auto_prefix(1000, 50);
        assert!(dense < sparse);
        assert!(sparse <= 999);
        assert!(dense >= KMAX);
        assert_eq!(DistanceTable::auto_prefix(10, 1), 9);
    }
}
