//! The distance indexing table (paper §3.2) — the headline optimization.
//!
//! Brute-force CCM recomputes, **per subsample**, the distances from every
//! prediction point to the L library points and re-selects the top E+1 —
//! `O(r * n * L)` distance work plus selection. The paper instead builds,
//! once per `(E, tau)`, a table over the *whole* embedded series: for each
//! manifold point, all other points sorted by distance. The table is
//! broadcast to every worker; each subsample's k-NN then degenerates to
//! walking the precomputed sorted list and keeping the first E+1 entries
//! that are members of the sampled library — no distance computation, no
//! sorting, expected `O(n/L * k)` walk per query.
//!
//! Memory: `n * (n-1)` u32 indices (the paper's noted space/time
//! trade-off; ~64 MB at n = 4000). Neighbour *distances* are recomputed on
//! the fly for accepted entries only (k per query), saving 8x memory over
//! storing them.

use crate::ccm::backend::NeighborPanels;
use crate::ccm::embedding::Embedding;
use crate::{BIG, EMAX, KMAX};

/// Sorted-neighbour index over a full shadow manifold.
pub struct DistanceTable {
    /// Flat `[n, n-1]`: row i lists every other manifold row, ascending by
    /// distance to i (ties by index).
    neighbors: Vec<u32>,
    /// Number of manifold points.
    pub n: usize,
    /// The manifold the table indexes (owned copy of the flat vectors —
    /// needed to recompute accepted-neighbour distances).
    vecs: Vec<f32>,
    /// Time index of row 0 (Theiler windows work on original time).
    pub t0: usize,
}

impl DistanceTable {
    /// Build the full table serially. The parallel build used by the
    /// pipelines is [`DistanceTable::build_rows`] + [`DistanceTable::assemble`].
    pub fn build(emb: &Embedding) -> DistanceTable {
        let rows: Vec<Vec<u32>> = (0..emb.n).map(|i| Self::sorted_row(emb, i)).collect();
        Self::assemble(emb, rows)
    }

    /// Compute the sorted neighbour list of manifold row `i` — the unit of
    /// parallel table construction (each engine task handles a chunk of
    /// rows).
    ///
    /// §Perf: squared distances are non-negative, so their IEEE-754 bit
    /// patterns are order-monotone; packing `(dist_bits << 32) | index`
    /// into a u64 replaces the branchy `partial_cmp` comparator sort with
    /// a plain integer sort (ties fall through to the index — exactly the
    /// lowest-index tie-break the kernels use). ~2.3x faster build.
    pub fn sorted_row(emb: &Embedding, i: usize) -> Vec<u32> {
        let n = emb.n;
        let a = emb.point(i);
        let mut keys: Vec<u64> = Vec::with_capacity(n - 1);
        for j in 0..n {
            if j == i {
                continue;
            }
            let b = emb.point(j);
            let mut d = 0.0f32;
            for l in 0..EMAX {
                let diff = a[l] - b[l];
                d += diff * diff;
            }
            keys.push(((d.to_bits() as u64) << 32) | j as u64);
        }
        keys.sort_unstable();
        keys.into_iter().map(|k| k as u32).collect()
    }

    /// Assemble per-row sorted lists (in row order) into a table.
    pub fn assemble(emb: &Embedding, rows: Vec<Vec<u32>>) -> DistanceTable {
        let n = emb.n;
        assert_eq!(rows.len(), n);
        let mut neighbors = Vec::with_capacity(n * n.saturating_sub(1));
        for r in &rows {
            assert_eq!(r.len(), n - 1);
            neighbors.extend_from_slice(r);
        }
        DistanceTable { neighbors, n, vecs: emb.vecs.clone(), t0: emb.t0 }
    }

    /// Serialized size for broadcast cost accounting.
    pub fn size_bytes(&self) -> usize {
        self.neighbors.len() * 4 + self.vecs.len() * 4
    }

    /// Squared distance between manifold rows (recomputed, EMAX-padded).
    #[inline]
    fn sq_dist(&self, i: usize, j: usize) -> f32 {
        let a = &self.vecs[i * EMAX..(i + 1) * EMAX];
        let b = &self.vecs[j * EMAX..(j + 1) * EMAX];
        let mut d = 0.0f32;
        for l in 0..EMAX {
            let diff = a[l] - b[l];
            d += diff * diff;
        }
        d
    }

    /// k-NN of manifold row `qi` restricted to library members, by walking
    /// the precomputed list. `in_library[j] != 0` marks manifold row j as a
    /// library member; `lib_target_of[j]` is the target value for member
    /// rows (unused slots arbitrary). Matches brute-force semantics:
    /// Theiler exclusion on original time, KMAX slots padded with BIG/0.
    pub fn query_into(
        &self,
        qi: usize,
        in_library: &[u8],
        lib_target_of: &[f32],
        theiler: f32,
        out_d: &mut [f32; KMAX],
        out_t: &mut [f32; KMAX],
    ) {
        out_d.fill(BIG);
        out_t.fill(0.0);
        let row = &self.neighbors[qi * (self.n - 1)..(qi + 1) * (self.n - 1)];
        let qt = (self.t0 + qi) as f32;
        let mut found = 0;
        for &j in row {
            let j = j as usize;
            if in_library[j] == 0 {
                continue;
            }
            if theiler >= 0.0 && ((self.t0 + j) as f32 - qt).abs() <= theiler {
                continue;
            }
            out_d[found] = self.sq_dist(qi, j);
            out_t[found] = lib_target_of[j];
            found += 1;
            if found == KMAX {
                break;
            }
        }
    }

    /// Batch query: neighbour panels for every manifold row (the standard
    /// CCM prediction set is the whole manifold).
    pub fn query_all(
        &self,
        in_library: &[u8],
        lib_target_of: &[f32],
        theiler: f32,
    ) -> NeighborPanels {
        let mut dvals = vec![0.0f32; self.n * KMAX];
        let mut tvals = vec![0.0f32; self.n * KMAX];
        let mut d = [0.0f32; KMAX];
        let mut t = [0.0f32; KMAX];
        for qi in 0..self.n {
            self.query_into(qi, in_library, lib_target_of, theiler, &mut d, &mut t);
            dvals[qi * KMAX..(qi + 1) * KMAX].copy_from_slice(&d);
            tvals[qi * KMAX..(qi + 1) * KMAX].copy_from_slice(&t);
        }
        NeighborPanels { dvals, tvals, n_pred: self.n }
    }
}

/// Build the membership mask + target lookup for a library sample.
pub fn library_mask(
    n_manifold: usize,
    rows: &[usize],
    targets_by_row: &[f32],
) -> (Vec<u8>, Vec<f32>) {
    let mut mask = vec![0u8; n_manifold];
    let mut target_of = vec![0.0f32; n_manifold];
    for &r in rows {
        mask[r] = 1;
        target_of[r] = targets_by_row[r];
    }
    (mask, target_of)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ccm::knn::knn_batch;
    use crate::timeseries::generators::{coupled_logistic, CoupledLogisticParams};
    use crate::util::rng::Rng;

    fn embedding() -> (Embedding, Vec<f32>) {
        let (x, y) = coupled_logistic(300, CoupledLogisticParams::default());
        let emb = Embedding::new(&y, 3, 2);
        let targets = emb.align_targets(&x);
        (emb, targets)
    }

    #[test]
    fn rows_sorted_ascending() {
        let (emb, _) = embedding();
        let table = DistanceTable::build(&emb);
        for i in [0usize, 7, emb.n - 1] {
            let row = &table.neighbors[i * (emb.n - 1)..(i + 1) * (emb.n - 1)];
            assert_eq!(row.len(), emb.n - 1);
            let dists: Vec<f32> = row.iter().map(|&j| table.sq_dist(i, j as usize)).collect();
            assert!(dists.windows(2).all(|w| w[0] <= w[1]), "row {i} not sorted");
            // no self, no duplicates
            assert!(!row.contains(&(i as u32)));
            let mut uniq = row.to_vec();
            uniq.sort_unstable();
            uniq.dedup();
            assert_eq!(uniq.len(), emb.n - 1);
        }
    }

    #[test]
    fn table_query_matches_bruteforce_knn() {
        // THE critical equivalence: paper §3.2 is an optimization, not an
        // approximation. Table-mode k-NN must equal brute force exactly.
        let (emb, targets) = embedding();
        let table = DistanceTable::build(&emb);
        let mut rng = Rng::new(5);
        let rows = rng.sample_indices(emb.n, 120);
        let (mask, target_of) = library_mask(emb.n, &rows, &targets);
        let panels = table.query_all(&mask, &target_of, 0.0);

        // brute force over the same library
        let mut lib_vecs = Vec::new();
        let mut lib_targets = Vec::new();
        let mut lib_times = Vec::new();
        for &r in &rows {
            lib_vecs.extend_from_slice(emb.point(r));
            lib_targets.push(targets[r]);
            lib_times.push(emb.time_of(r) as f32);
        }
        let pred_times: Vec<f32> = (0..emb.n).map(|i| emb.time_of(i) as f32).collect();
        let (bd, bt) = knn_batch(&emb.vecs, &pred_times, &lib_vecs, &lib_targets, &lib_times, 0.0);

        for i in 0..emb.n * KMAX {
            assert!(
                (panels.dvals[i] - bd[i]).abs() < 1e-5,
                "dval mismatch at {i}: {} vs {}",
                panels.dvals[i],
                bd[i]
            );
            assert_eq!(panels.tvals[i], bt[i], "tval mismatch at {i}");
        }
    }

    #[test]
    fn theiler_respected_in_table_query() {
        let (emb, targets) = embedding();
        let table = DistanceTable::build(&emb);
        let all_rows: Vec<usize> = (0..emb.n).collect();
        let (mask, target_of) = library_mask(emb.n, &all_rows, &targets);
        let mut d = [0.0; KMAX];
        let mut t = [0.0; KMAX];
        // theiler = 5: all neighbours at least 6 steps away in time
        table.query_into(50, &mask, &target_of, 5.0, &mut d, &mut t);
        // verify by brute force over allowed rows
        let best = (0..emb.n)
            .filter(|&j| (j as i64 - 50).abs() > 5)
            .map(|j| table.sq_dist(50, j))
            .fold(f32::INFINITY, f32::min);
        assert!((d[0] - best).abs() < 1e-6);
    }

    #[test]
    fn sparse_library_pads_with_big() {
        let (emb, targets) = embedding();
        let table = DistanceTable::build(&emb);
        let rows = vec![3usize, 40, 80]; // only 3 members
        let (mask, target_of) = library_mask(emb.n, &rows, &targets);
        let mut d = [0.0; KMAX];
        let mut t = [0.0; KMAX];
        table.query_into(10, &mask, &target_of, 0.0, &mut d, &mut t);
        assert!(d[0] < BIG && d[1] < BIG && d[2] < BIG);
        assert_eq!(d[3], BIG);
        assert_eq!(t[3], 0.0);
    }

    #[test]
    fn size_accounting() {
        let (emb, _) = embedding();
        let table = DistanceTable::build(&emb);
        assert_eq!(table.size_bytes(), emb.n * (emb.n - 1) * 4 + emb.n * EMAX * 4);
    }
}
