//! CCM parameters and experiment scenarios.

/// One `(E, tau, L)` parameter combination — the paper's sensitivity
/// parameters (§1): embedding dimension, embedding delay, library size.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct CcmParams {
    /// Embedding dimension (1..=10; simplex uses E+1 neighbours).
    pub e: usize,
    /// Embedding delay.
    pub tau: usize,
    /// Library size: number of manifold points sampled per realization.
    pub l: usize,
}

impl CcmParams {
    pub fn new(e: usize, tau: usize, l: usize) -> CcmParams {
        assert!((1..=10).contains(&e), "E must be in 1..=10, got {e}");
        assert!(tau >= 1, "tau must be >= 1");
        assert!(l >= e + 2, "library size {l} too small for E={e}");
        CcmParams { e, tau, l }
    }

    /// Number of neighbours used by simplex projection.
    pub fn k(&self) -> usize {
        self.e + 1
    }
}

/// A full experiment scenario: the parameter grid, the number of random
/// realizations, and the input series length.
#[derive(Clone, Debug)]
pub struct Scenario {
    /// Input time-series length.
    pub series_len: usize,
    /// Number of random library subsamples per combination (paper: 500).
    pub r: usize,
    /// Library sizes to sweep (convergence axis).
    pub ls: Vec<usize>,
    /// Embedding dimensions to sweep.
    pub es: Vec<usize>,
    /// Embedding delays to sweep.
    pub taus: Vec<usize>,
    /// Theiler exclusion radius (0 = exclude self only, rEDM default).
    pub theiler: usize,
    /// Master RNG seed.
    pub seed: u64,
    /// Partitions per pipeline job (Spark default parallelism analogue).
    pub partitions: usize,
}

impl Scenario {
    /// The paper's baseline scenario (§4): series 4000, r = 500,
    /// L in {500, 1000, 2000}, E and tau in {1, 2, 4}.
    pub fn paper_baseline() -> Scenario {
        Scenario {
            series_len: 4000,
            r: 500,
            ls: vec![500, 1000, 2000],
            es: vec![1, 2, 4],
            taus: vec![1, 2, 4],
            theiler: 0,
            seed: 20190101,
            partitions: 40,
        }
    }

    /// A 1-core-friendly scaled version preserving the baseline's shape
    /// (same grid structure, ~1/8 the series, 1/10 the realizations). Used
    /// by CI and default bench runs; `--full` switches to
    /// [`Scenario::paper_baseline`].
    pub fn scaled_baseline() -> Scenario {
        Scenario {
            series_len: 1000,
            r: 50,
            ls: vec![125, 250, 500],
            es: vec![1, 2, 4],
            taus: vec![1, 2, 4],
            theiler: 0,
            seed: 20190101,
            partitions: 10,
        }
    }

    /// A tiny smoke scenario for unit/integration tests.
    pub fn smoke() -> Scenario {
        Scenario {
            series_len: 300,
            r: 8,
            ls: vec![50, 100],
            es: vec![2],
            taus: vec![1],
            theiler: 0,
            seed: 7,
            partitions: 4,
        }
    }

    /// All `(E, tau, L)` combinations, L-major (the paper loops L for the
    /// convergence axis within each (E, tau) cell).
    pub fn combos(&self) -> Vec<CcmParams> {
        let mut out = Vec::new();
        for &e in &self.es {
            for &tau in &self.taus {
                for &l in &self.ls {
                    out.push(CcmParams::new(e, tau, l));
                }
            }
        }
        out
    }

    /// Largest embedded-manifold size across the grid (for table sizing):
    /// `series_len - (E-1)*tau` at the maximal (E, tau).
    pub fn max_manifold_points(&self) -> usize {
        self.series_len
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn combos_cover_grid_in_order() {
        let s = Scenario {
            series_len: 100,
            r: 1,
            ls: vec![10, 20],
            es: vec![1, 2],
            taus: vec![1],
            theiler: 0,
            seed: 0,
            partitions: 1,
        };
        let c = s.combos();
        assert_eq!(c.len(), 4);
        assert_eq!(c[0], CcmParams::new(1, 1, 10));
        assert_eq!(c[1], CcmParams::new(1, 1, 20));
        assert_eq!(c[2], CcmParams::new(2, 1, 10));
    }

    #[test]
    fn paper_baseline_matches_section4() {
        let s = Scenario::paper_baseline();
        assert_eq!(s.series_len, 4000);
        assert_eq!(s.r, 500);
        assert_eq!(s.ls, vec![500, 1000, 2000]);
        assert_eq!(s.es, vec![1, 2, 4]);
        assert_eq!(s.taus, vec![1, 2, 4]);
        assert_eq!(s.combos().len(), 27);
    }

    #[test]
    #[should_panic(expected = "E must be in 1..=10")]
    fn rejects_bad_e() {
        CcmParams::new(11, 1, 100);
    }

    #[test]
    #[should_panic(expected = "too small")]
    fn rejects_tiny_library() {
        CcmParams::new(4, 1, 5);
    }

    #[test]
    fn k_is_e_plus_one() {
        assert_eq!(CcmParams::new(3, 2, 100).k(), 4);
    }
}
