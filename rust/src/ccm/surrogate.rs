//! Surrogate-based significance testing (extension beyond the paper's
//! core, standard practice in the CCM literature — e.g. Mønster et al.
//! 2017): compare the observed cross-map skill against a null distribution
//! obtained by destroying the cause/effect time alignment while preserving
//! each series' marginal (and, for circular shifts, autocorrelation)
//! structure.

use std::sync::Arc;

use crate::ccm::backend::{ComputeBackend, TaskArena};
use crate::ccm::params::CcmParams;
use crate::ccm::pipeline::CcmProblem;
use crate::ccm::subsample::draw_samples;
use crate::util::rng::Rng;

/// How null surrogates of the cause series are generated.
#[derive(Clone, Copy, Debug)]
pub enum SurrogateKind {
    /// Random permutation: destroys all temporal structure.
    Shuffle,
    /// Circular shift by a random offset: preserves autocorrelation,
    /// destroys alignment — the stricter null.
    CircularShift,
}

/// Result of a significance test.
#[derive(Clone, Debug)]
pub struct SignificanceReport {
    /// Mean observed skill over `r` realizations.
    pub observed_rho: f64,
    /// Null-skill for each surrogate.
    pub null_rhos: Vec<f64>,
    /// Fraction of surrogates with skill >= observed (add-one smoothed).
    pub p_value: f64,
}

/// Mean cross-map skill of `cause` from `effect`'s manifold.
fn mean_skill(
    effect: &[f32],
    cause: &[f32],
    params: CcmParams,
    r: usize,
    theiler: f32,
    seed: u64,
    backend: &Arc<dyn ComputeBackend>,
) -> f64 {
    let problem = CcmProblem::new(effect, cause, params.e, params.tau, theiler);
    let master = Rng::new(seed);
    let samples = draw_samples(&master, params, problem.emb.n, r);
    let mut arena = TaskArena::new();
    let mut acc = 0.0f64;
    for s in &samples {
        acc += backend.cross_map_into(&problem.input_for(s), &mut arena) as f64;
    }
    acc / r.max(1) as f64
}

/// Test whether the observed skill beats `n_surrogates` nulls.
#[allow(clippy::too_many_arguments)]
pub fn significance_test(
    effect: &[f32],
    cause: &[f32],
    params: CcmParams,
    r: usize,
    theiler: f32,
    kind: SurrogateKind,
    n_surrogates: usize,
    seed: u64,
    backend: Arc<dyn ComputeBackend>,
) -> SignificanceReport {
    let observed = mean_skill(effect, cause, params, r, theiler, seed, &backend);
    let mut rng = Rng::new(seed ^ 0x5A5A5A5A);
    let mut null_rhos = Vec::with_capacity(n_surrogates);
    for _ in 0..n_surrogates {
        let surrogate: Vec<f32> = match kind {
            SurrogateKind::Shuffle => {
                let mut s = cause.to_vec();
                rng.shuffle(&mut s);
                s
            }
            SurrogateKind::CircularShift => {
                // offset away from 0 so alignment is genuinely destroyed
                let n = cause.len();
                let off = n / 4 + rng.below(n / 2);
                (0..n).map(|i| cause[(i + off) % n]).collect()
            }
        };
        null_rhos.push(mean_skill(effect, &surrogate, params, r, theiler, seed, &backend));
    }
    let beats = null_rhos.iter().filter(|&&x| x >= observed).count();
    let p_value = (beats + 1) as f64 / (n_surrogates + 1) as f64;
    SignificanceReport { observed_rho: observed, null_rhos, p_value }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::native::NativeBackend;
    use crate::timeseries::generators::{ar1, coupled_logistic, CoupledLogisticParams};

    #[test]
    fn coupled_system_is_significant() {
        let (x, y) = coupled_logistic(400, CoupledLogisticParams::default());
        let rep = significance_test(
            &y,
            &x,
            CcmParams::new(2, 1, 150),
            5,
            0.0,
            SurrogateKind::Shuffle,
            9,
            11,
            Arc::new(NativeBackend),
        );
        assert!(rep.observed_rho > 0.7);
        assert!(rep.p_value <= 0.1, "p = {}", rep.p_value);
        assert_eq!(rep.null_rhos.len(), 9);
    }

    #[test]
    fn independent_noise_is_not_significant() {
        let a = ar1(400, 0.5, 1);
        let b = ar1(400, 0.5, 2);
        let rep = significance_test(
            &b,
            &a,
            CcmParams::new(2, 1, 150),
            5,
            0.0,
            SurrogateKind::CircularShift,
            9,
            13,
            Arc::new(NativeBackend),
        );
        assert!(
            rep.p_value > 0.1,
            "independent AR(1) pair flagged causal: rho {} p {}",
            rep.observed_rho,
            rep.p_value
        );
    }
}
