//! The compute-backend contract shared by the pure-Rust implementation
//! ([`crate::native::NativeBackend`]) and the AOT/XLA one
//! ([`crate::runtime::XlaBackend`]).
//!
//! Everything above this trait (pipelines, driver, benches) is backend-
//! agnostic; integration tests cross-check the two implementations against
//! each other, which is how the Rust side inherits the Pallas kernels'
//! pytest-verified semantics.
//!
//! # Zero-copy task memory layout
//!
//! A cross-map task owns **no copy of shared state**. The problem's shadow
//! manifold, aligned targets, and time column live once per worker behind
//! the broadcast `Arc<CcmProblem>`; a [`CrossMapInput`] is a *view*: three
//! borrowed slices plus the library's manifold-row indices. Assembling the
//! input for one of the `r x |L| x |E x tau|` subsample tasks is therefore
//! O(1) — previously each task deep-copied `n * EMAX` prediction vectors
//! plus two length-`n` columns, which dominated task setup at scale (the
//! same broadcast-vs-materialization observation Belletti et al. make for
//! Spark-side causal inference).
//!
//! Per-task *working* memory comes from a [`TaskArena`]: one per worker
//! partition, reused across every sample in the partition, so no O(n) or
//! O(L) allocation survives on the hot path. The arena holds the gathered
//! library panel (the only inherently per-sample O(L) work), the k-NN
//! distance scratch, the neighbour panels, the packed library bitmask for
//! table-mode queries, and the prediction output buffer.

use crate::ccm::pipeline::PearsonSums;
use crate::ccm::table::{LibraryMask, TableShard};
use crate::{EMAX, KMAX};

/// One cross-map evaluation, as a borrowed view of shared problem state:
/// predict `targets` at every manifold point from the E+1 nearest
/// neighbours among the library rows.
///
/// The prediction set is the whole manifold (standard CCM); the library is
/// identified by ascending manifold-row indices into the shared arrays.
/// Vectors are flat row-major with EMAX-lane padding (see
/// [`crate::ccm::embedding::Embedding`]); `times` carries original-series
/// time indices for Theiler-window self-exclusion.
#[derive(Clone, Copy, Debug)]
pub struct CrossMapInput<'a> {
    /// Shared manifold points, `[n, EMAX]` flat (library and prediction
    /// rows both index into this).
    pub vecs: &'a [f32],
    /// Target (cause-series) value at each manifold row's time.
    pub targets: &'a [f32],
    /// Original time index of each manifold row.
    pub times: &'a [f32],
    /// Library membership: ascending manifold-row indices.
    pub lib_rows: &'a [usize],
    /// Embedding dimension in use (k = e+1 neighbours enter the simplex).
    pub e: usize,
    /// Exclusion radius: library points with `|t_lib - t_pred| <= theiler`
    /// are never neighbours. 0 = exclude exact self (rEDM default);
    /// negative disables exclusion.
    pub theiler: f32,
}

impl<'a> CrossMapInput<'a> {
    pub fn n_lib(&self) -> usize {
        self.lib_rows.len()
    }

    pub fn n_pred(&self) -> usize {
        self.targets.len()
    }

    /// Internal consistency check (used by debug asserts and tests).
    pub fn validate(&self) {
        assert_eq!(self.vecs.len(), self.n_pred() * EMAX);
        assert_eq!(self.times.len(), self.n_pred());
        assert!(self.lib_rows.iter().all(|&r| r < self.n_pred()));
        assert!((1..EMAX + 1).contains(&self.e));
        assert!(self.e + 1 <= KMAX);
    }
}

/// Cross-map result: prediction skill and the per-point predictions.
#[derive(Clone, Debug)]
pub struct CrossMapOutput {
    /// Pearson correlation between predictions and observations.
    pub rho: f32,
    /// Simplex predictions at each prediction point.
    pub preds: Vec<f32>,
}

/// Owned nearest-neighbour panels (the distance-indexing-table path):
/// squared distances and gathered targets, `[n_pred, KMAX]` flat,
/// ascending per row, padded with `BIG`/0 when a row has fewer neighbours.
///
/// The hot pipelines keep these flat buffers inside a [`TaskArena`] and
/// call [`ComputeBackend::simplex_tail_into`] directly; this owned struct
/// is the convenience/serialization form used by tests and one-off calls.
#[derive(Clone, Debug)]
pub struct NeighborPanels {
    pub dvals: Vec<f32>,
    pub tvals: Vec<f32>,
    pub n_pred: usize,
}

/// Per-worker scratch: every buffer a cross-map or table-query task needs,
/// allocated once per partition and reused across samples. Buffers are
/// `clear()`+`resize()`d, so capacity ratchets up to the partition's
/// largest sample and no hot-path `vec!` survives.
#[derive(Default)]
pub struct TaskArena {
    /// Gathered library manifold points, `[n_lib, EMAX]` flat.
    pub lib_vecs: Vec<f32>,
    /// Gathered library targets.
    pub lib_targets: Vec<f32>,
    /// Gathered library time indices.
    pub lib_times: Vec<f32>,
    /// k-NN distance sweep scratch (length >= n_lib).
    pub dist: Vec<f32>,
    /// Neighbour panel distances, `[n_pred, KMAX]` flat.
    pub dvals: Vec<f32>,
    /// Neighbour panel targets, `[n_pred, KMAX]` flat.
    pub tvals: Vec<f32>,
    /// Simplex predictions (length n_pred after a cross-map).
    pub preds: Vec<f32>,
    /// Packed u64 library membership mask (table-mode queries).
    pub mask: LibraryMask,
}

impl TaskArena {
    pub fn new() -> TaskArena {
        TaskArena::default()
    }

    /// Gather the library panel out of the shared view — the only O(L)
    /// per-sample work on the zero-copy path (the gathered rows differ per
    /// sample, so this copy is inherent; the buffers are reused).
    pub fn gather_library(&mut self, input: &CrossMapInput) {
        let l = input.lib_rows.len();
        self.lib_vecs.clear();
        self.lib_vecs.reserve(l * EMAX);
        self.lib_targets.clear();
        self.lib_targets.reserve(l);
        self.lib_times.clear();
        self.lib_times.reserve(l);
        for &row in input.lib_rows {
            self.lib_vecs.extend_from_slice(&input.vecs[row * EMAX..(row + 1) * EMAX]);
            self.lib_targets.push(input.targets[row]);
            self.lib_times.push(input.times[row]);
        }
    }
}

/// Observability counters for one compute pool, snapshotted by
/// [`ComputeBackend::run_counters`]. One typed struct instead of the old
/// per-counter getter sprawl: adding a counter means adding a field here
/// and a line in [`PoolCounters::to_pairs`], and every consumer — the
/// `--dump-skills` `.meta.json` sidecar, benches, integration tests — sees
/// it. In-process backends report all zeros (the default); the cluster
/// runtime fills in its pool state.
///
/// `live_workers` is a point-in-time gauge; everything else is a
/// monotonically increasing count over the pool's lifetime.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolCounters {
    /// Workers currently alive (gauge).
    pub live_workers: u64,
    /// Dead local workers replaced with fresh spawns.
    pub respawns: u64,
    /// Remote workers lost (remote pools shrink instead of respawning).
    pub remote_lost: u64,
    /// Workers declared dead by keepalive ping timeout.
    pub keepalive_deaths: u64,
    /// Broadcast payload ships to workers (first ships + replicas).
    pub broadcast_ships: u64,
    /// Bytes of broadcast payload shipped.
    pub broadcast_ship_bytes: u64,
    /// Ships of a payload a worker was already supposed to hold.
    pub rebroadcasts: u64,
    /// Re-replication ships triggered by worker death.
    pub repair_ships: u64,
    /// Bytes shipped by death-triggered re-replication.
    pub repair_ship_bytes: u64,
    /// Wire-level broadcast evictions sent.
    pub evictions: u64,
    /// Remote workers successfully re-admitted after rejoin.
    pub rejoins: u64,
    /// Rejoin dial attempts (successful or not).
    pub rejoin_attempts: u64,
    /// Rejoin handshakes rejected (auth/version mismatch).
    pub rejoin_rejected: u64,
    /// Payload ships to rejoined workers re-warming their store.
    pub rejoin_ships: u64,
    /// Bytes shipped to rejoined workers.
    pub rejoin_ship_bytes: u64,
    /// Connections admitted speaking the v6 binary wire (cumulative:
    /// initial spawns, respawns, and rejoins each count their admit).
    pub binary_connections: u64,
    /// Connections admitted pinned to the JSON line wire (v<=5 peers).
    pub json_connections: u64,
    /// Speculative duplicate tasks launched against stragglers.
    pub speculative_launches: u64,
    /// Speculative duplicates that finished before the original.
    pub speculative_wins: u64,
    /// Tasks killed for exceeding `--task-deadline-secs`.
    pub deadline_kills: u64,
    /// Frames rejected by the v4 checksum layer.
    pub corrupt_frames_detected: u64,
    /// Tasks that exhausted retries and fell back to the native backend.
    pub exhausted_fallbacks: u64,
    /// Bytes of task-result frames received by the driver — the
    /// result-movement cost the worker-side reduce (`--reduce worker`)
    /// exists to shrink.
    pub result_ingress_bytes: u64,
    /// Grid cells stopped early by the `--partial eps,conf` bounded
    /// evaluator (confidence interval tight, or the whole (E, tau) slice
    /// statistically decided).
    pub partial_stops: u64,
    /// Subsample tasks never dispatched because their cell stopped early
    /// — the work the partial evaluator saved.
    pub partial_saved_tasks: u64,
}

impl PoolCounters {
    /// The counters as (name, value) pairs, in a stable documented order —
    /// the serialization the `--dump-skills` sidecar writes. Names are
    /// load-bearing: CI asserts on them, so they never change spelling.
    pub fn to_pairs(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("live_workers", self.live_workers),
            ("respawns", self.respawns),
            ("remote_lost", self.remote_lost),
            ("keepalive_deaths", self.keepalive_deaths),
            ("broadcast_ships", self.broadcast_ships),
            ("broadcast_ship_bytes", self.broadcast_ship_bytes),
            ("rebroadcasts", self.rebroadcasts),
            ("repair_ships", self.repair_ships),
            ("repair_ship_bytes", self.repair_ship_bytes),
            ("evictions", self.evictions),
            ("rejoins", self.rejoins),
            ("rejoin_attempts", self.rejoin_attempts),
            ("rejoin_rejected", self.rejoin_rejected),
            ("rejoin_ships", self.rejoin_ships),
            ("rejoin_ship_bytes", self.rejoin_ship_bytes),
            ("binary_connections", self.binary_connections),
            ("json_connections", self.json_connections),
            ("speculative_launches", self.speculative_launches),
            ("speculative_wins", self.speculative_wins),
            ("deadline_kills", self.deadline_kills),
            ("corrupt_frames_detected", self.corrupt_frames_detected),
            ("exhausted_fallbacks", self.exhausted_fallbacks),
            ("result_ingress_bytes", self.result_ingress_bytes),
            ("partial_stops", self.partial_stops),
            ("partial_saved_tasks", self.partial_saved_tasks),
        ]
    }
}

/// The backend contract.
///
/// The `*_into` methods are the hot path: they borrow a [`TaskArena`] (or
/// explicit output buffers) and perform no owned allocation of O(n) data.
/// The `cross_map` / `simplex_tail` wrappers allocate per call and exist
/// for tests, validation commands, and one-off analysis code.
pub trait ComputeBackend: Send + Sync {
    /// Full cross-map (distances -> top-k -> simplex -> Pearson) into the
    /// arena; returns the skill. Predictions are left in `arena.preds`.
    fn cross_map_into(&self, input: &CrossMapInput, arena: &mut TaskArena) -> f32;

    /// Simplex + Pearson over pre-gathered neighbour panels (flat
    /// `[n_pred, KMAX]` slices) — the table-mode tail. Predictions are
    /// written into `preds` (cleared first); returns the skill.
    fn simplex_tail_into(
        &self,
        dvals: &[f32],
        tvals: &[f32],
        pred_targets: &[f32],
        e: usize,
        preds: &mut Vec<f32>,
    ) -> f32;

    /// Full pairwise squared-distance matrix of `n` EMAX-padded points
    /// (row-major `[n, n]`) — the distance-indexing-table construction
    /// primitive (paper §3.2).
    fn distance_matrix(&self, vecs: &[f32], n: usize) -> Vec<f32>;

    /// Sharded table-mode partial cross-map: k-NN via `shard`'s sorted
    /// prefixes for the query rows it owns (`[shard.row_lo, shard.row_hi)`),
    /// then simplex over those rows only. Predictions for the shard's rows
    /// are written to `preds` (cleared first). The caller concatenates
    /// shard chunks in row order and computes Pearson over the full
    /// prediction vector, which reproduces the unsharded table pipeline
    /// bit-for-bit (simplex is row-independent; the walk code is shared).
    ///
    /// The default implementation runs in-process; a serializing backend
    /// (e.g. `ccm::cluster::ClusterBackend`) overrides it to ship
    /// `(shard wire id, targets wire id, lib_rows, e, theiler)` — a few KB
    /// — to a worker process that holds the shard broadcast.
    ///
    /// Caveat: the default runs the *native* simplex kernel. For
    /// `NativeBackend` (and the process workers, which compute natively)
    /// sharded results are bit-identical to the monolithic table path. A
    /// backend that overrides `simplex_tail_into` with different
    /// arithmetic (a real XLA tail) would need to override this too to
    /// keep sharded == monolithic at the bit level; the current
    /// `XlaBackend` stub falls back to native, so the guarantee holds
    /// everywhere in this build.
    #[allow(clippy::too_many_arguments)]
    fn shard_chunk_into(
        &self,
        shard: &TableShard,
        targets: &[f32],
        theiler: f32,
        lib_rows: &[usize],
        e: usize,
        arena: &mut TaskArena,
        preds: &mut Vec<f32>,
    ) {
        arena.mask.set_from(shard.n, lib_rows);
        shard.query_rows_into(
            lib_rows,
            &arena.mask,
            targets,
            theiler,
            &mut arena.dvals,
            &mut arena.tvals,
        );
        crate::ccm::simplex::simplex_batch_into(
            &arena.dvals,
            &arena.tvals,
            shard.num_rows(),
            e,
            preds,
        );
    }

    /// Shuffle-stage partial reduce: like [`ComputeBackend::shard_chunk_into`],
    /// but the shard's predictions are folded straight into compensated
    /// partial Pearson sums against the shard's own target rows
    /// (`targets[shard.row_lo..shard.row_hi]`) and only the ~48-byte
    /// [`PearsonSums`] comes back — never the predictions.
    ///
    /// The default computes the chunk in-process (reusing `arena.preds`)
    /// and accumulates locally. `ccm::cluster::ClusterBackend` overrides it
    /// to ship a wire-v5 `agg_chunk` task when a v5-capable worker is
    /// available, falling back to this default otherwise. Both produce
    /// bit-identical sums: accumulation order is fixed by row order and the
    /// Kahan compensation never leaves the accumulation call.
    fn agg_chunk_into(
        &self,
        shard: &TableShard,
        targets: &[f32],
        theiler: f32,
        lib_rows: &[usize],
        e: usize,
        arena: &mut TaskArena,
    ) -> PearsonSums {
        let mut preds = std::mem::take(&mut arena.preds);
        self.shard_chunk_into(shard, targets, theiler, lib_rows, e, arena, &mut preds);
        let sums = PearsonSums::from_slices(&preds, &targets[shard.row_lo..shard.row_hi]);
        arena.preds = preds;
        sums
    }

    /// Merge per-shard partial sums (callers pass them sorted by shard
    /// index) into one [`PearsonSums`]. The default merges in-process;
    /// `ccm::cluster::ClusterBackend` ships the partials to a v5 worker as
    /// a `merge_sums` task so the final reduce also runs worker-side. The
    /// merge is a pure function of the ordered slice, so every
    /// implementation is bit-identical.
    fn merge_sums(&self, partials: &[PearsonSums]) -> PearsonSums {
        PearsonSums::merge_all(partials)
    }

    /// Hint that every task referencing these broadcast wire ids has been
    /// harvested: a distributed backend (e.g.
    /// [`crate::ccm::cluster::ClusterBackend`]) releases its cached
    /// serialized payloads and sends wire `evict`s so worker memory stays
    /// bounded across a parameter grid. Ids a backend never shipped are
    /// ignored; in-process backends hold no payloads, hence the no-op
    /// default. The driver computes ids via
    /// [`crate::ccm::cluster::problem_wire_id`] /
    /// [`crate::ccm::cluster::targets_wire_id`] /
    /// [`crate::ccm::table::TableShard::wire_id`].
    fn evict_broadcasts(&self, _ids: &[u64]) {}

    /// Report a batch of partial-evaluation stop decisions: `stops` grid
    /// cells terminated early, skipping `saved_tasks` subsample tasks that
    /// were never dispatched. The driver calls this once per run so the
    /// counters land in [`PoolCounters`] (`partial_stops` /
    /// `partial_saved_tasks`) and the `--dump-skills` sidecar. In-process
    /// backends keep no counters, hence the no-op default;
    /// `ccm::cluster::ClusterBackend` accumulates pool-wide, and its
    /// per-job `JobBackend` view also attributes to the job's tally.
    fn record_partial(&self, _stops: u64, _saved_tasks: u64) {}

    /// Observability counters for run-metadata dumps. In-process backends
    /// report all zeros (the default); the cluster runtime snapshots its
    /// pool counters (ships, repairs, rejoins, result ingress, ...) so CLI
    /// runs can write a machine-readable sidecar next to `--dump-skills` —
    /// the skills file itself must stay byte-comparable across backends,
    /// so counters never go in it.
    fn run_counters(&self) -> PoolCounters {
        PoolCounters::default()
    }

    /// Wire encoding the DES should price simulated traffic at, so
    /// modeled bytes track what this backend's pool actually ships.
    /// In-process backends move no bytes, so the identity
    /// [`WirePricing::Binary`](crate::engine::config::WirePricing) default
    /// keeps their reports raw-sized; `ccm::cluster::ClusterBackend`
    /// answers `Json` once any connection in its pool has pinned the
    /// legacy line wire (a v<=5 peer).
    fn wire_pricing(&self) -> crate::engine::config::WirePricing {
        crate::engine::config::WirePricing::Binary
    }

    /// Human-readable backend name (for logs/benches).
    fn name(&self) -> &'static str;

    /// Convenience wrapper: fresh arena per call, owned output.
    fn cross_map(&self, input: &CrossMapInput) -> CrossMapOutput {
        let mut arena = TaskArena::new();
        let rho = self.cross_map_into(input, &mut arena);
        CrossMapOutput { rho, preds: std::mem::take(&mut arena.preds) }
    }

    /// Convenience wrapper over owned [`NeighborPanels`].
    fn simplex_tail(
        &self,
        panels: &NeighborPanels,
        pred_targets: &[f32],
        e: usize,
    ) -> CrossMapOutput {
        let mut preds = Vec::new();
        let rho = self.simplex_tail_into(&panels.dvals, &panels.tvals, pred_targets, e, &mut preds);
        CrossMapOutput { rho, preds }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_accepts_consistent_input() {
        let vecs = vec![0.0; 4 * EMAX];
        let targets = vec![0.0; 4];
        let times = vec![0.0, 1.0, 2.0, 3.0];
        let rows = vec![0usize, 2];
        let input = CrossMapInput {
            vecs: &vecs,
            targets: &targets,
            times: &times,
            lib_rows: &rows,
            e: 2,
            theiler: 0.0,
        };
        input.validate();
        assert_eq!(input.n_lib(), 2);
        assert_eq!(input.n_pred(), 4);
    }

    #[test]
    #[should_panic]
    fn validate_rejects_mismatched_vecs() {
        let vecs = vec![0.0; 3]; // not n_pred * EMAX
        let targets = vec![0.0; 4];
        let times = vec![0.0; 4];
        let input = CrossMapInput {
            vecs: &vecs,
            targets: &targets,
            times: &times,
            lib_rows: &[],
            e: 2,
            theiler: 0.0,
        };
        input.validate();
    }

    #[test]
    #[should_panic]
    fn validate_rejects_out_of_range_library_row() {
        let vecs = vec![0.0; 2 * EMAX];
        let targets = vec![0.0; 2];
        let times = vec![0.0; 2];
        let rows = vec![5usize];
        let input = CrossMapInput {
            vecs: &vecs,
            targets: &targets,
            times: &times,
            lib_rows: &rows,
            e: 1,
            theiler: 0.0,
        };
        input.validate();
    }

    #[test]
    fn pool_counters_pairs_are_stable() {
        let c = PoolCounters {
            rejoins: 3,
            result_ingress_bytes: 42,
            partial_saved_tasks: 17,
            ..Default::default()
        };
        let pairs = c.to_pairs();
        assert_eq!(pairs.len(), 25);
        // the sidecar keys CI asserts on must exist under these exact names
        for key in [
            "rejoins",
            "rejoin_ships",
            "rebroadcasts",
            "speculative_launches",
            "speculative_wins",
            "corrupt_frames_detected",
            "result_ingress_bytes",
            "binary_connections",
            "json_connections",
            "partial_stops",
            "partial_saved_tasks",
        ] {
            assert!(pairs.iter().any(|&(k, _)| k == key), "missing sidecar key {key}");
        }
        assert_eq!(pairs.iter().find(|&&(k, _)| k == "rejoins").unwrap().1, 3);
        assert_eq!(
            pairs.iter().find(|&&(k, _)| k == "result_ingress_bytes").unwrap().1,
            42
        );
        assert_eq!(
            pairs.iter().find(|&&(k, _)| k == "partial_saved_tasks").unwrap().1,
            17
        );
    }

    #[test]
    fn arena_gathers_library_and_reuses_capacity() {
        let n = 6;
        let mut vecs = vec![0.0f32; n * EMAX];
        for (i, v) in vecs.iter_mut().enumerate() {
            *v = i as f32;
        }
        let targets: Vec<f32> = (0..n).map(|i| 10.0 * i as f32).collect();
        let times: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let rows = vec![1usize, 4];
        let input = CrossMapInput {
            vecs: &vecs,
            targets: &targets,
            times: &times,
            lib_rows: &rows,
            e: 2,
            theiler: 0.0,
        };
        let mut arena = TaskArena::new();
        arena.gather_library(&input);
        assert_eq!(arena.lib_targets, vec![10.0, 40.0]);
        assert_eq!(arena.lib_times, vec![1.0, 4.0]);
        assert_eq!(&arena.lib_vecs[..EMAX], &vecs[EMAX..2 * EMAX]);
        let cap = arena.lib_vecs.capacity();
        // smaller gather must not shrink or reallocate
        let rows2 = vec![2usize];
        let input2 = CrossMapInput { lib_rows: &rows2, ..input };
        arena.gather_library(&input2);
        assert_eq!(arena.lib_targets, vec![20.0]);
        assert!(arena.lib_vecs.capacity() >= cap);
    }
}
