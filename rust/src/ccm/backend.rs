//! The compute-backend contract shared by the pure-Rust implementation
//! ([`crate::native::NativeBackend`]) and the AOT/XLA one
//! ([`crate::runtime::XlaBackend`]).
//!
//! Everything above this trait (pipelines, driver, benches) is backend-
//! agnostic; integration tests cross-check the two implementations against
//! each other, which is how the Rust side inherits the Pallas kernels'
//! pytest-verified semantics.

use crate::{EMAX, KMAX};

/// One cross-map evaluation: predict `pred_targets` at every prediction
/// point from the E+1 nearest library neighbours.
///
/// Vectors are flat row-major with EMAX-lane padding (see
/// [`crate::ccm::embedding::Embedding`]). `*_times` carry original-series
/// time indices for Theiler-window self-exclusion.
#[derive(Clone, Debug)]
pub struct CrossMapInput {
    /// Library manifold points, `[n_lib, EMAX]` flat.
    pub lib_vecs: Vec<f32>,
    /// Target (cause-series) value at each library point's time.
    pub lib_targets: Vec<f32>,
    /// Original time index of each library point.
    pub lib_times: Vec<f32>,
    /// Prediction manifold points, `[n_pred, EMAX]` flat.
    pub pred_vecs: Vec<f32>,
    /// Observed target at each prediction point (for the skill score).
    pub pred_targets: Vec<f32>,
    /// Original time index of each prediction point.
    pub pred_times: Vec<f32>,
    /// Embedding dimension in use (k = e+1 neighbours enter the simplex).
    pub e: usize,
    /// Exclusion radius: library points with `|t_lib - t_pred| <= theiler`
    /// are never neighbours. 0 = exclude exact self (rEDM default);
    /// negative disables exclusion.
    pub theiler: f32,
}

impl CrossMapInput {
    pub fn n_lib(&self) -> usize {
        self.lib_targets.len()
    }

    pub fn n_pred(&self) -> usize {
        self.pred_targets.len()
    }

    /// Internal consistency check (used by debug asserts and tests).
    pub fn validate(&self) {
        assert_eq!(self.lib_vecs.len(), self.n_lib() * EMAX);
        assert_eq!(self.lib_times.len(), self.n_lib());
        assert_eq!(self.pred_vecs.len(), self.n_pred() * EMAX);
        assert_eq!(self.pred_times.len(), self.n_pred());
        assert!((1..EMAX + 1).contains(&self.e));
        assert!(self.e + 1 <= KMAX);
    }
}

/// Cross-map result: prediction skill and the per-point predictions.
#[derive(Clone, Debug)]
pub struct CrossMapOutput {
    /// Pearson correlation between predictions and observations.
    pub rho: f32,
    /// Simplex predictions at each prediction point.
    pub preds: Vec<f32>,
}

/// Pre-gathered nearest-neighbour panels (the distance-indexing-table
/// path): squared distances and gathered targets, `[n_pred, KMAX]` flat,
/// ascending per row, padded with `BIG`/0 when a row has fewer neighbours.
#[derive(Clone, Debug)]
pub struct NeighborPanels {
    pub dvals: Vec<f32>,
    pub tvals: Vec<f32>,
    pub n_pred: usize,
}

/// The backend contract.
pub trait ComputeBackend: Send + Sync {
    /// Full cross-map (distances -> top-k -> simplex -> Pearson).
    fn cross_map(&self, input: &CrossMapInput) -> CrossMapOutput;

    /// Full pairwise squared-distance matrix of `n` EMAX-padded points
    /// (row-major `[n, n]`) — the distance-indexing-table construction
    /// primitive (paper §3.2).
    fn distance_matrix(&self, vecs: &[f32], n: usize) -> Vec<f32>;

    /// Simplex + Pearson over pre-gathered neighbour panels — the
    /// table-mode tail.
    fn simplex_tail(
        &self,
        panels: &NeighborPanels,
        pred_targets: &[f32],
        e: usize,
    ) -> CrossMapOutput;

    /// Human-readable backend name (for logs/benches).
    fn name(&self) -> &'static str;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validate_accepts_consistent_input() {
        let input = CrossMapInput {
            lib_vecs: vec![0.0; 4 * EMAX],
            lib_targets: vec![0.0; 4],
            lib_times: vec![0.0; 4],
            pred_vecs: vec![0.0; 2 * EMAX],
            pred_targets: vec![0.0; 2],
            pred_times: vec![0.0; 2],
            e: 2,
            theiler: 0.0,
        };
        input.validate();
        assert_eq!(input.n_lib(), 4);
        assert_eq!(input.n_pred(), 2);
    }

    #[test]
    #[should_panic]
    fn validate_rejects_mismatched_vecs() {
        let input = CrossMapInput {
            lib_vecs: vec![0.0; 3],
            lib_targets: vec![0.0; 4],
            lib_times: vec![0.0; 4],
            pred_vecs: vec![],
            pred_targets: vec![],
            pred_times: vec![],
            e: 2,
            theiler: 0.0,
        };
        input.validate();
    }
}
