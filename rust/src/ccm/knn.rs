//! Native k-nearest-neighbour search (brute force, top-KMAX per query).
//!
//! Semantics are kept bit-compatible with the Pallas path: squared
//! euclidean distances over EMAX-padded vectors, excluded/invalid entries
//! pushed past [`BIG`], ties broken toward the lower library index, and
//! always KMAX slots returned (padded with `BIG`/0.0 when the library is
//! small). The hot loop maintains a KMAX-wide insertion buffer — for
//! k = 11 that beats heap- or sort-based selection by a wide margin.
//!
//! All entry points take caller-provided scratch (normally a
//! [`crate::ccm::backend::TaskArena`] field) so repeated queries perform
//! zero allocation.

use crate::{BIG, EMAX, KMAX};

/// Top-KMAX neighbours of one query point.
///
/// Writes `(sq_distances, targets)` into `out_d`/`out_t` (first KMAX
/// slots), ascending by distance. Library entries with
/// `|lib_time - pred_time| <= theiler` are skipped (self-exclusion); a
/// negative `theiler` disables exclusion. `scratch` is grown as needed and
/// reused across calls — route it through the task arena so per-query
/// allocation only happens on the first call.
#[allow(clippy::too_many_arguments)]
pub fn knn_one(
    query: &[f32],
    query_time: f32,
    lib_vecs: &[f32],
    lib_targets: &[f32],
    lib_times: &[f32],
    theiler: f32,
    scratch: &mut Vec<f32>,
    out_d: &mut [f32],
    out_t: &mut [f32],
) {
    let n = lib_targets.len();
    if scratch.len() < n {
        scratch.resize(n, 0.0);
    }
    knn_into(
        query,
        query_time,
        lib_vecs,
        lib_targets,
        lib_times,
        theiler,
        scratch,
        out_d,
        out_t,
    );
}

/// Core k-NN with a caller-provided distance scratch buffer (`len >= n`).
///
/// §Perf: two passes — a branch-free distance sweep the autovectorizer
/// turns into 8-lane SIMD, then a pruned selection scan. Fusing the two
/// (compute + insert per element) costs ~35% more because the exclusion
/// and insertion branches break vectorization of the distance loop.
#[allow(clippy::too_many_arguments)]
#[inline]
pub fn knn_into(
    query: &[f32],
    query_time: f32,
    lib_vecs: &[f32],
    lib_targets: &[f32],
    lib_times: &[f32],
    theiler: f32,
    scratch: &mut [f32],
    out_d: &mut [f32],
    out_t: &mut [f32],
) {
    debug_assert_eq!(query.len(), EMAX);
    debug_assert!(out_d.len() >= KMAX && out_t.len() >= KMAX);
    let n = lib_targets.len();
    debug_assert!(scratch.len() >= n);

    // pass 1: pure distance sweep (vectorizes; no branches)
    let q: [f32; EMAX] = query.try_into().unwrap();
    for (j, slot) in scratch[..n].iter_mut().enumerate() {
        let base = j * EMAX;
        let mut d = 0.0f32;
        for l in 0..EMAX {
            let diff = q[l] - lib_vecs[base + l];
            d += diff * diff;
        }
        *slot = d;
    }

    // pass 2: pruned top-KMAX selection with Theiler exclusion
    out_d[..KMAX].fill(BIG);
    out_t[..KMAX].fill(0.0);
    let mut worst = BIG;
    for j in 0..n {
        let d = scratch[j];
        if d >= worst {
            continue;
        }
        if theiler >= 0.0 && (lib_times[j] - query_time).abs() <= theiler {
            continue;
        }
        // insertion into the top-KMAX buffer; strict '<' keeps the earlier
        // (lower-index) element on ties, matching the kernel's argmin.
        let mut pos = KMAX - 1;
        while pos > 0 && d < out_d[pos - 1] {
            out_d[pos] = out_d[pos - 1];
            out_t[pos] = out_t[pos - 1];
            pos -= 1;
        }
        out_d[pos] = d;
        out_t[pos] = lib_targets[j];
        worst = out_d[KMAX - 1];
    }
}

/// Top-KMAX neighbours for a batch of query points, written into flat
/// `[n_pred, KMAX]` buffers (the [`crate::ccm::backend::NeighborPanels`]
/// layout). All buffers are resized in place and reused across calls.
#[allow(clippy::too_many_arguments)]
pub fn knn_batch_into(
    pred_vecs: &[f32],
    pred_times: &[f32],
    lib_vecs: &[f32],
    lib_targets: &[f32],
    lib_times: &[f32],
    theiler: f32,
    scratch: &mut Vec<f32>,
    dvals: &mut Vec<f32>,
    tvals: &mut Vec<f32>,
) {
    let n_pred = pred_times.len();
    let n_lib = lib_targets.len();
    // size-only resize: every slot is overwritten below, so skip the
    // per-sample memset when the arena buffer already has the right shape
    if dvals.len() != n_pred * KMAX {
        dvals.resize(n_pred * KMAX, 0.0);
    }
    if tvals.len() != n_pred * KMAX {
        tvals.resize(n_pred * KMAX, 0.0);
    }
    if scratch.len() < n_lib {
        scratch.resize(n_lib, 0.0);
    }
    for i in 0..n_pred {
        knn_into(
            &pred_vecs[i * EMAX..(i + 1) * EMAX],
            pred_times[i],
            lib_vecs,
            lib_targets,
            lib_times,
            theiler,
            scratch,
            &mut dvals[i * KMAX..(i + 1) * KMAX],
            &mut tvals[i * KMAX..(i + 1) * KMAX],
        );
    }
}

/// Allocating convenience wrapper over [`knn_batch_into`] (tests and
/// one-off analysis; the pipelines reuse arena buffers instead).
pub fn knn_batch(
    pred_vecs: &[f32],
    pred_times: &[f32],
    lib_vecs: &[f32],
    lib_targets: &[f32],
    lib_times: &[f32],
    theiler: f32,
) -> (Vec<f32>, Vec<f32>) {
    let mut dvals = Vec::new();
    let mut tvals = Vec::new();
    let mut scratch = Vec::new();
    knn_batch_into(
        pred_vecs,
        pred_times,
        lib_vecs,
        lib_targets,
        lib_times,
        theiler,
        &mut scratch,
        &mut dvals,
        &mut tvals,
    );
    (dvals, tvals)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn pad(points: &[&[f32]]) -> Vec<f32> {
        let mut out = vec![0.0; points.len() * EMAX];
        for (i, p) in points.iter().enumerate() {
            out[i * EMAX..i * EMAX + p.len()].copy_from_slice(p);
        }
        out
    }

    fn knn_simple(
        query: &[f32],
        query_time: f32,
        lib: &[f32],
        targets: &[f32],
        times: &[f32],
        theiler: f32,
        out_d: &mut [f32; KMAX],
        out_t: &mut [f32; KMAX],
    ) {
        let mut scratch = Vec::new();
        knn_one(query, query_time, lib, targets, times, theiler, &mut scratch, out_d, out_t);
    }

    #[test]
    fn finds_nearest_in_order() {
        let lib = pad(&[&[0.0], &[1.0], &[2.0], &[10.0]]);
        let targets = [100.0, 101.0, 102.0, 110.0];
        let times = [0.0, 1.0, 2.0, 3.0];
        let query = pad(&[&[1.4]]);
        let mut d = [0.0; KMAX];
        let mut t = [0.0; KMAX];
        knn_simple(&query, -100.0, &lib, &targets, &times, 0.0, &mut d, &mut t);
        assert_eq!(t[0], 101.0);
        assert_eq!(t[1], 102.0);
        assert_eq!(t[2], 100.0);
        assert_eq!(t[3], 110.0);
        assert!((d[0] - 0.16).abs() < 1e-6);
        // only 4 library points -> remaining slots padded
        assert_eq!(d[4], BIG);
        assert_eq!(t[4], 0.0);
    }

    #[test]
    fn theiler_excludes_window() {
        let lib = pad(&[&[0.0], &[0.1], &[0.2], &[0.3]]);
        let targets = [10.0, 11.0, 12.0, 13.0];
        let times = [0.0, 1.0, 2.0, 3.0];
        let query = pad(&[&[0.1]]);
        let mut d = [0.0; KMAX];
        let mut t = [0.0; KMAX];
        // query at time 1, theiler 1 -> times 0,1,2 excluded
        knn_simple(&query, 1.0, &lib, &targets, &times, 1.0, &mut d, &mut t);
        assert_eq!(t[0], 13.0);
        assert_eq!(d[1], BIG);
        // negative theiler disables exclusion: exact self picked first
        knn_simple(&query, 1.0, &lib, &targets, &times, -1.0, &mut d, &mut t);
        assert_eq!(t[0], 11.0);
        assert_eq!(d[0], 0.0);
    }

    #[test]
    fn ties_break_to_lower_index() {
        let lib = pad(&[&[1.0], &[1.0], &[1.0]]);
        let targets = [7.0, 8.0, 9.0];
        let times = [0.0, 1.0, 2.0];
        let query = pad(&[&[0.0]]);
        let mut d = [0.0; KMAX];
        let mut t = [0.0; KMAX];
        knn_simple(&query, -10.0, &lib, &targets, &times, 0.0, &mut d, &mut t);
        assert_eq!(&t[..3], &[7.0, 8.0, 9.0]);
    }

    #[test]
    fn scratch_reused_without_growth() {
        let lib = pad(&[&[0.0], &[1.0], &[2.0]]);
        let targets = [1.0, 2.0, 3.0];
        let times = [0.0, 1.0, 2.0];
        let query = pad(&[&[0.5]]);
        let mut d = [0.0; KMAX];
        let mut t = [0.0; KMAX];
        let mut scratch = Vec::new();
        knn_one(&query, -5.0, &lib, &targets, &times, 0.0, &mut scratch, &mut d, &mut t);
        let cap = scratch.capacity();
        assert!(cap >= 3);
        for _ in 0..10 {
            knn_one(&query, -5.0, &lib, &targets, &times, 0.0, &mut scratch, &mut d, &mut t);
        }
        assert_eq!(scratch.capacity(), cap, "repeated queries must not reallocate");
    }

    #[test]
    fn matches_naive_sort_on_random_data() {
        let mut rng = Rng::new(3);
        let n = 200;
        let mut lib = vec![0.0f32; n * EMAX];
        for (i, v) in lib.iter_mut().enumerate() {
            if i % EMAX < 3 {
                *v = rng.f32();
            }
        }
        let targets: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let times: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let query: Vec<f32> = (0..EMAX).map(|l| if l < 3 { rng.f32() } else { 0.0 }).collect();

        let mut d = [0.0; KMAX];
        let mut t = [0.0; KMAX];
        knn_simple(&query, 50.0, &lib, &targets, &times, 2.0, &mut d, &mut t);

        // naive: compute all, filter, stable sort
        let mut all: Vec<(f32, usize)> = (0..n)
            .filter(|&j| (times[j] - 50.0).abs() > 2.0)
            .map(|j| {
                let mut dist = 0.0;
                for l in 0..EMAX {
                    let diff = query[l] - lib[j * EMAX + l];
                    dist += diff * diff;
                }
                (dist, j)
            })
            .collect();
        all.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
        for k in 0..KMAX {
            assert!((d[k] - all[k].0).abs() < 1e-6, "slot {k}");
            assert_eq!(t[k], targets[all[k].1], "slot {k}");
        }
    }

    #[test]
    fn batch_matches_one() {
        let mut rng = Rng::new(5);
        let n = 64;
        let p = 16;
        let mk = |count: usize, rng: &mut Rng| -> Vec<f32> {
            let mut v = vec![0.0f32; count * EMAX];
            for i in 0..count {
                for l in 0..2 {
                    v[i * EMAX + l] = rng.f32();
                }
            }
            v
        };
        let lib = mk(n, &mut rng);
        let pred = mk(p, &mut rng);
        let targets: Vec<f32> = (0..n).map(|_| rng.f32()).collect();
        let lib_times: Vec<f32> = (0..n).map(|i| i as f32).collect();
        let pred_times: Vec<f32> = (0..p).map(|i| (i + 100) as f32).collect();
        let (dv, tv) = knn_batch(&pred, &pred_times, &lib, &targets, &lib_times, 0.0);
        let mut d = [0.0; KMAX];
        let mut t = [0.0; KMAX];
        for i in 0..p {
            knn_simple(
                &pred[i * EMAX..(i + 1) * EMAX],
                pred_times[i],
                &lib,
                &targets,
                &lib_times,
                0.0,
                &mut d,
                &mut t,
            );
            assert_eq!(&dv[i * KMAX..(i + 1) * KMAX], &d);
            assert_eq!(&tv[i * KMAX..(i + 1) * KMAX], &t);
        }
    }
}
