//! The cluster runtime: a transport-generic, replica-aware
//! [`ComputeBackend`] that ships CCM tasks to worker processes over any
//! [`Transport`] (pipe/fork or TCP loopback — see [`crate::ccm::transport`]).
//!
//! This is PR 2's `ProcessBackend` rebuilt as a scheduler: the wire format
//! and worker loop are unchanged at v1 fidelity (pipe results stay
//! bit-identical), but the pool now tracks which worker holds which
//! broadcast, keeps every broadcast resident on `replicas` workers, and
//! requeues a dead worker's task onto a surviving replica **without
//! re-shipping** the broadcast (re-broadcast happens only when the last
//! replica dies — both paths are counted and asserted in tests).
//!
//! # Wire protocol (version [`WIRE_VERSION`] = 7)
//!
//! Line-delimited JSON over the worker's transport — or, on a
//! v6-negotiated connection, the same messages inside length-prefixed
//! binary frames (see below). Large read-only state moves once per
//! holding worker as content-addressed *broadcasts*; tasks then reference
//! broadcasts by id and carry only library-row indices. The JSON shapes
//! shown here are canonical: the binary wire is an alternate encoding of
//! exactly these messages, negotiated per connection.
//!
//! Worker -> driver on startup (v5 hello; older workers omit newer fields
//! and never receive newer-version messages). `auth` is present iff the
//! worker was configured with a shared token:
//!
//! ```json
//! {"type":"hello","v":5,"pid":12345,"transport":"pipe",
//!  "caps":["evict","keepalive"],"auth":"<token>"}
//! ```
//!
//! Driver -> worker (broadcasts and evicts are not acknowledged; tasks get
//! exactly one `result` or `error` reply; pings get exactly one `pong`):
//!
//! ```json
//! {"v":5,"type":"hello_ack","auth":"<token>"}
//! {"v":5,"type":"reject","msg":"auth token mismatch: ..."}
//! {"v":5,"type":"broadcast","id":"<hex64>","kind":"problem",
//!  "vecs":[...],"targets":[...],"times":[...]}
//! {"v":5,"type":"broadcast","id":"<hex64>","kind":"targets","targets":[...]}
//! {"v":5,"type":"broadcast","id":"<hex64>","kind":"shard","shard_id":0,
//!  "row_lo":0,"row_hi":100,"row_len":64,"n":400,"t0":2,
//!  "neighbors":[...],"vecs":[...]}
//! {"v":5,"type":"task","task":7,"op":"cross_map","problem":"<hex64>",
//!  "lib_rows":[...],"e":2,"theiler":0}
//! {"v":5,"type":"task","task":8,"op":"shard_chunk","shard":"<hex64>",
//!  "targets":"<hex64>","lib_rows":[...],"e":2,"theiler":0}
//! {"v":5,"type":"task","task":9,"op":"agg_chunk","shard":"<hex64>",
//!  "targets":"<hex64>","lib_rows":[...],"e":2,"theiler":0}
//! {"v":5,"type":"task","task":10,"op":"merge_sums",
//!  "sums":[[100,1.5,2.5,3.75,2.25,6.25],...]}
//! {"v":5,"type":"evict","id":"<hex64>"}
//! {"v":5,"type":"ping","nonce":41}
//! {"type":"shutdown"}
//! ```
//!
//! Worker -> driver replies (`agg_chunk`/`merge_sums` return the six
//! partial Pearson sums `[n, Σx, Σy, Σxy, Σx², Σy²]` — never predictions):
//!
//! ```json
//! {"type":"result","task":7,"rho":0.93,"preds":[...]}
//! {"type":"result","task":8,"preds":[...]}
//! {"type":"result","task":9,"sums":[100,1.5,2.5,3.75,2.25,6.25]}
//! {"type":"result","task":10,"sums":[400,6.0,10.0,15.0,9.0,25.0]}
//! {"type":"error","task":8,"msg":"unknown broadcast deadbeef"}
//! {"type":"pong","nonce":41}
//! ```
//!
//! v2 added `evict`: once a problem's jobs are harvested, the driver tells
//! every holder to drop the broadcast and releases its own serialized
//! payload (the payload cache is refcounted), so driver and worker memory
//! stay bounded on paper-scale parameter grids. v3 added the
//! authenticated handshake (`auth` in hello, answered by `hello_ack`,
//! refused by `reject` — clean named errors on both ends) and the
//! keepalive `ping`/`pong` pair that detects silently-dead remotes. v4
//! added the per-frame FNV-1a checksum suffix (`...}#<16 hex>`): once the
//! hello/`hello_ack` exchange negotiates v4 on both sides, every later
//! frame in both directions is checksummed and verified, so byte
//! corruption anywhere on the path is a *detected*, counted connection
//! death (`corrupt_frames_detected`) feeding the normal requeue/repair
//! machinery instead of a JSON-parse coin flip. v≤3 peers negotiate the
//! old byte streams unchanged (the handshake itself is never
//! checksummed). v5 added the worker-side reduce ops: `agg_chunk` folds a
//! shard chunk into compensated partial Pearson sums on the worker and
//! `merge_sums` merges ordered partials there, so with `--reduce worker`
//! the driver's result ingress shrinks from O(rows) prediction chunks to
//! ~48-byte sums (counted by `result_ingress_bytes`). Pools containing
//! any v≤4 worker — and the default `--reduce driver` — keep the
//! driver-concat path bit-for-bit. v6 added the binary wire
//! ([`BINARY_WIRE_VERSION`], codec in [`crate::ccm::binwire`]): once the
//! handshake negotiates v6 on both sides, every post-handshake message in
//! both directions rides a length-prefixed frame — payload-bearing
//! messages (the three broadcast kinds, `result` preds, v5 `sums`) as
//! tagged raw little-endian arrays with bit-packed neighbor indices,
//! everything else (tasks, ping/pong, evict, error, shutdown) as compact
//! JSON inside a `TAG_JSON` envelope, so the lease/speculation machinery
//! re-sends task lines verbatim regardless of wire mode. Negotiation is
//! **per connection**, at min(worker, driver): one v≤5 worker in a pool
//! silently pins *its own* connection to the byte-identical JSON wire
//! (`json_connections` vs `binary_connections` count the admits) without
//! affecting its v6 peers — unlike `pool_speaks_agg`, which must gate
//! pool-wide because agg results flow through shared driver state. The
//! v4 checksum rides along: binary frames carry an 8-byte little-endian
//! FNV-1a trailer instead of the 17-byte text suffix, with the same
//! counted-detection semantics. v7 added nothing on the worker wire: it
//! introduced the client-role hello (`"role":"client"`) and the
//! serve-mode control messages (`submit`/`status`/`fetch`/`cancel`,
//! plain JSON envelopes carried unchanged by the v6 framing) spoken
//! between a `parccm serve` daemon and its job clients — see
//! [`crate::ccm::serve`]. Workers are never sent any of them.
//!
//! Floats ride as JSON numbers; the writer emits shortest-roundtrip f64
//! and f32 -> f64 is exact, so every finite value survives the wire
//! bit-for-bit (`util::json` tests pin this), keeping cluster-backend
//! results bit-identical to in-process ones — on both transports. Binary
//! frames carry the raw bits themselves, which extends bit-exactness to
//! the values JSON text cannot express (NaN payloads, -0.0).
//!
//! # Scheduling, replication, and failure handling
//!
//! Workers come from a [`WorkerSource`] (see [`crate::ccm::lifecycle`]):
//! forked children of the driver binary, or pre-started remote
//! `parccm worker --listen` processes named by `--workers-at`. The
//! scheduler is source-agnostic; only death handling differs (fork:
//! respawn; remote: mark dead, shrink the pool).
//!
//! Dispatch is shard-affine with a load-balanced replica choice: among
//! idle workers already holding every broadcast a task needs, the one with
//! the fewest completed tasks wins; with no holder idle, the least-loaded
//! idle worker is shipped to. The **first** ship of a broadcast also
//! replicates it to `replicas - 1` additional idle workers, so shard loss
//! does not imply re-ship: a worker that dies mid-task (EOF/EPIPE/RST —
//! the OS closes the socket when the process dies, so a kill surfaces as
//! an I/O error even mid-exchange) is discarded, and the task is requeued
//! — onto a surviving replica with zero additional broadcast bytes when
//! one exists, or with a counted re-broadcast when the last replica died.
//! The keepalive prober covers the remaining gap for *idle* workers: a
//! remote whose host froze or dropped off the network without closing the
//! socket is pinged every interval and discarded when it misses the
//! deadline. A worker that goes silent the same way while *leased* to a
//! task is covered by the per-task lease scan on the same maintenance
//! thread: every dispatched task records a lease (start time, task kind,
//! holder), and a lease past `--task-deadline-secs` gets its worker
//! killed and the task requeued (`deadline_kills`), while a lease past
//! `--speculate-factor` × the running median duration for its task kind
//! gets a *speculative duplicate* launched on a different idle worker —
//! first result wins, the straggler is shot, and the loser's late reply
//! is discarded (`speculative_launches` / `speculative_wins`). With both
//! knobs unset, no lease is ever recorded and dispatch is byte-for-byte
//! the pre-v4 behavior.
//! After any death with `replicas > 1`, the scheduler *eagerly* re-ships
//! the dead worker's broadcasts to other live workers until the
//! replication factor is restored (counted separately as `repair_ships` /
//! `repair_ship_bytes`), so a second death inside the repair window no
//! longer forces a full re-broadcast. Between task attempts the scheduler
//! sleeps a jittered exponential backoff (the [`RejoinPolicy`] curve at
//! task scale), and after [`MAX_TASK_ATTEMPTS`] failures the task returns
//! a typed [`TaskExhausted`] error: `--on-exhausted abort` (default)
//! panics with an actionable message, `--on-exhausted fallback` computes
//! the task on the in-process native backend instead — bit-identical
//! results, counted as `exhausted_fallbacks`. A pool whose last worker
//! died and cannot regrow panics with an actionable message instead of
//! hanging.
//!
//! With `--rejoin-backoff-secs` set, a remote death is no longer final:
//! the dead address stays on a [`RejoinPolicy`] exponential-backoff
//! redial schedule, and a restarted `parccm worker --listen` on the same
//! host:port is re-admitted by the maintenance thread after a fresh
//! auth handshake — with a new worker id and an *empty* broadcast store,
//! so payloads re-ship on demand from the driver cache (counted as
//! `rejoin_ships` / `rejoin_ship_bytes`, distinct from the death-driven
//! `repair_ships`). An auth mismatch during a rejoin handshake retires
//! the address permanently (named error on both ends, no hot redial
//! loop).

use std::collections::{HashMap, HashSet, VecDeque};
use std::io::{BufRead, Write};
use std::net::TcpStream;
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::{Duration, Instant};

use crate::ccm::backend::{ComputeBackend, CrossMapInput, PoolCounters, TaskArena};
use crate::ccm::binwire;
use crate::ccm::chaos::{chaos_from_env, ChaosProfile, ChaosState, ChaosTransport};
use crate::ccm::lifecycle::{exp_backoff, RejoinPolicy, WorkerSource};
use crate::ccm::pipeline::PearsonSums;
use crate::ccm::table::TableShard;
use crate::ccm::transport::{
    bind_reuseaddr, connect_remote_deadline, ping_payload, read_frame, recv_json_counted,
    resolve_auth_token, write_frame, ChecksumTransport, Transport, TransportKind, WorkerLink,
    AGG_WIRE_VERSION, BINARY_WIRE_VERSION, CHECKSUM_WIRE_VERSION, EVICT_WIRE_VERSION,
    KEEPALIVE_WIRE_VERSION, REJOIN_CONNECT_TIMEOUT, WIRE_VERSION,
};
use crate::native::NativeBackend;
use crate::util::cli::Args;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Base delay of the jittered exponential backoff between task retry
/// attempts (the [`RejoinPolicy`] curve at task scale).
const RETRY_BACKOFF_BASE: Duration = Duration::from_millis(25);

/// Ceiling on the per-attempt retry backoff delay.
const RETRY_BACKOFF_CAP: Duration = Duration::from_secs(2);

/// Poll granularity of the leased-task reply read when a deadline or
/// speculation knob is active: the reply read wakes this often to check
/// whether its lease was superseded (speculative win) or deadline-killed.
const LEASE_POLL: Duration = Duration::from_millis(200);

/// Longest a speculative launch waits for an idle worker before giving
/// up quietly (the primary attempt still owns the task).
const SPECULATE_ACQUIRE_TIMEOUT: Duration = Duration::from_secs(2);

/// Running-median window per task kind for the speculation threshold.
const DURATION_WINDOW: usize = 512;

/// Minimum completed samples of a task kind before its running median is
/// trusted to arm speculation.
const MEDIAN_MIN_SAMPLES: usize = 3;

/// Attempts per task across worker replacements before giving up.
pub const MAX_TASK_ATTEMPTS: usize = 3;

/// Child-env knob that doctors the version a worker advertises in its
/// hello — a test seam for the handshake-mismatch regression tests (set
/// per-child by the driver's `worker_env`, never globally).
pub const TEST_HELLO_V_ENV: &str = "PARCCM_TEST_HELLO_V";

/// Env knob that makes a worker silently swallow keepalive pings — the
/// test seam for "silently-dead remote" coverage: the connection stays
/// open but the worker never answers, so only the keepalive deadline can
/// notice it is gone.
pub const TEST_IGNORE_PING_ENV: &str = "PARCCM_TEST_IGNORE_PING";

/// Keepalive cadence for remote pools when none is configured: idle
/// remote workers are pinged this often, and one that stays silent for a
/// further interval is marked dead — so a silently-dead remote is
/// detected within ~2 intervals instead of on the next task.
pub const DEFAULT_REMOTE_KEEPALIVE: Duration = Duration::from_secs(5);

// ---------------------------------------------------------------------------
// content addressing (same FNV-1a scheme as TableShard::wire_id — one
// shared helper so shard identity and wire dedup keys can never diverge)
// ---------------------------------------------------------------------------

use crate::ccm::table::{fnv1a64_word as fnv_word, FNV_OFFSET};

fn fnv_f32s(mut h: u64, xs: &[f32]) -> u64 {
    h = fnv_word(h, xs.len() as u64);
    for &x in xs {
        h = fnv_word(h, x.to_bits() as u64);
    }
    h
}

/// Content id of a brute-force problem broadcast (manifold + targets +
/// times). Hashing is O(n) per task but microseconds against a k-NN sweep,
/// and content addressing can never serve stale state after reallocation.
pub fn problem_wire_id(vecs: &[f32], targets: &[f32], times: &[f32]) -> u64 {
    fnv_f32s(fnv_f32s(fnv_f32s(fnv_word(FNV_OFFSET, 1), vecs), targets), times)
}

/// Content id of a targets-only broadcast (sharded table mode).
pub fn targets_wire_id(targets: &[f32]) -> u64 {
    fnv_f32s(fnv_word(FNV_OFFSET, 2), targets)
}

fn hex(id: u64) -> String {
    format!("{id:016x}")
}

// ---------------------------------------------------------------------------
// payload builders (driver side; cached per broadcast id)
// ---------------------------------------------------------------------------

fn broadcast_header(id: u64, kind: &str) -> Vec<(&'static str, Json)> {
    vec![
        ("v", Json::Num(WIRE_VERSION as f64)),
        ("type", Json::Str("broadcast".into())),
        ("id", Json::Str(hex(id))),
        ("kind", Json::Str(kind.to_string())),
    ]
}

/// The legacy JSON broadcast line for a problem — the v<=5 wire, still
/// shipped verbatim on pinned-JSON connections. Public so benches can
/// price the two wire encodings of the same content against each other.
pub fn problem_payload(id: u64, vecs: &[f32], targets: &[f32], times: &[f32]) -> String {
    let mut fields = broadcast_header(id, "problem");
    fields.push(("vecs", Json::f32s(vecs)));
    fields.push(("targets", Json::f32s(targets)));
    fields.push(("times", Json::f32s(times)));
    Json::obj(fields).to_string()
}

fn targets_payload(id: u64, targets: &[f32]) -> String {
    let mut fields = broadcast_header(id, "targets");
    fields.push(("targets", Json::f32s(targets)));
    Json::obj(fields).to_string()
}

fn shard_payload(id: u64, shard: &TableShard) -> String {
    let (neighbors, vecs) = shard.raw_parts();
    let mut fields = broadcast_header(id, "shard");
    fields.push(("shard_id", Json::Num(shard.shard_id as f64)));
    fields.push(("row_lo", Json::Num(shard.row_lo as f64)));
    fields.push(("row_hi", Json::Num(shard.row_hi as f64)));
    fields.push(("row_len", Json::Num(shard.row_len() as f64)));
    fields.push(("n", Json::Num(shard.n as f64)));
    fields.push(("t0", Json::Num(shard.t0 as f64)));
    fields.push(("neighbors", Json::u32s(neighbors)));
    fields.push(("vecs", Json::f32s(vecs)));
    Json::obj(fields).to_string()
}

fn evict_payload(id: u64) -> String {
    Json::obj(vec![
        ("v", Json::Num(WIRE_VERSION as f64)),
        ("type", Json::Str("evict".into())),
        ("id", Json::Str(hex(id))),
    ])
    .to_string()
}

// ---------------------------------------------------------------------------
// per-connection wire mode (v6)
// ---------------------------------------------------------------------------

/// Send a control or task message in the connection's wire mode: binary
/// connections wrap the line in a `TAG_JSON` envelope frame, JSON
/// connections send it verbatim. The handshake never comes through here.
fn send_control(t: &mut dyn Transport, binary: bool, line: &str) -> std::io::Result<()> {
    if binary {
        t.send_frame(&binwire::encode_json(line))
    } else {
        t.send_line(line)
    }
}

/// Worker-side reply send: on a binary connection, payload-bearing
/// results get their binary tag (via [`binwire::reply_frame`]), control
/// replies ride the JSON envelope; a JSON connection gets the line.
fn send_reply(t: &mut dyn Transport, binary: bool, reply: &Json) -> std::io::Result<()> {
    if binary {
        t.send_frame(&binwire::reply_frame(reply))
    } else {
        t.send_line(&reply.to_string())
    }
}

/// Driver-side receive in the connection's wire mode, returning the
/// message plus its on-wire byte count (JSON: trimmed line + newline;
/// binary: frame body + 4-byte length prefix — both excluding the
/// checksum layer's own overhead). EOF and malformed frames surface as
/// the same error kinds the JSON path produces, feeding the identical
/// connection-death machinery.
fn recv_msg_counted(t: &mut dyn Transport, binary: bool) -> std::io::Result<(Json, u64)> {
    if !binary {
        return recv_json_counted(t);
    }
    let Some(frame) = t.recv_frame()? else {
        return Err(std::io::Error::new(
            std::io::ErrorKind::UnexpectedEof,
            "worker closed its connection",
        ));
    };
    let bytes = frame.len() as u64 + 4;
    binwire::decode(&frame)
        .and_then(binwire::to_json)
        .map(|msg| (msg, bytes))
        .map_err(|e| std::io::Error::new(std::io::ErrorKind::InvalidData, e))
}

/// [`recv_msg_counted`] without the tally (keepalive probes).
fn recv_msg(t: &mut dyn Transport, binary: bool) -> std::io::Result<Json> {
    recv_msg_counted(t, binary).map(|(msg, _)| msg)
}

/// The raw content of one broadcast, kept driver-side so either wire
/// encoding can be produced on demand. Owning the arrays (rather than a
/// pre-serialized string) is what makes the dual encoding lazy: a pool
/// that negotiated v6 everywhere never pays the float→text JSON encode at
/// all, and a pinned-JSON connection never pays the binary one.
enum PayloadSrc {
    Problem { id: u64, vecs: Vec<f32>, targets: Vec<f32>, times: Vec<f32> },
    Targets { id: u64, targets: Vec<f32> },
    /// An owned copy rebuilt from the source shard's raw parts
    /// ([`TableShard`] is deliberately not `Clone` — it carries per-shard
    /// runtime state), captured once when the payload is first cached.
    Shard { id: u64, shard: TableShard },
}

/// One cached broadcast payload with both wire encodings, each produced
/// on first use and then shared by every later ship of the same content.
struct Payload {
    src: PayloadSrc,
    line: OnceLock<Arc<String>>,
    bin: OnceLock<Arc<Vec<u8>>>,
}

impl PayloadSrc {
    /// Capture an owned copy of `shard` for the payload cache.
    fn from_shard(id: u64, shard: &TableShard) -> PayloadSrc {
        let (neighbors, vecs) = shard.raw_parts();
        PayloadSrc::Shard {
            id,
            shard: TableShard::from_parts(
                shard.shard_id,
                shard.row_lo,
                shard.row_hi,
                shard.row_len(),
                shard.n,
                shard.t0,
                neighbors.to_vec(),
                vecs.to_vec(),
            ),
        }
    }
}

impl Payload {
    fn new(src: PayloadSrc) -> Payload {
        Payload { src, line: OnceLock::new(), bin: OnceLock::new() }
    }

    /// The JSON wire line — byte-identical to the pre-v6 payload builders
    /// (the pinned-JSON fallback tests compare against exactly this).
    fn line(&self) -> &Arc<String> {
        self.line.get_or_init(|| {
            Arc::new(match &self.src {
                PayloadSrc::Problem { id, vecs, targets, times } => {
                    problem_payload(*id, vecs, targets, times)
                }
                PayloadSrc::Targets { id, targets } => targets_payload(*id, targets),
                PayloadSrc::Shard { id, shard } => shard_payload(*id, shard),
            })
        })
    }

    /// The v6 binary frame body.
    fn bin(&self) -> &Arc<Vec<u8>> {
        self.bin.get_or_init(|| {
            Arc::new(match &self.src {
                PayloadSrc::Problem { id, vecs, targets, times } => {
                    binwire::encode_problem(*id, vecs, targets, times)
                }
                PayloadSrc::Targets { id, targets } => binwire::encode_targets(*id, targets),
                PayloadSrc::Shard { id, shard } => binwire::encode_shard(*id, shard),
            })
        })
    }

    /// On-wire byte count of one ship of this payload in the given mode
    /// (line + newline, or frame body + length prefix).
    fn wire_bytes(&self, binary: bool) -> u64 {
        if binary {
            self.bin().len() as u64 + 4
        } else {
            self.line().len() as u64 + 1
        }
    }

    /// Send this payload in the connection's wire mode.
    fn send(&self, t: &mut dyn Transport, binary: bool) -> std::io::Result<()> {
        if binary {
            t.send_frame(self.bin())
        } else {
            t.send_line(self.line())
        }
    }
}

// ---------------------------------------------------------------------------
// worker (child-process side)
// ---------------------------------------------------------------------------

enum Stored {
    Problem { vecs: Vec<f32>, targets: Vec<f32>, times: Vec<f32> },
    Targets(Vec<f32>),
    Shard(TableShard),
}

fn field_f64(msg: &Json, key: &str) -> Result<f64, String> {
    msg.get(key).and_then(Json::as_f64).ok_or_else(|| format!("missing number '{key}'"))
}

fn field_usize(msg: &Json, key: &str) -> Result<usize, String> {
    Ok(field_f64(msg, key)? as usize)
}

fn field_str<'a>(msg: &'a Json, key: &str) -> Result<&'a str, String> {
    msg.get(key).and_then(Json::as_str).ok_or_else(|| format!("missing string '{key}'"))
}

fn field_f32s(msg: &Json, key: &str) -> Result<Vec<f32>, String> {
    msg.get(key).and_then(Json::as_f32s).ok_or_else(|| format!("missing f32 array '{key}'"))
}

fn store_broadcast(store: &mut HashMap<String, Stored>, msg: &Json) -> Result<(), String> {
    let id = field_str(msg, "id")?.to_string();
    let value = match field_str(msg, "kind")? {
        "problem" => Stored::Problem {
            vecs: field_f32s(msg, "vecs")?,
            targets: field_f32s(msg, "targets")?,
            times: field_f32s(msg, "times")?,
        },
        "targets" => Stored::Targets(field_f32s(msg, "targets")?),
        "shard" => Stored::Shard(TableShard::from_parts(
            field_usize(msg, "shard_id")?,
            field_usize(msg, "row_lo")?,
            field_usize(msg, "row_hi")?,
            field_usize(msg, "row_len")?,
            field_usize(msg, "n")?,
            field_usize(msg, "t0")?,
            msg.get("neighbors").and_then(Json::as_u32s).ok_or("missing 'neighbors'")?,
            field_f32s(msg, "vecs")?,
        )),
        other => return Err(format!("unknown broadcast kind '{other}'")),
    };
    store.insert(id, value);
    Ok(())
}

/// Store a broadcast that arrived as a typed v6 frame — no JSON detour:
/// the decoded arrays (and the rebuilt [`TableShard`]) move straight into
/// the store the task ops read from.
fn store_bin_broadcast(store: &mut HashMap<String, Stored>, b: binwire::Broadcast) {
    match b {
        binwire::Broadcast::Problem { id, vecs, targets, times } => {
            store.insert(hex(id), Stored::Problem { vecs, targets, times });
        }
        binwire::Broadcast::Targets { id, targets } => {
            store.insert(hex(id), Stored::Targets(targets));
        }
        binwire::Broadcast::Shard { id, shard } => {
            store.insert(hex(id), Stored::Shard(shard));
        }
    }
}

/// Encode partial Pearson sums as the wire array `[n, Σx, Σy, Σxy, Σx²,
/// Σy²]`. The JSON writer emits shortest-roundtrip f64, so the sums
/// survive the wire bit-for-bit.
fn sums_to_json(s: &PearsonSums) -> Json {
    Json::Arr(vec![
        Json::Num(s.n as f64),
        Json::Num(s.sx),
        Json::Num(s.sy),
        Json::Num(s.sxy),
        Json::Num(s.sxx),
        Json::Num(s.syy),
    ])
}

fn sums_from_json(v: &Json) -> Result<PearsonSums, String> {
    let arr = v.as_arr().ok_or("partial sums must be a 6-element array")?;
    if arr.len() != 6 {
        return Err(format!("partial sums must have 6 elements, got {}", arr.len()));
    }
    let f = |i: usize| arr[i].as_f64().ok_or_else(|| format!("non-numeric sum at index {i}"));
    Ok(PearsonSums { n: f(0)? as u64, sx: f(1)?, sy: f(2)?, sxy: f(3)?, sxx: f(4)?, syy: f(5)? })
}

/// Parse the common cross-map task fields (library rows, E, theiler) —
/// present on every op except `merge_sums`, which carries only sums.
fn task_common(msg: &Json) -> Result<(Vec<usize>, usize, f32), String> {
    let lib_rows = msg
        .get("lib_rows")
        .and_then(Json::as_usizes)
        .ok_or("missing 'lib_rows'")?;
    let e = field_usize(msg, "e")?;
    let theiler = field_f64(msg, "theiler")? as f32;
    Ok((lib_rows, e, theiler))
}

fn run_task(
    store: &HashMap<String, Stored>,
    arena: &mut TaskArena,
    msg: &Json,
) -> Result<Json, String> {
    let task = field_f64(msg, "task")?;
    let backend = NativeBackend;
    match field_str(msg, "op")? {
        "cross_map" => {
            let (lib_rows, e, theiler) = task_common(msg)?;
            let pid = field_str(msg, "problem")?;
            let Some(Stored::Problem { vecs, targets, times }) = store.get(pid) else {
                return Err(format!("unknown broadcast {pid}"));
            };
            let input = CrossMapInput {
                vecs,
                targets,
                times,
                lib_rows: &lib_rows,
                e,
                theiler,
            };
            let rho = backend.cross_map_into(&input, arena);
            Ok(Json::obj(vec![
                ("type", Json::Str("result".into())),
                ("task", Json::Num(task)),
                ("rho", Json::Num(rho as f64)),
                ("preds", Json::f32s(&arena.preds)),
            ]))
        }
        "shard_chunk" => {
            let (lib_rows, e, theiler) = task_common(msg)?;
            let sid = field_str(msg, "shard")?;
            let tid = field_str(msg, "targets")?;
            let Some(Stored::Shard(shard)) = store.get(sid) else {
                return Err(format!("unknown broadcast {sid}"));
            };
            let Some(Stored::Targets(targets)) = store.get(tid) else {
                return Err(format!("unknown broadcast {tid}"));
            };
            let mut preds = Vec::new();
            backend.shard_chunk_into(shard, targets, theiler, &lib_rows, e, arena, &mut preds);
            Ok(Json::obj(vec![
                ("type", Json::Str("result".into())),
                ("task", Json::Num(task)),
                ("preds", Json::f32s(&preds)),
            ]))
        }
        // v5: fold the shard's predictions into partial Pearson sums on
        // this side of the wire — the reply is ~48 bytes of sums, never
        // the predictions.
        "agg_chunk" => {
            let (lib_rows, e, theiler) = task_common(msg)?;
            let sid = field_str(msg, "shard")?;
            let tid = field_str(msg, "targets")?;
            let Some(Stored::Shard(shard)) = store.get(sid) else {
                return Err(format!("unknown broadcast {sid}"));
            };
            let Some(Stored::Targets(targets)) = store.get(tid) else {
                return Err(format!("unknown broadcast {tid}"));
            };
            let sums = backend.agg_chunk_into(shard, targets, theiler, &lib_rows, e, arena);
            Ok(Json::obj(vec![
                ("type", Json::Str("result".into())),
                ("task", Json::Num(task)),
                ("sums", sums_to_json(&sums)),
            ]))
        }
        // v5: merge ordered partials (the driver sends them sorted by
        // shard index) into one sums vector. No broadcasts needed.
        "merge_sums" => {
            let parts = msg
                .get("sums")
                .and_then(Json::as_arr)
                .ok_or("missing 'sums'")?
                .iter()
                .map(sums_from_json)
                .collect::<Result<Vec<PearsonSums>, String>>()?;
            let merged = backend.merge_sums(&parts);
            Ok(Json::obj(vec![
                ("type", Json::Str("result".into())),
                ("task", Json::Num(task)),
                ("sums", sums_to_json(&merged)),
            ]))
        }
        other => Err(format!("unknown op '{other}'")),
    }
}

fn error_reply(msg: &Json, err: String) -> Json {
    Json::obj(vec![
        ("type", Json::Str("error".into())),
        ("task", msg.get("task").cloned().unwrap_or(Json::Null)),
        ("msg", Json::Str(err)),
    ])
}

/// The worker's stdio byte layer, as a [`Transport`] so the serve loop
/// can layer chaos/checksum wrappers over pipes exactly as over TCP.
struct StdioTransport {
    stdin: std::io::BufReader<std::io::Stdin>,
    stdout: std::io::Stdout,
}

impl StdioTransport {
    fn new() -> StdioTransport {
        StdioTransport { stdin: std::io::BufReader::new(std::io::stdin()), stdout: std::io::stdout() }
    }
}

impl Transport for StdioTransport {
    fn send_line(&mut self, line: &str) -> std::io::Result<()> {
        writeln!(self.stdout, "{line}")?;
        self.stdout.flush()
    }

    fn recv_line(&mut self) -> std::io::Result<Option<String>> {
        let mut line = String::new();
        match self.stdin.read_line(&mut line)? {
            0 => Ok(None),
            _ => Ok(Some(line)),
        }
    }

    fn send_frame(&mut self, frame: &[u8]) -> std::io::Result<()> {
        write_frame(&mut self.stdout, frame)
    }

    fn recv_frame(&mut self) -> std::io::Result<Option<Vec<u8>>> {
        read_frame(&mut self.stdin)
    }

    fn kind(&self) -> TransportKind {
        TransportKind::Pipe
    }
}

/// Serve one driver connection: emit the hello (presenting the shared
/// auth token when one is configured), then answer the v3 handshake ack,
/// keepalive pings, broadcasts, evicts, and tasks until EOF (driver gone)
/// or an explicit shutdown. Once the `hello_ack` reveals a v4+ driver,
/// the rest of the connection (both directions) runs checksummed — and
/// chaos-wrapped when `PARCCM_CHAOS` is set in the worker's environment.
/// A corrupt frame is a clean, logged connection death: the driver sees
/// EOF and its normal requeue/repair machinery takes over.
fn serve(
    mut transport: Box<dyn Transport>,
    kind: TransportKind,
    token: Option<String>,
) -> std::process::ExitCode {
    let advertised = std::env::var(TEST_HELLO_V_ENV)
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or(WIRE_VERSION);
    let ignore_ping = std::env::var(TEST_IGNORE_PING_ENV).is_ok();
    let pid = std::process::id();
    let chaos = match chaos_from_env() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("[worker {pid}] {e}");
            return std::process::ExitCode::FAILURE;
        }
    };
    let chaos_state = ChaosState::new();
    let mut fields = vec![
        ("type", Json::Str("hello".into())),
        ("v", Json::Num(advertised as f64)),
        ("pid", Json::Num(pid as f64)),
        ("transport", Json::Str(kind.name().into())),
        (
            "caps",
            Json::Arr(vec![Json::Str("evict".into()), Json::Str("keepalive".into())]),
        ),
    ];
    if let Some(t) = &token {
        fields.push(("auth", Json::Str(t.clone())));
    }
    let hello = Json::obj(fields);
    if transport.send_line(&hello.to_string()).is_err() {
        return std::process::ExitCode::FAILURE;
    }
    // with a token configured, the driver must prove knowledge of it in
    // its hello_ack before any broadcast or task is honored
    let mut authed = token.is_none();
    // the handshake always rides the raw byte layer; chaos + checksum are
    // layered on when the hello_ack announces a v4+ driver
    let mut wrapped = false;
    // set when the hello_ack negotiates v6: every later message in both
    // directions is a binary frame (the handshake itself is always lines)
    let mut binary = false;
    let mut store: HashMap<String, Stored> = HashMap::new();
    let mut arena = TaskArena::new();
    loop {
        let msg = if binary {
            let frame = match transport.recv_frame() {
                Ok(Some(f)) => f,
                Ok(None) => break, // EOF: driver gone
                Err(e) => {
                    // includes a failed v4 checksum: die cleanly and loudly
                    // so the driver's death machinery requeues our task
                    eprintln!("[worker {pid}] connection error: {e}");
                    return std::process::ExitCode::FAILURE;
                }
            };
            match binwire::decode(&frame) {
                // typed broadcasts skip the JSON detour entirely (binary
                // mode implies the hello_ack already authenticated us)
                Ok(binwire::BinMsg::Broadcast(b)) => {
                    store_bin_broadcast(&mut store, b);
                    continue;
                }
                Ok(binwire::BinMsg::Json(m)) => m,
                Ok(_) => {
                    eprintln!("[worker {pid}] protocol error: result frame from the driver");
                    return std::process::ExitCode::FAILURE;
                }
                Err(e) => {
                    eprintln!("[worker {pid}] bad frame: {e}");
                    return std::process::ExitCode::FAILURE;
                }
            }
        } else {
            let line = match transport.recv_line() {
                Ok(Some(l)) => l,
                Ok(None) => break, // EOF: driver gone
                Err(e) => {
                    // includes a failed v4 checksum: die cleanly and loudly
                    // so the driver's death machinery requeues our task
                    eprintln!("[worker {pid}] connection error: {e}");
                    return std::process::ExitCode::FAILURE;
                }
            };
            if line.trim().is_empty() {
                continue;
            }
            match Json::parse(&line) {
                Ok(m) => m,
                Err(e) => {
                    eprintln!("[worker {pid}] bad message: {e}");
                    return std::process::ExitCode::FAILURE;
                }
            }
        };
        let kind_str = msg.get("type").and_then(Json::as_str);
        // handshake / keepalive traffic first — valid before auth
        match kind_str {
            Some("shutdown") => return std::process::ExitCode::SUCCESS,
            Some("reject") => {
                // the driver refused us by name (auth/version): surface it
                let why = msg.get("msg").and_then(Json::as_str).unwrap_or("unspecified");
                eprintln!("[worker {pid}] rejected by driver: {why}");
                return std::process::ExitCode::FAILURE;
            }
            Some("hello_ack") => {
                if token.is_some() && msg.get("auth").and_then(Json::as_str) != token.as_deref() {
                    eprintln!(
                        "[worker {pid}] auth token mismatch: driver's hello_ack does not \
                         carry this worker's token — refusing to serve it"
                    );
                    return std::process::ExitCode::FAILURE;
                }
                authed = true;
                if !wrapped {
                    wrapped = true;
                    let driver_v =
                        msg.get("v").and_then(Json::as_f64).map(|v| v as u64).unwrap_or(0);
                    let negotiated = driver_v.min(advertised);
                    if negotiated >= CHECKSUM_WIRE_VERSION {
                        if let Some((seed, profile)) = &chaos {
                            transport = Box::new(ChaosTransport::new(
                                transport,
                                *seed,
                                profile.clone(),
                                Arc::clone(&chaos_state),
                            ));
                        }
                        transport = Box::new(ChecksumTransport::new(transport, None));
                    }
                    // v6: both sides switch to length-prefixed binary frames
                    // for everything after the handshake
                    binary = negotiated >= BINARY_WIRE_VERSION;
                }
                continue;
            }
            Some("ping") => {
                if ignore_ping {
                    continue; // test seam: play silently dead
                }
                let pong = Json::obj(vec![
                    ("type", Json::Str("pong".into())),
                    ("nonce", msg.get("nonce").cloned().unwrap_or(Json::Null)),
                ]);
                if send_reply(transport.as_mut(), binary, &pong).is_err() {
                    break;
                }
                continue;
            }
            _ => {}
        }
        if !authed {
            eprintln!(
                "[worker {pid}] refusing {} before an authenticated hello_ack",
                kind_str.unwrap_or("message")
            );
            let _ = send_reply(transport.as_mut(), binary, &error_reply(&msg, "worker requires auth".into()));
            return std::process::ExitCode::FAILURE;
        }
        let reply = match kind_str {
            Some("broadcast") => match store_broadcast(&mut store, &msg) {
                Ok(()) => None, // broadcasts are unacknowledged
                Err(e) => Some(error_reply(&msg, e)),
            },
            // v2: drop a harvested broadcast; unacknowledged like broadcast
            Some("evict") => match field_str(&msg, "id") {
                Ok(id) => {
                    store.remove(id);
                    None
                }
                Err(e) => Some(error_reply(&msg, e)),
            },
            Some("task") => match run_task(&store, &mut arena, &msg) {
                Ok(r) => Some(r),
                Err(e) => Some(error_reply(&msg, e)),
            },
            other => Some(error_reply(&msg, format!("unknown message type {other:?}"))),
        };
        if let Some(reply) = reply {
            if send_reply(transport.as_mut(), binary, &reply).is_err() {
                break; // driver hung up
            }
        }
    }
    std::process::ExitCode::SUCCESS
}

/// The worker process entry point (`parccm worker [--connect ADDR |
/// --listen ADDR] [--auth-token T]`): serve the driver over stdio
/// (default), an outbound TCP connection (`--connect`, how
/// [`ClusterBackend`] spawns TCP workers), or a single accepted inbound
/// connection (`--listen`, for pre-started remote workers reached via
/// `--workers-at`). Listen mode announces the bound address on **stdout**
/// as `PARCCM_WORKER_LISTENING host:port` (so `--listen 127.0.0.1:0`
/// ephemeral ports can be captured by scripts); diagnostics go to stderr.
pub fn worker_main(args: &Args) -> std::process::ExitCode {
    let token = resolve_auth_token(args.get("auth-token"));
    if let Some(addr) = args.get("connect") {
        let stream = match TcpStream::connect(addr) {
            Ok(s) => s,
            Err(e) => {
                eprintln!("[worker] cannot connect to driver at {addr}: {e}");
                return std::process::ExitCode::FAILURE;
            }
        };
        serve_tcp(stream, token)
    } else if let Some(addr) = args.get("listen") {
        // SO_REUSEADDR bind: a RESTARTED worker must be able to re-listen
        // on the port its predecessor just died on (the rejoin path is
        // "same address, new process"), even while the dead connection
        // lingers in TIME_WAIT
        let listener = match bind_reuseaddr(addr) {
            Ok(l) => l,
            Err(e) => {
                eprintln!("[worker] cannot listen on {addr}: {e}");
                return std::process::ExitCode::FAILURE;
            }
        };
        let bound = listener
            .local_addr()
            .map(|a| a.to_string())
            .unwrap_or_else(|_| addr.to_string());
        // machine-readable ready line on stdout: launch scripts parse it
        println!("PARCCM_WORKER_LISTENING {bound}");
        let _ = std::io::stdout().flush();
        eprintln!("[worker {}] listening on {bound}", std::process::id());
        match listener.accept() {
            Ok((stream, peer)) => {
                // close the listener: later dials get a clean refusal
                // instead of queueing in a backlog nothing will accept
                // (a rejoin redial probing a busy worker must fail fast)
                drop(listener);
                eprintln!("[worker {}] driver connected from {peer}", std::process::id());
                serve_tcp(stream, token)
            }
            Err(e) => {
                eprintln!("[worker] accept failed: {e}");
                std::process::ExitCode::FAILURE
            }
        }
    } else {
        serve(Box::new(StdioTransport::new()), TransportKind::Pipe, token)
    }
}

fn serve_tcp(stream: TcpStream, token: Option<String>) -> std::process::ExitCode {
    match crate::ccm::transport::TcpTransport::from_stream(stream) {
        Ok(t) => serve(Box::new(t), TransportKind::Tcp, token),
        Err(e) => {
            eprintln!("[worker] cannot set up socket: {e}");
            std::process::ExitCode::FAILURE
        }
    }
}

// ---------------------------------------------------------------------------
// driver (scheduler side)
// ---------------------------------------------------------------------------

/// How a [`ClusterBackend`] is shaped: worker source, transport, pool
/// width, replication, and liveness probing.
#[derive(Clone, Debug)]
pub struct ClusterOptions {
    /// Byte layer to reach workers over (`--transport pipe|tcp`).
    pub transport: TransportKind,
    /// Worker processes in the pool (`--proc-workers N`). Ignored when
    /// `workers_at` is non-empty — the address list *is* the pool.
    pub workers: usize,
    /// Workers each broadcast is resident on (`--replicas R`); clamped to
    /// the pool size. 1 = no replication (ship only where tasks land).
    pub replicas: usize,
    /// Extra environment set on spawned workers only (test seams such as
    /// [`TEST_HELLO_V_ENV`], log knobs; never inherited by the driver).
    /// Remote workers are pre-started and never see it.
    pub worker_env: Vec<(String, String)>,
    /// Pre-started `parccm worker --listen` processes to connect to
    /// instead of forking (`--workers-at host:port,...`). Non-empty
    /// selects [`WorkerSource::Remote`]: the transport is TCP by
    /// construction and a dead worker cannot be respawned.
    pub workers_at: Vec<String>,
    /// Shared secret for the authenticated handshake (`--auth-token` /
    /// `PARCCM_AUTH_TOKEN`); forked workers inherit it automatically.
    pub auth_token: Option<String>,
    /// Keepalive cadence for idle workers. `None` = automatic
    /// ([`DEFAULT_REMOTE_KEEPALIVE`] for remote pools, off for forked
    /// pools, whose death is visible as EOF); `Some(Duration::ZERO)` =
    /// explicitly off.
    pub keepalive: Option<Duration>,
    /// Base delay of the [`RejoinPolicy`] redial schedule for dead
    /// remote workers (`--rejoin-backoff-secs`). `None` or zero = off —
    /// a dead remote is gone for the life of the pool (the pre-rejoin
    /// behavior). Only meaningful for remote sources; forked workers are
    /// respawned instead.
    pub rejoin_backoff: Option<Duration>,
    /// Hard per-task wall-clock limit (`--task-deadline-secs`). A leased
    /// task running longer has its worker killed and is requeued
    /// (`deadline_kills`). `None` = off (the pre-v4 behavior).
    pub task_deadline: Option<Duration>,
    /// Straggler threshold (`--speculate-factor X`): a leased task
    /// running longer than X times the running median duration of its
    /// task kind gets a speculative duplicate on a different idle worker;
    /// first result wins. `None` = off.
    pub speculate_factor: Option<f64>,
    /// What to do when a task exhausts [`MAX_TASK_ATTEMPTS`]
    /// (`--on-exhausted abort|fallback`).
    pub on_exhausted: OnExhausted,
    /// Driver-side deterministic fault injection: seed + profile wrapped
    /// around every post-handshake worker connection (filled from
    /// `PARCCM_CHAOS` by the CLI; a field rather than an env read so
    /// threaded tests can scope chaos to one pool).
    pub chaos: Option<(u64, ChaosProfile)>,
}

impl Default for ClusterOptions {
    fn default() -> ClusterOptions {
        ClusterOptions {
            transport: TransportKind::Pipe,
            workers: 2,
            replicas: 1,
            worker_env: Vec::new(),
            workers_at: Vec::new(),
            auth_token: None,
            keepalive: None,
            rejoin_backoff: None,
            task_deadline: None,
            speculate_factor: None,
            on_exhausted: OnExhausted::Abort,
            chaos: None,
        }
    }
}

/// Policy when a task fails [`MAX_TASK_ATTEMPTS`] times across worker
/// replacements (`--on-exhausted`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum OnExhausted {
    /// Panic with an actionable message (the default, and the pre-v4
    /// behavior — minus the backoff between attempts).
    #[default]
    Abort,
    /// Compute the task on the in-process native backend instead —
    /// bit-identical results (workers run the same native kernels),
    /// counted as `exhausted_fallbacks` and logged.
    Fallback,
}

impl OnExhausted {
    /// Parse the `--on-exhausted` flag value.
    pub fn parse(s: &str) -> Option<OnExhausted> {
        match s {
            "abort" => Some(OnExhausted::Abort),
            "fallback" => Some(OnExhausted::Fallback),
            _ => None,
        }
    }
}

/// Typed terminal failure of one task: every attempt died or errored.
/// Surfaced through [`ComputeBackend`] so the driver can degrade per
/// [`OnExhausted`] instead of unconditionally aborting mid-job.
#[derive(Debug)]
pub struct TaskExhausted {
    /// Wire id of the task that gave up.
    pub task_id: u64,
    /// Attempts made ([`MAX_TASK_ATTEMPTS`]).
    pub attempts: usize,
    /// The last attempt's failure, verbatim.
    pub last_err: String,
}

impl std::fmt::Display for TaskExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "cluster backend task {} failed {} attempts: {}",
            self.task_id, self.attempts, self.last_err
        )
    }
}

struct Worker {
    /// Stable identity for holder bookkeeping (pids can recycle).
    serial: u64,
    /// Pool slot (for remote sources, the index into the address list —
    /// what the rejoin redialer needs to know *which* address died).
    slot: usize,
    /// Admitted by a rejoin redial: its on-demand broadcast re-ships are
    /// counted as `rejoin_ships` (the price of the rejoin).
    rejoined: bool,
    link: WorkerLink,
    /// Wire version negotiated at handshake (v1 workers get no `evict`).
    wire_v: u64,
    /// Broadcast ids this worker holds (reset on respawn).
    has: HashSet<u64>,
    /// Completed tasks — the load-balancing key among replicas.
    tasks_done: u64,
}

impl Worker {
    /// This connection negotiated the v6 binary wire at its handshake.
    /// Per-connection, not pool-wide: one legacy worker pins only its own
    /// connection to the JSON line wire.
    fn binary(&self) -> bool {
        self.wire_v >= BINARY_WIRE_VERSION
    }
}

#[derive(Default)]
struct PoolState {
    idle: Vec<Worker>,
    /// Workers existing (idle or leased to a task).
    live: usize,
    /// Live workers whose negotiated wire version predates
    /// [`AGG_WIRE_VERSION`]. While nonzero, the driver never dispatches
    /// the v5 reduce ops (a mixed pool keeps the compatible concat path
    /// instead of risking unknown-op retries on a legacy worker).
    legacy_live: usize,
    /// Workers replaced after dying mid-exchange (fork sources only).
    respawns: u64,
    /// Remote workers lost for good (no respawn possible).
    remote_lost: u64,
    /// Workers declared dead by the keepalive prober (no pong in time).
    keepalive_deaths: u64,
    /// Broadcast id -> serials of live workers holding it.
    holders: HashMap<u64, HashSet<u64>>,
    /// Ids ever shipped (distinguishes first ships from re-broadcasts).
    shipped_ever: HashSet<u64>,
    /// Evicted ids whose leased holders still need the evict message.
    evicted_pending: HashSet<u64>,
    /// (id, worker) broadcast ships performed, including replica copies.
    ships: u64,
    /// Bytes actually written for broadcast ships under each
    /// connection's negotiated encoding (JSON line + newline, or binary
    /// frame + length prefix; checksum trailers excluded in both modes).
    ship_bytes: u64,
    /// Ships of an id whose replicas had all died — the re-broadcast
    /// fallback replication exists to avoid.
    rebroadcasts: u64,
    /// Repair copies shipped by eager re-replication after a death
    /// (counted apart from task-driven `ships`, so "zero re-ship requeue"
    /// stays assertable).
    repair_ships: u64,
    /// Bytes written by eager re-replication repair ships.
    repair_ship_bytes: u64,
    /// `evict` messages delivered to workers.
    evictions: u64,
    /// Remote workers re-admitted by the rejoin redialer.
    rejoins: u64,
    /// Rejoin redial attempts (successes, failures, and rejections).
    rejoin_attempts: u64,
    /// Addresses permanently retired after an auth-rejected rejoin.
    rejoin_rejected: u64,
    /// Task-driven broadcast ships whose target was a worker admitted by
    /// rejoin (also included in `ships`; replica/repair copies are
    /// counted on their own counters, never here). A rejoined worker
    /// starts empty, so its early ships are the rejoin's lazy
    /// re-population; the flag is permanent, so later first-ships of
    /// brand-new content to it also land here — an *upper bound* on the
    /// rejoin's re-ship cost, distinct from the death-driven
    /// `repair_ships`.
    rejoin_ships: u64,
    /// Bytes written by task-driven ships to rejoined workers.
    rejoin_ship_bytes: u64,
    /// Connections admitted speaking the v6 binary wire (cumulative over
    /// the run: spawns, respawns, and rejoins all count their admit).
    binary_connections: u64,
    /// Connections admitted pinned to the JSON line wire (v≤5 peers).
    json_connections: u64,
    /// Round-robin grant order across jobs with waiters in [`acquire`].
    /// Each job id appears at most once; the front job owns the next idle
    /// worker. Fairness is at *worker-grant* granularity: a job with a
    /// thousand queued tasks gets one worker, then goes to the back of
    /// the line behind every other waiting job — one huge grid cannot
    /// starve a small one. Batch runs (every task job 0) degenerate to
    /// exactly the old FIFO-on-condvar behaviour.
    rr: VecDeque<u64>,
    /// Waiter count per job currently parked in [`acquire`]; a job leaves
    /// `rr` when its count drops to zero.
    waiting: HashMap<u64, usize>,
}

/// Per-job slice of the pool counters, keyed by the job id every task and
/// ship is tagged with (batch paths run as job 0). Summed over all jobs,
/// `broadcast_ships` equals the pool's `ships` and `result_ingress_bytes`
/// equals the pool's total — asserted by the serve-mode tests, so counter
/// bleed between tenants is structurally visible.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct JobTally {
    /// Tasks completed on behalf of this job (speculative wins count once).
    pub tasks: u64,
    /// Broadcast ships performed for this job's dispatches, including
    /// replica copies made on its first ship.
    pub broadcast_ships: u64,
    /// On-wire bytes of those ships (same encoding rules as `ship_bytes`).
    pub broadcast_ship_bytes: u64,
    /// Bytes of accepted task-result frames attributed to this job.
    pub result_ingress_bytes: u64,
    /// Grid cells this job's driver stopped early under `--partial`
    /// (CI-tight stops plus slice-pruned cells).
    pub partial_stops: u64,
    /// Subsample tasks this job never dispatched because of those stops.
    pub partial_saved_tasks: u64,
}

impl JobTally {
    /// Stable (name, value) pairs for JSON surfaces, mirroring
    /// [`PoolCounters::to_pairs`] naming.
    pub fn to_pairs(&self) -> Vec<(&'static str, u64)> {
        vec![
            ("tasks", self.tasks),
            ("broadcast_ships", self.broadcast_ships),
            ("broadcast_ship_bytes", self.broadcast_ship_bytes),
            ("result_ingress_bytes", self.result_ingress_bytes),
            ("partial_stops", self.partial_stops),
            ("partial_saved_tasks", self.partial_saved_tasks),
        ]
    }
}

/// Why a worker was declared dead (for counters and log lines).
#[derive(Clone, Copy, Debug)]
enum DeathCause {
    /// An I/O failure surfaced while exchanging traffic with it.
    Exchange,
    /// It stayed silent past the keepalive deadline.
    Keepalive,
}

/// How a task exchange failed: a broken connection means the worker is
/// gone, while a wire-level `error` reply comes from a live, healthy
/// worker — the two must not share a recovery path (discarding a live
/// REMOTE worker over a task error would shrink the pool forever).
enum ExchangeError {
    /// Connection-level failure (EOF/EPIPE/RST): the worker is dead.
    Dead(std::io::Error),
    /// The worker answered `{"type":"error",...}`: it is alive.
    App(String),
}

/// Tally one admitted connection under the wire mode its handshake
/// negotiated. Called wherever a worker enters the pool: initial spawn,
/// death respawn, and rejoin redial.
fn note_connection(st: &mut PoolState, w: &Worker) {
    if w.binary() {
        st.binary_connections += 1;
    } else {
        st.json_connections += 1;
    }
}

/// Record one (id -> worker) broadcast ship; returns whether this was the
/// id's first ship ever (the moment replication tops up). `wire_bytes` is
/// the on-wire size of the ship under the connection's negotiated
/// encoding (line + newline, or binary frame + length prefix).
fn record_ship(st: &mut PoolState, id: u64, serial: u64, wire_bytes: u64) -> bool {
    let first_ever = st.shipped_ever.insert(id);
    let lost_all = match st.holders.get(&id) {
        Some(set) => set.is_empty(),
        None => true,
    };
    if !first_ever && lost_all {
        st.rebroadcasts += 1;
    }
    st.holders.entry(id).or_default().insert(serial);
    st.ships += 1;
    st.ship_bytes += wire_bytes;
    first_ever
}

/// Remove `serial` from `id`'s holder set, clearing bookkeeping when the
/// last holder is gone.
fn drop_holder(st: &mut PoolState, id: u64, serial: u64) {
    if let Some(set) = st.holders.get_mut(&id) {
        set.remove(&serial);
        if set.is_empty() {
            st.holders.remove(&id);
            // a fully-evicted id is forgotten entirely: if its content
            // recurs later it is a fresh first ship again (replication
            // re-arms) — the re-broadcast counter is reserved for copies
            // lost to worker DEATH, where `shipped_ever` must persist
            if st.evicted_pending.remove(&id) {
                st.shipped_ever.remove(&id);
            }
        }
    }
}

/// One waiter of `job` leaves [`acquire`]'s round-robin queue (grant or
/// panic). The job's slot in `rr` is surrendered and — when it still has
/// parked waiters — re-taken at the BACK, which is the rotation that makes
/// grants fair across jobs.
fn rr_depart(st: &mut PoolState, job: u64) {
    let remaining = {
        let count = st.waiting.entry(job).or_insert(1);
        *count = count.saturating_sub(1);
        *count
    };
    if let Some(pos) = st.rr.iter().position(|&j| j == job) {
        st.rr.remove(pos);
    }
    if remaining == 0 {
        st.waiting.remove(&job);
    } else {
        st.rr.push_back(job);
    }
}

struct PayloadEntry {
    /// Lazily dual-encoded broadcast content: JSON line and v6 binary
    /// frame are each built at most once, on first ship over a
    /// connection of that wire mode.
    payload: Arc<Payload>,
    /// Owners that have not yet evicted this payload; freed at zero.
    refs: u32,
    /// Jobs that have retained this payload via the job-aware path: each
    /// job holds at most ONE ref no matter how many times it re-requests
    /// the id, and `evict_broadcast_ids_for_job` releases only that job's
    /// ref — so two jobs sharing a problem share one cache entry and the
    /// first finisher's eviction cannot pull it out from under the other.
    /// Job-agnostic callers (`retain_broadcast_ids`) bypass this set and
    /// keep the raw refcount semantics.
    jobs: HashSet<u64>,
}

/// One dispatched task's lease: everything the maintenance scan needs to
/// spot a straggler, everything a speculative duplicate needs to re-run
/// it, and the cell a speculative win commits its result into. A lease
/// exists exactly while a primary attempt is in flight — it is removed
/// (under the leases lock) *before* the attempt requeues or releases its
/// worker, so a deadline/speculation kill can only ever land on a worker
/// still leased to the task: a kill can never double-requeue.
struct Lease {
    started: Instant,
    /// Job the leased task belongs to (0 for batch runs): a speculative
    /// re-run must attribute its traffic to the same job as the primary.
    job: u64,
    /// Task kind (`"cross_map"` / `"shard_chunk"`) keying the running
    /// median used by the speculation threshold.
    kind: &'static str,
    /// Local child pid when the holder is a forked worker we own — the
    /// SIGKILL target for deadline kills and speculative supersedes.
    /// `None` for remote workers (their pid is another machine's).
    holder_pid: Option<u32>,
    /// A speculative duplicate has been launched (at most one per lease).
    speculated: bool,
    /// The holder was deliberately killed (deadline breach or speculative
    /// supersede) — the primary's reply read translates this to a death
    /// instead of waiting forever on a wedged remote.
    killed: bool,
    /// A speculative win, committed here for the primary to collect.
    result: Option<Json>,
    /// The task's broadcast needs, cloned for the speculative re-run.
    needs: Vec<(u64, Arc<Payload>)>,
    /// The exact task line, re-sent verbatim by the speculative run (same
    /// task id, so either reply matches the exchange filter).
    task_line: Arc<String>,
}

/// The shared scheduler core: pool state, payload cache, and every
/// operation the scheduling threads *and* the background keepalive prober
/// need. [`ClusterBackend`] wraps it in an `Arc` so the prober can outlive
/// individual calls without borrowing the backend.
struct ClusterCore {
    source: WorkerSource,
    opts: ClusterOptions,
    state: Mutex<PoolState>,
    cv: Condvar,
    /// Refcounted serialized broadcast payloads by id, for (re-)shipping
    /// to any worker; entries are dropped by eviction.
    payloads: Mutex<HashMap<u64, PayloadEntry>>,
    /// Redial schedule for dead remote addresses (disabled at base 0).
    /// Lock order: `state` may be held while taking this, never the
    /// reverse.
    rejoin: Mutex<RejoinPolicy>,
    /// Live task leases by task id (empty unless a deadline or
    /// speculation knob is set). Lock order: `leases` is a leaf except
    /// for `durations`, which it may take; never hold `state` and take
    /// `leases`, or vice versa.
    leases: Mutex<HashMap<u64, Lease>>,
    /// Completed-task duration samples per task kind (bounded ring,
    /// [`DURATION_WINDOW`]) feeding the speculation median.
    durations: Mutex<HashMap<&'static str, VecDeque<f64>>>,
    /// Frames rejected by the v4 checksum layer on any driver-side
    /// connection (shared with every [`ChecksumTransport`] it wraps).
    corrupt_frames: Arc<AtomicU64>,
    /// Shared frame/connection counters for driver-side chaos injection.
    chaos_state: Arc<ChaosState>,
    /// Speculative duplicates actually dispatched to a worker.
    speculative_launches: AtomicU64,
    /// Speculative duplicates whose result superseded the primary's.
    speculative_wins: AtomicU64,
    /// Workers killed for breaching `--task-deadline-secs`.
    deadline_kills: AtomicU64,
    /// Tasks computed on the in-process native backend after exhausting
    /// their attempts (`--on-exhausted fallback`).
    exhausted_fallbacks: AtomicU64,
    /// Bytes of matched task-result frames received by the driver — the
    /// result-movement cost `--reduce worker` shrinks (the frame bytes of
    /// each accepted `result`, including its newline; stale/superseded
    /// replies are not counted).
    result_ingress_bytes: AtomicU64,
    /// Grid cells a driver stopped early under `--partial` (reported via
    /// [`ComputeBackend::record_partial`]).
    partial_stops: AtomicU64,
    /// Subsample tasks never dispatched because of those stops.
    partial_saved_tasks: AtomicU64,
    /// Per-job counter slices (see [`JobTally`]); entries are created on a
    /// job's first attributed event and live for the pool's lifetime (a
    /// daemon's `status`/`fetch` replies read them after the job ends).
    /// Lock order: strict leaf — only ever taken with no other lock held.
    job_tallies: Mutex<HashMap<u64, JobTally>>,
    next_task: AtomicU64,
    next_serial: AtomicU64,
    local: NativeBackend,
}

/// A [`ComputeBackend`] whose cross-map work executes in worker processes
/// reached over a pluggable [`Transport`] (see the module docs for the
/// wire protocol and the scheduling model). Workers come from a
/// [`WorkerSource`]: forked children (respawned on death) or pre-started
/// remote listeners (`--workers-at`; death shrinks the pool and eager
/// re-replication repairs the replication factor on survivors).
/// `cross_map_into` and `shard_chunk_into` cross the process boundary;
/// `simplex_tail_into` and `distance_matrix` are driver-side combine/build
/// steps and run locally on the native backend.
pub struct ClusterBackend {
    core: Arc<ClusterCore>,
    maint_stop: Arc<AtomicBool>,
    maint_thread: Option<std::thread::JoinHandle<()>>,
}

impl ClusterCore {
    /// Pool-state lock that survives a poisoning panic (an actionable
    /// abort in `acquire` must not turn `Drop` into a second panic).
    fn lock_state(&self) -> MutexGuard<'_, PoolState> {
        self.state.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn lock_payloads(&self) -> MutexGuard<'_, HashMap<u64, PayloadEntry>> {
        self.payloads.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn lock_rejoin(&self) -> MutexGuard<'_, RejoinPolicy> {
        self.rejoin.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn lock_leases(&self) -> MutexGuard<'_, HashMap<u64, Lease>> {
        self.leases.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn lock_durations(&self) -> MutexGuard<'_, HashMap<&'static str, VecDeque<f64>>> {
        self.durations.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn lock_job_tallies(&self) -> MutexGuard<'_, HashMap<u64, JobTally>> {
        self.job_tallies.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Snapshot of one job's counter slice (zero if the job never ran).
    fn job_tally(&self, job: u64) -> JobTally {
        self.lock_job_tallies().get(&job).copied().unwrap_or_default()
    }

    /// Snapshot of every job's counter slice, sorted by job id.
    fn job_tallies_snapshot(&self) -> Vec<(u64, JobTally)> {
        let mut all: Vec<(u64, JobTally)> =
            self.lock_job_tallies().iter().map(|(&j, &t)| (j, t)).collect();
        all.sort_unstable_by_key(|&(j, _)| j);
        all
    }

    /// Credit a driver's partial-evaluation tally to the pool counters and
    /// to `job`'s slice (the driver calls this once per run, after the
    /// grid sweep).
    fn record_partial_for(&self, job: u64, stops: u64, saved_tasks: u64) {
        if stops == 0 && saved_tasks == 0 {
            return;
        }
        self.partial_stops.fetch_add(stops, Ordering::Relaxed);
        self.partial_saved_tasks.fetch_add(saved_tasks, Ordering::Relaxed);
        let mut tallies = self.lock_job_tallies();
        let t = tallies.entry(job).or_default();
        t.partial_stops += stops;
        t.partial_saved_tasks += saved_tasks;
    }

    /// Whether task leases are tracked at all (either liveness knob set).
    /// With both off, dispatch takes no lease lock and no reply-read
    /// deadline — byte-for-byte the pre-v4 behavior.
    fn tracks_leases(&self) -> bool {
        self.opts.task_deadline.is_some() || self.opts.speculate_factor.is_some()
    }

    /// Whether every live worker speaks the v5 reduce ops (false for an
    /// empty pool). Checked per agg dispatch: a legacy worker joining
    /// mid-run (rejoin with a doctored hello) flips this off and the
    /// caller silently computes the bit-identical in-process default
    /// instead. If the race still lands an agg task on a legacy worker,
    /// its `unknown op` error rides the normal retry path and the
    /// exhaustion fallback keeps the answer correct.
    fn pool_speaks_agg(&self) -> bool {
        let st = self.lock_state();
        st.live > 0 && st.legacy_live == 0
    }

    /// Post-handshake transport layering for a fresh worker connection:
    /// chaos (when configured) under the v4 checksum layer, so injected
    /// corruption on either side is *detected* by the peer's verify. The
    /// handshake itself always rides the raw byte layer, and v≤3 workers
    /// keep their old byte streams exactly.
    fn wrap_transport(&self, raw: Box<dyn Transport>, wire_v: u64) -> Box<dyn Transport> {
        let mut t = raw;
        if let Some((seed, profile)) = &self.opts.chaos {
            t = Box::new(ChaosTransport::new(
                t,
                *seed,
                profile.clone(),
                Arc::clone(&self.chaos_state),
            ));
        }
        if wire_v >= CHECKSUM_WIRE_VERSION {
            t = Box::new(ChecksumTransport::new(t, Some(Arc::clone(&self.corrupt_frames))));
        }
        t
    }

    fn spawn(&self, slot: usize) -> std::io::Result<Worker> {
        let (mut link, hello) = self.source.connect(
            slot,
            self.opts.transport,
            &self.opts.worker_env,
            self.opts.auth_token.as_deref(),
        )?;
        link.transport = self.wrap_transport(link.transport, hello.version);
        Ok(Worker {
            serial: self.next_serial.fetch_add(1, Ordering::Relaxed),
            slot,
            rejoined: false,
            link,
            wire_v: hello.version,
            has: HashSet::new(),
            tasks_done: 0,
        })
    }

    /// Cache (and return) the payload for broadcast `id`, retaining it on
    /// behalf of `job`. A fresh entry starts with one reference owned by
    /// `job`; a job re-requesting an id it already holds is a no-op, and a
    /// *different* job requesting a cached id adds exactly one ref — the
    /// cross-tenant sharing path: the bytes are NOT re-encoded and (because
    /// broadcasts are content-addressed) never re-shipped to workers that
    /// hold them. The entry holds the broadcast's *content*
    /// ([`PayloadSrc`]); the JSON line and binary frame encodings are each
    /// materialized lazily on first use.
    fn payload(&self, job: u64, id: u64, build: impl FnOnce() -> PayloadSrc) -> Arc<Payload> {
        let mut map = self.lock_payloads();
        let entry = map.entry(id).or_insert_with(|| PayloadEntry {
            payload: Arc::new(Payload::new(build())),
            refs: 0,
            jobs: HashSet::new(),
        });
        if entry.jobs.insert(job) {
            entry.refs += 1;
        }
        Arc::clone(&entry.payload)
    }

    fn retain_broadcast_ids(&self, ids: &[u64]) {
        let mut map = self.lock_payloads();
        for id in ids {
            if let Some(e) = map.get_mut(id) {
                e.refs += 1;
            }
        }
    }

    fn evict_broadcast_ids(&self, ids: &[u64]) {
        let mut freed = Vec::new();
        {
            let mut map = self.lock_payloads();
            for id in ids {
                if let Some(e) = map.get_mut(id) {
                    e.refs = e.refs.saturating_sub(1);
                    if e.refs == 0 {
                        map.remove(id);
                        freed.push(*id);
                    }
                }
            }
        }
        self.push_evictions(freed);
    }

    /// Release `job`'s references on `ids`: each id loses at most the one
    /// ref `job` holds ([`ClusterCore::payload`]), so one tenant finishing
    /// cannot evict a broadcast another tenant still computes against.
    fn evict_broadcast_ids_for_job(&self, job: u64, ids: &[u64]) {
        let mut freed = Vec::new();
        {
            let mut map = self.lock_payloads();
            for id in ids {
                if let Some(e) = map.get_mut(id) {
                    if e.jobs.remove(&job) {
                        e.refs = e.refs.saturating_sub(1);
                        if e.refs == 0 {
                            map.remove(id);
                            freed.push(*id);
                        }
                    }
                }
            }
        }
        self.push_evictions(freed);
    }

    /// Deliver wire evictions for ids whose driver cache entry was just
    /// freed (shared tail of both evict paths).
    fn push_evictions(&self, freed: Vec<u64>) {
        if freed.is_empty() {
            return;
        }
        // mark the freed ids, then pull each idle v2+ holder out of the
        // pool and put it back through release(), which flushes pending
        // evictions OUTSIDE the pool lock — a slow worker must stall only
        // its own notification, never the scheduler. Leased holders and
        // v1 workers (no evict message exists for them; their copy stays
        // valid because ids are content-addressed) are handled the same
        // way on their own release, or forgotten when they die.
        let mut notify = Vec::new();
        {
            let mut st = self.lock_state();
            for &id in &freed {
                if st.holders.contains_key(&id) {
                    st.evicted_pending.insert(id);
                } else {
                    // already holderless (e.g. every copy died): forget it
                    st.shipped_ever.remove(&id);
                }
            }
            let mut i = 0;
            while i < st.idle.len() {
                let w = &st.idle[i];
                if w.wire_v >= EVICT_WIRE_VERSION && freed.iter().any(|id| w.has.contains(id)) {
                    notify.push(st.idle.swap_remove(i));
                } else {
                    i += 1;
                }
            }
        }
        for w in notify {
            self.release(w);
        }
    }

    /// Lease an idle worker for a task of `job` needing broadcast ids
    /// `needs`: least-loaded among workers already holding all of them
    /// (replica load balancing), else least-loaded overall (it will be
    /// shipped to); blocks while all workers are leased. Grants rotate
    /// round-robin across jobs with parked waiters ([`PoolState::rr`]):
    /// each idle worker goes to the front job, which then re-queues behind
    /// every other waiting job — so a small grid makes progress at 1/J of
    /// the pool against a huge co-tenant instead of starving. A single
    /// job (every batch run) always finds itself at the front, preserving
    /// the old behaviour exactly. Panics with an actionable message when
    /// the pool is empty and cannot regrow (remote sources).
    fn acquire(&self, job: u64, needs: &[u64]) -> Worker {
        let mut st = self.lock_state();
        if !st.waiting.contains_key(&job) {
            st.rr.push_back(job);
        }
        *st.waiting.entry(job).or_insert(0) += 1;
        loop {
            if !st.idle.is_empty() && st.rr.front() == Some(&job) {
                rr_depart(&mut st, job);
                let holder = st
                    .idle
                    .iter()
                    .enumerate()
                    .filter(|(_, w)| needs.iter().all(|id| w.has.contains(id)))
                    .min_by_key(|(_, w)| w.tasks_done)
                    .map(|(i, _)| i);
                let pos = holder.unwrap_or_else(|| {
                    // no replica idle: least-loaded worker, newest first
                    // on ties — after a mass kill the freshest respawn is
                    // the one most likely to still be alive
                    st.idle
                        .iter()
                        .enumerate()
                        .min_by_key(|(_, w)| (w.tasks_done, u64::MAX - w.serial))
                        .map(|(i, _)| i)
                        .unwrap()
                });
                let worker = st.idle.swap_remove(pos);
                // the rotation just promoted a NEW front job; its waiters
                // may have re-slept after seeing us at the front, so any
                // worker still idle needs a fresh wake to be claimed
                if !st.idle.is_empty() && !st.rr.is_empty() {
                    self.cv.notify_all();
                }
                return worker;
            }
            if st.live == 0 {
                if self.source.is_remote() {
                    // with rejoin armed and at least one dead address
                    // still on the redial schedule, the pool can regrow:
                    // wait for the maintenance thread instead of aborting
                    // (re-checked each timeout — every address could yet
                    // be retired by an auth rejection)
                    let rejoinable = {
                        let rj = self.lock_rejoin();
                        rj.enabled() && rj.pending() > 0
                    };
                    if rejoinable {
                        let (guard, _) = self
                            .cv
                            .wait_timeout(st, Duration::from_millis(50))
                            .unwrap_or_else(PoisonError::into_inner);
                        st = guard;
                        continue;
                    }
                    rr_depart(&mut st, job);
                    panic!(
                        "cluster backend has no live workers left: all {} remote workers \
                         from --workers-at are gone and remote workers cannot be \
                         respawned. Restart the listeners (see \
                         scripts/launch_local_cluster.sh) and re-run; --replicas 2 or \
                         more lets a run survive losing some of them, and \
                         --rejoin-backoff-secs N lets restarted listeners rejoin a \
                         live run",
                        self.opts.workers
                    );
                }
                rr_depart(&mut st, job);
                panic!(
                    "cluster backend has no live workers left: every forked worker died \
                     and none could be respawned"
                );
            }
            st = self.cv.wait(st).unwrap_or_else(PoisonError::into_inner);
        }
    }

    /// Return a worker to the idle pool, first delivering any evictions
    /// that became due while it was out. The evict writes happen with the
    /// pool lock RELEASED — only this worker is stalled by a slow link.
    fn release(&self, mut worker: Worker) {
        let pending: Vec<u64> = if worker.wire_v >= EVICT_WIRE_VERSION {
            let st = self.lock_state();
            if st.evicted_pending.is_empty() {
                Vec::new()
            } else {
                worker
                    .has
                    .iter()
                    .copied()
                    .filter(|id| st.evicted_pending.contains(id))
                    .collect()
            }
        } else {
            Vec::new()
        };
        for &id in &pending {
            let binary = worker.binary();
            if send_control(worker.link.transport.as_mut(), binary, &evict_payload(id)).is_err() {
                self.handle_death(worker, DeathCause::Exchange, "evict delivery failed");
                return;
            }
            worker.has.remove(&id);
        }
        let mut st = self.lock_state();
        for &id in &pending {
            st.evictions += 1;
            drop_holder(&mut st, id, worker.serial);
        }
        st.idle.push(worker);
        drop(st);
        self.cv.notify_all();
    }

    /// Reap a dead worker: respawn its replacement when the source owns
    /// worker lifecycles (fork), else permanently shrink the pool
    /// (remote). Either way, eagerly repair the replication factor of
    /// every broadcast the dead worker held (`replicas > 1`), so a second
    /// death in the repair window no longer forces a re-broadcast.
    fn handle_death(&self, mut dead: Worker, cause: DeathCause, why: &str) {
        if let Some(child) = dead.link.child.as_mut() {
            let _ = child.kill();
            let _ = child.wait();
        }
        // respawn into the SLOT the dead worker occupied (fork sources
        // ignore the slot today, but slot-keyed bookkeeping — rejoin
        // redial, lease kill targeting — must never see a recycled 0)
        let replacement =
            if self.source.can_respawn() { Some(self.spawn(dead.slot)) } else { None };
        let held: Vec<u64> = dead.has.iter().copied().collect();
        let mut remote_death = false;
        let mut repair: Vec<(u64, Arc<Payload>)> = Vec::new();
        {
            let mut st = self.lock_state();
            st.live -= 1;
            if dead.wire_v < AGG_WIRE_VERSION {
                st.legacy_live -= 1;
            }
            if matches!(cause, DeathCause::Keepalive) {
                st.keepalive_deaths += 1;
            }
            // every broadcast copy this worker held is gone with it
            for &id in &held {
                drop_holder(&mut st, id, dead.serial);
            }
            match replacement {
                Some(Ok(w)) => {
                    if w.wire_v < AGG_WIRE_VERSION {
                        st.legacy_live += 1;
                    }
                    note_connection(&mut st, &w);
                    st.idle.push(w);
                    st.live += 1;
                    st.respawns += 1;
                }
                Some(Err(e)) => {
                    // NOT counted in respawns: no replacement exists, the
                    // pool genuinely shrank
                    eprintln!("[cluster backend] failed to respawn worker: {e}");
                }
                None => {
                    st.remote_lost += 1;
                    remote_death = true;
                    let who = dead.link.addr.as_deref().unwrap_or("<unknown addr>");
                    eprintln!(
                        "[cluster backend] remote worker {who} (pid {}) is gone ({why}); \
                         remote workers cannot be respawned — {} of {} remain",
                        dead.link.pid, st.live, self.opts.workers
                    );
                }
            }
            // collect the repair work under the lock, ship outside it
            if self.opts.replicas > 1 {
                let payloads = self.lock_payloads();
                for id in held {
                    if st.evicted_pending.contains(&id) {
                        continue;
                    }
                    let holders = st.holders.get(&id).map_or(0, |s| s.len());
                    if holders < self.opts.replicas {
                        if let Some(e) = payloads.get(&id) {
                            repair.push((id, Arc::clone(&e.payload)));
                        }
                    }
                }
            }
        }
        // put the dead address on the redial schedule: a restarted
        // listener on the same host:port can rejoin the pool
        if remote_death {
            let mut rj = self.lock_rejoin();
            if rj.enabled() && !rj.is_rejected(dead.slot) {
                rj.note_death(dead.slot, Instant::now());
                eprintln!(
                    "[cluster backend] will redial {} on an exponential backoff \
                     (--rejoin-backoff-secs); restart the listener there to rejoin",
                    dead.link.addr.as_deref().unwrap_or("<unknown addr>")
                );
            }
        }
        self.cv.notify_all();
        for (id, payload) in repair {
            self.repair_ship(id, &payload);
        }
    }

    /// Redial every dead remote address whose backoff has elapsed,
    /// re-running the full v3 authenticated handshake on the
    /// [`connect_remote_deadline`] path (short deadline: a half-open peer
    /// stalls only its own probe). Success re-admits the worker with a
    /// fresh serial, an empty broadcast store, and the `rejoined` mark;
    /// a connection failure re-arms the exponential backoff; an auth
    /// rejection retires the address permanently — the named error is
    /// logged here and the worker end received a wire `reject`.
    fn attempt_due_rejoins(&self) {
        let due: Vec<usize> = {
            let rj = self.lock_rejoin();
            if !rj.enabled() {
                return;
            }
            rj.due_slots(Instant::now())
        };
        for slot in due {
            let Some(addr) = self.source.remote_addr(slot).map(str::to_string) else {
                continue;
            };
            {
                self.lock_state().rejoin_attempts += 1;
            }
            let auth = self.opts.auth_token.as_deref();
            match connect_remote_deadline(&addr, auth, REJOIN_CONNECT_TIMEOUT) {
                Ok((mut link, hello)) => {
                    link.transport = self.wrap_transport(link.transport, hello.version);
                    let worker = Worker {
                        serial: self.next_serial.fetch_add(1, Ordering::Relaxed),
                        slot,
                        rejoined: true,
                        link,
                        wire_v: hello.version,
                        has: HashSet::new(),
                        tasks_done: 0,
                    };
                    // clear the schedule BEFORE publishing the worker: once
                    // it is leasable, it can die again, and that death's
                    // note_death must not be erased by a late note_success
                    self.lock_rejoin().note_success(slot);
                    {
                        let mut st = self.lock_state();
                        st.live += 1;
                        if worker.wire_v < AGG_WIRE_VERSION {
                            st.legacy_live += 1;
                        }
                        note_connection(&mut st, &worker);
                        st.rejoins += 1;
                        st.idle.push(worker);
                    }
                    self.cv.notify_all();
                    eprintln!(
                        "[cluster backend] remote worker {addr} rejoined the pool (fresh \
                         worker id, empty broadcast store; payloads re-ship on demand)"
                    );
                }
                // permanent retirement is reserved for the HANDSHAKE's
                // auth verdict (finish_handshake: PermissionDenied + a
                // message naming the token) — a connect-phase EACCES
                // (firewall hiccup, ICMP admin-prohibited) also surfaces
                // as PermissionDenied and must back off instead
                Err(e)
                    if e.kind() == std::io::ErrorKind::PermissionDenied
                        && e.to_string().contains("auth token") =>
                {
                    self.lock_rejoin().note_rejected(slot);
                    {
                        self.lock_state().rejoin_rejected += 1;
                    }
                    // an acquire() waiting on an empty pool must re-check:
                    // this address will never come back
                    self.cv.notify_all();
                    eprintln!(
                        "[cluster backend] rejoin of {addr} permanently rejected ({e}); \
                         the address will not be redialed — fix its auth token and \
                         restart the driver"
                    );
                }
                Err(e) => {
                    self.lock_rejoin().note_failure(slot, Instant::now());
                    eprintln!(
                        "[cluster backend] rejoin redial of {addr} failed ({e}); \
                         backing off"
                    );
                }
            }
        }
    }

    /// Eager re-replication: top copies of `id` back up to the configured
    /// replication factor on idle workers that lack it. Best effort (a
    /// busy pool repairs less; the next task-driven ship finishes the
    /// job); counted apart from task-driven ships as `repair_ships` /
    /// `repair_ship_bytes`.
    fn repair_ship(&self, id: u64, payload: &Arc<Payload>) {
        loop {
            let target = {
                let mut st = self.lock_state();
                let holders = st.holders.get(&id).map_or(0, |s| s.len());
                if holders >= self.opts.replicas || st.evicted_pending.contains(&id) {
                    return;
                }
                // a harvested (evicted) broadcast must not be resurrected:
                // the payload being gone from the driver cache means no
                // evict could ever follow the repair copy
                if !self.lock_payloads().contains_key(&id) {
                    return;
                }
                match st.idle.iter().position(|w| !w.has.contains(&id)) {
                    Some(i) => {
                        let mut w = st.idle.swap_remove(i);
                        // claim holdership UNDER the lock: a concurrent
                        // evict then sees this copy, marks it pending, and
                        // release() below delivers the evict — the repair
                        // copy can never outlive its broadcast
                        w.has.insert(id);
                        st.holders.entry(id).or_default().insert(w.serial);
                        w
                    }
                    None => return, // no idle candidate: leave it task-driven
                }
            };
            let mut w = target;
            let binary = w.binary();
            if payload.send(w.link.transport.as_mut(), binary).is_err() {
                // handle_death drops the claimed holdership via w.has
                self.handle_death(w, DeathCause::Exchange, "repair ship failed");
                continue;
            }
            {
                let mut st = self.lock_state();
                st.repair_ships += 1;
                st.repair_ship_bytes += payload.wire_bytes(binary);
            }
            self.release(w);
        }
    }

    /// Probe one idle worker: ping, await the matching pong within
    /// `deadline`. `Ok(false)` = the transport cannot enforce deadlines
    /// (pipe) and the probe was skipped; `Err` = the worker is silently
    /// dead (or the link broke) and must be discarded.
    fn ping_worker(
        &self,
        worker: &mut Worker,
        nonce: u64,
        deadline: Duration,
    ) -> std::io::Result<bool> {
        if !worker.link.transport.set_recv_deadline(Some(deadline))? {
            return Ok(false);
        }
        let binary = worker.binary();
        send_control(worker.link.transport.as_mut(), binary, &ping_payload(nonce))?;
        loop {
            let reply = recv_msg(worker.link.transport.as_mut(), binary)?;
            if reply.get("type").and_then(Json::as_str) == Some("pong")
                && reply.get("nonce").and_then(Json::as_f64) == Some(nonce as f64)
            {
                worker.link.transport.set_recv_deadline(None)?;
                return Ok(true);
            }
        }
    }

    /// One request/response exchange on `worker`: ship missing broadcasts,
    /// send the task, read its reply.
    ///
    /// With a liveness knob set (`tracks_leases`), the reply read polls at
    /// [`LEASE_POLL`] instead of blocking forever, so a *primary* attempt
    /// notices its lease was superseded (speculative win) or
    /// deadline-killed even when the wedged worker is remote (no local
    /// pid to kill). A *speculative* attempt (`speculative = true`) polls
    /// only to bound how long it waits after the primary has already
    /// finished. Pipe transports cannot enforce read deadlines
    /// (`set_recv_deadline` = false) and keep the blocking read — forked
    /// pipe workers are unblocked by the pid kill instead.
    fn exchange(
        &self,
        job: u64,
        worker: &mut Worker,
        needs: &[(u64, Arc<Payload>)],
        task_id: u64,
        task_line: &str,
        speculative: bool,
    ) -> Result<Json, ExchangeError> {
        let binary = worker.binary();
        for (id, payload) in needs {
            if !worker.has.contains(id) {
                self.ship(job, worker, *id, payload).map_err(ExchangeError::Dead)?;
            }
        }
        // tasks are control-plane traffic: they ride a TAG_JSON envelope
        // frame on a binary connection, byte-identical JSON inside
        send_control(worker.link.transport.as_mut(), binary, task_line)
            .map_err(ExchangeError::Dead)?;
        let polling = self.tracks_leases()
            && worker
                .link
                .transport
                .set_recv_deadline(Some(LEASE_POLL))
                .map_err(ExchangeError::Dead)?;
        // bound a speculative loser's wait for a reply that may be very
        // slow: after the lease is gone (primary finished) allow a long
        // grace, then abandon the connection rather than leak the worker
        let mut orphan_polls: u32 = 0;
        let abandon_after = (Duration::from_secs(60).as_millis() / LEASE_POLL.as_millis()) as u32;
        loop {
            let (reply, reply_bytes) = match recv_msg_counted(worker.link.transport.as_mut(), binary)
            {
                Ok(r) => r,
                Err(e)
                    if polling
                        && matches!(
                            e.kind(),
                            std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
                        ) =>
                {
                    let leases = self.lock_leases();
                    match leases.get(&task_id) {
                        Some(l) if !speculative && l.result.is_some() => {
                            return Err(ExchangeError::Dead(std::io::Error::new(
                                std::io::ErrorKind::TimedOut,
                                "superseded by a speculative result",
                            )));
                        }
                        Some(l) if !speculative && l.killed => {
                            return Err(ExchangeError::Dead(std::io::Error::new(
                                std::io::ErrorKind::TimedOut,
                                "task deadline exceeded",
                            )));
                        }
                        None if speculative => {
                            orphan_polls += 1;
                            if orphan_polls > abandon_after {
                                return Err(ExchangeError::Dead(std::io::Error::new(
                                    std::io::ErrorKind::TimedOut,
                                    "speculative reply abandoned (primary finished long ago)",
                                )));
                            }
                            continue;
                        }
                        _ => continue, // still ours: keep waiting
                    }
                }
                Err(e) => return Err(ExchangeError::Dead(e)),
            };
            match reply.get("type").and_then(Json::as_str) {
                Some("result")
                    if reply.get("task").and_then(Json::as_f64) == Some(task_id as f64) =>
                {
                    if polling {
                        worker
                            .link
                            .transport
                            .set_recv_deadline(None)
                            .map_err(ExchangeError::Dead)?;
                    }
                    // only the accepted result frame is charged as ingress
                    // (stale pongs and late loser replies are noise, not
                    // result movement)
                    self.result_ingress_bytes.fetch_add(reply_bytes, Ordering::Relaxed);
                    self.lock_job_tallies().entry(job).or_default().result_ingress_bytes +=
                        reply_bytes;
                    return Ok(reply);
                }
                Some("error") => {
                    // a well-formed reply: the worker is ALIVE, the task
                    // (or our bookkeeping about the worker's store) is not
                    if polling {
                        let _ = worker.link.transport.set_recv_deadline(None);
                    }
                    return Err(ExchangeError::App(
                        reply
                            .get("msg")
                            .and_then(Json::as_str)
                            .unwrap_or("unspecified worker error")
                            .to_string(),
                    ));
                }
                _ => continue, // stale pongs / late loser replies: skip
            }
        }
    }

    /// Ship broadcast `id` to `worker` for `job`; on the id's first-ever
    /// ship, also top up replicas on other idle workers (their copies are
    /// attributed to the same job — it triggered them).
    fn ship(&self, job: u64, worker: &mut Worker, id: u64, payload: &Payload) -> std::io::Result<()> {
        let binary = worker.binary();
        payload.send(worker.link.transport.as_mut(), binary)?;
        worker.has.insert(id);
        let wire_bytes = payload.wire_bytes(binary);
        let first_ever = {
            let mut st = self.lock_state();
            if worker.rejoined {
                // lazy re-population of a rejoined worker's empty store —
                // the on-demand price of a rejoin, distinct from the
                // death-driven repair_ships
                st.rejoin_ships += 1;
                st.rejoin_ship_bytes += wire_bytes;
            }
            record_ship(&mut st, id, worker.serial, wire_bytes)
        };
        {
            let mut tallies = self.lock_job_tallies();
            let t = tallies.entry(job).or_default();
            t.broadcast_ships += 1;
            t.broadcast_ship_bytes += wire_bytes;
        }
        if first_ever && self.opts.replicas > 1 {
            self.replicate(job, id, payload, worker.serial);
        }
        Ok(())
    }

    /// Place up to `replicas - 1` additional copies of `id` on idle
    /// workers (best effort: a smaller pool or busy workers may satisfy
    /// fewer; later ships are task-driven). Targets are leased out of the
    /// pool under the lock but the (potentially large) payload writes
    /// happen OUTSIDE it, so a slow replica link never stalls dispatch.
    fn replicate(&self, job: u64, id: u64, payload: &Payload, exclude: u64) {
        let mut targets = Vec::new();
        {
            let mut st = self.lock_state();
            let holders = st.holders.get(&id).map_or(0, |s| s.len());
            let mut need = self.opts.replicas.saturating_sub(holders);
            let mut i = 0;
            while i < st.idle.len() && need > 0 {
                if st.idle[i].serial != exclude && !st.idle[i].has.contains(&id) {
                    targets.push(st.idle.swap_remove(i));
                    need -= 1;
                } else {
                    i += 1;
                }
            }
        }
        for mut w in targets {
            let binary = w.binary();
            if payload.send(w.link.transport.as_mut(), binary).is_err() {
                self.handle_death(w, DeathCause::Exchange, "replica ship failed");
                continue;
            }
            w.has.insert(id);
            let wire_bytes = payload.wire_bytes(binary);
            {
                let mut st = self.lock_state();
                record_ship(&mut st, id, w.serial, wire_bytes);
            }
            {
                let mut tallies = self.lock_job_tallies();
                let t = tallies.entry(job).or_default();
                t.broadcast_ships += 1;
                t.broadcast_ship_bytes += wire_bytes;
            }
            self.release(w);
        }
    }

    /// Register the lease for one dispatched attempt (no-op when no
    /// liveness knob is set — dispatch then takes no lease lock at all).
    fn lease_task(
        &self,
        job: u64,
        task_id: u64,
        kind: &'static str,
        worker: &Worker,
        needs: &[(u64, Arc<Payload>)],
        task_line: &Arc<String>,
    ) {
        if !self.tracks_leases() {
            return;
        }
        self.lock_leases().insert(
            task_id,
            Lease {
                started: Instant::now(),
                job,
                kind,
                holder_pid: worker.link.child.is_some().then_some(worker.link.pid),
                speculated: false,
                killed: false,
                result: None,
                needs: needs.to_vec(),
                task_line: Arc::clone(task_line),
            },
        );
    }

    /// Remove (and return) the task's lease. Called by the primary
    /// attempt *before* it requeues, releases, or reaps its worker — the
    /// invariant that makes deadline/speculation kills unable to
    /// double-requeue (they only ever act on a live lease).
    fn finish_lease(&self, task_id: u64) -> Option<Lease> {
        if !self.tracks_leases() {
            return None;
        }
        self.lock_leases().remove(&task_id)
    }

    /// Collect a speculative win if one has been committed for `task_id`
    /// (removing the lease).
    fn take_lease_result(&self, task_id: u64) -> Option<Json> {
        if !self.tracks_leases() {
            return None;
        }
        let mut leases = self.lock_leases();
        if leases.get(&task_id).is_some_and(|l| l.result.is_some()) {
            return leases.remove(&task_id).and_then(|l| l.result);
        }
        None
    }

    /// Feed one completed attempt's wall-clock into the running per-kind
    /// median (bounded ring).
    fn record_duration(&self, kind: &'static str, secs: f64) {
        if !self.tracks_leases() {
            return;
        }
        let mut durations = self.lock_durations();
        let ring = durations.entry(kind).or_default();
        if ring.len() >= DURATION_WINDOW {
            ring.pop_front();
        }
        ring.push_back(secs);
    }

    /// Running median task duration for `kind`, once enough samples exist
    /// to trust it.
    fn median_duration(&self, kind: &'static str) -> Option<f64> {
        let durations = self.lock_durations();
        let ring = durations.get(kind)?;
        if ring.len() < MEDIAN_MIN_SAMPLES {
            return None;
        }
        let mut sorted: Vec<f64> = ring.iter().copied().collect();
        sorted.sort_unstable_by(f64::total_cmp);
        Some(sorted[sorted.len() / 2])
    }

    /// The maintenance thread's lease scan: kill deadline breaches,
    /// launch (at most one) speculative duplicate per straggling lease.
    fn scan_leases(self: &Arc<Self>) {
        if !self.tracks_leases() {
            return;
        }
        let now = Instant::now();
        let mut speculate: Vec<u64> = Vec::new();
        {
            let mut leases = self.lock_leases();
            for (&task_id, lease) in leases.iter_mut() {
                if lease.killed || lease.result.is_some() {
                    continue;
                }
                let elapsed = now.duration_since(lease.started);
                if let Some(deadline) = self.opts.task_deadline {
                    if elapsed >= deadline {
                        // kill under the leases lock: the primary cannot
                        // have requeued (it removes the lease first), so
                        // the shot always lands on the leased worker
                        lease.killed = true;
                        if let Some(pid) = lease.holder_pid {
                            kill_pid(pid);
                        }
                        self.deadline_kills.fetch_add(1, Ordering::Relaxed);
                        eprintln!(
                            "[cluster backend] task {task_id} ({}) exceeded \
                             --task-deadline-secs after {:.1}s; killing its worker and \
                             requeueing",
                            lease.kind,
                            elapsed.as_secs_f64()
                        );
                        continue;
                    }
                }
                if let Some(factor) = self.opts.speculate_factor {
                    if !lease.speculated {
                        if let Some(median) = self.median_duration(lease.kind) {
                            // floor the threshold: micro-task medians must
                            // not arm speculation on scheduler jitter
                            let threshold = (median * factor).max(0.001);
                            if elapsed.as_secs_f64() >= threshold {
                                lease.speculated = true;
                                speculate.push(task_id);
                            }
                        }
                    }
                }
            }
        }
        for task_id in speculate {
            let core = Arc::clone(self);
            std::thread::spawn(move || core.speculate(task_id));
        }
    }

    /// Run one speculative duplicate of a straggling task on a different
    /// idle worker. First result wins: a committed win also shoots the
    /// straggler (under the leases lock, so the kill can only land while
    /// the primary still owns the lease); if the primary finished first,
    /// this duplicate's reply is discarded. Best effort throughout — no
    /// idle worker within [`SPECULATE_ACQUIRE_TIMEOUT`] (or a duplicate
    /// that itself dies) re-arms the lease for a later scan rather than
    /// stranding a wedged primary with its one spent chance.
    fn speculate(self: &Arc<Self>, task_id: u64) {
        let (job, needs, task_line, ids) = {
            let leases = self.lock_leases();
            let Some(lease) = leases.get(&task_id) else { return };
            let ids: Vec<u64> = lease.needs.iter().map(|(id, _)| *id).collect();
            (lease.job, lease.needs.clone(), Arc::clone(&lease.task_line), ids)
        };
        // the straggler itself is leased (not idle), so it can never be
        // picked as its own speculative stand-in
        let Some(mut worker) = self.try_acquire(&ids, SPECULATE_ACQUIRE_TIMEOUT) else {
            // no stand-in right now: re-arm the lease so a later scan can
            // retry — a wedged primary must not lose its only rescue to a
            // momentarily-busy pool
            if let Some(lease) = self.lock_leases().get_mut(&task_id) {
                lease.speculated = false;
            }
            return;
        };
        self.speculative_launches.fetch_add(1, Ordering::Relaxed);
        eprintln!(
            "[cluster backend] task {task_id} is straggling; launching a speculative \
             duplicate (first result wins)"
        );
        match self.exchange(job, &mut worker, &needs, task_id, &task_line, true) {
            Ok(reply) => {
                {
                    let mut leases = self.lock_leases();
                    match leases.get_mut(&task_id) {
                        Some(lease) if lease.result.is_none() && !lease.killed => {
                            lease.result = Some(reply);
                            lease.killed = true;
                            if let Some(pid) = lease.holder_pid {
                                kill_pid(pid);
                            }
                            self.speculative_wins.fetch_add(1, Ordering::Relaxed);
                            eprintln!(
                                "[cluster backend] speculative duplicate of task {task_id} \
                                 won; superseding the straggler"
                            );
                        }
                        // the primary finished (lease gone) or was already
                        // deadline-killed: this duplicate lost — discard
                        _ => {}
                    }
                }
                worker.tasks_done += 1;
                self.release(worker);
            }
            Err(ExchangeError::Dead(e)) => {
                self.handle_death(worker, DeathCause::Exchange, &e.to_string());
                // the duplicate died, not the primary: re-arm so a later
                // scan may try again on another worker (no-op if the
                // primary finished or was deadline-killed meanwhile)
                if let Some(lease) = self.lock_leases().get_mut(&task_id) {
                    lease.speculated = false;
                }
            }
            Err(ExchangeError::App(_)) => {
                // a live worker that cannot run the duplicate (store
                // drift): roll back its claims and repool it; the primary
                // still owns the task
                {
                    let mut st = self.lock_state();
                    for id in &ids {
                        if worker.has.remove(id) {
                            drop_holder(&mut st, *id, worker.serial);
                        }
                    }
                }
                self.release(worker);
            }
        }
    }

    /// Bounded-wait acquire for speculative launches: same replica
    /// preference as [`ClusterCore::acquire`], but gives up (returning
    /// `None`) after `timeout` or on a dead pool instead of blocking or
    /// panicking — a speculative duplicate is opportunistic by design.
    fn try_acquire(&self, needs: &[u64], timeout: Duration) -> Option<Worker> {
        let deadline = Instant::now() + timeout;
        let mut st = self.lock_state();
        loop {
            if !st.idle.is_empty() {
                let holder = st
                    .idle
                    .iter()
                    .enumerate()
                    .filter(|(_, w)| needs.iter().all(|id| w.has.contains(id)))
                    .min_by_key(|(_, w)| w.tasks_done)
                    .map(|(i, _)| i);
                let pos = holder.unwrap_or_else(|| {
                    st.idle
                        .iter()
                        .enumerate()
                        .min_by_key(|(_, w)| (w.tasks_done, u64::MAX - w.serial))
                        .map(|(i, _)| i)
                        .unwrap()
                });
                return Some(st.idle.swap_remove(pos));
            }
            let now = Instant::now();
            if st.live == 0 || now >= deadline {
                return None;
            }
            let (guard, _) = self
                .cv
                .wait_timeout(st, deadline - now)
                .unwrap_or_else(PoisonError::into_inner);
            st = guard;
        }
    }

    /// Run a task to completion, requeueing if the leased worker dies
    /// mid-exchange — onto a surviving replica (zero re-ship) when one
    /// holds the task's broadcasts, else with a counted re-broadcast. A
    /// worker that answers with a clean wire `error` is alive and stays
    /// pooled (crucial for remote workers, which cannot be respawned);
    /// only connection-level failures declare it dead. Attempts after the
    /// first are separated by a jittered exponential backoff (the
    /// [`RejoinPolicy`] curve at task scale), and exhausting
    /// [`MAX_TASK_ATTEMPTS`] returns a typed [`TaskExhausted`] for the
    /// caller's `--on-exhausted` policy instead of panicking here.
    ///
    /// Every completed task is tallied against `job` (batch runs pass 0);
    /// the traffic it generated was attributed as it happened (ships in
    /// [`ClusterCore::ship`]/[`ClusterCore::replicate`], ingress in
    /// [`ClusterCore::exchange`] — the speculative path included, via the
    /// job stored on the lease).
    fn execute(
        &self,
        job: u64,
        needs: &[(u64, Arc<Payload>)],
        kind: &'static str,
        build_task: impl Fn(u64) -> String,
    ) -> Result<Json, TaskExhausted> {
        let reply = self.execute_inner(job, needs, kind, build_task)?;
        self.lock_job_tallies().entry(job).or_default().tasks += 1;
        Ok(reply)
    }

    fn execute_inner(
        &self,
        job: u64,
        needs: &[(u64, Arc<Payload>)],
        kind: &'static str,
        build_task: impl Fn(u64) -> String,
    ) -> Result<Json, TaskExhausted> {
        let task_id = self.next_task.fetch_add(1, Ordering::Relaxed);
        let task_line = Arc::new(build_task(task_id));
        let ids: Vec<u64> = needs.iter().map(|(id, _)| *id).collect();
        let mut last_err = String::new();
        let mut jitter = Rng::new(task_id);
        for attempt in 0..MAX_TASK_ATTEMPTS {
            if attempt > 0 {
                // decorrelate requeue storms after a mass death: jittered
                // exponential backoff between attempts
                let delay = exp_backoff(RETRY_BACKOFF_BASE, attempt as u32, RETRY_BACKOFF_CAP);
                std::thread::sleep(delay.mul_f64(0.5 + jitter.f64()));
            }
            // a speculative duplicate may have finished while we backed off
            if let Some(reply) = self.take_lease_result(task_id) {
                return Ok(reply);
            }
            let mut worker = self.acquire(job, &ids);
            let started = Instant::now();
            self.lease_task(job, task_id, kind, &worker, needs, &task_line);
            match self.exchange(job, &mut worker, needs, task_id, &task_line, false) {
                Ok(reply) => {
                    let lease = self.finish_lease(task_id);
                    self.record_duration(kind, started.elapsed().as_secs_f64());
                    worker.tasks_done += 1;
                    // a speculative win may have shot this worker just as
                    // its own (bit-identical) reply was already in flight:
                    // the reply stands, the worker does not
                    if lease.as_ref().is_some_and(|l| l.killed) {
                        self.handle_death(worker, DeathCause::Exchange, "superseded mid-reply");
                    } else {
                        self.release(worker);
                    }
                    return Ok(reply);
                }
                Err(ExchangeError::Dead(e)) => {
                    last_err = e.to_string();
                    // remove the lease BEFORE reaping: once the task is
                    // requeueable, no deadline/speculation kill can target
                    // it (the no-double-requeue invariant)
                    let lease = self.finish_lease(task_id);
                    self.handle_death(worker, DeathCause::Exchange, &last_err);
                    if let Some(reply) = lease.and_then(|l| l.result) {
                        // superseded: the speculative duplicate already won
                        return Ok(reply);
                    }
                }
                Err(ExchangeError::App(msg)) => {
                    last_err = msg;
                    self.finish_lease(task_id);
                    // roll back this worker's claim to the task's
                    // broadcasts: if the error was store drift ("unknown
                    // broadcast"), the retry re-ships instead of trusting
                    // the stale bookkeeping (and instead of discarding a
                    // healthy worker)
                    {
                        let mut st = self.lock_state();
                        for id in &ids {
                            if worker.has.remove(id) {
                                drop_holder(&mut st, *id, worker.serial);
                            }
                        }
                    }
                    self.release(worker);
                }
            }
        }
        if let Some(reply) = self.take_lease_result(task_id) {
            return Ok(reply);
        }
        Err(TaskExhausted { task_id, attempts: MAX_TASK_ATTEMPTS, last_err })
    }

    /// Apply the `--on-exhausted` policy to a terminal task failure:
    /// abort (default) panics with an actionable message; fallback counts
    /// and logs, and the caller computes the task on the in-process
    /// native backend (bit-identical — workers run the same kernels).
    fn note_exhausted(&self, exhausted: &TaskExhausted) {
        match self.opts.on_exhausted {
            OnExhausted::Abort => panic!(
                "{exhausted}; pass --on-exhausted fallback to degrade to the in-process \
                 native backend instead of aborting"
            ),
            OnExhausted::Fallback => {
                self.exhausted_fallbacks.fetch_add(1, Ordering::Relaxed);
                eprintln!(
                    "[cluster backend] {exhausted}; --on-exhausted fallback: computing it \
                     on the in-process native backend (bit-identical results)"
                );
            }
        }
    }
}

/// SIGKILL a forked worker we own (deadline breach / speculative
/// supersede). Unix-only by the same libc precedent as `bind_reuseaddr`;
/// elsewhere the reply-read polling alone unblocks the primary.
#[cfg(unix)]
fn kill_pid(pid: u32) {
    extern "C" {
        fn kill(pid: i32, sig: i32) -> i32;
    }
    const SIGKILL: i32 = 9;
    unsafe {
        kill(pid as i32, SIGKILL);
    }
}

#[cfg(not(unix))]
fn kill_pid(_pid: u32) {}

impl Drop for ClusterCore {
    fn drop(&mut self) {
        let mut st = self.lock_state();
        for mut w in st.idle.drain(..) {
            let binary = w.binary();
            let _ = send_control(w.link.transport.as_mut(), binary, r#"{"type":"shutdown"}"#);
            if let Some(child) = w.link.child.as_mut() {
                let _ = child.wait();
            }
        }
    }
}

/// The background maintenance thread: keepalive probing, rejoin
/// redialing, and the per-task lease scan on one loop.
///
/// Keepalive (when `keepalive` is set): periodically pings every idle
/// keepalive-capable worker and discards any that stays silent past the
/// deadline — a silently-dead remote (network partition, frozen host) is
/// detected within ~2 intervals instead of on the next task.
///
/// Rejoin (when the core's [`RejoinPolicy`] is enabled): dead remote
/// addresses whose backoff has elapsed are redialed every tick; a
/// restarted listener is re-admitted to the pool.
///
/// Lease scan (when a deadline/speculation knob is set): every tick,
/// leased tasks are checked against `--task-deadline-secs` (breach =
/// kill + requeue) and `--speculate-factor` × the running median for
/// their kind (breach = speculative duplicate on another worker).
///
/// The concerns share the thread because all are periodic pool upkeep —
/// a redial may delay a probe round by up to its (short) connect
/// deadline, never block it; the lease scan itself launches speculative
/// work on detached threads and never blocks the loop.
fn maintenance_loop(core: Arc<ClusterCore>, stop: Arc<AtomicBool>, keepalive: Option<Duration>) {
    let mut tick = Duration::from_millis(25);
    if let Some(iv) = keepalive {
        tick = tick.min(iv);
    }
    let mut next_probe = keepalive.map(|iv| Instant::now() + iv);
    let mut nonce: u64 = 0;
    loop {
        if stop.load(Ordering::Relaxed) {
            return;
        }
        std::thread::sleep(tick);
        if stop.load(Ordering::Relaxed) {
            return;
        }
        core.attempt_due_rejoins();
        core.scan_leases();
        let Some(interval) = keepalive else { continue };
        if next_probe.is_some_and(|t| Instant::now() < t) {
            continue;
        }
        // probe idle capable workers ONE at a time (pull, ping, release
        // before pulling the next): a silently-dead worker stalls only
        // its own probe, never the rest of the pool behind it — tasks can
        // still acquire every other worker while a probe waits out its
        // deadline. `probed` stops a released worker from being re-pulled
        // within the same round.
        let mut probed: HashSet<u64> = HashSet::new();
        loop {
            let target = {
                let mut st = core.lock_state();
                let pos = st.idle.iter().position(|w| {
                    w.wire_v >= KEEPALIVE_WIRE_VERSION && !probed.contains(&w.serial)
                });
                match pos {
                    Some(i) => st.idle.swap_remove(i),
                    None => break,
                }
            };
            let mut w = target;
            probed.insert(w.serial);
            nonce += 1;
            match core.ping_worker(&mut w, nonce, interval) {
                Ok(_) => core.release(w),
                Err(e) => {
                    let why = format!("no pong within {interval:?}: {e}");
                    core.handle_death(w, DeathCause::Keepalive, &why);
                }
            }
            if stop.load(Ordering::Relaxed) {
                return;
            }
        }
        next_probe = Some(Instant::now() + interval);
    }
}

impl ClusterBackend {
    /// Pipe-transport pool of `workers` children of this executable
    /// (`<current_exe> worker`), no replication — PR 2 behavior.
    pub fn new(workers: usize) -> std::io::Result<ClusterBackend> {
        Self::with_command(std::env::current_exe()?, workers)
    }

    /// [`ClusterBackend::new`] with an explicit binary (tests pass
    /// `env!("CARGO_BIN_EXE_parccm")`).
    pub fn with_command(
        cmd: impl Into<PathBuf>,
        workers: usize,
    ) -> std::io::Result<ClusterBackend> {
        Self::with_options(cmd, ClusterOptions { workers, ..ClusterOptions::default() })
    }

    /// Fully-specified construction: source, transport, pool width,
    /// replication, keepalive. A non-empty `workers_at` connects to
    /// pre-started remote listeners (TCP by construction, pool width =
    /// address count) instead of forking children of `cmd`.
    pub fn with_options(
        cmd: impl Into<PathBuf>,
        opts: ClusterOptions,
    ) -> std::io::Result<ClusterBackend> {
        let mut opts = opts;
        // forked workers inherit the process environment, so they would
        // present PARCCM_AUTH_TOKEN even when the caller left auth_token
        // unset — resolve the same fallback on the driver side, or the
        // two halves of the handshake disagree with themselves
        opts.auth_token = resolve_auth_token(opts.auth_token.as_deref());
        let source = if opts.workers_at.is_empty() {
            WorkerSource::Fork { cmd: cmd.into() }
        } else {
            opts.transport = TransportKind::Tcp; // remote workers are sockets
            WorkerSource::Remote { addrs: std::mem::take(&mut opts.workers_at) }
        };
        // >= 1 by construction: Fork clamps to 1, Remote requires the
        // non-empty workers_at that selected it
        opts.workers = source.pool_size(opts.workers);
        opts.replicas = opts.replicas.clamp(1, opts.workers);
        let keepalive = match opts.keepalive {
            // pipes cannot enforce recv deadlines (set_recv_deadline is a
            // no-op there), so a prober would only churn the pool — the
            // CLI warns about the combination
            Some(d) if d > Duration::ZERO && opts.transport == TransportKind::Tcp => Some(d),
            Some(_) => None, // explicit zero (or pipe transport): off
            None if source.is_remote() => Some(DEFAULT_REMOTE_KEEPALIVE),
            None => None,
        };
        // rejoin redialing only exists for remote sources (forked workers
        // are respawned in place); zero/unset = off
        let rejoin_base = match opts.rejoin_backoff {
            Some(d) if !d.is_zero() && source.is_remote() => Some(d),
            _ => None,
        };
        let core = Arc::new(ClusterCore {
            source,
            opts,
            state: Mutex::new(PoolState::default()),
            cv: Condvar::new(),
            payloads: Mutex::new(HashMap::new()),
            rejoin: Mutex::new(RejoinPolicy::new(rejoin_base.unwrap_or(Duration::ZERO))),
            leases: Mutex::new(HashMap::new()),
            durations: Mutex::new(HashMap::new()),
            corrupt_frames: Arc::new(AtomicU64::new(0)),
            chaos_state: ChaosState::new(),
            speculative_launches: AtomicU64::new(0),
            speculative_wins: AtomicU64::new(0),
            deadline_kills: AtomicU64::new(0),
            exhausted_fallbacks: AtomicU64::new(0),
            result_ingress_bytes: AtomicU64::new(0),
            partial_stops: AtomicU64::new(0),
            partial_saved_tasks: AtomicU64::new(0),
            job_tallies: Mutex::new(HashMap::new()),
            next_task: AtomicU64::new(1),
            next_serial: AtomicU64::new(1),
            local: NativeBackend,
        });
        let mut idle = Vec::with_capacity(core.opts.workers);
        for slot in 0..core.opts.workers {
            idle.push(core.spawn(slot)?);
        }
        {
            let mut st = core.lock_state();
            st.live = idle.len();
            st.legacy_live = idle.iter().filter(|w| w.wire_v < AGG_WIRE_VERSION).count();
            for w in &idle {
                note_connection(&mut st, w);
            }
            st.idle = idle;
        }
        let maint_stop = Arc::new(AtomicBool::new(false));
        // the lease scan rides the same maintenance thread as keepalive
        // probing and rejoin redialing — any of the three warrants it
        let maint_thread =
            (keepalive.is_some() || rejoin_base.is_some() || core.tracks_leases()).then(|| {
                let core = Arc::clone(&core);
                let stop = Arc::clone(&maint_stop);
                std::thread::spawn(move || maintenance_loop(core, stop, keepalive))
            });
        Ok(ClusterBackend { core, maint_stop, maint_thread })
    }

    /// Transport the pool runs on.
    pub fn transport_kind(&self) -> TransportKind {
        self.core.opts.transport
    }

    /// Configured replication factor (post-clamp).
    pub fn replicas(&self) -> usize {
        self.core.opts.replicas
    }

    /// Whether the pool connects to pre-started remote workers
    /// (`--workers-at`) rather than forking children.
    pub fn is_remote(&self) -> bool {
        self.core.source.is_remote()
    }

    /// Live worker pids (for observability and kill-recovery tests; idle
    /// workers only, like PR 2).
    pub fn worker_pids(&self) -> Vec<u32> {
        self.core.lock_state().idle.iter().map(|w| w.link.pid).collect()
    }

    /// Workers currently alive (idle + leased).
    pub fn num_workers(&self) -> usize {
        self.core.lock_state().live
    }

    /// Serialized broadcast payloads currently cached driver-side.
    pub fn cached_payloads(&self) -> usize {
        self.core.lock_payloads().len()
    }

    /// Add an owner to already-cached payloads (callers sharing broadcast
    /// content across problems pair this with a later eviction).
    pub fn retain_broadcast_ids(&self, ids: &[u64]) {
        self.core.retain_broadcast_ids(ids);
    }

    /// Release one ownership reference on each id; payloads that reach
    /// zero references are dropped from the driver cache and evicted from
    /// every worker (v2+ workers get the wire `evict`; leased holders are
    /// notified when their task completes). Unknown ids are ignored, so
    /// callers may pass a problem's full candidate id set.
    pub fn evict_broadcast_ids(&self, ids: &[u64]) {
        self.core.evict_broadcast_ids(ids);
    }

    /// Snapshot of one job's counter slice (all-zero for an unknown job).
    pub fn job_tally(&self, job: u64) -> JobTally {
        self.core.job_tally(job)
    }

    /// Every job's counter slice, sorted by job id. Summed across jobs,
    /// `broadcast_ships`/`broadcast_ship_bytes` equal the pool's `ships`/
    /// `ship_bytes` and `result_ingress_bytes` equals the pool total.
    pub fn job_tallies(&self) -> Vec<(u64, JobTally)> {
        self.core.job_tallies_snapshot()
    }
}

/// A [`ComputeBackend`] view of a shared [`ClusterBackend`] whose every
/// task, ship, and result byte is attributed to one job id — the handle a
/// `parccm serve` job runner computes through. Cloning is cheap (one
/// `Arc`); any number of `JobBackend`s drive the same warm pool
/// concurrently, with [`acquire`](ClusterCore::acquire)'s round-robin
/// keeping worker grants fair across their job ids and the job-aware
/// payload cache refcounts keeping shared broadcasts alive until the last
/// tenant evicts. The plain `ComputeBackend` impl on `ClusterBackend`
/// itself is exactly `JobBackend` with job 0.
#[derive(Clone)]
pub struct JobBackend {
    backend: Arc<ClusterBackend>,
    job: u64,
}

impl JobBackend {
    /// Attribute work on `backend`'s pool to `job`. Job 0 is reserved for
    /// the batch path (the `ClusterBackend` trait impl), so serve-mode
    /// callers should hand out ids from 1.
    pub fn new(backend: Arc<ClusterBackend>, job: u64) -> Self {
        JobBackend { backend, job }
    }

    /// The job id this handle attributes to.
    pub fn job(&self) -> u64 {
        self.job
    }

    /// This job's counter slice so far.
    pub fn tally(&self) -> JobTally {
        self.backend.job_tally(self.job)
    }
}

impl ComputeBackend for JobBackend {
    fn cross_map_into(&self, input: &CrossMapInput, arena: &mut TaskArena) -> f32 {
        self.backend.cross_map_for(self.job, input, arena)
    }

    fn simplex_tail_into(
        &self,
        dvals: &[f32],
        tvals: &[f32],
        pred_targets: &[f32],
        e: usize,
        preds: &mut Vec<f32>,
    ) -> f32 {
        self.backend.core.local.simplex_tail_into(dvals, tvals, pred_targets, e, preds)
    }

    fn distance_matrix(&self, vecs: &[f32], n: usize) -> Vec<f32> {
        self.backend.core.local.distance_matrix(vecs, n)
    }

    #[allow(clippy::too_many_arguments)]
    fn shard_chunk_into(
        &self,
        shard: &TableShard,
        targets: &[f32],
        theiler: f32,
        lib_rows: &[usize],
        e: usize,
        arena: &mut TaskArena,
        preds: &mut Vec<f32>,
    ) {
        self.backend.shard_chunk_for(self.job, shard, targets, theiler, lib_rows, e, arena, preds)
    }

    fn agg_chunk_into(
        &self,
        shard: &TableShard,
        targets: &[f32],
        theiler: f32,
        lib_rows: &[usize],
        e: usize,
        arena: &mut TaskArena,
    ) -> PearsonSums {
        self.backend.agg_chunk_for(self.job, shard, targets, theiler, lib_rows, e, arena)
    }

    fn merge_sums(&self, partials: &[PearsonSums]) -> PearsonSums {
        self.backend.merge_sums_for(self.job, partials)
    }

    fn evict_broadcasts(&self, ids: &[u64]) {
        // release only THIS job's refs: a co-tenant still computing
        // against a shared broadcast keeps it cached and shipped
        self.backend.core.evict_broadcast_ids_for_job(self.job, ids);
    }

    fn record_partial(&self, stops: u64, saved_tasks: u64) {
        self.backend.core.record_partial_for(self.job, stops, saved_tasks);
    }

    fn run_counters(&self) -> PoolCounters {
        // pool-wide totals (the sidecar shape); the per-job slice is
        // available via [`JobBackend::tally`]
        self.backend.run_counters()
    }

    fn wire_pricing(&self) -> crate::engine::config::WirePricing {
        self.backend.wire_pricing()
    }

    fn name(&self) -> &'static str {
        self.backend.name()
    }
}

impl Drop for ClusterBackend {
    fn drop(&mut self) {
        // stop the maintenance thread before the core tears the pool
        // down, so no ping or rejoin redial races the shutdown messages
        self.maint_stop.store(true, Ordering::Relaxed);
        if let Some(handle) = self.maint_thread.take() {
            let _ = handle.join();
        }
    }
}

/// The job-attributed task paths: each ships/executes exactly as the
/// [`ComputeBackend`] methods below (which delegate here with job 0), but
/// tags every acquire, ship, and result byte with a job id so a
/// [`JobBackend`] tenant's traffic lands on its own [`JobTally`].
impl ClusterBackend {
    fn cross_map_for(&self, job: u64, input: &CrossMapInput, arena: &mut TaskArena) -> f32 {
        let id = problem_wire_id(input.vecs, input.targets, input.times);
        let payload = self.core.payload(job, id, || PayloadSrc::Problem {
            id,
            vecs: input.vecs.to_vec(),
            targets: input.targets.to_vec(),
            times: input.times.to_vec(),
        });
        let e = input.e;
        let theiler = input.theiler;
        let lib_rows = Json::usizes(input.lib_rows);
        let reply = self.core.execute(job, &[(id, payload)], "cross_map", |task| {
            Json::obj(vec![
                ("v", Json::Num(WIRE_VERSION as f64)),
                ("type", Json::Str("task".into())),
                ("task", Json::Num(task as f64)),
                ("op", Json::Str("cross_map".into())),
                ("problem", Json::Str(hex(id))),
                ("lib_rows", lib_rows.clone()),
                ("e", Json::Num(e as f64)),
                ("theiler", Json::Num(theiler as f64)),
            ])
            .to_string()
        });
        let reply = match reply {
            Ok(reply) => reply,
            Err(exhausted) => {
                self.core.note_exhausted(&exhausted);
                // workers run the same native kernels, so the local
                // fallback is bit-identical to a worker result
                return self.core.local.cross_map_into(input, arena);
            }
        };
        arena.preds = reply
            .get("preds")
            .and_then(Json::as_f32s)
            .expect("worker result missing preds");
        reply.get("rho").and_then(Json::as_f64).expect("worker result missing rho") as f32
    }

    #[allow(clippy::too_many_arguments)]
    fn shard_chunk_for(
        &self,
        job: u64,
        shard: &TableShard,
        targets: &[f32],
        theiler: f32,
        lib_rows: &[usize],
        e: usize,
        _arena: &mut TaskArena,
        preds: &mut Vec<f32>,
    ) {
        let sid = shard.wire_id();
        let tid = targets_wire_id(targets);
        let shard_line = self.core.payload(job, sid, || PayloadSrc::from_shard(sid, shard));
        let targets_line = self
            .core
            .payload(job, tid, || PayloadSrc::Targets { id: tid, targets: targets.to_vec() });
        let rows = Json::usizes(lib_rows);
        let reply = self
            .core
            .execute(job, &[(sid, shard_line), (tid, targets_line)], "shard_chunk", |task| {
                Json::obj(vec![
                    ("v", Json::Num(WIRE_VERSION as f64)),
                    ("type", Json::Str("task".into())),
                    ("task", Json::Num(task as f64)),
                    ("op", Json::Str("shard_chunk".into())),
                    ("shard", Json::Str(hex(sid))),
                    ("targets", Json::Str(hex(tid))),
                    ("lib_rows", rows.clone()),
                    ("e", Json::Num(e as f64)),
                    ("theiler", Json::Num(theiler as f64)),
                ])
                .to_string()
            });
        let reply = match reply {
            Ok(reply) => reply,
            Err(exhausted) => {
                self.core.note_exhausted(&exhausted);
                self.core
                    .local
                    .shard_chunk_into(shard, targets, theiler, lib_rows, e, _arena, preds);
                return;
            }
        };
        *preds = reply
            .get("preds")
            .and_then(Json::as_f32s)
            .expect("worker result missing preds");
    }

    /// Worker-side shuffle-stage reduce (wire v5): ship an `agg_chunk`
    /// task referencing the shard + targets broadcasts; only the ~48-byte
    /// partial sums come back. If any live worker negotiated below v5 (or
    /// the exchange exhausts its retries), the bit-identical in-process
    /// default computes the partial locally instead — same sums, larger
    /// local compute, zero wire traffic.
    fn agg_chunk_for(
        &self,
        job: u64,
        shard: &TableShard,
        targets: &[f32],
        theiler: f32,
        lib_rows: &[usize],
        e: usize,
        arena: &mut TaskArena,
    ) -> PearsonSums {
        if !self.core.pool_speaks_agg() {
            return self.core.local.agg_chunk_into(shard, targets, theiler, lib_rows, e, arena);
        }
        let sid = shard.wire_id();
        let tid = targets_wire_id(targets);
        let shard_line = self.core.payload(job, sid, || PayloadSrc::from_shard(sid, shard));
        let targets_line = self
            .core
            .payload(job, tid, || PayloadSrc::Targets { id: tid, targets: targets.to_vec() });
        let rows = Json::usizes(lib_rows);
        let reply = self
            .core
            .execute(job, &[(sid, shard_line), (tid, targets_line)], "agg_chunk", |task| {
                Json::obj(vec![
                    ("v", Json::Num(WIRE_VERSION as f64)),
                    ("type", Json::Str("task".into())),
                    ("task", Json::Num(task as f64)),
                    ("op", Json::Str("agg_chunk".into())),
                    ("shard", Json::Str(hex(sid))),
                    ("targets", Json::Str(hex(tid))),
                    ("lib_rows", rows.clone()),
                    ("e", Json::Num(e as f64)),
                    ("theiler", Json::Num(theiler as f64)),
                ])
                .to_string()
            });
        let reply = match reply {
            Ok(reply) => reply,
            Err(exhausted) => {
                self.core.note_exhausted(&exhausted);
                return self
                    .core
                    .local
                    .agg_chunk_into(shard, targets, theiler, lib_rows, e, arena);
            }
        };
        sums_from_json(reply.get("sums").expect("worker result missing sums"))
            .expect("worker result carried malformed sums")
    }

    /// Final merge on a worker (wire v5): ship the ordered partials as a
    /// `merge_sums` task (no broadcast needs — the payload IS the sums)
    /// and take the merged sums back. The merge is a pure function of the
    /// ordered slice, so the local fallback is bit-identical.
    fn merge_sums_for(&self, job: u64, partials: &[PearsonSums]) -> PearsonSums {
        if !self.core.pool_speaks_agg() {
            return self.core.local.merge_sums(partials);
        }
        let sums = Json::Arr(partials.iter().map(sums_to_json).collect());
        let reply = self.core.execute(job, &[], "merge_sums", |task| {
            Json::obj(vec![
                ("v", Json::Num(WIRE_VERSION as f64)),
                ("type", Json::Str("task".into())),
                ("task", Json::Num(task as f64)),
                ("op", Json::Str("merge_sums".into())),
                ("sums", sums.clone()),
            ])
            .to_string()
        });
        let reply = match reply {
            Ok(reply) => reply,
            Err(exhausted) => {
                self.core.note_exhausted(&exhausted);
                return self.core.local.merge_sums(partials);
            }
        };
        sums_from_json(reply.get("sums").expect("worker result missing sums"))
            .expect("worker result carried malformed sums")
    }
}

impl ComputeBackend for ClusterBackend {
    fn cross_map_into(&self, input: &CrossMapInput, arena: &mut TaskArena) -> f32 {
        self.cross_map_for(0, input, arena)
    }

    fn simplex_tail_into(
        &self,
        dvals: &[f32],
        tvals: &[f32],
        pred_targets: &[f32],
        e: usize,
        preds: &mut Vec<f32>,
    ) -> f32 {
        // driver-side combine step (cheap O(n*K)); panels never ship
        self.core.local.simplex_tail_into(dvals, tvals, pred_targets, e, preds)
    }

    fn distance_matrix(&self, vecs: &[f32], n: usize) -> Vec<f32> {
        // table construction happens driver-side; shards ship afterwards
        self.core.local.distance_matrix(vecs, n)
    }

    #[allow(clippy::too_many_arguments)]
    fn shard_chunk_into(
        &self,
        shard: &TableShard,
        targets: &[f32],
        theiler: f32,
        lib_rows: &[usize],
        e: usize,
        arena: &mut TaskArena,
        preds: &mut Vec<f32>,
    ) {
        self.shard_chunk_for(0, shard, targets, theiler, lib_rows, e, arena, preds)
    }

    fn agg_chunk_into(
        &self,
        shard: &TableShard,
        targets: &[f32],
        theiler: f32,
        lib_rows: &[usize],
        e: usize,
        arena: &mut TaskArena,
    ) -> PearsonSums {
        self.agg_chunk_for(0, shard, targets, theiler, lib_rows, e, arena)
    }

    fn merge_sums(&self, partials: &[PearsonSums]) -> PearsonSums {
        self.merge_sums_for(0, partials)
    }

    fn evict_broadcasts(&self, ids: &[u64]) {
        self.core.evict_broadcast_ids(ids);
    }

    fn record_partial(&self, stops: u64, saved_tasks: u64) {
        // batch path: job 0, like every other ComputeBackend method here
        self.core.record_partial_for(0, stops, saved_tasks);
    }

    fn run_counters(&self) -> PoolCounters {
        let st = self.core.lock_state();
        PoolCounters {
            live_workers: st.live as u64,
            respawns: st.respawns,
            remote_lost: st.remote_lost,
            keepalive_deaths: st.keepalive_deaths,
            broadcast_ships: st.ships,
            broadcast_ship_bytes: st.ship_bytes,
            rebroadcasts: st.rebroadcasts,
            repair_ships: st.repair_ships,
            repair_ship_bytes: st.repair_ship_bytes,
            evictions: st.evictions,
            rejoins: st.rejoins,
            rejoin_attempts: st.rejoin_attempts,
            rejoin_rejected: st.rejoin_rejected,
            rejoin_ships: st.rejoin_ships,
            rejoin_ship_bytes: st.rejoin_ship_bytes,
            binary_connections: st.binary_connections,
            json_connections: st.json_connections,
            speculative_launches: self.core.speculative_launches.load(Ordering::Relaxed),
            speculative_wins: self.core.speculative_wins.load(Ordering::Relaxed),
            deadline_kills: self.core.deadline_kills.load(Ordering::Relaxed),
            corrupt_frames_detected: self.core.corrupt_frames.load(Ordering::Relaxed),
            exhausted_fallbacks: self.core.exhausted_fallbacks.load(Ordering::Relaxed),
            result_ingress_bytes: self.core.result_ingress_bytes.load(Ordering::Relaxed),
            partial_stops: self.core.partial_stops.load(Ordering::Relaxed),
            partial_saved_tasks: self.core.partial_saved_tasks.load(Ordering::Relaxed),
        }
    }

    fn wire_pricing(&self) -> crate::engine::config::WirePricing {
        // conservative: one pinned-JSON connection in the pool means some
        // real traffic ships as decimal text, so the DES prices the whole
        // run at the JSON rate (connections are per-worker; the model has
        // no per-link granularity)
        if self.core.lock_state().json_connections > 0 {
            crate::engine::config::WirePricing::Json
        } else {
            crate::engine::config::WirePricing::Binary
        }
    }

    fn name(&self) -> &'static str {
        if self.core.source.is_remote() {
            return "cluster-remote";
        }
        match self.core.opts.transport {
            TransportKind::Pipe => "process",
            TransportKind::Tcp => "cluster-tcp",
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ccm::pipeline::CcmProblem;
    use crate::timeseries::generators::{coupled_logistic, CoupledLogisticParams};

    // In-process round-trip tests of the wire pieces; full multi-process
    // coverage lives in tests/integration_process.rs and
    // tests/integration_cluster.rs (they need the built `parccm` binary
    // via CARGO_BIN_EXE).

    #[test]
    fn content_ids_are_stable_and_sensitive() {
        let a = vec![1.0f32, 2.0, 3.0];
        let b = vec![1.0f32, 2.0, 3.0];
        let c = vec![1.0f32, 2.0, 3.5];
        assert_eq!(problem_wire_id(&a, &a, &a), problem_wire_id(&b, &b, &b));
        assert_ne!(problem_wire_id(&a, &a, &a), problem_wire_id(&a, &a, &c));
        // kind-tagged: the same bytes as problem vs targets never collide
        assert_ne!(problem_wire_id(&a, &[], &[]), targets_wire_id(&a));
    }

    #[test]
    fn broadcast_payloads_roundtrip_through_worker_store() {
        let (x, y) = coupled_logistic(120, CoupledLogisticParams::default());
        let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
        let pid = problem_wire_id(&problem.emb.vecs, &problem.targets, &problem.times);
        let line = problem_payload(pid, &problem.emb.vecs, &problem.targets, &problem.times);
        let mut store = HashMap::new();
        store_broadcast(&mut store, &Json::parse(&line).unwrap()).unwrap();
        match store.get(&hex(pid)) {
            Some(Stored::Problem { vecs, targets, times }) => {
                assert_eq!(vecs, &problem.emb.vecs);
                assert_eq!(targets, &problem.targets);
                assert_eq!(times, &problem.times);
            }
            _ => panic!("problem broadcast not stored"),
        }
    }

    #[test]
    fn shard_payload_roundtrips_with_identical_wire_id() {
        let (x, y) = coupled_logistic(120, CoupledLogisticParams::default());
        let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
        let table = crate::ccm::table::DistanceTable::build_truncated(&problem.emb, 16);
        let sharded = table.shard(3);
        let shard = &sharded.shards()[1];
        let line = shard_payload(shard.wire_id(), shard);
        let mut store = HashMap::new();
        store_broadcast(&mut store, &Json::parse(&line).unwrap()).unwrap();
        match store.get(&hex(shard.wire_id())) {
            Some(Stored::Shard(s)) => assert_eq!(s.wire_id(), shard.wire_id()),
            _ => panic!("shard broadcast not stored"),
        }
    }

    #[test]
    fn worker_task_runner_matches_local_backend() {
        // drive run_task directly (no subprocess): cross_map over the wire
        // model must equal the local native backend bit-for-bit
        let (x, y) = coupled_logistic(200, CoupledLogisticParams::default());
        let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
        let pid = problem_wire_id(&problem.emb.vecs, &problem.targets, &problem.times);
        let mut store = HashMap::new();
        let line = problem_payload(pid, &problem.emb.vecs, &problem.targets, &problem.times);
        store_broadcast(&mut store, &Json::parse(&line).unwrap()).unwrap();
        let lib_rows: Vec<usize> = (0..problem.emb.n).step_by(3).collect();
        let task = Json::obj(vec![
            ("v", Json::Num(WIRE_VERSION as f64)),
            ("type", Json::Str("task".into())),
            ("task", Json::Num(9.0)),
            ("op", Json::Str("cross_map".into())),
            ("problem", Json::Str(hex(pid))),
            ("lib_rows", Json::usizes(&lib_rows)),
            ("e", Json::Num(2.0)),
            ("theiler", Json::Num(0.0)),
        ]);
        // simulate the reply crossing the wire as text
        let mut arena = TaskArena::new();
        let reply = run_task(&store, &mut arena, &task).unwrap();
        let reply = Json::parse(&reply.to_string()).unwrap();

        let sample = crate::ccm::subsample::LibrarySample {
            sample_id: 0,
            params: crate::ccm::params::CcmParams::new(2, 1, lib_rows.len()),
            rows: lib_rows,
        };
        let want = NativeBackend.cross_map(&problem.input_for(&sample));
        assert_eq!(reply.get("rho").and_then(Json::as_f64).unwrap() as f32, want.rho);
        assert_eq!(reply.get("preds").and_then(Json::as_f32s).unwrap(), want.preds);
    }

    #[test]
    fn worker_agg_chunk_matches_local_sums_bit_for_bit() {
        // drive the v5 agg_chunk op through run_task and the wire text:
        // the partial sums must equal the in-process default bit-for-bit
        let (x, y) = coupled_logistic(200, CoupledLogisticParams::default());
        let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
        let table = crate::ccm::table::DistanceTable::build_truncated(&problem.emb, 16);
        let sharded = table.shard(3);
        let shard = &sharded.shards()[1];
        let tid = targets_wire_id(&problem.targets);
        let mut store = HashMap::new();
        let shard_line = shard_payload(shard.wire_id(), shard);
        let targets_line = targets_payload(tid, &problem.targets);
        store_broadcast(&mut store, &Json::parse(&shard_line).unwrap()).unwrap();
        store_broadcast(&mut store, &Json::parse(&targets_line).unwrap()).unwrap();
        let lib_rows: Vec<usize> = (0..problem.emb.n).step_by(3).collect();
        let task = Json::obj(vec![
            ("v", Json::Num(WIRE_VERSION as f64)),
            ("type", Json::Str("task".into())),
            ("task", Json::Num(11.0)),
            ("op", Json::Str("agg_chunk".into())),
            ("shard", Json::Str(hex(shard.wire_id()))),
            ("targets", Json::Str(hex(tid))),
            ("lib_rows", Json::usizes(&lib_rows)),
            ("e", Json::Num(2.0)),
            ("theiler", Json::Num(0.0)),
        ]);
        let mut arena = TaskArena::new();
        let reply = run_task(&store, &mut arena, &task).unwrap();
        // simulate the reply crossing the wire as text
        let reply = Json::parse(&reply.to_string()).unwrap();
        let got = sums_from_json(reply.get("sums").unwrap()).unwrap();

        let want = NativeBackend.agg_chunk_into(
            shard,
            &problem.targets,
            0.0,
            &lib_rows,
            2,
            &mut TaskArena::new(),
        );
        assert_eq!(got, want, "wire sums must be bit-identical to in-process sums");
        assert_eq!(got.n as usize, shard.num_rows());
    }

    #[test]
    fn worker_merge_sums_matches_local_merge_bit_for_bit() {
        let parts = vec![
            PearsonSums { n: 3, sx: 1.5, sy: -2.25, sxy: 0.125, sxx: 9.0, syy: 4.5 },
            PearsonSums { n: 5, sx: 0.1, sy: 0.2, sxy: 0.3, sxx: 0.4, syy: 0.5 },
            PearsonSums { n: 2, sx: -7.0, sy: 3.5, sxy: 1.0e-9, sxx: 2.0, syy: 1.0 },
        ];
        let task = Json::obj(vec![
            ("v", Json::Num(WIRE_VERSION as f64)),
            ("type", Json::Str("task".into())),
            ("task", Json::Num(12.0)),
            ("op", Json::Str("merge_sums".into())),
            ("sums", Json::Arr(parts.iter().map(sums_to_json).collect())),
        ]);
        let store = HashMap::new();
        let mut arena = TaskArena::new();
        let reply = run_task(&store, &mut arena, &task).unwrap();
        let reply = Json::parse(&reply.to_string()).unwrap();
        let got = sums_from_json(reply.get("sums").unwrap()).unwrap();
        assert_eq!(got, PearsonSums::merge_all(&parts));
        assert_eq!(got.n, 10);
    }

    #[test]
    fn sums_wire_encoding_roundtrips_bit_for_bit() {
        // adversarial f64s: subnormal-ish, negative, high-precision
        let s = PearsonSums {
            n: u64::from(u32::MAX),
            sx: 0.1 + 0.2,
            sy: -1.0e-300,
            sxy: std::f64::consts::PI,
            sxx: 4.9e-324_f64,
            syy: 1.0e300,
        };
        let line = sums_to_json(&s).to_string();
        let back = sums_from_json(&Json::parse(&line).unwrap()).unwrap();
        assert_eq!(back, s, "sums must survive the wire bit-for-bit");
        // malformed arrays are named errors, not panics
        assert!(sums_from_json(&Json::parse("[1,2]").unwrap()).is_err());
        assert!(sums_from_json(&Json::parse("\"nope\"").unwrap()).is_err());
    }

    #[test]
    fn merge_sums_task_rejects_malformed_partials() {
        let task = Json::obj(vec![
            ("type", Json::Str("task".into())),
            ("task", Json::Num(1.0)),
            ("op", Json::Str("merge_sums".into())),
            ("sums", Json::parse("[[1,2,3]]").unwrap()),
        ]);
        let store = HashMap::new();
        let mut arena = TaskArena::new();
        let err = run_task(&store, &mut arena, &task).unwrap_err();
        assert!(err.contains("6 elements"), "{err}");
    }

    #[test]
    fn unknown_broadcast_yields_error() {
        let store = HashMap::new();
        let mut arena = TaskArena::new();
        let task = Json::obj(vec![
            ("type", Json::Str("task".into())),
            ("task", Json::Num(1.0)),
            ("op", Json::Str("cross_map".into())),
            ("problem", Json::Str("feedbeef00000000".into())),
            ("lib_rows", Json::usizes(&[1, 2, 3])),
            ("e", Json::Num(2.0)),
            ("theiler", Json::Num(0.0)),
        ]);
        let err = run_task(&store, &mut arena, &task).unwrap_err();
        assert!(err.contains("unknown broadcast"), "{err}");
    }

    #[test]
    fn evict_message_drops_stored_broadcast() {
        // store a targets broadcast, run an evict line against the same
        // store shape the worker loop uses, and confirm the task now fails
        let tid = targets_wire_id(&[1.0, 2.0]);
        let line = targets_payload(tid, &[1.0, 2.0]);
        let mut store = HashMap::new();
        store_broadcast(&mut store, &Json::parse(&line).unwrap()).unwrap();
        assert!(store.contains_key(&hex(tid)));
        let evict = Json::parse(&evict_payload(tid)).unwrap();
        let id = evict.get("id").and_then(Json::as_str).unwrap();
        store.remove(id);
        assert!(store.is_empty(), "evict must free the worker-side copy");
    }

    #[test]
    fn ship_accounting_counts_replicas_and_rebroadcasts() {
        let mut st = PoolState::default();
        // first ship of id 7 to worker 1: first_ever, no rebroadcast
        // (100 = the caller-computed on-wire size, encoding included)
        assert!(record_ship(&mut st, 7, 1, 100));
        // replica copy to worker 2: not first_ever, holders non-empty
        assert!(!record_ship(&mut st, 7, 2, 100));
        assert_eq!(st.ships, 2);
        assert_eq!(st.ship_bytes, 200);
        assert_eq!(st.rebroadcasts, 0);
        // both replicas die
        drop_holder(&mut st, 7, 1);
        drop_holder(&mut st, 7, 2);
        assert!(!st.holders.contains_key(&7));
        // next ship is the re-broadcast fallback
        assert!(!record_ship(&mut st, 7, 3, 99));
        assert_eq!(st.rebroadcasts, 1);
    }

    #[test]
    fn evicted_ids_reship_as_fresh_not_rebroadcast() {
        let mut st = PoolState::default();
        assert!(record_ship(&mut st, 7, 1, 10));
        // driver evicts the id; the last holder drops it
        st.evicted_pending.insert(7);
        drop_holder(&mut st, 7, 1);
        assert!(!st.shipped_ever.contains(&7), "eviction must forget the id entirely");
        // the same content recurring later is a FIRST ship again:
        // replication re-arms and the re-broadcast counter (reserved for
        // copies lost to worker death) stays untouched
        assert!(record_ship(&mut st, 7, 2, 10));
        assert_eq!(st.rebroadcasts, 0);
    }

    #[test]
    fn payload_cache_refcounts() {
        // exercise the refcount logic without spawning workers: build the
        // backend pieces by hand (no pool needed for this path)
        let mut map: HashMap<u64, PayloadEntry> = HashMap::new();
        let src = PayloadSrc::Targets { id: 5, targets: vec![1.0, 2.0] };
        map.insert(
            5,
            PayloadEntry {
                payload: Arc::new(Payload::new(src)),
                refs: 1,
                jobs: HashSet::from([0]),
            },
        );
        // retain then double-evict: survives the first, freed by the second
        map.get_mut(&5).unwrap().refs += 1;
        for _ in 0..2 {
            let e = map.get_mut(&5).unwrap();
            e.refs -= 1;
            if e.refs == 0 {
                map.remove(&5);
            }
        }
        assert!(map.is_empty());
    }

    #[test]
    fn payload_cache_is_shared_and_refcounted_per_job() {
        // two tenants requesting the same content-addressed id share ONE
        // cache entry; re-requests by the same job add nothing, and each
        // job's eviction releases only its own ref
        let core = bare_core(ClusterOptions::default());
        let build = || PayloadSrc::Targets { id: 9, targets: vec![1.0, 2.0, 3.0] };
        let a = core.payload(1, 9, build);
        let again = core.payload(1, 9, build);
        assert!(Arc::ptr_eq(&a, &again), "same entry, not a re-encode");
        let b = core.payload(2, 9, build);
        assert!(Arc::ptr_eq(&a, &b), "tenants share the driver cache entry");
        {
            let map = core.lock_payloads();
            let e = map.get(&9).unwrap();
            assert_eq!(e.refs, 2, "one ref per job, idempotent per job");
            assert_eq!(e.jobs.len(), 2);
        }
        // job 1 finishes: the entry survives for job 2 — and a repeat
        // eviction by job 1 is a no-op, not a double-free
        core.evict_broadcast_ids_for_job(1, &[9]);
        core.evict_broadcast_ids_for_job(1, &[9]);
        assert!(core.lock_payloads().contains_key(&9), "co-tenant keeps it alive");
        core.evict_broadcast_ids_for_job(2, &[9]);
        assert!(core.lock_payloads().is_empty(), "last tenant out frees the entry");
    }

    #[test]
    fn job_tallies_accumulate_and_snapshot_sorted() {
        let core = bare_core(ClusterOptions::default());
        {
            let mut t = core.lock_job_tallies();
            t.entry(2).or_default().tasks = 5;
            let one = t.entry(1).or_default();
            one.tasks = 3;
            one.broadcast_ships = 2;
            one.broadcast_ship_bytes = 128;
            one.result_ingress_bytes = 64;
        }
        assert_eq!(core.job_tally(1).tasks, 3);
        assert_eq!(core.job_tally(7), JobTally::default(), "unknown job reads zero");
        let snap = core.job_tallies_snapshot();
        assert_eq!(snap.iter().map(|&(j, _)| j).collect::<Vec<_>>(), vec![1, 2]);
        // a driver's partial tally lands on the pool atomics AND the job's
        // slice; the all-zero call is a no-op that creates no entry
        core.record_partial_for(1, 2, 40);
        core.record_partial_for(9, 0, 0);
        assert_eq!(core.partial_stops.load(Ordering::Relaxed), 2);
        assert_eq!(core.partial_saved_tasks.load(Ordering::Relaxed), 40);
        assert_eq!(core.job_tally(9), JobTally::default(), "zero tally creates nothing");
        let pairs = core.job_tally(1).to_pairs();
        assert_eq!(
            pairs,
            vec![
                ("tasks", 3),
                ("broadcast_ships", 2),
                ("broadcast_ship_bytes", 128),
                ("result_ingress_bytes", 64),
                ("partial_stops", 2),
                ("partial_saved_tasks", 40),
            ]
        );
    }

    #[test]
    fn rr_queue_rotates_grants_across_jobs() {
        // pure PoolState bookkeeping: two jobs with parked waiters take
        // turns at the front; a departing job with more waiters re-queues
        // at the BACK, and a fully-departed job leaves the queue
        let mut st = PoolState::default();
        // job 10 parks two waiters, job 20 parks one (acquire's preamble)
        for job in [10, 10, 20] {
            if !st.waiting.contains_key(&job) {
                st.rr.push_back(job);
            }
            *st.waiting.entry(job).or_insert(0) += 1;
        }
        assert_eq!(st.rr.front(), Some(&10));
        rr_depart(&mut st, 10); // first grant: job 10 still has a waiter
        assert_eq!(st.rr.front(), Some(&20), "job 20 is next despite arriving later");
        assert_eq!(st.rr.back(), Some(&10), "job 10 re-queued behind it");
        rr_depart(&mut st, 20); // job 20's only waiter departs
        assert!(!st.waiting.contains_key(&20));
        assert_eq!(st.rr.iter().copied().collect::<Vec<_>>(), vec![10]);
        rr_depart(&mut st, 10);
        assert!(st.rr.is_empty() && st.waiting.is_empty());
    }

    #[test]
    fn payload_line_is_byte_identical_to_the_legacy_builders() {
        // the pinned-JSON (v<=5) fallback promises the exact bytes a
        // pre-v6 driver would have sent; Payload::line() must keep that
        let (x, y) = coupled_logistic(160, CoupledLogisticParams::default());
        let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
        let pid = problem_wire_id(&problem.emb.vecs, &problem.targets, &problem.times);
        let p = Payload::new(PayloadSrc::Problem {
            id: pid,
            vecs: problem.emb.vecs.clone(),
            targets: problem.targets.clone(),
            times: problem.times.clone(),
        });
        assert_eq!(
            p.line().as_str(),
            problem_payload(pid, &problem.emb.vecs, &problem.targets, &problem.times)
        );
        assert_eq!(p.wire_bytes(false), p.line().len() as u64 + 1);
        assert_eq!(p.wire_bytes(true), p.bin().len() as u64 + 4);

        let tid = targets_wire_id(&problem.targets);
        let t = Payload::new(PayloadSrc::Targets { id: tid, targets: problem.targets.clone() });
        assert_eq!(t.line().as_str(), targets_payload(tid, &problem.targets));

        let table = crate::ccm::table::DistanceTable::build_truncated(&problem.emb, 16);
        let sharded = table.shard(2);
        let shard = &sharded.shards()[0];
        let s = Payload::new(PayloadSrc::from_shard(shard.wire_id(), shard));
        assert_eq!(s.line().as_str(), shard_payload(shard.wire_id(), shard));
    }

    #[test]
    fn payload_bin_lands_the_same_content_in_a_worker_store() {
        let (x, y) = coupled_logistic(160, CoupledLogisticParams::default());
        let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
        let pid = problem_wire_id(&problem.emb.vecs, &problem.targets, &problem.times);
        let p = Payload::new(PayloadSrc::Problem {
            id: pid,
            vecs: problem.emb.vecs.clone(),
            targets: problem.targets.clone(),
            times: problem.times.clone(),
        });
        let mut store = HashMap::new();
        match binwire::decode(p.bin()).unwrap() {
            binwire::BinMsg::Broadcast(b) => store_bin_broadcast(&mut store, b),
            _ => panic!("problem payload must decode as a broadcast frame"),
        }
        match store.get(&hex(pid)) {
            Some(Stored::Problem { vecs, targets, times }) => {
                assert_eq!(vecs, &problem.emb.vecs);
                assert_eq!(targets, &problem.targets);
                assert_eq!(times, &problem.times);
            }
            _ => panic!("binary problem broadcast not stored"),
        }
        // and the shard form, including its neighbor bit-packing
        let table = crate::ccm::table::DistanceTable::build_truncated(&problem.emb, 16);
        let sharded = table.shard(2);
        let shard = &sharded.shards()[1];
        let s = Payload::new(PayloadSrc::from_shard(shard.wire_id(), shard));
        let mut store = HashMap::new();
        match binwire::decode(s.bin()).unwrap() {
            binwire::BinMsg::Broadcast(b) => store_bin_broadcast(&mut store, b),
            _ => panic!("shard payload must decode as a broadcast frame"),
        }
        match store.get(&hex(shard.wire_id())) {
            Some(Stored::Shard(got)) => {
                assert_eq!(got.wire_id(), shard.wire_id());
                assert_eq!(got.raw_parts().0, shard.raw_parts().0);
                assert_eq!(got.raw_parts().1, shard.raw_parts().1);
            }
            _ => panic!("binary shard broadcast not stored"),
        }
    }

    #[test]
    fn note_connection_tallies_by_negotiated_wire_version() {
        let mut st = PoolState::default();
        let count = |st: &mut PoolState, wire_v: u64| {
            // only wire_v matters to the tally; fabricate the rest
            let listener = std::net::TcpListener::bind(("127.0.0.1", 0)).unwrap();
            let addr = listener.local_addr().unwrap();
            let client = std::thread::spawn(move || std::net::TcpStream::connect(addr).unwrap());
            let (stream, _) = listener.accept().unwrap();
            let _keep = client.join().unwrap();
            let w = Worker {
                serial: 1,
                slot: 0,
                rejoined: false,
                link: WorkerLink {
                    child: None,
                    transport: Box::new(
                        crate::ccm::transport::TcpTransport::from_stream(stream).unwrap(),
                    ),
                    pid: 0,
                    addr: None,
                },
                wire_v,
                has: HashSet::new(),
                tasks_done: 0,
            };
            note_connection(st, &w);
        };
        count(&mut st, WIRE_VERSION);
        count(&mut st, BINARY_WIRE_VERSION);
        count(&mut st, AGG_WIRE_VERSION); // v5: pinned to JSON
        count(&mut st, 1);
        assert_eq!(st.binary_connections, 2);
        assert_eq!(st.json_connections, 2);
    }

    /// A core with no workers and no threads: enough for the pure lease /
    /// median / policy bookkeeping, which never touches the pool.
    fn bare_core(opts: ClusterOptions) -> ClusterCore {
        ClusterCore {
            source: WorkerSource::Fork { cmd: PathBuf::from("unused") },
            opts,
            state: Mutex::new(PoolState::default()),
            cv: Condvar::new(),
            payloads: Mutex::new(HashMap::new()),
            rejoin: Mutex::new(RejoinPolicy::new(Duration::ZERO)),
            leases: Mutex::new(HashMap::new()),
            durations: Mutex::new(HashMap::new()),
            corrupt_frames: Arc::new(AtomicU64::new(0)),
            chaos_state: ChaosState::new(),
            speculative_launches: AtomicU64::new(0),
            speculative_wins: AtomicU64::new(0),
            deadline_kills: AtomicU64::new(0),
            exhausted_fallbacks: AtomicU64::new(0),
            result_ingress_bytes: AtomicU64::new(0),
            partial_stops: AtomicU64::new(0),
            partial_saved_tasks: AtomicU64::new(0),
            job_tallies: Mutex::new(HashMap::new()),
            next_task: AtomicU64::new(1),
            next_serial: AtomicU64::new(1),
            local: NativeBackend,
        }
    }

    fn bare_lease(kind: &'static str) -> Lease {
        Lease {
            started: Instant::now(),
            job: 0,
            kind,
            holder_pid: None,
            speculated: false,
            killed: false,
            result: None,
            needs: Vec::new(),
            task_line: Arc::new(String::new()),
        }
    }

    #[test]
    fn on_exhausted_parses_the_two_policies_and_rejects_garbage() {
        assert_eq!(OnExhausted::parse("abort"), Some(OnExhausted::Abort));
        assert_eq!(OnExhausted::parse("fallback"), Some(OnExhausted::Fallback));
        assert_eq!(OnExhausted::parse("retry"), None);
        assert_eq!(OnExhausted::default(), OnExhausted::Abort);
    }

    #[test]
    fn task_exhausted_displays_the_id_attempts_and_cause() {
        let e = TaskExhausted { task_id: 41, attempts: 3, last_err: "boom".into() };
        let msg = e.to_string();
        assert!(msg.contains("41") && msg.contains('3') && msg.contains("boom"), "{msg}");
    }

    #[test]
    fn median_needs_samples_and_the_ring_stays_bounded() {
        let core = bare_core(ClusterOptions {
            speculate_factor: Some(3.0),
            ..ClusterOptions::default()
        });
        assert!(core.tracks_leases());
        assert_eq!(core.median_duration("cross_map"), None);
        core.record_duration("cross_map", 1.0);
        core.record_duration("cross_map", 2.0);
        assert_eq!(core.median_duration("cross_map"), None, "under MEDIAN_MIN_SAMPLES");
        core.record_duration("cross_map", 3.0);
        assert_eq!(core.median_duration("cross_map"), Some(2.0));
        // flood the window with a new regime: the ring forgets the old one
        for _ in 0..DURATION_WINDOW + 8 {
            core.record_duration("cross_map", 10.0);
        }
        assert_eq!(core.lock_durations().get("cross_map").unwrap().len(), DURATION_WINDOW);
        assert_eq!(core.median_duration("cross_map"), Some(10.0));
        // kinds are independent
        assert_eq!(core.median_duration("shard_chunk"), None);
    }

    #[test]
    fn durations_are_not_tracked_with_the_knobs_off() {
        let core = bare_core(ClusterOptions::default());
        assert!(!core.tracks_leases());
        core.record_duration("cross_map", 1.0);
        assert!(core.lock_durations().is_empty(), "knobs off must mean zero bookkeeping");
    }

    #[test]
    fn take_lease_result_only_collects_a_committed_win() {
        let core = bare_core(ClusterOptions {
            task_deadline: Some(Duration::from_secs(300)),
            ..ClusterOptions::default()
        });
        core.lock_leases().insert(7, bare_lease("cross_map"));
        // no result committed: the lease must stay (the primary still owns
        // the task and will finish_lease it itself)
        assert!(core.take_lease_result(7).is_none());
        assert!(core.lock_leases().contains_key(&7));
        // commit a speculative win, then collect it exactly once
        core.lock_leases().get_mut(&7).unwrap().result = Some(Json::Num(1.0));
        assert!(core.take_lease_result(7).is_some());
        assert!(core.lock_leases().is_empty(), "collection removes the lease");
        assert!(core.take_lease_result(7).is_none());
    }

    #[test]
    fn deadline_scan_kills_once_and_arms_speculation_once() {
        let core = Arc::new(bare_core(ClusterOptions {
            task_deadline: Some(Duration::ZERO), // everything is overdue
            ..ClusterOptions::default()
        }));
        core.lock_leases().insert(1, bare_lease("cross_map"));
        core.scan_leases();
        assert_eq!(core.deadline_kills.load(Ordering::Relaxed), 1);
        assert!(core.lock_leases().get(&1).unwrap().killed);
        // a second scan must not re-kill (no double-requeue pressure)
        core.scan_leases();
        assert_eq!(core.deadline_kills.load(Ordering::Relaxed), 1);
    }
}
