//! Convergence assessment: the "C" in CCM.
//!
//! A causal link X -> Y is inferred when the skill of cross-mapping X from
//! M_Y *increases with library size and converges* (Sugihara et al. 2012).
//! This module turns a set of [`SkillSummary`] rows (one per L) into a
//! verdict.

use crate::ccm::result::SkillSummary;

/// Convergence analysis across library sizes for a fixed (E, tau).
#[derive(Clone, Debug)]
pub struct ConvergenceVerdict {
    /// Mean skill at the smallest library size.
    pub rho_min_l: f64,
    /// Mean skill at the largest library size.
    pub rho_max_l: f64,
    /// rho(Lmax) - rho(Lmin).
    pub delta: f64,
    /// Monotone non-decreasing trend across the L sweep (tolerance for
    /// sampling noise).
    pub increasing: bool,
    /// Verdict: skill is meaningfully positive and grew with L.
    pub causal: bool,
}

/// Assess convergence from per-L summaries (must share (E, tau); sorted
/// internally by L).
///
/// `min_rho` is the skill floor (default 0.1 in callers) and `min_delta`
/// the required improvement from Lmin to Lmax.
///
/// An empty slice (an (E, tau) slice fully pruned by partial evaluation)
/// yields the all-zero non-causal verdict rather than panicking. A
/// single-L slice can show no convergence *trend*, so it is never causal:
/// its `delta` is necessarily 0, which would vacuously satisfy any
/// `min_delta <= 0` threshold a caller relaxes to.
pub fn assess(summaries: &[SkillSummary], min_rho: f64, min_delta: f64) -> ConvergenceVerdict {
    if summaries.is_empty() {
        return ConvergenceVerdict {
            rho_min_l: 0.0,
            rho_max_l: 0.0,
            delta: 0.0,
            increasing: false,
            causal: false,
        };
    }
    let mut by_l: Vec<&SkillSummary> = summaries.iter().collect();
    by_l.sort_by_key(|s| s.params.l);
    let rho_min_l = by_l.first().unwrap().mean_rho;
    let rho_max_l = by_l.last().unwrap().mean_rho;
    let delta = rho_max_l - rho_min_l;
    // allow small dips (half a std-dev of the noisier end) between steps
    let tol = by_l.iter().map(|s| s.std_rho).fold(0.0f64, f64::max) * 0.5 + 1e-9;
    let increasing = by_l.windows(2).all(|w| w[1].mean_rho >= w[0].mean_rho - tol);
    // convergence is a trend across library sizes: with fewer than two L
    // values there is no trend, so the verdict cannot be causal (delta is
    // exactly 0 there and must not pass a min_delta of 0 by equality)
    let has_sweep = by_l.len() >= 2;
    ConvergenceVerdict {
        rho_min_l,
        rho_max_l,
        delta,
        increasing,
        causal: has_sweep && rho_max_l >= min_rho && delta >= min_delta && increasing,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ccm::params::CcmParams;

    fn summary(l: usize, mean: f64, std: f64) -> SkillSummary {
        SkillSummary { params: CcmParams::new(2, 1, l), n: 10, mean_rho: mean, std_rho: std, q05: 0.0, q95: 1.0 }
    }

    #[test]
    fn converging_series_is_causal() {
        let v = assess(
            &[summary(50, 0.4, 0.05), summary(100, 0.7, 0.03), summary(200, 0.85, 0.02)],
            0.1,
            0.05,
        );
        assert!(v.causal);
        assert!(v.increasing);
        assert!((v.delta - 0.45).abs() < 1e-9);
    }

    #[test]
    fn flat_weak_skill_not_causal() {
        let v = assess(
            &[summary(50, 0.02, 0.05), summary(100, 0.03, 0.05), summary(200, 0.01, 0.05)],
            0.1,
            0.05,
        );
        assert!(!v.causal);
    }

    #[test]
    fn decreasing_skill_not_causal() {
        let v = assess(
            &[summary(50, 0.8, 0.01), summary(100, 0.5, 0.01), summary(200, 0.3, 0.01)],
            0.1,
            0.05,
        );
        assert!(!v.increasing);
        assert!(!v.causal);
    }

    #[test]
    fn noise_tolerance_allows_small_dips() {
        let v = assess(
            &[summary(50, 0.40, 0.10), summary(100, 0.39, 0.10), summary(200, 0.70, 0.05)],
            0.1,
            0.05,
        );
        assert!(v.increasing, "small dip within noise should not break the trend");
        assert!(v.causal);
    }

    #[test]
    fn empty_is_non_causal_not_a_panic() {
        // a fully pruned (E, tau) slice reaches assess with no summaries
        let v = assess(&[], 0.1, 0.05);
        assert!(!v.causal);
        assert!(!v.increasing);
        assert_eq!(v.delta, 0.0);
        assert_eq!(v.rho_min_l, 0.0);
        assert_eq!(v.rho_max_l, 0.0);
    }

    #[test]
    fn single_l_cannot_be_causal_even_with_zero_min_delta() {
        // delta == 0 for one L; a min_delta of 0 must not make it causal
        let v = assess(&[summary(200, 0.9, 0.01)], 0.1, 0.0);
        assert_eq!(v.delta, 0.0);
        assert!(v.increasing, "a single point is vacuously non-decreasing");
        assert!(!v.causal, "no L sweep means no convergence evidence");
    }
}
