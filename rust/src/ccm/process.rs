//! Compatibility shim: PR 3 split the process-separated backend into
//! [`crate::ccm::transport`] (the byte layer: pipe/fork and TCP-loopback
//! transports, hello/version negotiation, death detection) and
//! [`crate::ccm::cluster`] (the wire format and the replica-aware
//! scheduler). The old `ProcessBackend` name is the pipe-transport
//! [`ClusterBackend`][crate::ccm::cluster::ClusterBackend] with a
//! replication factor of 1 — construction and behavior are unchanged
//! (bit-identical results, same requeue-on-death semantics), so existing
//! callers keep working through these re-exports.

pub use crate::ccm::cluster::{worker_main, ClusterBackend as ProcessBackend, MAX_TASK_ATTEMPTS};
pub use crate::ccm::transport::{BINARY_WIRE_VERSION, MIN_WIRE_VERSION, WIRE_VERSION};
