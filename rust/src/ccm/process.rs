//! Process-separated compute: a serializing [`ComputeBackend`] that ships
//! CCM tasks to forked worker processes over pipes — the first genuine
//! process boundary in the stack (native_spark-style: the driver moves
//! serialized work to executors instead of sharing memory).
//!
//! # Wire protocol (version [`WIRE_VERSION`])
//!
//! Line-delimited JSON over the worker's stdin/stdout. Large read-only
//! state moves once per worker as content-addressed *broadcasts*; tasks
//! then reference broadcasts by id and carry only library-row indices —
//! a few KB, exactly the index-only task layout PR 1's zero-copy
//! [`CrossMapInput`] made possible.
//!
//! Worker -> driver on startup:
//!
//! ```json
//! {"type":"hello","v":1,"pid":12345}
//! ```
//!
//! Driver -> worker (broadcasts are not acknowledged; tasks get exactly
//! one `result` or `error` reply):
//!
//! ```json
//! {"v":1,"type":"broadcast","id":"<hex64>","kind":"problem",
//!  "vecs":[...],"targets":[...],"times":[...]}
//! {"v":1,"type":"broadcast","id":"<hex64>","kind":"targets","targets":[...]}
//! {"v":1,"type":"broadcast","id":"<hex64>","kind":"shard","shard_id":0,
//!  "row_lo":0,"row_hi":100,"row_len":64,"n":400,"t0":2,
//!  "neighbors":[...],"vecs":[...]}
//! {"v":1,"type":"task","task":7,"op":"cross_map","problem":"<hex64>",
//!  "lib_rows":[...],"e":2,"theiler":0}
//! {"v":1,"type":"task","task":8,"op":"shard_chunk","shard":"<hex64>",
//!  "targets":"<hex64>","lib_rows":[...],"e":2,"theiler":0}
//! {"type":"shutdown"}
//! ```
//!
//! Worker -> driver replies:
//!
//! ```json
//! {"type":"result","task":7,"rho":0.93,"preds":[...]}
//! {"type":"result","task":8,"preds":[...]}
//! {"type":"error","task":8,"msg":"unknown broadcast deadbeef"}
//! ```
//!
//! Floats ride as JSON numbers; the writer emits shortest-roundtrip f64
//! and f32 -> f64 is exact, so every finite value survives the pipe
//! bit-for-bit (`util::json` tests pin this), keeping process-backend
//! results bit-identical to in-process ones.
//!
//! # Lifecycle and failure handling
//!
//! The driver spawns `parccm worker` children (handshake validates the
//! wire version), tracks which broadcast each worker holds, and
//! dispatches tasks to idle workers — preferring one that already holds
//! the task's broadcasts (shard-aware scheduling: shard `s` gravitates
//! to the worker that first served it). A worker that dies mid-task
//! (EOF/EPIPE) is reaped, a replacement is spawned, and the task is
//! requeued on another worker with its broadcasts re-shipped from the
//! driver-side payload cache — RDD-style task resilience across a real
//! process boundary. After [`MAX_TASK_ATTEMPTS`] failures the task
//! panics, which the engine's own task-retry then surfaces as a job
//! failure.
//!
//! Known limitation: broadcasts are retained for the backend's lifetime
//! (driver-side serialized payloads and worker-side decoded stores) —
//! there is no evict message yet. Memory therefore grows with the
//! parameter grid; fine at current scenario sizes, and the ROADMAP
//! tracks broadcast eviction alongside shard replicas.

use std::collections::{HashMap, HashSet};
use std::io::{BufRead, BufReader, Write};
use std::path::{Path, PathBuf};
use std::process::{Child, ChildStdin, ChildStdout, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use crate::ccm::backend::{ComputeBackend, CrossMapInput, TaskArena};
use crate::ccm::table::TableShard;
use crate::native::NativeBackend;
use crate::util::json::Json;

/// Protocol version; bumped on any incompatible message change. The
/// handshake rejects mismatched workers instead of mis-decoding them.
pub const WIRE_VERSION: u64 = 1;

/// Attempts per task across worker replacements before giving up.
pub const MAX_TASK_ATTEMPTS: usize = 3;

// ---------------------------------------------------------------------------
// content addressing (same FNV-1a scheme as TableShard::wire_id — one
// shared helper so shard identity and wire dedup keys can never diverge)
// ---------------------------------------------------------------------------

use crate::ccm::table::{fnv1a64_word as fnv_word, FNV_OFFSET};

fn fnv_f32s(mut h: u64, xs: &[f32]) -> u64 {
    h = fnv_word(h, xs.len() as u64);
    for &x in xs {
        h = fnv_word(h, x.to_bits() as u64);
    }
    h
}

/// Content id of a brute-force problem broadcast (manifold + targets +
/// times). Hashing is O(n) per task but microseconds against a k-NN sweep,
/// and content addressing can never serve stale state after reallocation.
fn problem_id(vecs: &[f32], targets: &[f32], times: &[f32]) -> u64 {
    fnv_f32s(fnv_f32s(fnv_f32s(fnv_word(FNV_OFFSET, 1), vecs), targets), times)
}

/// Content id of a targets-only broadcast (sharded table mode).
fn targets_id(targets: &[f32]) -> u64 {
    fnv_f32s(fnv_word(FNV_OFFSET, 2), targets)
}

fn hex(id: u64) -> String {
    format!("{id:016x}")
}

// ---------------------------------------------------------------------------
// payload builders (driver side; cached per broadcast id)
// ---------------------------------------------------------------------------

fn broadcast_header(id: u64, kind: &str) -> Vec<(&'static str, Json)> {
    vec![
        ("v", Json::Num(WIRE_VERSION as f64)),
        ("type", Json::Str("broadcast".into())),
        ("id", Json::Str(hex(id))),
        ("kind", Json::Str(kind.to_string())),
    ]
}

fn problem_payload(id: u64, vecs: &[f32], targets: &[f32], times: &[f32]) -> String {
    let mut fields = broadcast_header(id, "problem");
    fields.push(("vecs", Json::f32s(vecs)));
    fields.push(("targets", Json::f32s(targets)));
    fields.push(("times", Json::f32s(times)));
    Json::obj(fields).to_string()
}

fn targets_payload(id: u64, targets: &[f32]) -> String {
    let mut fields = broadcast_header(id, "targets");
    fields.push(("targets", Json::f32s(targets)));
    Json::obj(fields).to_string()
}

fn shard_payload(id: u64, shard: &TableShard) -> String {
    let (neighbors, vecs) = shard.raw_parts();
    let mut fields = broadcast_header(id, "shard");
    fields.push(("shard_id", Json::Num(shard.shard_id as f64)));
    fields.push(("row_lo", Json::Num(shard.row_lo as f64)));
    fields.push(("row_hi", Json::Num(shard.row_hi as f64)));
    fields.push(("row_len", Json::Num(shard.row_len() as f64)));
    fields.push(("n", Json::Num(shard.n as f64)));
    fields.push(("t0", Json::Num(shard.t0 as f64)));
    fields.push(("neighbors", Json::u32s(neighbors)));
    fields.push(("vecs", Json::f32s(vecs)));
    Json::obj(fields).to_string()
}

// ---------------------------------------------------------------------------
// worker (child-process side)
// ---------------------------------------------------------------------------

enum Stored {
    Problem { vecs: Vec<f32>, targets: Vec<f32>, times: Vec<f32> },
    Targets(Vec<f32>),
    Shard(TableShard),
}

fn field_f64(msg: &Json, key: &str) -> Result<f64, String> {
    msg.get(key).and_then(Json::as_f64).ok_or_else(|| format!("missing number '{key}'"))
}

fn field_usize(msg: &Json, key: &str) -> Result<usize, String> {
    Ok(field_f64(msg, key)? as usize)
}

fn field_str<'a>(msg: &'a Json, key: &str) -> Result<&'a str, String> {
    msg.get(key).and_then(Json::as_str).ok_or_else(|| format!("missing string '{key}'"))
}

fn field_f32s(msg: &Json, key: &str) -> Result<Vec<f32>, String> {
    msg.get(key).and_then(Json::as_f32s).ok_or_else(|| format!("missing f32 array '{key}'"))
}

fn store_broadcast(store: &mut HashMap<String, Stored>, msg: &Json) -> Result<(), String> {
    let id = field_str(msg, "id")?.to_string();
    let value = match field_str(msg, "kind")? {
        "problem" => Stored::Problem {
            vecs: field_f32s(msg, "vecs")?,
            targets: field_f32s(msg, "targets")?,
            times: field_f32s(msg, "times")?,
        },
        "targets" => Stored::Targets(field_f32s(msg, "targets")?),
        "shard" => Stored::Shard(TableShard::from_parts(
            field_usize(msg, "shard_id")?,
            field_usize(msg, "row_lo")?,
            field_usize(msg, "row_hi")?,
            field_usize(msg, "row_len")?,
            field_usize(msg, "n")?,
            field_usize(msg, "t0")?,
            msg.get("neighbors").and_then(Json::as_u32s).ok_or("missing 'neighbors'")?,
            field_f32s(msg, "vecs")?,
        )),
        other => return Err(format!("unknown broadcast kind '{other}'")),
    };
    store.insert(id, value);
    Ok(())
}

fn run_task(
    store: &HashMap<String, Stored>,
    arena: &mut TaskArena,
    msg: &Json,
) -> Result<Json, String> {
    let task = field_f64(msg, "task")?;
    let lib_rows = msg
        .get("lib_rows")
        .and_then(Json::as_usizes)
        .ok_or("missing 'lib_rows'")?;
    let e = field_usize(msg, "e")?;
    let theiler = field_f64(msg, "theiler")? as f32;
    let backend = NativeBackend;
    match field_str(msg, "op")? {
        "cross_map" => {
            let pid = field_str(msg, "problem")?;
            let Some(Stored::Problem { vecs, targets, times }) = store.get(pid) else {
                return Err(format!("unknown broadcast {pid}"));
            };
            let input = CrossMapInput {
                vecs,
                targets,
                times,
                lib_rows: &lib_rows,
                e,
                theiler,
            };
            let rho = backend.cross_map_into(&input, arena);
            Ok(Json::obj(vec![
                ("type", Json::Str("result".into())),
                ("task", Json::Num(task)),
                ("rho", Json::Num(rho as f64)),
                ("preds", Json::f32s(&arena.preds)),
            ]))
        }
        "shard_chunk" => {
            let sid = field_str(msg, "shard")?;
            let tid = field_str(msg, "targets")?;
            let Some(Stored::Shard(shard)) = store.get(sid) else {
                return Err(format!("unknown broadcast {sid}"));
            };
            let Some(Stored::Targets(targets)) = store.get(tid) else {
                return Err(format!("unknown broadcast {tid}"));
            };
            let mut preds = Vec::new();
            backend.shard_chunk_into(shard, targets, theiler, &lib_rows, e, arena, &mut preds);
            Ok(Json::obj(vec![
                ("type", Json::Str("result".into())),
                ("task", Json::Num(task)),
                ("preds", Json::f32s(&preds)),
            ]))
        }
        other => Err(format!("unknown op '{other}'")),
    }
}

/// The worker process entry point (`parccm worker`): serve broadcasts and
/// tasks from stdin until EOF (driver gone) or an explicit shutdown.
/// Replies go to stdout, one JSON object per line; diagnostics to stderr.
pub fn worker_main() -> std::process::ExitCode {
    let stdin = std::io::stdin();
    let stdout = std::io::stdout();
    let mut out = stdout.lock();
    let hello = Json::obj(vec![
        ("type", Json::Str("hello".into())),
        ("v", Json::Num(WIRE_VERSION as f64)),
        ("pid", Json::Num(std::process::id() as f64)),
    ]);
    if writeln!(out, "{hello}").and_then(|_| out.flush()).is_err() {
        return std::process::ExitCode::FAILURE;
    }
    let mut store: HashMap<String, Stored> = HashMap::new();
    let mut arena = TaskArena::new();
    for line in stdin.lock().lines() {
        let Ok(line) = line else { break };
        if line.trim().is_empty() {
            continue;
        }
        let msg = match Json::parse(&line) {
            Ok(m) => m,
            Err(e) => {
                eprintln!("[worker {}] bad message: {e}", std::process::id());
                return std::process::ExitCode::FAILURE;
            }
        };
        let reply = match msg.get("type").and_then(Json::as_str) {
            Some("shutdown") => return std::process::ExitCode::SUCCESS,
            Some("broadcast") => match store_broadcast(&mut store, &msg) {
                Ok(()) => None, // broadcasts are unacknowledged
                Err(e) => Some(error_reply(&msg, e)),
            },
            Some("task") => match run_task(&store, &mut arena, &msg) {
                Ok(r) => Some(r),
                Err(e) => Some(error_reply(&msg, e)),
            },
            other => Some(error_reply(&msg, format!("unknown message type {other:?}"))),
        };
        if let Some(reply) = reply {
            if writeln!(out, "{reply}").and_then(|_| out.flush()).is_err() {
                break; // driver hung up
            }
        }
    }
    std::process::ExitCode::SUCCESS
}

fn error_reply(msg: &Json, err: String) -> Json {
    Json::obj(vec![
        ("type", Json::Str("error".into())),
        ("task", msg.get("task").cloned().unwrap_or(Json::Null)),
        ("msg", Json::Str(err)),
    ])
}

// ---------------------------------------------------------------------------
// driver (parent-process side)
// ---------------------------------------------------------------------------

struct Worker {
    child: Child,
    stdin: ChildStdin,
    stdout: BufReader<ChildStdout>,
    /// Broadcast ids this worker holds (reset on respawn).
    has: HashSet<u64>,
    pid: u32,
}

impl Worker {
    fn send(&mut self, line: &str) -> std::io::Result<()> {
        self.stdin.write_all(line.as_bytes())?;
        self.stdin.write_all(b"\n")?;
        self.stdin.flush()
    }

    fn recv(&mut self) -> std::io::Result<Json> {
        let mut line = String::new();
        loop {
            line.clear();
            if self.stdout.read_line(&mut line)? == 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "worker closed its pipe",
                ));
            }
            if line.trim().is_empty() {
                continue;
            }
            return Json::parse(&line).map_err(|e| {
                std::io::Error::new(std::io::ErrorKind::InvalidData, e.to_string())
            });
        }
    }
}

#[derive(Default)]
struct PoolState {
    idle: Vec<Worker>,
    /// Workers existing (idle or leased to a task).
    live: usize,
    /// Workers replaced after dying mid-exchange.
    respawns: u64,
}

/// A [`ComputeBackend`] whose cross-map work executes in forked worker
/// processes (see the module docs for the wire protocol). `cross_map_into`
/// and `shard_chunk_into` cross the process boundary; `simplex_tail_into`
/// and `distance_matrix` are driver-side combine/build steps and run
/// locally on the native backend.
pub struct ProcessBackend {
    cmd: PathBuf,
    state: Mutex<PoolState>,
    cv: Condvar,
    /// Serialized broadcast lines by id, for (re-)shipping to any worker.
    payloads: Mutex<HashMap<u64, Arc<String>>>,
    next_task: AtomicU64,
    local: NativeBackend,
}

impl ProcessBackend {
    /// Spawn `workers` children of this executable (`<current_exe> worker`).
    pub fn new(workers: usize) -> std::io::Result<ProcessBackend> {
        Self::with_command(std::env::current_exe()?, workers)
    }

    /// Spawn `workers` children of an explicit binary (tests pass
    /// `env!("CARGO_BIN_EXE_parccm")`).
    pub fn with_command(
        cmd: impl Into<PathBuf>,
        workers: usize,
    ) -> std::io::Result<ProcessBackend> {
        let cmd = cmd.into();
        let workers = workers.max(1);
        let mut idle = Vec::with_capacity(workers);
        for _ in 0..workers {
            idle.push(spawn_worker(&cmd)?);
        }
        Ok(ProcessBackend {
            cmd,
            state: Mutex::new(PoolState { live: idle.len(), idle, respawns: 0 }),
            cv: Condvar::new(),
            payloads: Mutex::new(HashMap::new()),
            next_task: AtomicU64::new(1),
            local: NativeBackend,
        })
    }

    /// Live worker pids (for observability and kill-recovery tests).
    pub fn worker_pids(&self) -> Vec<u32> {
        self.state.lock().unwrap().idle.iter().map(|w| w.pid).collect()
    }

    /// Workers currently alive (idle + leased).
    pub fn num_workers(&self) -> usize {
        self.state.lock().unwrap().live
    }

    /// How many workers have been replaced after dying.
    pub fn respawns(&self) -> u64 {
        self.state.lock().unwrap().respawns
    }

    /// Cache (and return) the serialized payload for broadcast `id`.
    fn payload(&self, id: u64, build: impl FnOnce() -> String) -> Arc<String> {
        let mut map = self.payloads.lock().unwrap();
        Arc::clone(map.entry(id).or_insert_with(|| Arc::new(build())))
    }

    /// Lease an idle worker, preferring one that already holds every id in
    /// `needs` (shard affinity); blocks while all workers are leased.
    fn acquire(&self, needs: &[u64]) -> Worker {
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.idle.is_empty() {
                let pos = st
                    .idle
                    .iter()
                    .position(|w| needs.iter().all(|id| w.has.contains(id)))
                    .unwrap_or(st.idle.len() - 1);
                return st.idle.swap_remove(pos);
            }
            assert!(st.live > 0, "process backend has no live workers left");
            st = self.cv.wait(st).unwrap();
        }
    }

    fn release(&self, worker: Worker) {
        let mut st = self.state.lock().unwrap();
        st.idle.push(worker);
        drop(st);
        self.cv.notify_all();
    }

    /// Reap a dead worker and spawn its replacement (fresh broadcast set).
    fn discard_and_respawn(&self, mut dead: Worker) {
        let _ = dead.child.kill();
        let _ = dead.child.wait();
        let replacement = spawn_worker(&self.cmd);
        let mut st = self.state.lock().unwrap();
        st.live -= 1;
        st.respawns += 1;
        match replacement {
            Ok(w) => {
                st.idle.push(w);
                st.live += 1;
            }
            Err(e) => {
                eprintln!("[process backend] failed to respawn worker: {e}");
                assert!(st.live > 0, "process backend lost every worker and cannot respawn");
            }
        }
        drop(st);
        self.cv.notify_all();
    }

    /// One request/response exchange on `worker`: ship missing broadcasts,
    /// send the task, read its reply.
    fn exchange(
        &self,
        worker: &mut Worker,
        needs: &[(u64, Arc<String>)],
        task_id: u64,
        task_line: &str,
    ) -> std::io::Result<Json> {
        for (id, payload) in needs {
            if !worker.has.contains(id) {
                worker.send(payload)?;
                worker.has.insert(*id);
            }
        }
        worker.send(task_line)?;
        loop {
            let reply = worker.recv()?;
            match reply.get("type").and_then(Json::as_str) {
                Some("result")
                    if reply.get("task").and_then(Json::as_f64) == Some(task_id as f64) =>
                {
                    return Ok(reply);
                }
                Some("error") => {
                    return Err(std::io::Error::other(
                        reply
                            .get("msg")
                            .and_then(Json::as_str)
                            .unwrap_or("unspecified worker error")
                            .to_string(),
                    ));
                }
                _ => continue, // hello echoes / stale lines: skip
            }
        }
    }

    /// Run a task to completion, requeueing on a fresh worker if the
    /// leased one dies mid-exchange.
    fn execute(&self, needs: &[(u64, Arc<String>)], build_task: impl Fn(u64) -> String) -> Json {
        let task_id = self.next_task.fetch_add(1, Ordering::Relaxed);
        let task_line = build_task(task_id);
        let ids: Vec<u64> = needs.iter().map(|(id, _)| *id).collect();
        let mut last_err = String::new();
        for _attempt in 0..MAX_TASK_ATTEMPTS {
            let mut worker = self.acquire(&ids);
            match self.exchange(&mut worker, needs, task_id, &task_line) {
                Ok(reply) => {
                    self.release(worker);
                    return reply;
                }
                Err(e) => {
                    last_err = e.to_string();
                    self.discard_and_respawn(worker);
                }
            }
        }
        panic!("process backend task {task_id} failed {MAX_TASK_ATTEMPTS} attempts: {last_err}");
    }
}

fn spawn_worker(cmd: &Path) -> std::io::Result<Worker> {
    let mut child = Command::new(cmd)
        .arg("worker")
        .stdin(Stdio::piped())
        .stdout(Stdio::piped())
        .spawn()?;
    let stdin = child.stdin.take().expect("piped stdin");
    let stdout = BufReader::new(child.stdout.take().expect("piped stdout"));
    let pid = child.id();
    let mut worker = Worker { child, stdin, stdout, has: HashSet::new(), pid };
    // handshake: hello with a matching wire version
    let hello = worker.recv()?;
    let ok = hello.get("type").and_then(Json::as_str) == Some("hello")
        && hello.get("v").and_then(Json::as_f64) == Some(WIRE_VERSION as f64);
    if !ok {
        let _ = worker.child.kill();
        let _ = worker.child.wait();
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            format!("worker handshake failed (want v{WIRE_VERSION}, got {hello})"),
        ));
    }
    Ok(worker)
}

impl Drop for ProcessBackend {
    fn drop(&mut self) {
        let mut st = self.state.lock().unwrap();
        for mut w in st.idle.drain(..) {
            let _ = w.send(r#"{"type":"shutdown"}"#);
            let _ = w.child.wait();
        }
    }
}

impl ComputeBackend for ProcessBackend {
    fn cross_map_into(&self, input: &CrossMapInput, arena: &mut TaskArena) -> f32 {
        let id = problem_id(input.vecs, input.targets, input.times);
        let payload =
            self.payload(id, || problem_payload(id, input.vecs, input.targets, input.times));
        let e = input.e;
        let theiler = input.theiler;
        let lib_rows = Json::usizes(input.lib_rows);
        let reply = self.execute(&[(id, payload)], |task| {
            Json::obj(vec![
                ("v", Json::Num(WIRE_VERSION as f64)),
                ("type", Json::Str("task".into())),
                ("task", Json::Num(task as f64)),
                ("op", Json::Str("cross_map".into())),
                ("problem", Json::Str(hex(id))),
                ("lib_rows", lib_rows.clone()),
                ("e", Json::Num(e as f64)),
                ("theiler", Json::Num(theiler as f64)),
            ])
            .to_string()
        });
        arena.preds = reply
            .get("preds")
            .and_then(Json::as_f32s)
            .expect("worker result missing preds");
        reply.get("rho").and_then(Json::as_f64).expect("worker result missing rho") as f32
    }

    fn simplex_tail_into(
        &self,
        dvals: &[f32],
        tvals: &[f32],
        pred_targets: &[f32],
        e: usize,
        preds: &mut Vec<f32>,
    ) -> f32 {
        // driver-side combine step (cheap O(n*K)); panels never ship
        self.local.simplex_tail_into(dvals, tvals, pred_targets, e, preds)
    }

    fn distance_matrix(&self, vecs: &[f32], n: usize) -> Vec<f32> {
        // table construction happens driver-side; shards ship afterwards
        self.local.distance_matrix(vecs, n)
    }

    #[allow(clippy::too_many_arguments)]
    fn shard_chunk_into(
        &self,
        shard: &TableShard,
        targets: &[f32],
        theiler: f32,
        lib_rows: &[usize],
        e: usize,
        _arena: &mut TaskArena,
        preds: &mut Vec<f32>,
    ) {
        let sid = shard.wire_id();
        let tid = targets_id(targets);
        let shard_line = self.payload(sid, || shard_payload(sid, shard));
        let targets_line = self.payload(tid, || targets_payload(tid, targets));
        let lib_rows = Json::usizes(lib_rows);
        let reply = self.execute(&[(sid, shard_line), (tid, targets_line)], |task| {
            Json::obj(vec![
                ("v", Json::Num(WIRE_VERSION as f64)),
                ("type", Json::Str("task".into())),
                ("task", Json::Num(task as f64)),
                ("op", Json::Str("shard_chunk".into())),
                ("shard", Json::Str(hex(sid))),
                ("targets", Json::Str(hex(tid))),
                ("lib_rows", lib_rows.clone()),
                ("e", Json::Num(e as f64)),
                ("theiler", Json::Num(theiler as f64)),
            ])
            .to_string()
        });
        *preds = reply
            .get("preds")
            .and_then(Json::as_f32s)
            .expect("worker result missing preds");
    }

    fn name(&self) -> &'static str {
        "process"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ccm::pipeline::CcmProblem;
    use crate::timeseries::generators::{coupled_logistic, CoupledLogisticParams};

    // In-process round-trip tests of the wire pieces; full multi-process
    // coverage lives in tests/integration_process.rs (it needs the built
    // `parccm` binary via CARGO_BIN_EXE).

    #[test]
    fn content_ids_are_stable_and_sensitive() {
        let a = vec![1.0f32, 2.0, 3.0];
        let b = vec![1.0f32, 2.0, 3.0];
        let c = vec![1.0f32, 2.0, 3.5];
        assert_eq!(problem_id(&a, &a, &a), problem_id(&b, &b, &b));
        assert_ne!(problem_id(&a, &a, &a), problem_id(&a, &a, &c));
        // kind-tagged: the same bytes as problem vs targets never collide
        assert_ne!(problem_id(&a, &[], &[]), targets_id(&a));
    }

    #[test]
    fn broadcast_payloads_roundtrip_through_worker_store() {
        let (x, y) = coupled_logistic(120, CoupledLogisticParams::default());
        let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
        let pid = problem_id(&problem.emb.vecs, &problem.targets, &problem.times);
        let line = problem_payload(pid, &problem.emb.vecs, &problem.targets, &problem.times);
        let mut store = HashMap::new();
        store_broadcast(&mut store, &Json::parse(&line).unwrap()).unwrap();
        match store.get(&hex(pid)) {
            Some(Stored::Problem { vecs, targets, times }) => {
                assert_eq!(vecs, &problem.emb.vecs);
                assert_eq!(targets, &problem.targets);
                assert_eq!(times, &problem.times);
            }
            _ => panic!("problem broadcast not stored"),
        }
    }

    #[test]
    fn shard_payload_roundtrips_with_identical_wire_id() {
        let (x, y) = coupled_logistic(120, CoupledLogisticParams::default());
        let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
        let table = crate::ccm::table::DistanceTable::build_truncated(&problem.emb, 16);
        let sharded = table.shard(3);
        let shard = &sharded.shards()[1];
        let line = shard_payload(shard.wire_id(), shard);
        let mut store = HashMap::new();
        store_broadcast(&mut store, &Json::parse(&line).unwrap()).unwrap();
        match store.get(&hex(shard.wire_id())) {
            Some(Stored::Shard(s)) => assert_eq!(s.wire_id(), shard.wire_id()),
            _ => panic!("shard broadcast not stored"),
        }
    }

    #[test]
    fn worker_task_runner_matches_local_backend() {
        // drive run_task directly (no subprocess): cross_map over the wire
        // model must equal the local native backend bit-for-bit
        let (x, y) = coupled_logistic(200, CoupledLogisticParams::default());
        let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
        let pid = problem_id(&problem.emb.vecs, &problem.targets, &problem.times);
        let mut store = HashMap::new();
        let line = problem_payload(pid, &problem.emb.vecs, &problem.targets, &problem.times);
        store_broadcast(&mut store, &Json::parse(&line).unwrap()).unwrap();
        let lib_rows: Vec<usize> = (0..problem.emb.n).step_by(3).collect();
        let task = Json::obj(vec![
            ("v", Json::Num(1.0)),
            ("type", Json::Str("task".into())),
            ("task", Json::Num(9.0)),
            ("op", Json::Str("cross_map".into())),
            ("problem", Json::Str(hex(pid))),
            ("lib_rows", Json::usizes(&lib_rows)),
            ("e", Json::Num(2.0)),
            ("theiler", Json::Num(0.0)),
        ]);
        // simulate the reply crossing the pipe as text
        let mut arena = TaskArena::new();
        let reply = run_task(&store, &mut arena, &task).unwrap();
        let reply = Json::parse(&reply.to_string()).unwrap();

        let sample = crate::ccm::subsample::LibrarySample {
            sample_id: 0,
            params: crate::ccm::params::CcmParams::new(2, 1, lib_rows.len()),
            rows: lib_rows,
        };
        let want = NativeBackend.cross_map(&problem.input_for(&sample));
        assert_eq!(reply.get("rho").and_then(Json::as_f64).unwrap() as f32, want.rho);
        assert_eq!(reply.get("preds").and_then(Json::as_f32s).unwrap(), want.preds);
    }

    #[test]
    fn unknown_broadcast_yields_error() {
        let store = HashMap::new();
        let mut arena = TaskArena::new();
        let task = Json::obj(vec![
            ("type", Json::Str("task".into())),
            ("task", Json::Num(1.0)),
            ("op", Json::Str("cross_map".into())),
            ("problem", Json::Str("feedbeef00000000".into())),
            ("lib_rows", Json::usizes(&[1, 2, 3])),
            ("e", Json::Num(2.0)),
            ("theiler", Json::Num(0.0)),
        ]);
        let err = run_task(&store, &mut arena, &task).unwrap_err();
        assert!(err.contains("unknown broadcast"), "{err}");
    }
}
