//! The v6 binary wire codec: what goes *inside* a length-prefixed frame.
//!
//! [`crate::ccm::transport`] owns the byte layer (length prefix, checksum
//! trailer, deadlines); this module owns the frame body:
//!
//! ```text
//! [tag: u8] [payload...]
//! ```
//!
//! Payload-bearing messages — the broadcasts (`problem`, `targets`,
//! `shard`) and the results (`preds`, `sums`) — get dedicated tags with
//! raw little-endian f32/f64 arrays and varint section lengths, so an f32
//! crosses the wire as its exact 4 bytes instead of shortest-roundtrip
//! decimal text (bit-exact *including* NaN payloads and signed zeros,
//! which the JSON writer cannot even represent). Everything else — tasks,
//! hello/ack, ping/pong, evict, errors, shutdown — rides as compact JSON
//! text inside a [`TAG_JSON`] envelope: those messages are tiny and keeping
//! them JSON means the scheduler's lease/speculation machinery (which
//! stores and re-sends task lines verbatim) carries over unchanged. The v7
//! serve-mode control messages (`submit`/`status`/`fetch`/`cancel` and
//! their replies, see [`crate::ccm::serve`]) ride the same envelope, which
//! is why v7 needed no codec changes at all.
//!
//! Neighbor-index arrays (the dominant bytes of a `shard` broadcast) are
//! *bit-packed* to the width of their largest value rather than shipped as
//! raw u32: a row index is bounded by the manifold size, so it fits
//! ~10-20 bits, while both raw u32 and its decimal JSON form cost ~4
//! bytes — raw alone would leave shard ships nearly as large as JSON.
//! The packing is exact and self-describing (an explicit width byte).
//!
//! Decoding is strict: every section length is checked against the bytes
//! actually present, unknown tags and trailing garbage are errors, and a
//! decode error never panics — the caller surfaces it as `InvalidData`,
//! which flows into the same connection-death machinery as a checksum
//! mismatch.

use crate::ccm::pipeline::PearsonSums;
use crate::ccm::table::TableShard;
use crate::util::json::Json;

/// JSON-in-envelope: the payload is one UTF-8 JSON object, byte for byte
/// the line the JSON wire would have sent (minus the newline).
pub const TAG_JSON: u8 = 0x00;
/// Broadcast: brute-force problem (vecs + targets + times f32 arrays).
pub const TAG_BCAST_PROBLEM: u8 = 0x01;
/// Broadcast: shared targets column (one f32 array).
pub const TAG_BCAST_TARGETS: u8 = 0x02;
/// Broadcast: one sorted-neighbour table shard (packed indices + manifold).
pub const TAG_BCAST_SHARD: u8 = 0x03;
/// Result: prediction rows (optional rho + f32 array), `cross_map` and
/// `shard_chunk` replies.
pub const TAG_RESULT_PREDS: u8 = 0x10;
/// Result: six-number partial Pearson sums, `agg_chunk` / `merge_sums`
/// replies (the v5 reduce path).
pub const TAG_RESULT_SUMS: u8 = 0x11;

/// A decoded v6 frame body.
pub enum BinMsg {
    /// A control / task message (parsed from its JSON envelope).
    Json(Json),
    /// A broadcast, decoded straight to its typed form (no JSON detour —
    /// this is the bulk-bytes path).
    Broadcast(Broadcast),
    /// A `result` carrying prediction rows.
    ResultPreds { task: u64, rho: Option<f32>, preds: Vec<f32> },
    /// A `result` carrying partial Pearson sums.
    ResultSums { task: u64, sums: PearsonSums },
}

/// A typed broadcast payload (the worker stores these content-addressed).
pub enum Broadcast {
    Problem { id: u64, vecs: Vec<f32>, targets: Vec<f32>, times: Vec<f32> },
    Targets { id: u64, targets: Vec<f32> },
    Shard { id: u64, shard: TableShard },
}

// ---- primitive writers ------------------------------------------------

fn put_varint(out: &mut Vec<u8>, mut v: u64) {
    loop {
        let b = (v & 0x7f) as u8;
        v >>= 7;
        if v == 0 {
            out.push(b);
            return;
        }
        out.push(b | 0x80);
    }
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    put_varint(out, xs.len() as u64);
    out.reserve(xs.len() * 4);
    for &x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

/// Bit-packed index array: `[width: u8][count: varint][packed bits]`,
/// LSB-first within each byte, `width` = bits of the largest value (0 for
/// an all-zero or empty array — zero-width values decode as 0).
fn put_packed_u32s(out: &mut Vec<u8>, xs: &[u32]) {
    let width = xs.iter().copied().max().map_or(0, |m| 32 - m.leading_zeros()) as u8;
    out.push(width);
    put_varint(out, xs.len() as u64);
    if width == 0 {
        return;
    }
    out.reserve((xs.len() * width as usize).div_ceil(8));
    let mut acc: u64 = 0;
    let mut bits: u32 = 0;
    for &x in xs {
        acc |= (x as u64) << bits;
        bits += width as u32;
        while bits >= 8 {
            out.push((acc & 0xff) as u8);
            acc >>= 8;
            bits -= 8;
        }
    }
    if bits > 0 {
        out.push((acc & 0xff) as u8);
    }
}

// ---- primitive readers ------------------------------------------------

/// A cursor over a frame payload; every read is bounds-checked.
struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    fn new(buf: &'a [u8]) -> Reader<'a> {
        Reader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], String> {
        let end = self.pos.checked_add(n).filter(|&e| e <= self.buf.len());
        let end = end.ok_or_else(|| format!("frame truncated: wanted {n} more bytes"))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, String> {
        Ok(self.take(1)?[0])
    }

    fn varint(&mut self) -> Result<u64, String> {
        let mut v: u64 = 0;
        let mut shift = 0u32;
        loop {
            let b = self.u8()?;
            if shift >= 63 && b > 1 {
                return Err("varint overflows u64".into());
            }
            v |= u64::from(b & 0x7f) << shift;
            if b & 0x80 == 0 {
                return Ok(v);
            }
            shift += 7;
        }
    }

    /// A length prefix that must be satisfiable by the remaining bytes
    /// (guards against a corrupt count demanding a huge allocation).
    fn len(&mut self, bytes_per_item: usize) -> Result<usize, String> {
        let n = self.varint()? as usize;
        let need = n.checked_mul(bytes_per_item).ok_or("section length overflows")?;
        if need > self.buf.len() - self.pos {
            return Err(format!("section claims {n} items but the frame is too short"));
        }
        Ok(n)
    }

    fn f32(&mut self) -> Result<f32, String> {
        let b = self.take(4)?;
        Ok(f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    fn f64(&mut self) -> Result<f64, String> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(f64::from_le_bytes(a))
    }

    fn u64_raw(&mut self) -> Result<u64, String> {
        let b = self.take(8)?;
        let mut a = [0u8; 8];
        a.copy_from_slice(b);
        Ok(u64::from_le_bytes(a))
    }

    fn f32s(&mut self) -> Result<Vec<f32>, String> {
        let n = self.len(4)?;
        let mut out = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(self.f32()?);
        }
        Ok(out)
    }

    fn packed_u32s(&mut self) -> Result<Vec<u32>, String> {
        let width = self.u8()? as u32;
        if width > 32 {
            return Err(format!("packed index width {width} exceeds 32 bits"));
        }
        let n = self.varint()? as usize;
        if width == 0 {
            // zero-width: every value is 0 and no bits follow; cap the
            // count by the frame size to bound the allocation
            if n > self.buf.len().saturating_mul(8).max(1 << 16) {
                return Err("zero-width section claims an implausible count".into());
            }
            return Ok(vec![0; n]);
        }
        let need = n
            .checked_mul(width as usize)
            .map(|bits| bits.div_ceil(8))
            .ok_or("packed section length overflows")?;
        let bytes = self.take(need)?;
        let mask = if width == 32 { u64::MAX >> 32 } else { (1u64 << width) - 1 };
        let mut out = Vec::with_capacity(n);
        let mut acc: u64 = 0;
        let mut bits: u32 = 0;
        let mut iter = bytes.iter();
        for _ in 0..n {
            while bits < width {
                acc |= u64::from(*iter.next().expect("sized above")) << bits;
                bits += 8;
            }
            out.push((acc & mask) as u32);
            acc >>= width;
            bits -= width;
        }
        Ok(out)
    }

    fn finish(&self) -> Result<(), String> {
        if self.pos != self.buf.len() {
            return Err(format!("{} trailing bytes after the message", self.buf.len() - self.pos));
        }
        Ok(())
    }
}

// ---- encoders ---------------------------------------------------------

/// Wrap a pre-serialized JSON line in a [`TAG_JSON`] envelope.
pub fn encode_json(line: &str) -> Vec<u8> {
    let mut out = Vec::with_capacity(1 + line.len());
    out.push(TAG_JSON);
    out.extend_from_slice(line.as_bytes());
    out
}

/// Encode a `problem` broadcast.
pub fn encode_problem(id: u64, vecs: &[f32], targets: &[f32], times: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(9 + 4 * (vecs.len() + targets.len() + times.len()) + 15);
    out.push(TAG_BCAST_PROBLEM);
    out.extend_from_slice(&id.to_le_bytes());
    put_f32s(&mut out, vecs);
    put_f32s(&mut out, targets);
    put_f32s(&mut out, times);
    out
}

/// Encode a `targets` broadcast.
pub fn encode_targets(id: u64, targets: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(14 + 4 * targets.len());
    out.push(TAG_BCAST_TARGETS);
    out.extend_from_slice(&id.to_le_bytes());
    put_f32s(&mut out, targets);
    out
}

/// Encode a `shard` broadcast (packed indices + raw manifold copy).
pub fn encode_shard(id: u64, shard: &TableShard) -> Vec<u8> {
    let (neighbors, vecs) = shard.raw_parts();
    let mut out = Vec::with_capacity(64 + 3 * neighbors.len() + 4 * vecs.len());
    out.push(TAG_BCAST_SHARD);
    out.extend_from_slice(&id.to_le_bytes());
    for v in [shard.shard_id, shard.row_lo, shard.row_hi, shard.row_len(), shard.n, shard.t0] {
        put_varint(&mut out, v as u64);
    }
    put_packed_u32s(&mut out, neighbors);
    put_f32s(&mut out, vecs);
    out
}

/// Encode a `result` carrying prediction rows.
pub fn encode_result_preds(task: u64, rho: Option<f32>, preds: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(17 + 4 * preds.len());
    out.push(TAG_RESULT_PREDS);
    put_varint(&mut out, task);
    match rho {
        Some(r) => {
            out.push(1);
            out.extend_from_slice(&r.to_le_bytes());
        }
        None => out.push(0),
    }
    put_f32s(&mut out, preds);
    out
}

/// Encode a `result` carrying partial Pearson sums (bit-exact f64).
pub fn encode_result_sums(task: u64, sums: &PearsonSums) -> Vec<u8> {
    let mut out = Vec::with_capacity(64);
    out.push(TAG_RESULT_SUMS);
    put_varint(&mut out, task);
    put_varint(&mut out, sums.n);
    for v in [sums.sx, sums.sy, sums.sxy, sums.sxx, sums.syy] {
        out.extend_from_slice(&v.to_le_bytes());
    }
    out
}

/// Worker-side reply encoding: payload-bearing `result` replies get their
/// binary tag (preds/sums arrays as raw bytes, skipping float→text
/// formatting entirely — the encode-time win the v6 wire exists for);
/// everything else (pong, error, results with neither array) rides a
/// [`TAG_JSON`] envelope.
pub fn reply_frame(reply: &Json) -> Vec<u8> {
    if reply.get("type").and_then(Json::as_str) == Some("result") {
        if let Some(task) = reply.get("task").and_then(Json::as_f64) {
            let task = task as u64;
            if let Some(preds) = reply.get("preds").and_then(Json::as_f32s) {
                let rho = reply.get("rho").and_then(Json::as_f64).map(|r| r as f32);
                return encode_result_preds(task, rho, &preds);
            }
            if let Some(sums) = reply.get("sums").and_then(sums_from_json) {
                return encode_result_sums(task, &sums);
            }
        }
    }
    encode_json(&reply.to_string())
}

fn sums_from_json(v: &Json) -> Option<PearsonSums> {
    let arr = v.as_arr()?;
    if arr.len() != 6 {
        return None;
    }
    let f = |i: usize| arr[i].as_f64();
    Some(PearsonSums {
        n: f(0)? as u64,
        sx: f(1)?,
        sy: f(2)?,
        sxy: f(3)?,
        sxx: f(4)?,
        syy: f(5)?,
    })
}

/// Driver-side lowering: turn a decoded frame into the exact JSON shape
/// the scheduler already consumes, so the lease/retry/result machinery
/// never sees the wire mode. Broadcast frames never flow worker→driver —
/// one arriving is a protocol error, not a panic.
pub fn to_json(msg: BinMsg) -> Result<Json, String> {
    match msg {
        BinMsg::Json(m) => Ok(m),
        BinMsg::ResultPreds { task, rho, preds } => {
            let mut fields = vec![
                ("type", Json::Str("result".into())),
                ("task", Json::Num(task as f64)),
            ];
            if let Some(r) = rho {
                fields.push(("rho", Json::Num(r as f64)));
            }
            fields.push(("preds", Json::f32s(&preds)));
            Ok(Json::obj(fields))
        }
        BinMsg::ResultSums { task, sums } => Ok(Json::obj(vec![
            ("type", Json::Str("result".into())),
            ("task", Json::Num(task as f64)),
            (
                "sums",
                Json::Arr(vec![
                    Json::Num(sums.n as f64),
                    Json::Num(sums.sx),
                    Json::Num(sums.sy),
                    Json::Num(sums.sxy),
                    Json::Num(sums.sxx),
                    Json::Num(sums.syy),
                ]),
            ),
        ])),
        BinMsg::Broadcast(_) => Err("unexpected broadcast frame from a worker".into()),
    }
}

// ---- decoder ----------------------------------------------------------

/// Decode one frame body. Strict: unknown tags, truncated sections, and
/// trailing bytes are all errors (never panics).
pub fn decode(frame: &[u8]) -> Result<BinMsg, String> {
    let (&tag, payload) = frame.split_first().ok_or("empty frame")?;
    let mut r = Reader::new(payload);
    match tag {
        TAG_JSON => {
            let text = std::str::from_utf8(payload)
                .map_err(|e| format!("non-UTF-8 JSON envelope: {e}"))?;
            Json::parse(text).map(BinMsg::Json).map_err(|e| e.to_string())
        }
        TAG_BCAST_PROBLEM => {
            let id = r.u64_raw()?;
            let vecs = r.f32s()?;
            let targets = r.f32s()?;
            let times = r.f32s()?;
            r.finish()?;
            Ok(BinMsg::Broadcast(Broadcast::Problem { id, vecs, targets, times }))
        }
        TAG_BCAST_TARGETS => {
            let id = r.u64_raw()?;
            let targets = r.f32s()?;
            r.finish()?;
            Ok(BinMsg::Broadcast(Broadcast::Targets { id, targets }))
        }
        TAG_BCAST_SHARD => {
            let id = r.u64_raw()?;
            let shard_id = r.varint()? as usize;
            let row_lo = r.varint()? as usize;
            let row_hi = r.varint()? as usize;
            let row_len = r.varint()? as usize;
            let n = r.varint()? as usize;
            let t0 = r.varint()? as usize;
            let neighbors = r.packed_u32s()?;
            let vecs = r.f32s()?;
            r.finish()?;
            // from_parts asserts shape; validate here so corruption that
            // survived the checksum odds still errors instead of panicking
            if row_hi < row_lo
                || neighbors.len() != (row_hi - row_lo) * row_len
                || vecs.len() != n * crate::EMAX
            {
                return Err("shard sections disagree with the header".into());
            }
            let shard = TableShard::from_parts(shard_id, row_lo, row_hi, row_len, n, t0, neighbors, vecs);
            Ok(BinMsg::Broadcast(Broadcast::Shard { id, shard }))
        }
        TAG_RESULT_PREDS => {
            let task = r.varint()?;
            let rho = match r.u8()? {
                0 => None,
                1 => Some(r.f32()?),
                f => return Err(format!("bad rho flag {f}")),
            };
            let preds = r.f32s()?;
            r.finish()?;
            Ok(BinMsg::ResultPreds { task, rho, preds })
        }
        TAG_RESULT_SUMS => {
            let task = r.varint()?;
            let n = r.varint()?;
            let sums = PearsonSums {
                n,
                sx: r.f64()?,
                sy: r.f64()?,
                sxy: r.f64()?,
                sxx: r.f64()?,
                syy: r.f64()?,
            };
            r.finish()?;
            Ok(BinMsg::ResultSums { task, sums })
        }
        other => Err(format!("unknown frame tag 0x{other:02x}")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ccm::embedding::Embedding;
    use crate::ccm::table::DistanceTable;

    fn weird_f32s() -> Vec<f32> {
        vec![
            0.0,
            -0.0,
            1.0,
            -1.5e-7,
            f32::from_bits(0x7fc0_0001), // quiet NaN with payload
            f32::from_bits(0x7f80_0001), // signaling NaN bit pattern
            f32::from_bits(0xffc0_dead), // negative NaN with payload
            f32::INFINITY,
            f32::NEG_INFINITY,
            f32::MIN_POSITIVE,
            1.0e-40, // subnormal
            f32::MAX,
            3.14159265,
        ]
    }

    fn bits(xs: &[f32]) -> Vec<u32> {
        xs.iter().map(|x| x.to_bits()).collect()
    }

    #[test]
    fn varints_round_trip_at_every_boundary() {
        for v in [0u64, 1, 127, 128, 129, 16383, 16384, u32::MAX as u64, u64::MAX - 1, u64::MAX] {
            let mut buf = Vec::new();
            put_varint(&mut buf, v);
            let mut r = Reader::new(&buf);
            assert_eq!(r.varint().unwrap(), v);
            r.finish().unwrap();
        }
        // an overlong varint that would overflow u64 is an error
        let overflow = [0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0xff, 0x7f];
        assert!(Reader::new(&overflow).varint().is_err());
    }

    #[test]
    fn packed_indices_round_trip_all_widths() {
        for xs in [
            vec![],
            vec![0u32],
            vec![0, 0, 0],
            vec![1, 0, 1, 1, 0, 0, 1],
            vec![255, 17, 0, 254],
            vec![1023, 512, 7],
            (0..300).map(|i| i * 7919 % 100_000).collect::<Vec<u32>>(),
            vec![u32::MAX, 0, 12345],
        ] {
            let mut buf = Vec::new();
            put_packed_u32s(&mut buf, &xs);
            let mut r = Reader::new(&buf);
            assert_eq!(r.packed_u32s().unwrap(), xs, "width case {xs:?}");
            r.finish().unwrap();
        }
    }

    #[test]
    fn packed_indices_beat_raw_u32_for_bounded_values() {
        // the shard-table case: 10k indices bounded by n=1000 pack to 10
        // bits each — the reason shard ships shrink at all
        let xs: Vec<u32> = (0..10_000u32).map(|i| i % 1000).collect();
        let mut buf = Vec::new();
        put_packed_u32s(&mut buf, &xs);
        assert!(buf.len() < xs.len() * 2, "10-bit packing: {} bytes", buf.len());
    }

    #[test]
    fn f32_arrays_round_trip_bit_exact_including_nans() {
        let xs = weird_f32s();
        let mut buf = Vec::new();
        put_f32s(&mut buf, &xs);
        let mut r = Reader::new(&buf);
        let back = r.f32s().unwrap();
        r.finish().unwrap();
        assert_eq!(bits(&back), bits(&xs), "every bit pattern survives, incl. NaN payloads");
    }

    #[test]
    fn problem_and_targets_broadcasts_round_trip() {
        let vecs = weird_f32s();
        let targets = vec![0.25f32, -0.0, f32::from_bits(0x7fc0_0042)];
        let times = vec![0.0f32, 1.0, 2.0];
        let msg = decode(&encode_problem(0xdead_beef_cafe_f00d, &vecs, &targets, &times)).unwrap();
        match msg {
            BinMsg::Broadcast(Broadcast::Problem { id, vecs: v, targets: tg, times: tm }) => {
                assert_eq!(id, 0xdead_beef_cafe_f00d);
                assert_eq!(bits(&v), bits(&vecs));
                assert_eq!(bits(&tg), bits(&targets));
                assert_eq!(bits(&tm), bits(&times));
            }
            _ => panic!("wrong variant"),
        }
        match decode(&encode_targets(7, &targets)).unwrap() {
            BinMsg::Broadcast(Broadcast::Targets { id, targets: tg }) => {
                assert_eq!(id, 7);
                assert_eq!(bits(&tg), bits(&targets));
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn shard_broadcast_round_trips_with_identical_wire_id() {
        let series: Vec<f32> = (0..64).map(|i| ((i * 37) % 64) as f32 / 64.0).collect();
        let emb = Embedding::new(&series, 2, 1);
        let sharded = DistanceTable::build_truncated(&emb, 9).shard(2);
        for shard in sharded.shards() {
            let frame = encode_shard(shard.wire_id(), shard);
            match decode(&frame).unwrap() {
                BinMsg::Broadcast(Broadcast::Shard { id, shard: back }) => {
                    assert_eq!(id, shard.wire_id());
                    assert_eq!(back.wire_id(), shard.wire_id(), "content identity preserved");
                    assert_eq!(back.num_rows(), shard.num_rows());
                }
                _ => panic!("wrong variant"),
            }
        }
    }

    #[test]
    fn results_round_trip_bit_exact() {
        let preds = weird_f32s();
        let rho = f32::from_bits(0x8000_0000); // -0.0
        match decode(&encode_result_preds(900, Some(rho), &preds)).unwrap() {
            BinMsg::ResultPreds { task, rho: Some(r), preds: p } => {
                assert_eq!(task, 900);
                assert_eq!(r.to_bits(), rho.to_bits());
                assert_eq!(bits(&p), bits(&preds));
            }
            _ => panic!("wrong variant"),
        }
        match decode(&encode_result_preds(1, None, &[])).unwrap() {
            BinMsg::ResultPreds { task: 1, rho: None, preds } => assert!(preds.is_empty()),
            _ => panic!("wrong variant"),
        }
        let sums = PearsonSums {
            n: u64::MAX >> 8,
            sx: -0.0,
            sy: f64::NAN,
            sxy: 1.0000000000000002,
            sxx: f64::MIN_POSITIVE,
            syy: -1.7976931348623157e308,
        };
        match decode(&encode_result_sums(42, &sums)).unwrap() {
            BinMsg::ResultSums { task, sums: s } => {
                assert_eq!(task, 42);
                assert_eq!(s.n, sums.n);
                for (a, b) in [
                    (s.sx, sums.sx),
                    (s.sy, sums.sy),
                    (s.sxy, sums.sxy),
                    (s.sxx, sums.sxx),
                    (s.syy, sums.syy),
                ] {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn json_envelope_round_trips() {
        let line = r#"{"op":"cross_map","type":"task","v":6}"#;
        match decode(&encode_json(line)).unwrap() {
            BinMsg::Json(msg) => {
                assert_eq!(msg.get("op").and_then(Json::as_str), Some("cross_map"));
                assert_eq!(msg.to_string(), line, "envelope preserves the exact line");
            }
            _ => panic!("wrong variant"),
        }
    }

    #[test]
    fn serve_control_envelopes_round_trip_unchanged() {
        // v7 serve-mode control messages are plain JSON envelopes: the
        // binary framing carries them byte-for-byte, no new tags needed.
        for line in [
            r#"{"spec":{"case":"a1","shards":2},"type":"submit"}"#,
            r#"{"job":3,"type":"status"}"#,
            r#"{"job":3,"type":"fetch"}"#,
            r#"{"job":7,"type":"cancel"}"#,
        ] {
            let frame = encode_json(line);
            assert_eq!(frame[0], TAG_JSON);
            match decode(&frame).unwrap() {
                BinMsg::Json(msg) => {
                    assert_eq!(msg.to_string(), line, "control line survives framing");
                }
                _ => panic!("wrong variant"),
            }
        }
    }

    #[test]
    fn reply_frames_lower_back_to_the_same_json() {
        // a cross_map result: binary tag, preds bit-exact (incl. -0.0,
        // which JSON text cannot even represent)
        let reply = Json::obj(vec![
            ("type", Json::Str("result".into())),
            ("task", Json::Num(31.0)),
            ("rho", Json::Num(0.5)),
            ("preds", Json::f32s(&[1.0, -0.0, 2.5])),
        ]);
        let frame = reply_frame(&reply);
        assert_eq!(frame[0], TAG_RESULT_PREDS);
        let back = to_json(decode(&frame).unwrap()).unwrap();
        assert_eq!(back.get("task").and_then(Json::as_f64), Some(31.0));
        assert_eq!(back.get("rho").and_then(Json::as_f64), Some(0.5));
        let preds = back.get("preds").and_then(Json::as_f32s).unwrap();
        assert_eq!(bits(&preds), bits(&[1.0, -0.0, 2.5]));

        // an agg_chunk result: sums tag, all six values bit-exact
        let sums = Json::obj(vec![
            ("type", Json::Str("result".into())),
            ("task", Json::Num(8.0)),
            (
                "sums",
                Json::Arr(vec![
                    Json::Num(10.0),
                    Json::Num(0.1 + 0.2),
                    Json::Num(-1.0e-300),
                    Json::Num(std::f64::consts::PI),
                    Json::Num(4.9e-324),
                    Json::Num(1.0e300),
                ]),
            ),
        ]);
        let frame = reply_frame(&sums);
        assert_eq!(frame[0], TAG_RESULT_SUMS);
        let back = to_json(decode(&frame).unwrap()).unwrap();
        assert_eq!(back.to_string(), sums.to_string(), "sums survive bit-for-bit");

        // control replies ride the JSON envelope unchanged
        let pong = Json::obj(vec![
            ("type", Json::Str("pong".into())),
            ("nonce", Json::Num(4.0)),
        ]);
        let frame = reply_frame(&pong);
        assert_eq!(frame[0], TAG_JSON);
        let back = to_json(decode(&frame).unwrap()).unwrap();
        assert_eq!(back.to_string(), pong.to_string());
    }

    #[test]
    fn malformed_frames_error_instead_of_panicking() {
        assert!(decode(&[]).is_err(), "empty frame");
        assert!(decode(&[0xee]).is_err(), "unknown tag");
        assert!(decode(&[TAG_JSON, 0xff, 0xfe]).is_err(), "non-UTF-8 envelope");
        assert!(decode(&[TAG_JSON, b'{']).is_err(), "bad JSON");
        // truncate a valid frame at every length — all errors, no panics
        let frame = encode_result_preds(3, Some(0.5), &[1.0, 2.0, 3.0]);
        for cut in 0..frame.len() {
            assert!(decode(&frame[..cut]).is_err(), "truncated at {cut}");
        }
        // a section length that overstates the remaining bytes
        let mut lying = encode_targets(1, &[1.0]);
        lying[9] = 0x7f; // claim 127 f32s where 1 follows
        assert!(decode(&lying).is_err());
        // trailing garbage after a complete message
        let mut trailing = encode_targets(1, &[1.0]);
        trailing.push(0);
        assert!(decode(&trailing).is_err());
    }
}
