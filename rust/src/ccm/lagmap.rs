//! Cross-map lag analysis (Ye et al. 2015, "Distinguishing time-delayed
//! causal interactions using convergent cross mapping") — an extension
//! the CCM literature layers on the same machinery: cross-map skill as a
//! function of the *lag* between cause and effect. For a true causal link
//! X -> Y with interaction delay d, skill peaks at a *negative* lag
//! (the effect's manifold best reconstructs the cause's past); a peak at
//! positive lags flags the non-causal direction.

use std::sync::Arc;

use crate::ccm::backend::{ComputeBackend, TaskArena};
use crate::ccm::params::CcmParams;
use crate::ccm::pipeline::CcmProblem;
use crate::ccm::subsample::draw_samples;
use crate::util::rng::Rng;

/// Skill at each tested lag.
#[derive(Clone, Debug)]
pub struct LagProfile {
    /// (lag, mean rho) — lag < 0 means predicting the cause `|lag|` steps
    /// *before* the effect's observation time.
    pub skills: Vec<(i64, f64)>,
    /// Lag with maximal skill.
    pub best_lag: i64,
    pub best_rho: f64,
}

/// Shift `cause` by `lag` relative to `effect` (positive lag: cause's
/// future; negative: cause's past), truncating both to the overlap.
fn shift(effect: &[f32], cause: &[f32], lag: i64) -> (Vec<f32>, Vec<f32>) {
    let n = effect.len().min(cause.len()) as i64;
    if lag >= 0 {
        let m = (n - lag).max(0) as usize;
        (effect[..m].to_vec(), cause[lag as usize..lag as usize + m].to_vec())
    } else {
        let s = (-lag) as usize;
        let m = (n - (-lag)).max(0) as usize;
        (effect[s..s + m].to_vec(), cause[..m].to_vec())
    }
}

/// Cross-map `cause` from `effect`'s manifold at every lag in
/// `[-max_lag, +max_lag]`, averaging `r` library draws of size `l`.
#[allow(clippy::too_many_arguments)]
pub fn lag_profile(
    effect: &[f32],
    cause: &[f32],
    params: CcmParams,
    r: usize,
    theiler: f32,
    max_lag: usize,
    seed: u64,
    backend: Arc<dyn ComputeBackend>,
) -> LagProfile {
    let mut skills = Vec::new();
    let mut arena = TaskArena::new();
    for lag in -(max_lag as i64)..=(max_lag as i64) {
        let (eff, cau) = shift(effect, cause, lag);
        if eff.len() < params.l / 2 + (params.e - 1) * params.tau + 2 {
            continue;
        }
        let problem = CcmProblem::new(&eff, &cau, params.e, params.tau, theiler);
        let master = Rng::new(seed ^ (lag as u64).wrapping_mul(0x9E3779B97F4A7C15));
        let mut p = params;
        p.l = p.l.min(problem.emb.n);
        let samples = draw_samples(&master, p, problem.emb.n, r);
        let mean = samples
            .iter()
            .map(|s| backend.cross_map_into(&problem.input_for(s), &mut arena) as f64)
            .sum::<f64>()
            / r.max(1) as f64;
        skills.push((lag, mean));
    }
    let (best_lag, best_rho) = skills
        .iter()
        .copied()
        .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        .unwrap_or((0, f64::NAN));
    LagProfile { skills, best_lag, best_rho }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::native::NativeBackend;
    use crate::timeseries::generators::{coupled_logistic, CoupledLogisticParams};

    #[test]
    fn shift_overlap_is_consistent() {
        let e: Vec<f32> = (0..10).map(|i| i as f32).collect();
        let c: Vec<f32> = (0..10).map(|i| (i * 10) as f32).collect();
        let (e2, c2) = shift(&e, &c, 3);
        assert_eq!(e2, (0..7).map(|i| i as f32).collect::<Vec<_>>());
        assert_eq!(c2, (3..10).map(|i| (i * 10) as f32).collect::<Vec<_>>());
        let (e3, c3) = shift(&e, &c, -2);
        assert_eq!(e3, (2..10).map(|i| i as f32).collect::<Vec<_>>());
        assert_eq!(c3, (0..8).map(|i| (i * 10) as f32).collect::<Vec<_>>());
        let (e0, c0) = shift(&e, &c, 0);
        assert_eq!((e0.len(), c0.len()), (10, 10));
    }

    #[test]
    fn delayed_coupling_peaks_at_negative_lag() {
        // Build a system where Y is driven by X delayed by 2 steps:
        // generate standard coupling, then delay the recorded X.
        let (x, y) = coupled_logistic(
            800,
            CoupledLogisticParams { bxy: 0.0, byx: 0.3, ..Default::default() },
        );
        let delay = 2usize;
        // Y responds to X at time t; if we *record* X late (x_obs[t] =
        // x[t - delay]), the cross-map from M_Y should peak when asking
        // for X's past at lag = ... verify the peak moves by `delay`.
        let x_obs: Vec<f32> = (0..x.len())
            .map(|t| if t >= delay { x[t - delay] } else { x[0] })
            .collect();
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend);
        let base = lag_profile(
            &y,
            &x,
            CcmParams::new(2, 1, 300),
            4,
            0.0,
            4,
            9,
            Arc::clone(&backend),
        );
        let delayed = lag_profile(&y, &x_obs, CcmParams::new(2, 1, 300), 4, 0.0, 4, 9, backend);
        assert_eq!(
            delayed.best_lag - base.best_lag,
            delay as i64,
            "recording X {delay} steps late must shift the skill peak by +{delay}: base {:?} delayed {:?}",
            base.skills,
            delayed.skills
        );
        assert!(delayed.best_rho > 0.7);
    }
}
