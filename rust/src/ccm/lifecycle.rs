//! Worker lifecycle: where a pool's workers come from and what their
//! death means — split out of the scheduler so `ccm::cluster` can stay a
//! pure scheduling layer.
//!
//! Two sources exist:
//!
//! * [`WorkerSource::Fork`] — the pool spawns children of a binary
//!   (`parccm worker`, over pipe or TCP loopback) and *owns* their
//!   lifecycle: a dead worker is reaped and a fresh child respawned in
//!   its place, so the pool width is an invariant.
//! * [`WorkerSource::Remote`] — the pool dials pre-started
//!   `parccm worker --listen HOST:PORT` processes named by
//!   `--workers-at host:port,...` (or the [`WORKERS_ENV`] fallback). The
//!   driver does not own those processes: a dead remote cannot be
//!   respawned, so its death permanently shrinks the pool and the
//!   scheduler must requeue onto survivors (and eagerly restore the
//!   replication factor there). The pool width *is* the address list.
//!
//! The scheduler asks exactly two questions: [`WorkerSource::connect`]
//! (make me worker `slot`) and [`WorkerSource::can_respawn`] (is death
//! repairable?) — everything else about scheduling, replication, and
//! requeueing is source-agnostic.
//!
//! A remote death is no longer necessarily final: [`RejoinPolicy`] keeps
//! every dead address on a clock-injected exponential-backoff redial
//! schedule (`--rejoin-backoff-secs`), so a restarted
//! `parccm worker --listen` on the same host:port can re-register with a
//! live driver. The policy is a pure state machine — every method takes
//! `now` explicitly, so the cadence is unit-testable without sockets or
//! real sleeps; the actual redialing lives in the cluster runtime's
//! maintenance thread.
//!
//! Every admit — initial connect, respawn, rejoin — runs the full hello
//! handshake, so the wire mode is renegotiated per connection: a worker
//! that rejoins after upgrading (or downgrading) its binary may land on a
//! different negotiated version than it had before, including switching
//! between the v6 binary frames and the legacy JSON line wire. Wire mode
//! is connection state, never pool state.

use std::collections::HashMap;
use std::path::PathBuf;
use std::time::{Duration, Instant};

use crate::ccm::transport::{connect_remote, connect_worker, Hello, TransportKind, WorkerLink};

/// Environment fallback for `--workers-at`: a comma-separated
/// `host:port,...` list of pre-started listen-mode workers.
pub const WORKERS_ENV: &str = "PARCCM_WORKERS";

/// Where the cluster pool's workers come from.
#[derive(Clone, Debug)]
pub enum WorkerSource {
    /// Spawn children of `cmd` (`parccm worker`); death -> respawn.
    Fork {
        /// Binary to spawn (`<current_exe>` in production, the
        /// `CARGO_BIN_EXE_parccm` path in tests).
        cmd: PathBuf,
    },
    /// Connect to pre-started listen-mode workers; death -> mark dead.
    Remote {
        /// `host:port` of each `parccm worker --listen` process.
        addrs: Vec<String>,
    },
}

impl WorkerSource {
    /// How wide the pool actually is: `requested` for a forking source,
    /// the address-list length for a remote one (each address is exactly
    /// one worker).
    pub fn pool_size(&self, requested: usize) -> usize {
        match self {
            WorkerSource::Fork { .. } => requested.max(1),
            WorkerSource::Remote { addrs } => addrs.len(),
        }
    }

    /// Whether a dead worker can be replaced by this source.
    pub fn can_respawn(&self) -> bool {
        matches!(self, WorkerSource::Fork { .. })
    }

    /// Whether this source reaches pre-started remote workers.
    pub fn is_remote(&self) -> bool {
        matches!(self, WorkerSource::Remote { .. })
    }

    /// Address of remote pool slot `slot` (`None` for fork sources or
    /// out-of-range slots) — what the rejoin redialer dials.
    pub fn remote_addr(&self, slot: usize) -> Option<&str> {
        match self {
            WorkerSource::Remote { addrs } => addrs.get(slot).map(String::as_str),
            WorkerSource::Fork { .. } => None,
        }
    }

    /// Establish the connection for pool slot `slot` (respawns pass the
    /// slot of the worker being replaced; only remote sources care, and
    /// they never respawn).
    pub fn connect(
        &self,
        slot: usize,
        kind: TransportKind,
        extra_env: &[(String, String)],
        auth: Option<&str>,
    ) -> std::io::Result<(WorkerLink, Hello)> {
        match self {
            WorkerSource::Fork { cmd } => connect_worker(cmd, kind, extra_env, auth),
            WorkerSource::Remote { addrs } => {
                let addr = addrs.get(slot).ok_or_else(|| {
                    std::io::Error::new(
                        std::io::ErrorKind::InvalidInput,
                        format!("no remote worker address for slot {slot}"),
                    )
                })?;
                connect_remote(addr, auth)
            }
        }
    }

    /// Human-readable description for startup logs.
    pub fn describe(&self) -> String {
        match self {
            WorkerSource::Fork { cmd } => format!("fork {}", cmd.display()),
            WorkerSource::Remote { addrs } => format!("remote [{}]", addrs.join(", ")),
        }
    }
}

/// Parse a `--workers-at` value: comma-separated `host:port` entries,
/// whitespace-tolerant, empties dropped.
pub fn parse_workers_at(s: &str) -> Vec<String> {
    s.split(',')
        .map(str::trim)
        .filter(|a| !a.is_empty())
        .map(str::to_string)
        .collect()
}

/// The [`WORKERS_ENV`] fallback for `--workers-at`; `None` when unset or
/// empty.
pub fn workers_at_from_env() -> Option<Vec<String>> {
    let addrs = parse_workers_at(&std::env::var(WORKERS_ENV).ok()?);
    if addrs.is_empty() {
        None
    } else {
        Some(addrs)
    }
}

/// Ceiling on the rejoin redial delay: however many redials have failed,
/// a dead address is retried at least this often.
pub const DEFAULT_REJOIN_CAP: Duration = Duration::from_secs(60);

/// Pure exponential-backoff step shared by the rejoin redial schedule
/// and the cluster scheduler's task-retry loop: the delay after
/// `attempt` consecutive failures is `base * 2^attempt`, saturating and
/// capped at `cap`. The shift is clamped so large attempt counts cannot
/// overflow the multiplier.
pub fn exp_backoff(base: Duration, attempt: u32, cap: Duration) -> Duration {
    base.saturating_mul(1u32 << attempt.min(16)).min(cap)
}

/// Redial state of one dead remote pool slot.
#[derive(Clone, Debug)]
enum RejoinSlot {
    /// Scheduled for a redial at `due`; `attempt` redials have failed
    /// since the death.
    Waiting { due: Instant, attempt: u32 },
    /// The rejoin handshake was auth-rejected: the address is retired
    /// for the life of the pool (no hot redial loop against a
    /// misconfigured worker).
    Rejected,
}

/// Exponential-backoff redial schedule for dead remote workers — the
/// pure half of reconnect/rejoin.
///
/// A death schedules the slot's first redial one `base` after `now`;
/// each failed redial doubles the delay up to `cap`; a success clears
/// the slot entirely (the *next* death starts over at `base`); an auth
/// rejection retires the slot permanently. A zero `base` disables the
/// policy (`--rejoin-backoff-secs 0`).
///
/// Every method takes `now: Instant` — the clock is injected, so the
/// whole cadence is unit-tested with synthetic instants and no sleeps.
/// Thread-safety and the actual dialing are the caller's problem (the
/// cluster runtime wraps this in a mutex and redials from its
/// maintenance thread).
#[derive(Clone, Debug)]
pub struct RejoinPolicy {
    base: Duration,
    cap: Duration,
    slots: HashMap<usize, RejoinSlot>,
}

impl RejoinPolicy {
    /// Policy with the default delay ceiling ([`DEFAULT_REJOIN_CAP`]).
    /// `base` zero = disabled.
    pub fn new(base: Duration) -> RejoinPolicy {
        Self::with_cap(base, DEFAULT_REJOIN_CAP)
    }

    /// Policy with an explicit delay ceiling (clamped to at least
    /// `base`).
    pub fn with_cap(base: Duration, cap: Duration) -> RejoinPolicy {
        RejoinPolicy { base, cap: cap.max(base), slots: HashMap::new() }
    }

    /// Whether rejoin is on at all (`base > 0`).
    pub fn enabled(&self) -> bool {
        !self.base.is_zero()
    }

    /// A remote worker at `slot` died: schedule its first redial one
    /// `base` from `now`. No-op when disabled or the slot was retired by
    /// an auth rejection.
    pub fn note_death(&mut self, slot: usize, now: Instant) {
        if !self.enabled() || matches!(self.slots.get(&slot), Some(RejoinSlot::Rejected)) {
            return;
        }
        self.slots
            .insert(slot, RejoinSlot::Waiting { due: now + self.base, attempt: 0 });
    }

    /// Slots whose backoff has elapsed at `now` (sorted, so redial order
    /// is deterministic).
    pub fn due_slots(&self, now: Instant) -> Vec<usize> {
        let mut due: Vec<usize> = self
            .slots
            .iter()
            .filter(|(_, s)| matches!(s, RejoinSlot::Waiting { due, .. } if *due <= now))
            .map(|(&slot, _)| slot)
            .collect();
        due.sort_unstable();
        due
    }

    /// A redial of `slot` failed: double the delay (capped) and
    /// reschedule from `now`.
    pub fn note_failure(&mut self, slot: usize, now: Instant) {
        let base = self.base;
        let cap = self.cap;
        if let Some(RejoinSlot::Waiting { due, attempt }) = self.slots.get_mut(&slot) {
            *attempt += 1;
            *due = now + exp_backoff(base, *attempt, cap);
        }
    }

    /// A redial of `slot` completed its handshake: clear the slot so a
    /// later death starts back at the base delay (reset-on-success).
    pub fn note_success(&mut self, slot: usize) {
        self.slots.remove(&slot);
    }

    /// The rejoin handshake for `slot` was auth-rejected: retire the
    /// address permanently.
    pub fn note_rejected(&mut self, slot: usize) {
        self.slots.insert(slot, RejoinSlot::Rejected);
    }

    /// Whether `slot` has been permanently retired.
    pub fn is_rejected(&self, slot: usize) -> bool {
        matches!(self.slots.get(&slot), Some(RejoinSlot::Rejected))
    }

    /// Slots still scheduled for a redial (a non-zero count means an
    /// empty pool may yet regrow, so the scheduler waits instead of
    /// aborting).
    pub fn pending(&self) -> usize {
        self.slots
            .values()
            .filter(|s| matches!(s, RejoinSlot::Waiting { .. }))
            .count()
    }

    /// Slots permanently retired by an auth rejection.
    pub fn rejected(&self) -> usize {
        self.slots
            .values()
            .filter(|s| matches!(s, RejoinSlot::Rejected))
            .count()
    }
}

/// Pool lifetime across job boundaries in serve mode — the pure half of
/// `parccm serve`'s "the pool outlives every job" invariant. A batch run
/// tears its pool down at exit; a serve daemon instead keeps one
/// [`crate::ccm::cluster::ClusterBackend`] warm for its whole life and
/// threads every job through it, so this tracker only needs to answer:
/// how many jobs are on the pool right now, how many has it served, and
/// how long has it been idle (the input a future idle-scale-down policy
/// would read).
///
/// Same design as [`RejoinPolicy`]: every method takes `now: Instant`, so
/// the whole cadence is unit-tested with synthetic instants and no
/// sleeps; thread-safety is the caller's problem (the serve job tracker
/// wraps it in its own mutex).
#[derive(Clone, Debug)]
pub struct ServeLifecycle {
    active: usize,
    served: u64,
    /// When the pool last went idle (set at construction and every time
    /// the active count returns to zero).
    idle_since: Instant,
}

impl ServeLifecycle {
    /// A freshly-warmed pool with no jobs yet, idle since `now`.
    pub fn new(now: Instant) -> ServeLifecycle {
        ServeLifecycle { active: 0, served: 0, idle_since: now }
    }

    /// A job started computing on the pool.
    pub fn note_job_start(&mut self, _now: Instant) {
        self.active += 1;
    }

    /// A job left the pool (done, failed, or cancelled mid-queue after a
    /// start was noted — callers pair every start with exactly one end).
    pub fn note_job_end(&mut self, now: Instant) {
        debug_assert!(self.active > 0, "job end without a matching start");
        self.active = self.active.saturating_sub(1);
        self.served += 1;
        if self.active == 0 {
            self.idle_since = now;
        }
    }

    /// Jobs currently computing on the pool.
    pub fn active_jobs(&self) -> usize {
        self.active
    }

    /// Jobs the pool has finished over its lifetime (any terminal state).
    pub fn jobs_served(&self) -> u64 {
        self.served
    }

    /// How long the pool has been idle at `now` (`None` while any job is
    /// active).
    pub fn idle_for(&self, now: Instant) -> Option<Duration> {
        if self.active == 0 {
            Some(now.saturating_duration_since(self.idle_since))
        } else {
            None
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_workers_at_lists() {
        assert_eq!(
            parse_workers_at("a:1, b:2 ,,c:3,"),
            vec!["a:1".to_string(), "b:2".to_string(), "c:3".to_string()]
        );
        assert!(parse_workers_at("  ").is_empty());
    }

    #[test]
    fn pool_size_follows_the_source() {
        let fork = WorkerSource::Fork { cmd: PathBuf::from("parccm") };
        assert_eq!(fork.pool_size(3), 3);
        assert_eq!(fork.pool_size(0), 1, "fork pools are never empty");
        assert!(fork.can_respawn());
        assert!(!fork.is_remote());
        let remote =
            WorkerSource::Remote { addrs: vec!["h:1".into(), "h:2".into()] };
        assert_eq!(remote.pool_size(9), 2, "remote pool width IS the address list");
        assert!(!remote.can_respawn());
        assert!(remote.is_remote());
    }

    #[test]
    fn remote_connect_rejects_unknown_slot() {
        let remote = WorkerSource::Remote { addrs: vec!["127.0.0.1:1".into()] };
        let err = remote
            .connect(5, TransportKind::Tcp, &[], None)
            .expect_err("slot out of range");
        assert!(err.to_string().contains("slot 5"), "{err}");
    }

    #[test]
    fn describe_names_the_source() {
        assert!(WorkerSource::Fork { cmd: PathBuf::from("x") }.describe().contains("fork"));
        let r = WorkerSource::Remote { addrs: vec!["a:1".into(), "b:2".into()] };
        assert_eq!(r.describe(), "remote [a:1, b:2]");
    }

    #[test]
    fn remote_addr_maps_slots_to_the_address_list() {
        let r = WorkerSource::Remote { addrs: vec!["a:1".into(), "b:2".into()] };
        assert_eq!(r.remote_addr(0), Some("a:1"));
        assert_eq!(r.remote_addr(1), Some("b:2"));
        assert_eq!(r.remote_addr(2), None);
        assert_eq!(WorkerSource::Fork { cmd: PathBuf::from("x") }.remote_addr(0), None);
    }

    // ---- RejoinPolicy: clock-injected, no sockets, no sleeps ----

    const S: Duration = Duration::from_secs(1);

    #[test]
    fn exp_backoff_doubles_saturates_and_caps() {
        let cap = Duration::from_secs(8);
        assert_eq!(exp_backoff(S, 0, cap), S);
        assert_eq!(exp_backoff(S, 1, cap), 2 * S);
        assert_eq!(exp_backoff(S, 2, cap), 4 * S);
        assert_eq!(exp_backoff(S, 3, cap), cap);
        // huge attempt counts clamp the shift instead of overflowing
        assert_eq!(exp_backoff(S, 500, cap), cap);
        assert_eq!(exp_backoff(Duration::ZERO, 5, cap), Duration::ZERO);
    }

    #[test]
    fn rejoin_policy_zero_base_is_disabled() {
        let mut p = RejoinPolicy::new(Duration::ZERO);
        assert!(!p.enabled());
        let t0 = Instant::now();
        p.note_death(0, t0);
        assert_eq!(p.pending(), 0, "a disabled policy records nothing");
        assert!(p.due_slots(t0 + 100 * S).is_empty());
    }

    #[test]
    fn rejoin_policy_backoff_doubles_and_caps() {
        let t0 = Instant::now();
        let mut p = RejoinPolicy::with_cap(S, 8 * S);
        p.note_death(3, t0);
        assert!(p.due_slots(t0).is_empty(), "the first redial waits out the base delay");
        assert!(p.due_slots(t0 + S / 2).is_empty());
        assert_eq!(p.due_slots(t0 + S), vec![3]);
        // each failure doubles: base, 2, 4, 8(cap), 8(cap), ...
        p.note_failure(3, t0 + S);
        assert!(p.due_slots(t0 + 2 * S).is_empty());
        assert_eq!(p.due_slots(t0 + 3 * S), vec![3]);
        p.note_failure(3, t0 + 3 * S);
        assert!(p.due_slots(t0 + 6 * S).is_empty());
        assert_eq!(p.due_slots(t0 + 7 * S), vec![3]);
        p.note_failure(3, t0 + 7 * S);
        assert_eq!(p.due_slots(t0 + 15 * S), vec![3], "third failure waits the 8s cap");
        p.note_failure(3, t0 + 15 * S);
        assert!(p.due_slots(t0 + 22 * S).is_empty());
        assert_eq!(p.due_slots(t0 + 23 * S), vec![3], "the cap holds from here on");
        assert_eq!(p.pending(), 1);
    }

    #[test]
    fn rejoin_policy_resets_to_base_after_success() {
        let t0 = Instant::now();
        let mut p = RejoinPolicy::new(S);
        p.note_death(1, t0);
        p.note_failure(1, t0 + S);
        p.note_failure(1, t0 + 3 * S); // backoff now 4s
        p.note_success(1);
        assert_eq!(p.pending(), 0, "success clears the slot");
        // the NEXT death starts over at the base delay, not the old 4s
        p.note_death(1, t0 + 10 * S);
        assert!(p.due_slots(t0 + 10 * S).is_empty());
        assert_eq!(p.due_slots(t0 + 11 * S), vec![1]);
    }

    #[test]
    fn rejoin_policy_rejection_is_permanent() {
        let t0 = Instant::now();
        let mut p = RejoinPolicy::new(S);
        p.note_death(2, t0);
        p.note_rejected(2);
        assert!(p.is_rejected(2));
        assert_eq!(p.rejected(), 1);
        assert_eq!(p.pending(), 0);
        assert!(p.due_slots(t0 + 1000 * S).is_empty(), "never redialed again");
        // not even a fresh death resurrects a rejected address
        p.note_death(2, t0 + 5 * S);
        assert!(p.due_slots(t0 + 1000 * S).is_empty());
        assert!(p.is_rejected(2));
    }

    #[test]
    fn rejoin_policy_due_slots_are_sorted() {
        let t0 = Instant::now();
        let mut p = RejoinPolicy::new(S);
        p.note_death(9, t0);
        p.note_death(1, t0);
        p.note_death(4, t0);
        assert_eq!(p.due_slots(t0 + S), vec![1, 4, 9]);
        assert_eq!(p.pending(), 3);
    }

    // ---- ServeLifecycle: clock-injected, no threads, no sleeps ----

    #[test]
    fn serve_lifecycle_counts_jobs_across_pool_lifetime() {
        let t0 = Instant::now();
        let mut lc = ServeLifecycle::new(t0);
        assert_eq!(lc.active_jobs(), 0);
        assert_eq!(lc.jobs_served(), 0);
        assert_eq!(lc.idle_for(t0 + 3 * S), Some(3 * S), "idle since construction");
        lc.note_job_start(t0 + 3 * S);
        lc.note_job_start(t0 + 4 * S);
        assert_eq!(lc.active_jobs(), 2, "two overlapping tenants");
        assert_eq!(lc.idle_for(t0 + 5 * S), None, "not idle while jobs run");
        lc.note_job_end(t0 + 6 * S);
        assert_eq!(lc.active_jobs(), 1);
        assert_eq!(lc.jobs_served(), 1);
        assert_eq!(lc.idle_for(t0 + 7 * S), None, "one tenant still on the pool");
        lc.note_job_end(t0 + 8 * S);
        assert_eq!(lc.active_jobs(), 0);
        assert_eq!(lc.jobs_served(), 2, "the pool outlives every job it served");
        assert_eq!(lc.idle_for(t0 + 10 * S), Some(2 * S), "idle clock restarts at last end");
        // a third job on the SAME pool: serve mode never re-warms
        lc.note_job_start(t0 + 10 * S);
        lc.note_job_end(t0 + 11 * S);
        assert_eq!(lc.jobs_served(), 3);
    }
}
