//! Worker lifecycle: where a pool's workers come from and what their
//! death means — split out of the scheduler so `ccm::cluster` can stay a
//! pure scheduling layer.
//!
//! Two sources exist:
//!
//! * [`WorkerSource::Fork`] — the pool spawns children of a binary
//!   (`parccm worker`, over pipe or TCP loopback) and *owns* their
//!   lifecycle: a dead worker is reaped and a fresh child respawned in
//!   its place, so the pool width is an invariant.
//! * [`WorkerSource::Remote`] — the pool dials pre-started
//!   `parccm worker --listen HOST:PORT` processes named by
//!   `--workers-at host:port,...` (or the [`WORKERS_ENV`] fallback). The
//!   driver does not own those processes: a dead remote cannot be
//!   respawned, so its death permanently shrinks the pool and the
//!   scheduler must requeue onto survivors (and eagerly restore the
//!   replication factor there). The pool width *is* the address list.
//!
//! The scheduler asks exactly two questions: [`WorkerSource::connect`]
//! (make me worker `slot`) and [`WorkerSource::can_respawn`] (is death
//! repairable?) — everything else about scheduling, replication, and
//! requeueing is source-agnostic.

use std::path::PathBuf;

use crate::ccm::transport::{connect_remote, connect_worker, Hello, TransportKind, WorkerLink};

/// Environment fallback for `--workers-at`: a comma-separated
/// `host:port,...` list of pre-started listen-mode workers.
pub const WORKERS_ENV: &str = "PARCCM_WORKERS";

/// Where the cluster pool's workers come from.
#[derive(Clone, Debug)]
pub enum WorkerSource {
    /// Spawn children of `cmd` (`parccm worker`); death -> respawn.
    Fork {
        /// Binary to spawn (`<current_exe>` in production, the
        /// `CARGO_BIN_EXE_parccm` path in tests).
        cmd: PathBuf,
    },
    /// Connect to pre-started listen-mode workers; death -> mark dead.
    Remote {
        /// `host:port` of each `parccm worker --listen` process.
        addrs: Vec<String>,
    },
}

impl WorkerSource {
    /// How wide the pool actually is: `requested` for a forking source,
    /// the address-list length for a remote one (each address is exactly
    /// one worker).
    pub fn pool_size(&self, requested: usize) -> usize {
        match self {
            WorkerSource::Fork { .. } => requested.max(1),
            WorkerSource::Remote { addrs } => addrs.len(),
        }
    }

    /// Whether a dead worker can be replaced by this source.
    pub fn can_respawn(&self) -> bool {
        matches!(self, WorkerSource::Fork { .. })
    }

    /// Whether this source reaches pre-started remote workers.
    pub fn is_remote(&self) -> bool {
        matches!(self, WorkerSource::Remote { .. })
    }

    /// Establish the connection for pool slot `slot` (respawns pass the
    /// slot of the worker being replaced; only remote sources care, and
    /// they never respawn).
    pub fn connect(
        &self,
        slot: usize,
        kind: TransportKind,
        extra_env: &[(String, String)],
        auth: Option<&str>,
    ) -> std::io::Result<(WorkerLink, Hello)> {
        match self {
            WorkerSource::Fork { cmd } => connect_worker(cmd, kind, extra_env, auth),
            WorkerSource::Remote { addrs } => {
                let addr = addrs.get(slot).ok_or_else(|| {
                    std::io::Error::new(
                        std::io::ErrorKind::InvalidInput,
                        format!("no remote worker address for slot {slot}"),
                    )
                })?;
                connect_remote(addr, auth)
            }
        }
    }

    /// Human-readable description for startup logs.
    pub fn describe(&self) -> String {
        match self {
            WorkerSource::Fork { cmd } => format!("fork {}", cmd.display()),
            WorkerSource::Remote { addrs } => format!("remote [{}]", addrs.join(", ")),
        }
    }
}

/// Parse a `--workers-at` value: comma-separated `host:port` entries,
/// whitespace-tolerant, empties dropped.
pub fn parse_workers_at(s: &str) -> Vec<String> {
    s.split(',')
        .map(str::trim)
        .filter(|a| !a.is_empty())
        .map(str::to_string)
        .collect()
}

/// The [`WORKERS_ENV`] fallback for `--workers-at`; `None` when unset or
/// empty.
pub fn workers_at_from_env() -> Option<Vec<String>> {
    let addrs = parse_workers_at(&std::env::var(WORKERS_ENV).ok()?);
    if addrs.is_empty() {
        None
    } else {
        Some(addrs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_workers_at_lists() {
        assert_eq!(
            parse_workers_at("a:1, b:2 ,,c:3,"),
            vec!["a:1".to_string(), "b:2".to_string(), "c:3".to_string()]
        );
        assert!(parse_workers_at("  ").is_empty());
    }

    #[test]
    fn pool_size_follows_the_source() {
        let fork = WorkerSource::Fork { cmd: PathBuf::from("parccm") };
        assert_eq!(fork.pool_size(3), 3);
        assert_eq!(fork.pool_size(0), 1, "fork pools are never empty");
        assert!(fork.can_respawn());
        assert!(!fork.is_remote());
        let remote =
            WorkerSource::Remote { addrs: vec!["h:1".into(), "h:2".into()] };
        assert_eq!(remote.pool_size(9), 2, "remote pool width IS the address list");
        assert!(!remote.can_respawn());
        assert!(remote.is_remote());
    }

    #[test]
    fn remote_connect_rejects_unknown_slot() {
        let remote = WorkerSource::Remote { addrs: vec!["127.0.0.1:1".into()] };
        let err = remote
            .connect(5, TransportKind::Tcp, &[], None)
            .expect_err("slot out of range");
        assert!(err.to_string().contains("slot 5"), "{err}");
    }

    #[test]
    fn describe_names_the_source() {
        assert!(WorkerSource::Fork { cmd: PathBuf::from("x") }.describe().contains("fork"));
        let r = WorkerSource::Remote { addrs: vec!["a:1".into(), "b:2".into()] };
        assert_eq!(r.describe(), "remote [a:1, b:2]");
    }
}
