//! Embedding-parameter selection — the methods the paper cites as the
//! alternative to brute-force sweeps (Cao 1997 [1]; Kantz & Schreiber [4];
//! Kugiumtzis [5]), provided so users can *choose* (E, tau) instead of
//! (or before) sweeping them:
//!
//! * [`cao_e1`] / [`select_e_cao`] — Cao's minimum embedding dimension:
//!   E1(d) saturates near 1 once d is sufficient.
//! * [`mutual_information`] / [`select_tau_ami`] — first minimum of the
//!   histogram average mutual information picks tau.
//! * [`select_e_forecast`] — rEDM-style: E maximizing out-of-sample
//!   simplex forecast skill.

use crate::ccm::embedding::Embedding;
use crate::ccm::forecast::simplex_forecast;
use crate::EMAX;

/// Cao's E1 quantity for dimensions `1..=max_e`.
///
/// `E1(d) = E(d+1)/E(d)` where `E(d)` is the mean expansion factor of
/// nearest-neighbour distances when moving from a d- to a (d+1)-
/// dimensional embedding (Cao 1997, eq. 3, maximum-norm). E1 ≈ 1 and flat
/// means d is sufficient.
pub fn cao_e1(series: &[f32], tau: usize, max_e: usize) -> Vec<f64> {
    let max_e = max_e.min(EMAX - 1);
    let mut mean_expansion = Vec::new(); // E(d) for d = 1..=max_e
    for d in 1..=max_e {
        let emb_d = Embedding::new(series, d, tau);
        let emb_d1 = Embedding::new(series, d + 1, tau);
        // align: row i of emb_{d+1} corresponds to row i + tau of emb_d
        // (emb_{d+1} starts tau later)
        let n = emb_d1.n;
        let offset = emb_d.n - n;
        let mut acc = 0.0f64;
        let mut count = 0usize;
        for i in 0..n {
            // nearest neighbour of point i in d dims (max-norm, excluding self)
            let qi = i + offset;
            let mut best = f64::INFINITY;
            let mut best_j = usize::MAX;
            for j in 0..n {
                if j == i {
                    continue;
                }
                let qj = j + offset;
                let mut dist = 0.0f64;
                for l in 0..d {
                    let diff = (emb_d.point(qi)[l] - emb_d.point(qj)[l]).abs() as f64;
                    dist = dist.max(diff);
                }
                if dist < best {
                    best = dist;
                    best_j = j;
                }
            }
            if best_j == usize::MAX || best <= 0.0 {
                continue;
            }
            // expansion in d+1 dims with the SAME neighbour
            let mut dist1 = 0.0f64;
            for l in 0..=d {
                let diff = (emb_d1.point(i)[l] - emb_d1.point(best_j)[l]).abs() as f64;
                dist1 = dist1.max(diff);
            }
            acc += dist1 / best;
            count += 1;
        }
        mean_expansion.push(if count > 0 { acc / count as f64 } else { f64::NAN });
    }
    // E1(d) = E(d+1)/E(d)
    mean_expansion
        .windows(2)
        .map(|w| w[1] / w[0])
        .collect()
}

/// Smallest d whose E1 has saturated (|E1(d) - 1| < tol) — Cao's minimum
/// embedding dimension. Falls back to the argmax of E1 when nothing
/// saturates within `max_e`.
pub fn select_e_cao(series: &[f32], tau: usize, max_e: usize, tol: f64) -> usize {
    let e1 = cao_e1(series, tau, max_e);
    for (idx, v) in e1.iter().enumerate() {
        if (v - 1.0).abs() < tol {
            return idx + 1; // E1 index 0 compares d=1 vs d=2 -> E=1 sufficient
        }
    }
    1 + e1
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}

/// Histogram average mutual information I(x_t; x_{t+lag}) in nats,
/// for lags `1..=max_lag` (`bins` equal-width bins).
pub fn mutual_information(series: &[f32], max_lag: usize, bins: usize) -> Vec<f64> {
    assert!(bins >= 2);
    let n = series.len();
    let lo = series.iter().copied().fold(f32::INFINITY, f32::min) as f64;
    let hi = series.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let width = ((hi - lo) / bins as f64).max(1e-12);
    let bin_of = |v: f32| (((v as f64 - lo) / width) as usize).min(bins - 1);
    (1..=max_lag)
        .map(|lag| {
            let m = n - lag;
            let mut joint = vec![0.0f64; bins * bins];
            let mut px = vec![0.0f64; bins];
            let mut py = vec![0.0f64; bins];
            for t in 0..m {
                let a = bin_of(series[t]);
                let b = bin_of(series[t + lag]);
                joint[a * bins + b] += 1.0;
                px[a] += 1.0;
                py[b] += 1.0;
            }
            let mut mi = 0.0f64;
            for a in 0..bins {
                for b in 0..bins {
                    let pj = joint[a * bins + b] / m as f64;
                    if pj > 0.0 {
                        mi += pj * (pj / (px[a] / m as f64 * py[b] / m as f64)).ln();
                    }
                }
            }
            mi
        })
        .collect()
}

/// First local minimum of the AMI curve (standard tau heuristic); falls
/// back to the lag where AMI first drops below 1/e of its lag-1 value,
/// then to 1.
pub fn select_tau_ami(series: &[f32], max_lag: usize, bins: usize) -> usize {
    let ami = mutual_information(series, max_lag, bins);
    for i in 1..ami.len().saturating_sub(1) {
        if ami[i] < ami[i - 1] && ami[i] <= ami[i + 1] {
            return i + 1;
        }
    }
    let threshold = ami.first().copied().unwrap_or(0.0) / std::f64::consts::E;
    for (i, v) in ami.iter().enumerate() {
        if *v < threshold {
            return i + 1;
        }
    }
    1
}

/// rEDM-style E selection: the dimension in `1..=max_e` with the best
/// out-of-sample simplex forecast skill. Returns `(best_e, skills)`.
pub fn select_e_forecast(series: &[f32], tau: usize, max_e: usize) -> (usize, Vec<f64>) {
    let max_e = max_e.min(EMAX);
    let skills: Vec<f64> = (1..=max_e)
        .map(|e| simplex_forecast(series, e, tau, 1).rho as f64)
        .collect();
    let best = 1 + skills
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0);
    (best, skills)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::timeseries::generators::{coupled_logistic, lorenz63, CoupledLogisticParams};
    use crate::util::rng::Rng;

    fn logistic(n: usize) -> Vec<f32> {
        coupled_logistic(n, CoupledLogisticParams { byx: 0.0, bxy: 0.0, ..Default::default() }).0
    }

    #[test]
    fn cao_selects_small_e_for_lorenz() {
        // Lorenz-63 embeds in E ~ 3 (Takens bound 2*2.06+1); Cao's E1
        // saturates around d = 3-5. (The logistic *map* is deliberately
        // not used here: Cao's method assumes invertible dynamics, and
        // non-invertible maps keep E1 < 1 via preimage branching.)
        let (x, _, _) = lorenz63(1500, 0.01, 3);
        let e = select_e_cao(&x, 3, 6, 0.12);
        assert!((3..=6).contains(&e), "Cao E for Lorenz should be 3..6, got {e}");
    }

    #[test]
    fn cao_e1_rises_to_one_for_lorenz() {
        let (x, _, _) = lorenz63(1500, 0.01, 3);
        let e1 = cao_e1(&x, 3, 6);
        assert_eq!(e1.len(), 5);
        assert!(e1[0] < 0.5, "insufficient dimension must show E1 << 1: {e1:?}");
        let tail = *e1.last().unwrap();
        assert!((tail - 1.0).abs() < 0.15, "E1 tail {tail} should saturate near 1");
        assert!(e1.windows(2).all(|w| w[1] >= w[0] - 0.1), "roughly increasing: {e1:?}");
    }

    #[test]
    fn forecast_e_selection_prefers_low_e_for_logistic() {
        let x = logistic(800);
        let (best, skills) = select_e_forecast(&x, 1, 6);
        assert!(best <= 3, "logistic map forecast-E should be <= 3: {best} {skills:?}");
        assert!(skills[best - 1] > 0.9);
    }

    #[test]
    fn ami_decreases_then_selects_reasonable_tau_for_lorenz() {
        let (x, _, _) = lorenz63(3000, 0.01, 2);
        let ami = mutual_information(&x, 40, 16);
        assert!(ami[0] > *ami.last().unwrap(), "AMI should decay from lag 1");
        let tau = select_tau_ami(&x, 40, 16);
        assert!((3..=40).contains(&tau), "Lorenz AMI tau should be > a few samples: {tau}");
    }

    #[test]
    fn ami_of_iid_noise_is_flat_and_tau_is_one() {
        let mut rng = Rng::new(1);
        let x: Vec<f32> = (0..2000).map(|_| rng.f32()).collect();
        let ami = mutual_information(&x, 10, 8);
        assert!(ami.iter().all(|v| v.abs() < 0.1), "iid noise AMI ~ 0: {ami:?}");
    }

    #[test]
    fn mi_nonnegative() {
        let x = logistic(500);
        assert!(mutual_information(&x, 12, 12).iter().all(|&v| v >= -1e-9));
    }
}
