//! Native simplex projection — identical weighting to the Pallas kernel:
//! `w_j = exp(-d_j / d_1)` over euclidean distances (inputs are squared),
//! floored at 1e-6, over the first `e+1` neighbours.

use crate::KMAX;

/// Predict one point from its neighbour panel (ascending squared
/// distances + gathered targets, KMAX wide).
pub fn simplex_one(dvals: &[f32], tvals: &[f32], e: usize) -> f32 {
    debug_assert_eq!(dvals.len(), KMAX);
    debug_assert!(e + 1 <= KMAX);
    let d1 = dvals[0].max(0.0).sqrt().max(1e-30);
    let mut num = 0.0f32;
    let mut den = 0.0f32;
    for j in 0..=e {
        let d = dvals[j].max(0.0).sqrt();
        let w = (-d / d1).exp().max(1e-6);
        num += w * tvals[j];
        den += w;
    }
    num / den
}

/// Batch simplex over flat `[n, KMAX]` panels, written into a reused
/// output buffer (cleared first) — the arena-backed hot path.
pub fn simplex_batch_into(dvals: &[f32], tvals: &[f32], n: usize, e: usize, out: &mut Vec<f32>) {
    out.clear();
    out.reserve(n);
    for i in 0..n {
        out.push(simplex_one(
            &dvals[i * KMAX..(i + 1) * KMAX],
            &tvals[i * KMAX..(i + 1) * KMAX],
            e,
        ));
    }
}

/// Allocating batch simplex over flat `[n, KMAX]` panels.
pub fn simplex_batch(dvals: &[f32], tvals: &[f32], n: usize, e: usize) -> Vec<f32> {
    let mut out = Vec::new();
    simplex_batch_into(dvals, tvals, n, e, &mut out);
    out
}

/// Pearson correlation between two f32 slices (f64 accumulation), 0 when
/// degenerate — the skill score.
pub fn pearson_f32(x: &[f32], y: &[f32]) -> f32 {
    assert_eq!(x.len(), y.len());
    let n = x.len();
    if n == 0 {
        return 0.0;
    }
    let mut sx = 0.0f64;
    let mut sy = 0.0f64;
    for i in 0..n {
        sx += x[i] as f64;
        sy += y[i] as f64;
    }
    let mx = sx / n as f64;
    let my = sy / n as f64;
    let mut cov = 0.0f64;
    let mut vx = 0.0f64;
    let mut vy = 0.0f64;
    for i in 0..n {
        let dx = x[i] as f64 - mx;
        let dy = y[i] as f64 - my;
        cov += dx * dy;
        vx += dx * dx;
        vy += dy * dy;
    }
    let denom = (vx * vy).sqrt();
    if denom > 0.0 {
        (cov / denom) as f32
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::BIG;

    #[test]
    fn equidistant_neighbours_average() {
        let d = [1.0f32; KMAX];
        let t: Vec<f32> = (0..KMAX as u32).map(|i| i as f32).collect();
        // e=3 -> neighbours 0..=3, mean 1.5
        assert!((simplex_one(&d, &t, 3) - 1.5).abs() < 1e-6);
    }

    #[test]
    fn nearest_dominates_with_distance() {
        // d1 = 1, all others 100x further: their weights hit the 1e-6
        // floor and the prediction hugs the nearest target (w0 = e^-1).
        let mut d = [1.0e4f32; KMAX];
        d[0] = 1.0;
        let mut t = [50.0f32; KMAX];
        t[0] = 5.0;
        let p = simplex_one(&d, &t, 4);
        assert!((p - 5.0).abs() < 0.01, "prediction {p} should hug nearest target");
    }

    #[test]
    fn exact_match_returns_target() {
        let mut d = [1.0f32; KMAX];
        d[0] = 0.0;
        let mut t = [9.0f32; KMAX];
        t[0] = 3.0;
        // d1 = 0 -> w0 = 1, others exp(-inf) floored to 1e-6
        let p = simplex_one(&d, &t, 5);
        assert!((p - 3.0).abs() < 1e-3);
    }

    #[test]
    fn padded_big_slots_carry_no_weight() {
        let mut d = [BIG; KMAX];
        let mut t = [777.0f32; KMAX];
        d[0] = 0.04;
        t[0] = 2.0;
        d[1] = 0.09;
        t[1] = 4.0;
        // e = 4 but only 2 real neighbours: BIG slots get weight 1e-6.
        // w0 = exp(-0.2/0.2) = e^-1, w1 = exp(-0.3/0.2) = e^-1.5.
        let p = simplex_one(&d, &t, 4);
        let (w0, w1, wpad) = ((-1.0f32).exp(), (-1.5f32).exp(), 1e-6f32);
        let expected = (w0 * 2.0 + w1 * 4.0 + 3.0 * wpad * 777.0) / (w0 + w1 + 3.0 * wpad);
        assert!((p - expected).abs() < 1e-4, "{p} vs {expected}");
    }

    #[test]
    fn batch_matches_one() {
        let n = 7;
        let mut dv = vec![0.0f32; n * KMAX];
        let mut tv = vec![0.0f32; n * KMAX];
        for i in 0..n * KMAX {
            dv[i] = ((i * 13) % 17) as f32 * 0.1 + 0.1;
            tv[i] = ((i * 7) % 5) as f32;
        }
        // rows must be ascending for semantics; sort each row
        for i in 0..n {
            let row = &mut dv[i * KMAX..(i + 1) * KMAX];
            row.sort_by(|a, b| a.partial_cmp(b).unwrap());
        }
        let batch = simplex_batch(&dv, &tv, n, 3);
        for i in 0..n {
            let one = simplex_one(&dv[i * KMAX..(i + 1) * KMAX], &tv[i * KMAX..(i + 1) * KMAX], 3);
            assert_eq!(batch[i], one);
        }
    }

    #[test]
    fn pearson_basics() {
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let y = [10.0f32, 20.0, 30.0, 40.0];
        assert!((pearson_f32(&x, &y) - 1.0).abs() < 1e-6);
        let yneg = [4.0f32, 3.0, 2.0, 1.0];
        assert!((pearson_f32(&x, &yneg) + 1.0).abs() < 1e-6);
        assert_eq!(pearson_f32(&x, &[5.0; 4]), 0.0);
        assert_eq!(pearson_f32(&[], &[]), 0.0);
    }
}
