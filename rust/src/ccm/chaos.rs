//! Deterministic fault injection at the transport layer.
//!
//! [`ChaosTransport`] wraps any [`Transport`] and injects failures frame
//! by frame — delays, silent drops, truncated writes, byte corruption —
//! from a seeded [`Rng`], so a "flaky network" run is *replayable*: the
//! same seed and profile produce the same fault schedule. It is layered
//! *under* the v4 [`ChecksumTransport`](crate::ccm::transport::ChecksumTransport)
//! (checksum outermost on send, verify outermost on recv), which is what
//! turns injected corruption into a clean, counted detection instead of a
//! JSON-parse coin flip.
//!
//! Configuration rides in `PARCCM_CHAOS=seed:profile`, e.g.
//!
//! ```text
//! PARCCM_CHAOS="7:delay=6,delay_ms=2,corrupt_once=30"
//! ```
//!
//! The profile is comma-joined `k=v` pairs; every rate is "1 in N frames"
//! (`0` disables):
//!
//! | key            | effect                                                  |
//! |----------------|---------------------------------------------------------|
//! | `delay=N`      | 1-in-N frames (either direction) sleep before moving    |
//! | `delay_ms=M`   | how long a delayed frame sleeps (default 5 ms)          |
//! | `drop=N`       | 1-in-N *sent* frames silently vanish                    |
//! | `trunc=N`      | 1-in-N *sent* frames are cut mid-write and the send errs|
//! | `corrupt=N`    | 1-in-N frames, both directions, get one byte flipped    |
//! | `corrupt_send=N` | corruption on the send side only                      |
//! | `corrupt_recv=N` | corruption on the receive side only                   |
//! | `corrupt_once=N` | exactly the Nth frame *received* process-wide is      |
//! |                | corrupted, then never again — the deterministic "one    |
//! |                | corruption per run" the chaos CI pass asserts on        |
//!
//! The handshake is exempt by construction: callers wrap the transport
//! only after the hello/`hello_ack` exchange, so chaos can never make a
//! spawn flaky — only steady-state traffic.
//!
//! v6 binary frames get the same treatment as JSON lines: `drop` swallows
//! a sent frame whole, `trunc` ships a cut frame body (the length prefix
//! stays honest, mirroring how line truncation keeps its newline) and
//! fails the send, and the corrupt knobs flip one byte of the frame body
//! — which, layered under the checksum wrapper, includes the 8-byte
//! trailer. The `corrupt_once` received-frame counter is shared across
//! both wire modes, so "the Nth frame" means the Nth thing received,
//! line or binary.
//!
//! The driver threads its chaos config through
//! [`ClusterOptions::chaos`](crate::ccm::cluster::ClusterOptions) rather
//! than reading the environment per connection (process-global env races
//! across threaded tests); `main.rs` and the worker entrypoint fill it
//! from [`CHAOS_ENV`] via [`chaos_from_env`].

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::ccm::transport::{Transport, TransportKind};
use crate::util::rng::Rng;

/// Environment variable carrying `seed:profile`.
pub const CHAOS_ENV: &str = "PARCCM_CHAOS";

/// Parsed fault-injection profile: each rate is "1 in N frames", 0 = off.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ChaosProfile {
    /// 1-in-N frames (both directions) sleep [`ChaosProfile::delay_ms`].
    pub delay: u64,
    /// Sleep applied to a delayed frame (milliseconds, default 5).
    pub delay_ms: u64,
    /// 1-in-N sent frames are silently dropped.
    pub drop: u64,
    /// 1-in-N sent frames are truncated mid-write; the send then errors.
    pub trunc: u64,
    /// 1-in-N frames in both directions get one byte flipped.
    pub corrupt: u64,
    /// Send-side-only corruption rate.
    pub corrupt_send: u64,
    /// Receive-side-only corruption rate.
    pub corrupt_recv: u64,
    /// Corrupt exactly the Nth received frame process-wide, then stop.
    pub corrupt_once: u64,
}

impl ChaosProfile {
    /// Parse the comma-joined `k=v` profile string.
    pub fn parse(spec: &str) -> Result<ChaosProfile, String> {
        let mut p = ChaosProfile { delay_ms: 5, ..ChaosProfile::default() };
        for pair in spec.split(',').filter(|s| !s.trim().is_empty()) {
            let (key, value) = pair
                .split_once('=')
                .ok_or_else(|| format!("chaos profile entry '{pair}' is not k=v"))?;
            let n: u64 = value
                .trim()
                .parse()
                .map_err(|_| format!("chaos profile '{key}' value '{value}' is not a number"))?;
            match key.trim() {
                "delay" => p.delay = n,
                "delay_ms" => p.delay_ms = n,
                "drop" => p.drop = n,
                "trunc" => p.trunc = n,
                "corrupt" => p.corrupt = n,
                "corrupt_send" => p.corrupt_send = n,
                "corrupt_recv" => p.corrupt_recv = n,
                "corrupt_once" => p.corrupt_once = n,
                other => return Err(format!("unknown chaos profile key '{other}'")),
            }
        }
        Ok(p)
    }
}

/// State shared by every [`ChaosTransport`] in one process: the global
/// received-frame counter behind `corrupt_once`, and the connection
/// counter that forks each wrapper its own deterministic stream.
#[derive(Debug, Default)]
pub struct ChaosState {
    frames_recv: AtomicU64,
    connections: AtomicU64,
}

impl ChaosState {
    /// Fresh shared state (one per driver core / worker process).
    pub fn new() -> Arc<ChaosState> {
        Arc::new(ChaosState::default())
    }
}

/// Parse [`CHAOS_ENV`] into `(seed, profile)`; `None` when unset. A
/// malformed value is a loud error — a chaos run that silently ran clean
/// would "pass" while testing nothing.
pub fn chaos_from_env() -> Result<Option<(u64, ChaosProfile)>, String> {
    let Ok(raw) = std::env::var(CHAOS_ENV) else { return Ok(None) };
    if raw.trim().is_empty() {
        return Ok(None);
    }
    let (seed, spec) = raw
        .split_once(':')
        .ok_or_else(|| format!("{CHAOS_ENV} must be seed:profile, got '{raw}'"))?;
    let seed: u64 = seed
        .trim()
        .parse()
        .map_err(|_| format!("{CHAOS_ENV} seed '{seed}' is not a number"))?;
    let profile = ChaosProfile::parse(spec)?;
    Ok(Some((seed, profile)))
}

/// A [`Transport`] that deterministically misbehaves. Each wrapper forks
/// its own RNG stream from (seed, connection-serial) so reconnects after
/// an injected death see a fresh — but still reproducible — schedule.
pub struct ChaosTransport {
    inner: Box<dyn Transport>,
    profile: ChaosProfile,
    rng: Rng,
    state: Arc<ChaosState>,
}

impl ChaosTransport {
    /// Wrap `inner` with the given seed/profile and process-shared state.
    pub fn new(
        inner: Box<dyn Transport>,
        seed: u64,
        profile: ChaosProfile,
        state: Arc<ChaosState>,
    ) -> ChaosTransport {
        let conn = state.connections.fetch_add(1, Ordering::Relaxed);
        ChaosTransport { inner, profile, rng: Rng::new(seed).fork(conn), state }
    }

    fn hit(&mut self, one_in: u64) -> bool {
        one_in > 0 && self.rng.below(one_in as usize) == 0
    }

    fn maybe_delay(&mut self) {
        if self.profile.delay_ms > 0 && self.hit(self.profile.delay) {
            std::thread::sleep(Duration::from_millis(self.profile.delay_ms));
        }
    }

    /// Flip one byte of `line` at a seeded position (never the newline —
    /// the *frame* is corrupted, not the framing underneath it).
    fn corrupt_line(&mut self, line: &str) -> String {
        let mut bytes: Vec<u8> = line.as_bytes().to_vec();
        if bytes.is_empty() {
            return line.to_string();
        }
        let pos = self.rng.below(bytes.len());
        // xor with a sub-0x80 value keeps the byte printable-ish and the
        // line valid UTF-8 often enough to exercise the checksum (rather
        // than only the UTF-8) detection path; 0 is avoided so the byte
        // always actually changes
        let flip = 1 + (self.rng.below(0x5e) as u8);
        bytes[pos] = bytes[pos] ^ flip;
        if bytes[pos] == b'\n' {
            bytes[pos] ^= 1; // keep framing intact
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Flip one byte of a binary frame body at a seeded position. No
    /// byte is off-limits: the length prefix lives a layer below, so any
    /// flip here lands inside the checksummed body (or its trailer).
    fn corrupt_frame(&mut self, frame: &[u8]) -> Vec<u8> {
        let mut bytes = frame.to_vec();
        if bytes.is_empty() {
            return bytes;
        }
        let pos = self.rng.below(bytes.len());
        let flip = 1 + (self.rng.below(0xfe) as u8); // never 0: always a real change
        bytes[pos] ^= flip;
        bytes
    }
}

impl Transport for ChaosTransport {
    fn send_line(&mut self, line: &str) -> std::io::Result<()> {
        self.maybe_delay();
        if self.hit(self.profile.drop) {
            return Ok(()); // vanished in flight; the peer just never hears it
        }
        if self.hit(self.profile.trunc) {
            // a half-written frame: ship a prefix with no terminator and
            // fail the send so the scheduler declares this worker dead
            let mut cut = line.len() / 2;
            while cut > 0 && !line.is_char_boundary(cut) {
                cut -= 1;
            }
            let _ = self.inner.send_line(&line[..cut]);
            return Err(std::io::Error::new(
                std::io::ErrorKind::BrokenPipe,
                "chaos: truncated write",
            ));
        }
        if self.hit(self.profile.corrupt) || self.hit(self.profile.corrupt_send) {
            let mangled = self.corrupt_line(line);
            return self.inner.send_line(&mangled);
        }
        self.inner.send_line(line)
    }

    fn recv_line(&mut self) -> std::io::Result<Option<String>> {
        let got = self.inner.recv_line()?;
        let Some(line) = got else { return Ok(None) };
        self.maybe_delay();
        let nth = self.state.frames_recv.fetch_add(1, Ordering::Relaxed) + 1;
        let once = self.profile.corrupt_once > 0 && nth == self.profile.corrupt_once;
        if once || self.hit(self.profile.corrupt) || self.hit(self.profile.corrupt_recv) {
            return Ok(Some(self.corrupt_line(&line)));
        }
        Ok(Some(line))
    }

    fn send_frame(&mut self, frame: &[u8]) -> std::io::Result<()> {
        self.maybe_delay();
        if self.hit(self.profile.drop) {
            return Ok(()); // vanished in flight; the peer just never hears it
        }
        if self.hit(self.profile.trunc) {
            // ship a cut frame body — honestly framed, so the peer reads
            // it cleanly and the *checksum* layer calls it corrupt — and
            // fail the send so the scheduler declares this worker dead
            let cut = (frame.len() / 2).max(1);
            let _ = self.inner.send_frame(&frame[..cut]);
            return Err(std::io::Error::new(
                std::io::ErrorKind::BrokenPipe,
                "chaos: truncated write",
            ));
        }
        if self.hit(self.profile.corrupt) || self.hit(self.profile.corrupt_send) {
            let mangled = self.corrupt_frame(frame);
            return self.inner.send_frame(&mangled);
        }
        self.inner.send_frame(frame)
    }

    fn recv_frame(&mut self) -> std::io::Result<Option<Vec<u8>>> {
        let got = self.inner.recv_frame()?;
        let Some(frame) = got else { return Ok(None) };
        self.maybe_delay();
        let nth = self.state.frames_recv.fetch_add(1, Ordering::Relaxed) + 1;
        let once = self.profile.corrupt_once > 0 && nth == self.profile.corrupt_once;
        if once || self.hit(self.profile.corrupt) || self.hit(self.profile.corrupt_recv) {
            return Ok(Some(self.corrupt_frame(&frame)));
        }
        Ok(Some(frame))
    }

    fn kind(&self) -> TransportKind {
        self.inner.kind()
    }

    fn set_recv_deadline(&mut self, timeout: Option<Duration>) -> std::io::Result<bool> {
        self.inner.set_recv_deadline(timeout)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ccm::transport::{recv_json, ChecksumTransport, TcpTransport};
    use crate::util::json::Json;
    use std::net::{TcpListener, TcpStream};

    #[test]
    fn profile_parses_and_rejects_garbage() {
        let p = ChaosProfile::parse("delay=6,delay_ms=2,corrupt_once=30").unwrap();
        assert_eq!(p.delay, 6);
        assert_eq!(p.delay_ms, 2);
        assert_eq!(p.corrupt_once, 30);
        assert_eq!(p.drop, 0);
        assert_eq!(ChaosProfile::parse("").unwrap(), ChaosProfile { delay_ms: 5, ..Default::default() });
        assert!(ChaosProfile::parse("warp=9").unwrap_err().contains("warp"));
        assert!(ChaosProfile::parse("delay").unwrap_err().contains("k=v"));
        assert!(ChaosProfile::parse("delay=x").unwrap_err().contains("not a number"));
    }

    fn tcp_pair() -> (TcpTransport, TcpTransport) {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap();
        let client = std::thread::spawn(move || TcpStream::connect(addr).unwrap());
        let (server, _) = listener.accept().unwrap();
        (
            TcpTransport::from_stream(server).unwrap(),
            TcpTransport::from_stream(client.join().unwrap()).unwrap(),
        )
    }

    #[test]
    fn clean_profile_is_a_transparent_wrapper() {
        let (server, mut client) = tcp_pair();
        let mut chaotic = ChaosTransport::new(
            Box::new(server),
            7,
            ChaosProfile::parse("").unwrap(),
            ChaosState::new(),
        );
        client.send_line(r#"{"type":"ping"}"#).unwrap();
        let msg = recv_json(&mut chaotic).unwrap();
        assert_eq!(msg.get("type").and_then(Json::as_str), Some("ping"));
        chaotic.send_line(r#"{"type":"pong"}"#).unwrap();
        let reply = recv_json(&mut client).unwrap();
        assert_eq!(reply.get("type").and_then(Json::as_str), Some("pong"));
    }

    #[test]
    fn corrupt_once_hits_exactly_the_nth_received_frame() {
        let (server, mut client) = tcp_pair();
        let state = ChaosState::new();
        let profile = ChaosProfile::parse("corrupt_once=2").unwrap();
        let mut chaotic = ChaosTransport::new(Box::new(server), 1, profile, state);
        for i in 0..4 {
            client.send_line(&format!(r#"{{"n":{i}}}"#)).unwrap();
        }
        let mut mangled = 0;
        for i in 0..4 {
            let line = chaotic.recv_line().unwrap().unwrap();
            if line.trim_end() != format!(r#"{{"n":{i}}}"#) {
                mangled += 1;
                assert_eq!(i, 1, "only the 2nd frame is corrupted, got frame {i}: {line:?}");
            }
        }
        assert_eq!(mangled, 1, "exactly one corruption per process");
    }

    #[test]
    fn injected_corruption_is_caught_by_the_checksum_layer() {
        // the real layering: raw → chaos (recv corruption) → checksum
        let (server, client) = tcp_pair();
        let state = ChaosState::new();
        let profile = ChaosProfile::parse("corrupt_once=1").unwrap();
        let chaotic = ChaosTransport::new(Box::new(server), 3, profile, state);
        let tally = std::sync::Arc::new(AtomicU64::new(0));
        let mut checked = ChecksumTransport::new(Box::new(chaotic), Some(tally.clone()));
        let mut sender = ChecksumTransport::new(Box::new(client), None);
        sender.send_line(r#"{"type":"result","id":9}"#).unwrap();
        let err = checked.recv_line().unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{err}");
        assert_eq!(tally.load(Ordering::Relaxed), 1, "corruption detected and tallied");
    }

    #[test]
    fn binary_frame_corruption_is_caught_by_the_checksum_layer() {
        // same layering as the line test, binary wire: raw → chaos → checksum
        let (server, client) = tcp_pair();
        let profile = ChaosProfile::parse("corrupt_once=1").unwrap();
        let chaotic = ChaosTransport::new(Box::new(server), 3, profile, ChaosState::new());
        let tally = std::sync::Arc::new(AtomicU64::new(0));
        let mut checked = ChecksumTransport::new(Box::new(chaotic), Some(tally.clone()));
        let mut sender = ChecksumTransport::new(Box::new(client), None);
        sender.send_frame(&[0x10, 1, 2, 3, 4, 5]).unwrap();
        let err = checked.recv_frame().unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{err}");
        assert_eq!(tally.load(Ordering::Relaxed), 1, "corruption detected and tallied");
    }

    #[test]
    fn truncated_binary_send_errors_and_the_peer_counts_corruption() {
        let (server, client) = tcp_pair();
        let profile = ChaosProfile::parse("trunc=1").unwrap();
        let chaotic = ChaosTransport::new(Box::new(server), 5, profile, ChaosState::new());
        let mut sender = ChecksumTransport::new(Box::new(chaotic), None);
        let err = sender.send_frame(&[0x01; 64]).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::BrokenPipe, "{err}");
        let tally = std::sync::Arc::new(AtomicU64::new(0));
        let mut checked = ChecksumTransport::new(Box::new(client), Some(tally.clone()));
        let err = checked.recv_frame().unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData, "{err}");
        assert_eq!(tally.load(Ordering::Relaxed), 1, "cut frame counted as corruption");
    }

    #[test]
    fn dropped_binary_frames_vanish_without_breaking_the_stream() {
        let (server, mut client) = tcp_pair();
        let profile = ChaosProfile::parse("drop=1").unwrap();
        let mut chaotic = ChaosTransport::new(Box::new(server), 9, profile, ChaosState::new());
        chaotic.send_frame(&[0x10, 0xde, 0xad]).unwrap(); // swallowed, send "succeeds"
        chaotic.inner.send_frame(&[0x10, 0xbe, 0xef]).unwrap(); // bypasses chaos
        let got = client.recv_frame().unwrap().unwrap();
        assert_eq!(got, vec![0x10, 0xbe, 0xef], "first frame never hit the wire");
    }

    #[test]
    fn corrupt_once_counter_spans_lines_and_binary_frames() {
        // "the Nth frame received" counts both wire modes: a line then a
        // frame through the same state — corrupt_once=2 hits the frame
        let (server, mut client) = tcp_pair();
        let profile = ChaosProfile::parse("corrupt_once=2").unwrap();
        let mut chaotic = ChaosTransport::new(Box::new(server), 11, profile, ChaosState::new());
        client.send_line(r#"{"type":"ping"}"#).unwrap();
        client.send_frame(&[0x10, 7, 7, 7]).unwrap();
        let line = chaotic.recv_line().unwrap().unwrap();
        assert_eq!(line.trim_end(), r#"{"type":"ping"}"#, "frame 1 untouched");
        let frame = chaotic.recv_frame().unwrap().unwrap();
        assert_ne!(frame, vec![0x10, 7, 7, 7], "frame 2 corrupted");
        assert_eq!(frame.len(), 4, "corruption flips a byte, never resizes");
    }

    #[test]
    fn same_seed_same_fault_schedule() {
        let profile = ChaosProfile::parse("drop=3,corrupt=5").unwrap();
        let schedule = |seed: u64| -> Vec<(bool, bool)> {
            let (server, _client) = tcp_pair();
            let mut t =
                ChaosTransport::new(Box::new(server), seed, profile.clone(), ChaosState::new());
            (0..64).map(|_| (t.hit(t.profile.drop), t.hit(t.profile.corrupt))).collect()
        };
        assert_eq!(schedule(42), schedule(42), "replayable");
        assert_ne!(schedule(42), schedule(43), "seed actually matters");
    }
}
