//! Univariate EDM forecasting: simplex projection and S-map.
//!
//! These are the building blocks rEDM ships next to `ccm` (Ye et al.
//! 2016) and what the CCM literature uses to pick embedding parameters
//! (forecast skill vs E — see [`crate::ccm::select`]). Semantics follow
//! Sugihara & May 1990 (simplex) and Sugihara 1994 (S-map):
//!
//! * the series is split into a library half and a prediction half (no
//!   leakage);
//! * each prediction-half point is forecast `tp` steps ahead from its
//!   E+1 nearest library neighbours (simplex) or from a locally-weighted
//!   linear map over the whole library (S-map, locality set by `theta`);
//! * skill is the Pearson correlation between forecasts and truth.

use crate::ccm::embedding::Embedding;
use crate::ccm::knn::knn_into;
use crate::ccm::simplex::{pearson_f32, simplex_one};
use crate::util::linalg::weighted_ridge_lstsq;
use crate::{BIG, EMAX, KMAX};

/// Forecast result.
#[derive(Clone, Debug)]
pub struct ForecastReport {
    /// Pearson skill of the out-of-sample forecasts.
    pub rho: f32,
    /// Mean absolute error.
    pub mae: f32,
    /// (time index, predicted, observed) per forecast point.
    pub points: Vec<(usize, f32, f32)>,
}

fn split_indices(n: usize) -> (std::ops::Range<usize>, std::ops::Range<usize>) {
    let half = n / 2;
    (0..half, half..n)
}

/// Simplex-projection forecast skill of `series` at embedding `(e, tau)`,
/// predicting `tp >= 1` steps ahead. First half = library, second half =
/// out-of-sample prediction set.
pub fn simplex_forecast(series: &[f32], e: usize, tau: usize, tp: usize) -> ForecastReport {
    assert!(tp >= 1);
    let emb = Embedding::new(series, e, tau);
    let (lib_r, pred_r) = split_indices(emb.n);
    // library rows must have a target tp ahead within the series
    let lib_rows: Vec<usize> =
        lib_r.filter(|&i| emb.time_of(i) + tp < series.len()).collect();
    let mut lib_vecs = Vec::with_capacity(lib_rows.len() * EMAX);
    let mut lib_targets = Vec::with_capacity(lib_rows.len());
    let mut lib_times = Vec::with_capacity(lib_rows.len());
    for &i in &lib_rows {
        lib_vecs.extend_from_slice(emb.point(i));
        lib_targets.push(series[emb.time_of(i) + tp]);
        lib_times.push(emb.time_of(i) as f32);
    }

    let mut preds = Vec::new();
    let mut truths = Vec::new();
    let mut points = Vec::new();
    let mut d = [0.0f32; KMAX];
    let mut t = [0.0f32; KMAX];
    let mut scratch = vec![0.0f32; lib_targets.len()];
    for i in pred_r {
        let target_t = emb.time_of(i) + tp;
        if target_t >= series.len() {
            continue;
        }
        knn_into(
            emb.point(i),
            emb.time_of(i) as f32,
            &lib_vecs,
            &lib_targets,
            &lib_times,
            0.0,
            &mut scratch,
            &mut d,
            &mut t,
        );
        let yhat = simplex_one(&d, &t, e);
        preds.push(yhat);
        truths.push(series[target_t]);
        points.push((target_t, yhat, series[target_t]));
    }
    finish(preds, truths, points)
}

/// S-map forecast skill: a locally weighted linear model per prediction
/// point, with locality parameter `theta` (theta = 0 reduces to a global
/// linear AR model; larger theta = more state-dependent). The theta sweep
/// distinguishes nonlinear (state-dependent) dynamics from linear
/// stochastic ones — skill peaking at theta > 0 indicates nonlinearity.
pub fn smap_forecast(series: &[f32], e: usize, tau: usize, tp: usize, theta: f64) -> ForecastReport {
    assert!(tp >= 1);
    let emb = Embedding::new(series, e, tau);
    let (lib_r, pred_r) = split_indices(emb.n);
    let lib_rows: Vec<usize> =
        lib_r.filter(|&i| emb.time_of(i) + tp < series.len()).collect();
    let rows = lib_rows.len();
    // design matrix: [1, x_1..x_e] per library row
    let cols = e + 1;
    let mut design = vec![0.0f64; rows * cols];
    let mut targets = vec![0.0f64; rows];
    for (r, &i) in lib_rows.iter().enumerate() {
        design[r * cols] = 1.0;
        for l in 0..e {
            design[r * cols + 1 + l] = emb.point(i)[l] as f64;
        }
        targets[r] = series[emb.time_of(i) + tp] as f64;
    }

    let mut preds = Vec::new();
    let mut truths = Vec::new();
    let mut points = Vec::new();
    for i in pred_r {
        let target_t = emb.time_of(i) + tp;
        if target_t >= series.len() {
            continue;
        }
        let q = emb.point(i);
        // distances to all library rows + mean distance
        let mut dists = Vec::with_capacity(rows);
        let mut sum = 0.0f64;
        for &j in &lib_rows {
            let p = emb.point(j);
            let mut acc = 0.0f32;
            for l in 0..EMAX {
                let diff = q[l] - p[l];
                acc += diff * diff;
            }
            let dj = (acc as f64).sqrt();
            dists.push(dj);
            sum += dj;
        }
        let dbar = (sum / rows as f64).max(1e-12);
        let w: Vec<f64> = dists.iter().map(|dj| (-theta * dj / dbar).exp()).collect();
        let beta = match weighted_ridge_lstsq(&design, &targets, &w, rows, cols, 1e-8) {
            Some(b) => b,
            None => continue, // degenerate neighbourhood
        };
        let mut yhat = beta[0];
        for l in 0..e {
            yhat += beta[1 + l] * q[l] as f64;
        }
        preds.push(yhat as f32);
        truths.push(series[target_t]);
        points.push((target_t, yhat as f32, series[target_t]));
    }
    finish(preds, truths, points)
}

fn finish(preds: Vec<f32>, truths: Vec<f32>, points: Vec<(usize, f32, f32)>) -> ForecastReport {
    let rho = pearson_f32(&preds, &truths);
    let mae = if preds.is_empty() {
        BIG
    } else {
        preds.iter().zip(&truths).map(|(p, o)| (p - o).abs()).sum::<f32>() / preds.len() as f32
    };
    ForecastReport { rho, mae, points }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::timeseries::generators::{ar1, coupled_logistic, CoupledLogisticParams};
    use crate::util::rng::Rng;

    fn logistic(n: usize) -> Vec<f32> {
        coupled_logistic(n, CoupledLogisticParams { byx: 0.0, bxy: 0.0, ..Default::default() }).0
    }

    #[test]
    fn simplex_predicts_deterministic_chaos() {
        let x = logistic(800);
        let rep = simplex_forecast(&x, 2, 1, 1);
        assert!(rep.rho > 0.95, "1-step logistic forecast should be skillful: {}", rep.rho);
        assert!(!rep.points.is_empty());
    }

    #[test]
    fn skill_decays_with_horizon_for_chaos() {
        // hallmark of chaos (Sugihara & May 1990): skill falls with the
        // prediction horizon at the Lyapunov rate (measured: ~1.0 at tp=1,
        // ~0.79 at tp=10, ~0.29 at tp=15, noise floor by tp=30)
        let x = logistic(800);
        let tp1 = simplex_forecast(&x, 2, 1, 1).rho;
        let tp15 = simplex_forecast(&x, 2, 1, 15).rho;
        assert!(tp1 > 0.99, "tp=1 near-perfect: {tp1}");
        assert!(tp1 > tp15 + 0.3, "tp=1 {tp1} should beat tp=15 {tp15}");
    }

    #[test]
    fn simplex_beats_noise_baseline() {
        let mut rng = Rng::new(5);
        let noise: Vec<f32> = (0..600).map(|_| rng.f32()).collect();
        let rep = simplex_forecast(&noise, 3, 1, 1);
        assert!(rep.rho < 0.3, "iid noise must be unforecastable: {}", rep.rho);
    }

    #[test]
    fn smap_predicts_and_theta_matters_for_nonlinear() {
        let x = logistic(800);
        let linear = smap_forecast(&x, 2, 1, 1, 0.0).rho;
        let local = smap_forecast(&x, 2, 1, 1, 2.0).rho;
        assert!(local > 0.9, "S-map theta=2 on logistic: {local}");
        assert!(
            local > linear + 0.05,
            "state-dependent weights should beat global linear on nonlinear dynamics: {local} vs {linear}"
        );
    }

    #[test]
    fn smap_theta_flat_for_linear_process() {
        // AR(1) is linear: locality should not improve skill much
        let x = ar1(900, 0.8, 3);
        let linear = smap_forecast(&x, 3, 1, 1, 0.0).rho;
        let local = smap_forecast(&x, 3, 1, 1, 3.0).rho;
        assert!(
            local <= linear + 0.05,
            "AR(1): theta should not help much ({linear} -> {local})"
        );
    }

    #[test]
    fn forecast_points_are_out_of_sample() {
        let x = logistic(400);
        let rep = simplex_forecast(&x, 2, 1, 1);
        let emb_half_time = 1 + (400 - 1) / 2; // prediction half starts past the midpoint
        assert!(rep.points.iter().all(|&(t, _, _)| t >= emb_half_time));
    }
}
