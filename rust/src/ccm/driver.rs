//! The experiment driver: the paper's five implementation levels
//! (Table 1) over a [`Scenario`].
//!
//! | Case | Description                                               |
//! |------|-----------------------------------------------------------|
//! | A1   | Single-threaded CCM (no RDD & pipeline)                   |
//! | A2   | Synchronous CCM transform pipelines                       |
//! | A3   | Asynchronous CCM transform pipelines                      |
//! | A4   | Synchronous distance-indexing-table + transform pipelines |
//! | A5   | Asynchronous distance-indexing-table + transform pipelines|
//!
//! Each case produces identical skills for identical seeds (asserted by
//! integration tests) — the cases differ only in *how* the work is
//! scheduled, which is exactly what the paper's Fig. 4 measures. The
//! table cases additionally take a [`TablePolicy`]: the default
//! [`TablePolicy::TruncatedAuto`] broadcasts the `O(n * P)` truncated
//! table (bit-identical skills, smaller ship cost in the DES model);
//! [`TablePolicy::Full`] keeps the paper's `O(n^2)` layout.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Instant;

use crate::ccm::backend::{ComputeBackend, TaskArena};
use crate::ccm::cluster::{problem_wire_id, targets_wire_id};
use crate::ccm::params::Scenario;
use crate::ccm::pipeline::{
    ccm_transform_rdd, combine_shard_chunks, combine_shard_sums, sharded_agg_rdds,
    sharded_table_pipeline_mode, sharded_transform_rdds, table_pipeline_mode, table_transform_rdd,
    BoundedRho, CcmProblem, PartialSpec, TableMode,
};
use crate::ccm::result::{summarize, SkillRow, SkillSummary};
use crate::ccm::subsample::draw_samples;
use crate::ccm::table::DistanceTable;
use crate::engine::{Context, Deploy, EngineConfig, ExecutionReport};
use crate::util::json::Json;
use crate::util::rng::Rng;

/// The paper's implementation levels (Table 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Case {
    /// Single-threaded, engine-free loop.
    A1,
    /// Engine, brute-force k-NN, jobs submitted synchronously.
    A2,
    /// Engine, brute-force k-NN, jobs submitted asynchronously.
    A3,
    /// Engine, distance indexing table, synchronous.
    A4,
    /// Engine, distance indexing table, asynchronous.
    A5,
}

impl Case {
    pub const ALL: [Case; 5] = [Case::A1, Case::A2, Case::A3, Case::A4, Case::A5];

    /// Table 1 wording.
    pub fn description(&self) -> &'static str {
        match self {
            Case::A1 => "Single-threaded CCM (no RDD & Pipeline)",
            Case::A2 => "Synchronous CCM Transform Pipelines",
            Case::A3 => "Asynchronous CCM Transform Pipelines",
            Case::A4 => "Synchronous Distance Indexing Table & CCM Transform Pipelines",
            Case::A5 => "Asynchronous Distance Indexing Table & CCM Transform Pipelines",
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Case::A1 => "A1",
            Case::A2 => "A2",
            Case::A3 => "A3",
            Case::A4 => "A4",
            Case::A5 => "A5",
        }
    }

    /// Parse a CLI case name (`--case A4`, case-insensitive).
    pub fn parse(s: &str) -> Option<Case> {
        Case::ALL
            .into_iter()
            .find(|c| c.name().eq_ignore_ascii_case(s.trim()))
    }

    pub fn uses_table(&self) -> bool {
        matches!(self, Case::A4 | Case::A5)
    }

    pub fn is_async(&self) -> bool {
        matches!(self, Case::A3 | Case::A5)
    }
}

/// Distance-table layout policy for the table cases (A4/A5). Ignored by
/// A1–A3.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum TablePolicy {
    /// The paper's full `n * (n-1)` layout.
    Full,
    /// Truncated to [`DistanceTable::auto_prefix`] of the scenario's
    /// sparsest library — the default: identical skills, `O(n * P)`
    /// broadcast bytes.
    #[default]
    TruncatedAuto,
    /// Truncated to an explicit prefix (testing / tuning).
    Truncated(usize),
}

impl TablePolicy {
    /// Resolve to a concrete [`TableMode`] for an `n`-row manifold with
    /// smallest library `min_l`.
    pub fn mode_for(self, n: usize, min_l: usize) -> TableMode {
        match self {
            TablePolicy::Full => TableMode::Full,
            TablePolicy::TruncatedAuto => {
                TableMode::Truncated { prefix: DistanceTable::auto_prefix(n, min_l) }
            }
            TablePolicy::Truncated(prefix) => TableMode::Truncated { prefix },
        }
    }
}

/// Where the Pearson reduction runs for the table cases (A4/A5).
///
/// With [`ReduceMode::Driver`] (the default) every shard task ships its
/// raw prediction chunk back and the driver concatenates rows before a
/// two-pass Pearson — bit-identical to the monolithic table path. With
/// [`ReduceMode::Worker`] each shard task reduces its chunk to six
/// streaming partial sums on the worker (`agg_chunk`) and the driver only
/// merges sums (`merge_sums`) — result ingress shrinks from `O(rows)` to
/// `O(shards)` per skill, and the resulting rho is within 1 ULP of the
/// driver-concat value (see `ccm::pipeline`'s worker-side reduce docs).
///
/// [`ReduceMode::Worker`] also covers the *single-table* pipeline
/// (`--shards 1`): the driver routes it through the sharded machinery with
/// one shard spanning every row, so the full prediction vector reduces
/// worker-side and each task returns one ~48-byte sums record instead of
/// `O(rows)` predictions. The brute-force cases (A2/A3) already return a
/// single scalar rho per task, so there is nothing to move and the mode is
/// ignored there.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ReduceMode {
    /// Ship raw predictions; concatenate and reduce on the driver.
    #[default]
    Driver,
    /// Reduce to partial Pearson sums on the workers; merge on the driver.
    Worker,
}

impl ReduceMode {
    /// Parse a CLI mode name (`--reduce worker`, case-insensitive).
    pub fn parse(s: &str) -> Option<ReduceMode> {
        match s.trim().to_ascii_lowercase().as_str() {
            "driver" => Some(ReduceMode::Driver),
            "worker" => Some(ReduceMode::Worker),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ReduceMode::Driver => "driver",
            ReduceMode::Worker => "worker",
        }
    }
}

/// A single, composable description of one case run — the one entry point
/// the driver exposes. Build it, chain the knobs you care about, then call
/// [`RunSpec::run`] (one deploy) or [`RunSpec::run_multi`] (one execution,
/// many DES topologies):
///
/// ```no_run
/// # use parccm::ccm::driver::{Case, ReduceMode, RunSpec, TablePolicy};
/// # use parccm::ccm::params::Scenario;
/// # use parccm::engine::Deploy;
/// # use parccm::native::NativeBackend;
/// # use std::sync::Arc;
/// # let scenario = Scenario::smoke();
/// # let (effect, cause) = (vec![0.0f32; 64], vec![0.0f32; 64]);
/// let report = RunSpec::new(Case::A4, &scenario, &effect, &cause)
///     .deploy(Deploy::paper_cluster())
///     .policy(TablePolicy::TruncatedAuto)
///     .shards(3)
///     .reduce(ReduceMode::Worker)
///     .run(Arc::new(NativeBackend));
/// ```
///
/// Defaults: [`Deploy::SingleThread`], [`TablePolicy::TruncatedAuto`],
/// one shard (monolithic table broadcast), [`ReduceMode::Driver`].
/// Numerics never depend on the deploy, and the default policy / shard /
/// reduce combination is bit-identical to the paper's monolithic path.
#[derive(Clone)]
pub struct RunSpec<'a> {
    case: Case,
    scenario: &'a Scenario,
    effect: &'a [f32],
    cause: &'a [f32],
    deploy: Deploy,
    policy: TablePolicy,
    shards: usize,
    reduce: ReduceMode,
    partial: Option<PartialSpec>,
    cancel: Option<&'a AtomicBool>,
}

impl<'a> RunSpec<'a> {
    /// Describe a run of `case` over `scenario`, cross-mapping `cause`
    /// from the shadow manifold of `effect` (i.e. testing cause -> effect
    /// causality). All other knobs start at their defaults.
    pub fn new(case: Case, scenario: &'a Scenario, effect: &'a [f32], cause: &'a [f32]) -> Self {
        RunSpec {
            case,
            scenario,
            effect,
            cause,
            deploy: Deploy::SingleThread,
            policy: TablePolicy::default(),
            shards: 1,
            reduce: ReduceMode::default(),
            partial: None,
            cancel: None,
        }
    }

    /// Topology the DES replay prices ([`RunSpec::run`] only — the
    /// multi-deploy terminal takes its own list).
    pub fn deploy(mut self, deploy: Deploy) -> Self {
        self.deploy = deploy;
        self
    }

    /// Distance-table layout policy (table cases only).
    pub fn policy(mut self, policy: TablePolicy) -> Self {
        self.policy = policy;
        self
    }

    /// Split the distance table into `shards` per-node row-range shards
    /// (table cases only; `<= 1` keeps the monolithic broadcast).
    pub fn shards(mut self, shards: usize) -> Self {
        self.shards = shards;
        self
    }

    /// Where the Pearson reduction runs (sharded table cases only).
    pub fn reduce(mut self, reduce: ReduceMode) -> Self {
        self.reduce = reduce;
        self
    }

    /// Partial-evaluation contract (`--partial eps,conf`): stop dispatching
    /// a grid cell's remaining subsample tasks once the cell's mean-rho
    /// confidence interval at level `conf` has radius `<= eps`, and prune a
    /// whole (E, tau) slice once its completed cells are statistically
    /// decided non-convergent (see [`slice_decided`]). `None` (the default)
    /// is the exact seed path — bit-identical skills.
    pub fn partial(mut self, partial: Option<PartialSpec>) -> Self {
        self.partial = partial;
        self
    }

    /// Best-effort cancellation flag, checked at the partial-evaluation
    /// checkpoints (every dispatch wave / A1 task). When it reads `true`
    /// the run stops dispatching, keeps the skills harvested so far, and
    /// reports [`PartialOutcome::cancelled`]. A flag that never fires does
    /// not change the skills.
    pub fn cancel_flag(mut self, flag: &'a AtomicBool) -> Self {
        self.cancel = Some(flag);
        self
    }

    /// Execute on `backend`, pricing the configured deploy.
    pub fn run(self, backend: Arc<dyn ComputeBackend>) -> CaseReport {
        let case = self.case;
        // the knob-off contract is structural: with neither a partial spec
        // nor a cancel flag the seed code paths run untouched
        if self.partial.is_none() && self.cancel.is_none() {
            return match case {
                Case::A1 => run_a1(self.scenario, self.effect, self.cause, backend),
                _ => {
                    let deploys = [self.deploy.clone()];
                    let (skills, mut reports) = run_engine_case(
                        case,
                        self.scenario,
                        self.effect,
                        self.cause,
                        &deploys,
                        backend,
                        self.policy,
                        self.shards,
                        self.reduce,
                    );
                    CaseReport {
                        case,
                        skills,
                        report: reports.remove(0),
                        partial: PartialOutcome::default(),
                    }
                }
            };
        }
        match case {
            Case::A1 => run_a1_partial(
                self.scenario,
                self.effect,
                self.cause,
                backend,
                self.partial,
                self.cancel,
            ),
            _ => {
                let deploys = [self.deploy.clone()];
                let (skills, mut reports, outcome) = run_engine_case_partial(
                    case,
                    self.scenario,
                    self.effect,
                    self.cause,
                    &deploys,
                    backend,
                    self.policy,
                    self.shards,
                    self.reduce,
                    self.partial,
                    self.cancel,
                );
                CaseReport { case, skills, report: reports.remove(0), partial: outcome }
            }
        }
    }

    /// Execute ONCE, pricing MANY topologies via DES replay (numerics
    /// never depend on the deploy, so this is exact and saves re-running
    /// expensive cases per topology — e.g. Fig. 4's Local-vs-Yarn
    /// comparison). Ignores [`RunSpec::deploy`].
    pub fn run_multi(
        self,
        deploys: &[Deploy],
        backend: Arc<dyn ComputeBackend>,
    ) -> (Vec<SkillRow>, Vec<ExecutionReport>) {
        if self.partial.is_some() || self.cancel.is_some() {
            return match self.case {
                Case::A1 => {
                    let rep = run_a1_partial(
                        self.scenario,
                        self.effect,
                        self.cause,
                        backend,
                        self.partial,
                        self.cancel,
                    );
                    let reports = deploys.iter().map(|_| rep.report.clone()).collect();
                    (rep.skills, reports)
                }
                _ => {
                    let (skills, reports, _) = run_engine_case_partial(
                        self.case,
                        self.scenario,
                        self.effect,
                        self.cause,
                        deploys,
                        backend,
                        self.policy,
                        self.shards,
                        self.reduce,
                        self.partial,
                        self.cancel,
                    );
                    (skills, reports)
                }
            };
        }
        match self.case {
            Case::A1 => {
                let rep = run_a1(self.scenario, self.effect, self.cause, backend);
                let reports = deploys.iter().map(|_| rep.report.clone()).collect();
                (rep.skills, reports)
            }
            _ => run_engine_case(
                self.case,
                self.scenario,
                self.effect,
                self.cause,
                deploys,
                backend,
                self.policy,
                self.shards,
                self.reduce,
            ),
        }
    }
}

/// Outcome of one case run.
pub struct CaseReport {
    pub case: Case,
    /// Per-realization skills for every (E, tau, L) combination. Under
    /// `--partial` (or after a mid-run cancel) stopped cells carry only
    /// the realizations dispatched before the stop.
    pub skills: Vec<SkillRow>,
    /// Measured + DES-simulated costs (for A1 the two coincide).
    pub report: ExecutionReport,
    /// What partial evaluation did (all-zero/false when the knob was off
    /// and no cancel fired).
    pub partial: PartialOutcome,
}

/// Tally of what the partial-evaluation driver decided during one run.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PartialOutcome {
    /// Grid cells stopped before their full subsample budget (CI-tight
    /// stops plus cells pruned with their whole (E, tau) slice).
    pub stops: u64,
    /// Subsample tasks never dispatched because of those stops.
    pub saved_tasks: u64,
    /// True when a [`RunSpec::cancel_flag`] fired mid-run and the run
    /// returned early with the skills harvested so far.
    pub cancelled: bool,
}

/// True when the completed cells of one (E, tau) slice already decide the
/// convergence verdict against causality, so the slice's remaining
/// (larger-L) cells cannot flip it: with at least two completed cells,
/// [`crate::ccm::convergence::assess`] at zero thresholds must report a
/// broken monotone trend (`!increasing`) *and* a net skill **drop** from
/// the smallest to the largest completed library of at least `eps` — the
/// resolution the `--partial eps,conf` contract says the caller cares
/// about. Future cells can only widen the noise tolerance, not un-break a
/// drop that size, so dispatching them cannot produce a causal verdict.
pub fn slice_decided(cells: &[SkillSummary], eps: f64) -> bool {
    if cells.len() < 2 {
        return false;
    }
    let v = crate::ccm::convergence::assess(cells, 0.0, 0.0);
    !v.increasing && v.delta <= -eps
}

/// Canonical JSON dump of a skill set: rows sorted by (E, tau, L, sample)
/// with `rho` as an exact f32 -> f64 shortest-roundtrip number — two runs
/// are bit-identical iff their dumps are byte-identical, which is what
/// the `cluster-remote` CI job diffs across backends (`--dump-skills`).
pub fn skills_to_json(skills: &[SkillRow]) -> Json {
    let mut rows: Vec<&SkillRow> = skills.iter().collect();
    rows.sort_by_key(|r| (r.params.e, r.params.tau, r.params.l, r.sample_id));
    Json::obj(vec![(
        "skills",
        Json::Arr(
            rows.into_iter()
                .map(|r| {
                    Json::obj(vec![
                        ("e", Json::Num(r.params.e as f64)),
                        ("tau", Json::Num(r.params.tau as f64)),
                        ("l", Json::Num(r.params.l as f64)),
                        ("sample", Json::Num(r.sample_id as f64)),
                        ("rho", Json::Num(r.rho as f64)),
                    ])
                })
                .collect(),
        ),
    )])
}

/// An owned, wire-serializable description of one case run — the unit of
/// work a `parccm serve` daemon accepts. A [`RunSpec`] borrows its
/// scenario and input series; a `JobSpec` owns the scenario and
/// *regenerates* the series from it (the coupled-logistic generator is
/// deterministic in `series_len`), so a job crosses the wire as one small
/// JSON object and still reproduces the batch path byte for byte:
/// [`JobSpec::run`] builds exactly the series and [`RunSpec`] that
/// `parccm fig4` builds for the same flags.
#[derive(Clone, Debug)]
pub struct JobSpec {
    /// Implementation level to run.
    pub case: Case,
    /// Owned parameter grid; the input series regenerate from
    /// `series_len` via the default coupled-logistic map.
    pub scenario: Scenario,
    /// Distance-table layout (table cases only).
    pub policy: TablePolicy,
    /// Row-range table shards (`<= 1` keeps the monolithic broadcast).
    pub shards: usize,
    /// Where the Pearson reduction runs.
    pub reduce: ReduceMode,
    /// Partial-evaluation contract (`--partial eps,conf`); `None` (the
    /// default) runs the exact batch path.
    pub partial: Option<PartialSpec>,
}

impl JobSpec {
    /// A job with all-default knobs, mirroring [`RunSpec::new`].
    pub fn new(case: Case, scenario: Scenario) -> JobSpec {
        JobSpec {
            case,
            scenario,
            policy: TablePolicy::default(),
            shards: 1,
            reduce: ReduceMode::default(),
            partial: None,
        }
    }

    /// Serialize for the v7 `submit` control message. The sorted-key JSON
    /// writer makes equal specs serialize identically, which is what lets
    /// the serve daemon share driver payload-cache entries (and therefore
    /// broadcast ships) across jobs posing the same problem.
    pub fn to_json(&self) -> Json {
        let policy = match self.policy {
            TablePolicy::Full => Json::Str("full".into()),
            TablePolicy::TruncatedAuto => Json::Str("auto".into()),
            TablePolicy::Truncated(p) => Json::Num(p as f64),
        };
        let nums = |xs: &[usize]| Json::Arr(xs.iter().map(|&v| Json::Num(v as f64)).collect());
        let mut pairs = vec![
            ("case", Json::Str(self.case.name().into())),
            ("policy", policy),
            ("reduce", Json::Str(self.reduce.name().into())),
            ("shards", Json::Num(self.shards as f64)),
            (
                "scenario",
                Json::obj(vec![
                    ("series_len", Json::Num(self.scenario.series_len as f64)),
                    ("r", Json::Num(self.scenario.r as f64)),
                    ("es", nums(&self.scenario.es)),
                    ("ls", nums(&self.scenario.ls)),
                    ("taus", nums(&self.scenario.taus)),
                    ("theiler", Json::Num(self.scenario.theiler as f64)),
                    ("seed", Json::Num(self.scenario.seed as f64)),
                    ("partitions", Json::Num(self.scenario.partitions as f64)),
                ]),
            ),
        ];
        if let Some(spec) = &self.partial {
            // the CLI grammar, round-trip exact through Rust's
            // shortest-roundtrip float formatting
            pairs.push(("partial", Json::Str(format!("{},{}", spec.eps, spec.conf))));
        }
        Json::obj(pairs)
    }

    /// Parse a `submit` spec. Strict on the scenario (every field
    /// required); the knobs (`policy`/`shards`/`reduce`) default exactly
    /// like [`RunSpec::new`] when absent. Errors are strings the daemon
    /// bounces back to the client verbatim.
    pub fn from_json(j: &Json) -> Result<JobSpec, String> {
        fn num(j: &Json, key: &str) -> Result<usize, String> {
            j.get(key)
                .and_then(Json::as_f64)
                .map(|v| v as usize)
                .ok_or_else(|| format!("spec scenario: missing numeric `{key}`"))
        }
        fn nums(j: &Json, key: &str) -> Result<Vec<usize>, String> {
            let arr = j
                .get(key)
                .and_then(Json::as_arr)
                .ok_or_else(|| format!("spec scenario: missing array `{key}`"))?;
            arr.iter()
                .map(|v| {
                    v.as_f64()
                        .map(|v| v as usize)
                        .ok_or_else(|| format!("spec scenario: non-numeric `{key}` entry"))
                })
                .collect()
        }
        let case = j
            .get("case")
            .and_then(Json::as_str)
            .and_then(Case::parse)
            .ok_or("spec: missing or unknown `case`")?;
        let policy = match j.get("policy") {
            None => TablePolicy::default(),
            Some(Json::Str(s)) if s.as_str() == "full" => TablePolicy::Full,
            Some(Json::Str(s)) if s.as_str() == "auto" => TablePolicy::TruncatedAuto,
            Some(p) => TablePolicy::Truncated(
                p.as_f64().map(|v| v as usize).ok_or("spec: bad `policy`")?,
            ),
        };
        let reduce = match j.get("reduce").and_then(Json::as_str) {
            Some(s) => ReduceMode::parse(s).ok_or("spec: unknown `reduce`")?,
            None => ReduceMode::default(),
        };
        let shards = match j.get("shards") {
            Some(v) => v.as_f64().map(|v| v as usize).ok_or("spec: bad `shards`")?,
            None => 1,
        };
        let partial = match j.get("partial") {
            None => None,
            Some(Json::Str(s)) => {
                Some(PartialSpec::parse(s).ok_or("spec: bad `partial` (want \"eps,conf\")")?)
            }
            Some(_) => return Err("spec: bad `partial` (want \"eps,conf\")".into()),
        };
        let sc = j.get("scenario").ok_or("spec: missing `scenario`")?;
        let seed = sc
            .get("seed")
            .and_then(Json::as_f64)
            .map(|v| v as u64)
            .ok_or("spec scenario: missing numeric `seed`")?;
        let scenario = Scenario {
            series_len: num(sc, "series_len")?,
            r: num(sc, "r")?,
            ls: nums(sc, "ls")?,
            es: nums(sc, "es")?,
            taus: nums(sc, "taus")?,
            theiler: num(sc, "theiler")?,
            seed,
            partitions: num(sc, "partitions")?,
        };
        Ok(JobSpec { case, scenario, policy, shards, reduce, partial })
    }

    /// Execute on `backend`, regenerating the input series exactly as
    /// `parccm fig4` does (effect = y, cause = x of the coupled-logistic
    /// pair) — the skills, and therefore the canonical [`skills_to_json`]
    /// dump, are byte-identical to the batch path.
    pub fn run(&self, backend: Arc<dyn ComputeBackend>) -> CaseReport {
        self.run_with_cancel(backend, None)
    }

    /// Like [`JobSpec::run`], threading a best-effort cancellation flag
    /// into the driver: the serve daemon sets it when a `cancel` arrives
    /// for a *running* job, and the run returns early (with
    /// [`PartialOutcome::cancelled`] set) at the next partial-evaluation
    /// checkpoint.
    pub fn run_with_cancel(
        &self,
        backend: Arc<dyn ComputeBackend>,
        cancel: Option<&AtomicBool>,
    ) -> CaseReport {
        let (x, y) = crate::timeseries::generators::coupled_logistic(
            self.scenario.series_len,
            crate::timeseries::generators::CoupledLogisticParams::default(),
        );
        let mut spec = RunSpec::new(self.case, &self.scenario, &y, &x)
            .policy(self.policy)
            .shards(self.shards)
            .reduce(self.reduce)
            .partial(self.partial);
        if let Some(flag) = cancel {
            spec = spec.cancel_flag(flag);
        }
        spec.run(backend)
    }
}

/// Case A1: plain sequential loop, no engine. The measured wallclock *is*
/// the report (a single-threaded run has nothing to simulate). One
/// [`TaskArena`] serves the whole sweep — the sequential baseline enjoys
/// the same zero-copy task path as the pipelines.
fn run_a1(
    scenario: &Scenario,
    effect: &[f32],
    cause: &[f32],
    backend: Arc<dyn ComputeBackend>,
) -> CaseReport {
    let t = Instant::now();
    let master = Rng::new(scenario.seed);
    let mut skills = Vec::new();
    let mut arena = TaskArena::new();
    for &e in &scenario.es {
        for &tau in &scenario.taus {
            let problem = CcmProblem::new(effect, cause, e, tau, scenario.theiler as f32);
            for &l in &scenario.ls {
                let params = crate::ccm::params::CcmParams::new(e, tau, l);
                for sample in draw_samples(&master, params, problem.emb.n, scenario.r) {
                    let rho = backend.cross_map_into(&problem.input_for(&sample), &mut arena);
                    skills.push(SkillRow { params, sample_id: sample.sample_id, rho });
                }
            }
        }
    }
    let wall = t.elapsed().as_secs_f64();
    CaseReport {
        case: Case::A1,
        skills,
        report: ExecutionReport {
            measured_wall_s: wall,
            total_task_s: wall,
            sim_makespan_s: wall,
            sim_utilization: 1.0,
            sim_broadcast_ship_s: 0.0,
            sim_broadcast_ship_bytes: 0,
            sim_repair_ship_s: 0.0,
            sim_repair_ship_bytes: 0,
            sim_rejoin_ship_s: 0.0,
            sim_rejoin_ship_bytes: 0,
            sim_speculative_task_s: 0.0,
            sim_partial_saved_task_s: 0.0,
            sim_result_ingress_bytes: 0,
            sim_concurrent_jobs: 1,
            topology: "single-thread".to_string(),
        },
        partial: PartialOutcome::default(),
    }
}

/// Case A1 under `--partial` and/or a cancel flag: the same sequential
/// loop as [`run_a1`], with the full subsample budget always *drawn* (the
/// master Rng stream — and therefore every later cell's draws — must match
/// the full run exactly whatever this cell decides) but evaluation stopping
/// early per cell once the [`BoundedRho`] interval is tight, per slice once
/// [`slice_decided`], and everywhere once the cancel flag fires.
fn run_a1_partial(
    scenario: &Scenario,
    effect: &[f32],
    cause: &[f32],
    backend: Arc<dyn ComputeBackend>,
    partial: Option<PartialSpec>,
    cancel: Option<&AtomicBool>,
) -> CaseReport {
    let t = Instant::now();
    let master = Rng::new(scenario.seed);
    let mut skills = Vec::new();
    let mut arena = TaskArena::new();
    let mut outcome = PartialOutcome::default();
    'grid: for &e in &scenario.es {
        for &tau in &scenario.taus {
            let problem = CcmProblem::new(effect, cause, e, tau, scenario.theiler as f32);
            let mut slice_cells: Vec<SkillSummary> = Vec::new();
            let mut pruned = false;
            for &l in &scenario.ls {
                let params = crate::ccm::params::CcmParams::new(e, tau, l);
                let samples = draw_samples(&master, params, problem.emb.n, scenario.r);
                if pruned {
                    outcome.stops += 1;
                    outcome.saved_tasks += samples.len() as u64;
                    continue;
                }
                let mut ev = BoundedRho::new();
                let mut cell_rows = Vec::new();
                let mut done = 0usize;
                for sample in &samples {
                    if cancel.is_some_and(|c| c.load(Ordering::Relaxed)) {
                        outcome.cancelled = true;
                        skills.extend(cell_rows);
                        break 'grid;
                    }
                    let rho = backend.cross_map_into(&problem.input_for(sample), &mut arena);
                    cell_rows.push(SkillRow { params, sample_id: sample.sample_id, rho });
                    ev.observe(rho);
                    done += 1;
                    if done < samples.len()
                        && partial.as_ref().is_some_and(|spec| ev.decided(spec))
                    {
                        outcome.stops += 1;
                        outcome.saved_tasks += (samples.len() - done) as u64;
                        break;
                    }
                }
                slice_cells.extend(summarize(&cell_rows));
                skills.extend(cell_rows);
                if let Some(spec) = &partial {
                    if slice_decided(&slice_cells, spec.eps) {
                        pruned = true;
                    }
                }
            }
        }
    }
    backend.record_partial(outcome.stops, outcome.saved_tasks);
    let wall = t.elapsed().as_secs_f64();
    CaseReport {
        case: Case::A1,
        skills,
        report: ExecutionReport {
            measured_wall_s: wall,
            total_task_s: wall,
            sim_makespan_s: wall,
            sim_utilization: 1.0,
            sim_broadcast_ship_s: 0.0,
            sim_broadcast_ship_bytes: 0,
            sim_repair_ship_s: 0.0,
            sim_repair_ship_bytes: 0,
            sim_rejoin_ship_s: 0.0,
            sim_rejoin_ship_bytes: 0,
            sim_speculative_task_s: 0.0,
            sim_partial_saved_task_s: 0.0,
            sim_result_ingress_bytes: 0,
            sim_concurrent_jobs: 1,
            topology: "single-thread".to_string(),
        },
        partial: outcome,
    }
}

/// Modeled *raw* bytes per harvested result element for the DES
/// `sim_result_ingress_bytes` tally: one f32 prediction row, one
/// six-f64 partial-sums record, one f32 rho per skill row. Raw sizes
/// match the v6 binary wire; when the backend reports a JSON-pinned pool
/// ([`ComputeBackend::wire_pricing`]) the tally inflates through
/// [`crate::engine::config::WirePricing::bytes`] so the model tracks the
/// decimal-text wire.
const PRED_WIRE_BYTES: u64 = 4;
const SUMS_WIRE_BYTES: u64 = 48;
const ROW_WIRE_BYTES: u64 = 4;

/// Cases A2–A5: engine-scheduled pipelines. Executes once; returns one
/// [`ExecutionReport`] per requested deploy (DES replays of the same log).
#[allow(clippy::too_many_arguments)]
fn run_engine_case(
    case: Case,
    scenario: &Scenario,
    effect: &[f32],
    cause: &[f32],
    deploys: &[Deploy],
    backend: Arc<dyn ComputeBackend>,
    policy: TablePolicy,
    shards: usize,
    reduce: ReduceMode,
) -> (Vec<SkillRow>, Vec<ExecutionReport>) {
    // wire encoding the pool actually negotiated — prices both the DES
    // broadcast/repair/rejoin model and the result-ingress tally below
    let pricing = backend.wire_pricing();
    let ctx = Context::new(
        EngineConfig::new(deploys[0].clone())
            .with_default_parallelism(scenario.partitions)
            .with_wire_pricing(pricing),
    );
    let master = Rng::new(scenario.seed);
    let mut skills = Vec::new();
    // modeled result-ingress tally, mirrored into every report's
    // `sim_result_ingress_bytes` — the quantity worker-side reduce shrinks
    let mut ingress: u64 = 0;
    let min_l = scenario.ls.iter().copied().min().unwrap_or(1);

    // One problem + (optionally) one distance table per (E, tau); L only
    // affects the subsample draws. In the asynchronous cases (§3.3 /
    // Fig. 3) ALL combinations' transform jobs are submitted before any is
    // harvested, so independent pipelines overlap across the whole grid;
    // the synchronous cases block on every action. With a sharded table
    // the transform is one job per shard; prediction chunks are combined
    // driver-side into skills (bit-identical — see ccm::pipeline docs).
    // async work is grouped per problem so its broadcast wire ids can be
    // evicted from distributed backends the moment THAT problem's jobs
    // are harvested (bounds driver + worker memory over the grid instead
    // of peaking at the whole grid; a no-op for in-process backends)
    let mut pending = Vec::new();
    let mut pending_chunks = Vec::new();
    let mut pending_sums = Vec::new();
    for &e in &scenario.es {
        for &tau in &scenario.taus {
            let problem = CcmProblem::new(effect, cause, e, tau, scenario.theiler as f32);
            let n_manifold = problem.emb.n;
            let size = problem.size_bytes();
            let problem_b = ctx.broadcast(problem, size);

            // The distance indexing table is a hard dependency of its
            // transform jobs: its (internally parallel) pipeline blocks the
            // driver, exactly like the barrier in the paper's Fig. 2/3 DAG.
            let mode = policy.mode_for(n_manifold, min_l);
            // worker-side reduce needs the sharded machinery even for the
            // single-table pipeline: one shard spanning every row gives the
            // agg tasks a chunk to fold into partial sums
            let sharded_b =
                if case.uses_table() && (shards > 1 || reduce == ReduceMode::Worker) {
                    Some(sharded_table_pipeline_mode(
                        &ctx,
                        &problem_b,
                        scenario.partitions,
                        mode,
                        shards.max(1),
                    ))
                } else {
                    None
                };
            let table_b = if case.uses_table() && sharded_b.is_none() {
                Some(table_pipeline_mode(&ctx, &problem_b, scenario.partitions, mode))
            } else {
                None
            };

            // every wire id this problem's tasks can reference: the
            // brute-force problem broadcast plus, when sharded, the
            // targets column and each table shard
            let mut bcast_ids = {
                let p = problem_b.value();
                vec![problem_wire_id(&p.emb.vecs, &p.targets, &p.times)]
            };
            if let Some(sharded) = &sharded_b {
                bcast_ids.push(targets_wire_id(&problem_b.value().targets));
                bcast_ids.extend(sharded.shards().iter().map(|b| b.value().wire_id()));
            }

            let mut sync_chunks = Vec::new();
            let mut sync_sums = Vec::new();
            let mut async_chunk_futs = Vec::new();
            let mut async_sums_futs = Vec::new();
            let mut async_skill_futs = Vec::new();
            for &l in &scenario.ls {
                let params = crate::ccm::params::CcmParams::new(e, tau, l);
                let samples = draw_samples(&master, params, n_manifold, scenario.r);
                let rdd = ctx.parallelize_with(samples, scenario.partitions);
                if let Some(sharded) = &sharded_b {
                    let b = Arc::clone(&backend);
                    if reduce == ReduceMode::Worker {
                        // shuffle-stage reduce: each shard job returns six
                        // partial Pearson sums instead of its prediction rows
                        for sums_rdd in sharded_agg_rdds(&ctx, &rdd, &problem_b, sharded, b) {
                            if case.is_async() {
                                async_sums_futs.push(ctx.collect_async(&sums_rdd));
                            } else {
                                sync_sums.extend(ctx.collect(&sums_rdd));
                            }
                        }
                    } else {
                        for chunk_rdd in sharded_transform_rdds(&ctx, &rdd, &problem_b, sharded, b)
                        {
                            if case.is_async() {
                                async_chunk_futs.push(ctx.collect_async(&chunk_rdd));
                            } else {
                                sync_chunks.extend(ctx.collect(&chunk_rdd));
                            }
                        }
                    }
                    continue;
                }
                let skill_rdd = match &table_b {
                    Some(table) => {
                        table_transform_rdd(&ctx, rdd, &problem_b, table, Arc::clone(&backend))
                    }
                    None => ccm_transform_rdd(&ctx, rdd, &problem_b, Arc::clone(&backend)),
                };
                if case.is_async() {
                    async_skill_futs.push(ctx.collect_async(&skill_rdd));
                } else {
                    let got = ctx.collect(&skill_rdd);
                    ingress += pricing.bytes(got.len() as u64 * ROW_WIRE_BYTES);
                    skills.extend(got);
                }
            }
            if !sync_chunks.is_empty() {
                ingress += pricing.bytes(
                    sync_chunks.iter().map(|c| c.preds.len() as u64 * PRED_WIRE_BYTES).sum::<u64>(),
                );
                skills.extend(combine_shard_chunks(sync_chunks, problem_b.value()));
            }
            if !sync_sums.is_empty() {
                ingress += pricing.bytes(sync_sums.len() as u64 * SUMS_WIRE_BYTES);
                skills.extend(combine_shard_sums(sync_sums, problem_b.value(), backend.as_ref()));
            }
            if !async_chunk_futs.is_empty() {
                pending_chunks.push((problem_b.clone(), async_chunk_futs, bcast_ids));
            } else if !async_sums_futs.is_empty() {
                pending_sums.push((problem_b.clone(), async_sums_futs, bcast_ids));
            } else if !async_skill_futs.is_empty() {
                pending.push((async_skill_futs, bcast_ids));
            } else {
                // synchronous cases harvested this problem above
                backend.evict_broadcasts(&bcast_ids);
            }
        }
    }
    for (futs, bcast_ids) in pending {
        for fa in futs {
            let got = fa.get();
            ingress += pricing.bytes(got.len() as u64 * ROW_WIRE_BYTES);
            skills.extend(got);
        }
        backend.evict_broadcasts(&bcast_ids);
    }
    for (problem_b, futs, bcast_ids) in pending_chunks {
        let mut chunks = Vec::new();
        for fa in futs {
            chunks.extend(fa.get());
        }
        ingress += pricing
            .bytes(chunks.iter().map(|c| c.preds.len() as u64 * PRED_WIRE_BYTES).sum::<u64>());
        skills.extend(combine_shard_chunks(chunks, problem_b.value()));
        backend.evict_broadcasts(&bcast_ids);
    }
    for (problem_b, futs, bcast_ids) in pending_sums {
        let mut sums = Vec::new();
        for fa in futs {
            sums.extend(fa.get());
        }
        ingress += pricing.bytes(sums.len() as u64 * SUMS_WIRE_BYTES);
        skills.extend(combine_shard_sums(sums, problem_b.value(), backend.as_ref()));
        backend.evict_broadcasts(&bcast_ids);
    }

    let mut reports: Vec<ExecutionReport> =
        deploys.iter().map(|d| ctx.report_for(d.clone())).collect();
    for r in &mut reports {
        r.sim_result_ingress_bytes = ingress;
    }
    (skills, reports)
}

/// Cases A2–A5 under `--partial` and/or a cancel flag. A separate driver
/// from [`run_engine_case`] on purpose: the seed path stays untouched, so
/// the knob-off bit-identity contract holds structurally.
///
/// Partial evaluation needs results *before* deciding whether to dispatch
/// more, so each cell's subsample budget is dispatched synchronously in
/// **waves** (one task per partition per wave) instead of one bulk job —
/// the asynchronous cases (A3/A5) degrade to this wave-synchronous
/// schedule too. The full budget is always *drawn* per cell so the master
/// Rng stream matches the full run exactly; stopping only skips dispatch.
/// Harvested rhos feed a per-cell [`BoundedRho`] in sample-id order (the
/// stop decision is deterministic for a fixed seed), a tight interval
/// stops the cell, and [`slice_decided`] prunes the remaining cells of an
/// (E, tau) slice outright. The cancel flag is checked at every wave
/// boundary. Saved tasks are priced into `sim_partial_saved_task_s` at the
/// mean measured task duration — exactly the DES
/// `sim_partial_saved_tasks` formula, applied post-hoc because the saved
/// tasks are absent from the replayed log.
#[allow(clippy::too_many_arguments)]
fn run_engine_case_partial(
    case: Case,
    scenario: &Scenario,
    effect: &[f32],
    cause: &[f32],
    deploys: &[Deploy],
    backend: Arc<dyn ComputeBackend>,
    policy: TablePolicy,
    shards: usize,
    reduce: ReduceMode,
    partial: Option<PartialSpec>,
    cancel: Option<&AtomicBool>,
) -> (Vec<SkillRow>, Vec<ExecutionReport>, PartialOutcome) {
    let pricing = backend.wire_pricing();
    let ctx = Context::new(
        EngineConfig::new(deploys[0].clone())
            .with_default_parallelism(scenario.partitions)
            .with_wire_pricing(pricing),
    );
    let master = Rng::new(scenario.seed);
    let mut skills = Vec::new();
    let mut ingress: u64 = 0;
    let mut outcome = PartialOutcome::default();
    let min_l = scenario.ls.iter().copied().min().unwrap_or(1);
    // one decision checkpoint per wave: enough samples to fill every
    // partition with one task
    let wave = scenario.partitions.max(1);
    'grid: for &e in &scenario.es {
        for &tau in &scenario.taus {
            let problem = CcmProblem::new(effect, cause, e, tau, scenario.theiler as f32);
            let n_manifold = problem.emb.n;
            let size = problem.size_bytes();
            let problem_b = ctx.broadcast(problem, size);
            let mode = policy.mode_for(n_manifold, min_l);
            let sharded_b =
                if case.uses_table() && (shards > 1 || reduce == ReduceMode::Worker) {
                    Some(sharded_table_pipeline_mode(
                        &ctx,
                        &problem_b,
                        scenario.partitions,
                        mode,
                        shards.max(1),
                    ))
                } else {
                    None
                };
            let table_b = if case.uses_table() && sharded_b.is_none() {
                Some(table_pipeline_mode(&ctx, &problem_b, scenario.partitions, mode))
            } else {
                None
            };
            let mut bcast_ids = {
                let p = problem_b.value();
                vec![problem_wire_id(&p.emb.vecs, &p.targets, &p.times)]
            };
            if let Some(sharded) = &sharded_b {
                bcast_ids.push(targets_wire_id(&problem_b.value().targets));
                bcast_ids.extend(sharded.shards().iter().map(|b| b.value().wire_id()));
            }
            let mut slice_cells: Vec<SkillSummary> = Vec::new();
            let mut pruned = false;
            for &l in &scenario.ls {
                let params = crate::ccm::params::CcmParams::new(e, tau, l);
                let samples = draw_samples(&master, params, n_manifold, scenario.r);
                let total = samples.len();
                if pruned {
                    outcome.stops += 1;
                    outcome.saved_tasks += total as u64;
                    continue;
                }
                let mut ev = BoundedRho::new();
                let mut cell_rows: Vec<SkillRow> = Vec::new();
                let mut next = 0usize;
                while next < total {
                    if cancel.is_some_and(|c| c.load(Ordering::Relaxed)) {
                        outcome.cancelled = true;
                        skills.extend(cell_rows);
                        backend.evict_broadcasts(&bcast_ids);
                        break 'grid;
                    }
                    let hi = (next + wave).min(total);
                    let batch = samples[next..hi].to_vec();
                    let parts = scenario.partitions.min(hi - next).max(1);
                    let rdd = ctx.parallelize_with(batch, parts);
                    let mut wave_rows: Vec<SkillRow> = if let Some(sharded) = &sharded_b {
                        if reduce == ReduceMode::Worker {
                            let mut sums = Vec::new();
                            for sums_rdd in
                                sharded_agg_rdds(&ctx, &rdd, &problem_b, sharded, Arc::clone(&backend))
                            {
                                sums.extend(ctx.collect(&sums_rdd));
                            }
                            ingress += pricing.bytes(sums.len() as u64 * SUMS_WIRE_BYTES);
                            combine_shard_sums(sums, problem_b.value(), backend.as_ref())
                        } else {
                            let mut chunks = Vec::new();
                            for chunk_rdd in sharded_transform_rdds(
                                &ctx,
                                &rdd,
                                &problem_b,
                                sharded,
                                Arc::clone(&backend),
                            ) {
                                chunks.extend(ctx.collect(&chunk_rdd));
                            }
                            ingress += pricing.bytes(
                                chunks
                                    .iter()
                                    .map(|c| c.preds.len() as u64 * PRED_WIRE_BYTES)
                                    .sum::<u64>(),
                            );
                            combine_shard_chunks(chunks, problem_b.value())
                        }
                    } else {
                        let skill_rdd = match &table_b {
                            Some(table) => table_transform_rdd(
                                &ctx,
                                rdd,
                                &problem_b,
                                table,
                                Arc::clone(&backend),
                            ),
                            None => ccm_transform_rdd(&ctx, rdd, &problem_b, Arc::clone(&backend)),
                        };
                        let got = ctx.collect(&skill_rdd);
                        ingress += pricing.bytes(got.len() as u64 * ROW_WIRE_BYTES);
                        got
                    };
                    // the evaluator's observation order is pinned to
                    // sample-id order within the wave, whatever order the
                    // backend returned rows in
                    wave_rows.sort_by_key(|r| r.sample_id);
                    for row in &wave_rows {
                        ev.observe(row.rho);
                    }
                    cell_rows.extend(wave_rows);
                    next = hi;
                    if next < total && partial.as_ref().is_some_and(|spec| ev.decided(spec)) {
                        outcome.stops += 1;
                        outcome.saved_tasks += (total - next) as u64;
                        break;
                    }
                }
                slice_cells.extend(summarize(&cell_rows));
                skills.extend(cell_rows);
                if let Some(spec) = &partial {
                    if slice_decided(&slice_cells, spec.eps) {
                        pruned = true;
                    }
                }
            }
            if !outcome.cancelled {
                backend.evict_broadcasts(&bcast_ids);
            }
        }
    }
    backend.record_partial(outcome.stops, outcome.saved_tasks);
    // saved tasks are absent from the replayed log, so their DES price is
    // applied post-hoc: the mean measured task duration per saved task —
    // the same formula as `EngineConfig::sim_partial_saved_tasks`
    let saved_task_s = if outcome.saved_tasks > 0 {
        let tasks = ctx.events().tasks();
        if tasks.is_empty() {
            0.0
        } else {
            let mean = tasks.iter().map(|t| t.duration).sum::<f64>() / tasks.len() as f64;
            outcome.saved_tasks as f64 * mean
        }
    } else {
        0.0
    };
    let reports = deploys
        .iter()
        .map(|d| {
            let mut report = ctx.report_for(d.clone());
            report.sim_result_ingress_bytes = ingress;
            report.sim_partial_saved_task_s = saved_task_s;
            report
        })
        .collect();
    (skills, reports, outcome)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::native::NativeBackend;
    use crate::timeseries::generators::{coupled_logistic, CoupledLogisticParams};
    use crate::KMAX;

    fn series() -> (Vec<f32>, Vec<f32>) {
        coupled_logistic(300, CoupledLogisticParams::default())
    }

    fn sorted_skills(mut rows: Vec<SkillRow>) -> Vec<(usize, usize, usize, usize, f32)> {
        rows.sort_by_key(|r| (r.params.e, r.params.tau, r.params.l, r.sample_id));
        rows.iter()
            .map(|r| (r.params.e, r.params.tau, r.params.l, r.sample_id, r.rho))
            .collect()
    }

    #[test]
    fn all_cases_agree_on_skills() {
        let (x, y) = series();
        let scenario = Scenario::smoke();
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend);
        let deploy = Deploy::Local { cores: 2 };
        let a1 = RunSpec::new(Case::A1, &scenario, &y, &x)
            .deploy(deploy.clone())
            .run(Arc::clone(&backend));
        let expected = sorted_skills(a1.skills);
        assert_eq!(
            expected.len(),
            scenario.combos().len() * scenario.r,
            "A1 skill count"
        );
        // every engine case, and for the table cases every table layout —
        // full, auto-truncated, and a pathologically short prefix that
        // forces the brute-force fallback on most queries.
        let runs: Vec<(Case, TablePolicy)> = vec![
            (Case::A2, TablePolicy::Full),
            (Case::A3, TablePolicy::Full),
            (Case::A4, TablePolicy::Full),
            (Case::A4, TablePolicy::TruncatedAuto),
            (Case::A4, TablePolicy::Truncated(KMAX)),
            (Case::A5, TablePolicy::Full),
            (Case::A5, TablePolicy::TruncatedAuto),
            (Case::A5, TablePolicy::Truncated(KMAX)),
        ];
        for (case, policy) in runs {
            let rep = RunSpec::new(case, &scenario, &y, &x)
                .deploy(deploy.clone())
                .policy(policy)
                .run(Arc::clone(&backend));
            let got = sorted_skills(rep.skills);
            assert_eq!(got.len(), expected.len(), "{case:?}/{policy:?} skill count");
            for (a, b) in expected.iter().zip(&got) {
                assert_eq!(
                    (a.0, a.1, a.2, a.3),
                    (b.0, b.1, b.2, b.3),
                    "{case:?}/{policy:?} keys"
                );
                assert!(
                    (a.4 - b.4).abs() < 1e-5,
                    "{case:?}/{policy:?}: rho {} vs A1 {} at {:?}",
                    b.4,
                    a.4,
                    (a.0, a.1, a.2, a.3)
                );
            }
        }
    }

    #[test]
    fn sharded_table_cases_agree_with_a1() {
        let (x, y) = series();
        let scenario = Scenario::smoke();
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend);
        let deploy = Deploy::Local { cores: 2 };
        let a1 = RunSpec::new(Case::A1, &scenario, &y, &x)
            .deploy(deploy.clone())
            .run(Arc::clone(&backend));
        let expected = sorted_skills(a1.skills);
        // monolithic-table reference: sharded must be bit-identical to it
        let mono = RunSpec::new(Case::A4, &scenario, &y, &x)
            .deploy(deploy.clone())
            .policy(TablePolicy::TruncatedAuto)
            .run(Arc::clone(&backend));
        let mono = sorted_skills(mono.skills);
        for (case, shards) in [(Case::A4, 2), (Case::A4, 5), (Case::A5, 3)] {
            let rep = RunSpec::new(case, &scenario, &y, &x)
                .deploy(deploy.clone())
                .policy(TablePolicy::TruncatedAuto)
                .shards(shards)
                .run(Arc::clone(&backend));
            let got = sorted_skills(rep.skills);
            assert_eq!(got.len(), expected.len(), "{case:?}/{shards} shards skill count");
            for ((a, b), m) in expected.iter().zip(&got).zip(&mono) {
                assert_eq!((a.0, a.1, a.2, a.3), (b.0, b.1, b.2, b.3));
                assert!(
                    (a.4 - b.4).abs() < 1e-5,
                    "{case:?}/{shards} shards: rho {} vs A1 {}",
                    b.4,
                    a.4
                );
                assert_eq!(b.4, m.4, "{case:?}/{shards} shards: must equal monolithic table");
            }
        }
    }

    #[test]
    fn case_parse_round_trips() {
        for c in Case::ALL {
            assert_eq!(Case::parse(c.name()), Some(c));
        }
        assert_eq!(Case::parse("a4"), Some(Case::A4));
        assert_eq!(Case::parse(" A5 "), Some(Case::A5));
        assert_eq!(Case::parse("B9"), None);
    }

    #[test]
    fn skills_dump_is_order_invariant_and_exact() {
        use crate::ccm::params::CcmParams;
        let a = SkillRow { params: CcmParams::new(2, 1, 100), sample_id: 1, rho: 0.25f32 };
        let b = SkillRow { params: CcmParams::new(2, 1, 100), sample_id: 0, rho: 0.1f32 };
        let fwd = skills_to_json(&[a, b]).to_string();
        let rev = skills_to_json(&[b, a]).to_string();
        assert_eq!(fwd, rev, "dump must canonicalize row order");
        // 0.1f32 -> f64 is exact, and the writer round-trips it
        assert!(fwd.contains("\"sample\":0"), "{fwd}");
        let parsed = crate::util::json::Json::parse(&fwd).unwrap();
        let rows = parsed.get("skills").unwrap().as_arr().unwrap();
        assert_eq!(rows[0].get("rho").unwrap().as_f64().unwrap() as f32, 0.1f32);
    }

    #[test]
    fn job_spec_round_trips_through_json() {
        let mut spec = JobSpec::new(Case::A4, Scenario::smoke());
        spec.policy = TablePolicy::Truncated(64);
        spec.shards = 3;
        spec.reduce = ReduceMode::Worker;
        let j = spec.to_json();
        let back = JobSpec::from_json(&j).unwrap();
        assert_eq!(back.to_json().to_string(), j.to_string(), "round trip is stable");
        assert_eq!(back.case, Case::A4);
        assert_eq!(back.policy, TablePolicy::Truncated(64));
        assert_eq!(back.shards, 3);
        assert_eq!(back.reduce, ReduceMode::Worker);
        assert_eq!(back.scenario.seed, spec.scenario.seed);
        // the named policies round-trip by name
        for policy in [TablePolicy::Full, TablePolicy::TruncatedAuto] {
            let mut p = JobSpec::new(Case::A5, Scenario::smoke());
            p.policy = policy;
            assert_eq!(JobSpec::from_json(&p.to_json()).unwrap().policy, policy);
        }
        // knobs default like RunSpec::new when absent; scenario is required
        let minimal = Json::obj(vec![
            ("case", Json::Str("A2".into())),
            ("scenario", j.get("scenario").unwrap().clone()),
        ]);
        let d = JobSpec::from_json(&minimal).unwrap();
        assert_eq!(d.policy, TablePolicy::TruncatedAuto);
        assert_eq!(d.shards, 1);
        assert_eq!(d.reduce, ReduceMode::Driver);
        assert_eq!(d.partial, None, "absent `partial` must default off");
        // a partial contract round-trips through the CLI grammar
        let mut p = JobSpec::new(Case::A2, Scenario::smoke());
        p.partial = PartialSpec::parse("0.05,0.95");
        assert!(p.partial.is_some());
        let back = JobSpec::from_json(&p.to_json()).unwrap();
        assert_eq!(back.partial, p.partial);
        assert_eq!(back.to_json().to_string(), p.to_json().to_string());
        let bad = Json::obj(vec![
            ("case", Json::Str("A2".into())),
            ("partial", Json::Str("nope".into())),
            ("scenario", j.get("scenario").unwrap().clone()),
        ]);
        assert!(JobSpec::from_json(&bad).unwrap_err().contains("partial"));
        let err = JobSpec::from_json(&Json::obj(vec![("case", Json::Str("A4".into()))]))
            .unwrap_err();
        assert!(err.contains("scenario"), "{err}");
    }

    #[test]
    fn job_spec_run_matches_batch_dump_byte_for_byte() {
        let (x, y) = series();
        let scenario = Scenario::smoke();
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend);
        let batch = RunSpec::new(Case::A4, &scenario, &y, &x)
            .shards(2)
            .reduce(ReduceMode::Worker)
            .run(Arc::clone(&backend));
        let mut spec = JobSpec::new(Case::A4, scenario.clone());
        spec.shards = 2;
        spec.reduce = ReduceMode::Worker;
        let served = JobSpec::from_json(&spec.to_json()).unwrap().run(backend);
        assert_eq!(
            skills_to_json(&served.skills).to_string(),
            skills_to_json(&batch.skills).to_string(),
            "a JobSpec must reproduce the batch dump byte for byte"
        );
    }

    #[test]
    fn case_metadata() {
        assert!(Case::A5.uses_table() && Case::A5.is_async());
        assert!(Case::A4.uses_table() && !Case::A4.is_async());
        assert!(!Case::A2.uses_table() && !Case::A2.is_async());
        assert_eq!(Case::ALL.len(), 5);
        assert!(Case::A1.description().contains("Single-threaded"));
    }

    #[test]
    fn policy_resolves_modes() {
        assert_eq!(TablePolicy::Full.mode_for(1000, 100), TableMode::Full);
        assert_eq!(
            TablePolicy::Truncated(64).mode_for(1000, 100),
            TableMode::Truncated { prefix: 64 }
        );
        match TablePolicy::TruncatedAuto.mode_for(1000, 100) {
            TableMode::Truncated { prefix } => {
                assert_eq!(prefix, DistanceTable::auto_prefix(1000, 100))
            }
            other => panic!("expected truncated, got {other:?}"),
        }
    }

    #[test]
    fn engine_cases_record_jobs() {
        let (x, y) = series();
        let scenario = Scenario::smoke();
        let rep = RunSpec::new(Case::A5, &scenario, &y, &x)
            .deploy(Deploy::paper_cluster())
            .run(Arc::new(NativeBackend));
        assert!(rep.report.sim_makespan_s > 0.0);
        assert!(rep.report.measured_wall_s > 0.0);
        assert!(rep.report.sim_result_ingress_bytes > 0, "harvest tally must be recorded");
        assert_eq!(rep.report.topology, "cluster(5x4)");
    }

    #[test]
    fn reduce_mode_parse_round_trips() {
        for m in [ReduceMode::Driver, ReduceMode::Worker] {
            assert_eq!(ReduceMode::parse(m.name()), Some(m));
        }
        assert_eq!(ReduceMode::parse(" Worker "), Some(ReduceMode::Worker));
        assert_eq!(ReduceMode::parse("shuffle"), None);
        assert_eq!(ReduceMode::default(), ReduceMode::Driver);
    }

    #[test]
    fn worker_reduce_matches_driver_reduce_within_1_ulp() {
        use crate::ccm::pipeline::f32_ulp_distance;
        let (x, y) = series();
        let scenario = Scenario::smoke();
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend);
        let deploy = Deploy::Local { cores: 2 };
        for (case, shards) in [(Case::A4, 3), (Case::A5, 2)] {
            let spec = RunSpec::new(case, &scenario, &y, &x)
                .deploy(deploy.clone())
                .policy(TablePolicy::TruncatedAuto)
                .shards(shards);
            let driver_red = spec.clone().reduce(ReduceMode::Driver).run(Arc::clone(&backend));
            let worker_red = spec.reduce(ReduceMode::Worker).run(Arc::clone(&backend));
            let a = sorted_skills(driver_red.skills);
            let b = sorted_skills(worker_red.skills);
            assert_eq!(a.len(), b.len(), "{case:?}/{shards} shards skill count");
            for (d, w) in a.iter().zip(&b) {
                assert_eq!((d.0, d.1, d.2, d.3), (w.0, w.1, w.2, w.3), "{case:?} keys");
                assert!(
                    f32_ulp_distance(d.4, w.4) <= 1,
                    "{case:?}/{shards} shards: worker-reduce rho {} vs driver {} drifts > 1 ULP",
                    w.4,
                    d.4
                );
            }
            // six f64 sums per (skill, shard) must undercut raw prediction
            // rows in the modeled ingress too
            assert!(
                worker_red.report.sim_result_ingress_bytes
                    < driver_red.report.sim_result_ingress_bytes,
                "{case:?}/{shards} shards: worker-reduce ingress {} !< driver {}",
                worker_red.report.sim_result_ingress_bytes,
                driver_red.report.sim_result_ingress_bytes
            );
        }
    }

    #[test]
    fn single_table_worker_reduce_matches_monolithic_within_1_ulp() {
        use crate::ccm::pipeline::f32_ulp_distance;
        let (x, y) = series();
        let scenario = Scenario::smoke();
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend);
        let deploy = Deploy::Local { cores: 2 };
        for case in [Case::A4, Case::A5] {
            let spec = RunSpec::new(case, &scenario, &y, &x)
                .deploy(deploy.clone())
                .policy(TablePolicy::TruncatedAuto);
            let mono = spec.clone().run(Arc::clone(&backend));
            let worker_red = spec.reduce(ReduceMode::Worker).run(Arc::clone(&backend));
            let a = sorted_skills(mono.skills);
            let b = sorted_skills(worker_red.skills);
            assert_eq!(a.len(), b.len(), "{case:?} single-table skill count");
            for (d, w) in a.iter().zip(&b) {
                assert_eq!((d.0, d.1, d.2, d.3), (w.0, w.1, w.2, w.3), "{case:?} keys");
                assert!(
                    f32_ulp_distance(d.4, w.4) <= 1,
                    "{case:?}: single-shard worker-reduce rho {} vs monolithic {} drifts > 1 ULP",
                    w.4,
                    d.4
                );
            }
            // with one shard spanning the manifold, exactly one sums record
            // moves per skill row — the modeled ingress must say so
            assert_eq!(
                worker_red.report.sim_result_ingress_bytes,
                a.len() as u64 * SUMS_WIRE_BYTES,
                "{case:?}: single-shard worker reduce must ship one sums record per skill"
            );
        }
    }

    /// A native backend that reports a JSON-pinned pool, standing in for a
    /// cluster with a v<=5 peer: numerics identical, modeled bytes priced
    /// at the decimal-text rate.
    struct JsonPinned(NativeBackend);

    impl ComputeBackend for JsonPinned {
        fn cross_map_into(
            &self,
            input: &crate::ccm::backend::CrossMapInput,
            arena: &mut TaskArena,
        ) -> f32 {
            self.0.cross_map_into(input, arena)
        }

        fn simplex_tail_into(
            &self,
            dvals: &[f32],
            tvals: &[f32],
            pred_targets: &[f32],
            e: usize,
            preds: &mut Vec<f32>,
        ) -> f32 {
            self.0.simplex_tail_into(dvals, tvals, pred_targets, e, preds)
        }

        fn distance_matrix(&self, vecs: &[f32], n: usize) -> Vec<f32> {
            self.0.distance_matrix(vecs, n)
        }

        fn wire_pricing(&self) -> crate::engine::config::WirePricing {
            crate::engine::config::WirePricing::Json
        }

        fn name(&self) -> &'static str {
            "json-pinned-native"
        }
    }

    #[test]
    fn json_pinned_backend_inflates_modeled_bytes_only() {
        let (x, y) = series();
        let scenario = Scenario::smoke();
        let deploy = Deploy::paper_cluster();
        let bin = RunSpec::new(Case::A4, &scenario, &y, &x)
            .deploy(deploy.clone())
            .run(Arc::new(NativeBackend));
        let json = RunSpec::new(Case::A4, &scenario, &y, &x)
            .deploy(deploy)
            .run(Arc::new(JsonPinned(NativeBackend)));
        assert_eq!(
            sorted_skills(bin.skills),
            sorted_skills(json.skills),
            "wire pricing must never touch numerics"
        );
        // every tallied quantum is a multiple of 4 raw bytes, so the 11/4
        // inflation is exact end to end
        assert_eq!(
            json.report.sim_result_ingress_bytes,
            bin.report.sim_result_ingress_bytes * 11 / 4,
            "ingress must be priced at the JSON rate"
        );
        assert!(
            json.report.sim_broadcast_ship_bytes > bin.report.sim_broadcast_ship_bytes,
            "DES broadcast bytes must inflate on a JSON-pinned pool"
        );
    }

    fn cell_summary(l: usize, mean: f64, std: f64) -> SkillSummary {
        use crate::ccm::params::CcmParams;
        SkillSummary {
            params: CcmParams::new(2, 1, l),
            n: 50,
            mean_rho: mean,
            std_rho: std,
            q05: mean - std,
            q95: mean + std,
        }
    }

    #[test]
    fn slice_decided_prunes_only_statistically_dead_slices() {
        // too few cells: never decided
        assert!(!slice_decided(&[], 0.05));
        assert!(!slice_decided(&[cell_summary(50, 0.5, 0.01)], 0.05));
        // a healthy increasing trend is not pruned
        let rising = [cell_summary(50, 0.3, 0.01), cell_summary(100, 0.6, 0.01)];
        assert!(!slice_decided(&rising, 0.05));
        // a clear drop beyond eps is decided non-causal
        let falling = [cell_summary(50, 0.6, 0.01), cell_summary(100, 0.3, 0.01)];
        assert!(slice_decided(&falling, 0.05));
        // the same drop inside eps is NOT decided — resolution matters
        assert!(!slice_decided(&falling, 0.5));
        // a flat trend (delta ~ 0) is not a decided drop
        let flat = [cell_summary(50, 0.5, 0.05), cell_summary(100, 0.5, 0.05)];
        assert!(!slice_decided(&flat, 0.05));
    }

    #[test]
    fn unfired_cancel_flag_keeps_every_case_byte_identical() {
        use std::sync::atomic::AtomicBool;
        // a cancel flag that never fires routes every case through the
        // partial-capable driver (wave dispatch for the engine cases) with
        // no spec — the dump must stay byte-identical to the seed path
        let (x, y) = series();
        let scenario = Scenario::smoke();
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend);
        let flag = AtomicBool::new(false);
        for case in Case::ALL {
            let plain = RunSpec::new(case, &scenario, &y, &x).run(Arc::clone(&backend));
            let waved = RunSpec::new(case, &scenario, &y, &x)
                .cancel_flag(&flag)
                .run(Arc::clone(&backend));
            assert_eq!(
                skills_to_json(&waved.skills).to_string(),
                skills_to_json(&plain.skills).to_string(),
                "{case:?}: wave dispatch with no partial spec must be byte-identical"
            );
            assert_eq!(waved.partial, PartialOutcome::default(), "{case:?}: nothing to report");
        }
        // sharded + worker-reduce goes through the same wave machinery
        let plain = RunSpec::new(Case::A4, &scenario, &y, &x)
            .shards(2)
            .reduce(ReduceMode::Worker)
            .run(Arc::clone(&backend));
        let waved = RunSpec::new(Case::A4, &scenario, &y, &x)
            .shards(2)
            .reduce(ReduceMode::Worker)
            .cancel_flag(&flag)
            .run(Arc::clone(&backend));
        assert_eq!(
            skills_to_json(&waved.skills).to_string(),
            skills_to_json(&plain.skills).to_string(),
            "sharded worker-reduce wave dispatch must be byte-identical"
        );
    }

    #[test]
    fn pre_fired_cancel_flag_stops_before_any_dispatch() {
        use std::sync::atomic::AtomicBool;
        let (x, y) = series();
        let scenario = Scenario::smoke();
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend);
        let flag = AtomicBool::new(true);
        for case in [Case::A1, Case::A2, Case::A4] {
            let rep = RunSpec::new(case, &scenario, &y, &x)
                .cancel_flag(&flag)
                .run(Arc::clone(&backend));
            assert!(rep.partial.cancelled, "{case:?}: cancel must be reported");
            assert!(rep.skills.is_empty(), "{case:?}: nothing dispatched after cancel");
        }
    }

    /// The weak-coupling scenario the partial tests share: the y -> x
    /// direction of the coupled-logistic pair (bxy = 0.02, an order of
    /// magnitude below the x -> y coupling), with a subsample budget big
    /// enough that a tight confidence interval arrives well before the
    /// budget runs out.
    fn weak_scenario() -> Scenario {
        Scenario {
            series_len: 300,
            r: 48,
            ls: vec![50, 100],
            es: vec![2],
            taus: vec![1],
            theiler: 0,
            seed: 7,
            partitions: 4,
        }
    }

    fn mean_by_cell(rows: &[SkillRow]) -> std::collections::BTreeMap<(usize, usize, usize), f64> {
        let mut acc: std::collections::BTreeMap<(usize, usize, usize), (f64, u64)> =
            std::collections::BTreeMap::new();
        for r in rows {
            let e = acc.entry((r.params.e, r.params.tau, r.params.l)).or_insert((0.0, 0));
            e.0 += r.rho as f64;
            e.1 += 1;
        }
        acc.into_iter().map(|(k, (s, n))| (k, s / n as f64)).collect()
    }

    #[test]
    fn weak_coupling_partial_saves_tasks_within_eps() {
        let scenario = weak_scenario();
        let (x, y) = coupled_logistic(scenario.series_len, CoupledLogisticParams::default());
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend);
        let spec = PartialSpec::parse("0.2,0.9").unwrap();
        for case in [Case::A1, Case::A2, Case::A4] {
            // weak direction: cross-map the cause y from effect x's manifold
            let full = RunSpec::new(case, &scenario, &x, &y).run(Arc::clone(&backend));
            let part = RunSpec::new(case, &scenario, &x, &y)
                .partial(Some(spec))
                .run(Arc::clone(&backend));
            assert!(part.partial.stops >= 1, "{case:?}: expected at least one early stop");
            assert!(part.partial.saved_tasks > 0, "{case:?}: expected saved tasks");
            assert!(!part.partial.cancelled);
            let total = (scenario.combos().len() * scenario.r) as u64;
            assert_eq!(
                part.skills.len() as u64 + part.partial.saved_tasks,
                total,
                "{case:?}: every budgeted task is either dispatched or saved"
            );
            // the bounded-error contract: every partially-evaluated cell's
            // mean stays within eps of the full run's mean
            let full_means = mean_by_cell(&full.skills);
            for (cell, mean) in mean_by_cell(&part.skills) {
                let full_mean = full_means[&cell];
                assert!(
                    (mean - full_mean).abs() <= spec.eps,
                    "{case:?} {cell:?}: partial mean {mean} vs full {full_mean} exceeds eps"
                );
            }
            if case != Case::A1 {
                assert!(
                    part.report.sim_partial_saved_task_s > 0.0,
                    "{case:?}: saved tasks must be priced into the DES report"
                );
            }
        }
    }

    #[test]
    fn partial_stop_decisions_are_deterministic() {
        let scenario = weak_scenario();
        let (x, y) = coupled_logistic(scenario.series_len, CoupledLogisticParams::default());
        let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend);
        let spec = PartialSpec::parse("0.2,0.9").unwrap();
        for case in [Case::A1, Case::A4] {
            let a = RunSpec::new(case, &scenario, &x, &y)
                .partial(Some(spec))
                .run(Arc::clone(&backend));
            let b = RunSpec::new(case, &scenario, &x, &y)
                .partial(Some(spec))
                .run(Arc::clone(&backend));
            assert_eq!(
                skills_to_json(&a.skills).to_string(),
                skills_to_json(&b.skills).to_string(),
                "{case:?}: identical seeds must dispatch identical tasks"
            );
            assert_eq!(a.partial, b.partial, "{case:?}: identical stop decisions");
        }
    }
}
