//! `parccm serve`: a long-running multi-tenant job service over one warm
//! worker pool.
//!
//! Everything before this module was batch: one driver, one grid, exit —
//! the pool is torn down and every broadcast re-shipped per invocation.
//! The serve daemon inverts that: it owns one
//! [`crate::ccm::cluster::ClusterBackend`] (and therefore one
//! `ClusterCore` + warm worker pool) for its whole life and accepts many
//! concurrent CCM jobs over the existing framed wire. Per-job isolation
//! is the cluster layer's job — every task, broadcast ship, and result
//! byte is tagged with a job id ([`crate::ccm::cluster::JobBackend`]),
//! worker grants rotate round-robin across jobs so one huge grid cannot
//! starve a small one, and the driver payload cache refcounts per job so
//! two tenants posing the same problem share one broadcast ship. This
//! module adds the service half: the job tracker, admission control, the
//! control protocol, and the client.
//!
//! # Wire protocol (v7)
//!
//! A job client dials the daemon's listen port and runs the standard
//! hello handshake *as the listening side's peer*: it sends a `hello`
//! carrying `"role":"client"` (plus the shared auth token when one is
//! configured), and the daemon answers `hello_ack` / `reject` exactly
//! like a driver admitting a worker. Connections that present no client
//! role are rejected by name — a worker that mistakenly dials the job
//! port gets a readable error, not a protocol wedge. After the
//! handshake the connection follows the same negotiated layering as a
//! worker link: v4+ checksums, v6+ length-prefixed binary frames. The
//! control messages themselves are plain JSON envelopes
//! ([`crate::ccm::binwire::TAG_JSON`]), so the binary framing carries
//! them unchanged — v7 needed no codec changes at all.
//!
//! | client sends                         | daemon replies                                          |
//! |--------------------------------------|---------------------------------------------------------|
//! | `{"spec":{...},"type":"submit"}`     | `{"job":N,"state":"queued","type":"submitted"}`         |
//! | `{"job":N,"type":"status"}`          | `{"cancelled_running":B,"counters":{...},"job":N,"state":S,"type":"status"}` |
//! | `{"job":N,"type":"fetch"}`           | `{"job":N,"skills":"...","state":"done","type":"result"}` |
//! | `{"job":N,"type":"cancel"}`          | `{"job":N,"state":"cancelled"|"cancelling","type":"cancelled"}` |
//! | `{"type":"shutdown"}`                | `{"type":"shutdown_ack"}`, then the daemon drains       |
//!
//! Any failure is `{"msg":"...","type":"error"}` (plus `"job"` when one
//! was named). `status.counters` is the job's live [`JobTally`] slice —
//! summed across jobs it equals the pool totals, so cross-tenant counter
//! bleed is structurally visible to clients.
//!
//! # Cancel semantics
//!
//! Cancelling a **queued** job is immediate and exact: the entry flips to
//! `cancelled` and is never admitted. Cancelling a **running** job is
//! *best-effort*: the daemon sets the job's cancel flag and replies
//! `"state":"cancelling"`; the driver observes the flag at its next
//! partial-evaluation checkpoint (every dispatch wave / A1 task), stops
//! dispatching, and the job settles `cancelled` with
//! `"cancelled_running":true` in `status`. A run that completes before
//! the flag is observed settles `done` — the cancel was simply too late,
//! and the result is fetchable as normal. Cancelling a terminal job is an
//! error (`done`/`failed`), except re-cancelling a cancelled job, which is
//! an idempotent success.
//!
//! # Determinism
//!
//! A fetched result is the canonical
//! [`skills_to_json`](crate::ccm::driver::skills_to_json) dump of the
//! job's skills, byte-identical to what `parccm fig4 --dump-skills`
//! writes for the same spec: [`crate::ccm::driver::JobSpec::run`]
//! regenerates the same input series and builds the same `RunSpec`, and
//! the scheduler's fairness machinery never touches numerics. The
//! round-trip is asserted end-to-end in this module's tests, the
//! concurrent-jobs chaos test (`tests/integration_serve.rs`), and CI's
//! serve-mode pass.

use std::collections::{BTreeMap, VecDeque};
use std::io;
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::ccm::backend::ComputeBackend;
use crate::ccm::binwire;
use crate::ccm::cluster::{ClusterBackend, JobBackend, JobTally};
use crate::ccm::driver::{skills_to_json, JobSpec};
use crate::ccm::lifecycle::ServeLifecycle;
use crate::ccm::transport::{
    finish_handshake, negotiate_hello, recv_json, reject_payload, ChecksumTransport, TcpTransport,
    Transport, TransportKind, BINARY_WIRE_VERSION, CHECKSUM_WIRE_VERSION, SERVE_WIRE_VERSION,
    WIRE_VERSION,
};
use crate::util::json::Json;

/// Deadline covering a job client's TCP connect and handshake reads, and
/// the daemon's read of a fresh connection's hello (a dialer that never
/// speaks must not pin a handler thread forever).
const SERVE_CONNECT_TIMEOUT: Duration = Duration::from_secs(10);

/// Default admission bound (`--max-concurrent-jobs`): jobs computing on
/// the pool at once; excess submissions queue FIFO.
pub const DEFAULT_MAX_CONCURRENT_JOBS: usize = 4;

/// Identity of one submitted job. Ids are handed out from 1 — job 0 is
/// reserved for the batch path (`ClusterBackend`'s plain trait impl), so
/// a serve tenant can never alias the daemon's own maintenance traffic.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct JobId(pub u64);

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job {}", self.0)
    }
}

/// Lifecycle of one job, as surfaced through `status`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JobState {
    /// Admitted to the queue, not yet computing (admission bound full).
    Queued,
    /// Computing on the pool.
    Running,
    /// Finished; the canonical skills dump is ready to `fetch`.
    Done,
    /// The run panicked or errored; `status` carries the message.
    Failed,
    /// Cancelled: immediately while still queued, or best-effort while
    /// running (the driver stopped at a partial-evaluation checkpoint —
    /// `status` reports `cancelled_running:true` for that flavour).
    Cancelled,
}

impl JobState {
    /// The wire name (`status.state`).
    pub fn name(&self) -> &'static str {
        match self {
            JobState::Queued => "queued",
            JobState::Running => "running",
            JobState::Done => "done",
            JobState::Failed => "failed",
            JobState::Cancelled => "cancelled",
        }
    }

    /// Whether the job will never change state again.
    pub fn is_terminal(&self) -> bool {
        matches!(self, JobState::Done | JobState::Failed | JobState::Cancelled)
    }
}

/// Outcome of a `cancel` request (the `state` field of the wire reply).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum CancelOutcome {
    /// The job was still queued (or already cancelled): terminally
    /// `Cancelled` right now, exactly.
    Cancelled,
    /// The job was running: its cancel flag is set and the driver stops
    /// best-effort at its next partial-evaluation checkpoint. The job
    /// settles `Cancelled` (with `cancelled_running` in `status`) unless
    /// the run finishes first, in which case it settles `Done`.
    Cancelling,
}

impl CancelOutcome {
    /// The wire name (`cancelled` reply's `state`).
    pub fn name(&self) -> &'static str {
        match self {
            CancelOutcome::Cancelled => "cancelled",
            CancelOutcome::Cancelling => "cancelling",
        }
    }
}

struct JobEntry {
    spec: JobSpec,
    state: JobState,
    /// The canonical skills dump (set when `Done`).
    result: Option<String>,
    /// The failure message (set when `Failed`).
    error: Option<String>,
    /// Best-effort cancel flag, shared with the job's runner thread; the
    /// driver polls it at every partial-evaluation checkpoint.
    cancel: Arc<AtomicBool>,
    /// Whether this job was cancelled *while running* (as opposed to the
    /// exact queued-cancel path) — surfaced in `status`.
    cancelled_running: bool,
}

struct TrackerState {
    next_id: u64,
    jobs: BTreeMap<u64, JobEntry>,
    /// FIFO admission queue of job ids still `Queued` (lazily pruned:
    /// a cancelled entry is skipped at admit time, not removed here).
    queue: VecDeque<u64>,
    running: usize,
    lifecycle: ServeLifecycle,
}

/// The daemon's book of record: every submitted job's spec, state, and
/// result, plus FIFO admission against the `--max-concurrent-jobs`
/// bound. Pure bookkeeping behind one mutex — no threads, no sockets —
/// so the whole state machine is unit-testable; the daemon supplies the
/// threads ([`ServeDaemon`]) and the pool supplies fairness between the
/// jobs this tracker has admitted.
pub struct JobTracker {
    inner: Mutex<TrackerState>,
    max_concurrent: usize,
}

impl JobTracker {
    /// Tracker admitting at most `max_concurrent` running jobs (clamped
    /// to at least 1; excess submissions queue FIFO).
    pub fn new(max_concurrent: usize) -> JobTracker {
        JobTracker {
            inner: Mutex::new(TrackerState {
                next_id: 1, // 0 is the batch job id, never a tenant's
                jobs: BTreeMap::new(),
                queue: VecDeque::new(),
                running: 0,
                lifecycle: ServeLifecycle::new(Instant::now()),
            }),
            max_concurrent: max_concurrent.max(1),
        }
    }

    fn lock(&self) -> MutexGuard<'_, TrackerState> {
        // a panicking job runner must not wedge every later request
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Record a submission and queue it for admission.
    pub fn submit(&self, spec: JobSpec) -> JobId {
        let mut st = self.lock();
        let id = st.next_id;
        st.next_id += 1;
        st.jobs.insert(
            id,
            JobEntry {
                spec,
                state: JobState::Queued,
                result: None,
                error: None,
                cancel: Arc::new(AtomicBool::new(false)),
                cancelled_running: false,
            },
        );
        st.queue.push_back(id);
        JobId(id)
    }

    /// Admit the next queued job if the concurrency bound has room:
    /// marks it `Running` and returns its spec for a runner to execute.
    /// Cancelled entries are skipped. Callers loop until `None` to fill
    /// every free slot.
    pub fn admit(&self) -> Option<(JobId, JobSpec)> {
        let mut st = self.lock();
        while st.running < self.max_concurrent {
            let id = st.queue.pop_front()?;
            let Some(entry) = st.jobs.get_mut(&id) else { continue };
            if entry.state != JobState::Queued {
                continue; // cancelled while waiting
            }
            entry.state = JobState::Running;
            let spec = entry.spec.clone();
            st.running += 1;
            st.lifecycle.note_job_start(Instant::now());
            return Some((JobId(id), spec));
        }
        None
    }

    fn settle(&self, id: JobId, state: JobState, result: Option<String>, error: Option<String>) {
        let mut st = self.lock();
        if let Some(entry) = st.jobs.get_mut(&id.0) {
            debug_assert_eq!(entry.state, JobState::Running, "{id} settled twice");
            entry.state = state;
            entry.result = result;
            entry.error = error;
        }
        st.running = st.running.saturating_sub(1);
        st.lifecycle.note_job_end(Instant::now());
    }

    /// A runner finished `id`; `dump` is its canonical skills JSON.
    pub fn finish(&self, id: JobId, dump: String) {
        self.settle(id, JobState::Done, Some(dump), None);
    }

    /// A runner died computing `id`.
    pub fn fail(&self, id: JobId, err: String) {
        self.settle(id, JobState::Failed, None, Some(err));
    }

    /// Cancel a job. A queued job flips to `Cancelled` immediately and is
    /// never admitted. A running job cancels *best-effort*: its cancel
    /// flag is set ([`CancelOutcome::Cancelling`]) and the driver stops
    /// at its next partial-evaluation checkpoint — unless the run
    /// finishes first, in which case the job settles `Done` as normal.
    /// Cancelling an already-cancelled job is an idempotent success;
    /// `Done`/`Failed` are errors (nothing left to stop).
    pub fn cancel(&self, id: JobId) -> Result<CancelOutcome, String> {
        let mut st = self.lock();
        let Some(entry) = st.jobs.get_mut(&id.0) else {
            return Err(format!("unknown job {}", id.0));
        };
        match entry.state {
            JobState::Queued => {
                entry.state = JobState::Cancelled;
                Ok(CancelOutcome::Cancelled)
            }
            JobState::Running => {
                entry.cancel.store(true, Ordering::Relaxed);
                Ok(CancelOutcome::Cancelling)
            }
            JobState::Cancelled => Ok(CancelOutcome::Cancelled),
            state => Err(format!("{id} is {}; there is nothing left to cancel", state.name())),
        }
    }

    /// The job's shared cancel flag (what a runner threads into
    /// [`JobSpec::run_with_cancel`]); `None` for an unknown job.
    pub fn cancel_flag(&self, id: JobId) -> Option<Arc<AtomicBool>> {
        self.lock().jobs.get(&id.0).map(|e| Arc::clone(&e.cancel))
    }

    /// A runner observed the cancel flag and returned early: the job
    /// settles `Cancelled` with `cancelled_running` visible in `status`.
    pub fn cancelled_while_running(&self, id: JobId) {
        let mut st = self.lock();
        if let Some(entry) = st.jobs.get_mut(&id.0) {
            debug_assert_eq!(entry.state, JobState::Running, "{id} settled twice");
            entry.state = JobState::Cancelled;
            entry.cancelled_running = true;
        }
        st.running = st.running.saturating_sub(1);
        st.lifecycle.note_job_end(Instant::now());
    }

    /// Current state of `id` (`None` for an unknown job).
    pub fn state(&self, id: JobId) -> Option<JobState> {
        self.lock().jobs.get(&id.0).map(|e| e.state)
    }

    /// State, failure message, and the cancelled-while-running marker,
    /// for the `status` reply.
    pub fn status(&self, id: JobId) -> Option<(JobState, Option<String>, bool)> {
        self.lock().jobs.get(&id.0).map(|e| (e.state, e.error.clone(), e.cancelled_running))
    }

    /// The canonical skills dump of a `Done` job; every other state is a
    /// named error (clients poll `status` until `done`, then fetch once).
    pub fn fetch(&self, id: JobId) -> Result<String, String> {
        let st = self.lock();
        let Some(entry) = st.jobs.get(&id.0) else {
            return Err(format!("unknown job {}", id.0));
        };
        match entry.state {
            JobState::Done => Ok(entry.result.clone().unwrap_or_default()),
            JobState::Failed => Err(format!(
                "{id} failed: {}",
                entry.error.as_deref().unwrap_or("unspecified")
            )),
            state => Err(format!("{id} is {}; poll status until done", state.name())),
        }
    }

    /// Jobs waiting for admission (excluding lazily-pruned cancellations).
    pub fn queued(&self) -> usize {
        let st = self.lock();
        st.queue
            .iter()
            .filter(|id| st.jobs.get(id).map(|e| e.state == JobState::Queued).unwrap_or(false))
            .count()
    }

    /// Jobs currently computing on the pool.
    pub fn running(&self) -> usize {
        self.lock().running
    }

    /// Jobs that have reached `Done` or `Failed` over the tracker's life.
    pub fn jobs_served(&self) -> u64 {
        self.lock().lifecycle.jobs_served()
    }

    /// Nothing queued and nothing running (what a draining daemon waits
    /// for before letting the pool go).
    pub fn idle(&self) -> bool {
        let st = self.lock();
        st.running == 0
            && !st
                .queue
                .iter()
                .any(|id| st.jobs.get(id).map(|e| e.state == JobState::Queued).unwrap_or(false))
    }
}

/// What the daemon needs from the compute layer: a per-job backend
/// handle and the job's live counter slice. The production impl is
/// `Arc<ClusterBackend>` (handing out [`JobBackend`] views of one warm
/// pool); tests and degraded deployments substitute an in-process
/// backend without touching the service half.
pub trait JobPool: Send + Sync + 'static {
    /// A backend whose work is attributed to `job`.
    fn backend_for(&self, job: u64) -> Arc<dyn ComputeBackend>;

    /// The job's counter slice so far (all-zero for an unknown job).
    fn tally_for(&self, job: u64) -> JobTally;
}

impl JobPool for Arc<ClusterBackend> {
    fn backend_for(&self, job: u64) -> Arc<dyn ComputeBackend> {
        Arc::new(JobBackend::new(Arc::clone(self), job))
    }

    fn tally_for(&self, job: u64) -> JobTally {
        self.job_tally(job)
    }
}

/// Degraded single-process pool: every job computes on the one shared
/// backend with no per-job attribution (tallies stay all-zero). What
/// `parccm serve` runs under `--backend native`/`xla` — same results,
/// same protocol, no isolation counters.
impl JobPool for Arc<dyn ComputeBackend> {
    fn backend_for(&self, _job: u64) -> Arc<dyn ComputeBackend> {
        Arc::clone(self)
    }

    fn tally_for(&self, _job: u64) -> JobTally {
        JobTally::default()
    }
}

/// How a [`ServeDaemon`] is shaped.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Address to listen for job clients on (`--serve-at`; port 0 binds
    /// an ephemeral port, announced on stdout by `parccm serve`).
    pub listen: String,
    /// Shared auth token job clients must present (`--auth-token` /
    /// `PARCCM_AUTH_TOKEN`) — same semantics as the worker handshake.
    pub auth_token: Option<String>,
    /// Jobs computing on the pool at once (`--max-concurrent-jobs`);
    /// excess submissions queue FIFO.
    pub max_concurrent_jobs: usize,
}

impl Default for ServeOptions {
    fn default() -> ServeOptions {
        ServeOptions {
            listen: "127.0.0.1:0".to_string(),
            auth_token: None,
            max_concurrent_jobs: DEFAULT_MAX_CONCURRENT_JOBS,
        }
    }
}

/// Shared state of one daemon: the pool, the tracker, and the stop flag.
struct ServeCtx {
    pool: Arc<dyn JobPool>,
    tracker: JobTracker,
    stop: AtomicBool,
    auth: Option<String>,
    /// The bound listen address (what [`wake_accept`] dials on shutdown).
    addr: String,
}

/// The `parccm serve` daemon: one accept loop, one handler thread per
/// client connection, one runner thread per admitted job, all over a
/// single warm pool that outlives every job. Start it, announce
/// [`ServeDaemon::addr`], then [`ServeDaemon::wait`] until a client
/// sends `shutdown` (or call [`ServeDaemon::shutdown`] directly); both
/// drain queued and running jobs before returning, so no accepted work
/// is silently dropped.
pub struct ServeDaemon {
    ctx: Arc<ServeCtx>,
    accept: Option<JoinHandle<()>>,
}

impl ServeDaemon {
    /// Bind `opts.listen` and start accepting job clients against
    /// `pool`. Returns once the listener is live — the bound address is
    /// [`ServeDaemon::addr`].
    pub fn start<P: JobPool>(pool: P, opts: ServeOptions) -> io::Result<ServeDaemon> {
        let listener = TcpListener::bind(&opts.listen)?;
        let addr = listener.local_addr()?.to_string();
        let ctx = Arc::new(ServeCtx {
            pool: Arc::new(pool),
            tracker: JobTracker::new(opts.max_concurrent_jobs),
            stop: AtomicBool::new(false),
            auth: opts.auth_token,
            addr: addr.clone(),
        });
        let accept_ctx = Arc::clone(&ctx);
        let accept = std::thread::Builder::new()
            .name("parccm-serve-accept".to_string())
            .spawn(move || {
                for stream in listener.incoming() {
                    if accept_ctx.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    let Ok(stream) = stream else { continue };
                    let conn_ctx = Arc::clone(&accept_ctx);
                    let _ = std::thread::Builder::new()
                        .name("parccm-serve-conn".to_string())
                        .spawn(move || {
                            if let Err(e) = handle_client(stream, &conn_ctx) {
                                // handshake rejects and client hangups are
                                // routine; log and keep serving
                                eprintln!("[serve] client connection ended: {e}");
                            }
                        });
                }
            })?;
        Ok(ServeDaemon { ctx, accept: Some(accept) })
    }

    /// The bound listen address (resolved, even when `listen` asked for
    /// port 0).
    pub fn addr(&self) -> &str {
        &self.ctx.addr
    }

    /// The daemon's job book (daemon-side inspection and tests; clients
    /// go through `status`/`fetch`).
    pub fn tracker(&self) -> &JobTracker {
        &self.ctx.tracker
    }

    /// Whether a client has requested shutdown.
    pub fn stop_requested(&self) -> bool {
        self.ctx.stop.load(Ordering::SeqCst)
    }

    /// Block until a client sends `shutdown`, then drain and stop — the
    /// body of `parccm serve`.
    pub fn wait(&mut self) {
        while !self.ctx.stop.load(Ordering::SeqCst) {
            std::thread::sleep(Duration::from_millis(50));
        }
        self.shutdown();
    }

    /// Stop accepting connections and drain: every admitted job (queued
    /// or running) completes before this returns. Idempotent.
    pub fn shutdown(&mut self) {
        self.ctx.stop.store(true, Ordering::SeqCst);
        if let Some(accept) = self.accept.take() {
            wake_accept(&self.ctx.addr);
            let _ = accept.join();
        }
        // queued jobs keep admitting as runners free slots; wait them out
        while !self.ctx.tracker.idle() {
            pump(&self.ctx);
            std::thread::sleep(Duration::from_millis(10));
        }
    }
}

impl Drop for ServeDaemon {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Unblock an accept loop whose stop flag was just set: `incoming()`
/// only observes the flag after a connection arrives, so dial one.
fn wake_accept(addr: &str) {
    if let Ok(mut resolved) = addr.to_socket_addrs() {
        if let Some(a) = resolved.next() {
            let _ = TcpStream::connect_timeout(&a, Duration::from_millis(500));
        }
    }
}

/// Fill every free admission slot with a runner thread. Called after
/// every submit and at the tail of every runner, so the bound stays
/// saturated whenever work is queued.
fn pump(ctx: &Arc<ServeCtx>) {
    while let Some((id, spec)) = ctx.tracker.admit() {
        let run_ctx = Arc::clone(ctx);
        let _ = std::thread::Builder::new()
            .name(format!("parccm-serve-job-{}", id.0))
            .spawn(move || run_job(run_ctx, id, spec));
    }
}

fn run_job(ctx: Arc<ServeCtx>, id: JobId, spec: JobSpec) {
    let backend = ctx.pool.backend_for(id.0);
    let cancel = ctx.tracker.cancel_flag(id);
    // a panicking job (task exhaustion under --on-exhausted abort, a bad
    // spec tripping an assert) must fail ITS job, not the daemon
    let outcome = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        spec.run_with_cancel(backend, cancel.as_deref())
    }));
    match outcome {
        Ok(report) if report.partial.cancelled => ctx.tracker.cancelled_while_running(id),
        Ok(report) => ctx.tracker.finish(id, skills_to_json(&report.skills).to_string()),
        Err(panic) => ctx.tracker.fail(id, panic_message(panic)),
    }
    pump(&ctx);
}

fn panic_message(panic: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = panic.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = panic.downcast_ref::<String>() {
        s.clone()
    } else {
        "job runner panicked".to_string()
    }
}

fn invalid_data(msg: impl Into<String>) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.into())
}

/// Send a control message in the connection's negotiated wire mode:
/// binary connections wrap the line in a `TAG_JSON` envelope frame.
fn send_ctl(t: &mut dyn Transport, binary: bool, msg: &Json) -> io::Result<()> {
    let line = msg.to_string();
    if binary {
        t.send_frame(&binwire::encode_json(&line))
    } else {
        t.send_line(&line)
    }
}

/// Receive the next control message; `Ok(None)` is a clean hangup.
fn recv_ctl(t: &mut dyn Transport, binary: bool) -> io::Result<Option<Json>> {
    if binary {
        match t.recv_frame()? {
            None => Ok(None),
            Some(frame) => binwire::decode(&frame)
                .and_then(binwire::to_json)
                .map(Some)
                .map_err(invalid_data),
        }
    } else {
        loop {
            match t.recv_line()? {
                None => return Ok(None),
                Some(line) if line.trim().is_empty() => continue,
                Some(line) => {
                    return Json::parse(&line).map(Some).map_err(|e| invalid_data(e.to_string()))
                }
            }
        }
    }
}

fn error_reply(job: Option<u64>, msg: String) -> Json {
    let mut fields = Vec::new();
    if let Some(job) = job {
        fields.push(("job", Json::Num(job as f64)));
    }
    fields.push(("msg", Json::Str(msg)));
    fields.push(("type", Json::Str("error".into())));
    Json::obj(fields)
}

fn job_field(msg: &Json) -> Option<u64> {
    msg.get("job").and_then(Json::as_f64).map(|v| v as u64)
}

/// One client connection: handshake (role-gated), then a request/reply
/// loop until the client hangs up or sends `shutdown`.
fn handle_client(stream: TcpStream, ctx: &Arc<ServeCtx>) -> io::Result<()> {
    let mut transport: Box<dyn Transport> = Box::new(TcpTransport::from_stream(stream)?);
    transport.set_recv_deadline(Some(SERVE_CONNECT_TIMEOUT))?;
    let msg = recv_json(transport.as_mut())?;
    let hello = match negotiate_hello(&msg) {
        Ok(h) => h,
        Err(e) => {
            let _ = transport.send_line(&reject_payload(&e));
            return Err(invalid_data(e));
        }
    };
    if hello.role.as_deref() != Some("client") {
        let why = format!(
            "this is a parccm serve job port: connections must present a \
             v{SERVE_WIRE_VERSION}+ hello with role \"client\" (peer pid {} presented \
             {:?}) — workers belong on the pool, not here",
            hello.pid, hello.role
        );
        let _ = transport.send_line(&reject_payload(&why));
        return Err(invalid_data(why));
    }
    // same auth + ack flow as a driver admitting a worker (sends the
    // reject itself on an auth mismatch)
    finish_handshake(transport.as_mut(), &hello, ctx.auth.as_deref())?;
    transport.set_recv_deadline(None)?;
    // same post-handshake layering as a worker link: v4+ checksummed,
    // v6+ binary frames; the JSON-envelope control messages ride either
    let mut transport: Box<dyn Transport> = if hello.version >= CHECKSUM_WIRE_VERSION {
        Box::new(ChecksumTransport::new(transport, None))
    } else {
        transport
    };
    let binary = hello.version >= BINARY_WIRE_VERSION;
    loop {
        let Some(msg) = recv_ctl(transport.as_mut(), binary)? else {
            return Ok(()); // client hung up
        };
        let reply = match msg.get("type").and_then(Json::as_str) {
            Some("submit") => on_submit(ctx, &msg),
            Some("status") => on_status(ctx, &msg),
            Some("fetch") => on_fetch(ctx, &msg),
            Some("cancel") => on_cancel(ctx, &msg),
            Some("shutdown") => {
                ctx.stop.store(true, Ordering::SeqCst);
                wake_accept(&ctx.addr);
                send_ctl(
                    transport.as_mut(),
                    binary,
                    &Json::obj(vec![("type", Json::Str("shutdown_ack".into()))]),
                )?;
                return Ok(());
            }
            other => error_reply(None, format!("unknown control message type {other:?}")),
        };
        send_ctl(transport.as_mut(), binary, &reply)?;
    }
}

fn on_submit(ctx: &Arc<ServeCtx>, msg: &Json) -> Json {
    let Some(spec_json) = msg.get("spec") else {
        return error_reply(None, "submit carries no `spec`".to_string());
    };
    match JobSpec::from_json(spec_json) {
        Ok(spec) => {
            let id = ctx.tracker.submit(spec);
            pump(ctx);
            Json::obj(vec![
                ("job", Json::Num(id.0 as f64)),
                ("state", Json::Str("queued".into())),
                ("type", Json::Str("submitted".into())),
            ])
        }
        Err(e) => error_reply(None, e),
    }
}

fn on_status(ctx: &Arc<ServeCtx>, msg: &Json) -> Json {
    let Some(job) = job_field(msg) else {
        return error_reply(None, "status carries no `job`".to_string());
    };
    match ctx.tracker.status(JobId(job)) {
        None => error_reply(Some(job), format!("unknown job {job}")),
        Some((state, error, cancelled_running)) => {
            let tally = ctx.pool.tally_for(job);
            let counters = Json::obj(
                tally.to_pairs().into_iter().map(|(k, v)| (k, Json::Num(v as f64))).collect(),
            );
            let mut fields = vec![
                ("cancelled_running", Json::Bool(cancelled_running)),
                ("counters", counters),
                ("job", Json::Num(job as f64)),
                ("state", Json::Str(state.name().into())),
                ("type", Json::Str("status".into())),
            ];
            if let Some(e) = error {
                fields.push(("error", Json::Str(e)));
            }
            Json::obj(fields)
        }
    }
}

fn on_fetch(ctx: &Arc<ServeCtx>, msg: &Json) -> Json {
    let Some(job) = job_field(msg) else {
        return error_reply(None, "fetch carries no `job`".to_string());
    };
    match ctx.tracker.fetch(JobId(job)) {
        Ok(dump) => Json::obj(vec![
            ("job", Json::Num(job as f64)),
            ("skills", Json::Str(dump)),
            ("state", Json::Str("done".into())),
            ("type", Json::Str("result".into())),
        ]),
        Err(e) => error_reply(Some(job), e),
    }
}

fn on_cancel(ctx: &Arc<ServeCtx>, msg: &Json) -> Json {
    let Some(job) = job_field(msg) else {
        return error_reply(None, "cancel carries no `job`".to_string());
    };
    match ctx.tracker.cancel(JobId(job)) {
        Ok(outcome) => Json::obj(vec![
            ("job", Json::Num(job as f64)),
            ("state", Json::Str(outcome.name().into())),
            ("type", Json::Str("cancelled".into())),
        ]),
        Err(e) => error_reply(Some(job), e),
    }
}

/// A job client: one authenticated connection to a serve daemon, with
/// typed wrappers over the v7 control messages. Not `Sync` — clone
/// nothing, open one client per thread (CI's serve pass deliberately
/// drives two jobs from two separate client processes).
pub struct JobClient {
    transport: Box<dyn Transport>,
    binary: bool,
}

impl JobClient {
    /// Dial `addr` and run the client-role handshake (presenting `auth`
    /// when given). Fails with a named error on version mismatch, auth
    /// mismatch, or a daemon that rejects the role.
    pub fn connect(addr: &str, auth: Option<&str>) -> io::Result<JobClient> {
        let resolved = addr
            .to_socket_addrs()
            .map_err(|e| {
                io::Error::new(
                    io::ErrorKind::InvalidInput,
                    format!("cannot resolve serve daemon address '{addr}': {e}"),
                )
            })?
            .next()
            .ok_or_else(|| {
                io::Error::new(
                    io::ErrorKind::InvalidInput,
                    format!("serve daemon address '{addr}' resolved to nothing"),
                )
            })?;
        let stream = TcpStream::connect_timeout(&resolved, SERVE_CONNECT_TIMEOUT).map_err(|e| {
            io::Error::new(
                e.kind(),
                format!("cannot reach serve daemon at {addr}: {e} — is `parccm serve` running?"),
            )
        })?;
        let mut transport: Box<dyn Transport> = Box::new(TcpTransport::from_stream(stream)?);
        transport.set_recv_deadline(Some(SERVE_CONNECT_TIMEOUT))?;
        let mut fields = vec![
            ("type", Json::Str("hello".into())),
            ("v", Json::Num(WIRE_VERSION as f64)),
            ("pid", Json::Num(std::process::id() as f64)),
            ("transport", Json::Str(TransportKind::Tcp.name().into())),
            ("caps", Json::Arr(Vec::new())),
            ("role", Json::Str("client".into())),
        ];
        if let Some(token) = auth {
            fields.push(("auth", Json::Str(token.to_string())));
        }
        transport.send_line(&Json::obj(fields).to_string())?;
        let ack = recv_json(transport.as_mut())?;
        match ack.get("type").and_then(Json::as_str) {
            Some("hello_ack") => {}
            Some("reject") => {
                let why = ack.get("msg").and_then(Json::as_str).unwrap_or("unspecified");
                return Err(io::Error::new(
                    io::ErrorKind::PermissionDenied,
                    format!("serve daemon at {addr} rejected this client: {why}"),
                ));
            }
            other => {
                return Err(invalid_data(format!(
                    "expected hello_ack from serve daemon at {addr}, got {other:?}"
                )))
            }
        }
        // mutual auth, exactly like a worker verifying its driver: the
        // ack must echo the token this client presented
        if auth.is_some() && ack.get("auth").and_then(Json::as_str) != auth {
            return Err(io::Error::new(
                io::ErrorKind::PermissionDenied,
                format!(
                    "auth token mismatch: the hello_ack from {addr} does not echo this \
                     client's token"
                ),
            ));
        }
        let negotiated =
            ack.get("v").and_then(Json::as_f64).map(|v| v as u64).unwrap_or(0).min(WIRE_VERSION);
        transport.set_recv_deadline(None)?;
        let transport: Box<dyn Transport> = if negotiated >= CHECKSUM_WIRE_VERSION {
            Box::new(ChecksumTransport::new(transport, None))
        } else {
            transport
        };
        Ok(JobClient { transport, binary: negotiated >= BINARY_WIRE_VERSION })
    }

    /// Send one control message and return the daemon's reply verbatim
    /// (including `error` replies — the typed wrappers below surface
    /// those as `io::Error`s).
    pub fn request(&mut self, msg: &Json) -> io::Result<Json> {
        send_ctl(self.transport.as_mut(), self.binary, msg)?;
        recv_ctl(self.transport.as_mut(), self.binary)?.ok_or_else(|| {
            io::Error::new(io::ErrorKind::UnexpectedEof, "serve daemon closed the connection")
        })
    }

    fn expect(&mut self, msg: &Json, want: &str) -> io::Result<Json> {
        let reply = self.request(msg)?;
        match reply.get("type").and_then(Json::as_str) {
            Some(t) if t == want => Ok(reply),
            Some("error") => {
                let why = reply.get("msg").and_then(Json::as_str).unwrap_or("unspecified");
                Err(io::Error::other(format!("serve daemon: {why}")))
            }
            other => Err(invalid_data(format!("expected {want} reply, got {other:?}: {reply}"))),
        }
    }

    /// Submit a job; returns its id.
    pub fn submit(&mut self, spec: &JobSpec) -> io::Result<u64> {
        let reply = self.expect(
            &Json::obj(vec![("spec", spec.to_json()), ("type", Json::Str("submit".into()))]),
            "submitted",
        )?;
        job_field(&reply)
            .ok_or_else(|| invalid_data(format!("submitted reply carries no job id: {reply}")))
    }

    /// The job's `status` reply (state, per-job counters, error if any).
    pub fn status(&mut self, job: u64) -> io::Result<Json> {
        self.expect(
            &Json::obj(vec![("job", Json::Num(job as f64)), ("type", Json::Str("status".into()))]),
            "status",
        )
    }

    /// The canonical skills dump of a `done` job — byte-identical to the
    /// batch `--dump-skills` output for the same spec.
    pub fn fetch(&mut self, job: u64) -> io::Result<String> {
        let reply = self.expect(
            &Json::obj(vec![("job", Json::Num(job as f64)), ("type", Json::Str("fetch".into()))]),
            "result",
        )?;
        reply
            .get("skills")
            .and_then(Json::as_str)
            .map(str::to_string)
            .ok_or_else(|| invalid_data(format!("result reply carries no skills: {reply}")))
    }

    /// Cancel a job; returns the outcome name — `"cancelled"` for a
    /// queued (or already-cancelled) job, `"cancelling"` for a running
    /// one whose driver will stop best-effort at its next
    /// partial-evaluation checkpoint.
    pub fn cancel(&mut self, job: u64) -> io::Result<String> {
        let reply = self.expect(
            &Json::obj(vec![("job", Json::Num(job as f64)), ("type", Json::Str("cancel".into()))]),
            "cancelled",
        )?;
        Ok(reply.get("state").and_then(Json::as_str).unwrap_or("cancelled").to_string())
    }

    /// Ask the daemon to stop accepting jobs and drain.
    pub fn shutdown_daemon(&mut self) -> io::Result<()> {
        self.expect(&Json::obj(vec![("type", Json::Str("shutdown".into()))]), "shutdown_ack")
            .map(|_| ())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ccm::driver::Case;
    use crate::ccm::params::Scenario;
    use crate::native::NativeBackend;

    /// An in-process pool: every job computes on the native backend. The
    /// service half (tracker, protocol, threads) is identical to the
    /// cluster deployment — exactly what these tests pin down.
    struct NativePool;

    impl JobPool for NativePool {
        fn backend_for(&self, _job: u64) -> Arc<dyn ComputeBackend> {
            Arc::new(NativeBackend)
        }

        fn tally_for(&self, _job: u64) -> JobTally {
            JobTally::default()
        }
    }

    fn spec(case: Case) -> JobSpec {
        JobSpec::new(case, Scenario::smoke())
    }

    #[test]
    fn tracker_admits_fifo_within_the_concurrency_bound() {
        let tracker = JobTracker::new(1);
        let a = tracker.submit(spec(Case::A1));
        let b = tracker.submit(spec(Case::A2));
        let c = tracker.submit(spec(Case::A4));
        assert_eq!((a.0, b.0, c.0), (1, 2, 3), "ids start at 1 — job 0 is the batch path");
        assert_eq!(tracker.queued(), 3);
        let (first, _) = tracker.admit().expect("slot free");
        assert_eq!(first, a, "FIFO admission");
        assert!(tracker.admit().is_none(), "bound of 1 admits one job");
        assert_eq!(tracker.state(a), Some(JobState::Running));
        assert_eq!(tracker.state(b), Some(JobState::Queued));
        assert_eq!(tracker.running(), 1);
        assert_eq!(tracker.queued(), 2);
        assert!(!tracker.idle());
        tracker.finish(a, "{}".to_string());
        assert_eq!(tracker.state(a), Some(JobState::Done));
        assert_eq!(tracker.fetch(a).unwrap(), "{}");
        let (second, _) = tracker.admit().expect("slot freed");
        assert_eq!(second, b, "FIFO continues");
        tracker.fail(b, "boom".to_string());
        assert_eq!(tracker.state(b), Some(JobState::Failed));
        let (state, err, cancelled_running) = tracker.status(b).unwrap();
        assert_eq!(state, JobState::Failed);
        assert_eq!(err.as_deref(), Some("boom"));
        assert!(!cancelled_running);
        assert!(tracker.fetch(b).unwrap_err().contains("boom"));
        let (third, _) = tracker.admit().expect("last job");
        assert_eq!(third, c);
        tracker.finish(c, "{}".to_string());
        assert!(tracker.idle());
        assert_eq!(tracker.jobs_served(), 3);
        // wider bounds admit in parallel
        let wide = JobTracker::new(2);
        wide.submit(spec(Case::A1));
        wide.submit(spec(Case::A1));
        wide.submit(spec(Case::A1));
        assert!(wide.admit().is_some());
        assert!(wide.admit().is_some());
        assert!(wide.admit().is_none(), "bound of 2");
        assert_eq!(wide.running(), 2);
    }

    #[test]
    fn tracker_cancels_queued_exactly_and_running_best_effort() {
        let tracker = JobTracker::new(1);
        let a = tracker.submit(spec(Case::A1));
        let b = tracker.submit(spec(Case::A2));
        let (running, _) = tracker.admit().unwrap();
        assert_eq!(running, a);
        // running: best-effort — the flag flips, the state stays Running
        assert!(!tracker.cancel_flag(a).unwrap().load(Ordering::Relaxed));
        assert_eq!(tracker.cancel(a), Ok(CancelOutcome::Cancelling));
        assert!(tracker.cancel_flag(a).unwrap().load(Ordering::Relaxed));
        assert_eq!(tracker.state(a), Some(JobState::Running));
        assert_eq!(tracker.cancel(a), Ok(CancelOutcome::Cancelling), "re-cancel re-signals");
        // queued: cancelled exactly, and admit skips it
        assert_eq!(tracker.cancel(b), Ok(CancelOutcome::Cancelled));
        assert_eq!(tracker.cancel(b), Ok(CancelOutcome::Cancelled), "idempotent");
        assert_eq!(tracker.state(b), Some(JobState::Cancelled));
        let (_, _, b_running_cancel) = tracker.status(b).unwrap();
        assert!(!b_running_cancel, "queued cancel is not a running cancel");
        // the runner observes a's flag and settles it
        tracker.cancelled_while_running(a);
        assert_eq!(tracker.state(a), Some(JobState::Cancelled));
        let (state, err, cancelled_running) = tracker.status(a).unwrap();
        assert_eq!((state, err), (JobState::Cancelled, None));
        assert!(cancelled_running, "status distinguishes the running-cancel flavour");
        assert!(tracker.admit().is_none(), "cancelled jobs are never admitted");
        assert!(tracker.idle());
        // terminal cancels: cancelled is idempotent, done/failed refuse
        assert_eq!(tracker.cancel(a), Ok(CancelOutcome::Cancelled));
        let c = tracker.submit(spec(Case::A4));
        let (admitted, _) = tracker.admit().unwrap();
        assert_eq!(admitted, c);
        tracker.finish(c, "{}".to_string());
        let err = tracker.cancel(c).unwrap_err();
        assert!(err.contains("done"), "{err}");
        assert!(tracker.cancel(JobId(99)).unwrap_err().contains("unknown job"));
        // fetch of a cancelled job points at the state
        assert!(tracker.fetch(a).unwrap_err().contains("cancelled"));
        assert!(tracker.fetch(b).unwrap_err().contains("cancelled"));
        assert_eq!(
            tracker.jobs_served(),
            2,
            "a ran (then cancelled) and c ran; cancelled-in-queue b never did"
        );
        assert_eq!(CancelOutcome::Cancelling.name(), "cancelling");
    }

    #[test]
    fn job_state_names_are_stable() {
        for (state, name) in [
            (JobState::Queued, "queued"),
            (JobState::Running, "running"),
            (JobState::Done, "done"),
            (JobState::Failed, "failed"),
            (JobState::Cancelled, "cancelled"),
        ] {
            assert_eq!(state.name(), name);
        }
        assert!(!JobState::Queued.is_terminal());
        assert!(!JobState::Running.is_terminal());
        assert!(JobState::Done.is_terminal() && JobState::Cancelled.is_terminal());
    }

    #[test]
    fn daemon_serves_concurrent_jobs_byte_identical_to_batch() {
        let mut daemon = ServeDaemon::start(
            NativePool,
            ServeOptions {
                listen: "127.0.0.1:0".to_string(),
                auth_token: Some("sesame".to_string()),
                max_concurrent_jobs: 2,
            },
        )
        .expect("daemon binds an ephemeral port");
        let addr = daemon.addr().to_string();

        // wrong auth is a named rejection, not a hang
        let err = JobClient::connect(&addr, Some("wrong")).unwrap_err();
        assert!(err.to_string().contains("auth token mismatch"), "{err}");
        // a missing token against an auth-requiring daemon likewise
        assert!(JobClient::connect(&addr, None).is_err());

        // two tenants, two connections, overlapping jobs
        let mut c1 = JobClient::connect(&addr, Some("sesame")).expect("client 1 handshake");
        let mut c2 = JobClient::connect(&addr, Some("sesame")).expect("client 2 handshake");
        let s1 = spec(Case::A1);
        let s2 = spec(Case::A4);
        let j1 = c1.submit(&s1).unwrap();
        let j2 = c2.submit(&s2).unwrap();
        assert_ne!(j1, j2);
        assert!(j1 >= 1 && j2 >= 1, "job 0 is reserved for the batch path");

        let wait_done = |c: &mut JobClient, job: u64| loop {
            let st = c.status(job).expect("status reply");
            match st.get("state").and_then(Json::as_str) {
                Some("done") => {
                    assert!(st.get("counters").is_some(), "status carries per-job counters");
                    assert!(
                        matches!(st.get("cancelled_running"), Some(Json::Bool(false))),
                        "an uncancelled job reports cancelled_running:false: {st}"
                    );
                    return;
                }
                Some("failed") => panic!("job {job} failed: {st}"),
                _ => std::thread::sleep(Duration::from_millis(5)),
            }
        };
        wait_done(&mut c1, j1);
        wait_done(&mut c2, j2);

        // each tenant's dump is byte-identical to the same spec run batch
        let want1 = skills_to_json(&s1.run(Arc::new(NativeBackend)).skills).to_string();
        let want2 = skills_to_json(&s2.run(Arc::new(NativeBackend)).skills).to_string();
        assert_eq!(c1.fetch(j1).unwrap(), want1, "job {j1} dump != batch dump");
        assert_eq!(c2.fetch(j2).unwrap(), want2, "job {j2} dump != batch dump");
        // cross-tenant reads work too: the tracker is shared state
        assert_eq!(c2.fetch(j1).unwrap(), want1);

        // named errors for bad requests
        let err = c1.fetch(9999).unwrap_err();
        assert!(err.to_string().contains("unknown job"), "{err}");
        let err = c1.cancel(j1).unwrap_err();
        assert!(err.to_string().contains("done"), "{err}");

        c1.shutdown_daemon().expect("shutdown ack");
        daemon.shutdown();
        assert_eq!(daemon.tracker().jobs_served(), 2);
    }

    #[test]
    fn cancelling_a_running_job_stops_it_at_a_checkpoint() {
        let mut daemon =
            ServeDaemon::start(NativePool, ServeOptions::default()).expect("daemon starts");
        let addr = daemon.addr().to_string();
        let mut client = JobClient::connect(&addr, None).unwrap();
        // a grid big enough that the cancel lands mid-run
        let mut slow = spec(Case::A1);
        slow.scenario.series_len = 500;
        slow.scenario.r = 256;
        slow.scenario.ls = vec![100, 200, 300, 400];
        let j = client.submit(&slow).unwrap();
        // wait until it is computing, then cancel; on a machine fast
        // enough to finish the whole grid first, the cancel is simply
        // too late — that is the documented best-effort contract, and
        // the remaining assertions would not apply
        loop {
            let st = client.status(j).unwrap();
            match st.get("state").and_then(Json::as_str) {
                Some("running") => break,
                Some("done") => {
                    daemon.shutdown();
                    return;
                }
                _ => std::thread::sleep(Duration::from_millis(1)),
            }
        }
        assert_eq!(client.cancel(j).unwrap(), "cancelling", "running jobs cancel best-effort");
        let settled = loop {
            let st = client.status(j).unwrap();
            match st.get("state").and_then(Json::as_str) {
                Some("cancelled") | Some("done") => break st,
                Some("failed") => panic!("cancelled job failed instead: {st}"),
                _ => std::thread::sleep(Duration::from_millis(2)),
            }
        };
        if settled.get("state").and_then(Json::as_str) == Some("cancelled") {
            assert!(
                matches!(settled.get("cancelled_running"), Some(Json::Bool(true))),
                "status must mark the running-cancel: {settled}"
            );
            let err = client.fetch(j).unwrap_err();
            assert!(err.to_string().contains("cancelled"), "{err}");
            // re-cancelling the settled job is an idempotent success
            assert_eq!(client.cancel(j).unwrap(), "cancelled");
        }
        // the daemon still serves after a cancelled job
        let ok = client.submit(&spec(Case::A1)).unwrap();
        loop {
            let st = client.status(ok).unwrap();
            if st.get("state").and_then(Json::as_str) == Some("done") {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        daemon.shutdown();
    }

    #[test]
    fn daemon_rejects_worker_style_hellos_by_name() {
        let mut daemon =
            ServeDaemon::start(NativePool, ServeOptions::default()).expect("daemon starts");
        let addr = daemon.addr().to_string();
        // a worker-style hello: right version, no role
        let stream = TcpStream::connect(&addr).unwrap();
        let mut t: Box<dyn Transport> = Box::new(TcpTransport::from_stream(stream).unwrap());
        let hello = Json::obj(vec![
            ("type", Json::Str("hello".into())),
            ("v", Json::Num(WIRE_VERSION as f64)),
            ("pid", Json::Num(1.0)),
        ]);
        t.send_line(&hello.to_string()).unwrap();
        let reply = recv_json(t.as_mut()).unwrap();
        assert_eq!(reply.get("type").and_then(Json::as_str), Some("reject"));
        let why = reply.get("msg").and_then(Json::as_str).unwrap_or("");
        assert!(why.contains("role \"client\""), "{why}");
        daemon.shutdown();
    }

    #[test]
    fn a_failing_job_reports_failed_without_killing_the_daemon() {
        let mut daemon =
            ServeDaemon::start(NativePool, ServeOptions::default()).expect("daemon starts");
        let addr = daemon.addr().to_string();
        let mut client = JobClient::connect(&addr, None).unwrap();
        // L=3 < E+2: CcmParams::new panics inside the runner
        let mut bad = spec(Case::A1);
        bad.scenario.ls = vec![3];
        let j = client.submit(&bad).unwrap();
        let failed = loop {
            let st = client.status(j).unwrap();
            match st.get("state").and_then(Json::as_str) {
                Some("failed") => break st,
                Some("done") => panic!("bad spec unexpectedly succeeded"),
                _ => std::thread::sleep(Duration::from_millis(5)),
            }
        };
        assert!(
            failed.get("error").and_then(Json::as_str).is_some(),
            "status carries the failure: {failed}"
        );
        assert!(client.fetch(j).unwrap_err().to_string().contains("failed"));
        // the daemon still serves: a good job after a failed one
        let ok = client.submit(&spec(Case::A1)).unwrap();
        loop {
            let st = client.status(ok).unwrap();
            if st.get("state").and_then(Json::as_str) == Some("done") {
                break;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
        daemon.shutdown();
        assert_eq!(daemon.tracker().jobs_served(), 2, "failed jobs count as served");
    }
}
