//! Random library subsampling — the `r` realizations dimension.
//!
//! Each realization draws `L` distinct manifold rows (without replacement,
//! ascending). Draws are seeded per `(combo, sample_id)` via RNG forking,
//! so results are independent of partitioning, scheduling and case (A1–A5
//! produce identical libraries for identical seeds — the property the
//! equivalence tests rely on).

use crate::ccm::params::CcmParams;
use crate::util::rng::Rng;

/// One realization: which manifold rows form the library.
#[derive(Clone, Debug)]
pub struct LibrarySample {
    /// Realization id within its combo, `0..r`.
    pub sample_id: usize,
    /// Parameter combination this sample belongs to.
    pub params: CcmParams,
    /// Ascending manifold row indices, length `min(L, n_manifold)`.
    pub rows: Vec<usize>,
}

/// Stable sub-seed for a combo (mixes e/tau/l so different combos never
/// share library draws).
fn combo_stream(params: &CcmParams) -> u64 {
    (params.e as u64)
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add((params.tau as u64).wrapping_mul(0xC2B2AE3D27D4EB4F))
        .wrapping_add((params.l as u64).wrapping_mul(0x165667B19E3779F9))
}

/// Draw `r` library samples of size `params.l` from a manifold of
/// `n_manifold` rows.
pub fn draw_samples(
    master: &Rng,
    params: CcmParams,
    n_manifold: usize,
    r: usize,
) -> Vec<LibrarySample> {
    let l = params.l.min(n_manifold);
    let combo_rng = master.fork(combo_stream(&params));
    (0..r)
        .map(|sample_id| {
            let mut rng = combo_rng.fork(sample_id as u64);
            LibrarySample { sample_id, params, rows: rng.sample_indices(n_manifold, l) }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn draws_r_samples_of_size_l() {
        let master = Rng::new(1);
        let p = CcmParams::new(2, 1, 50);
        let s = draw_samples(&master, p, 200, 10);
        assert_eq!(s.len(), 10);
        for (i, smp) in s.iter().enumerate() {
            assert_eq!(smp.sample_id, i);
            assert_eq!(smp.rows.len(), 50);
            assert!(smp.rows.windows(2).all(|w| w[0] < w[1]));
            assert!(smp.rows.iter().all(|&r| r < 200));
        }
    }

    #[test]
    fn l_clamped_to_manifold() {
        let master = Rng::new(1);
        let p = CcmParams::new(2, 1, 500);
        let s = draw_samples(&master, p, 100, 2);
        assert_eq!(s[0].rows.len(), 100);
        assert_eq!(s[0].rows, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn deterministic_and_combo_independent() {
        let master = Rng::new(42);
        let p1 = CcmParams::new(2, 1, 20);
        let p2 = CcmParams::new(4, 1, 20);
        let a = draw_samples(&master, p1, 100, 5);
        let b = draw_samples(&master, p1, 100, 5);
        let c = draw_samples(&master, p2, 100, 5);
        for i in 0..5 {
            assert_eq!(a[i].rows, b[i].rows, "same combo must reproduce");
        }
        assert_ne!(a[0].rows, c[0].rows, "different combos must differ");
    }

    #[test]
    fn samples_differ_across_ids() {
        let master = Rng::new(3);
        let p = CcmParams::new(3, 2, 30);
        let s = draw_samples(&master, p, 500, 20);
        let distinct: std::collections::HashSet<_> = s.iter().map(|x| x.rows.clone()).collect();
        assert_eq!(distinct.len(), 20, "realizations should be distinct draws");
    }
}
