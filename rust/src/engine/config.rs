//! Engine configuration: deploy topology + cost-model constants.

/// Where (and how wide) jobs run.
///
/// The paper compares two submission modes on a Google Cloud cluster:
/// *Local Mode* (all work on the master node) and *Yarn Mode* (1 master +
/// 5 workers x 4 cores). This box has one physical core, so topology-level
/// parallelism is reproduced by the discrete-event simulator ([`crate::engine::des`])
/// replaying measured task durations against the configured topology.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum Deploy {
    /// One driver thread, no executor parallelism (paper Case A1 substrate).
    SingleThread,
    /// Master-node only, `cores` executor slots (paper "Local Mode").
    Local { cores: usize },
    /// `workers` worker nodes with `cores_per_worker` slots each
    /// (paper "Yarn Mode"; the paper's cluster is `workers: 5,
    /// cores_per_worker: 4`).
    Cluster { workers: usize, cores_per_worker: usize },
}

impl Deploy {
    /// The paper's evaluation cluster.
    pub fn paper_cluster() -> Deploy {
        Deploy::Cluster { workers: 5, cores_per_worker: 4 }
    }

    /// The paper's local mode (4-core master).
    pub fn paper_local() -> Deploy {
        Deploy::Local { cores: 4 }
    }

    /// Total executor slots in the topology.
    pub fn total_cores(&self) -> usize {
        match self {
            Deploy::SingleThread => 1,
            Deploy::Local { cores } => *cores,
            Deploy::Cluster { workers, cores_per_worker } => workers * cores_per_worker,
        }
    }

    /// Number of distinct nodes (broadcast ship targets).
    pub fn nodes(&self) -> usize {
        match self {
            Deploy::SingleThread | Deploy::Local { .. } => 1,
            Deploy::Cluster { workers, .. } => *workers,
        }
    }

    /// Node id for a given core slot.
    pub fn node_of_core(&self, core: usize) -> usize {
        match self {
            Deploy::SingleThread | Deploy::Local { .. } => 0,
            Deploy::Cluster { cores_per_worker, .. } => core / cores_per_worker,
        }
    }
}

/// How the DES converts a payload's raw in-memory size (4-byte lanes)
/// into on-wire bytes — the cost-model face of the cluster runtime's
/// per-connection wire negotiation (v6 binary frames vs the legacy JSON
/// line protocol).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum WirePricing {
    /// v6 binary framing: f32/u32 arrays ship as raw little-endian bytes,
    /// so the wire size is the raw size (tag/length/varint overhead is a
    /// rounding error at broadcast scale). The default — a homogeneous
    /// current-version pool negotiates binary on every connection.
    #[default]
    Binary,
    /// JSON line wire (any v<=5 peer in the pool pins its connections to
    /// it): a decimal-text f32 averages ~11 characters with its
    /// separator, so each raw 4-byte lane inflates by ~11/4.
    Json,
}

impl WirePricing {
    /// Price `raw` in-memory bytes as on-wire bytes.
    pub fn bytes(self, raw: u64) -> u64 {
        match self {
            WirePricing::Binary => raw,
            WirePricing::Json => raw * 11 / 4,
        }
    }
}

/// Engine configuration.
#[derive(Clone, Debug)]
pub struct EngineConfig {
    /// Topology the DES replays task logs against.
    pub deploy: Deploy,
    /// Default number of partitions for `parallelize` when unspecified.
    pub default_parallelism: usize,
    /// Per-task fixed overhead in the DES (scheduler delay, serialization,
    /// result shipping). Spark's is ~5-10 ms; ours defaults lower because
    /// tasks carry no JVM/serde cost.
    pub task_overhead_us: u64,
    /// Simulated broadcast bandwidth, MB/s per node link (DES).
    pub broadcast_mb_per_s: f64,
    /// Broadcast replication factor in the DES ship model (the cluster
    /// runtime's `--replicas R`): the first ship of a broadcast also
    /// places copies on `R - 1` further nodes (each on its own link), so
    /// a task re-run on a replica node after a worker death ships zero
    /// additional bytes. 1 = ship only where tasks land (Spark default).
    pub broadcast_replicas: usize,
    /// Worker-node failures to price in the DES (what-if knob mirroring
    /// the cluster runtime's eager re-replication): each failure costs one
    /// repair ship per broadcast resident on the failed node, restoring
    /// the replication factor on a surviving node — reported as
    /// `sim_repair_ship_s` / `sim_repair_ship_bytes`. Only meaningful with
    /// `broadcast_replicas > 1`, matching the real pool (at factor 1 the
    /// runtime re-ships lazily, task-driven). 0 = no failures priced.
    pub sim_worker_failures: usize,
    /// Worker-node *rejoins* to price in the DES (the cluster runtime's
    /// `--rejoin-backoff-secs`): rejoin `k` revives the node failure `k`
    /// dropped, with an **empty** broadcast store — its next tasks
    /// lazily re-fetch every broadcast it held, reported as
    /// `sim_rejoin_ship_s` / `sim_rejoin_ship_bytes` (distinct from the
    /// eager repair counters, at any replication factor — a rejoined
    /// worker always starts empty). Rejoins beyond `sim_worker_failures`
    /// have no dead node to revive and price nothing.
    pub sim_worker_rejoins: usize,
    /// Speculative task duplicates to price in the DES (the cluster
    /// runtime's `--speculate-factor` straggler defense): the `k`
    /// longest tasks in the log are assumed to straggle and be
    /// speculatively re-executed, so each contributes its full duration
    /// a second time — reported as `sim_speculative_task_s`, its own
    /// counter beside the makespan (speculation burns spare capacity; it
    /// does not serialize the critical path). Clamped to the task count.
    /// 0 = no speculation priced.
    pub sim_speculative_tasks: usize,
    /// Tasks *saved* by partial evaluation to price in the DES (the
    /// driver's `--partial eps,conf` early termination): each saved task
    /// is priced at the mean measured task duration and reported as
    /// `sim_partial_saved_task_s` — its own counter, **subtracted from
    /// nothing**: it quantifies compute the run did not spend, beside the
    /// makespan of the tasks it did. The driver sets this from its
    /// harvest tally (`PoolCounters::partial_saved_tasks`).
    /// 0 = nothing saved.
    pub sim_partial_saved_tasks: usize,
    /// Concurrent tenant jobs to price in the DES (the serve daemon's
    /// `--max-concurrent-jobs` admission bound): the measured task log is
    /// treated as one tenant's job and replayed as `n` identical jobs
    /// sharing the same topology — task clones contend for the same
    /// executor slots, but broadcast ships are **not** cloned, because
    /// the warm pool's job-refcounted payload cache ships a shared
    /// problem once no matter how many tenants pose it. Reported as
    /// `sim_concurrent_jobs` beside the (now multi-tenant) makespan.
    /// 1 = the batch baseline, a single job owning the pool.
    pub sim_concurrent_jobs: usize,
    /// Wire encoding the DES prices broadcast/repair/rejoin traffic at.
    /// Defaults to [`WirePricing::Binary`] (the v6 wire); a driver running
    /// against a pool with pinned-JSON connections sets
    /// [`WirePricing::Json`] so simulated bytes track the real wire.
    pub wire_pricing: WirePricing,
    /// OS threads actually executing tasks (defaults to the machine's
    /// available parallelism; results never depend on this).
    pub real_threads: usize,
    /// Maximum attempts per task before the job is failed (Spark's
    /// `spark.task.maxFailures`, default 4 there; tasks are retried on
    /// panic — the "resilient" in RDD).
    pub max_task_attempts: usize,
}

impl EngineConfig {
    pub fn new(deploy: Deploy) -> EngineConfig {
        let hw = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        let real_threads = match deploy {
            Deploy::SingleThread => 1,
            _ => hw,
        };
        EngineConfig {
            deploy,
            default_parallelism: 8,
            task_overhead_us: 500,
            broadcast_mb_per_s: 400.0,
            broadcast_replicas: 1,
            sim_worker_failures: 0,
            sim_worker_rejoins: 0,
            sim_speculative_tasks: 0,
            sim_partial_saved_tasks: 0,
            sim_concurrent_jobs: 1,
            wire_pricing: WirePricing::Binary,
            real_threads,
            max_task_attempts: 4,
        }
    }

    pub fn with_wire_pricing(mut self, pricing: WirePricing) -> Self {
        self.wire_pricing = pricing;
        self
    }

    pub fn with_broadcast_replicas(mut self, r: usize) -> Self {
        self.broadcast_replicas = r.max(1);
        self
    }

    pub fn with_sim_worker_failures(mut self, n: usize) -> Self {
        self.sim_worker_failures = n;
        self
    }

    pub fn with_sim_worker_rejoins(mut self, n: usize) -> Self {
        self.sim_worker_rejoins = n;
        self
    }

    pub fn with_sim_speculative_tasks(mut self, n: usize) -> Self {
        self.sim_speculative_tasks = n;
        self
    }

    pub fn with_sim_partial_saved_tasks(mut self, n: usize) -> Self {
        self.sim_partial_saved_tasks = n;
        self
    }

    pub fn with_sim_concurrent_jobs(mut self, n: usize) -> Self {
        self.sim_concurrent_jobs = n.max(1);
        self
    }

    pub fn with_max_task_attempts(mut self, n: usize) -> Self {
        self.max_task_attempts = n.max(1);
        self
    }

    pub fn with_default_parallelism(mut self, p: usize) -> Self {
        self.default_parallelism = p.max(1);
        self
    }

    pub fn with_task_overhead_us(mut self, us: u64) -> Self {
        self.task_overhead_us = us;
        self
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_topologies() {
        assert_eq!(Deploy::paper_cluster().total_cores(), 20);
        assert_eq!(Deploy::paper_cluster().nodes(), 5);
        assert_eq!(Deploy::paper_local().total_cores(), 4);
        assert_eq!(Deploy::paper_local().nodes(), 1);
    }

    #[test]
    fn node_of_core_maps_contiguously() {
        let d = Deploy::Cluster { workers: 3, cores_per_worker: 4 };
        assert_eq!(d.node_of_core(0), 0);
        assert_eq!(d.node_of_core(3), 0);
        assert_eq!(d.node_of_core(4), 1);
        assert_eq!(d.node_of_core(11), 2);
    }

    #[test]
    fn single_thread_uses_one_real_thread() {
        assert_eq!(EngineConfig::new(Deploy::SingleThread).real_threads, 1);
    }

    #[test]
    fn wire_pricing_defaults_to_binary_identity() {
        let c = EngineConfig::new(Deploy::SingleThread);
        assert_eq!(c.wire_pricing, WirePricing::Binary);
        assert_eq!(WirePricing::Binary.bytes(4000), 4000);
        // a decimal-text f32 averages ~11 chars per 4 raw bytes
        assert_eq!(WirePricing::Json.bytes(4000), 11_000);
        assert_eq!(WirePricing::Json.bytes(0), 0);
    }
}
