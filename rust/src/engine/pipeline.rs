//! Transform pipelines: named sequences of RDD->RDD stages (paper §3).
//!
//! "The pipeline is specified as a sequence of stages, and each stage
//! transforms the original RDD to another RDD accordingly." A
//! `Pipeline<I, O>` composes such stages while keeping their names for
//! logging; `apply` is lazy (returns the composed RDD), `run`/`run_async`
//! attach an action.

use std::sync::Arc;

use super::context::Context;
use super::future_action::FutureAction;
use super::rdd::Rdd;

type StageFn<I, O> = Arc<dyn Fn(&Context, Rdd<I>) -> Rdd<O> + Send + Sync>;

/// A named, composable RDD transformation chain.
pub struct Pipeline<I, O> {
    name: String,
    stages: Vec<String>,
    f: StageFn<I, O>,
}

impl<I, O> Clone for Pipeline<I, O> {
    fn clone(&self) -> Self {
        Pipeline { name: self.name.clone(), stages: self.stages.clone(), f: Arc::clone(&self.f) }
    }
}

impl<I: Send + Sync + 'static, O: Send + Sync + 'static> Pipeline<I, O> {
    /// A single-stage pipeline.
    pub fn new<F>(name: impl Into<String>, stage: F) -> Pipeline<I, O>
    where
        F: Fn(&Context, Rdd<I>) -> Rdd<O> + Send + Sync + 'static,
    {
        let name = name.into();
        Pipeline { stages: vec![name.clone()], name, f: Arc::new(stage) }
    }

    /// Append a stage, producing a longer pipeline.
    pub fn then<P, F>(self, stage_name: impl Into<String>, stage: F) -> Pipeline<I, P>
    where
        P: Send + Sync + 'static,
        F: Fn(&Context, Rdd<O>) -> Rdd<P> + Send + Sync + 'static,
        O: Clone,
    {
        let stage_name = stage_name.into();
        let mut stages = self.stages.clone();
        stages.push(stage_name);
        let prev = self.f;
        Pipeline {
            name: self.name.clone(),
            stages,
            f: Arc::new(move |ctx, input| stage(ctx, prev(ctx, input))),
        }
    }

    /// Compose lazily: input RDD -> output RDD, no job submitted.
    pub fn apply(&self, ctx: &Context, input: Rdd<I>) -> Rdd<O> {
        (self.f)(ctx, input)
    }

    /// Apply + blocking collect.
    pub fn run(&self, ctx: &Context, input: Rdd<I>) -> Vec<O>
    where
        O: Clone,
    {
        ctx.collect(&self.apply(ctx, input))
    }

    /// Apply + asynchronous collect (paper §3.3 — concurrent pipelines).
    pub fn run_async(&self, ctx: &Context, input: Rdd<I>) -> FutureAction<Vec<O>>
    where
        O: Clone,
    {
        ctx.collect_async(&self.apply(ctx, input))
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    /// Stage names, in order.
    pub fn stages(&self) -> &[String] {
        &self.stages
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::config::{Deploy, EngineConfig};

    fn ctx() -> Context {
        Context::new(EngineConfig::new(Deploy::Local { cores: 2 }).with_default_parallelism(3))
    }

    #[test]
    fn single_stage() {
        let c = ctx();
        let p: Pipeline<i32, i32> = Pipeline::new("double", |_, rdd| rdd.map(|x| x * 2));
        let got = p.run(&c, c.parallelize(vec![1, 2, 3]));
        assert_eq!(got, vec![2, 4, 6]);
    }

    #[test]
    fn multi_stage_composition_and_names() {
        let c = ctx();
        let p = Pipeline::<i32, i32>::new("embed", |_, rdd| rdd.map(|x| x + 1))
            .then("square", |_, rdd| rdd.map(|x| x * x))
            .then("stringify", |_, rdd| rdd.map(|x| format!("v{x}")));
        assert_eq!(p.stages(), &["embed", "square", "stringify"]);
        let got = p.run(&c, c.parallelize(vec![1, 2]));
        assert_eq!(got, vec!["v4".to_string(), "v9".to_string()]);
    }

    #[test]
    fn run_async_overlaps() {
        let c = ctx();
        let p: Pipeline<u64, u64> = Pipeline::new("spin", |_, rdd| {
            rdd.map(|x: u64| {
                let mut acc = x;
                for i in 0..10_000u64 {
                    acc = acc.wrapping_mul(31).wrapping_add(i);
                }
                acc
            })
        });
        let f1 = p.run_async(&c, c.parallelize((0..30).collect()));
        let f2 = p.run_async(&c, c.parallelize((0..30).collect()));
        assert_eq!(f1.get().len(), 30);
        assert_eq!(f2.get().len(), 30);
    }
}
