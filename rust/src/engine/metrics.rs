//! Event log + execution report: every job/task is recorded with
//! wall-clock-relative timestamps so the DES can replay the run against an
//! arbitrary cluster topology and the coordinator can report utilization.

use std::sync::Mutex;

use crate::util::json::Json;

/// One executed task.
#[derive(Clone, Debug)]
pub struct TaskRecord {
    pub job_id: u64,
    pub partition: usize,
    /// Seconds since context creation when the task started executing.
    pub start_rel: f64,
    /// Task busy duration in seconds (pure compute, excludes queue wait;
    /// includes retried attempts).
    pub duration: f64,
    /// Number of attempts it took to succeed (1 = first try).
    pub attempts: u32,
}

/// One submitted job (every action = one job; narrow transforms fuse, so
/// each job has exactly one stage of `num_tasks` tasks).
#[derive(Clone, Debug)]
pub struct JobRecord {
    pub job_id: u64,
    pub name: String,
    pub num_tasks: usize,
    /// Seconds since context creation at submission.
    pub submit_rel: f64,
    /// Seconds since context creation when the last task finished
    /// (f64::NAN until completion).
    pub finish_rel: f64,
    /// Broadcast variables the job's lineage reads: (id, bytes).
    pub broadcast_deps: Vec<(u64, usize)>,
}

/// Append-only execution history for one `Context`.
#[derive(Default)]
pub struct EventLog {
    inner: Mutex<EventLogInner>,
}

#[derive(Default)]
struct EventLogInner {
    jobs: Vec<JobRecord>,
    tasks: Vec<TaskRecord>,
}

impl EventLog {
    pub fn record_job_submit(&self, job: JobRecord) {
        self.inner.lock().unwrap().jobs.push(job);
    }

    pub fn record_job_finish(&self, job_id: u64, finish_rel: f64) {
        let mut g = self.inner.lock().unwrap();
        if let Some(j) = g.jobs.iter_mut().find(|j| j.job_id == job_id) {
            j.finish_rel = finish_rel;
        }
    }

    pub fn record_task(&self, t: TaskRecord) {
        self.inner.lock().unwrap().tasks.push(t);
    }

    pub fn jobs(&self) -> Vec<JobRecord> {
        self.inner.lock().unwrap().jobs.clone()
    }

    pub fn tasks(&self) -> Vec<TaskRecord> {
        self.inner.lock().unwrap().tasks.clone()
    }

    /// Total busy CPU-seconds across all tasks.
    pub fn total_task_seconds(&self) -> f64 {
        self.inner.lock().unwrap().tasks.iter().map(|t| t.duration).sum()
    }

    /// Measured wallclock span: first submit -> last finish, in seconds.
    pub fn wallclock_span(&self) -> f64 {
        let g = self.inner.lock().unwrap();
        let start = g
            .jobs
            .iter()
            .map(|j| j.submit_rel)
            .fold(f64::INFINITY, f64::min);
        let end = g
            .jobs
            .iter()
            .map(|j| j.finish_rel)
            .filter(|f| f.is_finite())
            .fold(0.0f64, f64::max);
        if start.is_finite() && end > start {
            end - start
        } else {
            0.0
        }
    }

    pub fn to_json(&self) -> Json {
        let g = self.inner.lock().unwrap();
        Json::obj(vec![
            (
                "jobs",
                Json::Arr(
                    g.jobs
                        .iter()
                        .map(|j| {
                            Json::obj(vec![
                                ("job_id", Json::Num(j.job_id as f64)),
                                ("name", Json::Str(j.name.clone())),
                                ("num_tasks", Json::Num(j.num_tasks as f64)),
                                ("submit_rel", Json::Num(j.submit_rel)),
                                ("finish_rel", Json::Num(j.finish_rel)),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "tasks",
                Json::Arr(
                    g.tasks
                        .iter()
                        .map(|t| {
                            Json::obj(vec![
                                ("job_id", Json::Num(t.job_id as f64)),
                                ("partition", Json::Num(t.partition as f64)),
                                ("start_rel", Json::Num(t.start_rel)),
                                ("duration", Json::Num(t.duration)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// What a run cost: real measured time plus the DES replay on the
/// configured topology.
#[derive(Clone, Debug)]
pub struct ExecutionReport {
    /// Wallclock actually measured on this machine (first submit -> last
    /// job finish).
    pub measured_wall_s: f64,
    /// Sum of task busy time (what a 1-core serial schedule would take,
    /// modulo overheads).
    pub total_task_s: f64,
    /// DES makespan on the configured topology.
    pub sim_makespan_s: f64,
    /// Mean executor-slot utilization during the DES makespan, in [0,1].
    pub sim_utilization: f64,
    /// Seconds the DES spent shipping broadcast variables (summed over
    /// nodes; overlaps with compute on other cores).
    pub sim_broadcast_ship_s: f64,
    /// Bytes the DES shipped for broadcasts, summed over (variable, node)
    /// pairs — the quantity sharding shrinks: a node running only shard
    /// `s`'s tasks pays for shard `s`, not the whole table. With
    /// `EngineConfig::broadcast_replicas > 1` this includes the eager
    /// replica copies (the cost of making worker-death requeue re-ship
    /// nothing); the cluster runtime's real counterpart is
    /// `ClusterBackend::broadcast_ship_bytes`.
    pub sim_broadcast_ship_bytes: u64,
    /// Seconds spent on eager re-replication repair ships after the
    /// simulated worker failures (`EngineConfig::sim_worker_failures`) —
    /// the DES price of the cluster runtime's repair traffic.
    pub sim_repair_ship_s: f64,
    /// Bytes shipped by the simulated repair traffic; the real
    /// counterpart is `ClusterBackend::repair_ship_bytes`.
    pub sim_repair_ship_bytes: u64,
    /// Seconds spent lazily re-shipping broadcasts to simulated rejoined
    /// nodes (`EngineConfig::sim_worker_rejoins`) — the DES price of a
    /// rejoined worker's empty store re-populating on demand.
    pub sim_rejoin_ship_s: f64,
    /// Bytes shipped by the simulated rejoin traffic; the real
    /// counterpart is `ClusterBackend::rejoin_ship_bytes`.
    pub sim_rejoin_ship_bytes: u64,
    /// Seconds of duplicated compute from simulated speculative task
    /// re-execution (`EngineConfig::sim_speculative_tasks` — the k
    /// longest tasks each run twice). Burned in parallel with the
    /// stragglers, so its own counter rather than makespan time; the
    /// real counterparts are `ClusterBackend::speculative_launches` /
    /// `speculative_wins`.
    pub sim_speculative_task_s: f64,
    /// Seconds of compute the run *avoided* through partial evaluation
    /// (`EngineConfig::sim_partial_saved_tasks` saved tasks, each priced
    /// at the mean measured task duration) — the DES price of the
    /// `--partial eps,conf` early termination. Work not done, so a
    /// standalone counter beside the makespan; the real counterpart is
    /// `PoolCounters::partial_saved_tasks`.
    pub sim_partial_saved_task_s: f64,
    /// Bytes of task results the driver would pull back over the wire —
    /// raw predictions under driver-side reduce, six-number partial sums
    /// under worker-side reduce (`--reduce worker`). Modeled from the
    /// harvested result payloads; the real counterpart is
    /// `PoolCounters::result_ingress_bytes`.
    pub sim_result_ingress_bytes: u64,
    /// Concurrent tenant jobs the DES priced
    /// (`EngineConfig::sim_concurrent_jobs`): the measured log replayed
    /// as this many identical jobs contending for the same executor
    /// slots while sharing broadcast residency, the cost model of the
    /// serve daemon's multi-tenant warm pool. 1 = batch baseline.
    pub sim_concurrent_jobs: u64,
    /// Topology description, e.g. `cluster(5x4)`.
    pub topology: String,
}

impl ExecutionReport {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("measured_wall_s", Json::Num(self.measured_wall_s)),
            ("total_task_s", Json::Num(self.total_task_s)),
            ("sim_makespan_s", Json::Num(self.sim_makespan_s)),
            ("sim_utilization", Json::Num(self.sim_utilization)),
            ("sim_broadcast_ship_s", Json::Num(self.sim_broadcast_ship_s)),
            ("sim_broadcast_ship_bytes", Json::Num(self.sim_broadcast_ship_bytes as f64)),
            ("sim_repair_ship_s", Json::Num(self.sim_repair_ship_s)),
            ("sim_repair_ship_bytes", Json::Num(self.sim_repair_ship_bytes as f64)),
            ("sim_rejoin_ship_s", Json::Num(self.sim_rejoin_ship_s)),
            ("sim_rejoin_ship_bytes", Json::Num(self.sim_rejoin_ship_bytes as f64)),
            ("sim_speculative_task_s", Json::Num(self.sim_speculative_task_s)),
            ("sim_partial_saved_task_s", Json::Num(self.sim_partial_saved_task_s)),
            ("sim_result_ingress_bytes", Json::Num(self.sim_result_ingress_bytes as f64)),
            ("sim_concurrent_jobs", Json::Num(self.sim_concurrent_jobs as f64)),
            ("topology", Json::Str(self.topology.clone())),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn job(id: u64, submit: f64, finish: f64) -> JobRecord {
        JobRecord {
            job_id: id,
            name: format!("job{id}"),
            num_tasks: 1,
            submit_rel: submit,
            finish_rel: finish,
            broadcast_deps: vec![],
        }
    }

    #[test]
    fn wallclock_span_covers_all_jobs() {
        let log = EventLog::default();
        log.record_job_submit(job(1, 0.5, f64::NAN));
        log.record_job_finish(1, 2.0);
        log.record_job_submit(job(2, 1.0, f64::NAN));
        log.record_job_finish(2, 3.5);
        assert!((log.wallclock_span() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn task_seconds_accumulate() {
        let log = EventLog::default();
        log.record_task(TaskRecord { job_id: 1, partition: 0, start_rel: 0.0, duration: 0.25, attempts: 1 });
        log.record_task(TaskRecord { job_id: 1, partition: 1, start_rel: 0.1, duration: 0.5, attempts: 1 });
        assert!((log.total_task_seconds() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn json_shape() {
        let log = EventLog::default();
        log.record_job_submit(job(1, 0.0, 1.0));
        let j = log.to_json();
        assert!(j.get("jobs").unwrap().as_arr().unwrap().len() == 1);
        assert!(j.get("tasks").unwrap().as_arr().unwrap().is_empty());
    }
}
