//! Executor pool: the threads that actually run tasks.
//!
//! A single shared FIFO injector queue (Mutex + Condvar) feeds
//! `real_threads` worker threads. Tasks are type-erased closures that
//! write their results into per-job result slots and record their
//! durations in the event log; FIFO order preserves Spark's default
//! scheduling semantics (jobs submitted earlier get their tasks queued
//! earlier, later jobs backfill idle slots — which is exactly what makes
//! asynchronous submission profitable on a wide topology).

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

/// A unit of scheduled work.
pub(crate) struct RunnableTask {
    pub job_id: u64,
    pub partition: usize,
    /// Executes the partition, records metrics, and (for the last task of
    /// a job) assembles + sends the job result.
    pub run: Box<dyn FnOnce() + Send>,
}

struct QueueState {
    tasks: VecDeque<RunnableTask>,
    shutdown: bool,
}

pub(crate) struct TaskQueue {
    state: Mutex<QueueState>,
    cv: Condvar,
}

impl TaskQueue {
    fn new() -> TaskQueue {
        TaskQueue {
            state: Mutex::new(QueueState { tasks: VecDeque::new(), shutdown: false }),
            cv: Condvar::new(),
        }
    }

    pub fn push_all(&self, tasks: Vec<RunnableTask>) {
        let mut st = self.state.lock().unwrap();
        st.tasks.extend(tasks);
        drop(st);
        self.cv.notify_all();
    }

    fn pop_blocking(&self) -> Option<RunnableTask> {
        let mut st = self.state.lock().unwrap();
        loop {
            if let Some(t) = st.tasks.pop_front() {
                return Some(t);
            }
            if st.shutdown {
                return None;
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    fn shutdown(&self) {
        self.state.lock().unwrap().shutdown = true;
        self.cv.notify_all();
    }
}

/// Fixed pool of worker threads draining the shared queue.
pub(crate) struct ExecutorPool {
    queue: Arc<TaskQueue>,
    threads: Vec<JoinHandle<()>>,
}

impl ExecutorPool {
    pub fn new(real_threads: usize) -> ExecutorPool {
        let queue = Arc::new(TaskQueue::new());
        let threads = (0..real_threads.max(1))
            .map(|i| {
                let q = Arc::clone(&queue);
                std::thread::Builder::new()
                    .name(format!("sparklet-exec-{i}"))
                    .spawn(move || {
                        while let Some(task) = q.pop_blocking() {
                            (task.run)();
                        }
                    })
                    .expect("failed to spawn executor thread")
            })
            .collect();
        ExecutorPool { queue, threads }
    }

    pub fn submit(&self, tasks: Vec<RunnableTask>) {
        self.queue.push_all(tasks);
    }
}

impl Drop for ExecutorPool {
    fn drop(&mut self) {
        self.queue.shutdown();
        // The pool can be dropped *from an executor thread*: tasks capture
        // a Context clone, so the last strong reference may die inside the
        // final task. Joining ourselves would deadlock — detach that one.
        let me = std::thread::current().id();
        for t in self.threads.drain(..) {
            if t.thread().id() == me {
                continue; // detach: it is exiting anyway after this task
            }
            let _ = t.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn runs_all_tasks() {
        let pool = ExecutorPool::new(4);
        let counter = Arc::new(AtomicUsize::new(0));
        let tasks: Vec<RunnableTask> = (0..100)
            .map(|p| {
                let c = Arc::clone(&counter);
                RunnableTask {
                    job_id: 0,
                    partition: p,
                    run: Box::new(move || {
                        c.fetch_add(1, Ordering::SeqCst);
                    }),
                }
            })
            .collect();
        pool.submit(tasks);
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while counter.load(Ordering::SeqCst) < 100 {
            assert!(std::time::Instant::now() < deadline, "tasks did not finish");
            std::thread::yield_now();
        }
    }

    #[test]
    fn drop_joins_threads_cleanly() {
        let pool = ExecutorPool::new(2);
        pool.submit(vec![]);
        drop(pool); // must not hang
    }
}
