//! The driver context: owns the executor pool and the event log, submits
//! jobs, exposes actions (sync and async) — the `SparkContext` analogue.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::channel;
use std::sync::{Arc, Mutex};
use std::time::Instant;

use super::broadcast::Broadcast;
use super::config::EngineConfig;
use super::des;
use super::executor::{ExecutorPool, RunnableTask};
use super::future_action::FutureAction;
use super::metrics::{EventLog, ExecutionReport, JobRecord, TaskRecord};
use super::rdd::Rdd;

/// Extract a readable message from a panic payload.
fn panic_message(e: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = e.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = e.downcast_ref::<String>() {
        s.clone()
    } else {
        "task panicked".to_string()
    }
}

struct ContextInner {
    config: EngineConfig,
    pool: ExecutorPool,
    events: EventLog,
    t0: Instant,
    next_job: AtomicU64,
}

/// The driver-side engine handle. Cheap to clone; dropping the last clone
/// joins the executor threads.
#[derive(Clone)]
pub struct Context {
    inner: Arc<ContextInner>,
}

impl Context {
    pub fn new(config: EngineConfig) -> Context {
        let pool = ExecutorPool::new(config.real_threads);
        Context {
            inner: Arc::new(ContextInner {
                config,
                pool,
                events: EventLog::default(),
                t0: Instant::now(),
                next_job: AtomicU64::new(1),
            }),
        }
    }

    pub fn config(&self) -> &EngineConfig {
        &self.inner.config
    }

    /// Seconds since context creation (the event-log clock).
    pub fn now_rel(&self) -> f64 {
        self.inner.t0.elapsed().as_secs_f64()
    }

    /// Distribute a vector across the default number of partitions.
    pub fn parallelize<T: Clone + Send + Sync + 'static>(&self, data: Vec<T>) -> Rdd<T> {
        Rdd::parallelize(data, self.inner.config.default_parallelism)
    }

    /// Distribute a vector across `partitions` partitions.
    pub fn parallelize_with<T: Clone + Send + Sync + 'static>(
        &self,
        data: Vec<T>,
        partitions: usize,
    ) -> Rdd<T> {
        Rdd::parallelize(data, partitions)
    }

    /// Create a broadcast variable (ships once per node in the DES model).
    pub fn broadcast<T>(&self, value: T, size_bytes: usize) -> Broadcast<T> {
        Broadcast::new(value, size_bytes)
    }

    /// Asynchronous collect — the `FutureAction` analogue (paper §3.3).
    /// Submits one task per partition and returns immediately.
    pub fn collect_async<T: Clone + Send + Sync + 'static>(
        &self,
        rdd: &Rdd<T>,
    ) -> FutureAction<Vec<T>> {
        let job_id = self.inner.next_job.fetch_add(1, Ordering::Relaxed);
        let n = rdd.num_partitions();
        let submit_rel = self.now_rel();
        self.inner.events.record_job_submit(JobRecord {
            job_id,
            name: rdd.name().to_string(),
            num_tasks: n,
            submit_rel,
            finish_rel: f64::NAN,
            broadcast_deps: rdd.broadcast_deps().to_vec(),
        });

        let (tx, rx) = channel();
        let slots: Arc<Mutex<Vec<Option<Vec<T>>>>> = Arc::new(Mutex::new(vec![None; n]));
        let remaining = Arc::new(AtomicUsize::new(n));
        let failed = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let max_attempts = self.inner.config.max_task_attempts;

        let tasks: Vec<RunnableTask> = (0..n)
            .map(|p| {
                let rdd = rdd.clone();
                let slots = Arc::clone(&slots);
                let remaining = Arc::clone(&remaining);
                let failed = Arc::clone(&failed);
                let tx = tx.clone();
                let ctx = self.clone();
                RunnableTask {
                    job_id,
                    partition: p,
                    run: Box::new(move || {
                        if failed.load(Ordering::Acquire) {
                            return; // job already failed: skip remaining tasks
                        }
                        // task retry loop — the "resilient" in RDD: a
                        // panicking task is re-attempted up to
                        // `max_task_attempts` times (Spark: task.maxFailures)
                        let start_rel = ctx.now_rel();
                        let t = Instant::now();
                        let mut outcome = None;
                        let mut last_err = String::new();
                        let mut attempts = 0u32;
                        for _ in 0..max_attempts {
                            attempts += 1;
                            match std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                                rdd.compute_partition(p)
                            })) {
                                Ok(v) => {
                                    outcome = Some(v);
                                    break;
                                }
                                Err(e) => {
                                    // &Box<dyn Any> would downcast as the Box
                                    // itself — deref to the payload first
                                    last_err = panic_message(&*e);
                                }
                            }
                        }
                        let duration = t.elapsed().as_secs_f64();
                        match outcome {
                            Some(result) => {
                                ctx.inner.events.record_task(TaskRecord {
                                    job_id,
                                    partition: p,
                                    start_rel,
                                    duration,
                                    attempts,
                                });
                                slots.lock().unwrap()[p] = Some(result);
                                if remaining.fetch_sub(1, Ordering::AcqRel) == 1 {
                                    // last task assembles and publishes
                                    ctx.inner.events.record_job_finish(job_id, ctx.now_rel());
                                    let mut guard = slots.lock().unwrap();
                                    let out: Vec<T> = guard
                                        .iter_mut()
                                        .flat_map(|s| s.take().expect("missing partition result"))
                                        .collect();
                                    let _ = tx.send(Ok(out));
                                }
                            }
                            None => {
                                if !failed.swap(true, Ordering::AcqRel) {
                                    ctx.inner.events.record_job_finish(job_id, ctx.now_rel());
                                    let _ = tx.send(Err(
                                        crate::engine::future_action::JobFailed {
                                            job_id,
                                            reason: format!(
                                                "task {p} failed {attempts} attempts: {last_err}"
                                            ),
                                        },
                                    ));
                                }
                            }
                        }
                    }),
                }
            })
            .collect();

        if n == 0 {
            self.inner.events.record_job_finish(job_id, self.now_rel());
            let _ = tx.send(Ok(Vec::new()));
        } else {
            self.inner.pool.submit(tasks);
        }
        FutureAction { job_id, rx }
    }

    /// Blocking collect.
    pub fn collect<T: Clone + Send + Sync + 'static>(&self, rdd: &Rdd<T>) -> Vec<T> {
        self.collect_async(rdd).get()
    }

    /// Blocking count.
    pub fn count<T: Clone + Send + Sync + 'static>(&self, rdd: &Rdd<T>) -> usize {
        self.collect(&rdd.map(|_| 1usize)).len()
    }

    /// Blocking fold over all elements (associative `combine` required).
    pub fn reduce<T, F>(&self, rdd: &Rdd<T>, combine: F) -> Option<T>
    where
        T: Clone + Send + Sync + 'static,
        F: Fn(T, T) -> T + Send + Sync + 'static,
    {
        let partials = self.collect(rdd);
        partials.into_iter().reduce(combine)
    }

    /// Snapshot of the event log (jobs, tasks).
    pub fn events(&self) -> &EventLog {
        &self.inner.events
    }

    /// Keyed reduction (Spark `reduceByKey`): map-side combine inside each
    /// partition task, then a driver-side merge of the partial maps (the
    /// single-reducer shuffle — the CCM pipelines group skills per
    /// (E, tau, L) combo this way). Result order is unspecified.
    pub fn reduce_by_key<K, V, F>(&self, rdd: &Rdd<(K, V)>, combine: F) -> Vec<(K, V)>
    where
        K: std::hash::Hash + Eq + Clone + Send + Sync + 'static,
        V: Clone + Send + Sync + 'static,
        F: Fn(V, V) -> V + Send + Sync + 'static,
    {
        use std::collections::HashMap;
        let combine = Arc::new(combine);
        let c2 = Arc::clone(&combine);
        let partials = rdd.map_partitions(move |_, pairs| {
            let mut m: HashMap<K, V> = HashMap::new();
            for (k, v) in pairs {
                match m.remove(&k) {
                    Some(acc) => {
                        let merged = c2(acc, v);
                        m.insert(k, merged);
                    }
                    None => {
                        m.insert(k, v);
                    }
                }
            }
            m.into_iter().collect::<Vec<(K, V)>>()
        });
        let mut out: HashMap<K, V> = HashMap::new();
        for (k, v) in self.collect(&partials) {
            match out.remove(&k) {
                Some(acc) => {
                    let merged = combine(acc, v);
                    out.insert(k, merged);
                }
                None => {
                    out.insert(k, v);
                }
            }
        }
        out.into_iter().collect()
    }

    /// Keyed grouping (Spark `groupByKey`): values keep encounter order
    /// within each partition, partitions merged in order.
    pub fn group_by_key<K, V>(&self, rdd: &Rdd<(K, V)>) -> Vec<(K, Vec<V>)>
    where
        K: std::hash::Hash + Eq + Clone + Send + Sync + 'static,
        V: Clone + Send + Sync + 'static,
    {
        use std::collections::HashMap;
        let mut out: HashMap<K, Vec<V>> = HashMap::new();
        for (k, v) in self.collect(rdd) {
            out.entry(k).or_default().push(v);
        }
        out.into_iter().collect()
    }

    /// Blocking collect that surfaces job failure instead of panicking.
    pub fn try_collect<T: Clone + Send + Sync + 'static>(
        &self,
        rdd: &Rdd<T>,
    ) -> Result<Vec<T>, super::future_action::JobFailed> {
        self.collect_async(rdd).try_get()
    }

    /// Measured + simulated execution report for everything run so far.
    pub fn report(&self) -> ExecutionReport {
        des::simulate(&self.inner.events, &self.inner.config)
    }

    /// Replay the same event log against a *different* topology — one real
    /// execution can be costed on many deploys (numerics never depend on
    /// the deploy, so this is exact, not an approximation).
    pub fn report_for(&self, deploy: super::config::Deploy) -> ExecutionReport {
        let mut cfg = self.inner.config.clone();
        cfg.deploy = deploy;
        des::simulate(&self.inner.events, &cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::config::Deploy;

    fn ctx(cores: usize) -> Context {
        Context::new(EngineConfig::new(Deploy::Local { cores }).with_default_parallelism(4))
    }

    #[test]
    fn collect_roundtrip_order_preserved() {
        let c = ctx(2);
        let rdd = c.parallelize((0..1000).collect::<Vec<i64>>()).map(|x| x * 3);
        assert_eq!(c.collect(&rdd), (0..1000).map(|x| x * 3).collect::<Vec<_>>());
    }

    #[test]
    fn count_and_reduce() {
        let c = ctx(2);
        let rdd = c.parallelize((1..=100).collect::<Vec<u64>>());
        assert_eq!(c.count(&rdd), 100);
        assert_eq!(c.reduce(&rdd, |a, b| a + b), Some(5050));
    }

    #[test]
    fn async_jobs_can_be_submitted_before_getting() {
        let c = ctx(4);
        let fas: Vec<_> = (0..6)
            .map(|i| {
                let rdd = c
                    .parallelize_with((0..50).collect::<Vec<i64>>(), 5)
                    .map(move |x| x + i);
                c.collect_async(&rdd)
            })
            .collect();
        for (i, fa) in fas.into_iter().enumerate() {
            let got = fa.get();
            assert_eq!(got.len(), 50);
            assert_eq!(got[0], i as i64);
        }
        // all 6 jobs recorded, all finished
        let jobs = c.events().jobs();
        assert_eq!(jobs.len(), 6);
        assert!(jobs.iter().all(|j| j.finish_rel.is_finite()));
    }

    #[test]
    fn empty_rdd_completes() {
        let c = ctx(1);
        let rdd = c.parallelize(Vec::<i32>::new());
        assert_eq!(c.collect(&rdd), Vec::<i32>::new());
    }

    #[test]
    fn report_has_tasks_and_makespan() {
        let c = ctx(4);
        let rdd = c
            .parallelize_with((0..64).collect::<Vec<u64>>(), 8)
            .map(|x| {
                // non-trivial busy time so durations are measurable
                let mut acc = x;
                for i in 0..50_000u64 {
                    acc = acc.wrapping_mul(6364136223846793005).wrapping_add(i);
                }
                acc
            });
        let _ = c.collect(&rdd);
        let rep = c.report();
        assert!(rep.total_task_s > 0.0);
        assert!(rep.sim_makespan_s > 0.0);
        assert!(rep.sim_makespan_s <= rep.total_task_s + 0.1);
        assert_eq!(rep.topology, "local(4)");
    }
}
