//! Broadcast variables: read-only values shipped to every worker node once.
//!
//! In Spark a `Broadcast<T>` is torrent-distributed to each executor the
//! first time a task on that node dereferences it; afterwards tasks read a
//! local copy. In-process the "shipping" is an `Arc` clone, but the DES
//! charges the configured per-node transfer time the first time a job that
//! depends on the broadcast schedules a task on a node — the paper's §3.2
//! cost model ("broadcast it to all nodes at one time rather than ship a
//! copy every time").

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

static NEXT_ID: AtomicU64 = AtomicU64::new(1);

/// A handle to a node-local read-only value.
pub struct Broadcast<T> {
    id: u64,
    value: Arc<T>,
    size_bytes: usize,
}

impl<T> Clone for Broadcast<T> {
    fn clone(&self) -> Self {
        Broadcast { id: self.id, value: Arc::clone(&self.value), size_bytes: self.size_bytes }
    }
}

impl<T> Broadcast<T> {
    /// Wrap `value`; `size_bytes` is the serialized size the DES charges
    /// when shipping to a node (callers estimate it — e.g. the distance
    /// indexing table reports `rows * cols * 8` bytes).
    pub fn new(value: T, size_bytes: usize) -> Broadcast<T> {
        Broadcast {
            id: NEXT_ID.fetch_add(1, Ordering::Relaxed),
            value: Arc::new(value),
            size_bytes,
        }
    }

    /// Node-local dereference.
    pub fn value(&self) -> &T {
        &self.value
    }

    /// Clone out the shared handle — lets the same node-local object back
    /// both a broadcast and a driver-side facade (e.g. the sharded
    /// distance table wraps the very `Arc<TableShard>`s its per-shard
    /// broadcasts hold, so no state is duplicated).
    pub fn share(&self) -> Arc<T> {
        Arc::clone(&self.value)
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn size_bytes(&self) -> usize {
        self.size_bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_are_unique() {
        let a = Broadcast::new(1, 8);
        let b = Broadcast::new(1, 8);
        assert_ne!(a.id(), b.id());
    }

    #[test]
    fn clones_share_value() {
        let a = Broadcast::new(vec![1, 2, 3], 24);
        let b = a.clone();
        assert_eq!(a.id(), b.id());
        assert_eq!(b.value(), &vec![1, 2, 3]);
        assert_eq!(b.size_bytes(), 24);
    }

    #[test]
    fn share_aliases_the_broadcast_value() {
        let a = Broadcast::new(vec![7u8], 1);
        let arc = a.share();
        assert!(std::ptr::eq(arc.as_ref(), a.value()));
    }
}
