//! Asynchronous job handles — the analogue of Spark's `FutureAction`.
//!
//! `Context::collect_async` (and friends) submit a job to the scheduler
//! and return immediately with a `FutureAction<T>`; the driver thread can
//! submit further jobs before blocking on [`FutureAction::get`]. This is
//! the mechanism behind the paper's §3.3: running the pipelines for many
//! `(L, tau, E)` combinations concurrently.

use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::time::Duration;

/// A failed job (a task exhausted `max_task_attempts`).
#[derive(Clone, Debug)]
pub struct JobFailed {
    pub job_id: u64,
    pub reason: String,
}

impl std::fmt::Display for JobFailed {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job {} failed: {}", self.job_id, self.reason)
    }
}

impl std::error::Error for JobFailed {}

/// A handle to a job running in the executor pool.
pub struct FutureAction<T> {
    pub(crate) job_id: u64,
    pub(crate) rx: Receiver<Result<T, JobFailed>>,
}

impl<T> FutureAction<T> {
    /// Engine-assigned job id (ties into the event log).
    pub fn job_id(&self) -> u64 {
        self.job_id
    }

    /// Block until the job completes and take its result. Panics if the
    /// job failed (a task exhausted its retry budget) — like Spark's
    /// action throwing on job failure; use [`FutureAction::try_get`] to
    /// handle failures programmatically.
    pub fn get(self) -> T {
        match self.try_get() {
            Ok(v) => v,
            Err(e) => panic!("{e}"),
        }
    }

    /// Block until the job completes; `Err` carries the task failure.
    pub fn try_get(self) -> Result<T, JobFailed> {
        self.rx
            .recv()
            .expect("job result channel closed: executor pool shut down mid-job")
    }

    /// Block up to `timeout`; `Err(self)` if still running (handle is
    /// returned so the caller can keep waiting).
    pub fn get_timeout(self, timeout: Duration) -> Result<Result<T, JobFailed>, FutureAction<T>> {
        match self.rx.recv_timeout(timeout) {
            Ok(v) => Ok(v),
            Err(RecvTimeoutError::Timeout) => Err(self),
            Err(RecvTimeoutError::Disconnected) => {
                panic!("job result channel closed: executor pool shut down mid-job")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc::channel;

    #[test]
    fn get_returns_sent_value() {
        let (tx, rx) = channel();
        let fa = FutureAction { job_id: 7, rx };
        tx.send(Ok(42)).unwrap();
        assert_eq!(fa.job_id(), 7);
        assert_eq!(fa.get(), 42);
    }

    #[test]
    fn timeout_returns_handle() {
        let (tx, rx) = channel::<Result<i32, JobFailed>>();
        let fa = FutureAction { job_id: 1, rx };
        let fa = fa.get_timeout(Duration::from_millis(10)).unwrap_err();
        tx.send(Ok(5)).unwrap();
        assert_eq!(fa.get(), 5);
    }

    #[test]
    fn try_get_surfaces_failure() {
        let (tx, rx) = channel::<Result<i32, JobFailed>>();
        let fa = FutureAction { job_id: 3, rx };
        tx.send(Err(JobFailed { job_id: 3, reason: "boom".into() })).unwrap();
        let err = fa.try_get().unwrap_err();
        assert_eq!(err.job_id, 3);
        assert!(err.to_string().contains("boom"));
    }
}
