//! "sparklet" — a from-scratch Spark-like execution engine.
//!
//! The paper implements CCM on Apache Spark using four primitives, all of
//! which are rebuilt here:
//!
//! * [`rdd::Rdd`] — an immutable, lazily-evaluated, partitioned dataset.
//!   Narrow transformations (`map`, `filter`, `flat_map`, ...) compose by
//!   closure fusion, exactly like Spark fuses narrow dependencies into a
//!   single stage.
//! * [`pipeline::Pipeline`] — a named sequence of RDD transform stages
//!   (paper §3: "each stage transforms the original RDD to another RDD").
//! * [`broadcast::Broadcast`] — a read-only value shipped to every worker
//!   node once (paper §3.2 ships the distance indexing table this way).
//! * [`future_action::FutureAction`] — asynchronous job submission (paper
//!   §3.3 uses Spark's `FutureAction` to overlap independent parameter
//!   combinations).
//!
//! Jobs run on a thread-pool [`executor::ExecutorPool`]; every task's
//! duration is recorded in the [`metrics::EventLog`], and the
//! [`des`] discrete-event simulator replays that log against a configured
//! cluster topology ([`config::Deploy::Cluster`]) to report the makespan a
//! Yarn deployment would achieve. On this single-core testbed the DES is
//! what reproduces the *shape* of the paper's Fig. 4 (see DESIGN.md
//! "Hardware substitutions"); measured wallclock is reported alongside.

pub mod broadcast;
pub mod config;
pub mod context;
pub mod des;
pub mod executor;
pub mod future_action;
pub mod metrics;
pub mod pipeline;
pub mod rdd;

pub use broadcast::Broadcast;
pub use config::{Deploy, EngineConfig};
pub use context::Context;
pub use future_action::FutureAction;
pub use metrics::{EventLog, ExecutionReport};
pub use pipeline::Pipeline;
pub use rdd::Rdd;
