//! Discrete-event simulation of the cluster scheduler.
//!
//! The engine executes every task for real (numerics are never simulated)
//! and logs its busy duration. This module replays that log against a
//! configured topology — e.g. the paper's 5-worker x 4-core Yarn cluster —
//! to obtain the makespan such a deployment would achieve. This is the
//! substitution that lets a 1-core CI box reproduce the *shape* of the
//! paper's Fig. 4 (see DESIGN.md "Hardware substitutions").
//!
//! Model (matching Spark's FIFO scheduler at the fidelity the paper's
//! experiments exercise):
//!
//! * Each job is one stage of independent tasks (narrow transforms fuse;
//!   the CCM pipelines are shuffle-free).
//! * Job dependency is inferred from the measured log: a job depends on
//!   every job that *finished before it was submitted* (a driver that
//!   blocked on `.get()` before submitting — the synchronous mode). Jobs
//!   whose submissions overlap in measured time ran concurrently in the
//!   driver (asynchronous mode) and may overlap in the DES too.
//! * Tasks are assigned FIFO in partition order to the earliest-free core.
//! * A per-task fixed overhead models scheduler/serialization latency.
//! * The first task of a broadcast-dependent job on each node pays the
//!   ship time `size_bytes / bandwidth` once per (broadcast, node).
//! * With `broadcast_replicas > 1`, the first ship of a broadcast also
//!   places copies on the next `R - 1` nodes (round-robin, each on its
//!   own serialized link) — pricing the cluster runtime's shard
//!   replication. A task later scheduled on a replica node finds the
//!   broadcast resident and ships nothing: requeue-without-reship.
//! * With `sim_worker_failures > 0` (and `replicas > 1`, matching the
//!   real pool's eager-repair condition), each simulated failure costs
//!   one repair ship per broadcast resident on the failed node: the copy
//!   is re-established on a surviving node that lacks it, on that node's
//!   serialized link. Reported as `sim_repair_ship_s` /
//!   `sim_repair_ship_bytes` — the DES price of the cluster runtime's
//!   eager re-replication (`ClusterBackend::repair_ship_bytes`).
//! * With `sim_worker_rejoins > 0`, rejoin `k` revives the node that
//!   failure `k` killed — with an **empty** store, so its next tasks
//!   lazily re-fetch every broadcast it held (minus anything eager
//!   repair already put back elsewhere leaves it without). Priced at any
//!   replication factor (a rejoined worker always starts empty) on its
//!   own counters, `sim_rejoin_ship_s` / `sim_rejoin_ship_bytes` —
//!   mirroring the real pool's `rejoin_ships` (`--rejoin-backoff-secs`).
//!   Rejoins beyond the failure count have no dead node to revive and
//!   price nothing.
//! * Every simulated byte counter prices raw payload sizes through the
//!   configured [`super::config::WirePricing`]: binary (v6 wire, identity
//!   — the default) or JSON lines (~11/4 inflation per 4-byte lane,
//!   matching a pool with pinned-JSON connections).
//! * With `sim_concurrent_jobs > 1`, the measured log is replayed as that
//!   many identical tenant jobs on the same topology — the cost model of
//!   the serve daemon's multi-tenant warm pool. Task clones contend for
//!   the same executor slots (job-dependency inference stays *within* a
//!   tenant: one tenant's sync chain never gates another's), but
//!   broadcast residency is shared: the clones carry the **same**
//!   broadcast ids, so a second tenant posing the same problem ships
//!   zero additional bytes — exactly what the pool's job-refcounted
//!   payload cache does for two jobs with equal specs.

use std::collections::{HashMap, HashSet};

use super::config::{Deploy, EngineConfig};
use super::metrics::{EventLog, ExecutionReport};

/// Replay `log` against `config.deploy`, returning the simulated report.
pub fn simulate(log: &EventLog, config: &EngineConfig) -> ExecutionReport {
    let mut jobs = log.jobs();
    jobs.sort_by(|a, b| a.submit_rel.partial_cmp(&b.submit_rel).unwrap());
    let tasks = log.tasks();
    let mut tasks_by_job: HashMap<u64, Vec<(usize, f64)>> = HashMap::new();
    for t in &tasks {
        tasks_by_job
            .entry(t.job_id)
            .or_default()
            .push((t.partition, t.duration));
    }
    for v in tasks_by_job.values_mut() {
        v.sort_by_key(|(p, _)| *p);
    }

    // Multi-tenant expansion: replay the log as `tenants` identical jobs.
    // Clones keep the measured submit/finish times (the sort above is
    // stable, so tenants interleave FIFO-fairly) and the SAME broadcast
    // ids — residency is per (id, node), so a shared problem ships once
    // no matter how many tenants pose it, like the warm pool's cache.
    let tenants = config.sim_concurrent_jobs.max(1);
    let tenant_stride = jobs.iter().map(|j| j.job_id).max().unwrap_or(0) + 1;
    if tenants > 1 {
        let base_jobs = jobs.clone();
        let base_tasks: Vec<(u64, Vec<(usize, f64)>)> =
            tasks_by_job.iter().map(|(id, v)| (*id, v.clone())).collect();
        for tenant in 1..tenants as u64 {
            for job in &base_jobs {
                let mut clone = job.clone();
                clone.job_id += tenant_stride * tenant;
                jobs.push(clone);
            }
            for (id, v) in &base_tasks {
                tasks_by_job.insert(id + tenant_stride * tenant, v.clone());
            }
        }
        jobs.sort_by(|a, b| a.submit_rel.partial_cmp(&b.submit_rel).unwrap());
    }
    let tenant_of = |job_id: u64| job_id / tenant_stride;

    let cores = config.deploy.total_cores();
    let nodes = config.deploy.nodes();
    let replicas = config.broadcast_replicas.clamp(1, nodes);
    let overhead = config.task_overhead_us as f64 * 1e-6;
    let bandwidth = config.broadcast_mb_per_s * 1e6; // bytes/s
    let mut core_free = vec![0.0f64; cores];
    let mut node_has_broadcast: HashSet<(u64, usize)> = HashSet::new();
    let mut bcast_seen: HashSet<u64> = HashSet::new();
    let mut node_bcast_ready: HashMap<usize, f64> = HashMap::new();
    let mut ship_total = 0.0f64;
    let mut ship_bytes = 0u64;
    let mut des_finish: HashMap<u64, f64> = HashMap::new();
    let mut busy = 0.0f64;
    let mut makespan = 0.0f64;

    for (ji, job) in jobs.iter().enumerate() {
        // Inferred readiness: all jobs that measurably finished before this
        // one was submitted must complete first in the simulation, too.
        // Only within the same tenant — tenants are independent clients
        // of the pool, so one tenant's sync chain never gates another's.
        let mut ready = 0.0f64;
        for prev in &jobs[..ji] {
            if tenant_of(prev.job_id) != tenant_of(job.job_id) {
                continue;
            }
            if prev.finish_rel.is_finite() && prev.finish_rel <= job.submit_rel + 1e-9 {
                if let Some(&f) = des_finish.get(&prev.job_id) {
                    ready = ready.max(f);
                }
            }
        }

        let mut job_finish = ready;
        if let Some(job_tasks) = tasks_by_job.get(&job.job_id) {
            for &(_partition, duration) in job_tasks {
                // earliest-free core (FIFO list scheduling)
                let (core, _) = core_free
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .unwrap();
                let node = config.deploy.node_of_core(core);
                let mut start = core_free[core].max(ready);

                // Broadcast shipping: once per (variable, node); the node's
                // link serializes ships. Raw sizes are priced through the
                // configured wire encoding (binary = identity, JSON ~11/4).
                for &(bid, bytes) in &job.broadcast_deps {
                    let wire_bytes = config.wire_pricing.bytes(bytes as u64);
                    if node_has_broadcast.insert((bid, node)) {
                        let link_free = node_bcast_ready.get(&node).copied().unwrap_or(0.0);
                        let ship_start = start.max(link_free);
                        let ship = wire_bytes as f64 / bandwidth;
                        node_bcast_ready.insert(node, ship_start + ship);
                        ship_total += ship;
                        ship_bytes += wire_bytes;
                        start = ship_start + ship;
                        // first ship of this broadcast anywhere: replicate
                        // to the next R-1 nodes (their own links; the
                        // current task does not wait on replica ships)
                        if bcast_seen.insert(bid) && replicas > 1 {
                            let mut placed = 1;
                            for k in 1..nodes {
                                if placed >= replicas {
                                    break;
                                }
                                let m = (node + k) % nodes;
                                if !node_has_broadcast.insert((bid, m)) {
                                    continue;
                                }
                                let m_free =
                                    node_bcast_ready.get(&m).copied().unwrap_or(0.0);
                                let m_start = ship_start.max(m_free);
                                node_bcast_ready.insert(m, m_start + ship);
                                ship_total += ship;
                                ship_bytes += wire_bytes;
                                placed += 1;
                            }
                        }
                    } else if let Some(&link) = node_bcast_ready.get(&node) {
                        // a ship to this node may still be in flight
                        start = start.max(link);
                    }
                }

                let end = start + overhead + duration;
                core_free[core] = end;
                busy += duration;
                job_finish = job_finish.max(end);
            }
        }
        des_finish.insert(job.job_id, job_finish);
        makespan = makespan.max(job_finish);
    }

    let utilization = if makespan > 0.0 {
        (busy / (makespan * cores as f64)).min(1.0)
    } else {
        0.0
    };

    // Eager re-replication repair pricing: each simulated failure drops a
    // node's resident copies; every dropped copy whose id still has a
    // node lacking it is re-shipped there on that node's link. Like the
    // real pool, repair only runs at replication factors above 1 (factor
    // 1 restores lazily, task-driven) — and repair traffic overlaps the
    // next problem's compute, so it is priced, not added to the makespan.
    // Rejoin pricing piggybacks on the same failure bookkeeping: rejoin
    // `k` revives failure `k`'s node with an empty store, and its lazy
    // re-fetch of everything it held is priced on the rejoin counters.
    let mut repair_ship_s = 0.0f64;
    let mut repair_ship_bytes = 0u64;
    let mut rejoin_ship_s = 0.0f64;
    let mut rejoin_ship_bytes = 0u64;
    if config.sim_worker_failures > 0 && nodes > 1 {
        let mut bytes_of: HashMap<u64, usize> = HashMap::new();
        for job in &jobs {
            for &(bid, bytes) in &job.broadcast_deps {
                bytes_of.insert(bid, bytes);
            }
        }
        // what each failure dropped, in failure order (rejoins pair up)
        let mut dropped: Vec<(usize, Vec<u64>)> = Vec::new();
        for failure in 0..config.sim_worker_failures {
            let failed = failure % nodes;
            let resident: Vec<u64> = node_has_broadcast
                .iter()
                .filter(|(_, n)| *n == failed)
                .map(|(bid, _)| *bid)
                .collect();
            for &bid in &resident {
                node_has_broadcast.remove(&(bid, failed));
            }
            if replicas > 1 {
                for &bid in &resident {
                    let target = (0..nodes)
                        .find(|m| *m != failed && !node_has_broadcast.contains(&(bid, *m)));
                    let (Some(target), Some(&bytes)) = (target, bytes_of.get(&bid)) else {
                        continue; // every survivor already holds it (or unknown id)
                    };
                    node_has_broadcast.insert((bid, target));
                    let wire_bytes = config.wire_pricing.bytes(bytes as u64);
                    let ship = wire_bytes as f64 / bandwidth;
                    let link_free = node_bcast_ready.get(&target).copied().unwrap_or(0.0);
                    node_bcast_ready.insert(target, link_free.max(makespan) + ship);
                    repair_ship_s += ship;
                    repair_ship_bytes += wire_bytes;
                }
            }
            dropped.push((failed, resident));
        }
        // rejoin k revives failure k's node: empty store, lazy re-fetch
        // of every broadcast it held — at ANY replication factor (a
        // rejoined worker always starts empty), on its own counters
        for (node, ids) in dropped.iter().take(config.sim_worker_rejoins) {
            for bid in ids {
                if !node_has_broadcast.insert((*bid, *node)) {
                    continue; // already back (e.g. repair landed here)
                }
                let Some(&bytes) = bytes_of.get(bid) else { continue };
                let wire_bytes = config.wire_pricing.bytes(bytes as u64);
                let ship = wire_bytes as f64 / bandwidth;
                let link_free = node_bcast_ready.get(node).copied().unwrap_or(0.0);
                node_bcast_ready.insert(*node, link_free.max(makespan) + ship);
                rejoin_ship_s += ship;
                rejoin_ship_bytes += wire_bytes;
            }
        }
    }

    // Speculative re-execution pricing: the k longest tasks are assumed
    // to straggle and be speculatively duplicated (the real pool's
    // `--speculate-factor` arms on exactly those tasks), so each is paid
    // for twice — the duplicate burns spare capacity in parallel with
    // the straggler, so the cost is its own counter, not makespan time.
    let mut speculative_task_s = 0.0f64;
    if config.sim_speculative_tasks > 0 {
        let mut durations: Vec<f64> =
            tasks_by_job.values().flatten().map(|&(_, d)| d).collect();
        durations.sort_unstable_by(f64::total_cmp);
        let k = config.sim_speculative_tasks.min(durations.len());
        speculative_task_s = durations[durations.len() - k..].iter().sum();
    }

    // Partial-evaluation pricing: the driver's `--partial eps,conf` early
    // termination skipped `sim_partial_saved_tasks` subsample tasks, none
    // of which appear in the measured log — each is priced at the mean
    // duration of the tasks that DID run, the best unbiased stand-in for
    // work never performed. Compute avoided, so its own counter; nothing
    // is subtracted from the makespan (the saved tasks were never on it).
    let mut partial_saved_task_s = 0.0f64;
    if config.sim_partial_saved_tasks > 0 {
        let durations: Vec<f64> =
            tasks_by_job.values().flatten().map(|&(_, d)| d).collect();
        if !durations.is_empty() {
            let mean = durations.iter().sum::<f64>() / durations.len() as f64;
            partial_saved_task_s = config.sim_partial_saved_tasks as f64 * mean;
        }
    }

    ExecutionReport {
        measured_wall_s: log.wallclock_span(),
        total_task_s: log.total_task_seconds(),
        sim_makespan_s: makespan,
        sim_utilization: utilization,
        sim_broadcast_ship_s: ship_total,
        sim_broadcast_ship_bytes: ship_bytes,
        sim_repair_ship_s: repair_ship_s,
        sim_repair_ship_bytes: repair_ship_bytes,
        sim_rejoin_ship_s: rejoin_ship_s,
        sim_rejoin_ship_bytes: rejoin_ship_bytes,
        sim_speculative_task_s: speculative_task_s,
        sim_partial_saved_task_s: partial_saved_task_s,
        // the event log carries no result payload sizes; the driver
        // overrides this with its harvest tally (see `run_engine_case`)
        sim_result_ingress_bytes: 0,
        sim_concurrent_jobs: tenants as u64,
        topology: match config.deploy {
            Deploy::SingleThread => "single-thread".to_string(),
            Deploy::Local { cores } => format!("local({cores})"),
            Deploy::Cluster { workers, cores_per_worker } => {
                format!("cluster({workers}x{cores_per_worker})")
            }
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::metrics::{JobRecord, TaskRecord};

    fn make_log(jobs: &[(u64, f64, f64, usize, f64)]) -> EventLog {
        // (job_id, submit, finish, ntasks, task_dur)
        let log = EventLog::default();
        for &(id, submit, finish, ntasks, dur) in jobs {
            log.record_job_submit(JobRecord {
                job_id: id,
                name: format!("j{id}"),
                num_tasks: ntasks,
                submit_rel: submit,
                finish_rel: finish,
                broadcast_deps: vec![],
            });
            for p in 0..ntasks {
                log.record_task(TaskRecord {
                    job_id: id,
                    partition: p,
                    start_rel: submit,
                    duration: dur,
                    attempts: 1,
                });
            }
        }
        log
    }

    fn config(deploy: Deploy) -> EngineConfig {
        let mut c = EngineConfig::new(deploy);
        c.task_overhead_us = 0;
        c
    }

    #[test]
    fn perfect_scaling_for_independent_tasks() {
        // 8 tasks x 1s on 1 core = 8s; on 4 cores = 2s.
        let log = make_log(&[(1, 0.0, 8.0, 8, 1.0)]);
        let one = simulate(&log, &config(Deploy::SingleThread));
        let four = simulate(&log, &config(Deploy::Local { cores: 4 }));
        assert!((one.sim_makespan_s - 8.0).abs() < 1e-9);
        assert!((four.sim_makespan_s - 2.0).abs() < 1e-9);
        assert!((four.sim_utilization - 1.0).abs() < 1e-9);
    }

    #[test]
    fn sequential_jobs_chain_in_sim() {
        // job2 submitted after job1 finished (sync driver): must not overlap.
        let log = make_log(&[(1, 0.0, 4.0, 4, 1.0), (2, 4.0, 8.0, 4, 1.0)]);
        let rep = simulate(&log, &config(Deploy::Local { cores: 4 }));
        assert!((rep.sim_makespan_s - 2.0).abs() < 1e-9, "1s per job on 4 cores");
    }

    #[test]
    fn async_jobs_overlap_in_sim() {
        // both submitted at t~0 (async driver): fill the cluster together.
        let log = make_log(&[(1, 0.0, 4.0, 4, 1.0), (2, 0.001, 8.0, 4, 1.0)]);
        let rep = simulate(&log, &config(Deploy::Local { cores: 8 }));
        assert!((rep.sim_makespan_s - 1.0).abs() < 1e-9, "8 tasks on 8 cores at once");
    }

    #[test]
    fn async_no_gain_when_saturated() {
        // paper: async helps only when cores are idle. 2 jobs x 4 tasks on
        // 2 cores: async and sync both take 4s.
        let sync_log = make_log(&[(1, 0.0, 2.0, 4, 1.0), (2, 2.0, 4.0, 4, 1.0)]);
        let async_log = make_log(&[(1, 0.0, 2.0, 4, 1.0), (2, 0.001, 4.0, 4, 1.0)]);
        let c = config(Deploy::Local { cores: 2 });
        let a = simulate(&sync_log, &c).sim_makespan_s;
        let b = simulate(&async_log, &c).sim_makespan_s;
        assert!((a - 4.0).abs() < 1e-9);
        assert!((b - 4.0).abs() < 1e-9);
    }

    #[test]
    fn broadcast_ships_once_per_node() {
        let log = EventLog::default();
        log.record_job_submit(JobRecord {
            job_id: 1,
            name: "j".into(),
            num_tasks: 8,
            submit_rel: 0.0,
            finish_rel: 8.0,
            broadcast_deps: vec![(42, 400_000_000)], // 1s at 400 MB/s
        });
        for p in 0..8 {
            log.record_task(TaskRecord { job_id: 1, partition: p, start_rel: 0.0, duration: 1.0, attempts: 1 });
        }
        let rep = simulate(
            &log,
            &config(Deploy::Cluster { workers: 2, cores_per_worker: 2 }),
        );
        // 2 nodes pay 1s ship each (in parallel), then 8 tasks over 4 cores.
        assert!((rep.sim_broadcast_ship_s - 2.0).abs() < 1e-9);
        assert!((rep.sim_makespan_s - 3.0).abs() < 1e-9, "{}", rep.sim_makespan_s);
    }

    #[test]
    fn json_wire_pricing_inflates_every_byte_counter() {
        use crate::engine::config::WirePricing;
        // one broadcast, replicas=2, one failure + one rejoin on a 3-node
        // cluster: all three byte counters move, and each must carry the
        // 11/4 JSON inflation when the pool is pinned to the line wire
        let bytes = 4_000_000usize;
        let log = EventLog::default();
        log.record_job_submit(JobRecord {
            job_id: 1,
            name: "j".into(),
            num_tasks: 1,
            submit_rel: 0.0,
            finish_rel: 2.0,
            broadcast_deps: vec![(9, bytes)],
        });
        log.record_task(TaskRecord {
            job_id: 1,
            partition: 0,
            start_rel: 0.0,
            duration: 1.0,
            attempts: 1,
        });
        let base = config(Deploy::Cluster { workers: 3, cores_per_worker: 1 })
            .with_broadcast_replicas(2)
            .with_sim_worker_failures(1)
            .with_sim_worker_rejoins(1);
        let binary = simulate(&log, &base.clone());
        let json = simulate(&log, &base.with_wire_pricing(WirePricing::Json));
        let inflate = |raw: u64| raw * 11 / 4;
        assert_eq!(binary.sim_broadcast_ship_bytes, 2 * bytes as u64, "binary = raw");
        assert_eq!(json.sim_broadcast_ship_bytes, 2 * inflate(bytes as u64));
        assert_eq!(json.sim_repair_ship_bytes, inflate(binary.sim_repair_ship_bytes));
        assert_eq!(json.sim_rejoin_ship_bytes, inflate(binary.sim_rejoin_ship_bytes));
        assert!(binary.sim_repair_ship_bytes > 0 && binary.sim_rejoin_ship_bytes > 0);
        // the slower wire also stretches simulated ship time
        assert!(json.sim_broadcast_ship_s > binary.sim_broadcast_ship_s);
    }

    #[test]
    fn sharded_broadcasts_priced_per_shard() {
        // A monolithic table dep ships all bytes to every node that runs
        // its tasks. Sharded: each shard job carries only its own shard's
        // bytes, so a 2-node cluster whose nodes end up running disjoint
        // shard jobs ships half the table per node.
        let whole = 400_000_000usize; // 1s at 400 MB/s
        let half = whole / 2;

        // monolithic: one job, 2 nodes * 2 cores, every node pays `whole`
        let mono = EventLog::default();
        mono.record_job_submit(JobRecord {
            job_id: 1,
            name: "mono".into(),
            num_tasks: 4,
            submit_rel: 0.0,
            finish_rel: 4.0,
            broadcast_deps: vec![(7, whole)],
        });
        for p in 0..4 {
            let t =
                TaskRecord { job_id: 1, partition: p, start_rel: 0.0, duration: 1.0, attempts: 1 };
            mono.record_task(t);
        }
        let c = config(Deploy::Cluster { workers: 2, cores_per_worker: 2 });
        let mono_rep = simulate(&mono, &c);
        assert_eq!(mono_rep.sim_broadcast_ship_bytes, 2 * whole as u64);

        // sharded: two concurrent jobs, one per shard, 2 tasks each. FIFO
        // list scheduling lands job 1 on node 0's cores and job 2 on node
        // 1's, so each node receives exactly one shard.
        let shard = EventLog::default();
        for (job, bid) in [(1u64, 71u64), (2, 72)] {
            shard.record_job_submit(JobRecord {
                job_id: job,
                name: format!("shard{bid}"),
                num_tasks: 2,
                submit_rel: (job - 1) as f64 * 0.001,
                finish_rel: 4.0,
                broadcast_deps: vec![(bid, half)],
            });
            for p in 0..2 {
                let t = TaskRecord {
                    job_id: job,
                    partition: p,
                    start_rel: 0.0,
                    duration: 1.0,
                    attempts: 1,
                };
                shard.record_task(t);
            }
        }
        let shard_rep = simulate(&shard, &c);
        assert_eq!(shard_rep.sim_broadcast_ship_bytes, whole as u64, "one shard per node");
        assert!(shard_rep.sim_broadcast_ship_s < mono_rep.sim_broadcast_ship_s);
        assert!(shard_rep.sim_makespan_s < mono_rep.sim_makespan_s);
    }

    #[test]
    fn replica_ships_priced_and_requeue_needs_no_reship() {
        let bytes = 400_000_000usize; // 1s at 400 MB/s
        let deploy = Deploy::Cluster { workers: 2, cores_per_worker: 1 };

        // log A: one job, one task — it lands on node 0
        let log_a = EventLog::default();
        log_a.record_job_submit(JobRecord {
            job_id: 1,
            name: "warm".into(),
            num_tasks: 1,
            submit_rel: 0.0,
            finish_rel: 5.0,
            broadcast_deps: vec![(9, bytes)],
        });
        log_a.record_task(TaskRecord {
            job_id: 1,
            partition: 0,
            start_rel: 0.0,
            duration: 5.0,
            attempts: 1,
        });

        // unreplicated: the broadcast ships only where the task ran
        let r1 = simulate(&log_a, &config(deploy.clone()));
        assert_eq!(r1.sim_broadcast_ship_bytes, bytes as u64);
        // replicas=2: the first ship also places a copy on node 1
        let c2 = config(deploy.clone()).with_broadcast_replicas(2);
        let r2 = simulate(&log_a, &c2);
        assert_eq!(r2.sim_broadcast_ship_bytes, 2 * bytes as u64, "replica ship priced");
        assert!((r2.sim_broadcast_ship_s - 2.0).abs() < 1e-9);

        // log B: a second (requeue-style) job over the same broadcast,
        // submitted while job 1 still runs — FIFO lands it on node 1
        let log_b = EventLog::default();
        for j in log_a.jobs() {
            log_b.record_job_submit(j);
        }
        for t in log_a.tasks() {
            log_b.record_task(t);
        }
        log_b.record_job_submit(JobRecord {
            job_id: 2,
            name: "requeue".into(),
            num_tasks: 1,
            submit_rel: 0.001,
            finish_rel: 6.0,
            broadcast_deps: vec![(9, bytes)],
        });
        log_b.record_task(TaskRecord {
            job_id: 2,
            partition: 0,
            start_rel: 0.001,
            duration: 1.0,
            attempts: 1,
        });

        // with replication, node 1 already holds the broadcast: the
        // requeued task ships ZERO additional bytes
        let rb = simulate(&log_b, &c2);
        assert_eq!(
            rb.sim_broadcast_ship_bytes, r2.sim_broadcast_ship_bytes,
            "requeue onto a replica node must not re-ship"
        );
        // without replication the second node pays the ship lazily —
        // same total bytes, but only after the failure/requeue, which is
        // exactly what eager replication buys
        let rb1 = simulate(&log_b, &config(deploy));
        assert_eq!(rb1.sim_broadcast_ship_bytes, 2 * bytes as u64);
    }

    #[test]
    fn replicas_clamped_to_node_count() {
        // a single-node deploy cannot hold more than one copy
        let log2 = EventLog::default();
        log2.record_job_submit(JobRecord {
            job_id: 1,
            name: "j".into(),
            num_tasks: 2,
            submit_rel: 0.0,
            finish_rel: 2.0,
            broadcast_deps: vec![(3, 100)],
        });
        for p in 0..2 {
            log2.record_task(TaskRecord {
                job_id: 1,
                partition: p,
                start_rel: 0.0,
                duration: 1.0,
                attempts: 1,
            });
        }
        let rep = simulate(
            &log2,
            &config(Deploy::Local { cores: 2 }).with_broadcast_replicas(8),
        );
        assert_eq!(rep.sim_broadcast_ship_bytes, 100);
    }

    #[test]
    fn worker_failure_prices_repair_reships() {
        // one broadcast, replicas=2 on a 3-node cluster: the first ship
        // lands on one node, the eager replica on the next — a failure of
        // node 0 must re-establish its copy on the remaining node, priced
        // as repair traffic (and NOT as broadcast ship traffic).
        let bytes = 400_000_000usize; // 1s at 400 MB/s
        let log = EventLog::default();
        log.record_job_submit(JobRecord {
            job_id: 1,
            name: "j".into(),
            num_tasks: 1,
            submit_rel: 0.0,
            finish_rel: 3.0,
            broadcast_deps: vec![(9, bytes)],
        });
        log.record_task(TaskRecord {
            job_id: 1,
            partition: 0,
            start_rel: 0.0,
            duration: 1.0,
            attempts: 1,
        });
        let deploy = Deploy::Cluster { workers: 3, cores_per_worker: 1 };
        let healthy = simulate(&log, &config(deploy.clone()).with_broadcast_replicas(2));
        assert_eq!(healthy.sim_repair_ship_bytes, 0, "no failures, no repair");

        let c = config(deploy.clone())
            .with_broadcast_replicas(2)
            .with_sim_worker_failures(1);
        let rep = simulate(&log, &c);
        assert_eq!(rep.sim_repair_ship_bytes, bytes as u64, "one copy repaired");
        assert!((rep.sim_repair_ship_s - 1.0).abs() < 1e-9);
        assert_eq!(
            rep.sim_broadcast_ship_bytes, healthy.sim_broadcast_ship_bytes,
            "repair traffic is priced on its own counters"
        );

        // replicas=1 matches the real pool: restoration is lazy and
        // task-driven, so the DES prices no eager repair
        let lazy = simulate(
            &log,
            &config(deploy).with_sim_worker_failures(1),
        );
        assert_eq!(lazy.sim_repair_ship_bytes, 0);
        assert_eq!(lazy.sim_repair_ship_s, 0.0);
    }

    #[test]
    fn rejoined_node_lazy_reships_priced_on_their_own_counters() {
        // one broadcast, replicas=2 on 3 nodes: the failure of node 0
        // drops its copy (repair puts one on the spare node); the rejoin
        // of node 0 re-fetches the copy it held, priced as rejoin
        // traffic — broadcast and repair counters must not move.
        let bytes = 400_000_000usize; // 1s at 400 MB/s
        let log = EventLog::default();
        log.record_job_submit(JobRecord {
            job_id: 1,
            name: "j".into(),
            num_tasks: 1,
            submit_rel: 0.0,
            finish_rel: 3.0,
            broadcast_deps: vec![(9, bytes)],
        });
        log.record_task(TaskRecord {
            job_id: 1,
            partition: 0,
            start_rel: 0.0,
            duration: 1.0,
            attempts: 1,
        });
        let deploy = Deploy::Cluster { workers: 3, cores_per_worker: 1 };
        let base = config(deploy)
            .with_broadcast_replicas(2)
            .with_sim_worker_failures(1);
        let no_rejoin = simulate(&log, &base);
        assert_eq!(no_rejoin.sim_rejoin_ship_bytes, 0, "no rejoin, no rejoin traffic");
        assert_eq!(no_rejoin.sim_rejoin_ship_s, 0.0);

        let rejoined = simulate(&log, &base.with_sim_worker_rejoins(1));
        assert_eq!(rejoined.sim_rejoin_ship_bytes, bytes as u64, "lazy re-fetch priced");
        assert!((rejoined.sim_rejoin_ship_s - 1.0).abs() < 1e-9);
        assert_eq!(
            rejoined.sim_repair_ship_bytes, no_rejoin.sim_repair_ship_bytes,
            "rejoin traffic must not leak into the repair counters"
        );
        assert_eq!(
            rejoined.sim_broadcast_ship_bytes, no_rejoin.sim_broadcast_ship_bytes,
            "rejoin traffic must not leak into the broadcast counters"
        );
    }

    #[test]
    fn rejoin_without_a_failure_prices_nothing() {
        // rejoins beyond the failure count have no dead node to revive
        let log = make_log(&[(1, 0.0, 1.0, 2, 1.0)]);
        let c = config(Deploy::Cluster { workers: 2, cores_per_worker: 1 })
            .with_sim_worker_rejoins(3);
        let rep = simulate(&log, &c);
        assert_eq!(rep.sim_rejoin_ship_bytes, 0);
        assert_eq!(rep.sim_rejoin_ship_s, 0.0);
    }

    #[test]
    fn rejoin_prices_lazy_reships_even_at_replication_factor_one() {
        // replicas=1: no eager repair exists, but a rejoined node still
        // starts empty — its lazy re-fetch is real traffic and is priced
        // (matching the real pool, whose rejoin_ships counter moves at
        // any replication factor)
        let bytes = 400_000_000usize;
        let log = EventLog::default();
        log.record_job_submit(JobRecord {
            job_id: 1,
            name: "j".into(),
            num_tasks: 1,
            submit_rel: 0.0,
            finish_rel: 2.0,
            broadcast_deps: vec![(4, bytes)],
        });
        log.record_task(TaskRecord {
            job_id: 1,
            partition: 0,
            start_rel: 0.0,
            duration: 1.0,
            attempts: 1,
        });
        let c = config(Deploy::Cluster { workers: 2, cores_per_worker: 1 })
            .with_sim_worker_failures(1)
            .with_sim_worker_rejoins(1);
        let rep = simulate(&log, &c);
        assert_eq!(rep.sim_repair_ship_bytes, 0, "factor 1 never repairs eagerly");
        assert_eq!(rep.sim_rejoin_ship_bytes, bytes as u64);
        assert!((rep.sim_rejoin_ship_s - 1.0).abs() < 1e-9);
    }

    #[test]
    fn repair_skips_fully_replicated_clusters() {
        // 2 nodes, replicas=2: both nodes already hold the broadcast, so
        // a failure has nowhere new to repair to — zero repair traffic
        // (the real pool behaves the same: no idle non-holder, no ship).
        let log = EventLog::default();
        log.record_job_submit(JobRecord {
            job_id: 1,
            name: "j".into(),
            num_tasks: 2,
            submit_rel: 0.0,
            finish_rel: 2.0,
            broadcast_deps: vec![(5, 1000)],
        });
        for p in 0..2 {
            log.record_task(TaskRecord {
                job_id: 1,
                partition: p,
                start_rel: 0.0,
                duration: 1.0,
                attempts: 1,
            });
        }
        let c = config(Deploy::Cluster { workers: 2, cores_per_worker: 1 })
            .with_broadcast_replicas(2)
            .with_sim_worker_failures(1);
        let rep = simulate(&log, &c);
        assert_eq!(rep.sim_broadcast_ship_bytes, 2000, "both nodes hold a copy");
        assert_eq!(rep.sim_repair_ship_bytes, 0, "no third node to repair onto");
    }

    #[test]
    fn two_tenants_on_one_core_double_the_makespan() {
        // the serve daemon admits a second identical job: same slots,
        // twice the compute — on one core the makespan exactly doubles
        let log = make_log(&[(1, 0.0, 4.0, 4, 1.0)]);
        let one = simulate(&log, &config(Deploy::SingleThread));
        let two =
            simulate(&log, &config(Deploy::SingleThread).with_sim_concurrent_jobs(2));
        assert_eq!(one.sim_concurrent_jobs, 1);
        assert_eq!(two.sim_concurrent_jobs, 2);
        assert!((two.sim_makespan_s - 2.0 * one.sim_makespan_s).abs() < 1e-9);
        assert!(two.sim_utilization <= 1.0 + 1e-9);
    }

    #[test]
    fn tenants_share_broadcasts_like_the_warm_pool() {
        // two tenants posing the same problem: the job-refcounted payload
        // cache ships it once per node, so simulated broadcast bytes must
        // not grow with the tenant count — only the compute contends
        let bytes = 400_000_000usize; // 1s at 400 MB/s
        let log = EventLog::default();
        log.record_job_submit(JobRecord {
            job_id: 1,
            name: "j".into(),
            num_tasks: 2,
            submit_rel: 0.0,
            finish_rel: 2.0,
            broadcast_deps: vec![(9, bytes)],
        });
        for p in 0..2 {
            log.record_task(TaskRecord {
                job_id: 1,
                partition: p,
                start_rel: 0.0,
                duration: 1.0,
                attempts: 1,
            });
        }
        let c = config(Deploy::Cluster { workers: 2, cores_per_worker: 1 });
        let one = simulate(&log, &c.clone());
        let two = simulate(&log, &c.with_sim_concurrent_jobs(2));
        assert_eq!(
            two.sim_broadcast_ship_bytes, one.sim_broadcast_ship_bytes,
            "a shared problem ships once, not once per tenant"
        );
        assert!(two.sim_makespan_s > one.sim_makespan_s, "tenants contend for cores");
    }

    #[test]
    fn tenant_sync_chains_stay_independent() {
        // a sync driver's j1 -> j2 chain must replicate per tenant without
        // cross-tenant gating: two chains on enough cores finish in the
        // single-tenant time
        let log = make_log(&[(1, 0.0, 4.0, 4, 1.0), (2, 4.0, 8.0, 4, 1.0)]);
        let one = simulate(&log, &config(Deploy::Local { cores: 4 }));
        let two = simulate(&log, &config(Deploy::Local { cores: 8 }).with_sim_concurrent_jobs(2));
        assert!((one.sim_makespan_s - 2.0).abs() < 1e-9);
        assert!((two.sim_makespan_s - 2.0).abs() < 1e-9, "{}", two.sim_makespan_s);
    }

    #[test]
    fn partial_saved_tasks_price_at_the_mean_duration() {
        // 4 measured tasks of 1s and 3s mean 2s each; 6 saved tasks price
        // at 12s — and the makespan is untouched (the saved tasks never
        // ran, so there is nothing to subtract them from)
        let log = EventLog::default();
        log.record_job_submit(JobRecord {
            job_id: 1,
            name: "j".into(),
            num_tasks: 4,
            submit_rel: 0.0,
            finish_rel: 8.0,
            broadcast_deps: vec![],
        });
        for (p, dur) in [1.0, 3.0, 1.0, 3.0].into_iter().enumerate() {
            log.record_task(TaskRecord {
                job_id: 1,
                partition: p,
                start_rel: 0.0,
                duration: dur,
                attempts: 1,
            });
        }
        let base = simulate(&log, &config(Deploy::SingleThread));
        assert_eq!(base.sim_partial_saved_task_s, 0.0, "knob off prices nothing");
        let rep = simulate(
            &log,
            &config(Deploy::SingleThread).with_sim_partial_saved_tasks(6),
        );
        assert!((rep.sim_partial_saved_task_s - 12.0).abs() < 1e-9);
        assert_eq!(rep.sim_makespan_s, base.sim_makespan_s, "makespan unchanged");
    }

    #[test]
    fn overhead_charged_per_task() {
        let log = make_log(&[(1, 0.0, 1.0, 4, 0.0)]);
        let mut c = config(Deploy::SingleThread);
        c.task_overhead_us = 1_000_000; // 1s
        let rep = simulate(&log, &c);
        assert!((rep.sim_makespan_s - 4.0).abs() < 1e-9);
    }
}
