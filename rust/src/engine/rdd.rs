//! Resilient-Distributed-Dataset analogue: an immutable, partitioned,
//! lazily evaluated dataset with narrow transformations.
//!
//! Like Spark, narrow transformations (`map`, `filter`, `flat_map`,
//! `map_partitions`) do **not** copy data: they compose the partition
//! compute function, so a chain of narrow transforms fuses into a single
//! task per partition — exactly Spark's stage-fusion behaviour. Actions
//! live on [`super::context::Context`].

use std::sync::{Arc, OnceLock};

/// Broadcast dependency tag: (id, size-in-bytes). Propagated through
/// transforms so the DES knows which jobs must ship which tables.
pub(crate) type BroadcastDep = (u64, usize);

pub(crate) struct RddInner<T> {
    /// Number of partitions.
    pub partitions: usize,
    /// Compute partition `p` from scratch (pure; may run on any thread).
    pub compute: Arc<dyn Fn(usize) -> Vec<T> + Send + Sync>,
    /// Human-readable lineage, e.g. `parallelize.map.filter`.
    pub name: String,
    /// Broadcast variables this lineage reads.
    pub broadcast_deps: Vec<BroadcastDep>,
    /// Cache slots (filled by `cache()` + first evaluation).
    pub cache: Option<Arc<Vec<OnceLock<Vec<T>>>>>,
}

/// An immutable, lazily evaluated, partitioned dataset.
pub struct Rdd<T> {
    pub(crate) inner: Arc<RddInner<T>>,
}

impl<T> Clone for Rdd<T> {
    fn clone(&self) -> Self {
        Rdd { inner: Arc::clone(&self.inner) }
    }
}

impl<T: Send + Sync + 'static> Rdd<T> {
    /// Build an RDD from an explicit partition compute function.
    pub fn from_compute<F>(partitions: usize, name: impl Into<String>, compute: F) -> Rdd<T>
    where
        F: Fn(usize) -> Vec<T> + Send + Sync + 'static,
    {
        Rdd {
            inner: Arc::new(RddInner {
                partitions,
                compute: Arc::new(compute),
                name: name.into(),
                broadcast_deps: Vec::new(),
                cache: None,
            }),
        }
    }

    /// Distribute `data` over `partitions` roughly equal slices.
    pub fn parallelize(data: Vec<T>, partitions: usize) -> Rdd<T>
    where
        T: Clone,
    {
        let partitions = partitions.max(1).min(data.len().max(1));
        let data = Arc::new(data);
        let n = data.len();
        Rdd::from_compute(partitions, "parallelize", move |p| {
            let lo = p * n / partitions;
            let hi = (p + 1) * n / partitions;
            data[lo..hi].to_vec()
        })
    }

    pub fn num_partitions(&self) -> usize {
        self.inner.partitions
    }

    pub fn name(&self) -> &str {
        &self.inner.name
    }

    /// Evaluate one partition (used by the scheduler; respects the cache).
    pub(crate) fn compute_partition(&self, p: usize) -> Vec<T>
    where
        T: Clone,
    {
        if let Some(cache) = &self.inner.cache {
            cache[p].get_or_init(|| (self.inner.compute)(p)).clone()
        } else {
            (self.inner.compute)(p)
        }
    }

    fn derive<U: Send + Sync + 'static>(
        &self,
        suffix: &str,
        partitions: usize,
        compute: Arc<dyn Fn(usize) -> Vec<U> + Send + Sync>,
    ) -> Rdd<U> {
        Rdd {
            inner: Arc::new(RddInner {
                partitions,
                compute,
                name: format!("{}.{}", self.inner.name, suffix),
                broadcast_deps: self.inner.broadcast_deps.clone(),
                cache: None,
            }),
        }
    }

    /// Element-wise transformation.
    pub fn map<U, F>(&self, f: F) -> Rdd<U>
    where
        U: Send + Sync + 'static,
        F: Fn(T) -> U + Send + Sync + 'static,
        T: Clone,
    {
        let parent = self.clone();
        self.derive(
            "map",
            self.inner.partitions,
            Arc::new(move |p| parent.compute_partition(p).into_iter().map(&f).collect()),
        )
    }

    /// Keep elements matching the predicate.
    pub fn filter<F>(&self, f: F) -> Rdd<T>
    where
        F: Fn(&T) -> bool + Send + Sync + 'static,
        T: Clone,
    {
        let parent = self.clone();
        self.derive(
            "filter",
            self.inner.partitions,
            Arc::new(move |p| parent.compute_partition(p).into_iter().filter(|x| f(x)).collect()),
        )
    }

    /// One-to-many transformation.
    pub fn flat_map<U, I, F>(&self, f: F) -> Rdd<U>
    where
        U: Send + Sync + 'static,
        I: IntoIterator<Item = U>,
        F: Fn(T) -> I + Send + Sync + 'static,
        T: Clone,
    {
        let parent = self.clone();
        self.derive(
            "flat_map",
            self.inner.partitions,
            Arc::new(move |p| parent.compute_partition(p).into_iter().flat_map(&f).collect()),
        )
    }

    /// Whole-partition transformation (the workhorse for batched XLA calls:
    /// one executable invocation can serve a whole partition).
    pub fn map_partitions<U, F>(&self, f: F) -> Rdd<U>
    where
        U: Send + Sync + 'static,
        F: Fn(usize, Vec<T>) -> Vec<U> + Send + Sync + 'static,
        T: Clone,
    {
        let parent = self.clone();
        self.derive(
            "map_partitions",
            self.inner.partitions,
            Arc::new(move |p| f(p, parent.compute_partition(p))),
        )
    }

    /// Deterministic Bernoulli sample of the dataset (Spark `sample`):
    /// element kept with probability `fraction`, seeded per partition so
    /// the result is independent of scheduling.
    pub fn sample(&self, fraction: f64, seed: u64) -> Rdd<T>
    where
        T: Clone,
    {
        assert!((0.0..=1.0).contains(&fraction));
        let parent = self.clone();
        self.derive(
            "sample",
            self.inner.partitions,
            Arc::new(move |p| {
                let mut rng = crate::util::rng::Rng::new(seed).fork(p as u64);
                parent
                    .compute_partition(p)
                    .into_iter()
                    .filter(|_| rng.f64() < fraction)
                    .collect()
            }),
        )
    }

    /// Pair each element with its global index (Spark `zipWithIndex`).
    ///
    /// Requires a pass to size the preceding partitions, like Spark's
    /// implementation; with a cached parent the extra pass is free.
    pub fn zip_with_index(&self) -> Rdd<(usize, T)>
    where
        T: Clone,
    {
        let parent = self.clone();
        self.derive(
            "zip_with_index",
            self.inner.partitions,
            Arc::new(move |p| {
                let offset: usize = (0..p).map(|q| parent.compute_partition(q).len()).sum();
                parent
                    .compute_partition(p)
                    .into_iter()
                    .enumerate()
                    .map(|(i, v)| (offset + i, v))
                    .collect()
            }),
        )
    }

    /// Key elements by `f` — the entry point to the keyed aggregations.
    pub fn key_by<K, F>(&self, f: F) -> Rdd<(K, T)>
    where
        K: Send + Sync + 'static,
        F: Fn(&T) -> K + Send + Sync + 'static,
        T: Clone,
    {
        let parent = self.clone();
        self.derive(
            "key_by",
            self.inner.partitions,
            Arc::new(move |p| {
                parent
                    .compute_partition(p)
                    .into_iter()
                    .map(|v| (f(&v), v))
                    .collect()
            }),
        )
    }

    /// Concatenate two RDDs (partition lists appended, like Spark union).
    pub fn union(&self, other: &Rdd<T>) -> Rdd<T>
    where
        T: Clone,
    {
        let a = self.clone();
        let b = other.clone();
        let na = a.inner.partitions;
        let mut deps = self.inner.broadcast_deps.clone();
        deps.extend(other.inner.broadcast_deps.iter().copied());
        Rdd {
            inner: Arc::new(RddInner {
                partitions: na + b.inner.partitions,
                compute: Arc::new(move |p| {
                    if p < na {
                        a.compute_partition(p)
                    } else {
                        b.compute_partition(p - na)
                    }
                }),
                name: format!("union({},{})", self.inner.name, other.inner.name),
                broadcast_deps: deps,
                cache: None,
            }),
        }
    }

    /// Rename the lineage (event-log/DES readability — e.g. the sharded
    /// table pipeline labels each per-shard transform job
    /// `table_shard_3.transform` so replays attribute ship costs to the
    /// right shard broadcast).
    pub fn named(&self, name: impl Into<String>) -> Rdd<T> {
        Rdd {
            inner: Arc::new(RddInner {
                partitions: self.inner.partitions,
                compute: Arc::clone(&self.inner.compute),
                name: name.into(),
                broadcast_deps: self.inner.broadcast_deps.clone(),
                cache: self.inner.cache.clone(),
            }),
        }
    }

    /// Mark this lineage as reading broadcast variable `b` — metadata for
    /// the DES cost model (ship once per node), mirroring Spark closures
    /// capturing a `Broadcast` handle.
    pub fn uses_broadcast<B>(&self, b: &super::broadcast::Broadcast<B>) -> Rdd<T> {
        let mut deps = self.inner.broadcast_deps.clone();
        if !deps.iter().any(|(id, _)| *id == b.id()) {
            deps.push((b.id(), b.size_bytes()));
        }
        Rdd {
            inner: Arc::new(RddInner {
                partitions: self.inner.partitions,
                compute: Arc::clone(&self.inner.compute),
                name: self.inner.name.clone(),
                broadcast_deps: deps,
                cache: self.inner.cache.clone(),
            }),
        }
    }

    /// Materialize each partition at most once (Spark `.cache()`):
    /// subsequent evaluations reuse the stored partitions.
    pub fn cache(&self) -> Rdd<T> {
        let cells = (0..self.inner.partitions).map(|_| OnceLock::new()).collect();
        Rdd {
            inner: Arc::new(RddInner {
                partitions: self.inner.partitions,
                compute: Arc::clone(&self.inner.compute),
                name: format!("{}.cache", self.inner.name),
                broadcast_deps: self.inner.broadcast_deps.clone(),
                cache: Some(Arc::new(cells)),
            }),
        }
    }

    pub(crate) fn broadcast_deps(&self) -> &[BroadcastDep] {
        &self.inner.broadcast_deps
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    fn eval<T: Clone + Send + Sync + 'static>(rdd: &Rdd<T>) -> Vec<T> {
        (0..rdd.num_partitions())
            .flat_map(|p| rdd.compute_partition(p))
            .collect()
    }

    #[test]
    fn parallelize_preserves_order_and_content() {
        let rdd = Rdd::parallelize((0..100).collect(), 7);
        assert_eq!(rdd.num_partitions(), 7);
        assert_eq!(eval(&rdd), (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn parallelize_more_partitions_than_elements() {
        let rdd = Rdd::parallelize(vec![1, 2, 3], 10);
        assert_eq!(rdd.num_partitions(), 3);
        assert_eq!(eval(&rdd), vec![1, 2, 3]);
    }

    #[test]
    fn map_filter_flat_map_fuse_lazily() {
        let rdd = Rdd::parallelize((0..20).collect(), 4)
            .map(|x| x * 2)
            .filter(|x| x % 4 == 0)
            .flat_map(|x| vec![x, x + 1]);
        assert_eq!(rdd.name(), "parallelize.map.filter.flat_map");
        let want: Vec<i32> = (0..20)
            .map(|x| x * 2)
            .filter(|x| x % 4 == 0)
            .flat_map(|x| vec![x, x + 1])
            .collect();
        assert_eq!(eval(&rdd), want);
    }

    #[test]
    fn map_partitions_sees_partition_index() {
        let rdd = Rdd::parallelize((0..12).collect::<Vec<i32>>(), 3)
            .map_partitions(|p, xs| vec![(p, xs.len())]);
        assert_eq!(eval(&rdd), vec![(0, 4), (1, 4), (2, 4)]);
    }

    #[test]
    fn union_concatenates() {
        let a = Rdd::parallelize(vec![1, 2], 1);
        let b = Rdd::parallelize(vec![3, 4], 2);
        let u = a.union(&b);
        assert_eq!(u.num_partitions(), 3);
        assert_eq!(eval(&u), vec![1, 2, 3, 4]);
    }

    #[test]
    fn lazy_until_evaluated() {
        static CALLS: AtomicUsize = AtomicUsize::new(0);
        let rdd = Rdd::parallelize((0..4).collect::<Vec<i32>>(), 2).map(|x| {
            CALLS.fetch_add(1, Ordering::SeqCst);
            x
        });
        assert_eq!(CALLS.load(Ordering::SeqCst), 0);
        let _ = eval(&rdd);
        assert_eq!(CALLS.load(Ordering::SeqCst), 4);
    }

    #[test]
    fn named_preserves_semantics_and_deps() {
        let b = crate::engine::Broadcast::new(1u8, 8);
        let rdd = Rdd::parallelize((0..6).collect::<Vec<i32>>(), 2)
            .map(|x| x + 1)
            .uses_broadcast(&b)
            .named("renamed");
        assert_eq!(rdd.name(), "renamed");
        assert_eq!(rdd.broadcast_deps(), &[(b.id(), 8)]);
        assert_eq!(eval(&rdd), vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn cache_computes_once() {
        static CALLS: AtomicUsize = AtomicUsize::new(0);
        let rdd = Rdd::parallelize((0..4).collect::<Vec<i32>>(), 2)
            .map(|x| {
                CALLS.fetch_add(1, Ordering::SeqCst);
                x * 10
            })
            .cache();
        assert_eq!(eval(&rdd), vec![0, 10, 20, 30]);
        assert_eq!(eval(&rdd), vec![0, 10, 20, 30]);
        assert_eq!(CALLS.load(Ordering::SeqCst), 4, "cached partitions recomputed");
    }
}
