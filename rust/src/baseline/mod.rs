//! Comparators from the paper's evaluation (§4.1).
//!
//! [`redm`] is a faithful Rust port of the sequential rEDM `ccm` loop the
//! paper benchmarks against ("approximately 15x faster than rEDM for the
//! baseline scenario").

pub mod redm;

pub use redm::{redm_ccm, RedmConfig};
