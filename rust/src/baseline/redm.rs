//! rEDM-style sequential CCM baseline.
//!
//! Mirrors the structure of rEDM's C++ `ccm` / `block_lnlp` path (Ye et
//! al. 2016): a straight per-subsample loop — embed once, then for every
//! library draw, brute-force neighbour search over the library, simplex
//! projection, Pearson skill. No engine, no table, no parallelism: this is
//! the external comparator of the paper's §4.1, so it deliberately shares
//! *no* scheduling machinery with the A-cases (only the low-level math
//! kernels, as rEDM shares BLAS with anything else).

use crate::ccm::embedding::Embedding;
use crate::ccm::knn::knn_one;
use crate::ccm::params::CcmParams;
use crate::ccm::result::SkillRow;
use crate::ccm::simplex::{pearson_f32, simplex_one};
use crate::ccm::subsample::draw_samples;
use crate::util::rng::Rng;
use crate::{EMAX, KMAX};

/// Baseline configuration (subset of a [`crate::ccm::params::Scenario`]).
#[derive(Clone, Debug)]
pub struct RedmConfig {
    pub params: CcmParams,
    /// Number of random library draws.
    pub r: usize,
    pub theiler: f32,
    pub seed: u64,
}

/// Sequential CCM: skill of cross-mapping `cause` from `effect`'s
/// manifold, one [`SkillRow`] per library draw.
pub fn redm_ccm(effect: &[f32], cause: &[f32], config: &RedmConfig) -> Vec<SkillRow> {
    let emb = Embedding::new(effect, config.params.e, config.params.tau);
    let targets = emb.align_targets(cause);
    let times: Vec<f32> = (0..emb.n).map(|i| emb.time_of(i) as f32).collect();
    let master = Rng::new(config.seed);
    let samples = draw_samples(&master, config.params, emb.n, config.r);

    let mut out = Vec::with_capacity(config.r);
    let mut dbuf = [0.0f32; KMAX];
    let mut tbuf = [0.0f32; KMAX];
    // hoisted scratch: the knn distance sweep buffer and the per-sample
    // library/prediction buffers are reused across all r * n queries
    let mut scratch: Vec<f32> = Vec::new();
    let mut lib_vecs: Vec<f32> = Vec::new();
    let mut lib_targets: Vec<f32> = Vec::new();
    let mut lib_times: Vec<f32> = Vec::new();
    let mut preds: Vec<f32> = Vec::new();
    for sample in samples {
        // materialize the library (rEDM gathers lib rows the same way)
        lib_vecs.clear();
        lib_targets.clear();
        lib_times.clear();
        lib_vecs.reserve(sample.rows.len() * EMAX);
        for &row in &sample.rows {
            lib_vecs.extend_from_slice(emb.point(row));
            lib_targets.push(targets[row]);
            lib_times.push(times[row]);
        }
        // predict at every manifold point
        preds.clear();
        for i in 0..emb.n {
            knn_one(
                emb.point(i),
                times[i],
                &lib_vecs,
                &lib_targets,
                &lib_times,
                config.theiler,
                &mut scratch,
                &mut dbuf,
                &mut tbuf,
            );
            preds.push(simplex_one(&dbuf, &tbuf, config.params.e));
        }
        let rho = pearson_f32(&preds, &targets);
        out.push(SkillRow { params: config.params, sample_id: sample.sample_id, rho });
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ccm::backend::ComputeBackend;
    use crate::ccm::pipeline::CcmProblem;
    use crate::native::NativeBackend;
    use crate::timeseries::generators::{coupled_logistic, CoupledLogisticParams};

    #[test]
    fn matches_native_backend_exactly() {
        // same seeds -> same libraries -> identical skills as the A-cases
        let (x, y) = coupled_logistic(300, CoupledLogisticParams::default());
        let config = RedmConfig { params: CcmParams::new(2, 1, 100), r: 6, theiler: 0.0, seed: 7 };
        let redm = redm_ccm(&y, &x, &config);

        let problem = CcmProblem::new(&y, &x, 2, 1, 0.0);
        let master = Rng::new(7);
        let samples = draw_samples(&master, config.params, problem.emb.n, 6);
        for (row, sample) in redm.iter().zip(&samples) {
            let out = NativeBackend.cross_map(&problem.input_for(sample));
            assert!(
                (row.rho - out.rho).abs() < 1e-6,
                "sample {}: redm {} vs native {}",
                sample.sample_id,
                row.rho,
                out.rho
            );
        }
    }

    #[test]
    fn produces_r_rows_with_skill() {
        let (x, y) = coupled_logistic(400, CoupledLogisticParams::default());
        let config =
            RedmConfig { params: CcmParams::new(2, 1, 200), r: 10, theiler: 0.0, seed: 1 };
        let rows = redm_ccm(&y, &x, &config);
        assert_eq!(rows.len(), 10);
        assert!(rows.iter().all(|r| r.rho > 0.5));
    }
}
