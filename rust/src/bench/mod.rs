//! criterion-lite: a small measurement harness for the `cargo bench`
//! targets (the offline image has no criterion).

pub mod harness;
pub mod report;

pub use harness::{bench, BenchResult, Bencher};
pub use report::{Row, TablePrinter};
