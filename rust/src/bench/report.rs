//! Table rendering + JSON persistence for bench results (the printed rows
//! mirror the paper's figures; see rust/benches/*).

use std::path::Path;

use crate::util::json::Json;

/// One table row: a label and named numeric cells.
#[derive(Clone, Debug)]
pub struct Row {
    pub label: String,
    pub cells: Vec<(String, f64)>,
}

impl Row {
    pub fn new(label: impl Into<String>) -> Row {
        Row { label: label.into(), cells: Vec::new() }
    }

    pub fn cell(mut self, name: impl Into<String>, value: f64) -> Row {
        self.cells.push((name.into(), value));
        self
    }
}

/// Fixed-width table printer + JSON dump.
pub struct TablePrinter {
    pub title: String,
    pub rows: Vec<Row>,
}

impl TablePrinter {
    pub fn new(title: impl Into<String>) -> TablePrinter {
        TablePrinter { title: title.into(), rows: Vec::new() }
    }

    pub fn push(&mut self, row: Row) {
        self.rows.push(row);
    }

    /// Render to stdout.
    pub fn print(&self) {
        println!("\n=== {} ===", self.title);
        if self.rows.is_empty() {
            println!("(no rows)");
            return;
        }
        let headers: Vec<&str> = self.rows[0].cells.iter().map(|(n, _)| n.as_str()).collect();
        print!("{:<34}", "");
        for h in &headers {
            print!("{h:>16}");
        }
        println!();
        for row in &self.rows {
            print!("{:<34}", truncate(&row.label, 33));
            for (_, v) in &row.cells {
                if v.abs() >= 1000.0 || (v.abs() < 0.01 && *v != 0.0) {
                    print!("{v:>16.3e}");
                } else {
                    print!("{v:>16.4}");
                }
            }
            println!();
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("title", Json::Str(self.title.clone())),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            let mut pairs = vec![("label", Json::Str(r.label.clone()))];
                            let cells: Vec<(&str, Json)> = r
                                .cells
                                .iter()
                                .map(|(n, v)| (n.as_str(), Json::Num(*v)))
                                .collect();
                            pairs.extend(cells);
                            Json::obj(pairs)
                        })
                        .collect(),
                ),
            ),
        ])
    }

    /// Persist as JSON under `results/` (created if needed).
    pub fn save(&self, path: impl AsRef<Path>) -> std::io::Result<()> {
        if let Some(parent) = path.as_ref().parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, self.to_json().to_string())
    }
}

fn truncate(s: &str, max: usize) -> String {
    if s.len() <= max {
        s.to_string()
    } else {
        format!("{}…", &s[..max - 1])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_rows_and_json() {
        let mut t = TablePrinter::new("demo");
        t.push(Row::new("a").cell("x", 1.0).cell("y", 2.0));
        t.push(Row::new("b").cell("x", 3.0).cell("y", 4.0));
        let j = t.to_json();
        let rows = j.get("rows").unwrap().as_arr().unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1].get("x").unwrap().as_f64(), Some(3.0));
    }

    #[test]
    fn save_roundtrip() {
        let mut t = TablePrinter::new("save");
        t.push(Row::new("r").cell("v", 5.0));
        let path = std::env::temp_dir().join("parccm_bench_report.json");
        t.save(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(Json::parse(&text).is_ok());
        let _ = std::fs::remove_file(path);
    }
}
