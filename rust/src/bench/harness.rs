//! Measurement core: warmup + N samples, summary statistics.

use std::time::Instant;

use crate::util::stats;

/// Summary of one benchmark.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub samples: Vec<f64>,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub median_s: f64,
}

impl BenchResult {
    pub fn from_samples(name: impl Into<String>, samples: Vec<f64>) -> BenchResult {
        let mean_s = stats::mean(&samples);
        let std_s = stats::stddev(&samples);
        let min_s = samples.iter().copied().fold(f64::INFINITY, f64::min);
        let max_s = samples.iter().copied().fold(0.0f64, f64::max);
        let median_s = stats::median(&samples);
        BenchResult { name: name.into(), samples, mean_s, std_s, min_s, max_s, median_s }
    }

    /// `mean ± std` with adaptive units.
    pub fn human(&self) -> String {
        format!("{} ± {}", human_time(self.mean_s), human_time(self.std_s))
    }
}

/// Render seconds with adaptive units.
pub fn human_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.1} µs", s * 1e6)
    }
}

/// Configurable bencher.
pub struct Bencher {
    warmup: usize,
    samples: usize,
    quiet: bool,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { warmup: 1, samples: 5, quiet: false }
    }
}

impl Bencher {
    pub fn new() -> Bencher {
        Bencher::default()
    }

    pub fn warmup(mut self, n: usize) -> Self {
        self.warmup = n;
        self
    }

    pub fn samples(mut self, n: usize) -> Self {
        self.samples = n.max(1);
        self
    }

    pub fn quiet(mut self, q: bool) -> Self {
        self.quiet = q;
        self
    }

    /// Measure `f` (returns wall time of each sample; the closure's result
    /// is returned through a sink to stop dead-code elimination).
    pub fn run<T, F: FnMut() -> T>(&self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup {
            sink(f());
        }
        let mut samples = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t = Instant::now();
            sink(f());
            samples.push(t.elapsed().as_secs_f64());
        }
        let result = BenchResult::from_samples(name, samples);
        if !self.quiet {
            println!("{:<52} {}", result.name, result.human());
        }
        result
    }
}

/// One-shot convenience wrapper.
pub fn bench<T, F: FnMut() -> T>(name: &str, samples: usize, f: F) -> BenchResult {
    Bencher::new().samples(samples).run(name, f)
}

#[inline]
fn sink<T>(value: T) {
    std::hint::black_box(value);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_positive_times() {
        let r = Bencher::new().quiet(true).warmup(0).samples(3).run("spin", || {
            let mut acc = 0u64;
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i * i);
            }
            acc
        });
        assert_eq!(r.samples.len(), 3);
        assert!(r.mean_s > 0.0);
        assert!(r.min_s <= r.median_s && r.median_s <= r.max_s);
    }

    #[test]
    fn human_units() {
        assert!(human_time(2.0).ends_with(" s"));
        assert!(human_time(0.002).ends_with(" ms"));
        assert!(human_time(2e-6).ends_with(" µs"));
    }
}
