//! Pure-Rust compute backend.
//!
//! Implements the [`ComputeBackend`] contract with hand-written kernels
//! ([`crate::ccm::knn`], [`crate::ccm::simplex`]). This is (a) the
//! reference the XLA path is cross-checked against in integration tests,
//! (b) the compute engine of the single-threaded baselines (Case A1,
//! rEDM-style), and (c) the default backend when `artifacts/` has not
//! been built.
//!
//! The hot entry point is [`ComputeBackend::cross_map_into`]: the library
//! panel is gathered once into the caller's [`TaskArena`] (reused buffers,
//! no allocation after the first sample), then the contiguous-library
//! k-NN sweep, simplex, and Pearson all run in arena storage.

use crate::ccm::backend::{ComputeBackend, CrossMapInput, CrossMapOutput, TaskArena};
use crate::ccm::knn::knn_batch_into;
use crate::ccm::simplex::{pearson_f32, simplex_batch_into};
use crate::EMAX;

/// Stateless, always-available backend.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend
    }
}

impl ComputeBackend for NativeBackend {
    fn cross_map_into(&self, input: &CrossMapInput, arena: &mut TaskArena) -> f32 {
        debug_assert!({
            input.validate();
            true
        });
        // Gather the library contiguously once (O(L*EMAX), reused buffer):
        // the branch-free distance sweep then vectorizes over a dense
        // panel for all n queries, which beats per-query index gathering.
        arena.gather_library(input);
        knn_batch_into(
            input.vecs,
            input.times,
            &arena.lib_vecs,
            &arena.lib_targets,
            &arena.lib_times,
            input.theiler,
            &mut arena.dist,
            &mut arena.dvals,
            &mut arena.tvals,
        );
        simplex_batch_into(&arena.dvals, &arena.tvals, input.n_pred(), input.e, &mut arena.preds);
        pearson_f32(&arena.preds, input.targets)
    }

    fn simplex_tail_into(
        &self,
        dvals: &[f32],
        tvals: &[f32],
        pred_targets: &[f32],
        e: usize,
        preds: &mut Vec<f32>,
    ) -> f32 {
        simplex_batch_into(dvals, tvals, pred_targets.len(), e, preds);
        pearson_f32(preds, pred_targets)
    }

    fn distance_matrix(&self, vecs: &[f32], n: usize) -> Vec<f32> {
        debug_assert_eq!(vecs.len(), n * EMAX);
        let mut out = vec![0.0f32; n * n];
        for i in 0..n {
            let a = &vecs[i * EMAX..(i + 1) * EMAX];
            // symmetric: fill upper triangle, mirror
            for j in (i + 1)..n {
                let b = &vecs[j * EMAX..(j + 1) * EMAX];
                let mut d = 0.0f32;
                for l in 0..EMAX {
                    let diff = a[l] - b[l];
                    d += diff * diff;
                }
                out[i * n + j] = d;
                out[j * n + i] = d;
            }
        }
        out
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ccm::backend::NeighborPanels;
    use crate::ccm::params::CcmParams;
    use crate::ccm::pipeline::CcmProblem;
    use crate::ccm::subsample::LibrarySample;
    use crate::timeseries::generators::{coupled_logistic, CoupledLogisticParams};
    use crate::util::rng::Rng;

    /// A problem predicting x from y's manifold plus a random library of
    /// `l` rows (the shared-view fixture for the zero-copy input).
    fn fixture(l: usize, e: usize, tau: usize, seed: u64) -> (CcmProblem, LibrarySample) {
        let (x, y) = coupled_logistic(600, CoupledLogisticParams::default());
        let problem = CcmProblem::new(&y, &x, e, tau, 0.0);
        let mut rng = Rng::new(seed);
        let rows = rng.sample_indices(problem.emb.n, l.min(problem.emb.n));
        let sample =
            LibrarySample { sample_id: 0, params: CcmParams::new(e, tau, l), rows };
        (problem, sample)
    }

    #[test]
    fn skillful_on_coupled_system() {
        let (problem, sample) = fixture(400, 2, 1, 1);
        let out = NativeBackend.cross_map(&problem.input_for(&sample));
        assert!(out.rho > 0.8, "expected high cross-map skill, got {}", out.rho);
        assert_eq!(out.preds.len(), problem.emb.n);
    }

    #[test]
    fn skill_grows_with_library() {
        let (p1, s1) = fixture(40, 2, 1, 2);
        let (p2, s2) = fixture(500, 2, 1, 2);
        let small = NativeBackend.cross_map(&p1.input_for(&s1)).rho;
        let large = NativeBackend.cross_map(&p2.input_for(&s2)).rho;
        assert!(
            large > small + 0.02,
            "convergence violated: rho({}) at L=40 vs rho({}) at L=500",
            small,
            large
        );
    }

    #[test]
    fn arena_reuse_is_deterministic() {
        // same arena across repeated samples must not change results
        let (problem, sample) = fixture(200, 2, 1, 7);
        let input = problem.input_for(&sample);
        let fresh = NativeBackend.cross_map(&input).rho;
        let mut arena = TaskArena::new();
        for _ in 0..3 {
            let rho = NativeBackend.cross_map_into(&input, &mut arena);
            assert_eq!(rho, fresh);
        }
    }

    #[test]
    fn distance_matrix_symmetric_zero_diag() {
        let (problem, _) = fixture(50, 3, 1, 3);
        let n = 50;
        let d = NativeBackend.distance_matrix(&problem.emb.vecs[..n * EMAX], n);
        for i in 0..n {
            assert_eq!(d[i * n + i], 0.0);
            for j in 0..n {
                assert_eq!(d[i * n + j], d[j * n + i]);
            }
        }
    }

    #[test]
    fn shard_chunks_concatenate_to_tail_preds() {
        // the default ComputeBackend::shard_chunk_into over every shard,
        // concatenated in row order, must equal the unsharded table tail
        let (problem, sample) = fixture(150, 2, 1, 9);
        let table = crate::ccm::table::DistanceTable::build(&problem.emb);
        let mut arena = TaskArena::new();
        arena.mask.set_from(table.n, &sample.rows);
        let panels = table.query_all(&sample.rows, &arena.mask, &problem.targets, 0.0);
        let tail = NativeBackend.simplex_tail(&panels, &problem.targets, 2);

        let sharded = table.shard(4);
        let mut preds = Vec::new();
        for shard in sharded.shards() {
            let mut chunk = Vec::new();
            NativeBackend.shard_chunk_into(
                shard,
                &problem.targets,
                0.0,
                &sample.rows,
                2,
                &mut arena,
                &mut chunk,
            );
            assert_eq!(chunk.len(), shard.num_rows());
            preds.extend_from_slice(&chunk);
        }
        assert_eq!(preds, tail.preds);
        assert_eq!(crate::ccm::simplex::pearson_f32(&preds, &problem.targets), tail.rho);
    }

    #[test]
    fn simplex_tail_equals_cross_map() {
        // gathering panels with knn then applying the tail must equal the
        // fused path — the table-mode equivalence.
        let (problem, sample) = fixture(200, 2, 1, 4);
        let input = problem.input_for(&sample);
        let full = NativeBackend.cross_map(&input);
        let mut arena = TaskArena::new();
        arena.gather_library(&input);
        let (dvals, tvals) = crate::ccm::knn::knn_batch(
            input.vecs,
            input.times,
            &arena.lib_vecs,
            &arena.lib_targets,
            &arena.lib_times,
            input.theiler,
        );
        let panels = NeighborPanels { dvals, tvals, n_pred: input.n_pred() };
        let tail = NativeBackend.simplex_tail(&panels, input.targets, input.e);
        assert_eq!(full.rho, tail.rho);
        assert_eq!(full.preds, tail.preds);
    }
}
