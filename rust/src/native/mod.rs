//! Pure-Rust compute backend.
//!
//! Implements the [`ComputeBackend`] contract with hand-written kernels
//! ([`crate::ccm::knn`], [`crate::ccm::simplex`]). This is (a) the
//! reference the XLA path is cross-checked against in integration tests,
//! (b) the compute engine of the single-threaded baselines (Case A1,
//! rEDM-style), and (c) the default backend when `artifacts/` has not
//! been built.

use crate::ccm::backend::{ComputeBackend, CrossMapInput, CrossMapOutput, NeighborPanels};
use crate::ccm::knn::knn_batch;
use crate::ccm::simplex::{pearson_f32, simplex_batch};
use crate::EMAX;

/// Stateless, always-available backend.
#[derive(Clone, Copy, Debug, Default)]
pub struct NativeBackend;

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend
    }
}

impl ComputeBackend for NativeBackend {
    fn cross_map(&self, input: &CrossMapInput) -> CrossMapOutput {
        debug_assert!({
            input.validate();
            true
        });
        let (dvals, tvals) = knn_batch(
            &input.pred_vecs,
            &input.pred_times,
            &input.lib_vecs,
            &input.lib_targets,
            &input.lib_times,
            input.theiler,
        );
        let preds = simplex_batch(&dvals, &tvals, input.n_pred(), input.e);
        let rho = pearson_f32(&preds, &input.pred_targets);
        CrossMapOutput { rho, preds }
    }

    fn distance_matrix(&self, vecs: &[f32], n: usize) -> Vec<f32> {
        debug_assert_eq!(vecs.len(), n * EMAX);
        let mut out = vec![0.0f32; n * n];
        for i in 0..n {
            let a = &vecs[i * EMAX..(i + 1) * EMAX];
            // symmetric: fill upper triangle, mirror
            for j in (i + 1)..n {
                let b = &vecs[j * EMAX..(j + 1) * EMAX];
                let mut d = 0.0f32;
                for l in 0..EMAX {
                    let diff = a[l] - b[l];
                    d += diff * diff;
                }
                out[i * n + j] = d;
                out[j * n + i] = d;
            }
        }
        out
    }

    fn simplex_tail(
        &self,
        panels: &NeighborPanels,
        pred_targets: &[f32],
        e: usize,
    ) -> CrossMapOutput {
        let preds = simplex_batch(&panels.dvals, &panels.tvals, panels.n_pred, e);
        let rho = pearson_f32(&preds, pred_targets);
        CrossMapOutput { rho, preds }
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ccm::embedding::Embedding;
    use crate::timeseries::generators::{coupled_logistic, CoupledLogisticParams};
    use crate::util::rng::Rng;

    /// Build a CrossMapInput predicting x from y's manifold with a random
    /// library of `l` rows.
    fn make_input(l: usize, e: usize, tau: usize, seed: u64) -> CrossMapInput {
        let (x, y) = coupled_logistic(600, CoupledLogisticParams::default());
        let emb = Embedding::new(&y, e, tau);
        let targets = emb.align_targets(&x);
        let mut rng = Rng::new(seed);
        let rows = rng.sample_indices(emb.n, l.min(emb.n));
        let mut lib_vecs = Vec::with_capacity(rows.len() * EMAX);
        let mut lib_targets = Vec::with_capacity(rows.len());
        let mut lib_times = Vec::with_capacity(rows.len());
        for &row in &rows {
            lib_vecs.extend_from_slice(emb.point(row));
            lib_targets.push(targets[row]);
            lib_times.push(emb.time_of(row) as f32);
        }
        CrossMapInput {
            lib_vecs,
            lib_targets,
            lib_times,
            pred_vecs: emb.vecs.clone(),
            pred_targets: targets,
            pred_times: (0..emb.n).map(|i| emb.time_of(i) as f32).collect(),
            e,
            theiler: 0.0,
        }
    }

    #[test]
    fn skillful_on_coupled_system() {
        let out = NativeBackend.cross_map(&make_input(400, 2, 1, 1));
        assert!(out.rho > 0.8, "expected high cross-map skill, got {}", out.rho);
        assert_eq!(out.preds.len(), make_input(400, 2, 1, 1).n_pred());
    }

    #[test]
    fn skill_grows_with_library() {
        let small = NativeBackend.cross_map(&make_input(40, 2, 1, 2)).rho;
        let large = NativeBackend.cross_map(&make_input(500, 2, 1, 2)).rho;
        assert!(
            large > small + 0.02,
            "convergence violated: rho({}) at L=40 vs rho({}) at L=500",
            small,
            large
        );
    }

    #[test]
    fn distance_matrix_symmetric_zero_diag() {
        let input = make_input(50, 3, 1, 3);
        let n = 50;
        let d = NativeBackend.distance_matrix(&input.lib_vecs, n);
        for i in 0..n {
            assert_eq!(d[i * n + i], 0.0);
            for j in 0..n {
                assert_eq!(d[i * n + j], d[j * n + i]);
            }
        }
    }

    #[test]
    fn simplex_tail_equals_cross_map() {
        // gathering panels with knn then applying the tail must equal the
        // fused path — the table-mode equivalence.
        let input = make_input(200, 2, 1, 4);
        let full = NativeBackend.cross_map(&input);
        let (dvals, tvals) = crate::ccm::knn::knn_batch(
            &input.pred_vecs,
            &input.pred_times,
            &input.lib_vecs,
            &input.lib_targets,
            &input.lib_times,
            input.theiler,
        );
        let panels = NeighborPanels { dvals, tvals, n_pred: input.n_pred() };
        let tail = NativeBackend.simplex_tail(&panels, &input.pred_targets, input.e);
        assert_eq!(full.rho, tail.rho);
        assert_eq!(full.preds, tail.preds);
    }
}
