//! A per-scope hang guard: abort the whole process if a scope outlives
//! its deadline.
//!
//! The integration tests that drive real subprocesses and sockets
//! (`tests/integration_process.rs`, `tests/integration_cluster.rs`) wrap
//! each test in a [`Watchdog`] so a wedged worker or a lost handshake
//! fails CI within seconds instead of stalling the job until the runner's
//! global timeout. Aborting (rather than panicking on the watchdog
//! thread) is deliberate: the hung test thread would never observe a
//! panic flag, but `abort` tears the test binary down immediately with a
//! non-zero status and the label in stderr.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Guard that aborts the process if still armed when `timeout` elapses.
/// Disarms on drop, so a test that finishes in time costs one parked
/// thread poll at most.
pub struct Watchdog {
    disarmed: Arc<AtomicBool>,
}

impl Watchdog {
    /// Arm a watchdog; keep the returned guard alive for the guarded
    /// scope (`let _guard = Watchdog::arm(...)`).
    #[must_use = "binding to _ drops (and disarms) the guard immediately"]
    pub fn arm(label: &'static str, timeout: Duration) -> Watchdog {
        let disarmed = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&disarmed);
        std::thread::spawn(move || {
            let deadline = Instant::now() + timeout;
            while Instant::now() < deadline {
                if flag.load(Ordering::Relaxed) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(50));
            }
            if !flag.load(Ordering::Relaxed) {
                eprintln!(
                    "[watchdog] '{label}' still running after {timeout:?}; \
                     aborting so CI fails fast instead of hanging"
                );
                std::process::abort();
            }
        });
        Watchdog { disarmed }
    }
}

impl Drop for Watchdog {
    fn drop(&mut self) {
        self.disarmed.store(true, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn disarmed_watchdog_does_not_fire() {
        // drop immediately; give the watchdog thread a chance to observe
        // the flag before its (short) deadline passes
        {
            let _guard = Watchdog::arm("noop", Duration::from_millis(200));
        }
        std::thread::sleep(Duration::from_millis(400));
        // reaching this line is the assertion: the process was not aborted
    }

    #[test]
    fn guard_scope_outlives_fast_work() {
        let _guard = Watchdog::arm("fast work", Duration::from_secs(60));
        let x: u64 = (0..1000).sum();
        assert_eq!(x, 499_500);
    }
}
