//! Minimal JSON: a value model, a recursive-descent parser, and a writer.
//!
//! Used for the artifact manifest (`artifacts/manifest.json`, written by
//! the Python AOT step), engine event logs, and bench result dumps. The
//! offline image has no serde, so this is a from-scratch implementation of
//! the subset of JSON those files use (which is all of JSON minus exotic
//! number forms).

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a BTreeMap so output is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Encode an f32 slice as a number array. The f32 -> f64 widening is
    /// exact and [`Json`]'s writer prints shortest-roundtrip f64, so
    /// decoding with [`Json::as_f32s`] is bit-identical for finite values
    /// — the property the process wire protocol relies on.
    pub fn f32s(xs: &[f32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Encode a u32 slice as a number array (exact in f64).
    pub fn u32s(xs: &[u32]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Encode a usize slice as a number array (callers keep values under
    /// 2^53 — manifold row indices always are).
    pub fn usizes(xs: &[usize]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x as f64)).collect())
    }

    /// Decode a number array into f32s (None if not an array of numbers).
    pub fn as_f32s(&self) -> Option<Vec<f32>> {
        self.as_arr()?.iter().map(|v| v.as_f64().map(|x| x as f32)).collect()
    }

    /// Decode a number array into u32s.
    pub fn as_u32s(&self) -> Option<Vec<u32>> {
        self.as_arr()?.iter().map(|v| v.as_f64().map(|x| x as u32)).collect()
    }

    /// Decode a number array into usizes.
    pub fn as_usizes(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_f64().map(|x| x as usize)).collect()
    }

    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }
}

#[derive(Debug)]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { pos: self.i, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.i += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.i;
                    let len = utf8_len(self.b[start]);
                    let end = (start + len).min(self.b.len());
                    let s = std::str::from_utf8(&self.b[start..end])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    out.push_str(s);
                    self.i = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.i += 1;
        }
        if self.peek() == Some(b'.') {
            self.i += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.i += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.i += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.i += 1;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.skip_ws();
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            out.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

impl fmt::Display for Json {
    /// Compact serialization.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    write!(f, "{}", *x as i64)
                } else {
                    write!(f, "{x}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\t' => write!(f, "\\t")?,
            '\r' => write!(f, "\\r")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a":[1,2,{"b":null}],"c":"x"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64(), Some(2.0));
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"big":1e30,"emax":8,"list":[1,2.5,"s",true,null],"nested":{"k":[{}]}}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "emax": 8, "kmax": 11, "big": 1e+30,
          "artifacts": [
            {"name": "ccm_n256", "kind": "cross_map", "file": "ccm_n256.hlo.txt", "n": 256, "p": 256}
          ]
        }"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.get("kmax").unwrap().as_usize(), Some(11));
        let a = &v.get("artifacts").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.get("kind").unwrap().as_str(), Some("cross_map"));
        assert_eq!(v.get("big").unwrap().as_f64(), Some(1e30));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn f32_arrays_roundtrip_bit_exact() {
        // (-0.0 is the one finite non-roundtripper: the integer fast path
        // prints it as "0" — the wire never carries signed zeros that
        // matter, simplex weights are strictly positive)
        let xs = vec![
            0.0f32,
            1.0,
            -1.5e-7,
            1e30, // BIG
            0.1,
            f32::MIN_POSITIVE,
            1.0e-40, // subnormal
            3.14159265,
            -2.718281828,
        ];
        let text = Json::f32s(&xs).to_string();
        let back = Json::parse(&text).unwrap().as_f32s().unwrap();
        assert_eq!(back.len(), xs.len());
        for (a, b) in xs.iter().zip(&back) {
            assert_eq!(a.to_bits(), b.to_bits(), "{a} vs {b}");
        }
    }

    #[test]
    fn index_arrays_roundtrip() {
        let us = vec![0usize, 1, 63, 64, 4000, (1usize << 40) + 3];
        let text = Json::usizes(&us).to_string();
        assert_eq!(Json::parse(&text).unwrap().as_usizes().unwrap(), us);
        let u32s = vec![0u32, 7, u32::MAX];
        let text = Json::u32s(&u32s).to_string();
        assert_eq!(Json::parse(&text).unwrap().as_u32s().unwrap(), u32s);
        assert!(Json::parse("[1,\"x\"]").unwrap().as_usizes().is_none());
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""A""#).unwrap(), Json::Str("A".into()));
        let v = Json::parse("\"caf\u{e9}\"").unwrap();
        assert_eq!(v.as_str(), Some("café"));
    }
}
