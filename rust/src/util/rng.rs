//! Deterministic, seedable PRNG: xoshiro256++ seeded via SplitMix64.
//!
//! CCM draws `r` random library subsamples per parameter combination; the
//! engine fans those draws across tasks, so every task derives its own
//! stream with [`Rng::fork`] (SplitMix64 over (seed, stream-id)) to keep
//! results independent of partitioning and scheduling order.

/// SplitMix64 step — used for seeding and stream derivation.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256++ — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 (never yields the all-zero state).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream for task `id` — deterministic in
    /// (parent seed, id), independent of call order.
    pub fn fork(&self, id: u64) -> Rng {
        let mut sm = self.s[0] ^ id.wrapping_mul(0xA24BAED4963EE407);
        Rng::new(splitmix64(&mut sm))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1) as f32.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Unbiased uniform integer in [0, n) (Lemire rejection).
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let (hi, lo) = {
                let m = (x as u128) * (n as u128);
                ((m >> 64) as u64, m as u64)
            };
            if lo >= n || lo >= n.wrapping_neg() % n {
                return hi as usize;
            }
        }
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// `k` distinct indices from [0, n), ascending — a partial Fisher–Yates
    /// over an implicit identity array (O(k) memory via a sparse map).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot sample {k} from {n}");
        use std::collections::HashMap;
        let mut swapped: HashMap<usize, usize> = HashMap::new();
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let j = i + self.below(n - i);
            let vi = *swapped.get(&i).unwrap_or(&i);
            let vj = *swapped.get(&j).unwrap_or(&j);
            out.push(vj);
            swapped.insert(j, vi);
        }
        out.sort_unstable();
        out
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn fork_independent_of_order() {
        let root = Rng::new(7);
        let mut a = root.fork(3);
        let _ = root.fork(9);
        let mut b = root.fork(3);
        assert_eq!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_unbiased_support() {
        let mut r = Rng::new(11);
        let mut seen = [0usize; 7];
        for _ in 0..7_000 {
            seen[r.below(7)] += 1;
        }
        for (i, &c) in seen.iter().enumerate() {
            assert!(c > 700, "bucket {i} severely underrepresented: {c}");
        }
    }

    #[test]
    fn sample_indices_distinct_sorted_in_range() {
        let mut r = Rng::new(13);
        for _ in 0..100 {
            let s = r.sample_indices(50, 20);
            assert_eq!(s.len(), 20);
            assert!(s.windows(2).all(|w| w[0] < w[1]));
            assert!(s.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn sample_indices_full_population() {
        let mut r = Rng::new(17);
        let s = r.sample_indices(10, 10);
        assert_eq!(s, (0..10).collect::<Vec<_>>());
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(23);
        let xs: Vec<f64> = (0..50_000).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / xs.len() as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(29);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
