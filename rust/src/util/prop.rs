//! A miniature property-testing harness (no proptest offline).
//!
//! [`check`] runs a property over `cases` seeded inputs; on failure it
//! reports the seed so the case can be replayed deterministically:
//!
//! ```no_run
//! # // no_run: doctest binaries don't inherit the xla_extension rpath on
//! # // this image (libstdc++ loader error); the same code is exercised by
//! # // the unit tests below and rust/tests/prop_invariants.rs.
//! use parccm::util::prop::check;
//! use parccm::util::rng::Rng;
//! check("sort is idempotent", 200, |rng: &mut Rng| {
//!     let mut v: Vec<u64> = (0..rng.below(50)).map(|_| rng.next_u64()).collect();
//!     v.sort_unstable();
//!     let w = { let mut w = v.clone(); w.sort_unstable(); w };
//!     if v == w { Ok(()) } else { Err("not idempotent".into()) }
//! });
//! ```

use super::rng::Rng;

/// Run `property` over `cases` deterministic random cases. Panics with the
/// failing seed on the first counterexample.
pub fn check<F>(name: &str, cases: u64, mut property: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    check_seeded(name, cases, 0xC0FFEE, &mut property);
}

/// Like [`check`] with an explicit base seed (use to replay a failure).
pub fn check_seeded<F>(name: &str, cases: u64, base_seed: u64, property: &mut F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed ^ case.wrapping_mul(0x9E3779B97F4A7C15);
        let mut rng = Rng::new(seed);
        if let Err(msg) = property(&mut rng) {
            panic!(
                "property '{name}' failed on case {case} (replay with seed {seed:#x}): {msg}"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_trivial_property() {
        check("u64 below bound", 100, |rng| {
            let n = 1 + rng.below(1000);
            let x = rng.below(n);
            if x < n {
                Ok(())
            } else {
                Err(format!("{x} >= {n}"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property 'always fails'")]
    fn reports_failure_with_seed() {
        check("always fails", 5, |_| Err("nope".into()));
    }
}
