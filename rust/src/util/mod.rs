//! Self-contained substrates the offline build cannot take from crates.io:
//! a seedable PRNG, a JSON parser/writer, a CLI argument parser, summary
//! statistics, an anyhow-style error type, and a miniature
//! property-testing harness.

pub mod cli;
pub mod error;
pub mod json;
pub mod linalg;
pub mod prop;
pub mod rng;
pub mod stats;
pub mod watchdog;
