//! A small command-line argument parser (the offline image has no clap).
//!
//! Supports `binary <subcommand> [--flag] [--key value] [--key=value]
//! [positional...]` — enough for the `parccm` launcher, the examples and
//! the bench binaries.

use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub flags: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Args {
    /// Parse from `std::env::args()` (skipping argv[0]).
    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    /// Parse an explicit iterator; the first non-dash token becomes the
    /// subcommand, later non-dash tokens are positional.
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut iter = items.into_iter().peekable();
        while let Some(tok) = iter.next() {
            if let Some(body) = tok.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.options.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(tok);
            } else {
                out.positional.push(tok);
            }
        }
        out
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.get(name)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got '{s}'")))
            .unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> f64 {
        self.get(name)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{name} expects a number, got '{s}'")))
            .unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.get(name)
            .map(|s| s.parse().unwrap_or_else(|_| panic!("--{name} expects an integer, got '{s}'")))
            .unwrap_or(default)
    }

    /// Comma-separated usize list, e.g. `--l 500,1000,2000`.
    pub fn get_usize_list(&self, name: &str, default: &[usize]) -> Vec<usize> {
        match self.get(name) {
            None => default.to_vec(),
            Some(s) => s
                .split(',')
                .map(|t| {
                    t.trim()
                        .parse()
                        .unwrap_or_else(|_| panic!("--{name}: bad integer '{t}'"))
                })
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("fig4 --full --seed 42 --l=500,1000 input.csv");
        assert_eq!(a.subcommand.as_deref(), Some("fig4"));
        assert!(a.flag("full"));
        assert_eq!(a.get_u64("seed", 0), 42);
        assert_eq!(a.get_usize_list("l", &[]), vec![500, 1000]);
        assert_eq!(a.positional, vec!["input.csv"]);
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert!(!a.flag("full"));
        assert_eq!(a.get_usize("r", 50), 50);
        assert_eq!(a.get_f64("alpha", 0.5), 0.5);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse("x --verbose");
        assert!(a.flag("verbose"));
    }

    #[test]
    fn option_value_dash_number() {
        let a = parse("x --k v --quiet");
        assert_eq!(a.get("k"), Some("v"));
        assert!(a.flag("quiet"));
    }
}
