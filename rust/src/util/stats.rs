//! Summary statistics shared by the bench harness, the metrics module and
//! the CCM result analysis.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator; 0 when n < 2).
pub fn stddev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Median (average of middle two for even n; 0 for empty).
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Linear-interpolated percentile in [0, 100].
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = (p / 100.0) * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (rank - lo as f64) * (v[hi] - v[lo])
    }
}

/// Pearson correlation between two equal-length slices (0 when degenerate —
/// matches the kernel/rEDM convention).
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    assert_eq!(x.len(), y.len());
    let n = x.len();
    if n == 0 {
        return 0.0;
    }
    let mx = mean(x);
    let my = mean(y);
    let mut cov = 0.0;
    let mut vx = 0.0;
    let mut vy = 0.0;
    for i in 0..n {
        let dx = x[i] - mx;
        let dy = y[i] - my;
        cov += dx * dy;
        vx += dx * dx;
        vy += dy * dy;
    }
    let denom = (vx * vy).sqrt();
    if denom > 0.0 {
        cov / denom
    } else {
        0.0
    }
}

/// Online mean/variance accumulator (Welford).
#[derive(Clone, Debug, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
}

impl Welford {
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
    }
    pub fn count(&self) -> u64 {
        self.n
    }
    pub fn mean(&self) -> f64 {
        self.mean
    }
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / (self.n - 1) as f64
        }
    }
    pub fn stddev(&self) -> f64 {
        self.variance().sqrt()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn basic_moments() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((stddev(&xs) - 2.138089935299395).abs() < 1e-12);
    }

    #[test]
    fn median_and_percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(median(&xs), 2.5);
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert_eq!(percentile(&[5.0], 37.0), 5.0);
    }

    #[test]
    fn pearson_known_values() {
        let x = [1.0, 2.0, 3.0, 4.0, 5.0];
        let y = [2.0, 4.0, 6.0, 8.0, 10.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let yneg: Vec<f64> = y.iter().map(|v| -v).collect();
        assert!((pearson(&x, &yneg) + 1.0).abs() < 1e-12);
        assert_eq!(pearson(&x, &[3.0; 5]), 0.0);
    }

    #[test]
    fn welford_matches_batch() {
        let xs = [0.5, 1.5, 2.5, 8.0, -3.0, 0.0];
        let mut w = Welford::default();
        for &x in &xs {
            w.push(x);
        }
        assert!((w.mean() - mean(&xs)).abs() < 1e-12);
        assert!((w.stddev() - stddev(&xs)).abs() < 1e-12);
        assert_eq!(w.count(), xs.len() as u64);
    }
}
