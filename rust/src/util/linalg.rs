//! Tiny dense linear algebra: solve A x = b via Gaussian elimination with
//! partial pivoting, and a ridge-regularized least-squares for the S-map
//! forecaster (the offline image has no LAPACK).

/// Solve `A x = b` in place for square `A` (row-major, n x n). Returns
/// `None` if the matrix is numerically singular.
pub fn solve(a: &mut [f64], b: &mut [f64], n: usize) -> Option<Vec<f64>> {
    assert_eq!(a.len(), n * n);
    assert_eq!(b.len(), n);
    for col in 0..n {
        // partial pivot
        let mut pivot = col;
        let mut best = a[col * n + col].abs();
        for row in (col + 1)..n {
            let v = a[row * n + col].abs();
            if v > best {
                best = v;
                pivot = row;
            }
        }
        if best < 1e-12 {
            return None;
        }
        if pivot != col {
            for k in 0..n {
                a.swap(col * n + k, pivot * n + k);
            }
            b.swap(col, pivot);
        }
        let diag = a[col * n + col];
        for row in (col + 1)..n {
            let factor = a[row * n + col] / diag;
            if factor == 0.0 {
                continue;
            }
            for k in col..n {
                a[row * n + k] -= factor * a[col * n + k];
            }
            b[row] -= factor * b[col];
        }
    }
    // back substitution
    let mut x = vec![0.0f64; n];
    for row in (0..n).rev() {
        let mut acc = b[row];
        for k in (row + 1)..n {
            acc -= a[row * n + k] * x[k];
        }
        x[row] = acc / a[row * n + row];
    }
    Some(x)
}

/// Weighted ridge least squares: minimize ||W^(1/2)(X beta - y)||^2 +
/// ridge*||beta||^2 over rows of X (`rows` x `cols`, row-major), weights
/// `w` per row. Returns beta (`cols`). Used by the S-map local linear fit.
pub fn weighted_ridge_lstsq(
    x: &[f64],
    y: &[f64],
    w: &[f64],
    rows: usize,
    cols: usize,
    ridge: f64,
) -> Option<Vec<f64>> {
    assert_eq!(x.len(), rows * cols);
    assert_eq!(y.len(), rows);
    assert_eq!(w.len(), rows);
    // normal equations: (X^T W X + ridge I) beta = X^T W y
    let mut ata = vec![0.0f64; cols * cols];
    let mut atb = vec![0.0f64; cols];
    for r in 0..rows {
        let wr = w[r];
        if wr == 0.0 {
            continue;
        }
        let row = &x[r * cols..(r + 1) * cols];
        for i in 0..cols {
            let wxi = wr * row[i];
            atb[i] += wxi * y[r];
            for j in i..cols {
                ata[i * cols + j] += wxi * row[j];
            }
        }
    }
    // symmetrize + ridge
    for i in 0..cols {
        for j in 0..i {
            ata[i * cols + j] = ata[j * cols + i];
        }
        ata[i * cols + i] += ridge;
    }
    solve(&mut ata, &mut atb, cols)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn solves_known_system() {
        // [2 1; 1 3] x = [5; 10] -> x = [1; 3]
        let mut a = vec![2.0, 1.0, 1.0, 3.0];
        let mut b = vec![5.0, 10.0];
        let x = solve(&mut a, &mut b, 2).unwrap();
        assert!((x[0] - 1.0).abs() < 1e-12);
        assert!((x[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn pivoting_handles_zero_diagonal() {
        let mut a = vec![0.0, 1.0, 1.0, 0.0];
        let mut b = vec![2.0, 3.0];
        let x = solve(&mut a, &mut b, 2).unwrap();
        assert!((x[0] - 3.0).abs() < 1e-12);
        assert!((x[1] - 2.0).abs() < 1e-12);
    }

    #[test]
    fn singular_returns_none() {
        let mut a = vec![1.0, 2.0, 2.0, 4.0];
        let mut b = vec![1.0, 2.0];
        assert!(solve(&mut a, &mut b, 2).is_none());
    }

    #[test]
    fn lstsq_recovers_linear_model() {
        // y = 3 + 2*x, exact fit with intercept column
        let rows = 5;
        let mut x = Vec::new();
        let mut y = Vec::new();
        for i in 0..rows {
            let xi = i as f64;
            x.extend_from_slice(&[1.0, xi]);
            y.push(3.0 + 2.0 * xi);
        }
        let w = vec![1.0; rows];
        let beta = weighted_ridge_lstsq(&x, &y, &w, rows, 2, 0.0).unwrap();
        assert!((beta[0] - 3.0).abs() < 1e-9);
        assert!((beta[1] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn weights_downweight_outliers() {
        // one wild outlier with zero weight must not affect the fit
        let x = vec![1.0, 0.0, 1.0, 1.0, 1.0, 2.0, 1.0, 3.0];
        let y = vec![0.0, 1.0, 2.0, 100.0];
        let w = vec![1.0, 1.0, 1.0, 0.0];
        let beta = weighted_ridge_lstsq(&x, &y, &w, 4, 2, 0.0).unwrap();
        assert!((beta[0] - 0.0).abs() < 1e-9);
        assert!((beta[1] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ridge_shrinks_towards_zero() {
        let x = vec![1.0, 1.0, 1.0, 1.0]; // 4 rows, 1 col of ones
        let y = vec![2.0, 2.0, 2.0, 2.0];
        let w = vec![1.0; 4];
        let none = weighted_ridge_lstsq(&x, &y, &w, 4, 1, 0.0).unwrap();
        let some = weighted_ridge_lstsq(&x, &y, &w, 4, 1, 4.0).unwrap();
        assert!((none[0] - 2.0).abs() < 1e-9);
        assert!(some[0] < none[0]); // (X'X + r)^-1 shrinks
    }
}
