//! anyhow-lite: the string-chained error type the offline build cannot
//! take from crates.io. API-compatible with the subset of `anyhow` this
//! crate uses — `Result`, `anyhow!`, `bail!`, and the `Context` extension
//! trait on both `Result` and `Option` — so call sites read identically.
//!
//! Context wrapping is eager (the chain is flattened into one message at
//! wrap time). That loses lazy formatting but keeps the type a plain
//! `String` wrapper: `Send + Sync + 'static`, no allocator tricks, no
//! downcasting — all this crate's error paths are terminal reporting.

use std::fmt;

/// A flattened error message (optionally with a `: `-joined context chain).
pub struct Error {
    msg: String,
}

impl Error {
    /// Build from a preformatted message (what `anyhow!` expands to).
    pub fn msg(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }

    /// Prepend a context layer, anyhow-style (`"context: cause"`).
    pub fn wrap(self, context: impl fmt::Display) -> Error {
        Error { msg: format!("{context}: {}", self.msg) }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<std::fmt::Error> for Error {
    fn from(e: std::fmt::Error) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<String> for Error {
    fn from(msg: String) -> Error {
        Error::msg(msg)
    }
}

impl From<&str> for Error {
    fn from(msg: &str) -> Error {
        Error::msg(msg)
    }
}

/// Drop-in for `anyhow::Result`.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a fallible value (`anyhow::Context` subset).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{context}: {e}")))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context.to_string()))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// `anyhow!`: format an [`Error`] value.
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// `bail!`: early-return a formatted [`Error`].
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::util::error::Error::msg(format!($($arg)*)))
    };
}

pub(crate) use anyhow;
pub(crate) use bail;

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/file").context("reading config")?;
        Ok(())
    }

    #[test]
    fn context_chains_messages() {
        let err = fails_io().unwrap_err();
        let text = err.to_string();
        assert!(text.starts_with("reading config: "), "{text}");
    }

    #[test]
    fn option_context_and_macros() {
        let missing: Option<usize> = None;
        let err = missing.context("field absent").unwrap_err();
        assert_eq!(err.to_string(), "field absent");
        let e = anyhow!("bad value {}", 42);
        assert_eq!(e.to_string(), "bad value 42");
        let f = || -> Result<()> { bail!("nope {}", "x") };
        assert_eq!(f().unwrap_err().to_string(), "nope x");
    }

    #[test]
    fn question_mark_converts_io() {
        let f = || -> Result<String> { Ok(std::fs::read_to_string("/no/such")?) };
        assert!(f().is_err());
    }
}
