//! # parccm — Parallel Convergent Cross Mapping
//!
//! A production-grade reproduction of *"Parallelizing Convergent Cross
//! Mapping Using Apache Spark"* (Pu, Duan, Osgood — CS.DC 2019) as a
//! three-layer Rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the coordination contribution: a from-scratch
//!   Spark-like engine ([`engine`]: lazy RDD lineage, transform pipelines,
//!   DAG scheduler, executor pools, broadcast variables, asynchronous job
//!   futures, and a discrete-event cluster simulator), plus the CCM
//!   driver that maps the paper's five implementation levels (Table 1,
//!   cases A1–A5) onto it ([`ccm`]).
//! * **L2/L1 (python/, build-time only)** — the CCM numerics as a JAX
//!   graph over Pallas kernels (pairwise distances on the MXU, k-pass
//!   top-k, simplex projection, Pearson skill), AOT-lowered to HLO text.
//! * **Runtime bridge** ([`runtime`]) — a PJRT CPU client that loads the
//!   AOT artifacts and executes them from the Rust hot path; Python never
//!   runs after `make artifacts`.
//!
//! The pure-Rust [`native`] backend implements the same kernel contract
//! and cross-checks the XLA path bit-for-bit at test time; [`baseline`]
//! holds the single-threaded rEDM-style comparator from the paper's §4.1.
//!
//! See `DESIGN.md` for the full system inventory and the experiment index
//! mapping every table/figure of the paper to a bench target.

pub mod baseline;
pub mod bench;
pub mod ccm;
pub mod engine;
pub mod native;
pub mod runtime;
pub mod timeseries;
pub mod util;

/// Embedding vectors are zero-padded to this many lanes in every backend
/// and artifact (padding is distance-invariant). Must match
/// `python/compile/kernels/__init__.py::EMAX`.
pub const EMAX: usize = 8;

/// Top-k always extracts this many neighbours; the simplex stage masks down
/// to E+1. Must match `KMAX` on the Python side.
pub const KMAX: usize = 11;

/// Additive distance mask for invalid / excluded library rows. Must match
/// `BIG` on the Python side.
pub const BIG: f32 = 1e30;
