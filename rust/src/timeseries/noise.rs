//! Observational noise models — used by the robustness example
//! (Mønster et al. 2017 studied CCM under noise; our
//! `examples/noise_robustness.rs` sweeps these).

use crate::util::rng::Rng;

/// Add zero-mean gaussian observation noise with standard deviation
/// `sigma * std(series)` (i.e. `sigma` is a *relative* noise level).
pub fn add_gaussian(series: &[f32], sigma_rel: f64, seed: u64) -> Vec<f32> {
    if series.is_empty() {
        return Vec::new();
    }
    let mean = series.iter().map(|&v| v as f64).sum::<f64>() / series.len() as f64;
    let var = series
        .iter()
        .map(|&v| (v as f64 - mean).powi(2))
        .sum::<f64>()
        / series.len() as f64;
    let sd = var.sqrt();
    let mut rng = Rng::new(seed);
    series
        .iter()
        .map(|&v| (v as f64 + rng.normal() * sigma_rel * sd) as f32)
        .collect()
}

/// Replace a fraction `frac` of points with linear interpolation of their
/// neighbours (simulates gap-filled sensor dropouts).
pub fn dropout_interpolate(series: &[f32], frac: f64, seed: u64) -> Vec<f32> {
    let mut out = series.to_vec();
    if series.len() < 3 || frac <= 0.0 {
        return out;
    }
    let mut rng = Rng::new(seed);
    let k = ((series.len() - 2) as f64 * frac.min(1.0)) as usize;
    let idx = rng.sample_indices(series.len() - 2, k);
    for i in idx {
        let i = i + 1; // keep endpoints
        out[i] = (series[i - 1] + series[i + 1]) / 2.0;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gaussian_noise_scales_with_sigma() {
        let base: Vec<f32> = (0..1000).map(|i| ((i as f32) * 0.1).sin()).collect();
        let noisy = add_gaussian(&base, 0.5, 1);
        let diff: f64 = base
            .iter()
            .zip(&noisy)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            / base.len() as f64;
        assert!(diff > 0.0);
        let clean = add_gaussian(&base, 0.0, 1);
        assert_eq!(clean, base);
    }

    #[test]
    fn dropout_preserves_length_and_endpoints() {
        let base: Vec<f32> = (0..100).map(|i| i as f32).collect();
        let out = dropout_interpolate(&base, 0.3, 7);
        assert_eq!(out.len(), 100);
        assert_eq!(out[0], base[0]);
        assert_eq!(out[99], base[99]);
        // linear series: interpolation is exact
        assert_eq!(out, base);
    }

    #[test]
    fn empty_and_tiny_series_safe() {
        assert!(add_gaussian(&[], 0.1, 0).is_empty());
        assert_eq!(dropout_interpolate(&[1.0, 2.0], 0.5, 0), vec![1.0, 2.0]);
    }
}
