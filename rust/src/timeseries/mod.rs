//! Workload substrate: synthetic dynamical systems, noise models, CSV IO,
//! and the small real-world dataset used by the examples.
//!
//! The paper evaluates on generated time series of length 4000; its
//! motivating example is a hare/lynx predator-prey system. We provide the
//! coupled logistic maps from Sugihara et al. 2012 (the canonical CCM
//! benchmark), a Lorenz-63 integrator for a continuous-time workload, and
//! the 1900-1920 Hudson Bay hare/lynx record for the real-data example.

pub mod data;
pub mod generators;
pub mod io;
pub mod noise;

pub use generators::{ar1, coupled_logistic, lorenz63, CoupledLogisticParams};
