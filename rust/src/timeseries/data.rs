//! Small embedded real-world dataset: the Hudson Bay Company hare & lynx
//! pelt counts, 1900–1920 (thousands of pelts) — the classic predator-prey
//! record the paper's introduction motivates CCM with ("for each timepoint
//! X measures the count of hares, and Y that of lynx").
//!
//! Source: Odum (1953) after MacLulich (1937); public-domain figures widely
//! reproduced in ecology texts.

/// Years covered by [`HARES`] / [`LYNX`].
pub const YEARS: [u16; 21] = [
    1900, 1901, 1902, 1903, 1904, 1905, 1906, 1907, 1908, 1909, 1910, 1911, 1912, 1913, 1914,
    1915, 1916, 1917, 1918, 1919, 1920,
];

/// Snowshoe hare pelts, thousands.
pub const HARES: [f32; 21] = [
    30.0, 47.2, 70.2, 77.4, 36.3, 20.6, 18.1, 21.4, 22.0, 25.4, 27.1, 40.3, 57.0, 76.6, 52.3,
    19.5, 11.2, 7.6, 14.6, 16.2, 24.7,
];

/// Canada lynx pelts, thousands.
pub const LYNX: [f32; 21] = [
    4.0, 6.1, 9.8, 35.2, 59.4, 41.7, 19.0, 13.0, 8.3, 9.1, 7.4, 8.0, 12.3, 19.5, 45.7, 51.1,
    29.7, 15.8, 9.7, 10.1, 8.6,
];

/// Linear-interpolation upsampling (factor `k`) — 21 yearly points are far
/// too few for CCM (which needs n ~ 10^3); the predator-prey *example*
/// interpolates to a dense series to exercise the pipeline on real-shaped
/// data while documenting that this is a demonstration, not ecology.
pub fn upsample_linear(series: &[f32], k: usize) -> Vec<f32> {
    if series.len() < 2 || k <= 1 {
        return series.to_vec();
    }
    let mut out = Vec::with_capacity((series.len() - 1) * k + 1);
    for w in series.windows(2) {
        for j in 0..k {
            let t = j as f32 / k as f32;
            out.push(w[0] * (1.0 - t) + w[1] * t);
        }
    }
    out.push(*series.last().unwrap());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arrays_aligned() {
        assert_eq!(YEARS.len(), HARES.len());
        assert_eq!(YEARS.len(), LYNX.len());
    }

    #[test]
    fn upsample_endpoints_and_length() {
        let up = upsample_linear(&HARES, 10);
        assert_eq!(up.len(), (HARES.len() - 1) * 10 + 1);
        assert_eq!(up[0], HARES[0]);
        assert_eq!(*up.last().unwrap(), *HARES.last().unwrap());
        // original samples preserved every k
        for (i, &h) in HARES.iter().enumerate().take(HARES.len() - 1) {
            assert!((up[i * 10] - h).abs() < 1e-6);
        }
    }

    #[test]
    fn upsample_degenerate() {
        assert_eq!(upsample_linear(&[1.0], 5), vec![1.0]);
        assert_eq!(upsample_linear(&HARES, 1), HARES.to_vec());
    }
}
