//! Synthetic dynamical systems for CCM workloads.

use crate::util::rng::Rng;

/// Parameters of the Sugihara et al. (2012) coupled logistic maps:
///
/// ```text
/// x[t+1] = x[t] (rx - rx x[t] - bxy y[t])
/// y[t+1] = y[t] (ry - ry y[t] - byx x[t])
/// ```
///
/// `byx` is the strength with which **X drives Y**; `bxy` the reverse.
/// The defaults give strong X->Y and weak Y->X coupling — the asymmetry
/// CCM is expected to detect.
#[derive(Clone, Copy, Debug)]
pub struct CoupledLogisticParams {
    pub rx: f64,
    pub ry: f64,
    pub bxy: f64,
    pub byx: f64,
    pub x0: f64,
    pub y0: f64,
    /// Transient steps discarded before recording.
    pub discard: usize,
}

impl Default for CoupledLogisticParams {
    fn default() -> Self {
        CoupledLogisticParams {
            rx: 3.8,
            ry: 3.5,
            bxy: 0.02,
            byx: 0.1,
            x0: 0.4,
            y0: 0.2,
            discard: 300,
        }
    }
}

/// Generate `n` samples of the coupled logistic system; returns `(x, y)`.
pub fn coupled_logistic(n: usize, p: CoupledLogisticParams) -> (Vec<f32>, Vec<f32>) {
    let total = n + p.discard;
    let mut x = p.x0;
    let mut y = p.y0;
    let mut xs = Vec::with_capacity(n);
    let mut ys = Vec::with_capacity(n);
    for t in 0..total {
        if t >= p.discard {
            xs.push(x as f32);
            ys.push(y as f32);
        }
        let nx = x * (p.rx - p.rx * x - p.bxy * y);
        let ny = y * (p.ry - p.ry * y - p.byx * x);
        x = nx;
        y = ny;
    }
    (xs, ys)
}

/// Lorenz-63 integrated with fixed-step RK4, sampled every `sample_dt`.
/// Returns the three coordinates; CCM on (x, z) is the classic example of
/// bidirectional coupling within one attractor.
pub fn lorenz63(n: usize, dt: f64, sample_every: usize) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
    const SIGMA: f64 = 10.0;
    const RHO: f64 = 28.0;
    const BETA: f64 = 8.0 / 3.0;
    let f = |s: [f64; 3]| {
        [
            SIGMA * (s[1] - s[0]),
            s[0] * (RHO - s[2]) - s[1],
            s[0] * s[1] - BETA * s[2],
        ]
    };
    let mut s = [1.0, 1.0, 1.0];
    // transient
    for _ in 0..5000 {
        s = rk4_step(&f, s, dt);
    }
    let mut xs = Vec::with_capacity(n);
    let mut ys = Vec::with_capacity(n);
    let mut zs = Vec::with_capacity(n);
    for _ in 0..n {
        for _ in 0..sample_every.max(1) {
            s = rk4_step(&f, s, dt);
        }
        xs.push(s[0] as f32);
        ys.push(s[1] as f32);
        zs.push(s[2] as f32);
    }
    (xs, ys, zs)
}

fn rk4_step<F: Fn([f64; 3]) -> [f64; 3]>(f: &F, s: [f64; 3], dt: f64) -> [f64; 3] {
    let add = |a: [f64; 3], b: [f64; 3], c: f64| [a[0] + c * b[0], a[1] + c * b[1], a[2] + c * b[2]];
    let k1 = f(s);
    let k2 = f(add(s, k1, dt / 2.0));
    let k3 = f(add(s, k2, dt / 2.0));
    let k4 = f(add(s, k3, dt));
    [
        s[0] + dt / 6.0 * (k1[0] + 2.0 * k2[0] + 2.0 * k3[0] + k4[0]),
        s[1] + dt / 6.0 * (k1[1] + 2.0 * k2[1] + 2.0 * k3[1] + k4[1]),
        s[2] + dt / 6.0 * (k1[2] + 2.0 * k2[2] + 2.0 * k3[2] + k4[2]),
    ]
}

/// AR(1) noise process `x[t+1] = phi x[t] + eps` — a *non-coupled* control
/// series: CCM against it should show no convergent skill.
pub fn ar1(n: usize, phi: f64, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed);
    let mut x = 0.0f64;
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        x = phi * x + rng.normal();
        out.push(x as f32);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn coupled_logistic_stays_in_unit_interval() {
        let (x, y) = coupled_logistic(4000, CoupledLogisticParams::default());
        assert_eq!(x.len(), 4000);
        assert!(x.iter().all(|&v| (0.0..=1.0).contains(&v)), "x escaped [0,1]");
        assert!(y.iter().all(|&v| (0.0..=1.0).contains(&v)), "y escaped [0,1]");
        // chaotic, not constant
        let mean = x.iter().map(|&v| v as f64).sum::<f64>() / 4000.0;
        assert!(x.iter().any(|&v| (v as f64 - mean).abs() > 0.1));
    }

    #[test]
    fn coupled_logistic_deterministic() {
        let p = CoupledLogisticParams::default();
        assert_eq!(coupled_logistic(100, p).0, coupled_logistic(100, p).0);
    }

    #[test]
    fn lorenz_is_bounded_and_chaotic() {
        let (x, _, z) = lorenz63(2000, 0.01, 2);
        assert_eq!(x.len(), 2000);
        assert!(x.iter().all(|v| v.abs() < 100.0));
        assert!(z.iter().all(|v| v.abs() < 100.0));
        let first = &x[..1000];
        let second = &x[1000..];
        let m1 = first.iter().sum::<f32>() / 1000.0;
        assert!(second.iter().any(|&v| (v - m1).abs() > 1.0));
    }

    #[test]
    fn ar1_moments() {
        let xs = ar1(20_000, 0.6, 9);
        let mean = xs.iter().map(|&v| v as f64).sum::<f64>() / xs.len() as f64;
        assert!(mean.abs() < 0.1, "mean {mean}");
        // stationary variance = 1 / (1 - phi^2) = 1.5625
        let var = xs.iter().map(|&v| (v as f64 - mean).powi(2)).sum::<f64>() / xs.len() as f64;
        assert!((var - 1.5625).abs() < 0.2, "var {var}");
    }
}
